//! Packing benchmarks — regenerates paper Fig. 18 (packing efficiency) and
//! Prop. 14 (padding-waste reduction), and times the BFD implementation
//! itself (the §S4.2 "under 2 seconds for Alpaca-52k" claim). Pure host
//! code: no backend or artifacts needed.
//!
//! Writes the headline numbers into the repo-root `BENCH_cpu.json`
//! (section `"packing"`).
//!
//! Run: `cargo bench --bench bench_packing`

use chronicals::data_source::JsonlSource;
use chronicals::harness;
use chronicals::packing::*;
use chronicals::report;
use chronicals::session::ExampleSource;
use chronicals::util::json::{Json, Obj};
use chronicals::util::rng::Rng;
use std::path::PathBuf;
use std::time::Instant;

/// BFD efficiency on the checked-in real corpus vs a synthetic corpus of
/// the same size, at the reference row capacity (DESIGN.md §8: the packing
/// story must hold on an actual length distribution, not only on the
/// log-normal generator it was tuned against).
fn real_vs_synthetic(section: &mut Obj) {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../data/sample.jsonl");
    let src = JsonlSource::new(&path, 7, 1024);
    let exs = match src.examples(64) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping real-corpus section: {e:#}");
            return;
        }
    };
    let capacity = 64; // reference backend row capacity
    let real: Vec<usize> = exs.iter().map(|e| e.len()).collect();
    let (_tok, synth) = harness::build_corpus(real.len(), 7, 64, capacity);
    let synth: Vec<usize> = synth.iter().map(|e| e.len()).collect();

    println!("\n| corpus              | n    | padded eff | bfd eff | recovery |");
    println!("|---------------------|------|------------|---------|----------|");
    let mut rows = Obj::default();
    for (name, lengths) in [("real (sample.jsonl)", &real), ("synthetic", &synth)] {
        let padded = no_packing(lengths, capacity);
        let packed = best_fit_decreasing(lengths, capacity);
        let recovery = if padded.waste() > 0.0 {
            ((padded.waste() - packed.waste()) / padded.waste()).clamp(0.0, 1.0)
        } else {
            0.0
        };
        println!(
            "| {name:<19} | {:<4} | {:>9.1}% | {:>6.1}% | {:>7.1}% |",
            lengths.len(),
            padded.efficiency() * 100.0,
            packed.efficiency() * 100.0,
            recovery * 100.0
        );
        let mut row = Obj::default();
        row.insert("n", Json::Num(lengths.len() as f64));
        row.insert("padded_efficiency", Json::Num(padded.efficiency()));
        row.insert("bfd_efficiency", Json::Num(packed.efficiency()));
        row.insert("padding_recovery", Json::Num(recovery));
        row.insert("oversized", Json::Num(packed.oversized.len() as f64));
        let key = if name.starts_with("real") { "real_sample" } else { "synthetic" };
        rows.insert(key, Json::Obj(row));
    }
    section.insert("real_vs_synthetic_cap64", Json::Obj(rows));
}

fn main() {
    // Fig. 18 tables at two capacities
    for capacity in [512usize, 2048] {
        println!("{}", harness::packing_report(capacity, 4096));
    }

    // BFD wall-clock at Alpaca-52k scale (52,000 sequences)
    let mut rng = Rng::new(52);
    let lengths: Vec<usize> = (0..52_000)
        .map(|_| (rng.lognormal(6.0, 0.6) as usize).clamp(16, 2048))
        .collect();
    let t0 = Instant::now();
    let p = best_fit_decreasing(&lengths, 2048);
    let bfd_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "BFD over 52,000 sequences: {bfd_ms:.1} ms -> {} bins, {:.1}% efficiency",
        p.n_bins(),
        p.efficiency() * 100.0
    );
    println!("(paper §S4.2: 'completes in under 2 seconds on a single CPU core')");

    // algorithm scaling comparison
    let mut algo_ms = Obj::default();
    println!("\n| n       | BFD ms | FFD ms | NF ms |");
    println!("|---------|--------|--------|-------|");
    for n in [1_000usize, 10_000, 52_000] {
        let ls = &lengths[..n];
        let time = |f: &dyn Fn(&[usize], usize) -> Packing| {
            let t = Instant::now();
            let _ = f(ls, 2048);
            t.elapsed().as_secs_f64() * 1e3
        };
        let (b, f, nf) = (
            time(&best_fit_decreasing),
            time(&first_fit_decreasing),
            time(&next_fit),
        );
        println!("| {n:<7} | {b:>6.1} | {f:>6.1} | {nf:>5.1} |");
        let mut row = Obj::default();
        row.insert("bfd_ms", Json::Num(b));
        row.insert("ffd_ms", Json::Num(f));
        row.insert("next_fit_ms", Json::Num(nf));
        algo_ms.insert(format!("n_{n}"), Json::Obj(row));
    }

    let mut section = Obj::default();
    section.insert("alpaca_52k_bfd_ms", Json::Num(bfd_ms));
    section.insert("alpaca_52k_bins", Json::Num(p.n_bins() as f64));
    section.insert("alpaca_52k_efficiency", Json::Num(p.efficiency()));
    section.insert("scaling", Json::Obj(algo_ms));
    real_vs_synthetic(&mut section);
    let path = report::bench_json_path();
    match report::update_bench_json(&path, "packing", Json::Obj(section)) {
        Ok(()) => println!("\nwrote packing numbers to {}", path.display()),
        Err(e) => eprintln!("could not update {}: {e:#}", path.display()),
    }
}
