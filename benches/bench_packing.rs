//! Packing benchmarks — regenerates paper Fig. 18 (packing efficiency) and
//! Prop. 14 (padding-waste reduction), and times the BFD implementation
//! itself (the §S4.2 "under 2 seconds for Alpaca-52k" claim).
//!
//! Run: `cargo bench --bench bench_packing`

use chronicals::harness;
use chronicals::packing::*;
use chronicals::util::rng::Rng;
use std::time::Instant;

fn main() {
    // Fig. 18 tables at two capacities
    for capacity in [512usize, 2048] {
        println!("{}", harness::packing_report(capacity, 4096));
    }

    // BFD wall-clock at Alpaca-52k scale (52,000 sequences)
    let mut rng = Rng::new(52);
    let lengths: Vec<usize> = (0..52_000)
        .map(|_| (rng.lognormal(6.0, 0.6) as usize).clamp(16, 2048))
        .collect();
    let t0 = Instant::now();
    let p = best_fit_decreasing(&lengths, 2048);
    let dt = t0.elapsed();
    println!(
        "BFD over 52,000 sequences: {:.1} ms -> {} bins, {:.1}% efficiency",
        dt.as_secs_f64() * 1e3,
        p.n_bins(),
        p.efficiency() * 100.0
    );
    println!("(paper §S4.2: 'completes in under 2 seconds on a single CPU core')");

    // algorithm scaling comparison
    println!("\n| n       | BFD ms | FFD ms | NF ms |");
    println!("|---------|--------|--------|-------|");
    for n in [1_000usize, 10_000, 52_000] {
        let ls = &lengths[..n];
        let time = |f: &dyn Fn(&[usize], usize) -> Packing| {
            let t = Instant::now();
            let _ = f(ls, 2048);
            t.elapsed().as_secs_f64() * 1e3
        };
        println!(
            "| {:<7} | {:>6.1} | {:>6.1} | {:>5.1} |",
            n,
            time(&best_fit_decreasing),
            time(&first_fit_decreasing),
            time(&next_fit)
        );
    }
}
