//! Quantization + summation benchmarks — regenerates the paper's §S11/§S16
//! error tables (int8 Eq. 18, FP8 Prop. 12/Thm. 11) and the §S2.4 Kahan
//! accuracy/cost trade-off, plus the DESIGN.md §12 memory-tier ladder
//! (resident state bytes + tok/s per tier on the fast CPU backend).
//! Hermetic: no artifacts or network needed.
//!
//! Writes the headline numbers into the repo-root `BENCH_cpu.json`
//! (sections `"quant"` and `"memory_tiers"`).
//!
//! Run: `cargo bench --bench bench_quant`
//! Env: STEPS (default 12) — measured steps per memory-tier rung.

use chronicals::backend::cpu::model as cpu_model;
use chronicals::backend::cpu_fast::FastCpuBackend;
use chronicals::backend::{Backend, DeviceState, MemoryCfg};
use chronicals::quant::*;
use chronicals::report;
use chronicals::session::{BackendSpec, DataSource, PackingStrategy, SessionBuilder, Task};
use chronicals::util::json::{Json, Obj};
use chronicals::util::rng::Rng;
use std::time::Instant;

/// The memory-tier ladder (DESIGN.md §12): each rung names the optimizer
/// state codec, frozen-base codec and checkpoint segment count it lowers.
const TIERS: [(&str, OptimStates, Option<BaseQuant>, usize); 5] = [
    ("legacy", OptimStates::Fp32, None, 0),
    ("int8_optim", OptimStates::Int8, None, 0),
    ("int8_base", OptimStates::Fp32, Some(BaseQuant::Int8), 0),
    ("fp8_base", OptimStates::Fp32, Some(BaseQuant::Fp8), 0),
    ("all_tiers", OptimStates::Int8, Some(BaseQuant::Int8), 2),
];

/// State-byte accounting for one tier on the fast backend's LoRA state
/// (after `configure_memory`, exactly the bytes a training run holds).
fn tier_bytes(optim: OptimStates, base: Option<BaseQuant>) -> Option<(usize, usize)> {
    let be = FastCpuBackend::new();
    let mut state = be.init_state("init_lora", 42).ok()?;
    let mem = MemoryCfg { optim_states: optim, base_quant: base, ckpt_segments: 0 };
    if !mem.is_default() {
        be.configure_memory(&mut state, &mem).ok()?;
    }
    match &state {
        DeviceState::Cpu(s) => {
            Some((cpu_model::optim_state_bytes(s), cpu_model::base_weight_bytes(s)))
        }
        #[cfg(feature = "pjrt")]
        _ => None,
    }
}

/// End-to-end tok/s + final loss for one tier: a short LoRA run on the
/// fast backend with the tier lowered through the session seam.
fn tier_run(
    steps: u64,
    optim: OptimStates,
    base: Option<BaseQuant>,
    segs: usize,
) -> Option<(f64, f32)> {
    let mut builder = SessionBuilder::new()
        .task(Task::lora())
        .steps(steps)
        .meter_warmup(2)
        .lr(2e-3)
        .packing(PackingStrategy::Bfd)
        .data(DataSource::synthetic(384, 42, 96))
        .backend(BackendSpec::CpuFast { threads: 0 })
        .optim_states(optim)
        .ckpt_segments(segs);
    if let Some(q) = base {
        builder = builder.base_quant(q);
    }
    match builder.build().and_then(|mut session| session.run()) {
        Ok(r) => Some((r.summary.tokens_per_sec, r.summary.last_loss)),
        Err(e) => {
            eprintln!("memory-tier run failed ({optim:?}/{base:?}/{segs}): {e:#}");
            None
        }
    }
}

fn main() {
    let mut rng = Rng::new(88);
    let n = 1 << 20;
    let x: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.1) as f32).collect();
    let mut section = Obj::default();

    // int8 block-wise: error + throughput at the paper's block sizes
    let mut int8 = Obj::default();
    println!("| int8 block | max err     | bound α/127 | quantize MB/s |");
    println!("|------------|-------------|-------------|---------------|");
    for block in [64usize, 128, 2048] {
        let t0 = Instant::now();
        let q = int8_quantize(&x, block);
        let dt = t0.elapsed().as_secs_f64();
        let back = int8_dequantize(&q);
        let err = x
            .iter()
            .zip(&back)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let mb_s = (n * 4) as f64 / dt / 1e6;
        println!(
            "| {:<10} | {:<11.3e} | {:<11.3e} | {:<13.0} |",
            block,
            err,
            amax / 127.0,
            mb_s
        );
        let mut row = Obj::default();
        row.insert("max_err", Json::Num(err as f64));
        row.insert("quantize_mb_per_s", Json::Num(mb_s));
        int8.insert(format!("block_{block}"), Json::Obj(row));
    }
    section.insert("int8", Json::Obj(int8));

    // FP8 formats: measured SNR vs the Thm. 11 formula (the formula is the
    // uniform-quantization lower bound; measured SNR exceeds it)
    let mut fp8 = Obj::default();
    println!("\n| format | measured SNR dB | formula dB | max rel err |");
    println!("|--------|-----------------|------------|-------------|");
    for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
        let xs: Vec<f32> = (0..65536)
            .map(|_| (rng.normal().abs().max(0.03) * 8.0) as f32)
            .collect();
        let q = fp8_decode(&xs, fmt);
        let sig: f64 = xs.iter().map(|&v| (v as f64).powi(2)).sum();
        let noise: f64 = xs
            .iter()
            .zip(&q)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let snr = 10.0 * (sig / noise.max(1e-30)).log10();
        let rel = xs
            .iter()
            .zip(&q)
            .map(|(a, b)| ((a - b) / a).abs())
            .fold(0.0f32, f32::max);
        println!(
            "| {:?}  | {:>15.1} | {:>10.1} | {:<11.4} |",
            fmt,
            snr,
            fmt.snr_db(),
            rel
        );
        let mut row = Obj::default();
        row.insert("snr_db", Json::Num(snr));
        row.insert("formula_db", Json::Num(fmt.snr_db()));
        row.insert("max_rel_err", Json::Num(rel as f64));
        fp8.insert(format!("{fmt:?}"), Json::Obj(row));
    }
    section.insert("fp8", Json::Obj(fp8));

    // Kahan vs naive: accuracy and cost on gradient-accumulation-shaped data
    let adversarial: Vec<f32> = std::iter::once(1e8f32)
        .chain((0..n).map(|_| 1.0f32 + (rng.f64() as f32) * 1e-3))
        .collect();
    let exact: f64 = adversarial.iter().map(|&v| v as f64).sum();
    let t0 = Instant::now();
    let ks = kahan_sum(&adversarial);
    let t_k = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let ns = naive_sum(&adversarial);
    let t_n = t0.elapsed().as_secs_f64();
    println!("\nKahan vs naive over {} elements (adversarial head):", adversarial.len());
    println!(
        "  kahan: err {:.3e} in {:.2} ms | naive: err {:.3e} in {:.2} ms | {:.1}x cost for {:.0}x accuracy",
        (ks as f64 - exact).abs(),
        t_k * 1e3,
        (ns as f64 - exact).abs(),
        t_n * 1e3,
        t_k / t_n.max(1e-9),
        ((ns as f64 - exact).abs() / (ks as f64 - exact).abs().max(1e-12)).max(1.0)
    );
    let mut kahan = Obj::default();
    kahan.insert("kahan_err", Json::Num((ks as f64 - exact).abs()));
    kahan.insert("naive_err", Json::Num((ns as f64 - exact).abs()));
    kahan.insert("kahan_ms", Json::Num(t_k * 1e3));
    kahan.insert("naive_ms", Json::Num(t_n * 1e3));
    section.insert("kahan", Json::Obj(kahan));

    // delayed-scaler stability (paper §S16.2/Prop. 25): with noisy per-step
    // amax, immediate scaling jitters every step (oscillating quantization
    // grids amplify noise); the 32-window max holds the scale nearly
    // constant. Metric: std of log2(scale) over a noisy amax stream.
    let mut delayed = DelayedScaler::new(32, Fp8Format::E4M3);
    let mut imm_log = Vec::new();
    let mut del_log = Vec::new();
    for _ in 0..1000 {
        // log-normal step-to-step amax noise (the §S16.2 oscillation regime)
        let amax = rng.lognormal(0.0, 0.5) as f32;
        imm_log.push((amax / 448.0).log2());
        del_log.push(delayed.update(amax).log2());
    }
    // per-step scale movement: immediate scaling re-quantizes the whole
    // tensor against a different grid every step; delayed holds the
    // window max and moves only when the max rolls over.
    let jitter = |v: &[f32]| {
        v.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f32>() / (v.len() - 1) as f32
    };
    let (ji, jd) = (jitter(&imm_log), jitter(&del_log));
    println!(
        "\ndelayed scaling (Alg. 27): mean per-step |Δlog2 scale|: immediate {ji:.3}, \
         delayed {jd:.4} ({:.0}% reduction; paper: delayed scaling reduced \
         FP8 loss spikes 73%)",
        (1.0 - jd as f64 / ji as f64) * 100.0
    );
    let mut scaler = Obj::default();
    scaler.insert("immediate_jitter", Json::Num(ji as f64));
    scaler.insert("delayed_jitter", Json::Num(jd as f64));
    section.insert("delayed_scaling", Json::Obj(scaler));

    let path = report::bench_json_path();
    match report::update_bench_json(&path, "quant", Json::Obj(section)) {
        Ok(()) => println!("\nwrote quant numbers to {}", path.display()),
        Err(e) => eprintln!("could not update {}: {e:#}", path.display()),
    }

    // memory-tier ladder (DESIGN.md §12): resident state bytes + end-to-end
    // tok/s per tier on the fast CPU backend. Throughput at this toy
    // geometry is dominated by per-tile dequant overhead rather than the
    // memory traffic the tiers save at LLM scale, so the section ships
    // `verified: false` — the byte columns are exact, the tok/s columns
    // are indicative only until measured at a representative geometry.
    let tier_steps: u64 = std::env::var("STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let mut tiers = Obj::default();
    println!("\n| tier       | optim bytes | base bytes  | tok/s       | last loss |");
    println!("|------------|-------------|-------------|-------------|-----------|");
    for (label, optim, base, segs) in TIERS {
        let Some((optim_bytes, base_bytes)) = tier_bytes(optim, base) else {
            eprintln!("  tier {label}: byte accounting failed");
            continue;
        };
        let Some((tps, last_loss)) = tier_run(tier_steps, optim, base, segs) else {
            continue;
        };
        println!(
            "| {:<10} | {:<11} | {:<11} | {:<11.0} | {:<9.4} |",
            label, optim_bytes, base_bytes, tps, last_loss
        );
        let mut row = Obj::default();
        row.insert("optim_state_bytes", Json::Num(optim_bytes as f64));
        row.insert("base_weight_bytes", Json::Num(base_bytes as f64));
        row.insert("ckpt_segments", Json::Num(segs as f64));
        row.insert("tokens_per_sec", Json::Num(tps));
        row.insert("last_loss", Json::Num(last_loss as f64));
        tiers.insert(label, Json::Obj(row));
    }
    let mut mem_section = Obj::default();
    mem_section.insert("backend", Json::Str("cpu-fast".into()));
    mem_section.insert("steps", Json::Num(tier_steps as f64));
    mem_section.insert("rows", Json::Obj(tiers));
    mem_section.insert("verified", Json::Bool(false));
    match report::update_bench_json(&path, "memory_tiers", Json::Obj(mem_section)) {
        Ok(()) => println!("wrote memory-tier rows to {}", path.display()),
        Err(e) => eprintln!("could not update {}: {e:#}", path.display()),
    }
}
