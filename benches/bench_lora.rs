//! LoRA training benchmarks — regenerates paper Table 3 (LoRA r=32:
//! Unsloth-shaped naive baseline vs Chronicals LoRA vs LoRA+ λ=16) and the
//! Fig. 10 broken-"fast-mode" row, each with gradient-flow verification.
//!
//! Run: `cargo bench --bench bench_lora`   Env: STEPS (default 12).

use chronicals::harness;
use chronicals::report;
use chronicals::runtime::Runtime;
use std::rc::Rc;

fn main() {
    let steps: u64 = std::env::var("STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => Rc::new(rt),
        Err(e) => {
            eprintln!("bench_lora skipped: {e:#} (run `make artifacts`)");
            return;
        }
    };
    println!("bench_lora: {steps} steps per config\n");
    match harness::lora_comparison(&rt, steps) {
        Ok(rows) => {
            println!(
                "{}",
                report::throughput_table(
                    "LoRA r=32 (paper Table 3 + Fig. 10)",
                    &rows,
                    "LoRA naive (Unsloth-shaped)"
                )
            );
            println!(
                "paper Table 3 reference: Unsloth MAX 2,857 tok/s -> Chronicals LoRA+\n\
                 11,699 tok/s (4.10x). The broken row reproduces Fig. 10: highest\n\
                 tok/s, grad_norm exactly 0 — excluded by verification."
            );
        }
        Err(e) => eprintln!("bench_lora failed: {e:#}"),
    }
}
