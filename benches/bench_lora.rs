//! LoRA training benchmarks — regenerates paper Table 3 (LoRA r=32:
//! Unsloth-shaped naive baseline vs Chronicals LoRA vs LoRA+ λ=16) and the
//! Fig. 10 broken-"fast-mode" row, each with gradient-flow verification,
//! through the Backend trait + typed Session tasks (no artifacts needed on
//! the CPU backends).
//!
//! Writes the per-row tokens/sec into the repo-root `BENCH_cpu.json`
//! (section `"lora"`).
//!
//! Run: `cargo bench --bench bench_lora`
//! Env: STEPS (default 12), BACKEND (default cpu-fast), CHRONICALS_THREADS.

use chronicals::backend::{create_backend, Backend};
use chronicals::harness;
use chronicals::report;
use chronicals::util::json::{Json, Obj};

fn main() {
    let steps: u64 = std::env::var("STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let backend_name = std::env::var("BACKEND").unwrap_or_else(|_| "cpu-fast".into());
    let be = match create_backend(&backend_name, "artifacts", 0) {
        Ok(be) => be,
        Err(e) => {
            eprintln!("bench_lora skipped: {e:#}");
            return;
        }
    };
    println!("bench_lora: {steps} steps per config (backend: {})\n", be.name());
    match harness::lora_comparison(&be, steps) {
        Ok(rows) => {
            println!(
                "{}",
                report::throughput_table(
                    "LoRA r=32 (paper Table 3 + Fig. 10)",
                    &rows,
                    "LoRA naive (Unsloth-shaped)"
                )
            );
            println!(
                "paper Table 3 reference: Unsloth MAX 2,857 tok/s -> Chronicals LoRA+\n\
                 11,699 tok/s (4.10x). The broken row reproduces Fig. 10: highest\n\
                 tok/s, grad_norm exactly 0 — excluded by verification."
            );
            let baseline = rows
                .iter()
                .find(|r| r.label == "LoRA naive (Unsloth-shaped)")
                .map(|r| r.tokens_per_sec)
                .unwrap_or(0.0);
            let mut per_row = Obj::default();
            for r in &rows {
                let mut entry = Obj::default();
                entry.insert("tokens_per_sec", Json::Num(r.tokens_per_sec));
                entry.insert("mean_step_ms", Json::Num(r.mean_step_ms));
                entry.insert(
                    "speedup_vs_naive",
                    Json::Num(if baseline > 0.0 { r.tokens_per_sec / baseline } else { 0.0 }),
                );
                entry.insert("status", Json::Str(r.status.clone()));
                per_row.insert(r.label.clone(), Json::Obj(entry));
            }
            let mut section = Obj::default();
            section.insert("backend", Json::Str(be.name().to_string()));
            section.insert("steps", Json::Num(steps as f64));
            section.insert("rows", Json::Obj(per_row));
            let path = report::bench_json_path();
            match report::update_bench_json(&path, "lora", Json::Obj(section)) {
                Ok(()) => println!("wrote LoRA rows to {}", path.display()),
                Err(e) => eprintln!("could not update {}: {e:#}", path.display()),
            }
        }
        Err(e) => eprintln!("bench_lora failed: {e:#}"),
    }
}
