//! Kernel microbenchmarks — regenerates paper Table 5 (fused vs naive
//! timings for RMSNorm / SwiGLU / QK-RoPE / Attention / Cross-Entropy /
//! AdamW / LoRA-linear) through the `Backend` trait.
//!
//! Plain-main bench (offline build: no criterion): mean over `REPS`
//! executions after warmup. Backend comes from `BACKEND` (default
//! `cpu-fast`, whose `bench_kernel` times its fused/tiled kernels against
//! the reference backend's scalar implementations on identical inputs;
//! `pjrt` times compiled kernel artifacts when available).
//!
//! Writes the per-kernel means into the repo-root `BENCH_cpu.json`
//! (section `"kernels"`) so the perf trajectory is machine-readable.
//!
//! Run: `cargo bench --bench bench_kernels`
//! Env: REPS (default 30), BACKEND (default cpu-fast), CHRONICALS_THREADS.

use chronicals::backend::{create_backend, Backend};
use chronicals::report;
use chronicals::util::json::{Json, Obj};

fn main() {
    let reps: usize = std::env::var("REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    let backend_name = std::env::var("BACKEND").unwrap_or_else(|_| "cpu-fast".into());
    let be = match create_backend(&backend_name, "artifacts", 0) {
        Ok(be) => be,
        Err(e) => {
            eprintln!("bench_kernels skipped: {e:#}");
            return;
        }
    };
    println!(
        "bench_kernels: {reps} reps per kernel (backend: {}, profile: {})",
        be.name(),
        be.manifest().profile
    );
    match chronicals::harness::kernel_microbench(be.as_ref(), reps) {
        Ok(rows) => {
            println!("{}", report::kernel_table(&rows));
            println!(
                "paper Table 5 reference (A100/Triton): RMSNorm 7.0x, SwiGLU 5.0x,\n\
                 QK-RoPE 2.3x, Cross-Entropy 6.8x. Reproduced property: the fused\n\
                 form wins wherever the naive form is barrier-split or materializes\n\
                 intermediates; exact ratios are substrate-dependent."
            );
            let mut kernels = Obj::default();
            for (name, fused, naive) in &rows {
                let mut entry = Obj::default();
                entry.insert("fused_ms", Json::Num(fused * 1e3));
                entry.insert("naive_ms", Json::Num(naive * 1e3));
                entry.insert("speedup", Json::Num(naive / fused));
                kernels.insert(name.clone(), Json::Obj(entry));
            }
            let mut section = Obj::default();
            section.insert("backend", Json::Str(be.name().to_string()));
            section.insert("reps", Json::Num(reps as f64));
            section.insert("per_kernel", Json::Obj(kernels));
            let path = report::bench_json_path();
            match report::update_bench_json(&path, "kernels", Json::Obj(section)) {
                Ok(()) => println!("wrote kernel means to {}", path.display()),
                Err(e) => eprintln!("could not update {}: {e:#}", path.display()),
            }
        }
        Err(e) => eprintln!("bench_kernels failed: {e:#}"),
    }
}
