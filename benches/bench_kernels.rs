//! Kernel microbenchmarks — regenerates paper Table 5 (fused vs naive
//! timings for RMSNorm / SwiGLU / QK-RoPE / Attention / Cross-Entropy /
//! AdamW / LoRA-linear) on the compiled AOT kernel artifacts.
//!
//! Plain-main bench (offline build: no criterion): mean over `REPS`
//! executions after warmup, on the PJRT CPU device.
//!
//! Run: `cargo bench --bench bench_kernels` (or `make bench`).

use chronicals::harness;
use chronicals::report;
use chronicals::runtime::Runtime;

fn main() {
    let reps: usize = std::env::var("REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("bench_kernels skipped: {e:#} (run `make artifacts`)");
            return;
        }
    };
    println!("bench_kernels: {reps} reps per kernel (profile: {})", rt.manifest.profile);
    match harness::kernel_microbench(&rt, reps) {
        Ok(rows) => {
            println!("{}", report::kernel_table(&rows));
            println!(
                "paper Table 5 reference (A100/Triton): RMSNorm 7.0x, SwiGLU 5.0x,\n\
                 QK-RoPE 2.3x, Cross-Entropy 6.8x. Reproduced property: the fused\n\
                 form wins wherever the naive form is barrier-split or materializes\n\
                 intermediates; exact ratios are substrate-dependent."
            );
        }
        Err(e) => eprintln!("bench_kernels failed: {e:#}"),
    }
}
