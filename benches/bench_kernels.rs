//! Kernel microbenchmarks — regenerates paper Table 5 (fused vs naive
//! timings for RMSNorm / SwiGLU / QK-RoPE / Attention / Cross-Entropy /
//! AdamW / LoRA-linear) through the `Backend` trait, plus the dispatch
//! comparison for the fast backend's persistent worker pool.
//!
//! Plain-main bench (offline build: no criterion): mean over `REPS`
//! executions after warmup. Backend comes from `BACKEND` (default
//! `cpu-fast`, whose `bench_kernel` times its fused/tiled kernels against
//! the reference backend's scalar implementations on identical inputs;
//! `pjrt` times compiled kernel artifacts when available).
//!
//! The `dispatch` section times one small-geometry matmul (T ≤ 64, where
//! per-call dispatch overhead dominates the arithmetic) three ways on a
//! `DISPATCH_THREADS`-lane fast backend: through the persistent pool
//! (`pool_ms`), through a fresh `std::thread::scope` spawn per call — the
//! PR 2 baseline — (`spawn_ms`), and fully single-threaded (`single_ms`).
//! `spawn_over_pool` ≥ 1.3 at 4 threads is the acceptance bar for the
//! pool actually amortizing spawn overhead.
//!
//! Writes the per-kernel means into the repo-root `BENCH_cpu.json`
//! (sections `"kernels"` and `"dispatch"`) so the perf trajectory is
//! machine-readable.
//!
//! Run: `cargo bench --bench bench_kernels`
//! Env: REPS (default 30), BACKEND (default cpu-fast), CHRONICALS_THREADS,
//!      DISPATCH_THREADS (default 4).

use chronicals::backend::cpu_fast::FastCpuBackend;
use chronicals::backend::{create_backend, Backend};
use chronicals::report;
use chronicals::util::json::{Json, Obj};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Time the pool-vs-spawn-vs-serial dispatch triple and merge the
/// `"dispatch"` section into `BENCH_cpu.json`.
fn dispatch_section(reps: usize) {
    let threads = env_usize("DISPATCH_THREADS", 4);
    let be = FastCpuBackend::with_threads(threads);
    let mut timings = Vec::new();
    for name in ["dispatch_matmul_pool", "dispatch_matmul_spawn", "dispatch_matmul_single"] {
        match be.bench_kernel(name, reps, 2) {
            Ok(secs) => timings.push((name, secs)),
            Err(e) => {
                eprintln!("dispatch bench {name} failed: {e:#}");
                return;
            }
        }
    }
    let (pool, spawn, single) = (timings[0].1, timings[1].1, timings[2].1);
    println!("\ndispatch (small-geometry matmul, T<=64, threads={threads}):");
    println!("  pool   {:>9.3} us", pool * 1e6);
    println!("  spawn  {:>9.3} us  ({:.2}x the pooled latency)", spawn * 1e6, spawn / pool);
    println!("  single {:>9.3} us", single * 1e6);

    let mut section = Obj::default();
    section.insert("threads", Json::Num(threads as f64));
    section.insert("reps", Json::Num(reps as f64));
    section.insert("pool_ms", Json::Num(pool * 1e3));
    section.insert("spawn_ms", Json::Num(spawn * 1e3));
    section.insert("single_ms", Json::Num(single * 1e3));
    section.insert("spawn_over_pool", Json::Num(spawn / pool));
    let path = report::bench_json_path();
    match report::update_bench_json(&path, "dispatch", Json::Obj(section)) {
        Ok(()) => println!("wrote dispatch means to {}", path.display()),
        Err(e) => eprintln!("could not update {}: {e:#}", path.display()),
    }
}

fn main() {
    let reps: usize = env_usize("REPS", 30);
    let backend_name = std::env::var("BACKEND").unwrap_or_else(|_| "cpu-fast".into());
    let be = match create_backend(&backend_name, "artifacts", 0) {
        Ok(be) => be,
        Err(e) => {
            eprintln!("bench_kernels skipped: {e:#}");
            return;
        }
    };
    println!(
        "bench_kernels: {reps} reps per kernel (backend: {}, profile: {})",
        be.name(),
        be.manifest().profile
    );
    match chronicals::harness::kernel_microbench(be.as_ref(), reps) {
        Ok(rows) => {
            println!("{}", report::kernel_table(&rows));
            println!(
                "paper Table 5 reference (A100/Triton): RMSNorm 7.0x, SwiGLU 5.0x,\n\
                 QK-RoPE 2.3x, Cross-Entropy 6.8x. Reproduced property: the fused\n\
                 form wins wherever the naive form is barrier-split or materializes\n\
                 intermediates; exact ratios are substrate-dependent."
            );
            let mut kernels = Obj::default();
            for (name, fused, naive) in &rows {
                let mut entry = Obj::default();
                entry.insert("fused_ms", Json::Num(fused * 1e3));
                entry.insert("naive_ms", Json::Num(naive * 1e3));
                entry.insert("speedup", Json::Num(naive / fused));
                kernels.insert(name.clone(), Json::Obj(entry));
            }
            let mut section = Obj::default();
            section.insert("backend", Json::Str(be.name().to_string()));
            section.insert("reps", Json::Num(reps as f64));
            section.insert("per_kernel", Json::Obj(kernels));
            let path = report::bench_json_path();
            match report::update_bench_json(&path, "kernels", Json::Obj(section)) {
                Ok(()) => println!("wrote kernel means to {}", path.display()),
                Err(e) => eprintln!("could not update {}: {e:#}", path.display()),
            }
        }
        Err(e) => eprintln!("bench_kernels failed: {e:#}"),
    }
    // the dispatch comparison is fast-backend-specific: run it regardless
    // of which backend the table above used (the fast CPU backend is
    // always available)
    dispatch_section(reps.max(100));
}
