//! End-to-end training throughput — regenerates paper Table 2 (full
//! fine-tuning comparison) and Table 4 / Fig. 14 (the ablation ladder),
//! with the paper's verification methodology applied to every row.
//!
//! Run: `cargo bench --bench bench_throughput`
//! Env: STEPS (default 12) — measured steps per configuration.

use chronicals::harness;
use chronicals::report;
use chronicals::runtime::Runtime;
use std::rc::Rc;

fn main() {
    let steps: u64 = std::env::var("STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => Rc::new(rt),
        Err(e) => {
            eprintln!("bench_throughput skipped: {e:#} (run `make artifacts`)");
            return;
        }
    };
    println!("bench_throughput: {steps} steps per config\n");

    match harness::full_ft_comparison(&rt, steps) {
        Ok(rows) => println!(
            "{}",
            report::throughput_table(
                "Full fine-tuning (paper Table 2)",
                &rows,
                "Baseline (naive, verified)"
            )
        ),
        Err(e) => eprintln!("full-ft comparison failed: {e:#}"),
    }

    match harness::ablation_ladder(&rt, steps) {
        Ok(rows) => {
            println!("{}", report::ablation_table(&rows));
            println!(
                "paper Table 4 reference: +flash 1.9x, +compile 2.85x, +liger 3.94x,\n\
                 +packing 4.80x, +fused-optim 5.15x cumulative over the HF baseline."
            );
        }
        Err(e) => eprintln!("ablation ladder failed: {e:#}"),
    }
}
