//! End-to-end training throughput through the `Backend` trait: the
//! reference `CpuBackend` vs the threaded fused-kernel `FastCpuBackend`
//! on an identical corpus, packing, schedule and seed — the repo-local
//! analogue of paper Table 2, with the paper's verification methodology
//! applied to every row (a tokens/sec figure only counts if gradients
//! flowed and the loss moved).
//!
//! Also regenerates the ablation ladder (Table 4 shape) on the fast
//! backend, and writes the headline numbers to the repo-root
//! `BENCH_cpu.json` (section `"throughput"`).
//!
//! Run: `cargo bench --bench bench_throughput`
//! Env: STEPS (default 12) — measured steps per configuration;
//!      CHRONICALS_THREADS — worker threads for the fast backend.

use chronicals::backend::cpu::CpuBackend;
use chronicals::backend::cpu_fast::FastCpuBackend;
use chronicals::backend::Backend;
use chronicals::coordinator::TrainSummary;
use chronicals::harness;
use chronicals::metrics::PhaseBreakdown;
use chronicals::report::{self, Row};
use chronicals::serve::{FuseMode, JobSpec, ServeConfig, ServeEngine};
use chronicals::session::{
    BackendSpec, DataSource, LossMode, PackingStrategy, Schedule, SessionBuilder, Task,
};
use chronicals::util::json::{Json, Obj};
use std::sync::Arc;

/// Bench geometry: larger than the 4×64 reference substrate so tiling,
/// threading and the no-materialization paths have real work to do.
const BATCH: usize = 4;
const SEQ: usize = 128;

fn run(backend: &Arc<dyn Backend>, task: Task, steps: u64) -> Option<TrainSummary> {
    let result = SessionBuilder::new()
        .task(task.clone())
        .steps(steps)
        .meter_warmup(2)
        .lr(5e-3)
        .packing(PackingStrategy::Bfd)
        .data(DataSource::synthetic(384, 42, 96))
        .on_backend(backend.clone())
        .build()
        .and_then(|mut session| session.run());
    match result {
        Ok(r) => Some(r.summary),
        Err(e) => {
            eprintln!("{task} on {} failed: {e:#}", backend.name());
            None
        }
    }
}

/// JSON shape for a measured per-phase breakdown (ms/step means).
fn phases_json(p: &PhaseBreakdown) -> Json {
    let mut o = Obj::default();
    o.insert("fwd_ms", Json::Num(p.fwd_ms));
    o.insert("bwd_ms", Json::Num(p.bwd_ms));
    o.insert("optim_ms", Json::Num(p.optim_ms));
    o.insert("data_ms", Json::Num(p.data_ms));
    Json::Obj(o)
}

/// One data-parallel ladder rung: the same session `run()` drives, but
/// with `workers` replicas built from the backend spec (on_backend cannot
/// be combined with workers — replicas are constructed per worker).
fn run_dp(workers: usize, steps: u64) -> Option<TrainSummary> {
    let result = SessionBuilder::new()
        .task(Task::FullFinetune)
        .steps(steps)
        .meter_warmup(2)
        .lr(5e-3)
        .packing(PackingStrategy::Bfd)
        .data(DataSource::synthetic(384, 42, 96))
        .backend(BackendSpec::CpuFast { threads: 0 })
        .workers(workers)
        .build()
        .and_then(|mut session| session.run());
    match result {
        Ok(r) => Some(r.summary),
        Err(e) => {
            eprintln!("data-parallel workers={workers} failed: {e:#}");
            None
        }
    }
}

/// One serve-ladder rung: `tenants` identical-geometry LoRA tenants
/// drained in `--once` mode under `mode` on a fresh fast backend. Returns
/// slot tokens/sec — every tenant runs `steps` steps over a `[B, S]`
/// batch, so throughput is `tenants × steps × B × S` over wall-clock —
/// plus the summed per-phase ms pulled from the `--round-stats` sidecar
/// (the per-job reports are timing-free by contract).
fn run_serve(mode: FuseMode, tenants: usize, steps: u64) -> Option<(f64, Json)> {
    let tag = format!("{mode:?}_{tenants}").to_lowercase();
    let out =
        std::env::temp_dir().join(format!("chronicals_bench_serve_{}_{tag}", std::process::id()));
    let stats = out.with_extension("stats.json");
    let _ = std::fs::remove_dir_all(&out);
    let _ = std::fs::remove_file(&stats);
    let backend: Arc<dyn Backend> = Arc::new(FastCpuBackend::with_geometry(BATCH, SEQ));
    let cfg = ServeConfig {
        out_dir: out.clone(),
        fuse: mode,
        steps_per_round: 4,
        round_stats: Some(stats.clone()),
        ..Default::default()
    };
    let res = (|| {
        let mut engine = ServeEngine::new(backend, cfg).ok()?;
        for i in 0..tenants {
            engine
                .admit_spec(JobSpec {
                    id: format!("tenant-{i}"),
                    task: Task::lora(),
                    steps,
                    lr: 5e-3,
                    seed: 7 + i as i64,
                    schedule: Schedule::Constant,
                    loss_mode: LossMode::default(),
                    data: DataSource::synthetic(40, 3 + i as u64, 48),
                })
                .ok()?;
        }
        let t0 = std::time::Instant::now();
        let summary = engine.run().ok()?;
        let secs = t0.elapsed().as_secs_f64();
        if summary.completed != tenants || secs <= 0.0 {
            return None;
        }
        let tok = (tenants as u64 * steps) as f64 * (BATCH * SEQ) as f64;
        let mut phases = Obj::default();
        let sidecar = std::fs::read_to_string(&stats).ok().and_then(|t| Json::parse(&t).ok());
        if let Some(json) = sidecar {
            if let Ok(rounds) = json.field("per_round") {
                if let Some(rounds) = rounds.as_arr() {
                    for key in ["fwd_ms", "bwd_ms", "optim_ms"] {
                        let total: f64 = rounds
                            .iter()
                            .filter_map(|r| r.field(key).ok().and_then(|v| v.as_f64()))
                            .sum();
                        phases.insert(key, Json::Num(total));
                    }
                }
            }
        }
        Some((tok / secs, Json::Obj(phases)))
    })();
    let _ = std::fs::remove_dir_all(&out);
    let _ = std::fs::remove_file(&stats);
    if res.is_none() {
        eprintln!("serve ladder rung failed: {mode:?} tenants={tenants}");
    }
    res
}

fn main() {
    let steps: u64 = std::env::var("STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let fast = FastCpuBackend::with_geometry(BATCH, SEQ);
    let threads = fast.threads();
    let reference: Arc<dyn Backend> = Arc::new(CpuBackend::with_geometry(BATCH, SEQ));
    let fast: Arc<dyn Backend> = Arc::new(fast);
    println!(
        "bench_throughput: {steps} steps per config, B={BATCH} S={SEQ}, \
         cpu-fast threads={threads}\n"
    );

    let mut section = Obj::default();
    let mut cfg_obj = Obj::default();
    cfg_obj.insert("batch", Json::Num(BATCH as f64));
    cfg_obj.insert("seq", Json::Num(SEQ as f64));
    cfg_obj.insert("steps", Json::Num(steps as f64));
    cfg_obj.insert("threads", Json::Num(threads as f64));
    section.insert("config", Json::Obj(cfg_obj));

    for (mode, task) in [("full_ft", Task::FullFinetune), ("lora", Task::lora())] {
        let (Some(r), Some(f)) =
            (run(&reference, task.clone(), steps), run(&fast, task, steps)) else {
            continue;
        };
        let rows = vec![
            Row::from_summary("CpuBackend (reference)", mode, BATCH, &r),
            Row::from_summary("FastCpuBackend (fused)", mode, BATCH, &f),
        ];
        println!(
            "{}",
            report::throughput_table(
                &format!("{mode}: reference vs fast CPU backend"),
                &rows,
                "CpuBackend (reference)"
            )
        );
        let speedup = if r.tokens_per_sec > 0.0 { f.tokens_per_sec / r.tokens_per_sec } else { 0.0 };
        println!("{mode} speedup: {speedup:.2}x (target ≥ 2x)\n");
        let mut entry = Obj::default();
        entry.insert("cpu_tokens_per_sec", Json::Num(r.tokens_per_sec));
        entry.insert("cpu_fast_tokens_per_sec", Json::Num(f.tokens_per_sec));
        entry.insert("cpu_mean_step_ms", Json::Num(r.mean_step_ms));
        entry.insert("cpu_fast_mean_step_ms", Json::Num(f.mean_step_ms));
        entry.insert("speedup", Json::Num(speedup));
        // the arXiv 2311.03687 discipline: a speedup claim ships with the
        // per-phase dissection that explains it
        if let Some(p) = &r.phases {
            entry.insert("cpu_phases", phases_json(p));
        }
        if let Some(p) = &f.phases {
            entry.insert("cpu_fast_phases", phases_json(p));
        }
        entry.insert(
            "verified",
            Json::Bool(r.verification.is_training && f.verification.is_training),
        );
        section.insert(mode, Json::Obj(entry));
    }

    match harness::ablation_ladder(&fast, steps) {
        Ok(rows) => {
            println!("{}", report::ablation_table(&rows));
            println!(
                "paper Table 4 reference: +flash 1.9x, +compile 2.85x, +liger 3.94x,\n\
                 +packing 4.80x, +fused-optim 5.15x cumulative over the HF baseline."
            );
        }
        Err(e) => eprintln!("ablation ladder failed: {e:#}"),
    }

    let path = report::bench_json_path();
    match report::update_bench_json(&path, "throughput", Json::Obj(section)) {
        Ok(()) => println!("wrote throughput numbers to {}", path.display()),
        Err(e) => eprintln!("could not update {}: {e:#}", path.display()),
    }

    // data-parallel worker ladder: same run at workers {1, 2, 4}. The
    // loss series is bitwise identical across the ladder (the parity
    // suite enforces it); this section measures what the worker count
    // does to wall-clock, phase by phase.
    let mut dp = Obj::default();
    let mut dp_cfg = Obj::default();
    dp_cfg.insert("task", Json::Str("full_ft".into()));
    dp_cfg.insert("steps", Json::Num(steps as f64));
    dp_cfg.insert("backend", Json::Str("cpu-fast".into()));
    dp.insert("config", Json::Obj(dp_cfg));
    let mut base_tps = 0.0f64;
    let mut rows = Vec::new();
    for workers in [1usize, 2, 4] {
        let Some(s) = run_dp(workers, steps) else {
            continue;
        };
        if !s.verification.is_training {
            eprintln!("data-parallel workers={workers}: verification failed, row inadmissible");
        }
        if workers == 1 {
            base_tps = s.tokens_per_sec;
        }
        let speedup_vs_1 = if base_tps > 0.0 { s.tokens_per_sec / base_tps } else { 0.0 };
        rows.push(Row::from_summary(
            &format!("workers={workers}"),
            "full_ft",
            BATCH,
            &s,
        ));
        let mut entry = Obj::default();
        entry.insert("tokens_per_sec", Json::Num(s.tokens_per_sec));
        entry.insert("mean_step_ms", Json::Num(s.mean_step_ms));
        entry.insert("speedup_vs_1", Json::Num(speedup_vs_1));
        if let Some(p) = &s.phases {
            entry.insert("phases", phases_json(p));
        }
        dp.insert(format!("workers_{workers}"), Json::Obj(entry));
    }
    dp.insert(
        "acceptance",
        Json::Str("workers_4.speedup_vs_1 >= 2.0 with process-backed replicas".into()),
    );
    // in-process replicas run sequentially (the determinism seam lands
    // first); the acceptance bar is for the mmap worker-process backend,
    // so this section stays unverified until measured on that path
    dp.insert("verified", Json::Bool(false));
    if !rows.is_empty() {
        println!(
            "{}",
            report::throughput_table("Data-parallel worker ladder", &rows, "workers=1")
        );
    }
    match report::update_bench_json(&path, "data_parallel", Json::Obj(dp)) {
        Ok(()) => println!("wrote data-parallel numbers to {}", path.display()),
        Err(e) => eprintln!("could not update {}: {e:#}", path.display()),
    }

    // serve intra-step fusion ladder: N identical LoRA tenants drained in
    // --once mode — serial (--fuse off) vs adapter-swap round fusion
    // (--fuse on) vs one concatenated base pass per quantum step
    // (--fuse intra). All three are bitwise identical (the serve suite
    // enforces it); this section measures what fusion buys in slot
    // throughput, phase by phase.
    let mut sv = Obj::default();
    let mut sv_cfg = Obj::default();
    sv_cfg.insert("task", Json::Str("lora".into()));
    sv_cfg.insert("steps_per_tenant", Json::Num(steps as f64));
    sv_cfg.insert("steps_per_round", Json::Num(4.0));
    sv_cfg.insert("backend", Json::Str("cpu-fast".into()));
    sv.insert("config", Json::Obj(sv_cfg));
    let mut isf = Obj::default();
    for tenants in [2usize, 4] {
        let mut serial_tps = 0.0f64;
        for (label, mode) in [
            ("serial", FuseMode::Off),
            ("round_fused", FuseMode::Round),
            ("intra_fused", FuseMode::Intra),
        ] {
            let Some((tps, phases)) = run_serve(mode, tenants, steps) else {
                continue;
            };
            if label == "serial" {
                serial_tps = tps;
            }
            let speedup = if serial_tps > 0.0 { tps / serial_tps } else { 0.0 };
            println!("serve {label} tenants={tenants}: {tps:.0} tok/s ({speedup:.2}x serial)");
            let mut entry = Obj::default();
            entry.insert("tokens_per_sec", Json::Num(tps));
            entry.insert("phases", phases);
            if label != "serial" {
                entry.insert("speedup_vs_serial", Json::Num(speedup));
            }
            isf.insert(format!("{label}_{tenants}"), Json::Obj(entry));
        }
    }
    sv.insert("intra_step_fusion", Json::Obj(isf));
    sv.insert(
        "acceptance",
        Json::Str("intra_step_fusion.intra_fused_4.speedup_vs_serial >= 2.0".into()),
    );
    // one shared base pass per quantum step amortizes forward/backward
    // across tenants; the ≥2x bar assumes real parallel headroom, so the
    // section ships unverified until measured on such a host
    sv.insert("verified", Json::Bool(false));
    match report::update_bench_json(&path, "serve", Json::Obj(sv)) {
        Ok(()) => println!("wrote serve fusion numbers to {}", path.display()),
        Err(e) => eprintln!("could not update {}: {e:#}", path.display()),
    }
}
