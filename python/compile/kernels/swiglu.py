"""Fused SwiGLU Pallas kernel (paper Alg. 6/7).

gate/up rows are loaded once, sigmoid·mul·mul happen in VMEM, one store —
vs the three barrier-separated kernels of the naive path. Backward is the
analytic gradient (paper Alg. 7) in a single fused kernel as well.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _fwd_kernel(g_ref, u_ref, y_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    sig = 1.0 / (1.0 + jnp.exp(-g))
    y_ref[...] = (g * sig * u).astype(y_ref.dtype)


def _bwd_kernel(g_ref, u_ref, dy_ref, dg_ref, du_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    sig = 1.0 / (1.0 + jnp.exp(-g))
    silu = g * sig
    d_silu = sig * (1.0 + g * (1.0 - sig))
    dg_ref[...] = (dy * u * d_silu).astype(dg_ref.dtype)
    du_ref[...] = (dy * silu).astype(du_ref.dtype)


def _call_rows(kernel, n_out, t, d, dtype, *args):
    return pl.pallas_call(
        kernel,
        grid=(t,),
        in_specs=[pl.BlockSpec((1, d), lambda i: (i, 0)) for _ in args],
        out_specs=[pl.BlockSpec((1, d), lambda i: (i, 0)) for _ in range(n_out)],
        out_shape=[jax.ShapeDtypeStruct((t, d), dtype) for _ in range(n_out)],
        interpret=INTERPRET,
    )(*args)


@jax.custom_vjp
def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    """y = SiLU(gate) ⊙ up over the last axis; any leading shape."""
    lead = gate.shape[:-1]
    d = gate.shape[-1]
    g2 = gate.reshape(-1, d)
    u2 = up.reshape(-1, d)
    (y,) = _call_rows(_fwd_kernel, 1, g2.shape[0], d, gate.dtype, g2, u2)
    return y.reshape(*lead, d)


def _vjp_fwd(gate, up):
    return swiglu(gate, up), (gate, up)


def _vjp_bwd(res, dy):
    gate, up = res
    lead = gate.shape[:-1]
    d = gate.shape[-1]
    g2 = gate.reshape(-1, d)
    u2 = up.reshape(-1, d)
    dy2 = dy.reshape(-1, d)
    dg, du = _call_rows(_bwd_kernel, 2, g2.shape[0], d, gate.dtype, g2, u2, dy2)
    return dg.reshape(*lead, d), du.reshape(*lead, d)


swiglu.defvjp(_vjp_fwd, _vjp_bwd)
