"""Chronicals L1 kernels: Pallas implementations + pure-jnp oracles (ref)."""
from . import ref  # noqa: F401
