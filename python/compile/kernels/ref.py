"""Pure-jnp reference oracles for every Chronicals kernel.

This module is the correctness contract for the whole stack:

* every Pallas kernel in this package is tested (pytest + hypothesis)
  against the function of the same name here;
* the "naive" benchmark variants lower these *unfused / materializing*
  implementations, reproducing the paper's baselines (full-logit
  cross-entropy, score-materializing attention, per-op optimizer);
* the "fused-structure" implementations (``*_chunked`` / ``*_flash``)
  implement the paper's algorithms (online softmax, chunked CCE, tiled
  attention) in plain jnp so XLA compiles them efficiently on any
  backend — these power the fast end-to-end artifacts, while the Pallas
  versions prove the kernel-level formulation.

Everything here is dtype-polymorphic and shape-polymorphic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# RMSNorm (paper Def. 4, Prop. 3; Alg. 4/5)
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """y = x / sqrt(mean(x^2) + eps) * gamma, reduced over the last axis."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    return (x.astype(jnp.float32) * rstd).astype(x.dtype) * gamma


def rmsnorm_naive(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Unfused RMSNorm: each step separated by an optimization barrier so XLA
    cannot fuse it — models the 5-kernel PyTorch sequence from §4."""
    x2 = jax.lax.optimization_barrier(jnp.square(x.astype(jnp.float32)))
    var = jax.lax.optimization_barrier(jnp.mean(x2, axis=-1, keepdims=True))
    rstd = jax.lax.optimization_barrier(jax.lax.rsqrt(var + eps))
    xn = jax.lax.optimization_barrier(x.astype(jnp.float32) * rstd)
    return (xn * gamma.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_bwd(
    x: jax.Array, gamma: jax.Array, dy: jax.Array, eps: float = 1e-6
) -> tuple[jax.Array, jax.Array]:
    """Analytic RMSNorm backward (paper Prop. 3).

    dx_i = gamma_i * rstd * (dy_i - xbar_i * mean_j(dy_j gamma_j xbar_j))
    dgamma = sum over rows of dy * xbar
    """
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    gf = gamma.astype(jnp.float32)
    d = x.shape[-1]
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xbar = xf * rstd
    c1 = jnp.sum(dyf * gf * xbar, axis=-1, keepdims=True) / d
    dx = rstd * (gf * dyf - xbar * c1)
    dgamma = jnp.sum((dyf * xbar).reshape(-1, d), axis=0)
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype)


# ---------------------------------------------------------------------------
# SwiGLU (paper Def. 3, Prop. 2; Alg. 6/7)
# ---------------------------------------------------------------------------


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    """y = SiLU(gate) * up."""
    gf = gate.astype(jnp.float32)
    return (gf * jax.nn.sigmoid(gf) * up.astype(jnp.float32)).astype(gate.dtype)


def swiglu_naive(gate: jax.Array, up: jax.Array) -> jax.Array:
    """Unfused SwiGLU: sigmoid / mul / mul as three barrier-separated steps."""
    gf = gate.astype(jnp.float32)
    sig = jax.lax.optimization_barrier(jax.nn.sigmoid(gf))
    silu = jax.lax.optimization_barrier(gf * sig)
    return (silu * up.astype(jnp.float32)).astype(gate.dtype)


def swiglu_bwd(
    gate: jax.Array, up: jax.Array, dy: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Analytic SwiGLU backward (paper Alg. 7)."""
    gf = gate.astype(jnp.float32)
    uf = up.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    sig = jax.nn.sigmoid(gf)
    silu = gf * sig
    d_silu = sig * (1.0 + gf * (1.0 - sig))
    dgate = dyf * uf * d_silu
    dup = dyf * silu
    return dgate.astype(gate.dtype), dup.astype(up.dtype)


# ---------------------------------------------------------------------------
# RoPE (paper Def. 15/21, Alg. 8/14) — split-half ("rotate_half") convention
# ---------------------------------------------------------------------------


def rope_cos_sin(
    positions: jax.Array, head_dim: int, base: float = 10000.0
) -> tuple[jax.Array, jax.Array]:
    """Precompute cos/sin tables for given integer positions.

    Returns (cos, sin) of shape positions.shape + (head_dim/2,).
    """
    half = head_dim // 2
    inv_freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    freqs = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (x1, x2) = (x[..., :d/2], x[..., d/2:]).

    x: [..., n_heads, head_dim]; cos/sin: broadcastable to [..., 1, head_dim/2].
    """
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def rope_qk(
    q: jax.Array, k: jax.Array, positions: jax.Array, base: float = 10000.0
) -> tuple[jax.Array, jax.Array]:
    """Fused-in-spirit QK-RoPE: one cos/sin table shared by Q and K.

    q: [B, S, Hq, D], k: [B, S, Hkv, D], positions: [B, S] int32.
    """
    cos, sin = rope_cos_sin(positions, q.shape[-1], base)
    cos = cos[..., None, :]  # [B, S, 1, D/2]
    sin = sin[..., None, :]
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin)


def rope_qk_naive(
    q: jax.Array, k: jax.Array, positions: jax.Array, base: float = 10000.0
) -> tuple[jax.Array, jax.Array]:
    """Separate-kernel RoPE: Q and K each recompute the cos/sin tables and are
    separated by barriers (two launches + duplicated trig loads, §4)."""
    cos_q, sin_q = rope_cos_sin(positions, q.shape[-1], base)
    q_out = jax.lax.optimization_barrier(
        apply_rope(q, cos_q[..., None, :], sin_q[..., None, :])
    )
    cos_k, sin_k = rope_cos_sin(positions, k.shape[-1], base)
    k_out = jax.lax.optimization_barrier(
        apply_rope(k, cos_k[..., None, :], sin_k[..., None, :])
    )
    return q_out, k_out


# ---------------------------------------------------------------------------
# Attention (paper Def. 1/2, Alg. 13) — with GQA + segment (packing) masks
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _segment_mask(seg_q: jax.Array, seg_kv: jax.Array) -> jax.Array:
    """Block-diagonal causal mask for packed sequences.

    seg id 0 is padding; tokens attend within their own segment only,
    causally. Shapes [B, S] -> bool [B, 1, S, S].
    """
    same = seg_q[:, :, None] == seg_kv[:, None, :]
    not_pad = (seg_q[:, :, None] != 0) & (seg_kv[:, None, :] != 0)
    s = seg_q.shape[-1]
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    return (same & not_pad & causal)[:, None, :, :]


def _expand_kv(k: jax.Array, n_q_heads: int) -> jax.Array:
    """GQA: repeat each KV head for its query group. [B,S,Hkv,D]->[B,S,Hq,D]."""
    n_kv = k.shape[2]
    if n_kv == n_q_heads:
        return k
    return jnp.repeat(k, n_q_heads // n_kv, axis=2)


def attention_naive(
    q: jax.Array, k: jax.Array, v: jax.Array, seg_ids: jax.Array
) -> jax.Array:
    """Score-materializing attention: builds the full [B,H,S,S] matrix
    (the paper's quadratic-memory baseline)."""
    b, s, h, d = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    qt = jnp.swapaxes(q, 1, 2).astype(jnp.float32)  # [B,H,S,D]
    kt = jnp.swapaxes(k, 1, 2).astype(jnp.float32)
    vt = jnp.swapaxes(v, 1, 2).astype(jnp.float32)
    scores = jnp.einsum("bhsd,bhtd->bhst", qt, kt) / jnp.sqrt(
        jnp.asarray(d, jnp.float32)
    )
    scores = jax.lax.optimization_barrier(scores)  # force materialization
    mask = _segment_mask(seg_ids, seg_ids)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jax.lax.optimization_barrier(probs)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, vt)
    out = jnp.where(
        jnp.any(mask, axis=-1)[..., None], out, 0.0
    )  # zero fully-masked (padding) rows
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, seg_ids: jax.Array
) -> jax.Array:
    """Mathematically identical attention without forced materialization —
    the differentiable oracle for the flash variants."""
    b, s, h, d = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    qt = jnp.swapaxes(q, 1, 2).astype(jnp.float32)
    kt = jnp.swapaxes(k, 1, 2).astype(jnp.float32)
    vt = jnp.swapaxes(v, 1, 2).astype(jnp.float32)
    scores = jnp.einsum("bhsd,bhtd->bhst", qt, kt) / jnp.sqrt(
        jnp.asarray(d, jnp.float32)
    )
    mask = _segment_mask(seg_ids, seg_ids)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, vt)
    out = jnp.where(jnp.any(mask, axis=-1)[..., None], out, 0.0)
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)


def flash_attention_scan(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    seg_ids: jax.Array,
    block_kv: int = 64,
) -> jax.Array:
    """FlashAttention forward structure in plain jnp (paper Alg. 13).

    Tiles the KV axis with an online-softmax carry (m, l, acc) so the
    [S, S] score matrix is never materialized; XLA compiles the scan body
    once. This is the "fused-structure" implementation used by the fast
    end-to-end artifacts; the Pallas version mirrors it tile-for-tile.
    """
    b, s, h, d = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qt = jnp.swapaxes(q, 1, 2).astype(jnp.float32)  # [B,H,S,D]
    kt = jnp.swapaxes(k, 1, 2).astype(jnp.float32)
    vt = jnp.swapaxes(v, 1, 2).astype(jnp.float32)

    n_blocks = (s + block_kv - 1) // block_kv
    pad = n_blocks * block_kv - s
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        seg_kv = jnp.pad(seg_ids, ((0, 0), (0, pad)))
    else:
        seg_kv = seg_ids

    kb = jnp.moveaxis(kt.reshape(b, h, n_blocks, block_kv, d), 2, 0)
    vb = jnp.moveaxis(vt.reshape(b, h, n_blocks, block_kv, d), 2, 0)
    segb = jnp.moveaxis(seg_kv.reshape(b, n_blocks, block_kv), 1, 0)
    q_pos = jnp.arange(s)

    def body(carry, blk):
        m, l, acc = carry
        k_j, v_j, seg_j, j = blk
        scores = jnp.einsum("bhsd,bhtd->bhst", qt, k_j) * scale
        kv_pos = j * block_kv + jnp.arange(block_kv)
        causal = q_pos[:, None] >= kv_pos[None, :]
        same = (
            (seg_ids[:, :, None] == seg_j[:, None, :])
            & (seg_ids[:, :, None] != 0)
            & (seg_j[:, None, :] != 0)
        )
        mask = (same & causal)[:, None, :, :]
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhst,bhtd->bhsd", p, v_j)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, h, s), dtype=jnp.float32)
    acc0 = jnp.zeros((b, h, s, d), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kb, vb, segb, jnp.arange(n_blocks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.where((l > 0)[..., None], out, 0.0)
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)


# ---------------------------------------------------------------------------
# Cross-entropy (paper Def. 5/6/7, Thm. 2/3/4; Alg. 1/2/3/19)
# ---------------------------------------------------------------------------


def cross_entropy_full(
    hidden: jax.Array,
    w_head: jax.Array,
    targets: jax.Array,
    z_loss: float = 0.0,
    label_smoothing: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Full-logit cross-entropy: materializes [T, V] (the paper's baseline).

    hidden: [T, H]; w_head: [V, H]; targets: [T] int32, -1 = ignore.
    Returns (summed loss over real tokens, n_real_tokens).
    """
    logits = hidden.astype(jnp.float32) @ w_head.astype(jnp.float32).T
    logits = jax.lax.optimization_barrier(logits)  # force materialization
    valid = targets >= 0
    tgt = jnp.where(valid, targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(logits, tgt[:, None], axis=-1)[:, 0]
    loss = lse - tgt_logit
    if label_smoothing > 0.0:
        smooth = lse - jnp.mean(logits, axis=-1)
        loss = (1.0 - label_smoothing) * loss + label_smoothing * smooth
    if z_loss > 0.0:
        loss = loss + z_loss * jnp.square(lse)
    loss = jnp.where(valid, loss, 0.0)
    return jnp.sum(loss), jnp.sum(valid.astype(jnp.float32))


def cce_chunked(
    hidden: jax.Array,
    w_head: jax.Array,
    targets: jax.Array,
    chunk: int = 1024,
    z_loss: float = 0.0,
    label_smoothing: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Cut Cross-Entropy: streams the vocabulary in chunks with an online
    logsumexp so the [T, V] logit tensor is never materialized (Alg. 1).

    Mathematically identical to :func:`cross_entropy_full`.
    """
    t, h = hidden.shape
    v = w_head.shape[0]
    n_chunks = (v + chunk - 1) // chunk
    pad = n_chunks * chunk - v
    wp = jnp.pad(w_head.astype(jnp.float32), ((0, pad), (0, 0)))
    wc = wp.reshape(n_chunks, chunk, h)
    hf = hidden.astype(jnp.float32)
    valid = targets >= 0
    tgt = jnp.where(valid, targets, 0)

    def body(carry, blk):
        m, d, tgt_logit, mean_acc = carry
        w_j, j = blk
        z = hf @ w_j.T  # [T, chunk] — only one chunk live at a time
        col = j * chunk + jnp.arange(chunk)
        in_vocab = col < v
        z = jnp.where(in_vocab[None, :], z, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(z, axis=-1))
        d = d * jnp.exp(m - m_new) + jnp.sum(jnp.exp(z - m_new[:, None]), axis=-1)
        in_chunk = (tgt >= j * chunk) & (tgt < (j + 1) * chunk)
        local = jnp.clip(tgt - j * chunk, 0, chunk - 1)
        z_t = jnp.take_along_axis(z, local[:, None], axis=-1)[:, 0]
        tgt_logit = jnp.where(in_chunk, z_t, tgt_logit)
        mean_acc = mean_acc + jnp.sum(jnp.where(in_vocab[None, :], z, 0.0), axis=-1)
        return (m_new, d, tgt_logit, mean_acc), None

    m0 = jnp.full((t,), NEG_INF, dtype=jnp.float32)
    d0 = jnp.zeros((t,), dtype=jnp.float32)
    tl0 = jnp.zeros((t,), dtype=jnp.float32)
    ma0 = jnp.zeros((t,), dtype=jnp.float32)
    (m, d, tgt_logit, mean_acc), _ = jax.lax.scan(
        body, (m0, d0, tl0, ma0), (wc, jnp.arange(n_chunks))
    )
    lse = jnp.log(d) + m
    loss = lse - tgt_logit
    if label_smoothing > 0.0:
        smooth = lse - mean_acc / v
        loss = (1.0 - label_smoothing) * loss + label_smoothing * smooth
    if z_loss > 0.0:
        loss = loss + z_loss * jnp.square(lse)
    loss = jnp.where(valid, loss, 0.0)
    return jnp.sum(loss), jnp.sum(valid.astype(jnp.float32))


def online_logsumexp(x: jax.Array) -> jax.Array:
    """Streaming logsumexp over the last axis (paper Def. 13 / Thm. 2) —
    element-at-a-time online softmax used by correctness tests."""

    def body(carry, xi):
        m, d = carry
        m_new = jnp.maximum(m, xi)
        d = d * jnp.exp(m - m_new) + jnp.exp(xi - m_new)
        return (m_new, d), None

    m0 = jnp.full(x.shape[:-1], -jnp.inf, dtype=jnp.float32)
    d0 = jnp.zeros(x.shape[:-1], dtype=jnp.float32)
    (m, d), _ = jax.lax.scan(
        body, (m0, d0), jnp.moveaxis(x.astype(jnp.float32), -1, 0)
    )
    return jnp.log(d) + m


# ---------------------------------------------------------------------------
# LoRA linear (paper Def. 10/16, Alg. 10)
# ---------------------------------------------------------------------------


def lora_linear(
    x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array, alpha: float
) -> jax.Array:
    """Fused-in-spirit y = x@W^T + (alpha/r) * (x@A^T)@B^T.

    x: [T, K]; w: [N, K]; a: [R, K]; b: [N, R].
    """
    r = a.shape[0]
    scale = alpha / r
    return x @ w.T + scale * ((x @ a.T) @ b.T)


def lora_linear_naive(
    x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array, alpha: float
) -> jax.Array:
    """Three separate GEMMs with materialized intermediates."""
    r = a.shape[0]
    scale = alpha / r
    base = jax.lax.optimization_barrier(x @ w.T)
    h = jax.lax.optimization_barrier(x @ a.T)
    lora = jax.lax.optimization_barrier(h @ b.T)
    return base + scale * lora


# ---------------------------------------------------------------------------
# AdamW (paper Def. 8, Alg. 18) and friends (§5, §S10)
# ---------------------------------------------------------------------------


def adamw_update(
    p: jax.Array,
    g: jax.Array,
    m: jax.Array,
    v: jax.Array,
    lr,
    step,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    clip_coef=1.0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One fused AdamW step. `step` is 1-based. Returns (p', m', v')."""
    g = g * clip_coef
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    bc1 = 1.0 - beta1**step
    bc2 = 1.0 - beta2**step
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    p_new = p * (1.0 - lr * weight_decay) - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return p_new, m_new, v_new


def adamw_update_naive(
    p, g, m, v, lr, step, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.01,
    clip_coef=1.0,
):
    """Unfused AdamW: the six separate kernels from §S3.1, barrier-separated."""
    g = jax.lax.optimization_barrier(g * clip_coef)
    p = jax.lax.optimization_barrier(p * (1.0 - lr * weight_decay))
    m_new = jax.lax.optimization_barrier(beta1 * m + (1.0 - beta1) * g)
    v_new = jax.lax.optimization_barrier(beta2 * v + (1.0 - beta2) * g * g)
    m_hat = jax.lax.optimization_barrier(m_new / (1.0 - beta1**step))
    v_hat = jax.lax.optimization_barrier(v_new / (1.0 - beta2**step))
    p_new = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return p_new, m_new, v_new


def adam_atan2_update(
    p, g, m, v, lr, step, beta1=0.9, beta2=0.999, weight_decay=0.01, clip_coef=1.0
):
    """Adam-atan2 (paper Def. 20): bounded, eps-free update."""
    g = g * clip_coef
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1**step)
    v_hat = v_new / (1.0 - beta2**step)
    p_new = p * (1.0 - lr * weight_decay) - lr * jnp.arctan2(m_hat, jnp.sqrt(v_hat))
    return p_new, m_new, v_new


def newton_schulz(g: jax.Array, steps: int = 5) -> jax.Array:
    """Newton–Schulz orthogonalization (paper Alg. 12 / Lemma 2).

    Returns the (approximately) orthogonal polar factor, scaled by ||G||_F.
    """
    gf = g.astype(jnp.float32)
    norm = jnp.linalg.norm(gf) + 1e-12
    x = gf / norm
    for _ in range(steps):
        x = 1.5 * x - 0.5 * (x @ x.T) @ x
    return x * norm


def muon_update(p, g, mom, lr, beta=0.95, ns_steps=5, clip_coef=1.0):
    """Muon (paper Def. 19): momentum + Newton–Schulz-orthogonalized update.

    Only sensible for 2-D params; callers fall back to AdamW for vectors.
    """
    g = g * clip_coef
    mom_new = beta * mom + g
    upd = newton_schulz(mom_new, ns_steps) / (jnp.linalg.norm(mom_new) + 1e-12)
    p_new = p - lr * upd * jnp.sqrt(jnp.asarray(p.size, jnp.float32))
    return p_new, mom_new


def schedule_free_update(
    p, z, g, lr, step, weight_decay=0.01, clip_coef=1.0
):
    """Schedule-Free SGD-style update (paper Def. 18, §S10.1).

    State: z (fast iterate). p is the averaged (slow) iterate:
      z' = z - lr * (g + wd * p)
      p' = (1 - c) * p + c * z',  c = 1/step
    """
    g = (g * clip_coef) + weight_decay * p
    z_new = z - lr * g
    c = 1.0 / step
    p_new = (1.0 - c) * p + c * z_new
    return p_new, z_new


def global_grad_norm(grads) -> jax.Array:
    """sqrt(sum of squared L2 norms) over a flat list of gradient arrays."""
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in grads)
    return jnp.sqrt(sq)


# ---------------------------------------------------------------------------
# Quantization (paper Def. 9/22/23, Alg. 15/23; §S11, §S16)
# ---------------------------------------------------------------------------


def int8_quantize_blockwise(x: jax.Array, block: int = 128):
    """Block-wise symmetric int8 quantization (paper Def. 9).

    Returns (q int8 [n_blocks, block], scales f32 [n_blocks]) over the
    flattened input, zero-padded to a block multiple.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    n_blocks = (n + block - 1) // block
    padded = jnp.pad(flat, (0, n_blocks * block - n)).reshape(n_blocks, block)
    amax = jnp.max(jnp.abs(padded), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(padded / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize_blockwise(q: jax.Array, scale: jax.Array, n: int, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def fp8_e4m3_quantize(x: jax.Array) -> jax.Array:
    """Simulated E4M3 round-trip: clamp to ±448, round to 3 mantissa bits."""
    return _fp8_sim(x, mant_bits=3, max_val=448.0, min_exp=-6)


def fp8_e5m2_quantize(x: jax.Array) -> jax.Array:
    """Simulated E5M2 round-trip: clamp to ±57344, round to 2 mantissa bits."""
    return _fp8_sim(x, mant_bits=2, max_val=57344.0, min_exp=-14)


def _fp8_sim(x: jax.Array, mant_bits: int, max_val: float, min_exp: int):
    xf = x.astype(jnp.float32)
    sign = jnp.sign(xf)
    mag = jnp.minimum(jnp.abs(xf), max_val)
    # exponent of the leading bit, clamped at the subnormal boundary
    exp = jnp.floor(jnp.log2(jnp.maximum(mag, 2.0**min_exp)))
    exp = jnp.maximum(exp, float(min_exp))
    quantum = jnp.exp2(exp - mant_bits)
    q = jnp.round(mag / quantum) * quantum
    q = jnp.minimum(q, max_val)
    return (sign * jnp.where(mag == 0, 0.0, q)).astype(x.dtype)


def fp8_blockwise_e4m3(x: jax.Array, block: int = 128):
    """Block-wise scaled E4M3 (paper Alg. 15): scale each block so its amax
    maps to 448, quantize, return (q_sim f32 blocks, scales)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    n_blocks = (n + block - 1) // block
    padded = jnp.pad(flat, (0, n_blocks * block - n)).reshape(n_blocks, block)
    amax = jnp.max(jnp.abs(padded), axis=1)
    scale = jnp.where(amax > 0, amax / 448.0, 1.0)
    q = fp8_e4m3_quantize(padded / scale[:, None])
    return q, scale


def kahan_sum(xs: jax.Array) -> jax.Array:
    """Kahan-compensated summation over axis 0 (paper Def. 14, §S2.4)."""

    def body(carry, x):
        s, c = carry
        y = x - c
        t = s + y
        c = (t - s) - y
        return (t, c), None

    s0 = jnp.zeros(xs.shape[1:], dtype=xs.dtype)
    (s, _), _ = jax.lax.scan(body, (s0, s0), xs)
    return s
