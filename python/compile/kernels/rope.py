"""Fused QK-RoPE Pallas kernel (paper Alg. 8, Prop. 8).

One kernel rotates both Q and K for a (batch·seq) position: the cos/sin
values are computed once per position and shared across all query *and* KV
heads — the Triton kernel's "shared trigonometric loads". The rotation is
the split-half convention (rotate_half), matching `ref.apply_rope`.

Backward = rotation by -θ (rotations are orthogonal), so the VJP reuses the
same kernel with negated sin; zero extra code paths to validate.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _rope_kernel(q_ref, k_ref, cos_ref, sin_ref, qo_ref, ko_ref):
    cos = cos_ref[...].astype(jnp.float32)  # [1, half]
    sin = sin_ref[...].astype(jnp.float32)

    def rotate(x):  # x: [1, H, D]
        half = x.shape[-1] // 2
        x1 = x[..., :half].astype(jnp.float32)
        x2 = x[..., half:].astype(jnp.float32)
        y1 = x1 * cos[:, None, :] - x2 * sin[:, None, :]
        y2 = x2 * cos[:, None, :] + x1 * sin[:, None, :]
        return jnp.concatenate([y1, y2], axis=-1)

    qo_ref[...] = rotate(q_ref[...]).astype(qo_ref.dtype)
    ko_ref[...] = rotate(k_ref[...]).astype(ko_ref.dtype)


def _rope_qk_flat(q, k, cos, sin):
    """q: [T, Hq, D], k: [T, Hkv, D], cos/sin: [T, D/2]."""
    t, hq, d = q.shape
    hkv = k.shape[1]
    half = d // 2
    return pl.pallas_call(
        _rope_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, hkv, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, half), lambda i: (i, 0)),
            pl.BlockSpec((1, half), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, hq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, hkv, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, hq, d), q.dtype),
            jax.ShapeDtypeStruct((t, hkv, d), k.dtype),
        ],
        interpret=INTERPRET,
    )(q, k, cos, sin)


def _cos_sin(positions, d, base):
    half = d // 2
    inv_freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    freqs = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(freqs), jnp.sin(freqs)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def rope_qk(q: jax.Array, k: jax.Array, positions: jax.Array, base: float = 10000.0):
    """Fused QK rotary embedding.

    q: [B, S, Hq, D]; k: [B, S, Hkv, D]; positions: [B, S] int32.
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    cos, sin = _cos_sin(positions.reshape(-1), d, base)
    qo, ko = _rope_qk_flat(q.reshape(-1, hq, d), k.reshape(-1, hkv, d), cos, sin)
    return qo.reshape(b, s, hq, d), ko.reshape(b, s, hkv, d)


def _vjp_fwd(q, k, positions, base):
    return rope_qk(q, k, positions, base), positions


def _vjp_bwd(base, positions, cotangents):
    dq_rot, dk_rot = cotangents
    b, s, hq, d = dq_rot.shape
    hkv = dk_rot.shape[2]
    cos, sin = _cos_sin(positions.reshape(-1), d, base)
    dq, dk = _rope_qk_flat(
        dq_rot.reshape(-1, hq, d), dk_rot.reshape(-1, hkv, d), cos, -sin
    )
    return dq.reshape(b, s, hq, d), dk.reshape(b, s, hkv, d), None


rope_qk.defvjp(_vjp_fwd, _vjp_bwd)
