"""Fused RMSNorm Pallas kernel (paper Alg. 4/5, Prop. 3/7).

One grid step per row: the row is staged into VMEM once, the variance
reduction, rsqrt and scale all happen in registers/VMEM, and the output is
written once — the Triton kernel's single-pass structure, re-expressed with
``BlockSpec`` for the TPU memory hierarchy (VMEM tile = the Triton thread
block). ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls (see DESIGN.md §Hardware-Adaptation).

VMEM footprint per grid step: 2·d floats (row + gamma) + d outputs —
for d=4096 that is 48 KiB, comfortably inside the ~16 MiB VMEM budget;
block shapes would be padded to (8, 128) lanes on real TPU hardware.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INTERPRET = True


def _fwd_kernel(x_ref, g_ref, y_ref, rstd_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x)
    rstd = jax.lax.rsqrt(var + eps)
    y_ref[...] = (x * rstd * g_ref[...].astype(jnp.float32)).astype(y_ref.dtype)
    rstd_ref[...] = jnp.full_like(rstd_ref[...], rstd)


def _bwd_kernel(x_ref, g_ref, rstd_ref, dy_ref, dx_ref, dgamma_ref, *, d: int):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    rstd = rstd_ref[0]
    xbar = x * rstd
    c1 = jnp.sum(dy * g * xbar) / d
    dx_ref[...] = (rstd * (g * dy - xbar * c1)).astype(dx_ref.dtype)
    # per-row dgamma partial; summed over rows by the caller
    dgamma_ref[...] = (dy * xbar).astype(dgamma_ref.dtype)


def _rmsnorm_fwd_2d(x, gamma, eps):
    """x: [T, d] -> (y [T, d], rstd [T])."""
    t, d = x.shape
    y, rstd = pl.pallas_call(
        partial(_fwd_kernel, eps=eps),
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), x.dtype),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x, gamma)
    return y, rstd


def _rmsnorm_bwd_2d(x, gamma, rstd, dy):
    t, d = x.shape
    dx, dgamma_rows = pl.pallas_call(
        partial(_bwd_kernel, d=d),
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), x.dtype),
            jax.ShapeDtypeStruct((t, d), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x, gamma, rstd, dy)
    return dx, jnp.sum(dgamma_rows, axis=0).astype(gamma.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Fused RMSNorm over the last axis; any leading shape."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    y, _ = _rmsnorm_fwd_2d(x.reshape(-1, d), gamma, eps)
    return y.reshape(*lead, d)


def _vjp_fwd(x, gamma, eps):
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    y, rstd = _rmsnorm_fwd_2d(x2, gamma, eps)
    return y.reshape(*lead, d), (x2, gamma, rstd, lead)


def _vjp_bwd(eps, res, dy):
    x2, gamma, rstd, lead = res
    d = x2.shape[-1]
    dx, dgamma = _rmsnorm_bwd_2d(x2, gamma, rstd, dy.reshape(-1, d))
    return dx.reshape(*lead, d), dgamma


rmsnorm.defvjp(_vjp_fwd, _vjp_bwd)
