"""Fused AdamW Pallas kernel (paper Alg. 18, §S3.1/§S4.1).

One grid step per parameter block: params, grads, m, v are each read once,
all six update phases (clip, decoupled decay, both moment EMAs, bias
correction, adaptive step) happen in VMEM, and the three outputs are
written once — 7 tensors of traffic instead of the ~27 of the six-kernel
unfused sequence. Block size 1024 matches the paper's choice (§S4.1).

Bias corrections are passed as precomputed scalars (computed host-side /
graph-side from the step counter) — the paper's "no GPU sync" point.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True
BLOCK = 1024


def _adamw_kernel(
    p_ref, g_ref, m_ref, v_ref, scal_ref, po_ref, mo_ref, vo_ref,
    *, beta1, beta2, eps, weight_decay,
):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lr = scal_ref[0]
    clip_coef = scal_ref[1]
    bc1 = scal_ref[2]
    bc2 = scal_ref[3]

    g = g * clip_coef
    p = p * (1.0 - lr * weight_decay)
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    p_new = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)

    po_ref[...] = p_new.astype(po_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)
    vo_ref[...] = v_new.astype(vo_ref.dtype)


def adamw_update(
    p: jax.Array,
    g: jax.Array,
    m: jax.Array,
    v: jax.Array,
    lr,
    step,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    clip_coef=1.0,
):
    """Fused AdamW over an arbitrary-shape tensor. `step` is 1-based."""
    shape = p.shape
    n = p.size
    n_blocks = (n + BLOCK - 1) // BLOCK
    pad = n_blocks * BLOCK - n

    def prep(x):
        return jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(
            n_blocks, BLOCK
        )

    stepf = jnp.asarray(step, jnp.float32)
    scalars = jnp.stack(
        [
            jnp.asarray(lr, jnp.float32),
            jnp.asarray(clip_coef, jnp.float32),
            1.0 - beta1**stepf,
            1.0 - beta2**stepf,
        ]
    )
    po, mo, vo = pl.pallas_call(
        partial(
            _adamw_kernel, beta1=beta1, beta2=beta2, eps=eps,
            weight_decay=weight_decay,
        ),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
        ],
        interpret=INTERPRET,
    )(prep(p), prep(g), prep(m), prep(v), scalars)

    def unprep(x, dtype):
        return x.reshape(-1)[:n].reshape(shape).astype(dtype)

    return unprep(po, p.dtype), unprep(mo, m.dtype), unprep(vo, v.dtype)
