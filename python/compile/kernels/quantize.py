"""Block-wise quantization Pallas kernels (paper Def. 9, Alg. 15/23).

int8 (8-bit optimizer states, §S11) and simulated FP8 E4M3 (DeepSeek-V3
style, §S16) with one scale per block. One grid step per block: amax
reduction, scale, round and clamp all happen in VMEM.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INTERPRET = True


def _int8_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = jnp.full_like(s_ref[...], scale)


def int8_quantize_blockwise(x: jax.Array, block: int = 128):
    """Returns (q int8 [n_blocks, block], scales f32 [n_blocks])."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    n_blocks = (n + block - 1) // block
    padded = jnp.pad(flat, (0, n_blocks * block - n)).reshape(n_blocks, block)
    return pl.pallas_call(
        _int8_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block), jnp.int8),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(padded)


def _fp8_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 448.0, 1.0)
    q_ref[...] = ref.fp8_e4m3_quantize(x / scale)
    s_ref[...] = jnp.full_like(s_ref[...], scale)


def fp8_blockwise_e4m3(x: jax.Array, block: int = 128):
    """Block-wise scaled simulated-E4M3 (paper Alg. 15).

    Returns (q f32 [n_blocks, block] holding E4M3-representable values,
    scales f32 [n_blocks]).
    """
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    n_blocks = (n + block - 1) // block
    padded = jnp.pad(flat, (0, n_blocks * block - n)).reshape(n_blocks, block)
    return pl.pallas_call(
        _fp8_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(padded)
