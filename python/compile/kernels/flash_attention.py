"""FlashAttention Pallas kernel (paper Alg. 13, Thm. 7).

Grid: (batch, head, q-block). Each grid step holds one Q tile in VMEM and
streams KV tiles through an online-softmax carry (m, l, acc) — exactly the
FlashAttention schedule, with ``BlockSpec`` expressing the HBM→VMEM tiling
that the CUDA version expressed with thread blocks. The [S, S] score matrix
never exists; memory is O(block_q · block_kv) per step.

IO complexity (paper Thm. 7): each KV tile is re-read once per Q block →
O(N²d/B_q) HBM reads; with B_q = Θ(√(M/d)) this is the paper's O(N²d²/M).
VMEM per step: (B_q + 2·B_kv)·d floats + B_q·B_kv scores; for the default
64/64 tiles at d=64 that is ~64 KiB.

Supports GQA (KV heads shared across query-head groups) and packed
sequences via segment ids (0 = padding). Backward: flash-style recompute
in chunked jnp (`ref.attention` VJP) — the standard
recompute-not-store trade (paper §2 Prop. 1); a full Pallas backward is a
compile-only target on real TPUs.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INTERPRET = True
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, segq_ref, segkv_ref, o_ref, *, block_q, block_kv, scale
):
    iq = pl.program_id(2)
    s = k_ref.shape[2]
    d = q_ref.shape[-1]
    n_kv = s // block_kv

    q = q_ref[0, 0].astype(jnp.float32) * scale  # [bq, d]
    segq = segq_ref[0]  # [bq]
    q_pos = iq * block_q + jnp.arange(block_q)

    def body(j, carry):
        m, l, acc = carry
        k_j = jax.lax.dynamic_slice(
            k_ref[0, 0], (j * block_kv, 0), (block_kv, d)
        ).astype(jnp.float32)
        v_j = jax.lax.dynamic_slice(
            v_ref[0, 0], (j * block_kv, 0), (block_kv, d)
        ).astype(jnp.float32)
        seg_j = jax.lax.dynamic_slice(segkv_ref[0], (j * block_kv,), (block_kv,))
        scores = q @ k_j.T  # [bq, bkv]
        kv_pos = j * block_kv + jnp.arange(block_kv)
        causal = q_pos[:, None] >= kv_pos[None, :]
        same = (segq[:, None] == seg_j[None, :]) & (segq[:, None] != 0) & (
            seg_j[None, :] != 0
        )
        mask = causal & same
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v_j
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    out = jnp.where((l > 0)[:, None], out, 0.0)
    o_ref[0, 0] = out.astype(o_ref.dtype)


def _flash_fwd(q, k, v, seg_ids, block_q, block_kv):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    assert s % block_q == 0 and s % block_kv == 0, "seq must divide block sizes"
    qt = jnp.swapaxes(q, 1, 2)  # [B, H, S, D]
    kt = jnp.swapaxes(k, 1, 2)  # [B, Hkv, S, D]
    vt = jnp.swapaxes(v, 1, 2)
    scale = 1.0 / float(d) ** 0.5

    out = pl.pallas_call(
        partial(_flash_kernel, block_q=block_q, block_kv=block_kv, scale=scale),
        grid=(b, h, s // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq: (ib, ih, iq, 0)),
            pl.BlockSpec(
                (1, 1, s, d), lambda ib, ih, iq, _g=group: (ib, ih // _g, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, s, d), lambda ib, ih, iq, _g=group: (ib, ih // _g, 0, 0)
            ),
            pl.BlockSpec((1, block_q), lambda ib, ih, iq: (ib, iq)),
            pl.BlockSpec((1, s), lambda ib, ih, iq: (ib, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda ib, ih, iq: (ib, ih, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        interpret=INTERPRET,
    )(qt, kt, vt, seg_ids, seg_ids)
    return jnp.swapaxes(out, 1, 2)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    seg_ids: jax.Array,
    block_q: int = 64,
    block_kv: int = 64,
) -> jax.Array:
    """Tiled online-softmax attention. q: [B,S,Hq,D], k/v: [B,S,Hkv,D]."""
    return _flash_fwd(q, k, v, seg_ids, block_q, block_kv)


def _vjp_fwd(q, k, v, seg_ids, block_q, block_kv):
    out = _flash_fwd(q, k, v, seg_ids, block_q, block_kv)
    return out, (q, k, v, seg_ids)


def _vjp_bwd(block_q, block_kv, res, dout):
    q, k, v, seg_ids = res
    # Recompute-based backward (FlashAttention's own strategy): differentiate
    # the mathematically-identical reference under the same mask.
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention(q_, k_, v_, seg_ids), q, k, v)
    dq, dk, dv = vjp(dout)
    return dq, dk, dv, None


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
