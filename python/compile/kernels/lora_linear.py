"""Fused LoRA linear Pallas kernel (paper Def. 16, Alg. 10, Prop. 9).

Grid: (m-blocks, n-blocks). Each step loads one X tile once and uses it for
both the base GEMM X·Wᵀ and the adapter path (X·Aᵀ)·Bᵀ — the "shared input
loads" of the LoRAFusion identity — accumulating into a single acc tile, so
the [M, R] intermediate never reaches HBM.

VMEM per step: BM·K (x) + BN·K (w) + R·K (a) + BN·R (b) + BM·BN (acc);
with BM=BN=64, K tiled by 128, R≤64 this stays well under the VMEM budget.

The VJP is the plain bilinear gradient (three small GEMMs), exact.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _lora_kernel(x_ref, w_ref, a_ref, b_ref, y_ref, *, scale):
    x = x_ref[...].astype(jnp.float32)  # [BM, K]
    w = w_ref[...].astype(jnp.float32)  # [BN, K]
    a = a_ref[...].astype(jnp.float32)  # [R, K]
    b = b_ref[...].astype(jnp.float32)  # [BN, R]
    acc = x @ w.T  # base GEMM
    h = x @ a.T  # adapter projection, stays in registers/VMEM
    acc = acc + scale * (h @ b.T)
    y_ref[...] = acc.astype(y_ref.dtype)


def _lora_fwd(x, w, a, b, alpha, block_m, block_n):
    m, k = x.shape
    n = w.shape[0]
    r = a.shape[0]
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    scale = alpha / r
    return pl.pallas_call(
        partial(_lora_kernel, scale=scale),
        grid=(m // block_m, n // block_n),
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
            pl.BlockSpec((r, k), lambda i, j: (0, 0)),
            pl.BlockSpec((block_n, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(x, w, a, b)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def lora_linear(
    x: jax.Array,
    w: jax.Array,
    a: jax.Array,
    b: jax.Array,
    alpha: float,
    block_m: int = 64,
    block_n: int = 64,
) -> jax.Array:
    """y = x@Wᵀ + (alpha/r)·(x@Aᵀ)@Bᵀ. x: [M,K], w: [N,K], a: [R,K], b: [N,R]."""
    return _lora_fwd(x, w, a, b, alpha, block_m, block_n)


def _vjp_fwd(x, w, a, b, alpha, block_m, block_n):
    return _lora_fwd(x, w, a, b, alpha, block_m, block_n), (x, w, a, b)


def _vjp_bwd(alpha, block_m, block_n, res, dy):
    x, w, a, b = res
    r = a.shape[0]
    scale = alpha / r
    dyf = dy.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    dx = dyf @ w.astype(jnp.float32) + scale * (dyf @ bf) @ af
    dw = dyf.T @ xf
    h = xf @ af.T  # [M, R]
    db = scale * (dyf.T @ h)
    da = scale * ((bf.T @ dyf.T) @ xf)
    return (
        dx.astype(x.dtype),
        dw.astype(w.dtype),
        da.astype(a.dtype),
        db.astype(b.dtype),
    )


lora_linear.defvjp(_vjp_fwd, _vjp_bwd)
