"""Cut Cross-Entropy Pallas kernel (paper Alg. 1/2/19, Thm. 2/3/4).

Grid: (rows, vocab-chunks), chunk axis innermost. Each grid step computes
one [1, C] logit chunk as h·W_chunkᵀ in VMEM and folds it into the online
softmax carry (running max m, running sum d, target logit) that lives in
output refs persisting across the chunk axis (same BlockSpec block for all
chunk steps — the Pallas idiom for cross-step carries). The [T, V] logit
tensor never exists: peak live memory is one C-column chunk, the paper's
V/C reduction (37× for V=151936, C=4096).

Chunk-size selection (paper Prop. 6, TPU form): C* = min(VMEM/(4·(H+1)), V)
so the W chunk [C, H] plus the logit row [1, C] fit in VMEM.

Backward: chunked jnp scan (recompute chunk logits from the cached lse,
subtract the target indicator, accumulate grad_h and grad_W chunk-by-chunk)
— identical chunking structure, never materializes [T, V] either.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True
NEG_INF = -1e30


def _cce_fwd_kernel(
    h_ref, w_ref, t_ref, loss_ref, lse_ref, m_ref, d_ref, tl_ref, *, chunk, v, n_chunks
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref[...])
        tl_ref[...] = jnp.zeros_like(tl_ref[...])
        loss_ref[...] = jnp.zeros_like(loss_ref[...])
        lse_ref[...] = jnp.zeros_like(lse_ref[...])

    h = h_ref[...].astype(jnp.float32)  # [1, H]
    w = w_ref[...].astype(jnp.float32)  # [C, H]
    z = (h @ w.T)[0]  # [C]
    col = j * chunk + jnp.arange(chunk)
    z = jnp.where(col < v, z, NEG_INF)

    m = m_ref[0]
    d = d_ref[0]
    chunk_max = jnp.max(z)
    m_new = jnp.maximum(m, chunk_max)
    d_new = d * jnp.exp(m - m_new) + jnp.sum(jnp.exp(z - m_new))
    m_ref[...] = jnp.full_like(m_ref[...], m_new)
    d_ref[...] = jnp.full_like(d_ref[...], d_new)

    tgt = t_ref[0]
    in_chunk = (tgt >= j * chunk) & (tgt < (j + 1) * chunk)
    local = jnp.clip(tgt - j * chunk, 0, chunk - 1)
    z_t = jnp.where(in_chunk, z[local], tl_ref[0])
    tl_ref[...] = jnp.full_like(tl_ref[...], z_t)

    @pl.when(j == n_chunks - 1)
    def _finish():
        lse = jnp.log(d_ref[0]) + m_ref[0]
        valid = t_ref[0] >= 0
        lse_ref[...] = jnp.full_like(lse_ref[...], lse)
        loss_ref[...] = jnp.full_like(
            loss_ref[...], jnp.where(valid, lse - tl_ref[0], 0.0)
        )


def _cce_fwd(hidden, w_head, targets, chunk):
    """hidden: [T, H], w_head: [V, H], targets: [T] (-1 = ignore).

    Returns (per-row loss [T], per-row lse [T]).
    """
    t, h = hidden.shape
    v = w_head.shape[0]
    n_chunks = (v + chunk - 1) // chunk
    pad = n_chunks * chunk - v
    wp = jnp.pad(w_head, ((0, pad), (0, 0)))
    tgt = jnp.where(targets >= 0, targets, 0)

    loss, lse, _m, _d, _tl = pl.pallas_call(
        partial(_cce_fwd_kernel, chunk=chunk, v=v, n_chunks=n_chunks),
        grid=(t, n_chunks),
        in_specs=[
            pl.BlockSpec((1, h), lambda i, j: (i, 0)),
            pl.BlockSpec((chunk, h), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),  # loss
            jax.ShapeDtypeStruct((t,), jnp.float32),  # lse
            jax.ShapeDtypeStruct((t,), jnp.float32),  # m carry
            jax.ShapeDtypeStruct((t,), jnp.float32),  # d carry
            jax.ShapeDtypeStruct((t,), jnp.float32),  # target-logit carry
        ],
        interpret=INTERPRET,
    )(hidden, wp, tgt)
    loss = jnp.where(targets >= 0, loss, 0.0)
    return loss, lse


def _cce_bwd_chunked(hidden, w_head, targets, lse, dloss, chunk):
    """Chunked backward (paper Alg. 3): grad_z = softmax(z) - 1[target]."""
    t, h = hidden.shape
    v = w_head.shape[0]
    n_chunks = (v + chunk - 1) // chunk
    pad = n_chunks * chunk - v
    wp = jnp.pad(w_head.astype(jnp.float32), ((0, pad), (0, 0))).reshape(
        n_chunks, chunk, h
    )
    hf = hidden.astype(jnp.float32)
    valid = (targets >= 0).astype(jnp.float32)
    tgt = jnp.where(targets >= 0, targets, 0)
    scale = (dloss * valid)[:, None]  # [T, 1]

    def body(grad_h, blk):
        w_j, j = blk
        z = hf @ w_j.T  # [T, C]
        col = j * chunk + jnp.arange(chunk)
        probs = jnp.where(col[None, :] < v, jnp.exp(z - lse[:, None]), 0.0)
        onehot = (tgt[:, None] == col[None, :]).astype(jnp.float32)
        gz = (probs - onehot) * scale  # [T, C]
        grad_h = grad_h + gz @ w_j
        grad_w_j = gz.T @ hf  # [C, H]
        return grad_h, grad_w_j

    gh0 = jnp.zeros_like(hf)
    grad_h, grad_w = jax.lax.scan(body, gh0, (wp, jnp.arange(n_chunks)))
    grad_w = grad_w.reshape(n_chunks * chunk, h)[:v]
    return grad_h.astype(hidden.dtype), grad_w.astype(w_head.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def cce_loss(
    hidden: jax.Array, w_head: jax.Array, targets: jax.Array, chunk: int = 1024
) -> tuple[jax.Array, jax.Array]:
    """Cut Cross-Entropy: (sum loss, n_valid_tokens) without full logits."""
    loss, _ = _cce_fwd(hidden, w_head, targets, chunk)
    n = jnp.sum((targets >= 0).astype(jnp.float32))
    return jnp.sum(loss), n


def _vjp_fwd(hidden, w_head, targets, chunk):
    loss, lse = _cce_fwd(hidden, w_head, targets, chunk)
    n = jnp.sum((targets >= 0).astype(jnp.float32))
    return (jnp.sum(loss), n), (hidden, w_head, targets, lse)


def _vjp_bwd(chunk, res, cot):
    dsum, _dn = cot
    hidden, w_head, targets, lse = res
    t = hidden.shape[0]
    dloss = jnp.broadcast_to(dsum, (t,))
    gh, gw = _cce_bwd_chunked(hidden, w_head, targets, lse, dloss, chunk)
    return gh, gw, None


cce_loss.defvjp(_vjp_fwd, _vjp_bwd)
