"""Chronicals L2: the JAX training graph (build-time only).

A Qwen2.5-style decoder-only transformer (RMSNorm, GQA + RoPE, SwiGLU,
untied LM head) with every optimization of the paper expressible as a
*variant* of the same training-step graph:

* attention: ``naive`` (score-materializing, barriered), ``flash_scan``
  (online-softmax tiles in jnp — "fused structure"), ``flash_pallas``
  (the L1 Pallas kernel);
* elementwise kernels (RMSNorm / SwiGLU / RoPE): ``naive`` (barriered,
  eager-style), ``jnp`` (fusable), ``pallas`` (L1 kernels);
* loss: ``full`` (materializes [T, V] logits), ``cce_scan`` (Cut
  Cross-Entropy, chunked online logsumexp), ``cce_pallas``;
* optimizer: ``adamw_naive`` (six barrier-separated phases, §S3.1),
  ``adamw`` (fused), ``adamw_pallas``, ``sf`` (Schedule-Free), ``muon``,
  ``atan2`` (Adam-atan2);
* parameterization: ``full``, ``lora`` (r, alpha; LoRA+ via a separate
  runtime lr_b scalar so λ = lr_b/lr needs no recompile), ``dora``;
* ``broken=True`` reproduces the paper's "Unsloth fast mode" bug: the
  loss is computed on ``stop_gradient``-ed parameters, so XLA dead-code
  eliminates the whole backward pass — throughput jumps and grad_norm
  is exactly 0.0 (Fig. 10/22).

The training step is a *single* XLA executable: params + optimizer state
+ batch + (step, lr, lr_b) → new params + new state + (loss, grad_norm,
n_tokens). Python never runs at training time; the Rust L3 keeps all
state device-resident and feeds batches.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import rmsnorm as k_rmsnorm
from .kernels import swiglu as k_swiglu
from .kernels import rope as k_rope
from .kernels import flash_attention as k_flash
from .kernels import cce as k_cce
from .kernels import adamw as k_adamw
from .kernels import lora_linear as k_lora

# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    rope_base: float = 10000.0
    rms_eps: float = 1e-6

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self, family: str = "full", lora_rank: int = 32) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        hkv = self.n_kv_heads * self.head_dim
        per_layer = d * d + 2 * d * hkv + d * d + 3 * d * f + 2 * d
        n = v * d * 2 + self.n_layers * per_layer + d
        if family in ("lora", "dora"):
            r = lora_rank
            lora = self.n_layers * (2 * r * (d + d) + 2 * r * (d + hkv))
            if family == "dora":
                lora += self.n_layers * (2 * d + 2 * hkv)
            n += lora
        return n


@dataclasses.dataclass(frozen=True)
class StepConfig:
    attention: str = "flash_scan"  # naive | ref | flash_scan | flash_pallas
    kernels: str = "jnp"  # naive | jnp | pallas
    loss: str = "cce_scan"  # full | cce_scan | cce_pallas
    optimizer: str = "adamw"  # adamw_naive | adamw | adamw_pallas | sf | muon | atan2
    family: str = "full"  # full | lora | dora
    lora_rank: int = 32
    lora_alpha: int = 64
    broken: bool = False  # "Unsloth fast mode": detached loss, zero grads
    cce_chunk: int = 1024
    flash_block: int = 64
    max_grad_norm: float = 1.0
    weight_decay: float = 0.01
    z_loss: float = 0.0
    label_smoothing: float = 0.0


# Named model sizes. "e2e" is the end-to-end demo scale (§Substitutions:
# the paper's 494M Qwen2.5-0.5B is scaled to fit a CPU-PJRT substrate; all
# shape *ratios* — GQA grouping, ff multiple, vocab≫d — are preserved).
MODEL_PRESETS: dict[str, ModelConfig] = {
    "tiny": ModelConfig(vocab=512, d_model=64, n_layers=2, n_heads=4,
                        n_kv_heads=2, d_ff=128),
    "small": ModelConfig(vocab=4096, d_model=256, n_layers=4, n_heads=8,
                         n_kv_heads=4, d_ff=768),
    "e2e": ModelConfig(vocab=8192, d_model=384, n_layers=6, n_heads=8,
                       n_kv_heads=4, d_ff=1024),
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _layer_names(cfg: ModelConfig) -> list[str]:
    return [f"layer_{i:02d}" for i in range(cfg.n_layers)]


def param_specs(cfg: ModelConfig, family: str, lora_rank: int = 32):
    """Ordered (name, shape) list. Trainable params come FIRST — this is the
    calling convention the Rust runtime relies on."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hkv = cfg.n_kv_heads * cfg.head_dim
    base: list[tuple[str, tuple[int, ...]]] = [("embed", (v, d))]
    for ln in _layer_names(cfg):
        base += [
            (f"{ln}.wq", (d, d)),
            (f"{ln}.wk", (hkv, d)),
            (f"{ln}.wv", (hkv, d)),
            (f"{ln}.wo", (d, d)),
            (f"{ln}.w_gate", (f, d)),
            (f"{ln}.w_up", (f, d)),
            (f"{ln}.w_down", (d, f)),
            (f"{ln}.norm1", (d,)),
            (f"{ln}.norm2", (d,)),
        ]
    base += [("norm_f", (d,)), ("head", (v, d))]

    if family == "full":
        return base, []  # (trainable, frozen)

    r = lora_rank
    lora: list[tuple[str, tuple[int, ...]]] = []
    for ln in _layer_names(cfg):
        lora += [
            (f"{ln}.wq_a", (r, d)), (f"{ln}.wq_b", (d, r)),
            (f"{ln}.wk_a", (r, d)), (f"{ln}.wk_b", (hkv, r)),
            (f"{ln}.wv_a", (r, d)), (f"{ln}.wv_b", (hkv, r)),
            (f"{ln}.wo_a", (r, d)), (f"{ln}.wo_b", (d, r)),
        ]
        if family == "dora":
            lora += [
                (f"{ln}.wq_m", (d,)), (f"{ln}.wk_m", (hkv,)),
                (f"{ln}.wv_m", (hkv,)), (f"{ln}.wo_m", (d,)),
            ]
    return lora, base  # lora params trainable, base frozen


def init_params(key: jax.Array, cfg: ModelConfig, family: str, lora_rank: int = 32):
    """Initialize (trainable, frozen) flat lists of f32 arrays."""
    trainable_specs, frozen_specs = param_specs(cfg, family, lora_rank)

    def init_one(key, name, shape):
        if name.endswith(("_b",)):  # LoRA B: zeros (paper §5)
            return jnp.zeros(shape, jnp.float32)
        if name.endswith(("norm1", "norm2", "norm_f", "_m")):
            return jnp.ones(shape, jnp.float32)
        if name.endswith("_a"):  # LoRA A: N(0, 1/r)
            return jax.random.normal(key, shape) * (1.0 / shape[0]) ** 0.5
        fan_in = shape[-1] if len(shape) > 1 else shape[0]
        return jax.random.normal(key, shape) * (1.0 / fan_in) ** 0.5

    def init_list(key, specs):
        out = []
        for name, shape in specs:
            key, sub = jax.random.split(key)
            out.append(init_one(sub, name, shape))
        return key, out

    key, trainable = init_list(key, trainable_specs)
    key, frozen = init_list(key, frozen_specs)
    # DoRA: magnitude starts at the column norm of the frozen base weight
    if family == "dora":
        fnames = [n for n, _ in frozen_specs]
        tnames = [n for n, _ in trainable_specs]
        fmap = dict(zip(fnames, frozen))
        for i, name in enumerate(tnames):
            if name.endswith("_m"):
                base_w = fmap[name[: -len("_m")]]
                trainable[i] = jnp.linalg.norm(base_w, axis=1)
    return trainable, frozen


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _as_dict(cfg, family, lora_rank, trainable, frozen):
    tspecs, fspecs = param_specs(cfg, family, lora_rank)
    p = dict(zip([n for n, _ in tspecs], trainable))
    p.update(zip([n for n, _ in fspecs], frozen))
    return p


def _linear(p, name, x, sc: StepConfig):
    """Projection with optional LoRA/DoRA adapter. x: [..., K] -> [..., N]."""
    w = p[name]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if sc.family == "full" or f"{name}_a" not in p:
        # MLP projections carry no adapter (paper targets q,k,v,o).
        return (x2 @ w.T).reshape(*lead, w.shape[0])
    a, b = p[f"{name}_a"], p[f"{name}_b"]
    if sc.family == "dora":
        # W' = m ⊙ (W + (α/r)·BA) / ||W + (α/r)·BA||_col  (paper Def. 28)
        m = p[f"{name}_m"]
        scale = sc.lora_alpha / sc.lora_rank
        w_comb = w + scale * (b @ a)
        norm = jnp.linalg.norm(w_comb, axis=1, keepdims=True) + 1e-8
        w_eff = w_comb / norm * m[:, None]
        return (x2 @ w_eff.T).reshape(*lead, w.shape[0])
    if sc.kernels == "pallas":
        y = k_lora.lora_linear(
            x2, w, a, b, float(sc.lora_alpha),
            block_m=min(64, x2.shape[0]), block_n=min(64, w.shape[0]),
        )
    elif sc.kernels == "naive":
        y = ref.lora_linear_naive(x2, w, a, b, float(sc.lora_alpha))
    else:
        y = ref.lora_linear(x2, w, a, b, float(sc.lora_alpha))
    return y.reshape(*lead, w.shape[0])


def _norm(x, gamma, sc: StepConfig, eps):
    if sc.kernels == "pallas":
        return k_rmsnorm.rmsnorm(x, gamma, eps)
    if sc.kernels == "naive":
        return ref.rmsnorm_naive(x, gamma, eps)
    return ref.rmsnorm(x, gamma, eps)


def _swiglu(g, u, sc: StepConfig):
    if sc.kernels == "pallas":
        return k_swiglu.swiglu(g, u)
    if sc.kernels == "naive":
        return ref.swiglu_naive(g, u)
    return ref.swiglu(g, u)


def _rope(q, k, pos, sc: StepConfig, base):
    if sc.kernels == "pallas":
        return k_rope.rope_qk(q, k, pos, base)
    if sc.kernels == "naive":
        return ref.rope_qk_naive(q, k, pos, base)
    return ref.rope_qk(q, k, pos, base)


def _attention(q, k, v, seg, sc: StepConfig):
    if sc.attention == "naive":
        return ref.attention_naive(q, k, v, seg)
    if sc.attention == "ref":
        return ref.attention(q, k, v, seg)
    if sc.attention == "flash_pallas":
        s = q.shape[1]
        blk = min(sc.flash_block, s)
        return k_flash.flash_attention(q, k, v, seg, blk, blk)
    return ref.flash_attention_scan(q, k, v, seg, block_kv=min(sc.flash_block, q.shape[1]))


def forward_hidden(p, cfg: ModelConfig, sc: StepConfig, tokens, seg_ids, pos_ids):
    """tokens/seg_ids/pos_ids: [B, S] int32 → hidden states [B, S, D]."""
    b, s = tokens.shape
    h = jnp.take(p["embed"], tokens, axis=0)  # [B, S, D]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    for ln in _layer_names(cfg):
        x = _norm(h, p[f"{ln}.norm1"], sc, cfg.rms_eps)
        q = _linear(p, f"{ln}.wq", x, sc).reshape(b, s, nh, hd)
        k = _linear(p, f"{ln}.wk", x, sc).reshape(b, s, nkv, hd)
        v = _linear(p, f"{ln}.wv", x, sc).reshape(b, s, nkv, hd)
        q, k = _rope(q, k, pos_ids, sc, cfg.rope_base)
        att = _attention(q, k, v, seg_ids, sc).reshape(b, s, nh * hd)
        h = h + _linear(p, f"{ln}.wo", att, sc)
        x = _norm(h, p[f"{ln}.norm2"], sc, cfg.rms_eps)
        g = _linear(p, f"{ln}.w_gate", x, sc)
        u = _linear(p, f"{ln}.w_up", x, sc)
        mlp = _swiglu(g, u, sc)
        # MLP down-projection never gets a LoRA adapter (paper targets q,k,v,o)
        lead = mlp.shape[:-1]
        h = h + (mlp.reshape(-1, cfg.d_ff) @ p[f"{ln}.w_down"].T).reshape(*lead, cfg.d_model)
    return _norm(h, p["norm_f"], sc, cfg.rms_eps)


def loss_fn(p, cfg: ModelConfig, sc: StepConfig, tokens, targets, seg_ids, pos_ids):
    """Returns (sum loss, n_real_tokens)."""
    hidden = forward_hidden(p, cfg, sc, tokens, seg_ids, pos_ids)
    t = hidden.reshape(-1, cfg.d_model)
    tgt = targets.reshape(-1)
    chunk = min(sc.cce_chunk, cfg.vocab)
    if sc.loss == "full":
        return ref.cross_entropy_full(t, p["head"], tgt, sc.z_loss, sc.label_smoothing)
    if sc.loss == "cce_pallas":
        return k_cce.cce_loss(t, p["head"], tgt, chunk)
    return ref.cce_chunked(t, p["head"], tgt, chunk, sc.z_loss, sc.label_smoothing)


# ---------------------------------------------------------------------------
# Optimizers (in-graph)
# ---------------------------------------------------------------------------

N_OPT_SLOTS = 2  # uniform across optimizers; unused slots carry zeros


def _apply_optimizer(sc: StepConfig, names, params, grads, s0, s1, step, lr, lr_b,
                     clip_coef):
    """Apply the configured optimizer to flat lists. Returns (p', s0', s1')."""
    new_p, new_s0, new_s1 = [], [], []
    for name, p, g, m, v in zip(names, params, grads, s0, s1):
        # LoRA+ (paper Thm. 1): B matrices train with lr_b = λ·lr, and weight
        # decay scales with the learning rate (Prop. 10) — both fall out of
        # using the per-group lr in the shared update rule.
        lr_g = lr_b if name.endswith("_b") else lr  # name is static
        if sc.optimizer == "adamw_naive":
            p2, m2, v2 = ref.adamw_update_naive(
                p, g, m, v, lr_g, step, weight_decay=sc.weight_decay,
                clip_coef=clip_coef)
        elif sc.optimizer == "adamw_pallas":
            p2, m2, v2 = k_adamw.adamw_update(
                p, g, m, v, lr_g, step, weight_decay=sc.weight_decay,
                clip_coef=clip_coef)
        elif sc.optimizer == "sf":
            p2, z2 = ref.schedule_free_update(
                p, m, g, lr_g, step, weight_decay=sc.weight_decay,
                clip_coef=clip_coef)
            m2, v2 = z2, v
        elif sc.optimizer == "muon" and p.ndim == 2:
            p2, m2 = ref.muon_update(p, g, m, lr_g, clip_coef=clip_coef)
            v2 = v
        elif sc.optimizer == "atan2":
            p2, m2, v2 = ref.adam_atan2_update(
                p, g, m, v, lr_g, step, weight_decay=sc.weight_decay,
                clip_coef=clip_coef)
        else:  # fused adamw (also the muon fallback for 1-D params)
            p2, m2, v2 = ref.adamw_update(
                p, g, m, v, lr_g, step, weight_decay=sc.weight_decay,
                clip_coef=clip_coef)
        new_p.append(p2)
        new_s0.append(m2)
        new_s1.append(v2)
    return new_p, new_s0, new_s1


# ---------------------------------------------------------------------------
# The train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, sc: StepConfig):
    """Returns (fn, input_specs, output_names).

    fn takes flat positional arrays in this exact order (the Rust calling
    convention):
        trainable..., frozen..., slot0..., slot1...,
        tokens, targets, seg_ids, pos_ids, step, lr, lr_b
    and returns:
        trainable'..., slot0'..., slot1'..., loss_mean, grad_norm, n_tokens
    """
    tspecs, fspecs = param_specs(cfg, sc.family, sc.lora_rank)
    tnames = [n for n, _ in tspecs]
    n_t, n_f = len(tspecs), len(fspecs)

    def fn(*args):
        i = 0
        trainable = list(args[i : i + n_t]); i += n_t
        frozen = list(args[i : i + n_f]); i += n_f
        s0 = list(args[i : i + n_t]); i += n_t
        s1 = list(args[i : i + n_t]); i += n_t
        tokens, targets, seg_ids, pos_ids, step, lr, lr_b = args[i : i + 7]

        def scalar_loss(tr):
            p = _as_dict(cfg, sc.family, sc.lora_rank, tr, frozen)
            total, n = loss_fn(p, cfg, sc, tokens, targets, seg_ids, pos_ids)
            return total / jnp.maximum(n, 1.0), n

        if sc.broken:
            # "Unsloth fast mode" (paper Fig. 10): gradients never flow —
            # XLA dead-code-eliminates the entire backward pass.
            loss, n = scalar_loss([jax.lax.stop_gradient(t) for t in trainable])
            grads = [jnp.zeros_like(t) for t in trainable]
        else:
            (loss, n), grads = jax.value_and_grad(scalar_loss, has_aux=True)(
                trainable
            )

        gnorm = ref.global_grad_norm(grads)
        clip = jnp.minimum(1.0, sc.max_grad_norm / (gnorm + 1e-6))
        new_t, new_s0, new_s1 = _apply_optimizer(
            sc, tnames, trainable, grads, s0, s1, step, lr, lr_b, clip
        )
        return (*new_t, *new_s0, *new_s1, loss, gnorm, n)

    return fn, (tspecs, fspecs), tnames


def make_eval_fn(cfg: ModelConfig, sc: StepConfig):
    """Forward-only mean loss: params..., batch -> (loss, n_tokens)."""
    tspecs, fspecs = param_specs(cfg, sc.family, sc.lora_rank)
    n_t, n_f = len(tspecs), len(fspecs)

    def fn(*args):
        trainable = list(args[:n_t])
        frozen = list(args[n_t : n_t + n_f])
        tokens, targets, seg_ids, pos_ids = args[n_t + n_f :]
        p = _as_dict(cfg, sc.family, sc.lora_rank, trainable, frozen)
        total, n = loss_fn(p, cfg, sc, tokens, targets, seg_ids, pos_ids)
        return total / jnp.maximum(n, 1.0), n

    return fn


def make_init_fn(cfg: ModelConfig, sc: StepConfig):
    """seed (i32 scalar) -> (trainable..., frozen..., slot0..., slot1...)."""
    tspecs, _ = param_specs(cfg, sc.family, sc.lora_rank)

    def fn(seed):
        key = jax.random.PRNGKey(seed)
        trainable, frozen = init_params(key, cfg, sc.family, sc.lora_rank)
        zeros = [jnp.zeros_like(t) for t in trainable]
        return (*trainable, *frozen, *zeros, *[jnp.zeros_like(t) for t in trainable])

    return fn
