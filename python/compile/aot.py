"""Chronicals AOT emitter: lower every benchmark variant to HLO text.

Python runs ONCE (``make artifacts``); the Rust L3 coordinator loads the
emitted ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and
never touches Python again.

Interchange format is HLO **text**, not serialized protos: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids (see
/opt/xla-example/README.md).

``manifest.json`` describes every executable: the exact positional input
and output layout (the Rust calling convention), parameter names/shapes,
batch geometry, and the model config echo. Keep it boring: the Rust side
has a hand-rolled JSON parser.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _f32():
    return _spec((), jnp.float32)


# ---------------------------------------------------------------------------
# Variant table — every benchmark configuration in DESIGN.md §5.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Variant:
    name: str
    model: str  # MODEL_PRESETS key
    batch: int
    seq: int
    step: M.StepConfig
    emit_init: bool = False
    emit_eval: bool = False


def variant_table(profile: str) -> list[Variant]:
    """profile: 'test' (tiny shapes, fast) or 'bench' (paper-shaped)."""
    if profile == "test":
        mp, b, s = "tiny", 2, 64
        mp_e2e, b_e2e, s_e2e = "tiny", 2, 64
    else:
        mp, b, s = "small", 4, 256
        mp_e2e, b_e2e, s_e2e = "e2e", 8, 256

    SC = M.StepConfig
    ladder = [
        # Table 4 ablation ladder (packing + batch-size rows reuse these
        # graphs with different data; see DESIGN.md §5/T4).
        Variant("ablate_naive", mp, b, s, SC(
            attention="naive", kernels="naive", loss="full",
            optimizer="adamw_naive")),
        Variant("ablate_flash", mp, b, s, SC(
            attention="flash_scan", kernels="naive", loss="full",
            optimizer="adamw_naive")),
        Variant("ablate_compiled", mp, b, s, SC(
            attention="flash_scan", kernels="jnp", loss="full",
            optimizer="adamw_naive")),
        Variant("ablate_liger", mp, b, s, SC(
            attention="flash_scan", kernels="jnp", loss="cce_scan",
            optimizer="adamw_naive")),
        Variant("chronicals", mp, b, s, SC(
            attention="flash_scan", kernels="jnp", loss="cce_scan",
            optimizer="adamw"), emit_init=True, emit_eval=True),
        # LoRA family (Table 3): one graph serves LoRA and LoRA+ — λ is the
        # runtime ratio lr_b/lr.
        Variant("lora", mp, b, s, SC(
            attention="flash_scan", kernels="jnp", loss="cce_scan",
            optimizer="adamw", family="lora"), emit_init=True, emit_eval=True),
        Variant("lora_naive", mp, b, s, SC(
            attention="naive", kernels="naive", loss="full",
            optimizer="adamw_naive", family="lora")),
        # The "Unsloth fast mode" bug (Fig. 10/22): detached loss.
        Variant("lora_broken", mp, b, s, SC(
            attention="flash_scan", kernels="jnp", loss="cce_scan",
            optimizer="adamw", family="lora", broken=True)),
        # Optimizer studies (§S10) on the chronicals graph.
        Variant("opt_sf", mp, b, s, SC(
            attention="flash_scan", kernels="jnp", loss="cce_scan",
            optimizer="sf")),
        Variant("opt_muon", mp, b, s, SC(
            attention="flash_scan", kernels="jnp", loss="cce_scan",
            optimizer="muon")),
        Variant("opt_atan2", mp, b, s, SC(
            attention="flash_scan", kernels="jnp", loss="cce_scan",
            optimizer="atan2")),
        # DoRA (§S9).
        Variant("dora", mp, b, s, SC(
            attention="flash_scan", kernels="jnp", loss="cce_scan",
            optimizer="adamw", family="dora"), emit_init=True),
        # Full-Pallas composition proof: every L1 kernel in one training
        # step (tiny shapes — interpret-mode grids are loop-heavy).
        Variant("chronicals_pallas", "tiny", 2, 64, SC(
            attention="flash_pallas", kernels="pallas", loss="cce_pallas",
            optimizer="adamw_pallas", cce_chunk=128, flash_block=32),
            emit_init=True),
        # End-to-end training demo scale.
        Variant("e2e", mp_e2e, b_e2e, s_e2e, SC(
            attention="flash_scan", kernels="jnp", loss="cce_scan",
            optimizer="adamw"), emit_init=True, emit_eval=True),
    ]
    return ladder


# ---------------------------------------------------------------------------
# Kernel microbench executables (Table 5)
# ---------------------------------------------------------------------------


def kernel_microbenches(profile: str):
    """(name, fn, arg_specs) for fused-vs-naive kernel pairs."""
    if profile == "test":
        t, d, f = 64, 64, 128
        tv, h, v = 64, 64, 512
        s_att, heads, hd = 64, 4, 16
    else:
        t, d, f = 2048, 896, 2432  # Qwen2.5-0.5B row shapes
        tv, h, v = 512, 896, 16384  # CCE rows (vocab scaled; ratio kept ≫ d)
        s_att, heads, hd = 256, 8, 64

    i32 = jnp.int32
    out = []

    def rms_fused(x, g):
        return (ref.rmsnorm(x, g),)

    def rms_naive(x, g):
        return (ref.rmsnorm_naive(x, g),)

    out.append(("kernel_rmsnorm_fused", rms_fused, [_spec((t, d)), _spec((d,))]))
    out.append(("kernel_rmsnorm_naive", rms_naive, [_spec((t, d)), _spec((d,))]))

    def swiglu_fused(g, u):
        return (ref.swiglu(g, u),)

    def swiglu_naive(g, u):
        return (ref.swiglu_naive(g, u),)

    out.append(("kernel_swiglu_fused", swiglu_fused, [_spec((t, f)), _spec((t, f))]))
    out.append(("kernel_swiglu_naive", swiglu_naive, [_spec((t, f)), _spec((t, f))]))

    qspec = _spec((1, s_att, heads, hd))
    kspec = _spec((1, s_att, heads // 2, hd))
    pspec = _spec((1, s_att), i32)

    def rope_fused(q, k, pos):
        return ref.rope_qk(q, k, pos)

    def rope_naive(q, k, pos):
        return ref.rope_qk_naive(q, k, pos)

    out.append(("kernel_rope_fused", rope_fused, [qspec, kspec, pspec]))
    out.append(("kernel_rope_naive", rope_naive, [qspec, kspec, pspec]))

    vspec = kspec
    sspec = _spec((1, s_att), i32)

    def attn_flash(q, k, v, seg):
        return (ref.flash_attention_scan(q, k, v, seg, block_kv=min(64, s_att)),)

    def attn_naive(q, k, v, seg):
        return (ref.attention_naive(q, k, v, seg),)

    out.append(("kernel_attention_flash", attn_flash, [qspec, kspec, vspec, sspec]))
    out.append(("kernel_attention_naive", attn_naive, [qspec, kspec, vspec, sspec]))

    hspec = _spec((tv, h))
    wspec = _spec((v, h))
    tgtspec = _spec((tv,), i32)

    def ce_fused(hid, w, tgt):
        loss, n = ref.cce_chunked(hid, w, tgt, chunk=min(1024, v))
        return (loss, n)

    def ce_naive(hid, w, tgt):
        loss, n = ref.cross_entropy_full(hid, w, tgt)
        return (loss, n)

    out.append(("kernel_cross_entropy_fused", ce_fused, [hspec, wspec, tgtspec]))
    out.append(("kernel_cross_entropy_naive", ce_naive, [hspec, wspec, tgtspec]))

    # Fused linear+CE (Table 5 last row): grad of CCE directly from hidden.
    def linear_ce_fused(hid, w, tgt):
        def f(hid_):
            loss, n = ref.cce_chunked(hid_, w, tgt, chunk=min(1024, v))
            return loss / jnp.maximum(n, 1.0)

        loss, grad = jax.value_and_grad(f)(hid)
        return (loss, grad)

    out.append(("kernel_linear_ce_fused", linear_ce_fused, [hspec, wspec, tgtspec]))

    n_el = 1 << 20 if profile != "test" else 1 << 12
    pspec2 = _spec((n_el,))

    def adamw_fused(p, g, m, v_):
        return ref.adamw_update(p, g, m, v_, 1e-3, 10.0)

    def adamw_naive(p, g, m, v_):
        return ref.adamw_update_naive(p, g, m, v_, 1e-3, 10.0)

    out.append(("kernel_adamw_fused", adamw_fused, [pspec2] * 4))
    out.append(("kernel_adamw_naive", adamw_naive, [pspec2] * 4))

    # LoRA linear fused vs naive (Prop. 9)
    mk, kk, nk, r = (256, 512, 512, 32) if profile != "test" else (64, 64, 64, 8)
    lspecs = [_spec((mk, kk)), _spec((nk, kk)), _spec((r, kk)), _spec((nk, r))]

    def lora_fused(x, w, a, b):
        return (ref.lora_linear(x, w, a, b, 2.0 * r),)

    def lora_naive(x, w, a, b):
        return (ref.lora_linear_naive(x, w, a, b, 2.0 * r),)

    out.append(("kernel_lora_linear_fused", lora_fused, lspecs))
    out.append(("kernel_lora_linear_naive", lora_naive, lspecs))
    return out


# ---------------------------------------------------------------------------
# Emission
# ---------------------------------------------------------------------------


def _input_entries(specs, roles):
    return [
        {
            "name": name,
            "shape": list(sds.shape),
            "dtype": str(sds.dtype),
            "role": role,
        }
        for (name, sds, role) in zip(
            [r[0] for r in roles], [r[1] for r in roles], [r[2] for r in roles]
        )
    ]


def emit_variant(var: Variant, outdir: str, manifest: dict, force: bool):
    cfg = M.MODEL_PRESETS[var.model]
    sc = var.step
    tspecs, fspecs = M.param_specs(cfg, sc.family, sc.lora_rank)
    b, s = var.batch, var.seq
    i32 = jnp.int32

    param_in = (
        [(n, _spec(sh), "param") for n, sh in tspecs]
        + [(n, _spec(sh), "frozen") for n, sh in fspecs]
    )
    state_in = param_in + [
        (f"slot0.{n}", _spec(sh), "opt") for n, sh in tspecs
    ] + [(f"slot1.{n}", _spec(sh), "opt") for n, sh in tspecs]
    batch_in = [
        ("tokens", _spec((b, s), i32), "batch"),
        ("targets", _spec((b, s), i32), "batch"),
        ("seg_ids", _spec((b, s), i32), "batch"),
        ("pos_ids", _spec((b, s), i32), "batch"),
    ]
    scalar_in = [
        ("step", _f32(), "scalar"),
        ("lr", _f32(), "scalar"),
        ("lr_b", _f32(), "scalar"),
    ]

    def emit(name, fn, roles, outputs, kind):
        path = os.path.join(outdir, f"{name}.hlo.txt")
        specs = [r[1] for r in roles]
        if force or not os.path.exists(path):
            lowered = jax.jit(fn, keep_unused=True).lower(*specs)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            print(f"  wrote {name}.hlo.txt ({len(text) // 1024} KiB)")
        manifest["executables"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": kind,
                "model": var.model,
                "variant": var.name,
                "family": sc.family,
                "batch": b,
                "seq": s,
                "n_trainable": len(tspecs),
                "n_frozen": len(fspecs),
                "n_slots": M.N_OPT_SLOTS,
                "param_count": int(cfg.param_count(sc.family, sc.lora_rank)),
                "trainable_param_count": int(
                    sum(int(jnp.prod(jnp.asarray(sh))) for _, sh in tspecs)
                ),
                "step_config": {
                    "attention": sc.attention,
                    "kernels": sc.kernels,
                    "loss": sc.loss,
                    "optimizer": sc.optimizer,
                    "broken": sc.broken,
                    "lora_rank": sc.lora_rank,
                    "lora_alpha": sc.lora_alpha,
                },
                "model_config": dataclasses.asdict(cfg),
                "inputs": [
                    {
                        "name": n,
                        "shape": list(sds.shape),
                        "dtype": str(sds.dtype),
                        "role": role,
                    }
                    for (n, sds, role) in roles
                ],
                "outputs": outputs,
            }
        )

    step_fn, _, _ = M.make_train_step(cfg, sc)
    train_outputs = (
        [f"param.{n}" for n, _ in tspecs]
        + [f"slot0.{n}" for n, _ in tspecs]
        + [f"slot1.{n}" for n, _ in tspecs]
        + ["loss", "grad_norm", "n_tokens"]
    )
    emit(
        f"train_step_{var.name}",
        step_fn,
        state_in + batch_in + scalar_in,
        train_outputs,
        "train",
    )

    if var.emit_init:
        init_fn = M.make_init_fn(cfg, sc)
        init_outputs = (
            [f"param.{n}" for n, _ in tspecs]
            + [f"frozen.{n}" for n, _ in fspecs]
            + [f"slot0.{n}" for n, _ in tspecs]
            + [f"slot1.{n}" for n, _ in tspecs]
        )
        emit(
            f"init_{var.name}",
            init_fn,
            [("seed", _spec((), i32), "scalar")],
            init_outputs,
            "init",
        )

    if var.emit_eval:
        eval_fn = M.make_eval_fn(cfg, sc)
        emit(
            f"eval_{var.name}",
            eval_fn,
            param_in + batch_in,
            ["loss", "n_tokens"],
            "eval",
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--profile", default="bench", choices=["test", "bench"],
        help="test = tiny shapes (CI), bench = paper-shaped",
    )
    ap.add_argument("--force", action="store_true", help="re-emit everything")
    ap.add_argument("--only", default=None, help="emit just one variant name")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest: dict = {"profile": args.profile, "executables": []}

    print(f"[aot] emitting profile={args.profile} -> {args.out}")
    for var in variant_table(args.profile):
        # --only restricts *re-emission* to one variant; the manifest always
        # covers everything (missing files are still written).
        force = args.force and (args.only in (None, var.name))
        print(f"[aot] variant {var.name} (model={var.model}, B={var.batch}, S={var.seq})")
        emit_variant(var, args.out, manifest, force)

    print("[aot] kernel microbenches")
    for name, fn, specs in kernel_microbenches(args.profile):
        force = args.force and (args.only in (None, name))
        path = os.path.join(args.out, f"{name}.hlo.txt")
        if force or not os.path.exists(path):
            lowered = jax.jit(fn, keep_unused=True).lower(*specs)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            print(f"  wrote {name}.hlo.txt ({len(text) // 1024} KiB)")
        manifest["executables"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": "kernel",
                "inputs": [
                    {
                        "name": f"arg{i}",
                        "shape": list(s.shape),
                        "dtype": str(s.dtype),
                        "role": "batch",
                    }
                    for i, s in enumerate(specs)
                ],
                "outputs": [],
            }
        )

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(manifest['executables'])} executables")


if __name__ == "__main__":
    main()
