"""L2 correctness: variant equivalence, training behaviour, LoRA+ dynamics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import compile.model as M

CFG = M.MODEL_PRESETS["tiny"]
B, S = 2, 64


def make_batch(seed=0, packed=False):
    rng = np.random.default_rng(seed)
    toks = rng.integers(1, CFG.vocab, size=(B, S)).astype(np.int32)
    tgts = np.roll(toks, -1, axis=1).astype(np.int32)
    tgts[:, -1] = -1
    if packed:
        seg = np.ones((B, S), np.int32)
        seg[:, S // 2 :] = 2
        pos = np.concatenate(
            [np.arange(S // 2), np.arange(S - S // 2)]
        ).astype(np.int32)
        pos = np.tile(pos, (B, 1))
        tgts[:, S // 2 - 1] = -1  # no target across the segment boundary
    else:
        seg = np.ones((B, S), np.int32)
        pos = np.tile(np.arange(S, dtype=np.int32), (B, 1))
    return map(jnp.asarray, (toks, tgts, seg, pos))


def init_state(sc):
    tr, fr = M.init_params(jax.random.PRNGKey(0), CFG, sc.family, sc.lora_rank)
    return tr, fr, [jnp.zeros_like(t) for t in tr], [jnp.zeros_like(t) for t in tr]


def run_steps(sc, n_steps=5, lr=1e-2, lr_b=None, seed=0, packed=False):
    fn, _, _ = M.make_train_step(CFG, sc)
    jfn = jax.jit(fn)
    tr, fr, s0, s1 = init_state(sc)
    toks, tgts, seg, pos = make_batch(seed, packed)
    lr_b = lr if lr_b is None else lr_b
    losses, gnorms = [], []
    for step in range(1, n_steps + 1):
        outs = jfn(*tr, *fr, *s0, *s1, toks, tgts, seg, pos, float(step), lr, lr_b)
        n_t = len(tr)
        tr = list(outs[:n_t])
        s0 = list(outs[n_t : 2 * n_t])
        s1 = list(outs[2 * n_t : 3 * n_t])
        losses.append(float(outs[-3]))
        gnorms.append(float(outs[-2]))
    return losses, gnorms


# ---------------------------------------------------------------------------
# Variant equivalence: all lowerings compute the same loss
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "sc",
    [
        M.StepConfig(attention="naive", kernels="naive", loss="full"),
        M.StepConfig(attention="ref", kernels="jnp", loss="full"),
        M.StepConfig(attention="flash_scan", kernels="jnp", loss="cce_scan"),
    ],
    ids=["naive", "ref", "chronicals"],
)
def test_variant_losses_identical(sc):
    """The paper's benchmark configurations are the SAME computation —
    fused/naive/flash/cce must agree on the loss to float tolerance."""
    tr, fr = M.init_params(jax.random.PRNGKey(1), CFG, "full")
    toks, tgts, seg, pos = make_batch(3)
    p = M._as_dict(CFG, "full", 32, tr, fr)
    total, n = M.loss_fn(p, CFG, sc, toks, tgts, seg, pos)
    base_sc = M.StepConfig(attention="naive", kernels="naive", loss="full")
    total0, n0 = M.loss_fn(p, CFG, base_sc, toks, tgts, seg, pos)
    np.testing.assert_allclose(float(total), float(total0), rtol=1e-4)
    assert float(n) == float(n0)


def test_pallas_variant_loss_matches_jnp():
    sc_p = M.StepConfig(
        attention="flash_pallas", kernels="pallas", loss="cce_pallas",
        cce_chunk=128, flash_block=32,
    )
    sc_j = M.StepConfig(attention="flash_scan", kernels="jnp", loss="cce_scan")
    tr, fr = M.init_params(jax.random.PRNGKey(2), CFG, "full")
    p = M._as_dict(CFG, "full", 32, tr, fr)
    toks, tgts, seg, pos = make_batch(4)
    lp, _ = M.loss_fn(p, CFG, sc_p, toks, tgts, seg, pos)
    lj, _ = M.loss_fn(p, CFG, sc_j, toks, tgts, seg, pos)
    np.testing.assert_allclose(float(lp), float(lj), rtol=1e-4)


def test_packed_batch_equals_unpacked_per_sequence_loss():
    """Packing two sequences with segment masks must give the same total
    loss as evaluating them separately (Fig. 18 correctness side)."""
    sc = M.StepConfig(attention="flash_scan", kernels="jnp", loss="cce_scan")
    tr, fr = M.init_params(jax.random.PRNGKey(5), CFG, "full")
    p = M._as_dict(CFG, "full", 32, tr, fr)
    rng = np.random.default_rng(7)
    half = S // 2
    seq_a = rng.integers(1, CFG.vocab, size=half).astype(np.int32)
    seq_b = rng.integers(1, CFG.vocab, size=half).astype(np.int32)

    # packed: [a | b] with segment ids + reset positions
    toks_p = jnp.asarray(np.concatenate([seq_a, seq_b])[None, :])
    tgt_a = np.roll(seq_a, -1); tgt_a[-1] = -1
    tgt_b = np.roll(seq_b, -1); tgt_b[-1] = -1
    tgts_p = jnp.asarray(np.concatenate([tgt_a, tgt_b])[None, :].astype(np.int32))
    seg_p = jnp.asarray(np.concatenate([np.ones(half), np.full(half, 2)])[None, :].astype(np.int32))
    pos_p = jnp.asarray(np.concatenate([np.arange(half), np.arange(half)])[None, :].astype(np.int32))
    loss_packed, n_packed = M.loss_fn(p, CFG, sc, toks_p, tgts_p, seg_p, pos_p)

    # separate: each sequence alone, padded to half
    def single(seq, tgt):
        toks = jnp.asarray(seq[None, :])
        tg = jnp.asarray(tgt[None, :].astype(np.int32))
        seg = jnp.ones((1, half), jnp.int32)
        pos = jnp.arange(half, dtype=jnp.int32)[None, :]
        return M.loss_fn(p, CFG, sc, toks, tg, seg, pos)

    la, na = single(seq_a, tgt_a)
    lb, nb = single(seq_b, tgt_b)
    np.testing.assert_allclose(float(loss_packed), float(la) + float(lb), rtol=1e-4)
    assert float(n_packed) == float(na) + float(nb)


# ---------------------------------------------------------------------------
# Training dynamics
# ---------------------------------------------------------------------------


def test_full_ft_trains():
    losses, gnorms = run_steps(M.StepConfig(), n_steps=6)
    assert losses[-1] < losses[0]
    assert all(g > 1e-8 for g in gnorms)


def test_lora_trains_and_base_frozen():
    sc = M.StepConfig(family="lora")
    fn, (tspecs, fspecs), _ = M.make_train_step(CFG, sc)
    jfn = jax.jit(fn)
    tr, fr, s0, s1 = init_state(sc)
    fr_before = [np.asarray(f).copy() for f in fr]
    toks, tgts, seg, pos = make_batch(0)
    outs = jfn(*tr, *fr, *s0, *s1, toks, tgts, seg, pos, 1.0, 1e-3, 16e-3)
    # frozen params are inputs only; they cannot change by construction,
    # but check the executable's trainable outputs differ from inputs
    n_t = len(tr)
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(outs[:n_t], tr)
    )
    assert changed


def test_broken_variant_grad_norm_zero_loss_constant():
    """The Unsloth-bug reproduction (paper Fig. 10)."""
    losses, gnorms = run_steps(M.StepConfig(family="lora", broken=True), n_steps=4)
    assert all(g == 0.0 for g in gnorms)
    assert abs(losses[0] - losses[-1]) < 1e-6


def test_lora_plus_converges_faster_than_lora():
    """Paper Fig. 17: lr_b = 16*lr reaches lower loss in equal steps."""
    sc = M.StepConfig(family="lora")
    losses_lora, _ = run_steps(sc, n_steps=10, lr=1e-3, lr_b=1e-3)
    losses_plus, _ = run_steps(sc, n_steps=10, lr=1e-3, lr_b=16e-3)
    assert losses_plus[-1] < losses_lora[-1]


def test_grad_norm_verification_separates_variants():
    """The paper's benchmarking methodology: healthy runs have gnorm>0."""
    _, g_ok = run_steps(M.StepConfig(family="lora"), n_steps=2)
    _, g_bad = run_steps(M.StepConfig(family="lora", broken=True), n_steps=2)
    assert min(g_ok) > 1e-8 and max(g_bad) == 0.0


@pytest.mark.parametrize("opt", ["adamw", "sf", "muon", "atan2"])
def test_optimizers_reduce_loss(opt):
    lr = 2e-3 if opt == "sf" else 1e-2
    losses, _ = run_steps(M.StepConfig(optimizer=opt), n_steps=8, lr=lr)
    assert losses[-1] < losses[0]


def test_dora_trains():
    losses, _ = run_steps(M.StepConfig(family="dora"), n_steps=6, lr=5e-3)
    assert losses[-1] < losses[0]


def test_packed_batch_trains():
    losses, _ = run_steps(M.StepConfig(), n_steps=5, packed=True)
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# Shapes / counting
# ---------------------------------------------------------------------------


def test_param_specs_trainable_first_convention():
    tspecs, fspecs = M.param_specs(CFG, "lora")
    assert all(n.endswith(("_a", "_b")) for n, _ in tspecs)
    assert not any(n.endswith(("_a", "_b")) for n, _ in fspecs)


def test_param_count_matches_specs():
    for fam in ["full", "lora", "dora"]:
        tspecs, fspecs = M.param_specs(CFG, fam)
        total = sum(int(np.prod(s)) for _, s in tspecs + fspecs)
        assert total == CFG.param_count(fam)


def test_init_lora_b_zero_a_nonzero():
    tr, fr = M.init_params(jax.random.PRNGKey(0), CFG, "lora")
    tspecs, _ = M.param_specs(CFG, "lora")
    for (name, _), arr in zip(tspecs, tr):
        if name.endswith("_b"):
            assert float(jnp.max(jnp.abs(arr))) == 0.0
        if name.endswith("_a"):
            assert float(jnp.max(jnp.abs(arr))) > 0.0


def test_eval_fn_matches_train_loss():
    sc = M.StepConfig()
    eval_fn = jax.jit(M.make_eval_fn(CFG, sc))
    step_fn, _, _ = M.make_train_step(CFG, sc)
    jfn = jax.jit(step_fn)
    tr, fr, s0, s1 = init_state(sc)
    toks, tgts, seg, pos = make_batch(9)
    loss_eval, _ = eval_fn(*tr, *fr, toks, tgts, seg, pos)
    outs = jfn(*tr, *fr, *s0, *s1, toks, tgts, seg, pos, 1.0, 0.0, 0.0)
    np.testing.assert_allclose(float(loss_eval), float(outs[-3]), rtol=1e-5)
