"""AOT emitter contract tests: variant table coverage + manifest helpers.

These do not lower anything (fast); full lowering is exercised by
`make artifacts` + the Rust integration suite.
"""

import jax.numpy as jnp

import compile.aot as aot
import compile.model as M


def test_variant_table_covers_paper_experiments():
    names = {v.name for v in aot.variant_table("test")}
    # Table 4 ablation ladder
    assert {"ablate_naive", "ablate_flash", "ablate_compiled", "ablate_liger",
            "chronicals"} <= names
    # Table 3 LoRA + the Fig. 10 broken mode
    assert {"lora", "lora_naive", "lora_broken"} <= names
    # §S10 optimizers + §S9 DoRA
    assert {"opt_sf", "opt_muon", "opt_atan2", "dora"} <= names
    # composition proof + e2e
    assert {"chronicals_pallas", "e2e"} <= names


def test_every_family_with_runtime_use_has_init():
    variants = aot.variant_table("test")
    families_with_init = {
        (v.step.family, v.model) for v in variants if v.emit_init
    }
    for v in variants:
        assert (v.step.family, v.model) in families_with_init, (
            f"variant {v.name} (family={v.step.family}, model={v.model}) "
            "has no init executable to initialize from"
        )


def test_broken_variant_is_lora_family():
    broken = [v for v in aot.variant_table("test") if v.step.broken]
    assert len(broken) == 1
    assert broken[0].step.family == "lora"


def test_bench_profile_uses_paper_shaped_models():
    bench = aot.variant_table("bench")
    e2e = next(v for v in bench if v.name == "e2e")
    cfg = M.MODEL_PRESETS[e2e.model]
    # vocab >> d_model: the CCE regime (paper Def. 12)
    assert cfg.vocab >= 8 * cfg.d_model
    # GQA grouping like Qwen (n_heads > n_kv_heads)
    assert cfg.n_heads > cfg.n_kv_heads


def test_kernel_microbenches_paired():
    names = [n for n, _, _ in aot.kernel_microbenches("test")]
    fused = {
        n.rsplit("_", 1)[0] for n in names if n.endswith(("_fused", "_flash"))
    }
    naive = {n[: -len("_naive")] for n in names if n.endswith("_naive")}
    # every naive baseline has a fused/flash counterpart
    assert naive <= fused, naive - fused


def test_hlo_text_emission_smoke():
    """One real lowering through the HLO-text interchange path."""
    import jax

    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn, keep_unused=True).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4]" in text


def test_model_presets_sane():
    for name, cfg in M.MODEL_PRESETS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert cfg.n_heads % cfg.n_kv_heads == 0, name
        assert cfg.head_dim % 2 == 0, name  # RoPE needs even head dim
        assert cfg.param_count() > 0
