"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is the
core correctness signal of the build (see DESIGN.md §7).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.rmsnorm import rmsnorm as p_rmsnorm
from compile.kernels.swiglu import swiglu as p_swiglu
from compile.kernels.rope import rope_qk as p_rope_qk
from compile.kernels.flash_attention import flash_attention as p_flash
from compile.kernels.cce import cce_loss as p_cce
from compile.kernels.lora_linear import lora_linear as p_lora
from compile.kernels.adamw import adamw_update as p_adamw
from compile.kernels.quantize import (
    int8_quantize_blockwise as p_int8,
    fp8_blockwise_e4m3 as p_fp8,
)

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, *shape, scale=1.0, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype) * scale)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 16),
    d=st.sampled_from([8, 32, 64, 96]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_fwd_matches_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, rows, d)
    g = rand(rng, d)
    np.testing.assert_allclose(
        p_rmsnorm(x, g), ref.rmsnorm(x, g), rtol=1e-5, atol=1e-5
    )


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 8),
    d=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_grads_match_autodiff_of_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, rows, d)
    g = rand(rng, d)

    def loss_p(x_, g_):
        return jnp.sum(jnp.sin(p_rmsnorm(x_, g_)))

    def loss_r(x_, g_):
        return jnp.sum(jnp.sin(ref.rmsnorm(x_, g_)))

    dxp, dgp = jax.grad(loss_p, argnums=(0, 1))(x, g)
    dxr, dgr = jax.grad(loss_r, argnums=(0, 1))(x, g)
    np.testing.assert_allclose(dxp, dxr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dgp, dgr, rtol=1e-4, atol=1e-4)


def test_rmsnorm_leading_batch_dims():
    rng = np.random.default_rng(0)
    x = rand(rng, 2, 3, 4, 16)
    g = rand(rng, 16)
    np.testing.assert_allclose(
        p_rmsnorm(x, g), ref.rmsnorm(x, g), rtol=1e-5, atol=1e-5
    )


def test_rmsnorm_analytic_bwd_matches_autodiff():
    rng = np.random.default_rng(1)
    x = rand(rng, 5, 32)
    g = rand(rng, 32)
    dy = rand(rng, 5, 32)
    dx_a, dg_a = ref.rmsnorm_bwd(x, g, dy)
    f = lambda x_, g_: jnp.sum(ref.rmsnorm(x_, g_) * dy)
    dx_n, dg_n = jax.grad(f, argnums=(0, 1))(x, g)
    np.testing.assert_allclose(dx_a, dx_n, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dg_a, dg_n, rtol=1e-4, atol=1e-4)


def test_rmsnorm_naive_matches_fused():
    rng = np.random.default_rng(2)
    x = rand(rng, 7, 24)
    g = rand(rng, 24)
    np.testing.assert_allclose(
        ref.rmsnorm_naive(x, g), ref.rmsnorm(x, g), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# SwiGLU
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 16),
    d=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_swiglu_fwd_matches_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    g = rand(rng, rows, d)
    u = rand(rng, rows, d)
    np.testing.assert_allclose(p_swiglu(g, u), ref.swiglu(g, u), rtol=1e-5, atol=1e-5)


def test_swiglu_grads_match():
    rng = np.random.default_rng(3)
    g = rand(rng, 6, 16)
    u = rand(rng, 6, 16)
    f_p = lambda g_, u_: jnp.sum(jnp.square(p_swiglu(g_, u_)))
    f_r = lambda g_, u_: jnp.sum(jnp.square(ref.swiglu(g_, u_)))
    dp = jax.grad(f_p, argnums=(0, 1))(g, u)
    dr = jax.grad(f_r, argnums=(0, 1))(g, u)
    for a, b in zip(dp, dr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_swiglu_analytic_bwd_matches_autodiff():
    rng = np.random.default_rng(4)
    g = rand(rng, 5, 12)
    u = rand(rng, 5, 12)
    dy = rand(rng, 5, 12)
    dg_a, du_a = ref.swiglu_bwd(g, u, dy)
    f = lambda g_, u_: jnp.sum(ref.swiglu(g_, u_) * dy)
    dg_n, du_n = jax.grad(f, argnums=(0, 1))(g, u)
    np.testing.assert_allclose(dg_a, dg_n, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(du_a, du_n, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    s=st.sampled_from([4, 8, 16]),
    hq=st.sampled_from([2, 4]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rope_matches_ref(s, hq, d, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, 2, s, hq, d)
    k = rand(rng, 2, s, hq // 2, d)
    pos = jnp.tile(jnp.arange(s, dtype=jnp.int32), (2, 1))
    qo, ko = p_rope_qk(q, k, pos)
    qr, kr = ref.rope_qk(q, k, pos)
    np.testing.assert_allclose(qo, qr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ko, kr, rtol=1e-5, atol=1e-5)


def test_rope_preserves_norm():
    """Rotations are orthogonal: ||RoPE(x)|| == ||x|| (paper §4)."""
    rng = np.random.default_rng(5)
    q = rand(rng, 1, 8, 2, 16)
    k = rand(rng, 1, 8, 1, 16)
    pos = jnp.arange(8, dtype=jnp.int32)[None, :]
    qo, _ = ref.rope_qk(q, k, pos)
    np.testing.assert_allclose(
        jnp.linalg.norm(qo, axis=-1), jnp.linalg.norm(q, axis=-1), rtol=1e-5
    )


def test_rope_relative_position_property():
    """(R_m q)·(R_n k) depends only on n-m (paper Lemma 1)."""
    rng = np.random.default_rng(6)
    q = rand(rng, 1, 1, 1, 16)
    k = rand(rng, 1, 1, 1, 16)
    scores = []
    for m, n in [(0, 3), (5, 8), (10, 13)]:
        qm, _ = ref.rope_qk(q, q, jnp.asarray([[m]], jnp.int32))
        kn, _ = ref.rope_qk(k, k, jnp.asarray([[n]], jnp.int32))
        scores.append(float(jnp.sum(qm * kn)))
    np.testing.assert_allclose(scores[0], scores[1], rtol=1e-4)
    np.testing.assert_allclose(scores[0], scores[2], rtol=1e-4)


def test_rope_grads_flow():
    rng = np.random.default_rng(7)
    q = rand(rng, 1, 4, 2, 8)
    k = rand(rng, 1, 4, 1, 8)
    pos = jnp.arange(4, dtype=jnp.int32)[None, :]
    f_p = lambda q_: jnp.sum(jnp.square(p_rope_qk(q_, k, pos)[0]))
    f_r = lambda q_: jnp.sum(jnp.square(ref.rope_qk(q_, k, pos)[0]))
    np.testing.assert_allclose(
        jax.grad(f_p)(q), jax.grad(f_r)(q), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    s=st.sampled_from([8, 16, 32]),
    h=st.sampled_from([2, 4]),
    d=st.sampled_from([8, 16]),
    gqa=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_pallas_matches_ref(s, h, d, gqa, seed):
    rng = np.random.default_rng(seed)
    hkv = h // 2 if gqa else h
    q = rand(rng, 2, s, h, d)
    k = rand(rng, 2, s, hkv, d)
    v = rand(rng, 2, s, hkv, d)
    seg = jnp.ones((2, s), jnp.int32)
    out = p_flash(q, k, v, seg, min(8, s), min(8, s))
    np.testing.assert_allclose(out, ref.attention(q, k, v, seg), rtol=1e-4, atol=1e-4)


def test_flash_packed_segments_isolated():
    """Packed segments must not attend across boundaries."""
    rng = np.random.default_rng(8)
    s = 16
    q = rand(rng, 1, s, 2, 8)
    k = rand(rng, 1, s, 2, 8)
    v = rand(rng, 1, s, 2, 8)
    seg = jnp.asarray([[1] * 8 + [2] * 8], jnp.int32)
    out_packed = p_flash(q, k, v, seg, 4, 4)
    # segment 2 alone, re-based positions
    out_alone = p_flash(
        q[:, 8:], k[:, 8:], v[:, 8:], jnp.ones((1, 8), jnp.int32), 4, 4
    )
    np.testing.assert_allclose(out_packed[:, 8:], out_alone, rtol=1e-4, atol=1e-4)


def test_flash_padding_rows_zero():
    rng = np.random.default_rng(9)
    s = 8
    q = rand(rng, 1, s, 1, 8)
    k = rand(rng, 1, s, 1, 8)
    v = rand(rng, 1, s, 1, 8)
    seg = jnp.asarray([[1, 1, 1, 1, 0, 0, 0, 0]], jnp.int32)
    out = p_flash(q, k, v, seg, 4, 4)
    np.testing.assert_allclose(out[:, 4:], jnp.zeros_like(out[:, 4:]), atol=1e-6)


def test_flash_scan_matches_naive():
    rng = np.random.default_rng(10)
    q = rand(rng, 2, 32, 4, 16)
    k = rand(rng, 2, 32, 2, 16)
    v = rand(rng, 2, 32, 2, 16)
    seg = jnp.concatenate(
        [jnp.ones((2, 20), jnp.int32), jnp.zeros((2, 12), jnp.int32)], axis=1
    )
    np.testing.assert_allclose(
        ref.flash_attention_scan(q, k, v, seg, block_kv=8),
        ref.attention_naive(q, k, v, seg),
        rtol=1e-4,
        atol=1e-4,
    )


def test_flash_grads_match_ref():
    rng = np.random.default_rng(11)
    q = rand(rng, 1, 16, 2, 8)
    k = rand(rng, 1, 16, 2, 8)
    v = rand(rng, 1, 16, 2, 8)
    seg = jnp.ones((1, 16), jnp.int32)
    f_p = lambda q_, k_, v_: jnp.sum(jnp.sin(p_flash(q_, k_, v_, seg, 8, 8)))
    f_r = lambda q_, k_, v_: jnp.sum(jnp.sin(ref.attention(q_, k_, v_, seg)))
    dp = jax.grad(f_p, argnums=(0, 1, 2))(q, k, v)
    dr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(dp, dr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Online softmax + CCE
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(2, 64),
    scale=st.sampled_from([0.1, 1.0, 30.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_online_logsumexp_matches(n, scale, seed):
    """Paper Thm. 2: online softmax == two-pass logsumexp, any magnitude."""
    rng = np.random.default_rng(seed)
    x = rand(rng, 3, n, scale=scale)
    np.testing.assert_allclose(
        ref.online_logsumexp(x), jax.nn.logsumexp(x, axis=-1), rtol=1e-5, atol=1e-5
    )


def test_online_logsumexp_extreme_values_stable():
    x = jnp.asarray([[1e4, -1e4, 0.0, 1e4]], jnp.float32)
    got = ref.online_logsumexp(x)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(got, jax.nn.logsumexp(x, axis=-1), rtol=1e-6)


@settings(**SETTINGS)
@given(
    t=st.integers(1, 12),
    v=st.sampled_from([16, 50, 130]),
    chunk=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cce_chunked_matches_full(t, v, chunk, seed):
    """Paper Thm. 3: CCE is mathematically identical to full CE."""
    rng = np.random.default_rng(seed)
    h = rand(rng, t, 16)
    w = rand(rng, v, 16, scale=0.2)
    tgt = jnp.asarray(rng.integers(-1, v, size=(t,)), jnp.int32)
    l_c, n_c = ref.cce_chunked(h, w, tgt, chunk=chunk)
    l_f, n_f = ref.cross_entropy_full(h, w, tgt)
    np.testing.assert_allclose(l_c, l_f, rtol=1e-5, atol=1e-6)
    assert n_c == n_f


@settings(**SETTINGS)
@given(
    t=st.integers(1, 8),
    v=st.sampled_from([32, 100]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cce_pallas_matches_full(t, v, seed):
    rng = np.random.default_rng(seed)
    h = rand(rng, t, 16)
    w = rand(rng, v, 16, scale=0.2)
    tgt = jnp.asarray(rng.integers(0, v, size=(t,)), jnp.int32)
    l_p, n_p = p_cce(h, w, tgt, 16)
    l_f, n_f = ref.cross_entropy_full(h, w, tgt)
    np.testing.assert_allclose(l_p, l_f, rtol=1e-5, atol=1e-6)
    assert n_p == n_f


def test_cce_pallas_grads_match_full():
    rng = np.random.default_rng(12)
    h = rand(rng, 6, 16)
    w = rand(rng, 50, 16, scale=0.2)
    tgt = jnp.asarray([0, 5, 49, -1, 7, 20], jnp.int32)
    gh_p, gw_p = jax.grad(lambda h_, w_: p_cce(h_, w_, tgt, 16)[0], argnums=(0, 1))(h, w)
    gh_f, gw_f = jax.grad(
        lambda h_, w_: ref.cross_entropy_full(h_, w_, tgt)[0], argnums=(0, 1)
    )(h, w)
    np.testing.assert_allclose(gh_p, gh_f, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw_p, gw_f, rtol=1e-4, atol=1e-5)


def test_cce_gradient_is_softmax_minus_onehot():
    """Paper Prop. 4 / Thm. 4, via the full-logit path."""
    rng = np.random.default_rng(13)
    z = rand(rng, 1, 10)
    tgt = jnp.asarray([3], jnp.int32)
    w = jnp.eye(10, dtype=jnp.float32)

    def f(z_):
        return ref.cross_entropy_full(z_, w, tgt)[0]

    grad = jax.grad(f)(z)
    expected = jax.nn.softmax(z, axis=-1) - jax.nn.one_hot(tgt, 10)
    np.testing.assert_allclose(grad, expected, rtol=1e-5, atol=1e-6)


def test_cce_ignore_index():
    rng = np.random.default_rng(14)
    h = rand(rng, 4, 8)
    w = rand(rng, 20, 8)
    tgt = jnp.asarray([-1, -1, -1, -1], jnp.int32)
    loss, n = ref.cce_chunked(h, w, tgt, chunk=8)
    assert float(loss) == 0.0 and float(n) == 0.0


def test_cce_zloss_and_label_smoothing_match_full():
    rng = np.random.default_rng(15)
    h = rand(rng, 5, 8)
    w = rand(rng, 30, 8)
    tgt = jnp.asarray([0, 1, 2, 3, 29], jnp.int32)
    l_c, _ = ref.cce_chunked(h, w, tgt, chunk=8, z_loss=1e-4, label_smoothing=0.1)
    l_f, _ = ref.cross_entropy_full(h, w, tgt, z_loss=1e-4, label_smoothing=0.1)
    np.testing.assert_allclose(l_c, l_f, rtol=1e-5)


# ---------------------------------------------------------------------------
# LoRA linear
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.sampled_from([32, 64, 128]),
    n=st.sampled_from([32, 64]),
    k=st.sampled_from([16, 32]),
    r=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lora_linear_matches_ref(m, n, k, r, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    w = rand(rng, n, k)
    a = rand(rng, r, k)
    b = rand(rng, n, r)
    out = p_lora(x, w, a, b, 2.0 * r, min(32, m), min(32, n))
    np.testing.assert_allclose(
        out, ref.lora_linear(x, w, a, b, 2.0 * r), rtol=1e-4, atol=1e-4
    )


def test_lora_linear_grads_match():
    rng = np.random.default_rng(16)
    x = rand(rng, 64, 16)
    w = rand(rng, 32, 16)
    a = rand(rng, 8, 16)
    b = rand(rng, 32, 8)
    f_p = lambda x_, a_, b_: jnp.sum(jnp.square(p_lora(x_, w, a_, b_, 16.0, 32, 32)))
    f_r = lambda x_, a_, b_: jnp.sum(jnp.square(ref.lora_linear(x_, w, a_, b_, 16.0)))
    dp = jax.grad(f_p, argnums=(0, 1, 2))(x, a, b)
    dr = jax.grad(f_r, argnums=(0, 1, 2))(x, a, b)
    for g1, g2 in zip(dp, dr):
        np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-4)


def test_lora_b_zero_init_means_identity():
    """With B=0, LoRA output equals the base projection (paper §5)."""
    rng = np.random.default_rng(17)
    x = rand(rng, 16, 8)
    w = rand(rng, 12, 8)
    a = rand(rng, 4, 8)
    b = jnp.zeros((12, 4), jnp.float32)
    np.testing.assert_allclose(
        ref.lora_linear(x, w, a, b, 8.0), x @ w.T, rtol=1e-5, atol=1e-6
    )


def test_lora_gradient_asymmetry_at_init():
    """Paper Eq. 52/53: at B=0, grad_B != 0 while grad_A == 0."""
    rng = np.random.default_rng(18)
    x = rand(rng, 16, 8)
    w = rand(rng, 12, 8)
    a = rand(rng, 4, 8)
    b = jnp.zeros((12, 4), jnp.float32)

    def loss(a_, b_):
        return jnp.sum(jnp.square(ref.lora_linear(x, w, a_, b_, 8.0)))

    da, db = jax.grad(loss, argnums=(0, 1))(a, b)
    assert float(jnp.max(jnp.abs(da))) < 1e-6
    assert float(jnp.max(jnp.abs(db))) > 1e-3


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 300),
    step=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_adamw_pallas_matches_ref(n, step, seed):
    rng = np.random.default_rng(seed)
    p = rand(rng, n)
    g = rand(rng, n)
    m = rand(rng, n, scale=0.1)
    v = jnp.abs(rand(rng, n, scale=0.1))
    outs_p = p_adamw(p, g, m, v, 1e-3, float(step))
    outs_r = ref.adamw_update(p, g, m, v, 1e-3, float(step))
    for a, b in zip(outs_p, outs_r):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)


def test_adamw_naive_matches_fused():
    rng = np.random.default_rng(19)
    p = rand(rng, 64)
    g = rand(rng, 64)
    m = jnp.zeros(64)
    v = jnp.zeros(64)
    a = ref.adamw_update(p, g, m, v, 1e-3, 1.0)
    b = ref.adamw_update_naive(p, g, m, v, 1e-3, 1.0)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-6)


def test_adamw_decoupled_weight_decay():
    """Decay shrinks params even with zero gradient (paper Def. 8)."""
    p = jnp.ones(4)
    g = jnp.zeros(4)
    m = jnp.zeros(4)
    v = jnp.zeros(4)
    p2, _, _ = ref.adamw_update(p, g, m, v, lr=0.1, step=1.0, weight_decay=0.5)
    np.testing.assert_allclose(p2, jnp.full(4, 0.95), rtol=1e-6)


def test_newton_schulz_orthogonalizes():
    """Paper Lemma 2: X_k -> orthogonal polar factor."""
    rng = np.random.default_rng(20)
    g = rand(rng, 16, 16)
    x = ref.newton_schulz(g, steps=12)
    xn = x / (jnp.linalg.norm(g) + 1e-12)
    gram = np.asarray(xn @ xn.T)
    # Newton–Schulz converges toward orthogonality; off-diagonal mass shrinks
    off = gram - np.diag(np.diag(gram))
    assert np.abs(off).max() < 0.3
    assert np.abs(np.diag(gram) - np.diag(gram).mean()).max() < 0.3


def test_adam_atan2_bounded():
    """Paper Prop. 18: update magnitude <= pi/2 * lr even with v ~ 0."""
    p = jnp.zeros(4)
    g = jnp.asarray([1e9, -1e9, 1e-9, 0.0], jnp.float32)
    m = jnp.zeros(4)
    v = jnp.zeros(4)
    p2, _, _ = ref.adam_atan2_update(p, g, m, v, lr=1.0, step=1.0, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(p2))) <= np.pi / 2 + 1e-6


def test_schedule_free_converges_on_quadratic():
    """Paper Thm. 10 sanity: averaged iterate reaches the optimum."""
    p = jnp.asarray(5.0)
    z = jnp.asarray(5.0)
    for t in range(1, 600):
        g = 2.0 * z  # d/dz of z^2, gradient taken at the fast iterate
        p, z = ref.schedule_free_update(p, z, g, lr=0.1, step=float(t), weight_decay=0.0)
    # the averaged iterate converges at the O(1/T) Polyak rate (Thm. 10)
    assert abs(float(p)) < 0.1
    assert abs(float(z)) < 1e-6


def test_global_grad_norm():
    gs = [jnp.asarray([3.0]), jnp.asarray([4.0])]
    np.testing.assert_allclose(ref.global_grad_norm(gs), 5.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# Quantization + Kahan
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 500),
    block=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_int8_roundtrip_error_bound(n, block, seed):
    """Paper Eq. 18: |x - dq(q(x))| <= amax/127 per block (+ half-ulp)."""
    rng = np.random.default_rng(seed)
    x = rand(rng, n)
    q, scale = ref.int8_quantize_blockwise(x, block)
    back = ref.int8_dequantize_blockwise(q, scale, n, (n,))
    err = np.abs(np.asarray(back - x))
    amax = float(jnp.max(jnp.abs(x)))
    assert err.max() <= amax / 127.0 * 0.5 + 1e-7


def test_int8_pallas_matches_ref():
    rng = np.random.default_rng(21)
    x = rand(rng, 300)
    qp, sp = p_int8(x, 64)
    qr, sr = ref.int8_quantize_blockwise(x, 64)
    np.testing.assert_allclose(qp, qr)
    np.testing.assert_allclose(sp, sr)


def test_fp8_e4m3_range_and_grid():
    """E4M3: max 448, values land on the 3-mantissa-bit grid (paper Def. 22)."""
    x = jnp.asarray([500.0, -500.0, 448.0, 1.0, 1.06, 0.0], jnp.float32)
    q = np.asarray(ref.fp8_e4m3_quantize(x))
    assert q[0] == 448.0 and q[1] == -448.0 and q[2] == 448.0
    assert q[3] == 1.0 and q[5] == 0.0
    # 1.06 rounds to the nearest 1/8 step in [1, 2): 1.0 (0.06 < 1/16)
    np.testing.assert_allclose(q[4], 1.0, atol=1e-7)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_fp8_e4m3_relative_error_bound(seed):
    """Relative error <= 2^-4 (half ulp at 3 mantissa bits) for normals."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.exp(rng.uniform(-3, 5, size=64)).astype(np.float32))
    x = jnp.minimum(x, 448.0)
    q = ref.fp8_e4m3_quantize(x)
    rel = np.abs(np.asarray((q - x) / x))
    assert rel.max() <= 2.0**-4 + 1e-6


def test_fp8_e5m2_wider_range_coarser_grid():
    x = jnp.asarray([57344.0, 60000.0, 1.1], jnp.float32)
    q = np.asarray(ref.fp8_e5m2_quantize(x))
    assert q[0] == 57344.0 and q[1] == 57344.0
    # 2 mantissa bits: quarter steps in [1, 2)
    assert q[2] in (1.0, 1.25)


def test_fp8_blockwise_pallas_matches_ref():
    rng = np.random.default_rng(22)
    x = rand(rng, 200, scale=10.0)
    qp, sp = p_fp8(x, 64)
    qr, sr = ref.fp8_blockwise_e4m3(x, 64)
    np.testing.assert_allclose(qp, qr)
    np.testing.assert_allclose(sp, sr)


def test_kahan_beats_naive_summation():
    """Paper Prop. 5: Kahan error O(eps) vs naive O(n*eps)."""
    n = 20000
    rng = np.random.default_rng(23)
    xs = (rng.uniform(0, 1, size=n) * 1e-4 + 1.0).astype(np.float32)
    exact = np.sum(xs.astype(np.float64))
    naive = np.float32(0.0)
    for x in xs:
        naive += x
    kahan = float(ref.kahan_sum(jnp.asarray(xs)))
    assert abs(kahan - exact) <= abs(float(naive) - exact)
    assert abs(kahan - exact) / exact < 1e-6
