//! LoRA+ convergence study (paper Fig. 17 + §5): identical runs at
//! λ = η_B/η_A ∈ {1, 4, 16, 32}, same seed and data order, comparing the
//! loss trajectory. The paper's claim: λ=16 reaches a given loss ~1.6x
//! faster than λ=1; λ=32 shows no further gain.
//!
//! Run: `cargo run --release --example lora_plus -- [steps]`

use chronicals::batching::packed_batches;
use chronicals::coordinator::Trainer;
use chronicals::harness;
use chronicals::optim::LrSchedule;
use chronicals::runtime::{Runtime, TrainState};
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let rt = Rc::new(Runtime::new("artifacts")?);
    let exe = "train_step_lora";
    let spec = rt.manifest.get(exe)?.clone();
    let (_tok, exs) = harness::build_corpus(1024, 7, spec.model_config.vocab, 1024);
    let batches = packed_batches(&exs, spec.batch, spec.seq);

    let ratios = [1.0, 4.0, 16.0, 32.0];
    let mut curves: Vec<Vec<f32>> = Vec::new();
    for &ratio in &ratios {
        let init = harness::resolve_init(&rt, exe, "init_lora")?;
        let state = TrainState::init(&rt, &init, 7)?;
        let schedule = LrSchedule::constant(1e-3, ratio);
        let mut trainer = Trainer::new(rt.clone(), exe, state, schedule, 0)?;
        let mut curve = Vec::new();
        for i in 0..steps {
            let b = &batches[(i % batches.len() as u64) as usize];
            curve.push(trainer.step(b)?.loss);
        }
        println!(
            "λ = {:>4}: loss {:.4} -> {:.4}",
            ratio,
            curve[0],
            curve.last().unwrap()
        );
        curves.push(curve);
    }

    // convergence speed: first step reaching the λ=1 final loss
    let target = *curves[0].last().unwrap();
    println!("\nsteps to reach the λ=1 final loss ({target:.4}):");
    for (r, c) in ratios.iter().zip(&curves) {
        let hit = c.iter().position(|&l| l <= target);
        match hit {
            Some(s) => println!("  λ = {:>4}: {} steps ({:.2}x faster)", r, s + 1, steps as f64 / (s + 1) as f64),
            None => println!("  λ = {:>4}: not reached in {steps} steps", r),
        }
    }

    let lora_final = *curves[0].last().unwrap();
    let plus_final = *curves[2].last().unwrap();
    anyhow::ensure!(
        plus_final < lora_final,
        "LoRA+ (λ=16) must beat LoRA (λ=1): {plus_final} vs {lora_final}"
    );
    println!("\nlora_plus OK — λ=16 converges faster (paper Thm. 1 / Fig. 17)");
    Ok(())
}
