//! LoRA+ convergence study (paper Fig. 17 + §5): identical runs at
//! λ = η_B/η_A ∈ {1, 4, 16, 32}, same seed and data order, comparing the
//! loss trajectory. The paper's claim: λ=16 reaches a given loss faster
//! than λ=1; λ=32 shows no further gain.
//!
//! Run: `cargo run --release --example lora_plus -- [steps]`

use chronicals::session::{DataSource, SessionBuilder, Task};

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);

    let ratios = [1.0, 4.0, 16.0, 32.0];
    let mut curves: Vec<Vec<f32>> = Vec::new();
    for &ratio in &ratios {
        // same seed and data source every run: only λ differs
        let mut session = SessionBuilder::new()
            .task(Task::lora_plus(ratio))
            .steps(steps)
            .lr(1e-3)
            .seed(7)
            .meter_warmup(0)
            .data(DataSource::synthetic(1024, 7, 1024))
            .build()?;
        session.run()?;
        let curve: Vec<f32> = session.records().iter().map(|r| r.loss).collect();
        println!(
            "λ = {:>4}: loss {:.4} -> {:.4}",
            ratio,
            curve[0],
            curve.last().unwrap()
        );
        curves.push(curve);
    }

    // convergence speed: first step reaching the λ=1 final loss
    let target = *curves[0].last().unwrap();
    println!("\nsteps to reach the λ=1 final loss ({target:.4}):");
    for (r, c) in ratios.iter().zip(&curves) {
        match c.iter().position(|&l| l <= target) {
            Some(s) => println!(
                "  λ = {:>4}: {} steps ({:.2}x faster)",
                r,
                s + 1,
                steps as f64 / (s + 1) as f64
            ),
            None => println!("  λ = {:>4}: not reached in {steps} steps", r),
        }
    }

    let lora_final = *curves[0].last().unwrap();
    let plus_final = *curves[2].last().unwrap();
    anyhow::ensure!(
        plus_final < lora_final,
        "LoRA+ (λ=16) must beat LoRA (λ=1): {plus_final} vs {lora_final}"
    );
    println!("\nlora_plus OK — λ=16 converges faster (paper Thm. 1 / Fig. 17)");
    Ok(())
}
