//! The Unsloth-bug demonstration (paper §8 "Critical Finding", Fig. 10/22):
//! a "fast mode" whose backward pass silently disappears reports much
//! higher tokens/sec while the model learns nothing — detectable only by
//! checking gradient norms, trainable fractions and loss movement.
//!
//! Run: `cargo run --release --example unsloth_bug -- [steps]`

use chronicals::coordinator::Verifier;
use chronicals::session::{DataSource, SessionBuilder, Task};
use chronicals::util::commas;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);

    println!("=== the benchmark that lies (paper Fig. 10) ===\n");
    let mut results = Vec::new();
    for (label, task) in [
        ("correct LoRA", Task::lora()),
        ("'fast mode' LoRA", Task::LoraBroken),
    ] {
        let mut session = SessionBuilder::new()
            .task(task)
            .steps(steps)
            .meter_warmup(1)
            .lr(1e-3)
            .data(DataSource::synthetic(512, 42, 1024))
            .build()?;
        let s = session.run()?.summary;
        println!(
            "{label:<18} {:>9} tok/s | loss {:.4} -> {:.4} | grad_norm max {:.3e} | {}",
            commas(s.tokens_per_sec as u64),
            s.first_loss,
            s.last_loss,
            s.verification.max_grad_norm,
            s.verification.status()
        );
        for f in &s.verification.failures {
            println!("{:<18}   ⚠ {f}", "");
        }
        results.push(s);
    }

    let speedup = results[1].tokens_per_sec / results[0].tokens_per_sec;
    println!(
        "\nthe broken config 'wins' by {speedup:.2}x — the same shape as the\n\
         paper's 46,000 vs 11,736 tok/s finding (3.9x) — while training NOTHING."
    );
    anyhow::ensure!(results[0].verification.is_training);
    anyhow::ensure!(!results[1].verification.is_training);
    anyhow::ensure!(speedup > 1.2, "broken mode should look faster");

    // the 72%-trainable failure mode (Fig. 22), shown on synthetic numbers:
    println!("\n=== partial-trainability check (the 72% case) ===");
    let mut v = Verifier::default();
    for i in 0..5 {
        v.observe(5.0 - 0.05 * i as f32, 0.4);
    }
    let r = v.report(72, 100); // 72 of 100 expected params trainable
    println!("verifier on a 72%-trainable run: {}", r.status());
    for f in &r.failures {
        println!("  ⚠ {f}");
    }
    anyhow::ensure!(!r.is_training);

    println!("\nunsloth_bug OK — always verify gradient flow before quoting tokens/sec");
    Ok(())
}
