//! Sequence-packing analysis (paper Fig. 18, Thm. 8, Prop. 14):
//! BFD vs FFD vs Next-Fit vs no packing on the synthetic Alpaca-shaped
//! corpus, plus the paper's mean-512/max-2048 waste claim and the BFD
//! bound against the capacity lower bound.
//!
//! Run: `cargo run --release --example packing_analysis`

use chronicals::data::{CorpusConfig, SyntheticCorpus};
use chronicals::harness;
use chronicals::packing::*;

fn main() -> anyhow::Result<()> {
    // 1) packing table on the tokenized corpus (capacity 512, 2048)
    for capacity in [512usize, 2048] {
        println!("{}", harness::packing_report(capacity, 4096));
    }

    // 2) the paper's Prop. 14 claim: mean≈512 / max 2048 ⇒ ~75% padding
    //    waste unpacked, <12% with BFD.
    let cfg = CorpusConfig {
        n_examples: 8192,
        lognorm_mu: 6.1, // mean ≈ e^{6.1+0.18} ≈ 530 words
        lognorm_sigma: 0.6,
        min_words: 32,
        max_words: 2048,
        seed: 1,
    };
    let corpus = SyntheticCorpus::generate(&cfg);
    let stats = SyntheticCorpus::length_stats(&corpus);
    println!(
        "Prop. 14 corpus: n={} mean={:.0} p50={} p90={} max={}",
        stats.n, stats.mean, stats.p50, stats.p90, stats.max
    );
    let lengths: Vec<usize> = corpus
        .iter()
        .map(|e| e.prompt.split_whitespace().count() + e.completion.split_whitespace().count())
        .collect();
    let unpacked = no_packing(&lengths, 2048);
    let packed = best_fit_decreasing(&lengths, 2048);
    println!(
        "padding waste: unpacked {:.1}% (paper: 60-75%), BFD {:.1}% (paper: <12%)",
        unpacked.waste() * 100.0,
        packed.waste() * 100.0
    );
    anyhow::ensure!(unpacked.waste() > 0.5);
    anyhow::ensure!(packed.waste() < 0.12);

    // 3) BFD bound check at scale (Thm. 8)
    let lb = Packing::opt_lower_bound(&lengths, 2048);
    println!(
        "BFD bins {} vs OPT lower bound {} => ratio {:.4} (bound: 11/9 ≈ 1.222)",
        packed.n_bins(),
        lb,
        packed.n_bins() as f64 / lb as f64
    );
    anyhow::ensure!((packed.n_bins() as f64) <= 11.0 / 9.0 * lb as f64 + 6.0 / 9.0);

    println!("\npacking_analysis OK");
    Ok(())
}
