//! End-to-end full fine-tuning driver (the DESIGN.md §5 "E2E validation"
//! experiment): train the reference-substrate transformer on the synthetic
//! instruction corpus for a few hundred steps on the fast CPU backend,
//! logging the loss curve, throughput and verification status, then
//! checkpoint the weights (f32 + int8).
//!
//! Run: `cargo run --release --example full_finetune -- [steps] [out.csv]`
//! Defaults: 300 steps, loss curve written to e2e_loss_curve.csv.

use chronicals::backend::Backend;
use chronicals::checkpoint::{self, Codec};
use chronicals::metrics::mfu_paper_scale;
use chronicals::session::{BackendSpec, DataSource, Schedule, SessionBuilder, Task};
use chronicals::util::commas;
use std::io::Write;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let csv_path = args
        .get(1)
        .cloned()
        .unwrap_or_else(|| "e2e_loss_curve.csv".to_string());

    let mut session = SessionBuilder::new()
        .task(Task::FullFinetune)
        .steps(steps)
        .lr(2e-3)
        .schedule(Schedule::WarmupCosine { warmup: steps / 20 })
        .meter_warmup(3)
        .seed(42)
        .data(DataSource::synthetic(4096, 42, 1024))
        .backend(BackendSpec::CpuFast { threads: 0 })
        .build()?;

    let spec = session.resolved().spec.clone();
    println!(
        "model: {} params ({} layers, d={}, vocab={}), batch {}x{} on {}",
        commas(spec.param_count),
        spec.model_config.n_layers,
        spec.model_config.d_model,
        spec.model_config.vocab,
        spec.batch,
        spec.seq,
        session.backend().name()
    );

    println!("training for {steps} steps (progress prints at the end — run() is one call)...");
    let t0 = std::time::Instant::now();
    let report = session.run()?;
    let wall = t0.elapsed().as_secs_f64();
    let s = &report.summary;

    let mut csv = String::from("step,loss,grad_norm,ms\n");
    println!("loss curve (sampled every 20 steps):");
    for rec in session.records() {
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.2}\n",
            rec.step, rec.loss, rec.grad_norm, rec.wall_ms
        ));
        if rec.step % 20 == 0 || rec.step == 1 {
            println!(
                "  step {:>4}: loss {:.4} | grad_norm {:.3} | {:.0} ms/step",
                rec.step, rec.loss, rec.grad_norm, rec.wall_ms
            );
        }
    }
    std::fs::File::create(&csv_path)?.write_all(csv.as_bytes())?;
    println!("\nloss curve written to {csv_path}");

    println!("\n=== summary ===");
    println!("wall time:   {wall:.1}s for {steps} steps");
    println!("loss:        {:.4} -> {:.4}", s.first_loss, s.last_loss);
    println!(
        "throughput:  {} real tokens/sec ({} slot tokens/sec)",
        commas(s.tokens_per_sec as u64),
        commas(s.slot_tokens_per_sec as u64)
    );
    println!(
        "data:        {} examples -> {} batches planned, {} staged{}",
        report.examples,
        report.batches_planned,
        report.batches_staged,
        if report.tail_padded { " (tail padded)" } else { "" }
    );
    println!(
        "MFU*:        {:.2}% (A100-peak-referenced comparator)",
        mfu_paper_scale(s.param_count, s.tokens_per_sec) * 100.0
    );
    println!("verification: {}", s.verification.status());

    // checkpoint the trained parameters (f32 + int8 for the size comparison)
    session.save_checkpoint("e2e_final.ckpt", Codec::F32)?;
    session.save_checkpoint("e2e_final_int8.ckpt", checkpoint::Codec::Int8)?;
    let f32_sz = std::fs::metadata("e2e_final.ckpt")?.len();
    let int8_sz = std::fs::metadata("e2e_final_int8.ckpt")?.len();
    println!(
        "checkpoints: f32 {} KiB, int8 {} KiB ({:.2}x smaller)",
        f32_sz >> 10,
        int8_sz >> 10,
        f32_sz as f64 / int8_sz.max(1) as f64
    );

    anyhow::ensure!(s.verification.is_training);
    anyhow::ensure!(s.last_loss < s.first_loss * 0.9, "insufficient learning");
    println!("\nfull_finetune OK");
    Ok(())
}
