//! End-to-end full fine-tuning driver (the DESIGN.md §5 "E2E validation"
//! experiment): train the `e2e`-scale transformer on the synthetic
//! instruction corpus for a few hundred steps, logging the loss curve,
//! throughput and verification status, then checkpoint the weights.
//!
//! Run: `cargo run --release --example full_finetune -- [steps] [out.csv]`
//! Defaults: 300 steps, loss curve written to e2e_loss_curve.csv.
//! Recorded in EXPERIMENTS.md §E2E.

use chronicals::batching::packed_batches;
use chronicals::checkpoint;
use chronicals::coordinator::Trainer;
use chronicals::harness;
use chronicals::metrics::mfu_paper_scale;
use chronicals::optim::LrSchedule;
use chronicals::runtime::{HostTensor, Runtime, TrainState};
use chronicals::util::commas;
use std::io::Write;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let csv_path = args
        .get(1)
        .cloned()
        .unwrap_or_else(|| "e2e_loss_curve.csv".to_string());

    let rt = Rc::new(Runtime::new("artifacts")?);
    let exe = "train_step_e2e";
    let spec = rt.manifest.get(exe)?.clone();
    println!(
        "e2e model: {} params ({} layers, d={}, vocab={}), batch {}x{}",
        commas(spec.param_count),
        spec.model_config.n_layers,
        spec.model_config.d_model,
        spec.model_config.vocab,
        spec.batch,
        spec.seq
    );

    // corpus: enough examples that batches don't repeat too often
    let (_tok, exs) = harness::build_corpus(4096, 42, spec.model_config.vocab, 1024);
    let batches = packed_batches(&exs, spec.batch, spec.seq);
    println!(
        "corpus: {} examples -> {} packed batches (density {:.1}%)",
        exs.len(),
        batches.len(),
        batches.iter().map(|b| b.density()).sum::<f64>() / batches.len() as f64 * 100.0
    );

    let init = harness::resolve_init(&rt, exe, "init_e2e")?;
    let state = TrainState::init(&rt, &init, 42)?;
    let schedule = LrSchedule::warmup_cosine(3e-4 * 2.0, steps / 20, steps, 1.0);
    let mut trainer = Trainer::new(rt.clone(), exe, state, schedule, 3)?;

    println!("training for {steps} steps...");
    let t0 = std::time::Instant::now();
    let mut csv = String::from("step,loss,grad_norm,ms\n");
    for i in 0..steps {
        let b = &batches[(i % batches.len() as u64) as usize];
        let rec = trainer.step(b)?;
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.2}\n",
            rec.step, rec.loss, rec.grad_norm, rec.wall_ms
        ));
        if rec.step % 20 == 0 || rec.step == 1 {
            println!(
                "  step {:>4}: loss {:.4} | grad_norm {:.3} | {:.0} ms/step",
                rec.step, rec.loss, rec.grad_norm, rec.wall_ms
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = trainer.summary();

    std::fs::File::create(&csv_path)?.write_all(csv.as_bytes())?;
    println!("\nloss curve written to {csv_path}");

    println!("\n=== e2e summary ===");
    println!("wall time:   {wall:.1}s for {steps} steps");
    println!("loss:        {:.4} -> {:.4}", s.first_loss, s.last_loss);
    println!(
        "throughput:  {} real tokens/sec ({} slot tokens/sec)",
        commas(s.tokens_per_sec as u64),
        commas(s.slot_tokens_per_sec as u64)
    );
    println!(
        "MFU*:        {:.2}% (A100-peak-referenced comparator)",
        mfu_paper_scale(s.param_count, s.tokens_per_sec) * 100.0
    );
    println!("verification: {}", s.verification.status());

    // checkpoint the trained parameters (f32 + int8 for the size comparison)
    let params = trainer.state.params_to_host()?;
    let tensors: Vec<HostTensor> = params
        .iter()
        .map(HostTensor::from_literal)
        .collect::<Result<_, _>>()?;
    checkpoint::save("e2e_final.ckpt", &tensors, checkpoint::Codec::F32)?;
    checkpoint::save("e2e_final_int8.ckpt", &tensors, checkpoint::Codec::Int8)?;
    let f32_sz = std::fs::metadata("e2e_final.ckpt")?.len();
    let int8_sz = std::fs::metadata("e2e_final_int8.ckpt")?.len();
    println!(
        "checkpoints: f32 {} MiB, int8 {} MiB ({:.2}x smaller)",
        f32_sz >> 20,
        int8_sz >> 20,
        f32_sz as f64 / int8_sz as f64
    );

    anyhow::ensure!(s.verification.is_training);
    anyhow::ensure!(s.last_loss < s.first_loss * 0.9, "insufficient learning");
    println!("\nfull_finetune OK");
    Ok(())
}
