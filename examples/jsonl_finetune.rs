//! End-to-end fine-tuning on the checked-in JSONL sample corpus — the
//! file-backed data path (ISSUE 5, DESIGN.md §8):
//!
//! 1. `data/sample.jsonl` streams through the byte-level mini-BPE
//!    tokenizer (learned from the corpus at a fixed seed, capped to the
//!    model vocab),
//! 2. BFD packs the real length distribution, the epoch policy shuffles
//!    the plan deterministically per epoch,
//! 3. the run reports full data accounting: malformed records skipped
//!    (the sample deliberately contains two), oversized drops, packing
//!    density and padding recovery,
//! 4. the whole thing is run twice to prove bitwise reproducibility.
//!
//! Runs on the hermetic CPU reference backend: no artifacts, no Python.
//!
//! Run: `cargo run --release --example jsonl_finetune`

use chronicals::session::{DataSource, PackingStrategy, RunReport, SessionBuilder, Task};
use std::path::PathBuf;

fn sample_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../data/sample.jsonl")
}

fn run_once() -> anyhow::Result<RunReport> {
    let mut session = SessionBuilder::new()
        .task(Task::FullFinetune)
        .packing(PackingStrategy::Bfd)
        .lr(5e-3)
        .meter_warmup(1)
        .data(DataSource::jsonl(sample_path().to_string_lossy(), 7, 1024))
        .shuffle_seed(7)
        .epochs(2)
        .build()?;
    session.run()
}

fn main() -> anyhow::Result<()> {
    println!("fine-tuning on data/sample.jsonl (bfd packing, shuffle seed 7, 2 epochs)\n");
    let report = run_once()?;
    let s = &report.summary;

    println!("=== results ===");
    println!("loss:        {:.4} -> {:.4}", s.first_loss, s.last_loss);
    println!(
        "steps:       {} ({} epochs over {} batches)",
        s.steps, report.epochs, report.batches_planned
    );
    println!(
        "data:        {} examples, {} malformed skipped, {} oversized dropped",
        report.examples, report.malformed_skipped, report.oversized_dropped
    );
    for n in &report.source_notes {
        println!("             {n}");
    }
    println!(
        "packing:     {:.1}% dense, {:.1}% of padding waste recovered",
        report.packed_density * 100.0,
        report.padding_recovery * 100.0
    );
    println!("status:      {}", s.verification.status());

    anyhow::ensure!(s.verification.is_training, "run failed gradient verification");
    anyhow::ensure!(s.last_loss < s.first_loss, "loss did not improve");
    anyhow::ensure!(
        report.malformed_skipped == 2,
        "the sample corpus carries exactly two deliberately malformed lines"
    );
    anyhow::ensure!(
        report.padding_recovery > 0.0,
        "BFD on the real length distribution must recover padding waste"
    );
    anyhow::ensure!(
        report.summary.steps as usize == report.batches_planned,
        "epoch mode derives the run length from the data"
    );

    // reproducibility: an identical second run must match bit for bit
    let again = run_once()?;
    anyhow::ensure!(
        report.summary.last_loss.to_bits() == again.summary.last_loss.to_bits()
            && report.summary.first_loss.to_bits() == again.summary.first_loss.to_bits(),
        "two identical invocations must be bitwise identical"
    );
    println!("\nreproducibility: second run matches bit for bit");
    println!("jsonl_finetune OK");
    Ok(())
}
