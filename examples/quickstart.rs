//! Quickstart: the minimal Chronicals workflow.
//!
//! 1. load the AOT artifacts (built once by `make artifacts`),
//! 2. generate + tokenize + BFD-pack an instruction corpus,
//! 3. initialize device-resident training state,
//! 4. train for a handful of steps with verified gradient flow.
//!
//! Run: `cargo run --release --example quickstart`

use chronicals::config::RunConfig;
use chronicals::harness;
use chronicals::runtime::Runtime;
use chronicals::util::commas;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    // The runtime compiles each HLO-text artifact once and keeps all
    // training state on the PJRT device between steps.
    let rt = Rc::new(Runtime::new("artifacts")?);
    println!(
        "loaded {} executables (profile: {})",
        rt.manifest.executables.len(),
        rt.manifest.profile
    );

    // Full fine-tuning with the complete Chronicals stack: flash-structure
    // attention, fused kernels, Cut Cross-Entropy, fused AdamW, BFD packing.
    let cfg = RunConfig {
        executable: "train_step_chronicals".into(),
        steps: 20,
        warmup_steps: 2,
        lr: 3e-3,
        packed: true,
        corpus_examples: 512,
        ..RunConfig::default()
    };

    println!("training {} for {} steps...", cfg.executable, cfg.steps);
    let summary = harness::run_variant(&rt, &cfg)?;

    println!("\n=== results ===");
    println!(
        "loss:        {:.4} -> {:.4}",
        summary.first_loss, summary.last_loss
    );
    println!(
        "throughput:  {} tokens/sec (real tokens)",
        commas(summary.tokens_per_sec as u64)
    );
    println!(
        "step time:   {:.1} ms ± {:.1}",
        summary.mean_step_ms, summary.std_step_ms
    );
    println!(
        "gradients:   [{:.3e}, {:.3e}]",
        summary.verification.min_grad_norm, summary.verification.max_grad_norm
    );
    println!("status:      {}", summary.verification.status());
    anyhow::ensure!(summary.verification.is_training, "run failed verification");
    anyhow::ensure!(summary.last_loss < summary.first_loss, "loss did not improve");
    println!("\nquickstart OK");
    Ok(())
}
