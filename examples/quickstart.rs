//! Quickstart: the minimal Chronicals workflow through the typed Session
//! API.
//!
//! 1. describe the run with the builder (task, packing, data, schedule),
//! 2. `build()` — validates the spec and resolves it against the backend
//!    manifest (bad combinations fail here with a real error message),
//! 3. `run()` — corpus → tokenize → BFD-pack → lazy batch stream →
//!    verified train steps.
//!
//! Runs on the hermetic CPU reference backend: no artifacts, no Python.
//!
//! Run: `cargo run --release --example quickstart`

use chronicals::backend::Backend;
use chronicals::session::{DataSource, PackingStrategy, SessionBuilder, Task};
use chronicals::util::commas;

fn main() -> anyhow::Result<()> {
    // Full fine-tuning with the complete Chronicals stack: BFD packing,
    // verified gradient flow, honest (real-token) throughput accounting.
    let mut session = SessionBuilder::new()
        .task(Task::FullFinetune)
        .packing(PackingStrategy::Bfd)
        .steps(20)
        .meter_warmup(2)
        .lr(3e-3)
        .data(DataSource::synthetic(512, 42, 1024))
        .build()?;

    println!(
        "training {} on the {} backend for 20 steps...",
        session.resolved().train,
        session.backend().name()
    );
    let report = session.run()?;
    let s = &report.summary;

    println!("\n=== results ===");
    println!("loss:        {:.4} -> {:.4}", s.first_loss, s.last_loss);
    println!(
        "throughput:  {} tokens/sec (real tokens)",
        commas(s.tokens_per_sec as u64)
    );
    println!("step time:   {:.1} ms ± {:.1}", s.mean_step_ms, s.std_step_ms);
    println!(
        "gradients:   [{:.3e}, {:.3e}]",
        s.verification.min_grad_norm, s.verification.max_grad_norm
    );
    println!(
        "data:        {} examples -> {} batches ({} staged)",
        report.examples, report.batches_planned, report.batches_staged
    );
    println!("status:      {}", s.verification.status());
    anyhow::ensure!(s.verification.is_training, "run failed verification");
    anyhow::ensure!(s.last_loss < s.first_loss, "loss did not improve");
    println!("\nquickstart OK");
    Ok(())
}
