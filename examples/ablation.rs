//! Ablation study (paper Table 4 / Fig. 14): measure the ladder
//! baseline → +FlashAttention → +whole-graph-compile → +fused kernels/CCE
//! → +sequence packing → +fused optimizer, each rung a real training run
//! with verified gradient flow.
//!
//! Run: `cargo run --release --example ablation -- [steps]`

use chronicals::harness;
use chronicals::report;
use chronicals::runtime::Runtime;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let rt = Rc::new(Runtime::new("artifacts")?);
    println!("running the 6-rung ablation ladder ({steps} steps each)...\n");
    let rows = harness::ablation_ladder(&rt, steps)?;
    println!("{}", report::ablation_table(&rows));

    let base = rows.first().unwrap().tokens_per_sec;
    let last = rows.last().unwrap().tokens_per_sec;
    println!(
        "total stack speedup: {:.2}x (paper: 3.51x over the verified baseline;\n\
         the *shape* — every rung helps, compounding multiplicatively — is the\n\
         reproduced claim; absolute ratios differ on the CPU substrate)",
        last / base
    );
    anyhow::ensure!(last > base, "the full stack must beat the baseline");
    println!("\nablation OK");
    Ok(())
}
