//! Ablation study (paper Table 4 / Fig. 14): measure the ladder
//! baseline → +FlashAttention → +whole-graph-compile → +fused kernels/CCE
//! → +sequence packing → +fused optimizer, each rung a real training run
//! with verified gradient flow, through the typed Session API.
//!
//! Run: `cargo run --release --example ablation -- [steps]`
//! Env: BACKEND=cpu|cpu-fast|pjrt (default cpu).

use chronicals::backend::{create_backend, Backend};
use chronicals::harness;
use chronicals::report;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let backend_name = std::env::var("BACKEND").unwrap_or_else(|_| "cpu".into());
    let backend = create_backend(&backend_name, "artifacts", 0)?;
    println!(
        "running the 6-rung ablation ladder on {} ({steps} steps each)...\n",
        backend.name()
    );
    let rows = harness::ablation_ladder(&backend, steps)?;
    println!("{}", report::ablation_table(&rows));

    let base = rows.first().unwrap().tokens_per_sec;
    let last = rows.last().unwrap().tokens_per_sec;
    println!(
        "total stack speedup: {:.2}x (paper: 3.51x over the verified baseline;\n\
         the *shape* — every rung helps, compounding multiplicatively — is the\n\
         reproduced claim; absolute ratios differ on the CPU substrate)",
        last / base
    );
    anyhow::ensure!(last.is_finite() && base.is_finite());
    println!("\nablation OK");
    Ok(())
}
