//! Two tenants, one base model (DESIGN.md §11):
//!
//! 1. `data/chat_sample.jsonl` is split into two disjoint tenant corpora
//!    (even- vs odd-indexed transcripts) via a custom [`ExampleSource`],
//! 2. `tenant-even` fine-tunes a LoRA adapter and `tenant-odd` a LoRA+
//!    adapter against the *same* shared base weights, co-scheduled by the
//!    serve engine in fused rounds — one workspace, two adapters swapped
//!    in and out per slice,
//! 3. the whole service runs twice; both tenants' report files must match
//!    bit for bit across runs (serve reports carry no wall-clock fields,
//!    so determinism is byte-level).
//!
//! Runs on the hermetic CPU reference backend: no artifacts, no Python.
//!
//! Run: `cargo run --release --example multi_tenant`

use chronicals::backend::create_backend;
use chronicals::data::TokenizedExample;
use chronicals::serve::{JobSpec, ServeConfig, ServeEngine};
use chronicals::session::{DataSource, ExampleSource, LossMode, Schedule, Task};
use std::path::{Path, PathBuf};
use std::rc::Rc;

fn chat_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../data/chat_sample.jsonl")
}

/// One tenant's private slice of the shared chat corpus: the even- or
/// odd-indexed transcripts, tokenized exactly like `DataSource::chat`.
struct ChatSlice {
    keep_odd: bool,
}

impl ExampleSource for ChatSlice {
    fn label(&self) -> String {
        format!("chat-slice({})", if self.keep_odd { "odd" } else { "even" })
    }

    fn examples(&self, vocab_cap: usize) -> anyhow::Result<Vec<TokenizedExample>> {
        let (all, _stats) = DataSource::chat(chat_path().to_string_lossy(), 7, 48)
            .tokenized(vocab_cap, LossMode::default())?;
        Ok(all
            .into_iter()
            .enumerate()
            .filter(|(i, _)| (i % 2 == 1) == self.keep_odd)
            .map(|(_, e)| e)
            .collect())
    }
}

fn tenant(id: &str, task: Task, seed: i64, keep_odd: bool) -> JobSpec {
    JobSpec {
        id: id.to_string(),
        task,
        steps: 8,
        lr: 5e-3,
        seed,
        schedule: Schedule::Constant,
        loss_mode: LossMode::default(),
        data: DataSource::Custom(Rc::new(ChatSlice { keep_odd })),
    }
}

/// Serve both tenants once; return each report file's exact text.
fn serve_once(out: &Path) -> anyhow::Result<(String, String)> {
    let _ = std::fs::remove_dir_all(out);
    let backend = create_backend("cpu", "", 0)?;
    let cfg =
        ServeConfig { out_dir: out.to_path_buf(), steps_per_round: 2, ..Default::default() };
    let mut engine = ServeEngine::new(backend, cfg)?;
    engine.admit_spec(tenant("tenant-even", Task::lora(), 7, false))?;
    engine.admit_spec(tenant("tenant-odd", Task::lora_plus(16.0), 11, true))?;
    let summary = engine.run()?;
    anyhow::ensure!(summary.completed == 2, "both tenants finish their budgets: {summary:?}");
    anyhow::ensure!(
        summary.fused_rounds > 0,
        "compatible LoRA tenants share fused rounds: {summary:?}"
    );
    let even = std::fs::read_to_string(out.join("tenant-even.report.json"))?;
    let odd = std::fs::read_to_string(out.join("tenant-odd.report.json"))?;
    Ok((even, odd))
}

fn main() -> anyhow::Result<()> {
    println!("serving two adapters over disjoint slices of data/chat_sample.jsonl\n");
    let base = std::env::temp_dir().join(format!("chronicals_multi_tenant_{}", std::process::id()));
    let (even_a, odd_a) = serve_once(&base.join("run1"))?;
    let (even_b, odd_b) = serve_once(&base.join("run2"))?;

    for (id, text) in [("tenant-even", &even_a), ("tenant-odd", &odd_a)] {
        anyhow::ensure!(
            text.contains("\"loss_decreased\": true"),
            "{id} must show decreasing loss:\n{text}"
        );
        anyhow::ensure!(text.contains("\"completed\": true"), "{id} must complete:\n{text}");
        println!("--- {id}.report.json ---\n{text}");
    }

    anyhow::ensure!(even_a == even_b, "tenant-even reports must match bit for bit across runs");
    anyhow::ensure!(odd_a == odd_b, "tenant-odd reports must match bit for bit across runs");
    println!("reproducibility: second service run produced byte-identical reports");

    let _ = std::fs::remove_dir_all(&base);
    println!("multi_tenant OK");
    Ok(())
}
