//! Chat-transcript fine-tuning with response-only loss and a held-out
//! eval loop (DESIGN.md §9):
//!
//! 1. `data/chat_sample.jsonl` streams `{"messages": [...]}` transcripts
//!    through the byte-level mini-BPE tokenizer, each turn framed as
//!    `role: content` with its own `<bos>`/`<eos>` envelope,
//! 2. under the default response-only loss mode every system and user
//!    token is loss-masked — only assistant turns are supervised,
//! 3. `eval_fraction(0.2)` holds out a seeded, shuffle-invariant 20% of
//!    the transcripts; the run reports a `(step, loss)` eval series from
//!    step 0 (untrained) through the final step,
//! 4. the whole thing runs twice to prove the report — eval series
//!    included — is bitwise reproducible.
//!
//! Runs on the hermetic CPU reference backend: no artifacts, no Python.
//!
//! Run: `cargo run --release --example chat_finetune`

use chronicals::session::{DataSource, PackingStrategy, RunReport, SessionBuilder, Task};
use std::path::PathBuf;

fn chat_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../data/chat_sample.jsonl")
}

fn run_once() -> anyhow::Result<RunReport> {
    let mut session = SessionBuilder::new()
        .task(Task::FullFinetune)
        .packing(PackingStrategy::Bfd)
        .lr(5e-3)
        .meter_warmup(1)
        .data(DataSource::chat(chat_path().to_string_lossy(), 7, 1024))
        .eval_fraction(0.2)
        .shuffle_seed(7)
        .epochs(2)
        .build()?;
    session.run()
}

fn main() -> anyhow::Result<()> {
    println!("fine-tuning on data/chat_sample.jsonl (response-only loss, 20% held out)\n");
    let report = run_once()?;
    let s = &report.summary;

    println!("=== results ===");
    println!("train loss:  {:.4} -> {:.4}", s.first_loss, s.last_loss);
    println!(
        "eval loss:   {}",
        report
            .eval
            .iter()
            .map(|(step, loss)| format!("{step}:{loss:.4}"))
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!(
        "data:        {} transcripts ({} held out for eval), {} malformed skipped",
        report.examples, report.eval_examples, report.malformed_skipped
    );
    println!(
        "steps:       {} ({} epochs over {} batches)",
        s.steps, report.epochs, report.batches_planned
    );
    println!("status:      {}", s.verification.status());

    anyhow::ensure!(s.verification.is_training, "run failed gradient verification");
    anyhow::ensure!(report.malformed_skipped == 0, "the chat corpus is fully well-formed");
    anyhow::ensure!(
        report.eval_examples == 2,
        "⌊12 · 0.2⌋ transcripts held out, got {}",
        report.eval_examples
    );
    anyhow::ensure!(
        report.eval.first().map(|&(step, _)| step) == Some(0),
        "eval starts before training"
    );
    anyhow::ensure!(
        report.final_eval_loss == report.eval.last().map(|&(_, l)| l),
        "the summary echoes the last eval point"
    );
    anyhow::ensure!(
        report.summary.steps as usize == report.batches_planned,
        "epoch mode derives the run length from the data"
    );

    // reproducibility: an identical second run must match bit for bit,
    // eval series included
    let again = run_once()?;
    let bits =
        |r: &RunReport| r.eval.iter().map(|&(s, l)| (s, l.to_bits())).collect::<Vec<_>>();
    anyhow::ensure!(
        report.summary.last_loss.to_bits() == again.summary.last_loss.to_bits()
            && report.summary.first_loss.to_bits() == again.summary.first_loss.to_bits(),
        "two identical invocations must train bitwise identically"
    );
    anyhow::ensure!(
        bits(&report) == bits(&again),
        "two identical invocations must report the same eval series"
    );
    println!("\nreproducibility: second run matches bit for bit, eval series included");
    println!("chat_finetune OK");
    Ok(())
}
