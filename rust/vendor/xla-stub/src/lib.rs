//! Host-only stub of the xla-rs API surface used by the `chronicals` crate.
//!
//! `Literal` is implemented for real (an in-memory host tensor), so code and
//! tests that only move data between host representations work unchanged.
//! Everything that would need libxla_extension — the PJRT client, buffers,
//! compiled executables — is represented by uninhabited-in-practice types
//! whose constructors return [`Error`]; `PjRtClient::cpu()` is the single
//! gate, so a `Runtime` can never be constructed on the stub and downstream
//! device paths are unreachable.
//!
//! To run real AOT artifacts, replace the `xla` path dependency in
//! `rust/Cargo.toml` with a vendored xla-rs checkout exposing this surface.

use std::fmt;

/// Error type mirroring xla-rs: carries a message, shows up via `{:?}`.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err(what: &str) -> Error {
    Error(format!(
        "{what}: this build links the host-only xla stub; vendor real xla-rs \
         bindings to execute PJRT artifacts (see DESIGN.md §4.2)"
    ))
}

/// Element types the chronicals artifacts use (plus F64 so error paths in
/// `clone_literal` are constructible in tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    F64,
    Pred,
}

/// Scalar types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn write(data: Vec<Self>, dims: Vec<i64>) -> Literal;
    fn read(lit: &Literal) -> Result<Vec<Self>>;
}

#[derive(Debug, Clone)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    F64(Vec<f64>),
    Tuple(Vec<Literal>),
}

/// A host tensor: the one piece of xla-rs this stub implements for real.
#[derive(Debug, Clone)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

macro_rules! native {
    ($t:ty, $variant:ident, $ty:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;
            fn write(data: Vec<Self>, dims: Vec<i64>) -> Literal {
                Literal { payload: Payload::$variant(data), dims }
            }
            fn read(lit: &Literal) -> Result<Vec<Self>> {
                match &lit.payload {
                    Payload::$variant(v) => Ok(v.clone()),
                    other => Err(Error(format!(
                        "literal is {:?}, not {:?}",
                        payload_ty(other),
                        $ty
                    ))),
                }
            }
        }
    };
}

native!(f32, F32, ElementType::F32);
native!(i32, I32, ElementType::S32);
native!(f64, F64, ElementType::F64);

fn payload_ty(p: &Payload) -> ElementType {
    match p {
        Payload::F32(_) => ElementType::F32,
        Payload::I32(_) => ElementType::S32,
        Payload::F64(_) => ElementType::F64,
        Payload::Tuple(_) => ElementType::Pred, // tuples have no array type
    }
}

impl Literal {
    pub fn scalar<T: NativeType>(t: T) -> Literal {
        T::write(vec![t], vec![])
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::write(data.to_vec(), vec![data.len() as i64])
    }

    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { payload: Payload::Tuple(elems), dims: vec![] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if n != have {
            return Err(Error(format!(
                "reshape to {dims:?} ({n} elements) from {have} elements"
            )));
        }
        let mut out = self.clone();
        out.dims = dims.to_vec();
        Ok(out)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(self)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::read(self)?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".into()))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.payload {
            Payload::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
            p => Ok(ArrayShape { ty: payload_ty(p), dims: self.dims.clone() }),
        }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(elems) => Ok(elems),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    pub fn size_bytes(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len() * 4,
            Payload::I32(v) => v.len() * 4,
            Payload::F64(v) => v.len() * 8,
            Payload::Tuple(elems) => elems.iter().map(Literal::size_bytes).sum(),
        }
    }

    fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::F64(v) => v.len(),
            Payload::Tuple(elems) => elems.len(),
        }
    }
}

/// Shape of an array literal: element type + dimensions.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: holds nothing; loading errors out).
pub struct HloModuleProto {
    _p: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub_err("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

/// Device-resident buffer (stub: unconstructible in practice).
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub: unconstructible in practice).
pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client. `cpu()` is the single construction gate: it always errors on
/// the stub, so no downstream device path can be reached.
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_err("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(stub_err("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(stub_err("PjRtClient::buffer_from_host_literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
        let t = Literal::tuple(vec![s, Literal::scalar(1.5f32)]);
        assert!(t.array_shape().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
    }

    #[test]
    fn device_paths_gated() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
