//! Offline workalike of the `anyhow` API subset used by the chronicals
//! workspace: [`Error`], [`Result`], [`anyhow!`], [`bail!`], [`ensure!`]
//! and the [`Context`] extension trait.
//!
//! Semantics mirror the real crate where it matters here:
//! * `Error` is cheap to build from a message or from any
//!   `std::error::Error` (whose source chain is captured);
//! * `Display` shows the outermost message, `{:#}` (alternate) shows the
//!   whole chain joined with `": "`, `Debug` shows the chain with a
//!   `Caused by:` trailer — so `error: {e:#}` CLI output reads the same;
//! * like the real crate, `Error` intentionally does **not** implement
//!   `std::error::Error`, which is what makes the blanket
//!   `From<E: std::error::Error>` impl coherent.

use std::fmt;

/// An error chain: outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

/// Construct an [`Error`] from a format string (inline captures work) or
/// any printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] when a condition is false (mirrors the
/// real crate: the bare form reports the stringified condition).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("Condition failed: `", stringify!($cond), "`")));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::from(io_err()).wrap("reading config");
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing thing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_build_errors() {
        let x = 41;
        let e = anyhow!("value was {x}");
        assert_eq!(e.to_string(), "value was 41");
        let e = anyhow!("value was {}", x + 1);
        assert_eq!(e.to_string(), "value was 42");

        fn fails() -> Result<()> {
            bail!("nope: {}", 7)
        }
        assert_eq!(fails().unwrap_err().to_string(), "nope: 7");
    }

    #[test]
    fn ensure_macro() {
        fn check(x: i32) -> Result<()> {
            ensure!(x > 0, "x must be positive, got {x}");
            ensure!(x != 3);
            Ok(())
        }
        assert!(check(1).is_ok());
        assert_eq!(
            check(-1).unwrap_err().to_string(),
            "x must be positive, got -1"
        );
        assert!(check(3).unwrap_err().to_string().contains("x != 3"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<f64> {
            Ok(s.parse::<f64>()?)
        }
        assert!(parse("1.5").is_ok());
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_on_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening: missing thing");

        let r2: Result<()> = Err(anyhow!("inner"));
        let e2 = r2.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e2:#}"), "outer 1: inner");
        assert_eq!(e2.chain().count(), 2);
    }
}
