//! Metrics: throughput meters, MFU (paper Eq. 87), and the analytic
//! memory model (paper §1, §S8, §S15) used for the paper-scale estimates
//! that the CPU substrate cannot measure directly.

pub mod memory;
pub mod throughput;

pub use memory::{MemoryBreakdown, MemoryModel, Precision};
pub use throughput::{PhaseBreakdown, ThroughputMeter};

/// Model FLOPs Utilization (paper Eq. 87):
/// `MFU = 6·N·tokens_per_sec / peak_flops`.
pub fn mfu(param_count: u64, tokens_per_sec: f64, peak_flops: f64) -> f64 {
    6.0 * param_count as f64 * tokens_per_sec / peak_flops
}

/// Training FLOPs for one step: 6·N·T (2 fwd + 4 bwd per param per token).
pub fn step_flops(param_count: u64, tokens: u64) -> f64 {
    6.0 * param_count as f64 * tokens as f64
}

/// A100 BF16 peak, the paper's reference device.
pub const A100_PEAK_FLOPS: f64 = 312e12;

/// Measured-at-runtime effective peak of this host (set per-run); used to
/// scale the paper's MFU numbers onto the CPU substrate.
pub fn mfu_paper_scale(param_count: u64, tokens_per_sec: f64) -> f64 {
    mfu(param_count, tokens_per_sec, A100_PEAK_FLOPS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mfu_matches_paper_numbers() {
        // paper §8: Chronicals 41,184 tok/s on 500M params => 39.6% MFU
        let m = mfu(500_000_000, 41_184.0, A100_PEAK_FLOPS);
        assert!((m - 0.396).abs() < 0.005, "{m}");
        // Unsloth 11,736 tok/s => 11.3%
        let u = mfu(500_000_000, 11_736.0, A100_PEAK_FLOPS);
        assert!((u - 0.113).abs() < 0.005, "{u}");
    }

    #[test]
    fn step_flops_formula() {
        assert_eq!(step_flops(1_000, 10), 60_000.0);
    }
}
