//! Analytic training-memory model (paper §1 "The Memory Bottleneck in
//! Concrete Terms", §S8 gradient checkpointing, §S15 memory breakdown).
//!
//! The CPU substrate cannot reproduce A100 VRAM numbers, so this model
//! regenerates the paper's memory tables (Table 10, the 84 GB LLaMA-7B
//! claim, the 4.97 GB logit tensor) from first principles, and is unit-
//! tested against every number the paper quotes.

/// Bytes per element by precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    Bf16,
}

impl Precision {
    pub fn bytes(self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 => 2,
        }
    }
}

#[derive(Debug, Clone)]
pub struct MemoryModel {
    pub params: u64,
    pub n_layers: u64,
    pub d_model: u64,
    pub n_heads: u64,
    pub vocab: u64,
    pub batch: u64,
    pub seq: u64,
    pub weight_prec: Precision,
    pub grad_prec: Precision,
    /// AdamW stores m and v in f32: 8 bytes/param (paper §2).
    pub optimizer_bytes_per_param: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct MemoryBreakdown {
    pub weights: u64,
    pub gradients: u64,
    pub optimizer: u64,
    pub activations: u64,
    pub attention_scores: u64,
    pub logits: u64,
    pub total: u64,
}

impl MemoryModel {
    /// Full breakdown without checkpointing or memory-efficient loss.
    pub fn naive(&self) -> MemoryBreakdown {
        let weights = self.params * self.weight_prec.bytes();
        let gradients = self.params * self.grad_prec.bytes();
        let optimizer = self.params * self.optimizer_bytes_per_param;
        let activations = self.activation_bytes(None);
        let attention_scores = self.attention_score_bytes();
        let logits = self.logit_bytes();
        MemoryBreakdown {
            weights,
            gradients,
            optimizer,
            activations,
            attention_scores,
            logits,
            total: weights + gradients + optimizer + activations + attention_scores + logits,
        }
    }

    /// Breakdown with the Chronicals stack: FlashAttention (no score
    /// matrix), Cut Cross-Entropy (V/C logit reduction) and optional
    /// gradient checkpointing every k layers.
    pub fn chronicals(&self, cce_chunk: u64, checkpoint_k: Option<u64>) -> MemoryBreakdown {
        let weights = self.params * self.weight_prec.bytes();
        let gradients = self.params * self.grad_prec.bytes();
        let optimizer = self.params * self.optimizer_bytes_per_param;
        let activations = self.activation_bytes(checkpoint_k);
        let attention_scores = 0; // FlashAttention: O(N) carries only
        let logits = self.logit_bytes() * cce_chunk / self.vocab.max(1);
        MemoryBreakdown {
            weights,
            gradients,
            optimizer,
            activations,
            attention_scores,
            logits,
            total: weights + gradients + optimizer + activations + attention_scores + logits,
        }
    }

    /// Per-layer hidden-state activations: L·B·N·d·4 bytes (paper Def. 27);
    /// with checkpointing every k layers: (L/k + k)·B·N·d·4 (paper Thm. 9).
    pub fn activation_bytes(&self, checkpoint_k: Option<u64>) -> u64 {
        let row = self.batch * self.seq * self.d_model * 4;
        match checkpoint_k {
            None => self.n_layers * row,
            Some(k) => (self.n_layers / k.max(1) + k) * row,
        }
    }

    /// Optimal checkpoint interval k* = sqrt(L) (paper Thm. 9).
    pub fn optimal_checkpoint_k(&self) -> u64 {
        (self.n_layers as f64).sqrt().round().max(1.0) as u64
    }

    /// Full [B, H, N, N] score matrix in f32 (paper Eq. 2/67).
    pub fn attention_score_bytes(&self) -> u64 {
        self.batch * self.n_heads * self.seq * self.seq * 4
    }

    /// Full [B, N, V] logit tensor in f32 (paper Def. 12).
    pub fn logit_bytes(&self) -> u64 {
        self.batch * self.seq * self.vocab * 4
    }

    /// Recompute overhead factor for checkpointing every k layers
    /// (paper Prop. 15): 1 + 1/k of forward ≈ +fwd/(fwd+bwd)·(1/k).
    pub fn checkpoint_compute_overhead(&self, k: u64) -> f64 {
        1.0 + 1.0 / (3.0 * k.max(1) as f64) * (3.0 / 5.0) * 5.0 / 3.0
    }
}

pub const GB: u64 = 1 << 30;
pub const GB_DEC: f64 = 1e9;

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper §1: LLaMA-7B full fine-tuning needs 84 GB = 14 + 14 + 56.
    #[test]
    fn llama7b_84gb_claim() {
        let m = MemoryModel {
            params: 7_000_000_000,
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            vocab: 32_000,
            batch: 1,
            seq: 2048,
            weight_prec: Precision::Bf16,
            grad_prec: Precision::Bf16,
            optimizer_bytes_per_param: 8,
        };
        let b = m.naive();
        assert_eq!(b.weights as f64 / GB_DEC, 14.0);
        assert_eq!(b.gradients as f64 / GB_DEC, 14.0);
        assert_eq!(b.optimizer as f64 / GB_DEC, 56.0);
    }

    /// Paper Def. 12: B=8, N=1024, V=151936 → 4.97 GB of logits.
    #[test]
    fn qwen_logit_tensor_497gb() {
        let m = MemoryModel {
            params: 494_000_000,
            n_layers: 24,
            d_model: 896,
            n_heads: 14,
            vocab: 151_936,
            batch: 8,
            seq: 1024,
            weight_prec: Precision::Bf16,
            grad_prec: Precision::Bf16,
            optimizer_bytes_per_param: 8,
        };
        let gb = m.logit_bytes() as f64 / GB_DEC;
        assert!((gb - 4.97).abs() < 0.03, "{gb}");
    }

    /// Paper Eq. 2: N=8192, 32 heads → 8.6 GB of attention scores.
    #[test]
    fn attention_scores_86gb() {
        let m = MemoryModel {
            params: 0,
            n_layers: 1,
            d_model: 4096,
            n_heads: 32,
            vocab: 1,
            batch: 1,
            seq: 8192,
            weight_prec: Precision::Bf16,
            grad_prec: Precision::Bf16,
            optimizer_bytes_per_param: 8,
        };
        let gb = m.attention_score_bytes() as f64 / GB_DEC;
        assert!((gb - 8.59).abs() < 0.05, "{gb}");
    }

    /// Paper Thm. 3: CCE reduction factor = V/C (37x for Qwen at C=4096).
    #[test]
    fn cce_37x_reduction() {
        let m = MemoryModel {
            params: 494_000_000,
            n_layers: 24,
            d_model: 896,
            n_heads: 14,
            vocab: 151_936,
            batch: 8,
            seq: 1024,
            weight_prec: Precision::Bf16,
            grad_prec: Precision::Bf16,
            optimizer_bytes_per_param: 8,
        };
        let naive = m.naive().logits;
        let cce = m.chronicals(4096, None).logits;
        let ratio = naive as f64 / cce as f64;
        assert!((ratio - 37.0).abs() < 0.2, "{ratio}");
    }

    /// Paper Thm. 9: optimal k* = sqrt(L); memory at k* = 2·sqrt(L)·BNd.
    #[test]
    fn checkpointing_sqrt_l() {
        let m = MemoryModel {
            params: 494_000_000,
            n_layers: 24,
            d_model: 896,
            n_heads: 14,
            vocab: 151_936,
            batch: 8,
            seq: 2048,
            weight_prec: Precision::Bf16,
            grad_prec: Precision::Bf16,
            optimizer_bytes_per_param: 8,
        };
        let k = m.optimal_checkpoint_k();
        assert_eq!(k, 5); // sqrt(24) ≈ 4.9
        let full = m.activation_bytes(None);
        let ckpt = m.activation_bytes(Some(k));
        assert!(full as f64 / ckpt as f64 > 2.0);
    }

    /// Paper §S15 Table 10: optimizer states = 3.96 GB for 494M params.
    #[test]
    fn optimizer_state_396gb() {
        let opt = 494_000_000u64 * 8;
        assert!((opt as f64 / GB_DEC - 3.95).abs() < 0.05);
    }

    #[test]
    fn chronicals_total_below_naive() {
        let m = MemoryModel {
            params: 494_000_000,
            n_layers: 24,
            d_model: 896,
            n_heads: 14,
            vocab: 151_936,
            batch: 8,
            seq: 2048,
            weight_prec: Precision::Bf16,
            grad_prec: Precision::Bf16,
            optimizer_bytes_per_param: 8,
        };
        let naive = m.naive();
        let chron = m.chronicals(4096, Some(m.optimal_checkpoint_k()));
        assert!(chron.total * 2 < naive.total, "{chron:?} vs {naive:?}");
    }
}
