//! Throughput metering with warmup exclusion — the paper's benchmark
//! methodology (§8): warmup steps excluded, tokens/sec over *real*
//! (non-padding) tokens, mean ± std over repeated windows.
//!
//! The clock seam: `step_begin`/`step_end` read `Instant` for live runs,
//! while [`ThroughputMeter::record_step`] injects an explicit duration —
//! that is what the tests use (no `thread::sleep`, no wall-clock flake)
//! and what replay tooling can feed from recorded traces.

use crate::backend::StepPhases;
use std::time::Instant;

/// Mean per-step phase breakdown in milliseconds, post-warmup (the
/// runtime-dissection view of arXiv 2311.03687: where a step's wall time
/// actually goes). `data_ms` is the residual of the measured step wall
/// time after the backend-reported compute phases — batch cycling,
/// metering, dispatch overhead.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseBreakdown {
    /// Forward-pass ms per step.
    pub fwd_ms: f64,
    /// Backward-pass ms per step (includes gradient reduction).
    pub bwd_ms: f64,
    /// Optimizer ms per step (grad-norm + AdamW).
    pub optim_ms: f64,
    /// Non-compute residual ms per step.
    pub data_ms: f64,
}

#[derive(Debug)]
pub struct ThroughputMeter {
    warmup_steps: usize,
    steps_seen: usize,
    tokens: u64,
    real_tokens: u64,
    /// per-step durations (seconds) after warmup
    step_times: Vec<f64>,
    last_step_start: Option<Instant>,
    /// post-warmup phase accumulators (seconds) + the step count that fed
    /// them — kept separate from `step_times` so phase-blind callers
    /// (older paths, backends reporting zeroed phases) never skew means
    phase_fwd_s: f64,
    phase_bwd_s: f64,
    phase_optim_s: f64,
    phase_data_s: f64,
    phase_steps: usize,
}

impl ThroughputMeter {
    pub fn new(warmup_steps: usize) -> Self {
        ThroughputMeter {
            warmup_steps,
            steps_seen: 0,
            tokens: 0,
            real_tokens: 0,
            step_times: Vec::new(),
            last_step_start: None,
            phase_fwd_s: 0.0,
            phase_bwd_s: 0.0,
            phase_optim_s: 0.0,
            phase_data_s: 0.0,
            phase_steps: 0,
        }
    }

    pub fn step_begin(&mut self) {
        self.last_step_start = Some(Instant::now());
    }

    /// Record a finished step using the live clock started by
    /// `step_begin`. `slot_tokens` = B·S, `real_tokens` excludes padding
    /// (the honest numerator for packed-vs-padded comparisons).
    pub fn step_end(&mut self, slot_tokens: u64, real_tokens: u64) {
        let dur = self
            .last_step_start
            .take()
            .map(|t0| t0.elapsed().as_secs_f64());
        self.note_step(dur, slot_tokens, real_tokens, None);
    }

    /// Like [`Self::step_end`], also folding the backend-reported phase
    /// breakdown into the post-warmup phase accounting. The data phase is
    /// derived here as the residual of the step wall time.
    pub fn step_end_phased(&mut self, slot_tokens: u64, real_tokens: u64, phases: StepPhases) {
        let dur = self
            .last_step_start
            .take()
            .map(|t0| t0.elapsed().as_secs_f64());
        self.note_step(dur, slot_tokens, real_tokens, Some(phases));
    }

    /// Record a finished step with an explicit duration — the
    /// deterministic injection point (tests, recorded traces). Identical
    /// warmup/token accounting to `step_end`.
    pub fn record_step(&mut self, seconds: f64, slot_tokens: u64, real_tokens: u64) {
        self.note_step(Some(seconds), slot_tokens, real_tokens, None);
    }

    /// [`Self::record_step`] with a phase breakdown — the deterministic
    /// injection point for the phase accounting tests.
    pub fn record_step_phased(
        &mut self,
        seconds: f64,
        slot_tokens: u64,
        real_tokens: u64,
        phases: StepPhases,
    ) {
        self.note_step(Some(seconds), slot_tokens, real_tokens, Some(phases));
    }

    fn note_step(
        &mut self,
        duration_secs: Option<f64>,
        slot_tokens: u64,
        real_tokens: u64,
        phases: Option<StepPhases>,
    ) {
        self.steps_seen += 1;
        if self.steps_seen <= self.warmup_steps {
            return;
        }
        if let Some(d) = duration_secs {
            self.step_times.push(d);
            if let Some(p) = phases {
                self.phase_fwd_s += p.fwd_s;
                self.phase_bwd_s += p.bwd_s;
                self.phase_optim_s += p.optim_s;
                // residual: wall time not attributed to a compute phase
                self.phase_data_s += (d - p.compute_s()).max(0.0);
                self.phase_steps += 1;
            }
        }
        self.tokens += slot_tokens;
        self.real_tokens += real_tokens;
    }

    /// Mean per-step phase breakdown over the post-warmup steps that
    /// reported phases; `None` when no step did (phase-blind callers).
    pub fn phase_breakdown(&self) -> Option<PhaseBreakdown> {
        if self.phase_steps == 0 {
            return None;
        }
        let n = self.phase_steps as f64;
        Some(PhaseBreakdown {
            fwd_ms: self.phase_fwd_s / n * 1e3,
            bwd_ms: self.phase_bwd_s / n * 1e3,
            optim_ms: self.phase_optim_s / n * 1e3,
            data_ms: self.phase_data_s / n * 1e3,
        })
    }

    pub fn measured_steps(&self) -> usize {
        self.step_times.len()
    }

    pub fn elapsed(&self) -> f64 {
        self.step_times.iter().sum()
    }

    /// tokens/sec over real (non-padding) tokens — the headline metric.
    pub fn tokens_per_sec(&self) -> f64 {
        let e = self.elapsed();
        if e <= 0.0 {
            0.0
        } else {
            self.real_tokens as f64 / e
        }
    }

    /// tokens/sec counting padded slots too (what a naive bench reports).
    pub fn slot_tokens_per_sec(&self) -> f64 {
        let e = self.elapsed();
        if e <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / e
        }
    }

    pub fn mean_step_ms(&self) -> f64 {
        if self.step_times.is_empty() {
            0.0
        } else {
            self.elapsed() / self.step_times.len() as f64 * 1e3
        }
    }

    pub fn std_step_ms(&self) -> f64 {
        let n = self.step_times.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.elapsed() / n as f64;
        let var = self
            .step_times
            .iter()
            .map(|t| (t - mean).powi(2))
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_excluded() {
        let mut m = ThroughputMeter::new(2);
        for _ in 0..5 {
            m.record_step(0.001, 100, 80);
        }
        assert_eq!(m.measured_steps(), 3);
        // only 3 post-warmup steps counted
        assert_eq!(m.tokens, 300);
        assert_eq!(m.real_tokens, 240);
    }

    #[test]
    fn real_vs_slot_tokens_deterministic() {
        // injected duration: exact arithmetic, no sleeping, no flake
        let mut m = ThroughputMeter::new(0);
        m.record_step(0.005, 1000, 500);
        assert_eq!(m.tokens_per_sec(), 500.0 / 0.005);
        assert_eq!(m.slot_tokens_per_sec(), 1000.0 / 0.005);
        assert!((m.slot_tokens_per_sec() / m.tokens_per_sec() - 2.0).abs() < 1e-12);
        assert_eq!(m.mean_step_ms(), 5.0);
    }

    #[test]
    fn std_over_recorded_windows() {
        let mut m = ThroughputMeter::new(0);
        m.record_step(0.004, 100, 100);
        m.record_step(0.006, 100, 100);
        assert_eq!(m.measured_steps(), 2);
        assert!((m.mean_step_ms() - 5.0).abs() < 1e-9);
        // sample std of {4ms, 6ms} = sqrt(2) ms
        assert!((m.std_step_ms() - 2.0f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn live_clock_path_still_works() {
        let mut m = ThroughputMeter::new(0);
        m.step_begin();
        m.step_end(10, 10);
        assert_eq!(m.measured_steps(), 1);
        assert!(m.elapsed() >= 0.0);
    }

    #[test]
    fn step_end_without_begin_counts_tokens_only() {
        let mut m = ThroughputMeter::new(0);
        m.step_end(10, 5);
        assert_eq!(m.measured_steps(), 0);
        assert_eq!(m.tokens, 10);
        assert_eq!(m.tokens_per_sec(), 0.0);
    }

    #[test]
    fn zero_steps_safe() {
        let m = ThroughputMeter::new(0);
        assert_eq!(m.tokens_per_sec(), 0.0);
        assert_eq!(m.mean_step_ms(), 0.0);
        assert_eq!(m.std_step_ms(), 0.0);
        assert_eq!(m.phase_breakdown(), None);
    }

    #[test]
    fn phase_breakdown_means_and_residual() {
        let mut m = ThroughputMeter::new(1);
        let p = StepPhases { fwd_s: 0.002, bwd_s: 0.004, optim_s: 0.001 };
        // warmup step must not feed the phase accounting
        m.record_step_phased(0.010, 100, 100, p);
        m.record_step_phased(0.010, 100, 100, p);
        m.record_step_phased(0.012, 100, 100, p);
        let b = m.phase_breakdown().unwrap();
        assert!((b.fwd_ms - 2.0).abs() < 1e-9);
        assert!((b.bwd_ms - 4.0).abs() < 1e-9);
        assert!((b.optim_ms - 1.0).abs() < 1e-9);
        // residual: (10 - 7) and (12 - 7) ms averaged
        assert!((b.data_ms - 4.0).abs() < 1e-9);
    }

    #[test]
    fn residual_clamps_at_zero_and_phase_blind_steps_do_not_skew() {
        let mut m = ThroughputMeter::new(0);
        // reported compute exceeds the wall duration (clock skew): clamp
        let p = StepPhases { fwd_s: 0.020, bwd_s: 0.0, optim_s: 0.0 };
        m.record_step_phased(0.010, 100, 100, p);
        // a phase-blind step contributes to throughput but not to phases
        m.record_step(0.010, 100, 100);
        let b = m.phase_breakdown().unwrap();
        assert_eq!(b.data_ms, 0.0);
        assert!((b.fwd_ms - 20.0).abs() < 1e-9);
        assert_eq!(m.measured_steps(), 2);
    }
}
