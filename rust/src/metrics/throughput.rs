//! Throughput metering with warmup exclusion — the paper's benchmark
//! methodology (§8): warmup steps excluded, tokens/sec over *real*
//! (non-padding) tokens, mean ± std over repeated windows.

use std::time::Instant;

#[derive(Debug)]
pub struct ThroughputMeter {
    warmup_steps: usize,
    steps_seen: usize,
    window_start: Option<Instant>,
    tokens: u64,
    real_tokens: u64,
    /// per-step durations (seconds) after warmup
    step_times: Vec<f64>,
    last_step_start: Option<Instant>,
}

impl ThroughputMeter {
    pub fn new(warmup_steps: usize) -> Self {
        ThroughputMeter {
            warmup_steps,
            steps_seen: 0,
            window_start: None,
            tokens: 0,
            real_tokens: 0,
            step_times: Vec::new(),
            last_step_start: None,
        }
    }

    pub fn step_begin(&mut self) {
        self.last_step_start = Some(Instant::now());
    }

    /// Record a finished step. `slot_tokens` = B·S, `real_tokens` excludes
    /// padding (the honest numerator for packed-vs-padded comparisons).
    pub fn step_end(&mut self, slot_tokens: u64, real_tokens: u64) {
        let now = Instant::now();
        self.steps_seen += 1;
        if self.steps_seen <= self.warmup_steps {
            return;
        }
        if let Some(t0) = self.last_step_start {
            self.step_times.push(now.duration_since(t0).as_secs_f64());
        }
        if self.window_start.is_none() {
            self.window_start = Some(now);
        }
        self.tokens += slot_tokens;
        self.real_tokens += real_tokens;
    }

    pub fn measured_steps(&self) -> usize {
        self.step_times.len()
    }

    pub fn elapsed(&self) -> f64 {
        self.step_times.iter().sum()
    }

    /// tokens/sec over real (non-padding) tokens — the headline metric.
    pub fn tokens_per_sec(&self) -> f64 {
        let e = self.elapsed();
        if e <= 0.0 {
            0.0
        } else {
            self.real_tokens as f64 / e
        }
    }

    /// tokens/sec counting padded slots too (what a naive bench reports).
    pub fn slot_tokens_per_sec(&self) -> f64 {
        let e = self.elapsed();
        if e <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / e
        }
    }

    pub fn mean_step_ms(&self) -> f64 {
        if self.step_times.is_empty() {
            0.0
        } else {
            self.elapsed() / self.step_times.len() as f64 * 1e3
        }
    }

    pub fn std_step_ms(&self) -> f64 {
        let n = self.step_times.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.elapsed() / n as f64;
        let var = self
            .step_times
            .iter()
            .map(|t| (t - mean).powi(2))
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_excluded() {
        let mut m = ThroughputMeter::new(2);
        for _ in 0..5 {
            m.step_begin();
            m.step_end(100, 80);
        }
        assert_eq!(m.measured_steps(), 3);
        // only 3 post-warmup steps counted
        assert_eq!(m.tokens, 300);
        assert_eq!(m.real_tokens, 240);
    }

    #[test]
    fn real_vs_slot_tokens() {
        let mut m = ThroughputMeter::new(0);
        m.step_begin();
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.step_end(1000, 500);
        assert!(m.tokens_per_sec() > 0.0);
        assert!((m.slot_tokens_per_sec() / m.tokens_per_sec() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_steps_safe() {
        let m = ThroughputMeter::new(0);
        assert_eq!(m.tokens_per_sec(), 0.0);
        assert_eq!(m.mean_step_ms(), 0.0);
        assert_eq!(m.std_step_ms(), 0.0);
    }
}
