//! Real, file-backed training data: JSONL corpora and the streaming
//! byte-level tokenizer (DESIGN.md §8).
//!
//! The synthetic corpus under [`crate::data`] exists to reproduce the
//! paper's *length distribution*; this module is the path that trains on
//! actual instruction data:
//!
//! * [`Tokenizer`] — the trait every text tokenizer implements. The
//!   default implementation is [`ByteBpe`], a deterministic byte-level
//!   mini-BPE with a seeded, corpus-learnable pair-merge vocabulary that
//!   is capped to the model's vocab and serializable to a plain-text
//!   vocab file (reproducible runs). The word-level
//!   [`crate::data::Tokenizer`] implements the trait too.
//! * [`JsonlSource`] — a file-backed [`crate::session::ExampleSource`]
//!   that streams an instruction-tuning JSONL file line by line
//!   (buffered reads, tokenize-as-you-go — the corpus never exists as a
//!   resident `Vec<String>`), reporting per-line errors as `file:line`
//!   and accounting for malformed / truncated records in
//!   [`SourceStats`] instead of dropping data silently.
//!
//! ```
//! use chronicals::data_source::{ByteBpe, Tokenizer};
//!
//! // Learn a 32-id vocabulary from a two-line corpus (seeded, deterministic).
//! let tok = ByteBpe::learn(["pack the tokens", "pack the bins"], 32, 7);
//! let ids = tok.encode("pack the bins");
//! assert!(ids.len() >= 3); // BOS + pieces + EOS
//! assert_eq!(tok.decode(&ids), "<bos>pack the bins<eos>");
//! # Ok::<(), anyhow::Error>(())
//! ```

#![warn(missing_docs)]

pub mod bpe;
pub mod chat;
pub mod jsonl;

pub use bpe::{BpeLearner, ByteBpe};
pub use chat::{tokenize_chat, ChatSource, ChatTurn, Role};
pub use jsonl::JsonlSource;

use crate::data::TokenizedExample;
use anyhow::{bail, Result};

/// Which token positions contribute to the loss (HyperSloth's
/// `--loss_type` knob). Lowered into tokenization-time target masking via
/// the `targets: -1` convention, so every backend honors it for free.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LossMode {
    /// Supervise every next-token position: prompts, system and user turns
    /// included (HyperSloth `--loss_type all`).
    Full,
    /// Supervise only response tokens — pair completions and assistant
    /// turns; everything else is loss-masked. The default, and bitwise
    /// identical to the historical pair-masking behavior.
    #[default]
    ResponseOnly,
}

impl LossMode {
    /// Parse a CLI/config name.
    pub fn parse(name: &str) -> Result<LossMode> {
        Ok(match name {
            "full" => LossMode::Full,
            "response-only" | "response_only" | "target-only" | "target_only" => {
                LossMode::ResponseOnly
            }
            other => bail!("unknown loss mode '{other}' (expected full | response-only)"),
        })
    }

    /// The canonical CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            LossMode::Full => "full",
            LossMode::ResponseOnly => "response-only",
        }
    }
}

/// A deterministic text tokenizer: text in, model-ready token ids out.
///
/// The contract mirrors the word tokenizer the synthetic pipeline uses:
/// `encode` frames the ids with `<bos>` … `<eos>`, every id is
/// `< vocab_size()`, and the same text always produces the same ids (runs
/// must be reproducible — see DESIGN.md §8).
pub trait Tokenizer {
    /// Encode text to token ids with `<bos>` / `<eos>` framing.
    fn encode(&self, text: &str) -> Vec<i32>;
    /// Best-effort inverse of [`Tokenizer::encode`]; ids outside the
    /// vocabulary (for example `-1` target masks) are skipped.
    fn decode(&self, ids: &[i32]) -> String;
    /// Number of distinct ids this tokenizer can emit (≤ the model vocab).
    fn vocab_size(&self) -> usize;
}

/// The word-level frequency tokenizer behind the synthetic corpus also
/// speaks the trait, so sources can swap tokenizers without caring which
/// family they got.
impl Tokenizer for crate::data::Tokenizer {
    fn encode(&self, text: &str) -> Vec<i32> {
        // inherent methods take precedence: this calls data::Tokenizer::encode
        self.encode(text)
    }
    fn decode(&self, ids: &[i32]) -> String {
        self.decode(ids)
    }
    fn vocab_size(&self) -> usize {
        self.vocab_size()
    }
}

/// Accounting for what a data source did to its records — folded into
/// [`crate::session::RunReport`] so nothing is dropped without a trace.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SourceStats {
    /// Records skipped because the line was not valid JSON or did not match
    /// the expected schema.
    pub malformed: usize,
    /// Records truncated to the source's `max_seq` token cap.
    pub truncated: usize,
    /// First few per-record diagnostics, each prefixed `file:line:`.
    pub notes: Vec<String>,
}

/// Tokenize an instruction pair: under [`LossMode::ResponseOnly`] the
/// prompt tokens are loss-masked and the completion supervised (the recipe
/// [`crate::data::tokenize_corpus`] uses); under [`LossMode::Full`] every
/// next-token position is supervised. Returns the example and whether it
/// was truncated to `max_len` tokens.
///
/// ```
/// use chronicals::data_source::{tokenize_pair, ByteBpe, LossMode};
///
/// let tok = ByteBpe::learn(["add two numbers", "four"], 40, 1);
/// let (ex, truncated) =
///     tokenize_pair(&tok, "add two numbers", "four", 64, LossMode::ResponseOnly);
/// assert!(!truncated);
/// // prompt interior is masked, completion is supervised
/// assert_eq!(ex.targets[0], -1);
/// assert!(ex.real_targets() > 0);
///
/// // Full mode supervises the prompt too
/// let (full, _) = tokenize_pair(&tok, "add two numbers", "four", 64, LossMode::Full);
/// assert_eq!(full.targets[0], full.tokens[1]);
/// assert!(full.real_targets() > ex.real_targets());
/// ```
pub fn tokenize_pair(
    tok: &dyn Tokenizer,
    prompt: &str,
    completion: &str,
    max_len: usize,
    mode: LossMode,
) -> (TokenizedExample, bool) {
    let mut tokens = tok.encode(prompt);
    let prompt_len = tokens.len();
    tokens.extend(tok.encode(completion));
    let truncated = tokens.len() > max_len;
    tokens.truncate(max_len);
    let mut targets = vec![-1i32; tokens.len()];
    let start = match mode {
        LossMode::Full => 0,
        LossMode::ResponseOnly => prompt_len.saturating_sub(1),
    };
    for i in start..tokens.len().saturating_sub(1) {
        targets[i] = tokens[i + 1];
    }
    (TokenizedExample { tokens, targets }, truncated)
}

/// Tokenize plain text (the `{"text": …}` JSONL fallback): every
/// next-token position is supervised, the final position is masked.
/// Returns the example and whether it was truncated to `max_len` tokens.
pub fn tokenize_text(
    tok: &dyn Tokenizer,
    text: &str,
    max_len: usize,
) -> (TokenizedExample, bool) {
    let mut tokens = tok.encode(text);
    let truncated = tokens.len() > max_len;
    tokens.truncate(max_len);
    let mut targets = vec![-1i32; tokens.len()];
    for i in 0..tokens.len().saturating_sub(1) {
        targets[i] = tokens[i + 1];
    }
    (TokenizedExample { tokens, targets }, truncated)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_tokenizer_speaks_the_trait() {
        let t = crate::data::Tokenizer::from_texts(["the cat sat".to_string()], 16);
        let dynamic: &dyn Tokenizer = &t;
        assert_eq!(dynamic.encode("the cat"), t.encode("the cat"));
        assert_eq!(dynamic.vocab_size(), t.vocab_size());
        assert_eq!(dynamic.decode(&t.encode("cat")), "<bos> cat <eos>");
    }

    #[test]
    fn loss_mode_parses_and_defaults() {
        assert_eq!(LossMode::parse("full").unwrap(), LossMode::Full);
        assert_eq!(LossMode::parse("response-only").unwrap(), LossMode::ResponseOnly);
        assert_eq!(LossMode::parse("target_only").unwrap(), LossMode::ResponseOnly);
        assert!(LossMode::parse("half").is_err());
        assert_eq!(LossMode::default(), LossMode::ResponseOnly);
        assert_eq!(LossMode::Full.name(), "full");
    }

    #[test]
    fn pair_masks_prompt_and_supervises_completion() {
        let tok = ByteBpe::learn(["ab cd", "ef"], 32, 0);
        let (ex, truncated) = tokenize_pair(&tok, "ab cd", "ef", 128, LossMode::ResponseOnly);
        assert!(!truncated);
        let prompt_len = tok.encode("ab cd").len();
        for i in 0..prompt_len - 1 {
            assert_eq!(ex.targets[i], -1, "prompt pos {i} must be masked");
        }
        for i in prompt_len - 1..ex.tokens.len() - 1 {
            assert_eq!(ex.targets[i], ex.tokens[i + 1], "pos {i}");
        }
        assert_eq!(*ex.targets.last().unwrap(), -1);
    }

    #[test]
    fn full_mode_supervises_the_prompt_too() {
        let tok = ByteBpe::learn(["ab cd", "ef"], 32, 0);
        let (ex, _) = tokenize_pair(&tok, "ab cd", "ef", 128, LossMode::Full);
        for i in 0..ex.tokens.len() - 1 {
            assert_eq!(ex.targets[i], ex.tokens[i + 1], "pos {i}");
        }
        assert_eq!(*ex.targets.last().unwrap(), -1);
    }

    #[test]
    fn text_supervises_everything_but_last() {
        let tok = ByteBpe::learn(["ab cd"], 32, 0);
        let (ex, _) = tokenize_text(&tok, "ab cd", 128);
        for i in 0..ex.tokens.len() - 1 {
            assert_eq!(ex.targets[i], ex.tokens[i + 1], "pos {i}");
        }
        assert_eq!(*ex.targets.last().unwrap(), -1);
    }

    #[test]
    fn truncation_reported_and_boundary_masked() {
        let tok = ByteBpe::learn(["abcdefgh"], 32, 0);
        let (ex, truncated) = tokenize_text(&tok, "abcdefgh", 4);
        assert!(truncated);
        assert_eq!(ex.tokens.len(), 4);
        assert_eq!(ex.targets.len(), 4);
        // last kept position must not predict a token we dropped
        assert_eq!(ex.targets[3], -1);
    }
}
