//! Chat-transcript JSONL corpora: multi-turn conversations with role
//! framing and per-turn loss masks (DESIGN.md §9).
//!
//! One JSON object per line, the conversational schema used by chat
//! fine-tuning stacks:
//!
//! ```text
//! {"messages": [{"role": "user", "content": "explain packing ."},
//!               {"role": "assistant", "content": "bins share rows ."}]}
//! ```
//!
//! Each turn is tokenized with `role: content` framing and its own
//! `<bos>`/`<eos>` envelope; under [`LossMode::ResponseOnly`] (the
//! default) only assistant turns are supervised — system and user tokens
//! are loss-masked via the `targets: -1` convention, so both CPU backends
//! honor the mask with no kernel changes. [`ChatSource`] streams plain
//! `.jsonl` and gzip-compressed `.jsonl.gz` files through the same
//! machinery as [`super::JsonlSource`] (file:line diagnostics, malformed
//! counting, truncation accounting).
//!
//! ```
//! use chronicals::data_source::ChatSource;
//! use chronicals::session::ExampleSource;
//!
//! let path = std::env::temp_dir().join("chronicals_doc_chat.jsonl");
//! std::fs::write(
//!     &path,
//!     "{\"messages\": [{\"role\": \"user\", \"content\": \"add two and two .\"}, \
//!       {\"role\": \"assistant\", \"content\": \"four\"}]}\n",
//! )?;
//! let src = ChatSource::new(&path, 7, 64);
//! let examples = src.examples(64)?;
//! assert_eq!(examples.len(), 1);
//! // the user turn is loss-masked, the assistant turn supervised
//! assert_eq!(examples[0].targets[0], -1);
//! assert!(examples[0].real_targets() > 0);
//! # std::fs::remove_file(&path).ok();
//! # Ok::<(), anyhow::Error>(())
//! ```

use super::jsonl::JsonlSource;
use super::{LossMode, SourceStats, Tokenizer};
use crate::data::TokenizedExample;
use crate::session::ExampleSource;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};

/// Who is speaking in a chat turn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Instructions framing the conversation; never supervised under
    /// [`LossMode::ResponseOnly`].
    System,
    /// The human side of the conversation.
    User,
    /// The model side — the only role supervised under
    /// [`LossMode::ResponseOnly`].
    Assistant,
}

impl Role {
    /// Parse a schema role name.
    pub fn parse(name: &str) -> Result<Role> {
        Ok(match name {
            "system" => Role::System,
            "user" => Role::User,
            "assistant" => Role::Assistant,
            other => bail!("unknown role \"{other}\" (expected system | user | assistant)"),
        })
    }

    /// The canonical schema name.
    pub fn name(&self) -> &'static str {
        match self {
            Role::System => "system",
            Role::User => "user",
            Role::Assistant => "assistant",
        }
    }
}

/// One `{"role": …, "content": …}` message of a transcript.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChatTurn {
    /// Who is speaking.
    pub role: Role,
    /// What they said.
    pub content: String,
}

impl ChatTurn {
    /// The exact text this turn tokenizes as: `role: content`. Exposed so
    /// tokenizer learning feeds the same strings encoding will see (the
    /// role prefix and `:` must be in the learned alphabet).
    pub fn framed(&self) -> String {
        format!("{}: {}", self.role.name(), self.content)
    }
}

/// Parse the value of a `"messages"` key into turns; errors name the
/// offending turn index.
pub fn parse_messages(v: &Json) -> Result<Vec<ChatTurn>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("\"messages\" must be an array"))?;
    if arr.is_empty() {
        bail!("\"messages\" is empty");
    }
    let mut turns = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let obj = item
            .as_obj()
            .ok_or_else(|| anyhow!("messages[{i}] is not an object"))?;
        let role = obj
            .get("role")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("messages[{i}] has no string \"role\""))?;
        let content = obj
            .get("content")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("messages[{i}] has no string \"content\""))?;
        turns.push(ChatTurn {
            role: Role::parse(role).map_err(|e| anyhow!("messages[{i}]: {e}"))?,
            content: content.to_string(),
        });
    }
    Ok(turns)
}

/// Tokenize a transcript: each turn is encoded as its [`ChatTurn::framed`]
/// text (with `<bos>`/`<eos>` framing per turn, like the pair recipe), and
/// the per-turn target masks follow `mode` — [`LossMode::ResponseOnly`]
/// supervises exactly the positions that predict assistant-turn tokens,
/// [`LossMode::Full`] supervises every next-token position. Returns the
/// example and whether it was truncated to `max_len` tokens (truncation
/// re-masks the dangling boundary, so no position predicts a dropped
/// token).
pub fn tokenize_chat(
    tok: &dyn Tokenizer,
    turns: &[ChatTurn],
    max_len: usize,
    mode: LossMode,
) -> (TokenizedExample, bool) {
    let mut tokens: Vec<i32> = Vec::new();
    // (start, end) token span of each turn, plus whether it is supervised
    let mut spans: Vec<(usize, usize, bool)> = Vec::with_capacity(turns.len());
    for turn in turns {
        let start = tokens.len();
        tokens.extend(tok.encode(&turn.framed()));
        let supervised = match mode {
            LossMode::Full => true,
            LossMode::ResponseOnly => turn.role == Role::Assistant,
        };
        spans.push((start, tokens.len(), supervised));
    }
    let truncated = tokens.len() > max_len;
    tokens.truncate(max_len);
    let mut targets = vec![-1i32; tokens.len()];
    for (start, end, supervised) in spans {
        if !supervised {
            continue;
        }
        // supervise predictions OF tokens[start..end]: positions
        // start-1 ..= end-2, clamped for the first turn and truncation
        let lo = start.saturating_sub(1);
        let hi = end.min(tokens.len()).saturating_sub(1);
        for i in lo..hi {
            targets[i] = tokens[i + 1];
        }
    }
    (TokenizedExample { tokens, targets }, truncated)
}

/// A file-backed [`ExampleSource`] for chat-transcript corpora: exactly
/// [`super::JsonlSource`]'s streaming, diagnostics and tokenizer handling,
/// but every record must be a `{"messages": …}` transcript — pair/text
/// records are counted as malformed, so a mis-pointed corpus is loud.
pub struct ChatSource {
    inner: JsonlSource,
}

impl ChatSource {
    /// Describe a chat corpus (`.jsonl` or `.jsonl.gz`). Nothing is read
    /// until [`ExampleSource::examples`] is called.
    pub fn new(path: impl Into<PathBuf>, seed: u64, max_seq: usize) -> ChatSource {
        ChatSource { inner: JsonlSource::new(path, seed, max_seq).chat_only() }
    }

    /// Persist the tokenizer vocab (see [`JsonlSource::with_vocab_file`]).
    pub fn with_vocab_file(mut self, path: impl Into<PathBuf>) -> ChatSource {
        self.inner = self.inner.with_vocab_file(path);
        self
    }

    /// Select which turns are supervised (default
    /// [`LossMode::ResponseOnly`]).
    pub fn with_loss_mode(mut self, mode: LossMode) -> ChatSource {
        self.inner = self.inner.with_loss_mode(mode);
        self
    }

    /// The corpus path this source reads.
    pub fn path(&self) -> &Path {
        self.inner.path()
    }
}

impl ExampleSource for ChatSource {
    fn label(&self) -> String {
        format!("chat({})", self.inner.path().display())
    }

    fn examples(&self, vocab_cap: usize) -> Result<Vec<TokenizedExample>> {
        self.inner.examples(vocab_cap)
    }

    fn stats(&self) -> SourceStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data_source::ByteBpe;

    fn turns(list: &[(&str, &str)]) -> Vec<ChatTurn> {
        list.iter()
            .map(|(r, c)| ChatTurn { role: Role::parse(r).unwrap(), content: (*c).to_string() })
            .collect()
    }

    fn learn_for(turns: &[ChatTurn]) -> ByteBpe {
        let framed: Vec<String> = turns.iter().map(ChatTurn::framed).collect();
        ByteBpe::learn(framed.iter().map(String::as_str), 96, 7)
    }

    #[test]
    fn roles_parse_and_reject() {
        assert_eq!(Role::parse("assistant").unwrap(), Role::Assistant);
        assert!(Role::parse("robot").is_err());
        assert_eq!(Role::User.name(), "user");
    }

    #[test]
    fn messages_schema_errors_name_the_turn() {
        let bad = Json::parse(r#"[{"role": "user"}]"#).unwrap();
        let err = parse_messages(&bad).unwrap_err().to_string();
        assert!(err.contains("messages[0]"), "{err}");

        let bad = Json::parse(r#"[{"role": "u", "content": "x"}]"#).unwrap();
        let err = parse_messages(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown role"), "{err}");

        let bad = Json::parse("[]").unwrap();
        assert!(parse_messages(&bad).unwrap_err().to_string().contains("empty"));

        let bad = Json::parse("\"hi\"").unwrap();
        assert!(parse_messages(&bad).unwrap_err().to_string().contains("array"));
    }

    #[test]
    fn response_only_masks_every_non_assistant_token() {
        let ts = turns(&[
            ("system", "be terse ."),
            ("user", "explain packing ."),
            ("assistant", "bins share rows ."),
            ("user", "and masks ?"),
            ("assistant", "targets mark masks ."),
        ]);
        let tok = learn_for(&ts);
        let (ex, truncated) = tokenize_chat(&tok, &ts, 4096, LossMode::ResponseOnly);
        assert!(!truncated);

        // recompute the assistant spans exactly as the tokenizer framed them
        let mut pos = 0usize;
        let mut supervised = vec![false; ex.tokens.len()];
        for t in &ts {
            let n = tok.encode(&t.framed()).len();
            if t.role == Role::Assistant {
                let lo = pos.saturating_sub(1);
                for s in supervised.iter_mut().take(pos + n - 1).skip(lo) {
                    *s = true;
                }
            }
            pos += n;
        }
        for (i, &sup) in supervised.iter().enumerate() {
            if sup {
                assert_eq!(ex.targets[i], ex.tokens[i + 1], "pos {i} must be supervised");
            } else {
                assert_eq!(ex.targets[i], -1, "pos {i} must be masked");
            }
        }
        // both assistant turns contribute
        assert!(ex.real_targets() > tok.encode(&ts[2].framed()).len() - 1);
    }

    #[test]
    fn full_mode_supervises_all_roles() {
        let ts = turns(&[("user", "a b c"), ("assistant", "d e")]);
        let tok = learn_for(&ts);
        let (ex, _) = tokenize_chat(&tok, &ts, 4096, LossMode::Full);
        for i in 0..ex.tokens.len() - 1 {
            assert_eq!(ex.targets[i], ex.tokens[i + 1], "pos {i}");
        }
        assert_eq!(*ex.targets.last().unwrap(), -1);
    }

    #[test]
    fn truncation_masks_the_boundary() {
        let ts = turns(&[("user", "q q q q"), ("assistant", "a a a a a a a a")]);
        let tok = learn_for(&ts);
        let full_len = ts.iter().map(|t| tok.encode(&t.framed()).len()).sum::<usize>();
        let cap = full_len - 3;
        let (ex, truncated) = tokenize_chat(&tok, &ts, cap, LossMode::ResponseOnly);
        assert!(truncated);
        assert_eq!(ex.tokens.len(), cap);
        assert_eq!(*ex.targets.last().unwrap(), -1, "boundary must not predict dropped tokens");
        assert!(ex.real_targets() > 0);
    }

    #[test]
    fn transcript_with_no_assistant_turn_is_fully_masked() {
        let ts = turns(&[("user", "anyone here ?")]);
        let tok = learn_for(&ts);
        let (ex, _) = tokenize_chat(&tok, &ts, 4096, LossMode::ResponseOnly);
        assert_eq!(ex.real_targets(), 0, "no assistant turn ⇒ nothing supervised");
        // …but Full mode still supervises it
        let (full, _) = tokenize_chat(&tok, &ts, 4096, LossMode::Full);
        assert!(full.real_targets() > 0);
    }

    #[test]
    fn chat_source_rejects_non_chat_records() {
        let path = std::env::temp_dir().join("chronicals_chat_strict.jsonl");
        std::fs::write(
            &path,
            concat!(
                "{\"messages\": [{\"role\": \"user\", \"content\": \"hi .\"}, \
                 {\"role\": \"assistant\", \"content\": \"hello .\"}]}\n",
                "{\"prompt\": \"a\", \"completion\": \"b\"}\n",
            ),
        )
        .unwrap();
        let src = ChatSource::new(&path, 7, 64);
        let exs = src.examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(exs.len(), 1);
        let stats = src.stats();
        assert_eq!(stats.malformed, 1, "pair record must be malformed in chat-only mode");
        assert!(stats.notes[0].contains("messages"), "{:?}", stats.notes);
        assert!(src.label().starts_with("chat("));
    }
}
