//! File-backed JSONL instruction data: the real-corpus `ExampleSource`.
//!
//! A corpus file holds one JSON object per line, either an instruction
//! pair or plain text:
//!
//! ```text
//! {"prompt": "explain sequence packing .", "completion": "bfd places each sequence ..."}
//! {"text": "padding wastes compute on positions that contribute nothing"}
//! ```
//!
//! [`JsonlSource`] streams the file with buffered line-at-a-time reads and
//! tokenizes each record as the line is consumed — no corpus-wide string,
//! no eager tokenization pass (ChunkFT's byte-streamed ethos). Parsing
//! uses the crate's hermetic [`crate::util::json`] parser — no serde, no
//! external dependencies.
//!
//! Error policy (DESIGN.md §8):
//! * I/O failures and unreadable files are hard errors naming the path,
//! * a line that is not valid JSON or does not match the schema is
//!   **skipped and counted** ([`SourceStats::malformed`]) with a
//!   `file:line:` diagnostic retained, so a half-corrupt corpus still
//!   trains — loudly;
//! * a file yielding zero usable examples is a hard error carrying the
//!   first per-line diagnostic.
//!
//! ```
//! use chronicals::data_source::JsonlSource;
//! use chronicals::session::ExampleSource;
//!
//! let path = std::env::temp_dir().join("chronicals_doc_corpus.jsonl");
//! std::fs::write(
//!     &path,
//!     "{\"prompt\": \"add two and two .\", \"completion\": \"four\"}\n\
//!      {\"text\": \"padding wastes compute\"}\n",
//! )?;
//! let src = JsonlSource::new(&path, 7, 64);
//! let examples = src.examples(64)?; // vocab-capped to the model
//! assert_eq!(examples.len(), 2);
//! assert_eq!(src.stats().malformed, 0);
//! # std::fs::remove_file(&path).ok();
//! # Ok::<(), anyhow::Error>(())
//! ```

use super::bpe::{BpeLearner, ByteBpe};
use super::{tokenize_pair, tokenize_text, SourceStats, Tokenizer};
use crate::data::TokenizedExample;
use crate::session::ExampleSource;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};

/// Retain at most this many per-line diagnostics in [`SourceStats::notes`].
const MAX_NOTES: usize = 8;

/// One parsed JSONL record.
enum Record {
    /// `{"prompt": …, "completion": …}` — prompt loss-masked, completion
    /// supervised.
    Pair { prompt: String, completion: String },
    /// `{"text": …}` — every next-token position supervised.
    Text(String),
}

/// Parse one line into a [`Record`]; schema errors name the offending key.
fn parse_record(line: &str) -> Result<Record> {
    let v = Json::parse(line)?;
    let obj = v.as_obj().ok_or_else(|| anyhow!("expected a JSON object"))?;
    let str_field = |key: &str, j: &Json| -> Result<String> {
        j.as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow!("\"{key}\" must be a string"))
    };
    match (obj.get("prompt"), obj.get("completion"), obj.get("text")) {
        (Some(p), Some(c), _) => Ok(Record::Pair {
            prompt: str_field("prompt", p)?,
            completion: str_field("completion", c)?,
        }),
        (Some(_), None, _) => bail!("\"prompt\" without \"completion\""),
        (None, Some(_), _) => bail!("\"completion\" without \"prompt\""),
        (None, None, Some(t)) => Ok(Record::Text(str_field("text", t)?)),
        (None, None, None) => {
            bail!("expected {{\"prompt\", \"completion\"}} or {{\"text\"}} keys")
        }
    }
}

/// A file-backed [`ExampleSource`] streaming an instruction-tuning JSONL
/// corpus through the byte-level mini-BPE tokenizer (see the module docs
/// for the schema and error policy).
pub struct JsonlSource {
    path: PathBuf,
    vocab_file: Option<PathBuf>,
    seed: u64,
    max_seq: usize,
    stats: RefCell<SourceStats>,
}

impl JsonlSource {
    /// Describe a JSONL corpus. Nothing is read until
    /// [`ExampleSource::examples`] is called. `seed` drives tokenizer
    /// learning (merge tie-breaks); `max_seq` caps tokens per example
    /// (longer records are truncated and counted).
    pub fn new(path: impl Into<PathBuf>, seed: u64, max_seq: usize) -> JsonlSource {
        JsonlSource {
            path: path.into(),
            vocab_file: None,
            seed,
            max_seq,
            stats: RefCell::new(SourceStats::default()),
        }
    }

    /// Persist the tokenizer: load the vocab file when it exists, else
    /// learn from the corpus and write it there — so a second run (or
    /// another machine) tokenizes identically without re-learning.
    pub fn with_vocab_file(mut self, path: impl Into<PathBuf>) -> JsonlSource {
        self.vocab_file = Some(path.into());
        self
    }

    /// The corpus path this source reads.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Stream the file once, calling `f` per well-formed record. Malformed
    /// lines are skipped and counted into the returned stats with
    /// `file:line:` diagnostics; I/O failures are hard errors.
    fn for_each_record(&self, mut f: impl FnMut(Record)) -> Result<SourceStats> {
        let file = File::open(&self.path)
            .with_context(|| format!("opening data file {}", self.path.display()))?;
        let reader = BufReader::new(file);
        let mut stats = SourceStats::default();
        for (i, line) in reader.lines().enumerate() {
            let lineno = i + 1;
            let line = line
                .with_context(|| format!("reading {}:{}", self.path.display(), lineno))?;
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            match parse_record(trimmed) {
                Ok(r) => f(r),
                Err(e) => {
                    stats.malformed += 1;
                    if stats.notes.len() < MAX_NOTES {
                        stats
                            .notes
                            .push(format!("{}:{}: {e:#}", self.path.display(), lineno));
                    }
                }
            }
        }
        Ok(stats)
    }

    /// Load or learn the tokenizer for this corpus under the model's
    /// vocab cap.
    fn resolve_tokenizer(&self, vocab_cap: usize) -> Result<ByteBpe> {
        if let Some(vf) = &self.vocab_file {
            if vf.exists() {
                let tok = ByteBpe::load(vf)?;
                if tok.vocab_size() > vocab_cap {
                    bail!(
                        "vocab file {} holds {} ids but the model vocab is {vocab_cap} — \
                         re-learn it (delete the file) or pick a smaller vocab",
                        vf.display(),
                        tok.vocab_size()
                    );
                }
                return Ok(tok);
            }
        }
        if vocab_cap <= 8 {
            bail!("model vocab {vocab_cap} is too small for the byte-level tokenizer");
        }
        let mut learner = BpeLearner::new();
        // pass-1 accounting is discarded; pass 2 records the real stats
        self.for_each_record(|r| match r {
            Record::Pair { prompt, completion } => {
                learner.feed(&prompt);
                learner.feed(&completion);
            }
            Record::Text(t) => learner.feed(&t),
        })?;
        let tok = learner.finish(vocab_cap, self.seed);
        if let Some(vf) = &self.vocab_file {
            tok.save(vf)?;
        }
        Ok(tok)
    }
}

impl ExampleSource for JsonlSource {
    fn label(&self) -> String {
        format!("jsonl({})", self.path.display())
    }

    fn examples(&self, vocab_cap: usize) -> Result<Vec<TokenizedExample>> {
        let tok = self.resolve_tokenizer(vocab_cap)?;
        let mut out = Vec::new();
        let mut truncated = 0usize;
        let mut stats = self.for_each_record(|r| {
            let (ex, was_truncated) = match r {
                Record::Pair { prompt, completion } => {
                    tokenize_pair(&tok, &prompt, &completion, self.max_seq)
                }
                Record::Text(t) => tokenize_text(&tok, &t, self.max_seq),
            };
            if was_truncated {
                truncated += 1;
            }
            // a record whose prompt alone fills max_seq ends up fully
            // loss-masked — it would occupy batch slots while contributing
            // nothing to the loss, so it is skipped (counted above)
            if !ex.is_empty() && ex.real_targets() > 0 {
                out.push(ex);
            }
        })?;
        stats.truncated = truncated;
        if out.is_empty() {
            match stats.notes.first() {
                Some(n) => bail!(
                    "no usable examples in {} ({} malformed records; first: {n})",
                    self.path.display(),
                    stats.malformed
                ),
                None => bail!("no examples in {}", self.path.display()),
            }
        }
        *self.stats.borrow_mut() = stats;
        Ok(out)
    }

    fn stats(&self) -> SourceStats {
        self.stats.borrow().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(name: &str, content: &str) -> PathBuf {
        let path = std::env::temp_dir().join(name);
        std::fs::write(&path, content).unwrap();
        path
    }

    const GOOD: &str = concat!(
        "{\"prompt\": \"explain packing .\", \"completion\": \"bins hold sequences\"}\n",
        "\n",
        "{\"text\": \"padding wastes compute on empty positions\"}\n",
        "{\"prompt\": \"count to three .\", \"completion\": \"one two three\"}\n",
    );

    #[test]
    fn streams_both_schemas() {
        let path = write_tmp("chronicals_jsonl_good.jsonl", GOOD);
        let src = JsonlSource::new(&path, 7, 64);
        let exs = src.examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(exs.len(), 3, "blank lines are skipped, both schemas parse");
        let stats = src.stats();
        assert_eq!(stats.malformed, 0);
        assert_eq!(stats.truncated, 0);
        // the pair records mask their prompt, the text record supervises all
        assert!(exs[0].real_targets() < exs[0].len() - 1);
        assert_eq!(exs[1].real_targets(), exs[1].len() - 1);
        // every id respects the vocab cap
        for ex in &exs {
            for &t in &ex.tokens {
                assert!((0..64).contains(&t), "token {t} out of range");
            }
        }
    }

    #[test]
    fn malformed_lines_are_counted_with_file_line() {
        let content = concat!(
            "{\"prompt\": \"a b\", \"completion\": \"c d\"}\n",
            "{not json at all\n",
            "{\"instruction\": \"wrong schema\"}\n",
            "{\"prompt\": \"only half\"}\n",
            "{\"text\": 42}\n",
            "{\"text\": \"still fine\"}\n",
        );
        let path = write_tmp("chronicals_jsonl_bad.jsonl", content);
        let src = JsonlSource::new(&path, 7, 64);
        let exs = src.examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(exs.len(), 2);
        let stats = src.stats();
        assert_eq!(stats.malformed, 4);
        assert_eq!(stats.notes.len(), 4);
        assert!(stats.notes[0].contains(":2:"), "{:?}", stats.notes);
        assert!(stats.notes[1].contains(":3:"), "{:?}", stats.notes);
        assert!(stats.notes[2].contains("completion"), "{:?}", stats.notes);
        assert!(stats.notes[3].contains(":5:"), "{:?}", stats.notes);
    }

    #[test]
    fn all_malformed_is_a_hard_error_naming_the_first_line() {
        let path = write_tmp("chronicals_jsonl_allbad.jsonl", "nope\nalso nope\n");
        let err = JsonlSource::new(&path, 7, 64).examples(64).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("no usable examples"), "{err}");
        assert!(err.contains(":1:"), "{err}");
    }

    #[test]
    fn missing_file_is_a_hard_error() {
        let err = JsonlSource::new("/definitely/not/here.jsonl", 7, 64)
            .examples(64)
            .map(|_| ())
            .unwrap_err();
        assert!(format!("{err:#}").contains("not/here.jsonl"), "{err:#}");
    }

    #[test]
    fn fully_masked_records_are_skipped() {
        // the prompt alone exceeds max_seq, so truncation leaves zero
        // supervised positions — the record must not occupy batch slots
        let long_prompt = "p ".repeat(64);
        let content = format!(
            "{{\"prompt\": \"{}\", \"completion\": \"lost\"}}\n{{\"text\": \"kept words\"}}\n",
            long_prompt.trim()
        );
        let path = write_tmp("chronicals_jsonl_masked.jsonl", &content);
        let src = JsonlSource::new(&path, 7, 16);
        let exs = src.examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(exs.len(), 1, "the fully-masked record must be skipped");
        assert_eq!(src.stats().truncated, 1);
        assert!(exs[0].real_targets() > 0);
    }

    #[test]
    fn truncation_counted() {
        let long = "w ".repeat(400);
        let content = format!("{{\"text\": \"{}\"}}\n{{\"text\": \"short\"}}\n", long.trim());
        let path = write_tmp("chronicals_jsonl_long.jsonl", &content);
        let src = JsonlSource::new(&path, 7, 32);
        let exs = src.examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(exs.len(), 2);
        assert_eq!(src.stats().truncated, 1);
        assert!(exs.iter().all(|e| e.len() <= 32));
    }

    #[test]
    fn vocab_file_written_then_reused() {
        let path = write_tmp("chronicals_jsonl_vocab_corpus.jsonl", GOOD);
        let vocab = std::env::temp_dir().join("chronicals_jsonl.vocab");
        std::fs::remove_file(&vocab).ok();

        let src = JsonlSource::new(&path, 7, 64).with_vocab_file(&vocab);
        let first = src.examples(64).unwrap();
        assert!(vocab.exists(), "learning must persist the vocab file");

        // a second source loads the file instead of re-learning
        let src2 = JsonlSource::new(&path, 999, 64).with_vocab_file(&vocab);
        let second = src2.examples(64).unwrap();
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.tokens, b.tokens, "loaded vocab must tokenize identically");
        }

        // an oversized vocab file against a smaller model vocab is an error
        let err = JsonlSource::new(&path, 7, 64)
            .with_vocab_file(&vocab)
            .examples(10)
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("model vocab"), "{err}");

        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&vocab).ok();
    }

    #[test]
    fn deterministic_across_calls() {
        let path = write_tmp("chronicals_jsonl_det.jsonl", GOOD);
        let a = JsonlSource::new(&path, 7, 64).examples(64).unwrap();
        let b = JsonlSource::new(&path, 7, 64).examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.targets, y.targets);
        }
    }
}
