//! File-backed JSONL instruction data: the real-corpus `ExampleSource`.
//!
//! A corpus file holds one JSON object per line — an instruction pair,
//! plain text, or a chat transcript (see [`super::chat`]):
//!
//! ```text
//! {"prompt": "explain sequence packing .", "completion": "bfd places each sequence ..."}
//! {"text": "padding wastes compute on positions that contribute nothing"}
//! {"messages": [{"role": "user", "content": "hi"}, {"role": "assistant", "content": "hello"}]}
//! ```
//!
//! A `.jsonl.gz` path streams through the hermetic [`crate::util::gzip`]
//! inflater; everything else (schema detection, diagnostics, accounting)
//! is identical for compressed and plain corpora.
//!
//! [`JsonlSource`] streams the file with buffered line-at-a-time reads and
//! tokenizes each record as the line is consumed — no corpus-wide string,
//! no eager tokenization pass (ChunkFT's byte-streamed ethos). Parsing
//! uses the crate's hermetic [`crate::util::json`] parser — no serde, no
//! external dependencies.
//!
//! Error policy (DESIGN.md §8):
//! * I/O failures and unreadable files are hard errors naming the path,
//! * a line that is not valid JSON or does not match the schema is
//!   **skipped and counted** ([`SourceStats::malformed`]) with a
//!   `file:line:` diagnostic retained, so a half-corrupt corpus still
//!   trains — loudly;
//! * a file yielding zero usable examples is a hard error carrying the
//!   first per-line diagnostic.
//!
//! ```
//! use chronicals::data_source::JsonlSource;
//! use chronicals::session::ExampleSource;
//!
//! let path = std::env::temp_dir().join("chronicals_doc_corpus.jsonl");
//! std::fs::write(
//!     &path,
//!     "{\"prompt\": \"add two and two .\", \"completion\": \"four\"}\n\
//!      {\"text\": \"padding wastes compute\"}\n",
//! )?;
//! let src = JsonlSource::new(&path, 7, 64);
//! let examples = src.examples(64)?; // vocab-capped to the model
//! assert_eq!(examples.len(), 2);
//! assert_eq!(src.stats().malformed, 0);
//! # std::fs::remove_file(&path).ok();
//! # Ok::<(), anyhow::Error>(())
//! ```

use super::bpe::{BpeLearner, ByteBpe};
use super::chat::{parse_messages, tokenize_chat, ChatTurn};
use super::{tokenize_pair, tokenize_text, LossMode, SourceStats, Tokenizer};
use crate::data::TokenizedExample;
use crate::session::ExampleSource;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::fs::File;
use std::io::{BufRead, BufReader, Cursor};
use std::path::{Path, PathBuf};

/// Retain at most this many per-line diagnostics in [`SourceStats::notes`].
const MAX_NOTES: usize = 8;

/// One parsed JSONL record.
enum Record {
    /// `{"prompt": …, "completion": …}` — prompt loss-masked, completion
    /// supervised.
    Pair { prompt: String, completion: String },
    /// `{"text": …}` — every next-token position supervised.
    Text(String),
    /// `{"messages": [{"role": …, "content": …}, …]}` — a chat transcript
    /// with per-turn masks (see [`super::chat`]).
    Chat(Vec<ChatTurn>),
}

/// Parse one line into a [`Record`]; schema errors name the offending key.
/// With `chat_only`, anything but a `messages` transcript is a schema
/// error (the [`super::ChatSource`] strictness).
fn parse_record(line: &str, chat_only: bool) -> Result<Record> {
    let v = Json::parse(line)?;
    let obj = v.as_obj().ok_or_else(|| anyhow!("expected a JSON object"))?;
    if let Some(m) = obj.get("messages") {
        return Ok(Record::Chat(parse_messages(m)?));
    }
    if chat_only {
        bail!("expected a {{\"messages\": [...]}} chat record");
    }
    let str_field = |key: &str, j: &Json| -> Result<String> {
        j.as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow!("\"{key}\" must be a string"))
    };
    match (obj.get("prompt"), obj.get("completion"), obj.get("text")) {
        (Some(p), Some(c), _) => Ok(Record::Pair {
            prompt: str_field("prompt", p)?,
            completion: str_field("completion", c)?,
        }),
        (Some(_), None, _) => bail!("\"prompt\" without \"completion\""),
        (None, Some(_), _) => bail!("\"completion\" without \"prompt\""),
        (None, None, Some(t)) => Ok(Record::Text(str_field("text", t)?)),
        (None, None, None) => {
            bail!("expected {{\"prompt\", \"completion\"}}, {{\"text\"}} or {{\"messages\"}} keys")
        }
    }
}

/// Open a corpus for buffered line reads; a `.gz` path is decompressed
/// through the hermetic [`crate::util::gzip`] inflater (corpora are small,
/// so whole-file decompression to memory is fine — the line iteration
/// stays streaming either way).
fn open_lines(path: &Path) -> Result<Box<dyn BufRead>> {
    if path.extension().and_then(|e| e.to_str()) == Some("gz") {
        let bytes = std::fs::read(path)
            .with_context(|| format!("opening data file {}", path.display()))?;
        let plain = crate::util::gzip::decompress(&bytes)
            .with_context(|| format!("decompressing {}", path.display()))?;
        Ok(Box::new(Cursor::new(plain)))
    } else {
        let file = File::open(path)
            .with_context(|| format!("opening data file {}", path.display()))?;
        Ok(Box::new(BufReader::new(file)))
    }
}

/// A file-backed [`ExampleSource`] streaming an instruction-tuning JSONL
/// corpus through the byte-level mini-BPE tokenizer (see the module docs
/// for the schema and error policy).
pub struct JsonlSource {
    path: PathBuf,
    vocab_file: Option<PathBuf>,
    seed: u64,
    max_seq: usize,
    loss_mode: LossMode,
    chat_only: bool,
    stats: RefCell<SourceStats>,
}

impl JsonlSource {
    /// Describe a JSONL corpus (`.jsonl` or `.jsonl.gz`). Nothing is read
    /// until [`ExampleSource::examples`] is called. `seed` drives tokenizer
    /// learning (merge tie-breaks); `max_seq` caps tokens per example
    /// (longer records are truncated and counted).
    pub fn new(path: impl Into<PathBuf>, seed: u64, max_seq: usize) -> JsonlSource {
        JsonlSource {
            path: path.into(),
            vocab_file: None,
            seed,
            max_seq,
            loss_mode: LossMode::default(),
            chat_only: false,
            stats: RefCell::new(SourceStats::default()),
        }
    }

    /// Persist the tokenizer: load the vocab file when it exists, else
    /// learn from the corpus and write it there — so a second run (or
    /// another machine) tokenizes identically without re-learning.
    pub fn with_vocab_file(mut self, path: impl Into<PathBuf>) -> JsonlSource {
        self.vocab_file = Some(path.into());
        self
    }

    /// Select which token positions are supervised (default
    /// [`LossMode::ResponseOnly`]: pair prompts and non-assistant chat
    /// turns are loss-masked).
    pub fn with_loss_mode(mut self, mode: LossMode) -> JsonlSource {
        self.loss_mode = mode;
        self
    }

    /// Restrict the schema to `{"messages": …}` transcripts (the
    /// [`super::ChatSource`] strictness).
    pub(super) fn chat_only(mut self) -> JsonlSource {
        self.chat_only = true;
        self
    }

    /// The corpus path this source reads.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Stream the file once, calling `f` per well-formed record. Malformed
    /// lines are skipped and counted into the returned stats with
    /// `file:line:` diagnostics; I/O failures are hard errors.
    fn for_each_record(&self, mut f: impl FnMut(Record)) -> Result<SourceStats> {
        let reader = open_lines(&self.path)?;
        let mut stats = SourceStats::default();
        for (i, line) in reader.lines().enumerate() {
            let lineno = i + 1;
            let line = line
                .with_context(|| format!("reading {}:{}", self.path.display(), lineno))?;
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            match parse_record(trimmed, self.chat_only) {
                Ok(r) => f(r),
                Err(e) => {
                    stats.malformed += 1;
                    if stats.notes.len() < MAX_NOTES {
                        stats
                            .notes
                            .push(format!("{}:{}: {e:#}", self.path.display(), lineno));
                    }
                }
            }
        }
        Ok(stats)
    }

    /// Load or learn the tokenizer for this corpus under the model's
    /// vocab cap.
    fn resolve_tokenizer(&self, vocab_cap: usize) -> Result<ByteBpe> {
        if let Some(vf) = &self.vocab_file {
            if vf.exists() {
                let tok = ByteBpe::load(vf)?;
                if tok.vocab_size() > vocab_cap {
                    bail!(
                        "vocab file {} holds {} ids but the model vocab is {vocab_cap} — \
                         re-learn it (delete the file) or pick a smaller vocab",
                        vf.display(),
                        tok.vocab_size()
                    );
                }
                return Ok(tok);
            }
        }
        if vocab_cap <= 8 {
            bail!("model vocab {vocab_cap} is too small for the byte-level tokenizer");
        }
        let mut learner = BpeLearner::new();
        // pass-1 accounting is discarded; pass 2 records the real stats
        self.for_each_record(|r| match r {
            Record::Pair { prompt, completion } => {
                learner.feed(&prompt);
                learner.feed(&completion);
            }
            Record::Text(t) => learner.feed(&t),
            // feed the framed form so the role prefixes (`user: `) are in
            // the learned alphabet exactly as encoding will see them
            Record::Chat(turns) => {
                for turn in &turns {
                    learner.feed(&turn.framed());
                }
            }
        })?;
        let tok = learner.finish(vocab_cap, self.seed);
        if let Some(vf) = &self.vocab_file {
            tok.save(vf)?;
        }
        Ok(tok)
    }
}

impl ExampleSource for JsonlSource {
    fn label(&self) -> String {
        format!("jsonl({})", self.path.display())
    }

    fn examples(&self, vocab_cap: usize) -> Result<Vec<TokenizedExample>> {
        let tok = self.resolve_tokenizer(vocab_cap)?;
        let mut out = Vec::new();
        let mut truncated = 0usize;
        let mut stats = self.for_each_record(|r| {
            let (ex, was_truncated) = match r {
                Record::Pair { prompt, completion } => {
                    tokenize_pair(&tok, &prompt, &completion, self.max_seq, self.loss_mode)
                }
                Record::Text(t) => tokenize_text(&tok, &t, self.max_seq),
                Record::Chat(turns) => {
                    tokenize_chat(&tok, &turns, self.max_seq, self.loss_mode)
                }
            };
            if was_truncated {
                truncated += 1;
            }
            // a record whose prompt alone fills max_seq ends up fully
            // loss-masked — it would occupy batch slots while contributing
            // nothing to the loss, so it is skipped (counted above)
            if !ex.is_empty() && ex.real_targets() > 0 {
                out.push(ex);
            }
        })?;
        stats.truncated = truncated;
        if out.is_empty() {
            match stats.notes.first() {
                Some(n) => bail!(
                    "no usable examples in {} ({} malformed records; first: {n})",
                    self.path.display(),
                    stats.malformed
                ),
                None => bail!("no examples in {}", self.path.display()),
            }
        }
        *self.stats.borrow_mut() = stats;
        Ok(out)
    }

    fn stats(&self) -> SourceStats {
        self.stats.borrow().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(name: &str, content: &str) -> PathBuf {
        let path = std::env::temp_dir().join(name);
        std::fs::write(&path, content).unwrap();
        path
    }

    const GOOD: &str = concat!(
        "{\"prompt\": \"explain packing .\", \"completion\": \"bins hold sequences\"}\n",
        "\n",
        "{\"text\": \"padding wastes compute on empty positions\"}\n",
        "{\"prompt\": \"count to three .\", \"completion\": \"one two three\"}\n",
    );

    #[test]
    fn streams_both_schemas() {
        let path = write_tmp("chronicals_jsonl_good.jsonl", GOOD);
        let src = JsonlSource::new(&path, 7, 64);
        let exs = src.examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(exs.len(), 3, "blank lines are skipped, both schemas parse");
        let stats = src.stats();
        assert_eq!(stats.malformed, 0);
        assert_eq!(stats.truncated, 0);
        // the pair records mask their prompt, the text record supervises all
        assert!(exs[0].real_targets() < exs[0].len() - 1);
        assert_eq!(exs[1].real_targets(), exs[1].len() - 1);
        // every id respects the vocab cap
        for ex in &exs {
            for &t in &ex.tokens {
                assert!((0..64).contains(&t), "token {t} out of range");
            }
        }
    }

    #[test]
    fn malformed_lines_are_counted_with_file_line() {
        let content = concat!(
            "{\"prompt\": \"a b\", \"completion\": \"c d\"}\n",
            "{not json at all\n",
            "{\"instruction\": \"wrong schema\"}\n",
            "{\"prompt\": \"only half\"}\n",
            "{\"text\": 42}\n",
            "{\"text\": \"still fine\"}\n",
        );
        let path = write_tmp("chronicals_jsonl_bad.jsonl", content);
        let src = JsonlSource::new(&path, 7, 64);
        let exs = src.examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(exs.len(), 2);
        let stats = src.stats();
        assert_eq!(stats.malformed, 4);
        assert_eq!(stats.notes.len(), 4);
        assert!(stats.notes[0].contains(":2:"), "{:?}", stats.notes);
        assert!(stats.notes[1].contains(":3:"), "{:?}", stats.notes);
        assert!(stats.notes[2].contains("completion"), "{:?}", stats.notes);
        assert!(stats.notes[3].contains(":5:"), "{:?}", stats.notes);
    }

    #[test]
    fn all_malformed_is_a_hard_error_naming_the_first_line() {
        let path = write_tmp("chronicals_jsonl_allbad.jsonl", "nope\nalso nope\n");
        let err = JsonlSource::new(&path, 7, 64).examples(64).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("no usable examples"), "{err}");
        assert!(err.contains(":1:"), "{err}");
    }

    #[test]
    fn missing_file_is_a_hard_error() {
        let err = JsonlSource::new("/definitely/not/here.jsonl", 7, 64)
            .examples(64)
            .map(|_| ())
            .unwrap_err();
        assert!(format!("{err:#}").contains("not/here.jsonl"), "{err:#}");
    }

    #[test]
    fn fully_masked_records_are_skipped() {
        // the prompt alone exceeds max_seq, so truncation leaves zero
        // supervised positions — the record must not occupy batch slots
        let long_prompt = "p ".repeat(64);
        let content = format!(
            "{{\"prompt\": \"{}\", \"completion\": \"lost\"}}\n{{\"text\": \"kept words\"}}\n",
            long_prompt.trim()
        );
        let path = write_tmp("chronicals_jsonl_masked.jsonl", &content);
        let src = JsonlSource::new(&path, 7, 16);
        let exs = src.examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(exs.len(), 1, "the fully-masked record must be skipped");
        assert_eq!(src.stats().truncated, 1);
        assert!(exs[0].real_targets() > 0);
    }

    #[test]
    fn truncation_counted() {
        let long = "w ".repeat(400);
        let content = format!("{{\"text\": \"{}\"}}\n{{\"text\": \"short\"}}\n", long.trim());
        let path = write_tmp("chronicals_jsonl_long.jsonl", &content);
        let src = JsonlSource::new(&path, 7, 32);
        let exs = src.examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(exs.len(), 2);
        assert_eq!(src.stats().truncated, 1);
        assert!(exs.iter().all(|e| e.len() <= 32));
    }

    #[test]
    fn vocab_file_written_then_reused() {
        let path = write_tmp("chronicals_jsonl_vocab_corpus.jsonl", GOOD);
        let vocab = std::env::temp_dir().join("chronicals_jsonl.vocab");
        std::fs::remove_file(&vocab).ok();

        let src = JsonlSource::new(&path, 7, 64).with_vocab_file(&vocab);
        let first = src.examples(64).unwrap();
        assert!(vocab.exists(), "learning must persist the vocab file");

        // a second source loads the file instead of re-learning
        let src2 = JsonlSource::new(&path, 999, 64).with_vocab_file(&vocab);
        let second = src2.examples(64).unwrap();
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.tokens, b.tokens, "loaded vocab must tokenize identically");
        }

        // an oversized vocab file against a smaller model vocab is an error
        let err = JsonlSource::new(&path, 7, 64)
            .with_vocab_file(&vocab)
            .examples(10)
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("model vocab"), "{err}");

        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&vocab).ok();
    }

    #[test]
    fn deterministic_across_calls() {
        let path = write_tmp("chronicals_jsonl_det.jsonl", GOOD);
        let a = JsonlSource::new(&path, 7, 64).examples(64).unwrap();
        let b = JsonlSource::new(&path, 7, 64).examples(64).unwrap();
        std::fs::remove_file(&path).ok();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.targets, y.targets);
        }
    }

    #[test]
    fn chat_records_stream_through_the_mixed_source() {
        let content = concat!(
            "{\"prompt\": \"explain packing .\", \"completion\": \"bins hold sequences\"}\n",
            "{\"messages\": [{\"role\": \"user\", \"content\": \"explain packing .\"}, \
             {\"role\": \"assistant\", \"content\": \"bins hold sequences\"}]}\n",
            "{\"messages\": [{\"role\": \"user\", \"content\": \"no reply here\"}]}\n",
        );
        let path = write_tmp("chronicals_jsonl_mixed.jsonl", content);
        let src = JsonlSource::new(&path, 7, 96);
        let exs = src.examples(96).unwrap();
        std::fs::remove_file(&path).ok();
        // the assistant-less transcript is fully masked and skipped
        assert_eq!(exs.len(), 2);
        assert_eq!(src.stats().malformed, 0);
        // the chat example masks its user turn
        assert_eq!(exs[1].targets[0], -1);
        assert!(exs[1].real_targets() > 0);
    }

    #[test]
    fn full_loss_mode_supervises_prompts() {
        let path = write_tmp("chronicals_jsonl_lossmode.jsonl", GOOD);
        let masked = JsonlSource::new(&path, 7, 64).examples(64).unwrap();
        let full = JsonlSource::new(&path, 7, 64)
            .with_loss_mode(LossMode::Full)
            .examples(64)
            .unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(masked.len(), full.len());
        for (m, f) in masked.iter().zip(&full) {
            assert_eq!(m.tokens, f.tokens, "loss mode must not change tokenization");
            assert!(f.real_targets() >= m.real_targets());
        }
        // the pair records gain prompt supervision
        assert!(full[0].real_targets() > masked[0].real_targets());
        assert_eq!(full[0].real_targets(), full[0].len() - 1);
    }

    #[test]
    fn gz_corpus_tokenizes_identically_to_plain() {
        // hand-built single-member gzip with a stored block: tests the
        // whole .jsonl.gz read path without shelling out to gzip
        fn crc32(data: &[u8]) -> u32 {
            let mut crc = 0xffff_ffffu32;
            for &b in data {
                crc ^= b as u32;
                for _ in 0..8 {
                    let mask = (crc & 1).wrapping_neg();
                    crc = (crc >> 1) ^ (0xedb8_8320 & mask);
                }
            }
            !crc
        }
        let plain = GOOD.as_bytes();
        let mut gz = vec![0x1f, 0x8b, 0x08, 0x00, 0, 0, 0, 0, 0x00, 0xff, 0x01];
        gz.extend_from_slice(&(plain.len() as u16).to_le_bytes());
        gz.extend_from_slice(&(!(plain.len() as u16)).to_le_bytes());
        gz.extend_from_slice(plain);
        gz.extend_from_slice(&crc32(plain).to_le_bytes());
        gz.extend_from_slice(&(plain.len() as u32).to_le_bytes());

        let plain_path = write_tmp("chronicals_jsonl_gzcmp.jsonl", GOOD);
        let gz_path = std::env::temp_dir().join("chronicals_jsonl_gzcmp.jsonl.gz");
        std::fs::write(&gz_path, &gz).unwrap();

        let a = JsonlSource::new(&plain_path, 7, 64).examples(64).unwrap();
        let b = JsonlSource::new(&gz_path, 7, 64).examples(64).unwrap();
        std::fs::remove_file(&plain_path).ok();
        std::fs::remove_file(&gz_path).ok();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens, "gz and plain corpora must tokenize identically");
            assert_eq!(x.targets, y.targets);
        }
    }

    #[test]
    fn corrupt_gz_is_a_hard_error() {
        let gz_path = std::env::temp_dir().join("chronicals_jsonl_corrupt.jsonl.gz");
        std::fs::write(&gz_path, b"not gzip at all").unwrap();
        let err = JsonlSource::new(&gz_path, 7, 64)
            .examples(64)
            .map(|_| ())
            .unwrap_err();
        std::fs::remove_file(&gz_path).ok();
        assert!(format!("{err:#}").contains("decompressing"), "{err:#}");
    }

    #[test]
    fn emoji_survives_the_full_pipeline() {
        // escaped surrogate pair in the file → real 😀 in the tokenized
        // stream → intact after decode (the §9 surrogate bugfix, end to end)
        let content = concat!(
            "{\"prompt\": \"decode the emoji \\ud83d\\ude00 .\", ",
            "\"completion\": \"the smile survives .\"}\n",
        );
        let path = write_tmp("chronicals_jsonl_emoji.jsonl", content);
        let src = JsonlSource::new(&path, 7, 96);
        let exs = src.examples(96).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(exs.len(), 1);
        assert_eq!(src.stats().malformed, 0, "{:?}", src.stats().notes);
        // rebuild the exact tokenizer the source learned (same feed order,
        // same cap, same seed) and decode the tokenized example
        let mut learner = BpeLearner::new();
        learner.feed("decode the emoji \u{1f600} .");
        learner.feed("the smile survives .");
        let tok = learner.finish(96, 7);
        let text = tok.decode(&exs[0].tokens);
        assert!(text.contains('\u{1f600}'), "emoji lost in {text:?}");
        assert!(!text.contains('\u{fffd}'), "replacement char in {text:?}");
        assert!(!text.contains("<unk>"), "unk in {text:?}");
    }
}
