//! Byte-level mini-BPE: a seeded, corpus-learnable pair-merge vocabulary.
//!
//! The paper fine-tunes with Qwen's 151,936-token BPE vocabulary. The
//! properties the experiments actually depend on are determinism and a
//! vocab capped to what the model's embedding table can index, so the
//! offline substitute is a miniature byte-pair encoder:
//!
//! * the **base alphabet** is the corpus's own bytes, frequency-ranked and
//!   capped (bytes never seen at learn time encode as `<unk>`),
//! * **merges** are learned greedily — repeatedly fuse the most frequent
//!   adjacent pair — until the vocab cap is reached or no pair repeats;
//!   ties are broken by a seeded SplitMix64 rank so learning is a pure
//!   function of (corpus, cap, seed),
//! * the learned vocabulary **serializes** to a plain-text vocab file
//!   (`chronicals-bpe v1`) and loads back bit-identically, so a run can be
//!   reproduced later without re-learning.
//!
//! Text is pre-tokenized GPT-2 style: lowercased, whitespace-normalized,
//! and split into chunks that keep their leading space, so decoding is a
//! pure concatenation and `decode(encode(text))` round-trips normalized
//! text exactly.
//!
//! Token ids: `0 <pad>`, `1 <unk>`, `2 <bos>`, `3 <eos>`, then the ranked
//! byte alphabet, then one id per merge in learn order.
//!
//! ```
//! use chronicals::data_source::{ByteBpe, Tokenizer};
//!
//! let corpus = ["the packing plan", "the packing bins", "the padded rows"];
//! let tok = ByteBpe::learn(corpus, 48, 7);
//! assert!(tok.vocab_size() <= 48);
//! // deterministic: same corpus, cap and seed ⇒ same ids
//! let again = ByteBpe::learn(corpus, 48, 7);
//! assert_eq!(tok.encode("the packing"), again.encode("the packing"));
//! // round-trip (modulo whitespace normalization and lowercasing)
//! assert_eq!(tok.decode(&tok.encode("THE  packing")), "<bos>the packing<eos>");
//! ```

use super::Tokenizer;
use crate::data::tokenizer::{BOS, EOS, UNK};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::Path;

const N_SPECIAL: usize = 4;
/// Vocab-file magic line; bump the version if the format ever changes.
const MAGIC: &str = "chronicals-bpe v1";

/// SplitMix64 finalizer (the same mix `util::rng` seeds with): a bijection
/// on `u64`, used to give every candidate pair a distinct seeded rank so
/// merge-order ties cannot exist.
fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Seeded total-order rank for a candidate pair (lower wins ties).
fn pair_rank(seed: u64, a: i32, b: i32) -> u64 {
    splitmix64(seed ^ ((a as u64) << 32) ^ (b as u64))
}

/// Lowercase + whitespace-normalize text into GPT-2-style chunks: the
/// first word is bare, every following word keeps one leading space.
/// Concatenating the chunks reproduces the normalized text.
fn chunks(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    for w in text.split_whitespace() {
        let w = w.to_lowercase();
        if out.is_empty() {
            out.push(w);
        } else {
            out.push(format!(" {w}"));
        }
    }
    out
}

/// One left-to-right pass replacing adjacent `(a, b)` with `new_id` — the
/// single merge-application primitive shared by learning and encoding, so
/// both always agree.
fn apply_merge(s: &mut Vec<i32>, a: i32, b: i32, new_id: i32) {
    if s.len() < 2 {
        return;
    }
    let mut out = Vec::with_capacity(s.len());
    let mut i = 0;
    while i < s.len() {
        if i + 1 < s.len() && s[i] == a && s[i + 1] == b {
            out.push(new_id);
            i += 2;
        } else {
            out.push(s[i]);
            i += 1;
        }
    }
    *s = out;
}

/// Streaming vocabulary learner: feed it text field by field (each call is
/// one record field — no corpus-wide `String` is ever built), then
/// [`BpeLearner::finish`] to fit the vocabulary.
#[derive(Debug, Default)]
pub struct BpeLearner {
    words: HashMap<String, u64>,
}

impl BpeLearner {
    /// Fresh learner with no observed text.
    pub fn new() -> BpeLearner {
        BpeLearner::default()
    }

    /// Observe one text field (a prompt, a completion, or a `text` value).
    pub fn feed(&mut self, text: &str) {
        for chunk in chunks(text) {
            *self.words.entry(chunk).or_default() += 1;
        }
    }

    /// Fit the vocabulary: rank the byte alphabet, then greedily learn
    /// pair merges until `cap` total ids or no adjacent pair repeats.
    /// Deterministic in (observed text, `cap`, `seed`).
    pub fn finish(self, cap: usize, seed: u64) -> ByteBpe {
        assert!(cap > N_SPECIAL, "vocab cap {cap} leaves no room for the byte alphabet");
        // deterministic word order for all subsequent accumulation
        let mut words: Vec<(String, u64)> = self.words.into_iter().collect();
        words.sort_by(|a, b| a.0.cmp(&b.0));

        // 1) byte alphabet, frequency-ranked (ties to the smaller byte)
        let mut byte_count = [0u64; 256];
        for (w, c) in &words {
            for &b in w.as_bytes() {
                byte_count[b as usize] += c;
            }
        }
        let mut ranked: Vec<u8> =
            (0..=255u8).filter(|&b| byte_count[b as usize] > 0).collect();
        ranked.sort_by(|&a, &b| {
            byte_count[b as usize].cmp(&byte_count[a as usize]).then(a.cmp(&b))
        });
        ranked.truncate(cap - N_SPECIAL);
        let mut byte_ids = [UNK; 256];
        for (i, &b) in ranked.iter().enumerate() {
            byte_ids[b as usize] = (N_SPECIAL + i) as i32;
        }

        // 2) symbol sequences for every distinct word
        let counts: Vec<u64> = words.iter().map(|(_, c)| *c).collect();
        let mut seqs: Vec<Vec<i32>> = words
            .iter()
            .map(|(w, _)| w.as_bytes().iter().map(|&b| byte_ids[b as usize]).collect())
            .collect();

        // 3) greedy pair merging under the cap
        let mut merges: Vec<(i32, i32)> = Vec::new();
        while N_SPECIAL + ranked.len() + merges.len() < cap {
            let mut pair_counts: HashMap<(i32, i32), u64> = HashMap::new();
            for (s, &c) in seqs.iter().zip(&counts) {
                for win in s.windows(2) {
                    // never merge across unknown bytes
                    if win[0] != UNK && win[1] != UNK {
                        *pair_counts.entry((win[0], win[1])).or_default() += c;
                    }
                }
            }
            // total order: count first, then the seeded rank (injective, so
            // HashMap iteration order cannot influence the pick)
            let best = pair_counts
                .into_iter()
                .max_by_key(|&((a, b), c)| (c, std::cmp::Reverse(pair_rank(seed, a, b))));
            let Some(((a, b), c)) = best else { break };
            if c < 2 {
                break; // a pair seen once compresses nothing
            }
            let new_id = (N_SPECIAL + ranked.len() + merges.len()) as i32;
            for s in &mut seqs {
                apply_merge(s, a, b, new_id);
            }
            merges.push((a, b));
        }
        ByteBpe::assemble(seed, cap, ranked, merges)
    }
}

/// A learned byte-level mini-BPE vocabulary (see the module docs for the
/// id layout and determinism contract).
#[derive(Debug, Clone)]
pub struct ByteBpe {
    seed: u64,
    cap: usize,
    /// id `4 + i` encodes byte `bytes[i]`.
    bytes: Vec<u8>,
    /// byte value → token id (`UNK` when outside the learned alphabet).
    byte_ids: [i32; 256],
    /// merge `k` fuses this (left, right) pair into id `4 + bytes.len() + k`.
    merges: Vec<(i32, i32)>,
    /// id → raw byte string (for decoding; specials render as markers).
    pieces: Vec<Vec<u8>>,
}

impl ByteBpe {
    /// Learn a vocabulary from an in-memory corpus — convenience wrapper
    /// over [`BpeLearner`] for tests, doctests and small corpora.
    pub fn learn<I, S>(texts: I, cap: usize, seed: u64) -> ByteBpe
    where
        I: IntoIterator<Item = S>,
        S: AsRef<str>,
    {
        let mut learner = BpeLearner::new();
        for t in texts {
            learner.feed(t.as_ref());
        }
        learner.finish(cap, seed)
    }

    fn assemble(seed: u64, cap: usize, bytes: Vec<u8>, merges: Vec<(i32, i32)>) -> ByteBpe {
        let mut byte_ids = [UNK; 256];
        for (i, &b) in bytes.iter().enumerate() {
            byte_ids[b as usize] = (N_SPECIAL + i) as i32;
        }
        let mut pieces: Vec<Vec<u8>> = vec![
            b"<pad>".to_vec(),
            b"<unk>".to_vec(),
            b"<bos>".to_vec(),
            b"<eos>".to_vec(),
        ];
        for &b in &bytes {
            pieces.push(vec![b]);
        }
        for &(a, b) in &merges {
            let mut p = pieces[a as usize].clone();
            p.extend_from_slice(&pieces[b as usize]);
            pieces.push(p);
        }
        ByteBpe { seed, cap, bytes, byte_ids, merges, pieces }
    }

    /// The seed the vocabulary was learned with (tie-break salt; recorded
    /// in the vocab file so re-learning reproduces the same merges).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The vocab cap the learning ran under (≥ [`Tokenizer::vocab_size`]).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Number of learned pair merges.
    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    /// Serialize to a plain-text vocab file. [`ByteBpe::load`] restores the
    /// exact vocabulary, making tokenization reproducible across runs and
    /// machines without re-learning.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut out = String::new();
        let _ = writeln!(out, "{MAGIC}");
        let _ = writeln!(out, "seed {}", self.seed);
        let _ = writeln!(out, "cap {}", self.cap);
        for &b in &self.bytes {
            let _ = writeln!(out, "byte {b}");
        }
        for &(a, b) in &self.merges {
            let _ = writeln!(out, "merge {a} {b}");
        }
        std::fs::write(path, out)
            .with_context(|| format!("writing vocab file {}", path.display()))
    }

    /// Load a vocabulary saved by [`ByteBpe::save`]. Errors carry
    /// `file:line` so a corrupt vocab file points at the offending line.
    pub fn load(path: impl AsRef<Path>) -> Result<ByteBpe> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading vocab file {}", path.display()))?;
        let at = |lineno: usize| format!("{}:{}", path.display(), lineno);
        let mut lines = text.lines().enumerate().map(|(i, l)| (i + 1, l.trim()));
        let (_, magic) = lines
            .next()
            .ok_or_else(|| anyhow!("{}: empty vocab file", path.display()))?;
        if magic != MAGIC {
            bail!("{}: not a '{MAGIC}' vocab file (got '{magic}')", at(1));
        }
        let mut seed: Option<u64> = None;
        let mut cap: Option<usize> = None;
        let mut bytes: Vec<u8> = Vec::new();
        let mut merges: Vec<(i32, i32)> = Vec::new();
        for (lineno, line) in lines {
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let key = parts.next().unwrap_or_default();
            let args: Vec<&str> = parts.collect();
            match (key, args.as_slice()) {
                ("seed", [v]) => {
                    seed = Some(v.parse().map_err(|_| anyhow!("{}: bad seed '{v}'", at(lineno)))?)
                }
                ("cap", [v]) => {
                    cap = Some(v.parse().map_err(|_| anyhow!("{}: bad cap '{v}'", at(lineno)))?)
                }
                ("byte", [v]) => {
                    if !merges.is_empty() {
                        bail!("{}: byte lines must precede merge lines", at(lineno));
                    }
                    let b: u8 =
                        v.parse().map_err(|_| anyhow!("{}: bad byte '{v}'", at(lineno)))?;
                    if bytes.contains(&b) {
                        bail!("{}: duplicate byte {b}", at(lineno));
                    }
                    bytes.push(b);
                }
                ("merge", [l, r]) => {
                    let parse = |v: &&str| {
                        v.parse::<i32>()
                            .map_err(|_| anyhow!("{}: bad merge operand '{v}'", at(lineno)))
                    };
                    let (l, r) = (parse(l)?, parse(r)?);
                    let defined = (N_SPECIAL + bytes.len() + merges.len()) as i32;
                    for op in [l, r] {
                        if op < N_SPECIAL as i32 || op >= defined {
                            bail!(
                                "{}: merge operand {op} is not a previously defined id \
                                 (expected {}..{defined})",
                                at(lineno),
                                N_SPECIAL
                            );
                        }
                    }
                    merges.push((l, r));
                }
                _ => bail!("{}: unrecognized vocab line '{line}'", at(lineno)),
            }
        }
        let seed = seed.ok_or_else(|| anyhow!("{}: missing 'seed' line", path.display()))?;
        let cap = cap.ok_or_else(|| anyhow!("{}: missing 'cap' line", path.display()))?;
        if cap <= N_SPECIAL {
            bail!("{}: cap {cap} is too small", path.display());
        }
        if N_SPECIAL + bytes.len() + merges.len() > cap {
            bail!(
                "{}: vocab holds {} ids but declares cap {cap}",
                path.display(),
                N_SPECIAL + bytes.len() + merges.len()
            );
        }
        Ok(ByteBpe::assemble(seed, cap, bytes, merges))
    }
}

impl Tokenizer for ByteBpe {
    fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = vec![BOS];
        for chunk in chunks(text) {
            let mut s: Vec<i32> =
                chunk.as_bytes().iter().map(|&b| self.byte_ids[b as usize]).collect();
            for (k, &(a, b)) in self.merges.iter().enumerate() {
                apply_merge(&mut s, a, b, (N_SPECIAL + self.bytes.len() + k) as i32);
            }
            out.extend(s);
        }
        out.push(EOS);
        out
    }

    fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if id >= 0 {
                if let Some(p) = self.pieces.get(id as usize) {
                    bytes.extend_from_slice(p);
                }
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn vocab_size(&self) -> usize {
        N_SPECIAL + self.bytes.len() + self.merges.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CORPUS: &[&str] = &[
        "the attention kernel streams tiles",
        "the packing plan streams bins",
        "the optimizer updates the weights",
    ];

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = ByteBpe::learn(CORPUS, 48, 7);
        let b = ByteBpe::learn(CORPUS, 48, 7);
        assert_eq!(a.encode("the packing plan"), b.encode("the packing plan"));
        assert_eq!(a.n_merges(), b.n_merges());
    }

    #[test]
    fn vocab_respects_cap() {
        for cap in [8, 16, 40, 64, 256] {
            let t = ByteBpe::learn(CORPUS, cap, 3);
            assert!(t.vocab_size() <= cap, "cap {cap}: {}", t.vocab_size());
            for id in t.encode("the attention kernel") {
                assert!((id as usize) < cap, "id {id} out of cap {cap}");
            }
        }
    }

    #[test]
    fn merges_compress() {
        // a cap of exactly 4 + |alphabet| leaves zero room for merges
        let distinct: std::collections::HashSet<u8> =
            CORPUS.iter().flat_map(|s| s.bytes()).collect();
        let no_merges = ByteBpe::learn(CORPUS, 4 + distinct.len(), 7);
        assert_eq!(no_merges.n_merges(), 0);
        let merged = ByteBpe::learn(CORPUS, 64, 7);
        assert!(merged.n_merges() > 0);
        let text = "the packing plan streams";
        assert!(
            merged.encode(text).len() < no_merges.encode(text).len(),
            "merges must shorten encodings"
        );
    }

    #[test]
    fn roundtrip_normalized_text() {
        let t = ByteBpe::learn(CORPUS, 64, 7);
        assert_eq!(
            t.decode(&t.encode("The  Packing   plan")),
            "<bos>the packing plan<eos>"
        );
    }

    #[test]
    fn unknown_bytes_map_to_unk() {
        let t = ByteBpe::learn(CORPUS, 64, 7);
        let ids = t.encode("qjxv!"); // none of these bytes appear in CORPUS
        assert!(ids.contains(&UNK));
        // every id still in range
        for id in ids {
            assert!((id as usize) < t.vocab_size());
        }
    }

    #[test]
    fn negative_ids_skipped_in_decode() {
        let t = ByteBpe::learn(CORPUS, 64, 7);
        assert_eq!(t.decode(&[-1, BOS, -1]), "<bos>");
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let t = ByteBpe::learn(CORPUS, 64, 9);
        let path = std::env::temp_dir().join("chronicals_bpe_roundtrip.vocab");
        t.save(&path).unwrap();
        let loaded = ByteBpe::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.seed(), 9);
        assert_eq!(loaded.vocab_size(), t.vocab_size());
        assert_eq!(loaded.n_merges(), t.n_merges());
        let text = "the attention kernel streams tiles and bins";
        assert_eq!(loaded.encode(text), t.encode(text));
    }

    #[test]
    fn load_rejects_corruption_with_file_line() {
        let path = std::env::temp_dir().join("chronicals_bpe_corrupt.vocab");
        std::fs::write(&path, "chronicals-bpe v1\nseed 1\ncap 64\nbyte 300\n").unwrap();
        let err = ByteBpe::load(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains(":4"), "error must carry file:line, got {err}");

        let path2 = std::env::temp_dir().join("chronicals_bpe_magic.vocab");
        std::fs::write(&path2, "not a vocab\n").unwrap();
        let err2 = ByteBpe::load(&path2).unwrap_err().to_string();
        std::fs::remove_file(&path2).ok();
        assert!(err2.contains("chronicals-bpe"), "{err2}");
    }

    #[test]
    fn merge_operand_validation() {
        let path = std::env::temp_dir().join("chronicals_bpe_badmerge.vocab");
        // merge references id 40, but only ids 4..6 are defined
        std::fs::write(
            &path,
            "chronicals-bpe v1\nseed 1\ncap 64\nbyte 97\nbyte 98\nmerge 4 40\n",
        )
        .unwrap();
        let err = ByteBpe::load(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("merge operand"), "{err}");
    }
}
