//! Batch construction: padded (baseline) and BFD-packed (chronicals).
//!
//! A batch is four `[B, S]` i32 tensors: tokens, targets (-1 = masked),
//! segment ids (0 = padding, 1..k = packed sequence index) and position ids
//! (reset to 0 at each segment start — paper Alg. 17, so RoPE sees
//! per-sequence positions).
//!
//! All layouts are produced by the lazy [`stream::BatchStream`] pipeline;
//! the eager `Vec<Batch>` helpers below are thin `collect()` adapters kept
//! for tests, benches and exact-legacy comparisons. The adapters copy the
//! example slice to feed the owning stream — fine at test-corpus scale;
//! hot paths (`session::Session::run`) hand their `Vec` to the stream by
//! value instead and never copy.

pub mod stream;

pub use stream::{BatchStream, EpochSpec, PackingStrategy, TailPolicy};

use crate::data::TokenizedExample;
use crate::packing::{best_fit_decreasing, Packing};
use crate::runtime::HostTensor;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: HostTensor,
    pub targets: HostTensor,
    pub seg_ids: HostTensor,
    pub pos_ids: HostTensor,
    /// Non-padding token count (throughput accounting).
    pub real_tokens: usize,
    /// Supervised target count.
    pub real_targets: usize,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    /// Fraction of [B, S] slots holding real tokens.
    pub fn density(&self) -> f64 {
        self.real_tokens as f64 / (self.batch * self.seq) as f64
    }

    /// Split into per-replica row shards for data-parallel execution
    /// (DESIGN.md §10): balanced contiguous row ranges via [`shard_rows`],
    /// remainder rows to the first `B % N` shards. Each shard is a
    /// standalone `[rows, S]` batch with its accounting recomputed from
    /// its own rows; replicas whose assignment is empty get no shard, so
    /// the shard count is `min(workers, B)`. The in-process
    /// [`crate::backend::DataParallel`] layer shards by borrowed row views
    /// instead (zero copies); this owning split is the seam a future
    /// mmap-backed worker process would consume, and what the multiset
    /// property tests exercise.
    pub fn shard(&self, workers: usize) -> Result<Vec<Batch>> {
        let tokens = self.tokens.as_i32()?;
        let targets = self.targets.as_i32()?;
        let seg_ids = self.seg_ids.as_i32()?;
        let pos_ids = self.pos_ids.as_i32()?;
        let mut out = Vec::new();
        for range in shard_rows(self.batch, workers) {
            if range.is_empty() {
                continue;
            }
            let rows = range.len();
            let (lo, hi) = (range.start * self.seq, range.end * self.seq);
            let shape = vec![rows, self.seq];
            let seg = seg_ids[lo..hi].to_vec();
            let tgt = targets[lo..hi].to_vec();
            let real_tokens = seg.iter().filter(|&&s| s != 0).count();
            let real_targets = tgt.iter().filter(|&&t| t >= 0).count();
            out.push(Batch {
                tokens: HostTensor::i32(tokens[lo..hi].to_vec(), shape.clone()),
                targets: HostTensor::i32(tgt, shape.clone()),
                seg_ids: HostTensor::i32(seg, shape.clone()),
                pos_ids: HostTensor::i32(pos_ids[lo..hi].to_vec(), shape),
                real_tokens,
                real_targets,
                batch: rows,
                seq: self.seq,
            });
        }
        Ok(out)
    }
}

/// Balanced contiguous row→replica assignment for data-parallel sharding:
/// replica `r` gets `rows / workers` rows, and the first `rows % workers`
/// replicas take one extra (the remainder policy, DESIGN.md §10). Returns
/// one range per replica, in replica order, covering `0..rows` exactly;
/// trailing replicas get empty ranges when `workers > rows`. The
/// assignment never influences gradient bits — the reduction tree is a
/// function of the row count alone — so this is purely a load-balancing
/// choice.
pub fn shard_rows(rows: usize, workers: usize) -> Vec<std::ops::Range<usize>> {
    let workers = workers.max(1);
    let base = rows / workers;
    let extra = rows % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0usize;
    for r in 0..workers {
        let len = base + usize::from(r < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Padded batching (the baseline): one example per row, truncated/padded to
/// `seq`. Waste = 1 - mean(len)/seq (paper Eq. 85). Eager adapter over
/// [`BatchStream`] with the historical drop-the-tail semantics.
pub fn padded_batches(examples: &[TokenizedExample], batch: usize, seq: usize) -> Vec<Batch> {
    BatchStream::new(examples.to_vec(), PackingStrategy::Padded, batch, seq, TailPolicy::Drop)
        .collect()
}

/// BFD-packed batching: pack examples into `seq`-capacity bins, then group
/// `batch` bins per batch. Rows carry multiple segments. Eager adapter over
/// [`BatchStream`] with the historical drop-the-tail semantics.
pub fn packed_batches(examples: &[TokenizedExample], batch: usize, seq: usize) -> Vec<Batch> {
    BatchStream::new(examples.to_vec(), PackingStrategy::Bfd, batch, seq, TailPolicy::Drop)
        .collect()
}

/// Convert an arbitrary packing into batches (used by the packing ablation
/// to compare BFD/FFD/NF end-to-end).
pub fn packing_to_batches(
    packing: &Packing,
    examples: &[TokenizedExample],
    batch: usize,
    seq: usize,
) -> Vec<Batch> {
    packing
        .bins
        .chunks(batch)
        .filter(|c| c.len() == batch)
        .map(|bins| {
            let mut b = BatchBuilder::new(batch, seq);
            for (row, bin) in bins.iter().enumerate() {
                let mut offset = 0;
                for (seg, &item) in bin.items.iter().enumerate() {
                    let ex = &examples[item];
                    b.place(row, offset, ex, (seg + 1) as i32);
                    offset += ex.len();
                }
            }
            b.finish()
        })
        .collect()
}

/// Token-budget batching (paper Def. 33, §S14.2): group whole sequences so
/// each batch carries at most `token_budget` real tokens, packing each
/// group with BFD into `seq`-capacity rows. Rows per batch therefore vary;
/// the emitted tensors are still [B, S] with B = ceil(budget/seq) so one
/// executable serves every batch (short groups pad the last rows).
pub fn token_budget_batches(
    examples: &[TokenizedExample],
    token_budget: usize,
    seq: usize,
) -> Vec<Batch> {
    assert!(token_budget >= seq, "budget must cover at least one row");
    let rows_per_batch = token_budget.div_ceil(seq);
    // 1) BFD-pack everything into seq-capacity bins (each bin = one row)
    let lengths: Vec<usize> = examples.iter().map(|e| e.len().min(seq)).collect();
    let packing = best_fit_decreasing(&lengths, seq);
    // 2) group bins greedily under the token budget (bins ≤ rows_per_batch
    //    follows because each bin holds ≤ seq tokens)
    let mut batches = Vec::new();
    let mut group: Vec<&crate::packing::Bin> = Vec::new();
    let mut group_tokens = 0usize;
    let flush = |group: &mut Vec<&crate::packing::Bin>, group_tokens: &mut usize,
                     batches: &mut Vec<Batch>| {
        if group.is_empty() {
            return;
        }
        let mut b = BatchBuilder::new(rows_per_batch, seq);
        for (row, bin) in group.iter().enumerate() {
            let mut offset = 0;
            for (seg, &item) in bin.items.iter().enumerate() {
                let ex = &examples[item];
                b.place(row, offset, ex, (seg + 1) as i32);
                offset += ex.len().min(seq - offset);
                if offset >= seq {
                    break;
                }
            }
        }
        batches.push(b.finish());
        group.clear();
        *group_tokens = 0;
    };
    for bin in &packing.bins {
        if (group_tokens + bin.used > token_budget || group.len() == rows_per_batch)
            && !group.is_empty()
        {
            flush(&mut group, &mut group_tokens, &mut batches);
        }
        group.push(bin);
        group_tokens += bin.used;
    }
    flush(&mut group, &mut group_tokens, &mut batches);
    batches
}

pub(crate) struct BatchBuilder {
    tokens: Vec<i32>,
    targets: Vec<i32>,
    seg_ids: Vec<i32>,
    pos_ids: Vec<i32>,
    batch: usize,
    seq: usize,
    real_tokens: usize,
    real_targets: usize,
}

impl BatchBuilder {
    pub(crate) fn new(batch: usize, seq: usize) -> Self {
        BatchBuilder {
            tokens: vec![0; batch * seq],
            targets: vec![-1; batch * seq],
            seg_ids: vec![0; batch * seq],
            pos_ids: vec![0; batch * seq],
            batch,
            seq,
            real_tokens: 0,
            real_targets: 0,
        }
    }

    pub(crate) fn place(&mut self, row: usize, offset: usize, ex: &TokenizedExample, seg: i32) {
        let n = ex.len().min(self.seq - offset);
        let base = row * self.seq + offset;
        for i in 0..n {
            self.tokens[base + i] = ex.tokens[i];
            self.targets[base + i] = ex.targets[i];
            self.seg_ids[base + i] = seg;
            self.pos_ids[base + i] = i as i32; // reset per segment (Alg. 17)
            if ex.targets[i] >= 0 {
                self.real_targets += 1;
            }
        }
        // a truncated final position must not predict a token we dropped
        if n < ex.len() && n > 0 {
            let last = base + n - 1;
            if self.targets[last] >= 0 {
                self.targets[last] = -1;
                self.real_targets -= 1;
            }
        }
        self.real_tokens += n;
    }

    pub(crate) fn finish(self) -> Batch {
        let shape = vec![self.batch, self.seq];
        Batch {
            tokens: HostTensor::i32(self.tokens, shape.clone()),
            targets: HostTensor::i32(self.targets, shape.clone()),
            seg_ids: HostTensor::i32(self.seg_ids, shape.clone()),
            pos_ids: HostTensor::i32(self.pos_ids, shape),
            real_tokens: self.real_tokens,
            real_targets: self.real_targets,
            batch: self.batch,
            seq: self.seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(n: usize, base: i32) -> TokenizedExample {
        let tokens: Vec<i32> = (0..n as i32).map(|i| base + i).collect();
        let mut targets: Vec<i32> = tokens.iter().skip(1).copied().collect();
        targets.push(-1);
        TokenizedExample { tokens, targets }
    }

    #[test]
    fn padded_layout() {
        let exs = vec![ex(3, 10), ex(5, 20)];
        let batches = padded_batches(&exs, 2, 8);
        assert_eq!(batches.len(), 1);
        let b = &batches[0];
        assert_eq!(b.real_tokens, 8);
        let toks = b.tokens.as_i32().unwrap();
        assert_eq!(&toks[0..4], &[10, 11, 12, 0]); // padded after 3
        let segs = b.seg_ids.as_i32().unwrap();
        assert_eq!(&segs[0..4], &[1, 1, 1, 0]);
        assert_eq!(b.density(), 0.5);
    }

    #[test]
    fn packed_positions_reset_per_segment() {
        let exs = vec![ex(4, 10), ex(4, 20)];
        let batches = packed_batches(&exs, 1, 8);
        assert_eq!(batches.len(), 1);
        let b = &batches[0];
        let pos = b.pos_ids.as_i32().unwrap();
        assert_eq!(pos, &[0, 1, 2, 3, 0, 1, 2, 3]);
        let segs = b.seg_ids.as_i32().unwrap();
        assert_eq!(segs, &[1, 1, 1, 1, 2, 2, 2, 2]);
        assert_eq!(b.density(), 1.0);
    }

    #[test]
    fn packed_density_beats_padded() {
        let exs: Vec<_> = (0..64).map(|i| ex(8 + (i % 24), 5)).collect();
        let padded = padded_batches(&exs, 4, 64);
        let packed = packed_batches(&exs, 4, 64);
        let pd: f64 = padded.iter().map(|b| b.density()).sum::<f64>() / padded.len() as f64;
        let kd: f64 = packed.iter().map(|b| b.density()).sum::<f64>() / packed.len() as f64;
        assert!(kd > pd, "packed {kd} <= padded {pd}");
        assert!(kd > 0.9);
    }

    #[test]
    fn truncation_masks_dangling_target() {
        let exs = vec![ex(10, 30)];
        let batches = padded_batches(&exs, 1, 4);
        let b = &batches[0];
        let tg = b.targets.as_i32().unwrap();
        assert_eq!(tg[3], -1); // truncated boundary must be masked
    }

    #[test]
    fn incomplete_final_batch_dropped() {
        let exs = vec![ex(4, 1), ex(4, 2), ex(4, 3)];
        let batches = padded_batches(&exs, 2, 8);
        assert_eq!(batches.len(), 1);
    }

    #[test]
    fn token_budget_respected() {
        let exs: Vec<_> = (0..32).map(|i| ex(4 + (i % 5), 10)).collect();
        let batches = token_budget_batches(&exs, 32, 8);
        for b in &batches {
            assert!(b.real_tokens <= 32, "batch carries {}", b.real_tokens);
            assert_eq!(b.batch, 4); // ceil(32/8)
            assert_eq!(b.seq, 8);
        }
        // all real tokens preserved across batches
        let total: usize = batches.iter().map(|b| b.real_tokens).sum();
        let expect: usize = exs.iter().map(|e| e.len()).sum();
        assert_eq!(total, expect);
    }

    #[test]
    fn token_budget_uniform_utilization() {
        // paper Prop. 23: utilization approaches 1 regardless of length mix
        let exs: Vec<_> = (0..64).map(|i| ex(2 + (i % 13), 3)).collect();
        let batches = token_budget_batches(&exs, 64, 16);
        let non_final = &batches[..batches.len() - 1];
        for b in non_final {
            assert!(b.real_tokens >= 48, "under-full budget batch: {}", b.real_tokens);
        }
    }

    #[test]
    fn shard_rows_is_balanced_and_covers() {
        for rows in 0..=9usize {
            for workers in 1..=5usize {
                let ranges = shard_rows(rows, workers);
                assert_eq!(ranges.len(), workers);
                assert_eq!(ranges[0].start, 0);
                assert_eq!(ranges.last().unwrap().end, rows);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start, "contiguous coverage");
                }
                let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(max - min <= 1, "balanced: {lens:?}");
                // remainder policy: the bigger shards come first
                assert!(lens.windows(2).all(|w| w[0] >= w[1]), "remainder first: {lens:?}");
            }
        }
    }

    #[test]
    fn shard_preserves_rows_and_accounting() {
        let exs: Vec<_> = (0..24).map(|i| ex(5 + (i % 9), 7)).collect();
        let batches = packed_batches(&exs, 4, 32);
        let b = &batches[0];
        for workers in [1usize, 2, 3, 4, 7] {
            let shards = b.shard(workers).unwrap();
            assert_eq!(shards.len(), workers.min(b.batch));
            let rows: usize = shards.iter().map(|s| s.batch).sum();
            assert_eq!(rows, b.batch);
            assert_eq!(shards.iter().map(|s| s.real_tokens).sum::<usize>(), b.real_tokens);
            assert_eq!(shards.iter().map(|s| s.real_targets).sum::<usize>(), b.real_targets);
            // concatenating shard rows reproduces the original tensors
            let cat: Vec<i32> = shards
                .iter()
                .flat_map(|s| s.tokens.as_i32().unwrap().iter().copied())
                .collect();
            assert_eq!(cat, b.tokens.as_i32().unwrap());
        }
    }

    #[test]
    fn token_budget_segments_isolated() {
        let exs = vec![ex(4, 10), ex(4, 50)];
        let batches = token_budget_batches(&exs, 8, 8);
        assert_eq!(batches.len(), 1);
        let segs = batches[0].seg_ids.as_i32().unwrap();
        // two segments on one row (BFD packs both into the 8-capacity bin)
        assert_eq!(&segs[0..8], &[1, 1, 1, 1, 2, 2, 2, 2]);
    }
}
