//! Lazy batch pipeline: tokenized examples → packing plan → batches on
//! demand.
//!
//! [`BatchStream`] is the one implementation behind every batch layout in
//! the crate: it plans the packing up front (lengths only — cheap), then
//! materializes each `[B, S]` tensor quadruple lazily as the iterator is
//! pulled. Corpora therefore never need to exist as a resident
//! `Vec<Batch>`; the eager [`super::packed_batches`] / [`super::padded_batches`]
//! helpers are thin `collect()` adapters over this stream and keep their
//! historical tail semantics ([`TailPolicy::Drop`]).

use super::{Batch, BatchBuilder};
use crate::data::TokenizedExample;
use crate::packing::{best_fit_decreasing, first_fit_decreasing, next_fit, Bin};
use anyhow::{bail, Result};

/// How examples are arranged into `[B, S]` rows (paper Fig. 18 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackingStrategy {
    /// One example per row, padded to `S` (the baseline; paper Eq. 85).
    Padded,
    /// Best-Fit Decreasing bin packing (the Chronicals default, Alg. 16).
    Bfd,
    /// First-Fit Decreasing (ablation baseline).
    Ffd,
    /// Next-Fit (the weakest packing baseline, §S4.2).
    NextFit,
}

impl PackingStrategy {
    /// Parse a CLI/config name.
    pub fn parse(name: &str) -> Result<PackingStrategy> {
        Ok(match name {
            "padded" | "none" => PackingStrategy::Padded,
            "bfd" => PackingStrategy::Bfd,
            "ffd" => PackingStrategy::Ffd,
            "next-fit" | "next_fit" | "nf" => PackingStrategy::NextFit,
            other => bail!(
                "unknown packing strategy '{other}' (expected padded | bfd | ffd | next-fit)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PackingStrategy::Padded => "padded",
            PackingStrategy::Bfd => "bfd",
            PackingStrategy::Ffd => "ffd",
            PackingStrategy::NextFit => "next-fit",
        }
    }
}

/// What to do with a trailing group of fewer than `batch` rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TailPolicy {
    /// Drop the partial batch (the historical `packed_batches` behavior;
    /// silently loses the tail examples — kept only for the eager adapters
    /// and exact-legacy comparisons).
    Drop,
    /// Emit the partial batch with the remaining rows empty (all padding,
    /// segment id 0). No example is ever lost; the session default.
    Pad,
}

/// Lazy `tokenize → pack → emit` pipeline over an owned example set.
///
/// The packing *plan* (bins of example indices) is computed eagerly from
/// the lengths; batch tensors are built one at a time in [`Iterator::next`].
/// Examples longer than `seq` are dropped by the packing algorithms exactly
/// as in the eager path (paper Alg. 16 "skip oversized") — the count is
/// reported by [`BatchStream::oversized_dropped`] so callers can surface it
/// instead of losing data without trace. `Padded` truncates instead of
/// dropping, mirroring the legacy padded path.
pub struct BatchStream {
    examples: Vec<TokenizedExample>,
    bins: Vec<Bin>,
    oversized: usize,
    batch: usize,
    seq: usize,
    tail: TailPolicy,
    next_bin: usize,
}

impl BatchStream {
    pub fn new(
        examples: Vec<TokenizedExample>,
        strategy: PackingStrategy,
        batch: usize,
        seq: usize,
        tail: TailPolicy,
    ) -> BatchStream {
        assert!(batch > 0 && seq > 0, "batch geometry must be positive");
        let (bins, oversized) = match strategy {
            PackingStrategy::Padded => {
                let bins = examples
                    .iter()
                    .enumerate()
                    .map(|(i, e)| Bin { items: vec![i], used: e.len().min(seq) })
                    .collect();
                (bins, 0)
            }
            _ => {
                let lengths: Vec<usize> = examples.iter().map(|e| e.len()).collect();
                let packing = match strategy {
                    PackingStrategy::Bfd => best_fit_decreasing(&lengths, seq),
                    PackingStrategy::Ffd => first_fit_decreasing(&lengths, seq),
                    PackingStrategy::NextFit => next_fit(&lengths, seq),
                    PackingStrategy::Padded => unreachable!(),
                };
                (packing.bins, packing.oversized.len())
            }
        };
        BatchStream { examples, bins, oversized, batch, seq, tail, next_bin: 0 }
    }

    /// Total batches this stream will emit (known from the plan).
    pub fn n_batches(&self) -> usize {
        match self.tail {
            TailPolicy::Drop => self.bins.len() / self.batch,
            TailPolicy::Pad => self.bins.len().div_ceil(self.batch),
        }
    }

    /// Planned row-bins (each bin becomes one `[S]` row).
    pub fn n_bins(&self) -> usize {
        self.bins.len()
    }

    /// Examples skipped by the packing plan because they exceed `seq`.
    pub fn oversized_dropped(&self) -> usize {
        self.oversized
    }

    /// Whether the final emitted batch carries empty padding rows.
    pub fn tail_padded(&self) -> bool {
        self.tail == TailPolicy::Pad && !self.bins.is_empty() && self.bins.len() % self.batch != 0
    }
}

impl Iterator for BatchStream {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.next_bin >= self.bins.len() {
            return None;
        }
        let end = (self.next_bin + self.batch).min(self.bins.len());
        if end - self.next_bin < self.batch && self.tail == TailPolicy::Drop {
            self.next_bin = self.bins.len();
            return None;
        }
        let mut b = BatchBuilder::new(self.batch, self.seq);
        for (row, bin) in self.bins[self.next_bin..end].iter().enumerate() {
            let mut offset = 0;
            for (seg, &item) in bin.items.iter().enumerate() {
                let ex = &self.examples[item];
                b.place(row, offset, ex, (seg + 1) as i32);
                offset += ex.len().min(self.seq - offset);
                if offset >= self.seq {
                    break;
                }
            }
        }
        self.next_bin = end;
        Some(b.finish())
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.bins.len() - self.next_bin;
        let n = match self.tail {
            TailPolicy::Drop => left / self.batch,
            TailPolicy::Pad => left.div_ceil(self.batch),
        };
        (n, Some(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(n: usize, base: i32) -> TokenizedExample {
        let tokens: Vec<i32> = (0..n as i32).map(|i| base + i).collect();
        let mut targets: Vec<i32> = tokens.iter().skip(1).copied().collect();
        targets.push(-1);
        TokenizedExample { tokens, targets }
    }

    fn corpus(n: usize) -> Vec<TokenizedExample> {
        (0..n).map(|i| ex(3 + (i % 7), 10 + i as i32)).collect()
    }

    #[test]
    fn drop_policy_matches_eager_adapters_exactly() {
        let exs = corpus(37);
        for (strategy, eager) in [
            (PackingStrategy::Bfd, super::super::packed_batches(&exs, 4, 16)),
            (PackingStrategy::Padded, super::super::padded_batches(&exs, 4, 16)),
        ] {
            let streamed: Vec<Batch> =
                BatchStream::new(exs.clone(), strategy, 4, 16, TailPolicy::Drop).collect();
            assert_eq!(streamed.len(), eager.len(), "{strategy:?}");
            for (a, b) in streamed.iter().zip(&eager) {
                assert_eq!(a.tokens, b.tokens, "{strategy:?}");
                assert_eq!(a.targets, b.targets);
                assert_eq!(a.seg_ids, b.seg_ids);
                assert_eq!(a.pos_ids, b.pos_ids);
                assert_eq!(a.real_tokens, b.real_tokens);
                assert_eq!(a.real_targets, b.real_targets);
            }
        }
    }

    #[test]
    fn pad_policy_keeps_every_example() {
        let exs = corpus(13); // 13 singleton rows won't divide by 4
        let total: usize = exs.iter().map(|e| e.len()).sum();
        let mut s = BatchStream::new(exs, PackingStrategy::Padded, 4, 16, TailPolicy::Pad);
        assert_eq!(s.n_batches(), 4); // ceil(13/4)
        assert!(s.tail_padded());
        let got: usize = s.by_ref().map(|b| b.real_tokens).sum();
        assert_eq!(got, total, "padding the tail must not lose tokens");
        assert!(s.next().is_none());
    }

    #[test]
    fn drop_policy_loses_the_tail() {
        let exs = corpus(13);
        let s = BatchStream::new(exs, PackingStrategy::Padded, 4, 16, TailPolicy::Drop);
        assert_eq!(s.n_batches(), 3); // floor(13/4): one example dropped
        assert!(!s.tail_padded());
        assert_eq!(s.count(), 3);
    }

    #[test]
    fn oversized_examples_are_counted_not_silent() {
        let exs = vec![ex(40, 1), ex(5, 2), ex(6, 3)];
        let s = BatchStream::new(exs, PackingStrategy::Bfd, 1, 16, TailPolicy::Pad);
        assert_eq!(s.oversized_dropped(), 1);
        assert_eq!(s.n_batches(), 1); // 5+6 pack into one 16-capacity bin
    }

    #[test]
    fn ffd_and_next_fit_strategies_emit_plans() {
        let exs = corpus(24);
        for strategy in [PackingStrategy::Ffd, PackingStrategy::NextFit] {
            let s = BatchStream::new(exs.clone(), strategy, 2, 16, TailPolicy::Pad);
            let total: usize = exs.iter().map(|e| e.len()).sum();
            let got: usize = s.map(|b| b.real_tokens).sum();
            assert_eq!(got, total, "{strategy:?}");
        }
    }

    #[test]
    fn size_hint_is_exact() {
        let exs = corpus(10);
        let mut s = BatchStream::new(exs, PackingStrategy::Padded, 4, 16, TailPolicy::Pad);
        assert_eq!(s.size_hint(), (3, Some(3)));
        s.next();
        assert_eq!(s.size_hint(), (2, Some(2)));
    }

    #[test]
    fn parse_names() {
        assert_eq!(PackingStrategy::parse("bfd").unwrap(), PackingStrategy::Bfd);
        assert_eq!(PackingStrategy::parse("padded").unwrap(), PackingStrategy::Padded);
        assert_eq!(PackingStrategy::parse("next-fit").unwrap(), PackingStrategy::NextFit);
        assert!(PackingStrategy::parse("zip").is_err());
        assert_eq!(PackingStrategy::Ffd.name(), "ffd");
    }
}
