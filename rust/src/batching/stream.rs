//! Lazy batch pipeline: tokenized examples → packing plan → batches on
//! demand.
//!
//! [`BatchStream`] is the one implementation behind every batch layout in
//! the crate: it plans the packing up front (lengths only — cheap), then
//! materializes each `[B, S]` tensor quadruple lazily as the iterator is
//! pulled. Corpora therefore never need to exist as a resident
//! `Vec<Batch>`; the eager [`super::packed_batches`] / [`super::padded_batches`]
//! helpers are thin `collect()` adapters over this stream and keep their
//! historical tail semantics ([`TailPolicy::Drop`]).

use super::{Batch, BatchBuilder};
use crate::data::TokenizedExample;
use crate::packing::{best_fit_decreasing, first_fit_decreasing, next_fit, Bin};
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// How examples are arranged into `[B, S]` rows (paper Fig. 18 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackingStrategy {
    /// One example per row, padded to `S` (the baseline; paper Eq. 85).
    Padded,
    /// Best-Fit Decreasing bin packing (the Chronicals default, Alg. 16).
    Bfd,
    /// First-Fit Decreasing (ablation baseline).
    Ffd,
    /// Next-Fit (the weakest packing baseline, §S4.2).
    NextFit,
}

impl PackingStrategy {
    /// Parse a CLI/config name.
    pub fn parse(name: &str) -> Result<PackingStrategy> {
        Ok(match name {
            "padded" | "none" => PackingStrategy::Padded,
            "bfd" => PackingStrategy::Bfd,
            "ffd" => PackingStrategy::Ffd,
            "next-fit" | "next_fit" | "nf" => PackingStrategy::NextFit,
            other => bail!(
                "unknown packing strategy '{other}' (expected padded | bfd | ffd | next-fit)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PackingStrategy::Padded => "padded",
            PackingStrategy::Bfd => "bfd",
            PackingStrategy::Ffd => "ffd",
            PackingStrategy::NextFit => "next-fit",
        }
    }
}

/// What to do with a trailing group of fewer than `batch` rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TailPolicy {
    /// Drop the partial batch (the historical `packed_batches` behavior;
    /// silently loses the tail examples — kept only for the eager adapters
    /// and exact-legacy comparisons).
    Drop,
    /// Emit the partial batch with the remaining rows empty (all padding,
    /// segment id 0). No example is ever lost; the session default.
    Pad,
}

/// How many passes a [`BatchStream`] makes over its packing plan, and
/// whether each pass reorders it. The session's
/// [`crate::session::EpochPolicy`] lowers into this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EpochSpec {
    /// Deterministic per-epoch shuffle seed for the bin order; `None`
    /// keeps plan order (bitwise-legacy).
    pub shuffle: Option<u64>,
    /// Number of passes over the plan (≥ 1).
    pub epochs: u64,
}

impl Default for EpochSpec {
    fn default() -> EpochSpec {
        EpochSpec { shuffle: None, epochs: 1 }
    }
}

/// Lazy `tokenize → pack → emit` pipeline over an owned example set.
///
/// The packing *plan* (bins of example indices) is computed eagerly from
/// the lengths; batch tensors are built one at a time in [`Iterator::next`].
/// Examples longer than `seq` are dropped by the packing algorithms exactly
/// as in the eager path (paper Alg. 16 "skip oversized") — the count is
/// reported by [`BatchStream::oversized_dropped`] so callers can surface it
/// instead of losing data without trace. `Padded` truncates instead of
/// dropping, mirroring the legacy padded path.
///
/// With [`BatchStream::with_epochs`] the stream makes several passes over
/// the plan; a shuffle seed permutes the *bin order* deterministically per
/// epoch (examples are packed once and never re-tokenized — each epoch
/// emits the same bins, possibly grouped into different batches).
pub struct BatchStream {
    examples: Vec<TokenizedExample>,
    bins: Vec<Bin>,
    oversized: usize,
    batch: usize,
    seq: usize,
    tail: TailPolicy,
    /// Bin emission order for the current epoch (indices into `bins`).
    order: Vec<usize>,
    next_bin: usize,
    epoch: u64,
    epochs: u64,
    shuffle: Option<u64>,
}

impl BatchStream {
    /// Single-pass stream in plan order — the legacy constructor; exactly
    /// `with_epochs(…, EpochSpec::default())`.
    pub fn new(
        examples: Vec<TokenizedExample>,
        strategy: PackingStrategy,
        batch: usize,
        seq: usize,
        tail: TailPolicy,
    ) -> BatchStream {
        Self::with_epochs(examples, strategy, batch, seq, tail, EpochSpec::default())
    }

    /// Multi-epoch stream: `epoch.epochs` passes over the packing plan,
    /// each pass's bin order permuted by `epoch.shuffle` (identity when
    /// `None` — bitwise identical to [`BatchStream::new`]).
    pub fn with_epochs(
        examples: Vec<TokenizedExample>,
        strategy: PackingStrategy,
        batch: usize,
        seq: usize,
        tail: TailPolicy,
        epoch: EpochSpec,
    ) -> BatchStream {
        assert!(batch > 0 && seq > 0, "batch geometry must be positive");
        assert!(epoch.epochs >= 1, "epochs must be ≥ 1");
        let (bins, oversized) = match strategy {
            PackingStrategy::Padded => {
                let bins = examples
                    .iter()
                    .enumerate()
                    .map(|(i, e)| Bin { items: vec![i], used: e.len().min(seq) })
                    .collect();
                (bins, 0)
            }
            _ => {
                let lengths: Vec<usize> = examples.iter().map(|e| e.len()).collect();
                let packing = match strategy {
                    PackingStrategy::Bfd => best_fit_decreasing(&lengths, seq),
                    PackingStrategy::Ffd => first_fit_decreasing(&lengths, seq),
                    PackingStrategy::NextFit => next_fit(&lengths, seq),
                    PackingStrategy::Padded => unreachable!(),
                };
                (packing.bins, packing.oversized.len())
            }
        };
        let mut s = BatchStream {
            examples,
            bins,
            oversized,
            batch,
            seq,
            tail,
            order: Vec::new(),
            next_bin: 0,
            epoch: 0,
            epochs: epoch.epochs,
            shuffle: epoch.shuffle,
        };
        s.plan_epoch();
        s
    }

    /// (Re)compute the bin order for the current epoch: identity without a
    /// shuffle seed; otherwise a Fisher–Yates permutation seeded by a
    /// golden-ratio mix of (seed, epoch) — epoch 0 uses the seed itself,
    /// and each epoch draws an unrelated permutation.
    fn plan_epoch(&mut self) {
        self.order = (0..self.bins.len()).collect();
        if let Some(seed) = self.shuffle {
            let mixed = seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(self.epoch);
            Rng::new(mixed).shuffle(&mut self.order);
        }
    }

    /// Batches one pass over the plan emits.
    pub fn batches_per_epoch(&self) -> usize {
        match self.tail {
            TailPolicy::Drop => self.bins.len() / self.batch,
            TailPolicy::Pad => self.bins.len().div_ceil(self.batch),
        }
    }

    /// Total batches this stream will emit across every epoch (known from
    /// the plan).
    pub fn n_batches(&self) -> usize {
        self.batches_per_epoch() * self.epochs as usize
    }

    /// Planned row-bins per epoch (each bin becomes one `[S]` row).
    pub fn n_bins(&self) -> usize {
        self.bins.len()
    }

    /// Real tokens one pass over the plan carries (Σ bin.used — the
    /// numerator of the packing-density accounting).
    pub fn planned_tokens(&self) -> usize {
        self.bins.iter().map(|b| b.used).sum()
    }

    /// Examples skipped by the packing plan because they exceed `seq`
    /// (counted once — the plan is shared by every epoch).
    pub fn oversized_dropped(&self) -> usize {
        self.oversized
    }

    /// Whether each epoch's final emitted batch carries empty padding rows.
    pub fn tail_padded(&self) -> bool {
        self.tail == TailPolicy::Pad && !self.bins.is_empty() && self.bins.len() % self.batch != 0
    }
}

impl Iterator for BatchStream {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        loop {
            if self.next_bin >= self.order.len() {
                // epoch rollover (or plain exhaustion for epochs == 1)
                if self.epoch + 1 >= self.epochs || self.order.is_empty() {
                    return None;
                }
                self.epoch += 1;
                self.next_bin = 0;
                self.plan_epoch();
            }
            let end = (self.next_bin + self.batch).min(self.order.len());
            if end - self.next_bin < self.batch && self.tail == TailPolicy::Drop {
                self.next_bin = self.order.len();
                continue; // may roll into the next epoch
            }
            let mut b = BatchBuilder::new(self.batch, self.seq);
            for (row, &bin_idx) in self.order[self.next_bin..end].iter().enumerate() {
                let bin = &self.bins[bin_idx];
                let mut offset = 0;
                for (seg, &item) in bin.items.iter().enumerate() {
                    let ex = &self.examples[item];
                    b.place(row, offset, ex, (seg + 1) as i32);
                    offset += ex.len().min(self.seq - offset);
                    if offset >= self.seq {
                        break;
                    }
                }
            }
            self.next_bin = end;
            return Some(b.finish());
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.order.len() - self.next_bin;
        let current = match self.tail {
            TailPolicy::Drop => left / self.batch,
            TailPolicy::Pad => left.div_ceil(self.batch),
        };
        let future_epochs = (self.epochs - self.epoch - 1) as usize;
        let n = current + future_epochs * self.batches_per_epoch();
        (n, Some(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(n: usize, base: i32) -> TokenizedExample {
        let tokens: Vec<i32> = (0..n as i32).map(|i| base + i).collect();
        let mut targets: Vec<i32> = tokens.iter().skip(1).copied().collect();
        targets.push(-1);
        TokenizedExample { tokens, targets }
    }

    fn corpus(n: usize) -> Vec<TokenizedExample> {
        (0..n).map(|i| ex(3 + (i % 7), 10 + i as i32)).collect()
    }

    #[test]
    fn drop_policy_matches_eager_adapters_exactly() {
        let exs = corpus(37);
        for (strategy, eager) in [
            (PackingStrategy::Bfd, super::super::packed_batches(&exs, 4, 16)),
            (PackingStrategy::Padded, super::super::padded_batches(&exs, 4, 16)),
        ] {
            let streamed: Vec<Batch> =
                BatchStream::new(exs.clone(), strategy, 4, 16, TailPolicy::Drop).collect();
            assert_eq!(streamed.len(), eager.len(), "{strategy:?}");
            for (a, b) in streamed.iter().zip(&eager) {
                assert_eq!(a.tokens, b.tokens, "{strategy:?}");
                assert_eq!(a.targets, b.targets);
                assert_eq!(a.seg_ids, b.seg_ids);
                assert_eq!(a.pos_ids, b.pos_ids);
                assert_eq!(a.real_tokens, b.real_tokens);
                assert_eq!(a.real_targets, b.real_targets);
            }
        }
    }

    #[test]
    fn pad_policy_keeps_every_example() {
        let exs = corpus(13); // 13 singleton rows won't divide by 4
        let total: usize = exs.iter().map(|e| e.len()).sum();
        let mut s = BatchStream::new(exs, PackingStrategy::Padded, 4, 16, TailPolicy::Pad);
        assert_eq!(s.n_batches(), 4); // ceil(13/4)
        assert!(s.tail_padded());
        let got: usize = s.by_ref().map(|b| b.real_tokens).sum();
        assert_eq!(got, total, "padding the tail must not lose tokens");
        assert!(s.next().is_none());
    }

    #[test]
    fn drop_policy_loses_the_tail() {
        let exs = corpus(13);
        let s = BatchStream::new(exs, PackingStrategy::Padded, 4, 16, TailPolicy::Drop);
        assert_eq!(s.n_batches(), 3); // floor(13/4): one example dropped
        assert!(!s.tail_padded());
        assert_eq!(s.count(), 3);
    }

    #[test]
    fn oversized_examples_are_counted_not_silent() {
        let exs = vec![ex(40, 1), ex(5, 2), ex(6, 3)];
        let s = BatchStream::new(exs, PackingStrategy::Bfd, 1, 16, TailPolicy::Pad);
        assert_eq!(s.oversized_dropped(), 1);
        assert_eq!(s.n_batches(), 1); // 5+6 pack into one 16-capacity bin
    }

    #[test]
    fn ffd_and_next_fit_strategies_emit_plans() {
        let exs = corpus(24);
        for strategy in [PackingStrategy::Ffd, PackingStrategy::NextFit] {
            let s = BatchStream::new(exs.clone(), strategy, 2, 16, TailPolicy::Pad);
            let total: usize = exs.iter().map(|e| e.len()).sum();
            let got: usize = s.map(|b| b.real_tokens).sum();
            assert_eq!(got, total, "{strategy:?}");
        }
    }

    #[test]
    fn size_hint_is_exact() {
        let exs = corpus(10);
        let mut s = BatchStream::new(exs, PackingStrategy::Padded, 4, 16, TailPolicy::Pad);
        assert_eq!(s.size_hint(), (3, Some(3)));
        s.next();
        assert_eq!(s.size_hint(), (2, Some(2)));
    }

    /// All real (segment ≠ 0) token ids a batch carries, in slot order.
    fn real_tokens_of(b: &Batch) -> Vec<i32> {
        let toks = b.tokens.as_i32().unwrap();
        let segs = b.seg_ids.as_i32().unwrap();
        toks.iter().zip(segs).filter(|(_, &s)| s != 0).map(|(&t, _)| t).collect()
    }

    #[test]
    fn no_shuffle_single_epoch_is_bitwise_legacy() {
        let exs = corpus(23);
        let legacy: Vec<Batch> =
            BatchStream::new(exs.clone(), PackingStrategy::Bfd, 4, 16, TailPolicy::Pad)
                .collect();
        let explicit: Vec<Batch> = BatchStream::with_epochs(
            exs,
            PackingStrategy::Bfd,
            4,
            16,
            TailPolicy::Pad,
            EpochSpec { shuffle: None, epochs: 1 },
        )
        .collect();
        assert_eq!(legacy.len(), explicit.len());
        for (a, b) in legacy.iter().zip(&explicit) {
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.targets, b.targets);
            assert_eq!(a.seg_ids, b.seg_ids);
            assert_eq!(a.pos_ids, b.pos_ids);
        }
    }

    #[test]
    fn epochs_repeat_the_plan() {
        let exs = corpus(17);
        let one = BatchStream::with_epochs(
            exs.clone(),
            PackingStrategy::Bfd,
            2,
            16,
            TailPolicy::Pad,
            EpochSpec { shuffle: None, epochs: 1 },
        );
        let per_epoch = one.batches_per_epoch();
        let first: Vec<Batch> = one.collect();
        let three = BatchStream::with_epochs(
            exs,
            PackingStrategy::Bfd,
            2,
            16,
            TailPolicy::Pad,
            EpochSpec { shuffle: None, epochs: 3 },
        );
        assert_eq!(three.n_batches(), 3 * per_epoch);
        assert_eq!(three.size_hint(), (3 * per_epoch, Some(3 * per_epoch)));
        let all: Vec<Batch> = three.collect();
        assert_eq!(all.len(), 3 * per_epoch);
        // without a shuffle seed every epoch is identical
        for e in 1..3 {
            for i in 0..per_epoch {
                assert_eq!(all[e * per_epoch + i].tokens, first[i].tokens, "epoch {e} batch {i}");
            }
        }
    }

    #[test]
    fn shuffled_epochs_preserve_the_token_multiset() {
        let exs = corpus(29);
        let epochs = 3usize;
        let plain: Vec<Batch> =
            BatchStream::new(exs.clone(), PackingStrategy::Bfd, 4, 16, TailPolicy::Pad)
                .collect();
        let mut expected: Vec<i32> = plain.iter().flat_map(real_tokens_of).collect();
        expected.sort_unstable();

        let per_epoch = plain.len();
        let shuffled: Vec<Batch> = BatchStream::with_epochs(
            exs,
            PackingStrategy::Bfd,
            4,
            16,
            TailPolicy::Pad,
            EpochSpec { shuffle: Some(7), epochs: epochs as u64 },
        )
        .collect();
        assert_eq!(shuffled.len(), epochs * per_epoch);
        for e in 0..epochs {
            let mut got: Vec<i32> = shuffled[e * per_epoch..(e + 1) * per_epoch]
                .iter()
                .flat_map(real_tokens_of)
                .collect();
            got.sort_unstable();
            assert_eq!(got, expected, "epoch {e} must carry the exact token multiset");
        }
    }

    #[test]
    fn shuffle_is_deterministic_and_epoch_dependent() {
        let exs = corpus(29);
        let collect = |seed: u64| -> Vec<Vec<i32>> {
            BatchStream::with_epochs(
                exs.clone(),
                PackingStrategy::Bfd,
                4,
                16,
                TailPolicy::Pad,
                EpochSpec { shuffle: Some(seed), epochs: 2 },
            )
            .map(|b| b.tokens.as_i32().unwrap().to_vec())
            .collect()
        };
        assert_eq!(collect(7), collect(7), "same seed ⇒ same batches, bit for bit");
        assert_ne!(collect(7), collect(8), "different seed ⇒ different order");
        let two = collect(7);
        let per_epoch = two.len() / 2;
        assert_ne!(
            two[..per_epoch],
            two[per_epoch..],
            "each epoch draws its own permutation"
        );
    }

    #[test]
    fn drop_tail_rolls_across_epochs() {
        // 3 singleton bins at batch 2, Drop tail: each epoch emits 1 batch
        let exs = corpus(3);
        let s = BatchStream::with_epochs(
            exs,
            PackingStrategy::Padded,
            2,
            16,
            TailPolicy::Drop,
            EpochSpec { shuffle: None, epochs: 2 },
        );
        assert_eq!(s.n_batches(), 2);
        assert_eq!(s.count(), 2);
    }

    #[test]
    fn parse_names() {
        assert_eq!(PackingStrategy::parse("bfd").unwrap(), PackingStrategy::Bfd);
        assert_eq!(PackingStrategy::parse("padded").unwrap(), PackingStrategy::Padded);
        assert_eq!(PackingStrategy::parse("next-fit").unwrap(), PackingStrategy::NextFit);
        assert!(PackingStrategy::parse("zip").is_err());
        assert_eq!(PackingStrategy::Ffd.name(), "ffd");
    }
}
