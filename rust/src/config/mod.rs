//! Run configuration: TOML files + presets mirroring the paper's Table 7
//! hyperparameters and every benchmark row.

use crate::util::toml::TomlDoc;
use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Train-step executable name (manifest key), e.g. "train_step_chronicals".
    pub executable: String,
    /// Matching init executable (empty = derive `init_<variant>`).
    pub init_executable: String,
    pub steps: u64,
    pub warmup_steps: usize,
    pub seed: u64,
    /// Use BFD-packed batches (true) or padded batches (false).
    pub packed: bool,
    pub lr: f64,
    /// LoRA+ ratio λ = η_B/η_A; 1.0 disables LoRA+.
    pub lora_plus_ratio: f64,
    pub lr_schedule: String, // "constant" | "warmup_cosine"
    pub lr_warmup_steps: u64,
    pub corpus_examples: usize,
    pub max_seq: usize,
    /// File-backed JSONL instruction corpus (`--data-file` / `data.file`);
    /// empty = the synthetic corpus.
    pub data_file: String,
    /// Tokenizer vocab file for the JSONL source (loaded when present,
    /// learned from the corpus and written there when absent); empty =
    /// learn in memory each run.
    pub tokenizer_file: String,
    /// Deterministic per-epoch shuffle seed for the batch plan.
    pub shuffle_seed: Option<u64>,
    /// Number of data passes; `None` = legacy cycle-to-`steps`.
    pub epochs: Option<u64>,
    /// Held-out eval fraction in (0, 1); `None` = no eval split.
    pub eval_fraction: Option<f64>,
    /// Which token positions the loss supervises: "response-only"
    /// (default) or "full". Empty = the default.
    pub loss_mode: String,
    pub artifacts_dir: String,
    /// Execution backend: "cpu" (reference oracle), "cpu-fast" (threaded
    /// fused kernels) or "pjrt" (AOT artifacts, `--features pjrt`).
    pub backend: String,
    /// Worker threads for the fast backend; 0 = autodetect
    /// (`available_parallelism`). Overridden by `CHRONICALS_THREADS`.
    pub threads: usize,
    /// Data-parallel replica count (`--workers` / `backend.workers`):
    /// shard each batch row-wise across `n` backend replicas and reduce
    /// gradients through the fixed-order tree. 0 = the legacy
    /// single-backend path (the default).
    pub workers: usize,
    /// AdamW m/v slot codec (`--optim-states` / `optim.states`):
    /// "fp32" (default) or "int8". Empty = the default.
    pub optim_states: String,
    /// Frozen-base weight codec for LoRA-family tasks (`--base-quant` /
    /// `optim.base_quant`): "none" (default), "int8" or "fp8". Empty =
    /// none.
    pub base_quant: String,
    /// Activation-checkpoint segment count (`--ckpt-segments` /
    /// `optim.ckpt_segments`); 0 = off.
    pub ckpt_segments: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            // full fine-tuning; the name comes from the session::resolve
            // seam so this stringly front-end never spells it itself
            executable: crate::session::resolve::train_executable(
                &crate::session::Task::FullFinetune,
            ),
            init_executable: String::new(),
            steps: 50,
            warmup_steps: 3,
            seed: 42,
            packed: true,
            lr: 2e-4,
            lora_plus_ratio: 1.0,
            lr_schedule: "constant".into(),
            lr_warmup_steps: 0,
            corpus_examples: 2048,
            max_seq: 1024,
            data_file: String::new(),
            tokenizer_file: String::new(),
            shuffle_seed: None,
            epochs: None,
            eval_fraction: None,
            loss_mode: String::new(),
            artifacts_dir: "artifacts".into(),
            backend: "cpu".into(),
            threads: 0,
            workers: 0,
            optim_states: String::new(),
            base_quant: String::new(),
            ckpt_segments: 0,
        }
    }
}

/// `CHRONICALS_THREADS`, when set to a positive integer. The environment
/// overrides both config files and `--threads` flags.
pub fn env_threads() -> Option<usize> {
    env_threads_from(std::env::var("CHRONICALS_THREADS").ok().as_deref())
}

/// Testable core of [`env_threads`] (no process-global env access).
pub fn env_threads_from(raw: Option<&str>) -> Option<usize> {
    raw.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

/// Resolve a worker-thread count: an explicit positive value wins, else the
/// `CHRONICALS_THREADS` env override, else `available_parallelism`.
pub fn resolve_threads(explicit: usize) -> usize {
    if explicit > 0 {
        return explicit;
    }
    if let Some(n) = env_threads() {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl RunConfig {
    pub fn from_file(path: impl AsRef<Path>) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = TomlDoc::parse(text)?;
        let d = RunConfig::default();
        // a negative value must not wrap through `as u64` into ~1.8e19
        let opt_u64 = |key: &str| -> Result<Option<u64>> {
            match doc.get(key).and_then(|v| v.as_i64()) {
                Some(v) if v < 0 => bail!("{key} must be non-negative (got {v})"),
                Some(v) => Ok(Some(v as u64)),
                None => Ok(None),
            }
        };
        Ok(RunConfig {
            executable: doc.str_or("train.executable", &d.executable).to_string(),
            init_executable: doc.str_or("train.init_executable", "").to_string(),
            steps: doc.i64_or("train.steps", d.steps as i64) as u64,
            warmup_steps: doc.i64_or("train.warmup_steps", d.warmup_steps as i64) as usize,
            seed: doc.i64_or("train.seed", d.seed as i64) as u64,
            packed: doc.bool_or("data.packed", d.packed),
            lr: doc.f64_or("optim.lr", d.lr),
            lora_plus_ratio: doc.f64_or("optim.lora_plus_ratio", d.lora_plus_ratio),
            lr_schedule: doc.str_or("optim.lr_schedule", &d.lr_schedule).to_string(),
            lr_warmup_steps: doc.i64_or("optim.lr_warmup_steps", 0) as u64,
            corpus_examples: doc.i64_or("data.corpus_examples", d.corpus_examples as i64)
                as usize,
            max_seq: doc.i64_or("data.max_seq", d.max_seq as i64) as usize,
            data_file: doc.str_or("data.file", "").to_string(),
            tokenizer_file: doc.str_or("data.tokenizer", "").to_string(),
            shuffle_seed: opt_u64("data.shuffle_seed")?,
            epochs: opt_u64("data.epochs")?,
            eval_fraction: doc.get("data.eval_fraction").and_then(|v| v.as_f64()),
            loss_mode: doc.str_or("data.loss_mode", "").to_string(),
            artifacts_dir: doc.str_or("artifacts_dir", &d.artifacts_dir).to_string(),
            backend: doc.str_or("backend.name", &d.backend).to_string(),
            threads: doc.i64_or("backend.threads", d.threads as i64).max(0) as usize,
            workers: doc.i64_or("backend.workers", d.workers as i64).max(0) as usize,
            optim_states: doc.str_or("optim.states", "").to_string(),
            base_quant: doc.str_or("optim.base_quant", "").to_string(),
            ckpt_segments: doc.i64_or("optim.ckpt_segments", 0).max(0) as usize,
        })
    }

    /// Effective worker-thread request for this run: the
    /// `CHRONICALS_THREADS` env override beats the configured value
    /// (0 = let the backend autodetect).
    pub fn effective_threads(&self) -> usize {
        env_threads().unwrap_or(self.threads)
    }

    /// Paper Table 7 presets. Executable names come from the typed task
    /// table behind `session::resolve` — this front-end never spells
    /// `train_step_*` strings itself. (The `e2e` preset targets the
    /// PJRT-only e2e-scale executable, which has no typed task; its name
    /// lives in `resolve::E2E_EXECUTABLE`.)
    pub fn preset(name: &str) -> Option<RunConfig> {
        use crate::session::{resolve, Task};
        let mut c = RunConfig::default();
        match name {
            "full_ft" => {
                c.executable = resolve::train_executable(&Task::FullFinetune);
                c.lr = 2e-5 * 10.0; // scaled for the small substrate model
                c.lora_plus_ratio = 1.0;
            }
            "lora" => {
                c.executable = resolve::train_executable(&Task::lora());
                c.lr = 1e-4 * 10.0;
                c.lora_plus_ratio = 1.0;
            }
            "lora_plus" => {
                c.executable = resolve::train_executable(&Task::lora_plus(16.0));
                c.lr = 1e-4 * 10.0;
                c.lora_plus_ratio = 16.0;
            }
            "e2e" => {
                c.executable = resolve::E2E_EXECUTABLE.into();
                c.steps = 300;
                c.lr = 3e-4;
                c.lr_schedule = "warmup_cosine".into();
                c.lr_warmup_steps = 10;
            }
            _ => return None,
        }
        Some(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_parse_from_empty() {
        let c = RunConfig::from_toml("").unwrap();
        assert_eq!(c, RunConfig::default());
    }

    #[test]
    fn full_config_parses() {
        let c = RunConfig::from_toml(
            r#"
artifacts_dir = "artifacts"
[train]
executable = "train_step_lora"
steps = 25
warmup_steps = 2
seed = 7
[data]
packed = false
corpus_examples = 512
max_seq = 256
[optim]
lr = 1e-3
lora_plus_ratio = 16.0
lr_schedule = "warmup_cosine"
lr_warmup_steps = 5
"#,
        )
        .unwrap();
        assert_eq!(c.executable, "train_step_lora");
        assert_eq!(c.steps, 25);
        assert!(!c.packed);
        assert_eq!(c.lora_plus_ratio, 16.0);
    }

    #[test]
    fn data_file_section_parses() {
        let c = RunConfig::from_toml(
            r#"
[data]
file = "data/sample.jsonl"
tokenizer = "data/sample.vocab"
shuffle_seed = 7
epochs = 2
"#,
        )
        .unwrap();
        assert_eq!(c.data_file, "data/sample.jsonl");
        assert_eq!(c.tokenizer_file, "data/sample.vocab");
        assert_eq!(c.shuffle_seed, Some(7));
        assert_eq!(c.epochs, Some(2));
        // eval/loss-mode keys parse and default to off
        let e = RunConfig::from_toml(
            "[data]\neval_fraction = 0.2\nloss_mode = \"full\"\n",
        )
        .unwrap();
        assert_eq!(e.eval_fraction, Some(0.2));
        assert_eq!(e.loss_mode, "full");
        let d0 = RunConfig::from_toml("").unwrap();
        assert_eq!(d0.eval_fraction, None);
        assert!(d0.loss_mode.is_empty());
        // absent keys stay None/empty (legacy behavior)
        let d = RunConfig::from_toml("").unwrap();
        assert!(d.data_file.is_empty());
        assert!(d.tokenizer_file.is_empty());
        assert_eq!(d.shuffle_seed, None);
        assert_eq!(d.epochs, None);
        // a negative epoch count must error, not wrap to ~1.8e19 passes
        let err = RunConfig::from_toml("[data]\nepochs = -1\n").unwrap_err();
        assert!(err.to_string().contains("non-negative"), "{err}");
        let err = RunConfig::from_toml("[data]\nshuffle_seed = -3\n").unwrap_err();
        assert!(err.to_string().contains("non-negative"), "{err}");
    }

    #[test]
    fn presets_exist() {
        for p in ["full_ft", "lora", "lora_plus", "e2e"] {
            assert!(RunConfig::preset(p).is_some(), "{p}");
        }
        assert!(RunConfig::preset("nope").is_none());
    }

    #[test]
    fn lora_plus_preset_has_ratio_16() {
        let c = RunConfig::preset("lora_plus").unwrap();
        assert_eq!(c.lora_plus_ratio, 16.0);
    }

    #[test]
    fn backend_section_parses() {
        let c = RunConfig::from_toml(
            r#"
[backend]
name = "cpu-fast"
threads = 3
"#,
        )
        .unwrap();
        assert_eq!(c.backend, "cpu-fast");
        assert_eq!(c.threads, 3);
        // defaults: reference backend, autodetected threads
        let d = RunConfig::from_toml("").unwrap();
        assert_eq!(d.backend, "cpu");
        assert_eq!(d.threads, 0);
        assert_eq!(d.workers, 0, "workers default to the legacy path");
    }

    #[test]
    fn optim_memory_tier_keys_parse() {
        let c = RunConfig::from_toml(
            "[optim]\nstates = \"int8\"\nbase_quant = \"fp8\"\nckpt_segments = 2\n",
        )
        .unwrap();
        assert_eq!(c.optim_states, "int8");
        assert_eq!(c.base_quant, "fp8");
        assert_eq!(c.ckpt_segments, 2);
        // defaults: legacy fp32/dense/no-checkpoint path
        let d = RunConfig::from_toml("").unwrap();
        assert!(d.optim_states.is_empty());
        assert!(d.base_quant.is_empty());
        assert_eq!(d.ckpt_segments, 0);
        // negative segment counts clamp to 0 (= off) rather than wrapping
        let n = RunConfig::from_toml("[optim]\nckpt_segments = -3\n").unwrap();
        assert_eq!(n.ckpt_segments, 0);
    }

    #[test]
    fn backend_workers_key_parses() {
        let c = RunConfig::from_toml("[backend]\nname = \"cpu-fast\"\nworkers = 4\n").unwrap();
        assert_eq!(c.workers, 4);
        // negative values clamp to 0 (= unset) rather than wrapping
        let c = RunConfig::from_toml("[backend]\nworkers = -2\n").unwrap();
        assert_eq!(c.workers, 0);
    }

    #[test]
    fn env_threads_parsing() {
        assert_eq!(env_threads_from(None), None);
        assert_eq!(env_threads_from(Some("")), None);
        assert_eq!(env_threads_from(Some("zero")), None);
        assert_eq!(env_threads_from(Some("0")), None, "0 means unset, not zero workers");
        assert_eq!(env_threads_from(Some("4")), Some(4));
        assert_eq!(env_threads_from(Some(" 2 ")), Some(2));
    }

    #[test]
    fn resolve_threads_explicit_wins_and_auto_is_positive() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }
}
