//! Run configuration: TOML files + presets mirroring the paper's Table 7
//! hyperparameters and every benchmark row.

use crate::util::toml::TomlDoc;
use anyhow::{Context, Result};
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Train-step executable name (manifest key), e.g. "train_step_chronicals".
    pub executable: String,
    /// Matching init executable (empty = derive `init_<variant>`).
    pub init_executable: String,
    pub steps: u64,
    pub warmup_steps: usize,
    pub seed: u64,
    /// Use BFD-packed batches (true) or padded batches (false).
    pub packed: bool,
    pub lr: f64,
    /// LoRA+ ratio λ = η_B/η_A; 1.0 disables LoRA+.
    pub lora_plus_ratio: f64,
    pub lr_schedule: String, // "constant" | "warmup_cosine"
    pub lr_warmup_steps: u64,
    pub corpus_examples: usize,
    pub max_seq: usize,
    pub artifacts_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            executable: "train_step_chronicals".into(),
            init_executable: String::new(),
            steps: 50,
            warmup_steps: 3,
            seed: 42,
            packed: true,
            lr: 2e-4,
            lora_plus_ratio: 1.0,
            lr_schedule: "constant".into(),
            lr_warmup_steps: 0,
            corpus_examples: 2048,
            max_seq: 1024,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl RunConfig {
    pub fn from_file(path: impl AsRef<Path>) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = TomlDoc::parse(text)?;
        let d = RunConfig::default();
        Ok(RunConfig {
            executable: doc.str_or("train.executable", &d.executable).to_string(),
            init_executable: doc.str_or("train.init_executable", "").to_string(),
            steps: doc.i64_or("train.steps", d.steps as i64) as u64,
            warmup_steps: doc.i64_or("train.warmup_steps", d.warmup_steps as i64) as usize,
            seed: doc.i64_or("train.seed", d.seed as i64) as u64,
            packed: doc.bool_or("data.packed", d.packed),
            lr: doc.f64_or("optim.lr", d.lr),
            lora_plus_ratio: doc.f64_or("optim.lora_plus_ratio", d.lora_plus_ratio),
            lr_schedule: doc.str_or("optim.lr_schedule", &d.lr_schedule).to_string(),
            lr_warmup_steps: doc.i64_or("optim.lr_warmup_steps", 0) as u64,
            corpus_examples: doc.i64_or("data.corpus_examples", d.corpus_examples as i64)
                as usize,
            max_seq: doc.i64_or("data.max_seq", d.max_seq as i64) as usize,
            artifacts_dir: doc.str_or("artifacts_dir", &d.artifacts_dir).to_string(),
        })
    }

    /// Derive the init executable name: explicit, or `init_<variant>` from
    /// the train executable name.
    pub fn init_name(&self) -> String {
        if !self.init_executable.is_empty() {
            return self.init_executable.clone();
        }
        self.executable
            .strip_prefix("train_step_")
            .map(|v| format!("init_{v}"))
            .unwrap_or_else(|| "init_chronicals".into())
    }

    /// Paper Table 7 presets.
    pub fn preset(name: &str) -> Option<RunConfig> {
        let mut c = RunConfig::default();
        match name {
            "full_ft" => {
                c.executable = "train_step_chronicals".into();
                c.lr = 2e-5 * 10.0; // scaled for the small substrate model
                c.lora_plus_ratio = 1.0;
            }
            "lora" => {
                c.executable = "train_step_lora".into();
                c.lr = 1e-4 * 10.0;
                c.lora_plus_ratio = 1.0;
            }
            "lora_plus" => {
                c.executable = "train_step_lora".into();
                c.lr = 1e-4 * 10.0;
                c.lora_plus_ratio = 16.0;
            }
            "e2e" => {
                c.executable = "train_step_e2e".into();
                c.steps = 300;
                c.lr = 3e-4;
                c.lr_schedule = "warmup_cosine".into();
                c.lr_warmup_steps = 10;
            }
            _ => return None,
        }
        Some(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_parse_from_empty() {
        let c = RunConfig::from_toml("").unwrap();
        assert_eq!(c, RunConfig::default());
    }

    #[test]
    fn full_config_parses() {
        let c = RunConfig::from_toml(
            r#"
artifacts_dir = "artifacts"
[train]
executable = "train_step_lora"
steps = 25
warmup_steps = 2
seed = 7
[data]
packed = false
corpus_examples = 512
max_seq = 256
[optim]
lr = 1e-3
lora_plus_ratio = 16.0
lr_schedule = "warmup_cosine"
lr_warmup_steps = 5
"#,
        )
        .unwrap();
        assert_eq!(c.executable, "train_step_lora");
        assert_eq!(c.steps, 25);
        assert!(!c.packed);
        assert_eq!(c.lora_plus_ratio, 16.0);
        assert_eq!(c.init_name(), "init_lora");
    }

    #[test]
    fn presets_exist() {
        for p in ["full_ft", "lora", "lora_plus", "e2e"] {
            assert!(RunConfig::preset(p).is_some(), "{p}");
        }
        assert!(RunConfig::preset("nope").is_none());
    }

    #[test]
    fn lora_plus_preset_has_ratio_16() {
        let c = RunConfig::preset("lora_plus").unwrap();
        assert_eq!(c.lora_plus_ratio, 16.0);
    }
}
