//! Word-level tokenizer with a frequency-built vocabulary.
//!
//! The paper uses Qwen's 151,936-token BPE vocabulary; the property every
//! experiment depends on is only *vocab ≫ hidden dim* (the CCE memory
//! ratio) and deterministic encode/decode, so a frequency-ranked word
//! vocabulary with an <unk> fallback is the faithful offline substitute.
//!
//! Token ids: 0 = <pad>, 1 = <unk>, 2 = <bos>, 3 = <eos>, 4.. = words.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const UNK: i32 = 1;
pub const BOS: i32 = 2;
pub const EOS: i32 = 3;
const N_SPECIAL: usize = 4;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: HashMap<String, i32>,
    words: Vec<String>,
    max_vocab: usize,
}

impl Tokenizer {
    /// Build a vocabulary from texts: rank words by frequency (ties broken
    /// lexicographically for determinism), keep the top `max_vocab - 4`.
    pub fn from_texts<I: IntoIterator<Item = String>>(texts: I, max_vocab: usize) -> Tokenizer {
        assert!(max_vocab > N_SPECIAL, "vocab too small");
        let mut freq: HashMap<String, u64> = HashMap::new();
        for t in texts {
            for w in t.split_whitespace() {
                *freq.entry(w.to_lowercase()).or_default() += 1;
            }
        }
        let mut ranked: Vec<(String, u64)> = freq.into_iter().collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        ranked.truncate(max_vocab - N_SPECIAL);

        let mut vocab = HashMap::new();
        let mut words = vec!["<pad>".into(), "<unk>".into(), "<bos>".into(), "<eos>".into()];
        for (i, (w, _)) in ranked.into_iter().enumerate() {
            vocab.insert(w.clone(), (N_SPECIAL + i) as i32);
            words.push(w);
        }
        Tokenizer { vocab, words, max_vocab }
    }

    pub fn vocab_size(&self) -> usize {
        self.words.len()
    }

    pub fn max_vocab(&self) -> usize {
        self.max_vocab
    }

    /// Encode text to ids with BOS/EOS framing.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = vec![BOS];
        for w in text.split_whitespace() {
            out.push(
                self.vocab
                    .get(&w.to_lowercase())
                    .copied()
                    .unwrap_or(UNK),
            );
        }
        out.push(EOS);
        out
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter_map(|&id| self.words.get(id as usize).map(String::as_str))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::from_texts(
            ["the cat sat on the mat the cat".to_string()],
            16,
        )
    }

    #[test]
    fn frequency_ranked_ids() {
        let t = tok();
        // "the" (3x) must get the lowest word id
        let ids = t.encode("the");
        assert_eq!(ids, vec![BOS, 4, EOS]);
    }

    #[test]
    fn unknown_words_map_to_unk() {
        let t = tok();
        let ids = t.encode("zebra");
        assert_eq!(ids, vec![BOS, UNK, EOS]);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = tok();
        let ids = t.encode("the cat sat");
        let text = t.decode(&ids);
        assert_eq!(text, "<bos> the cat sat <eos>");
    }

    #[test]
    fn vocab_capped() {
        let texts = (0..100).map(|i| format!("word{i}"));
        let t = Tokenizer::from_texts(texts, 10);
        assert_eq!(t.vocab_size(), 10);
    }

    #[test]
    fn case_insensitive() {
        let t = tok();
        assert_eq!(t.encode("THE Cat"), t.encode("the cat"));
    }

    #[test]
    fn deterministic_ordering_on_ties() {
        let a = Tokenizer::from_texts(["b a c".to_string()], 10);
        let b = Tokenizer::from_texts(["c a b".to_string()], 10);
        assert_eq!(a.encode("a b c"), b.encode("a b c"));
    }
}
