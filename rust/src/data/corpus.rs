//! Synthetic instruction-following corpus generator.
//!
//! Emits (prompt, completion) pairs from template pools with a controlled
//! length distribution: a log-normal body (many short examples) with a
//! long tail clipped at `max_words`, matching the paper's Alpaca
//! characterization (§7: mean ≈ 512 tokens, max 2048, 60–75% padding waste
//! under max-length padding).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Example {
    pub prompt: String,
    pub completion: String,
}

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub n_examples: usize,
    /// Mean of the underlying normal for the log-normal word count.
    pub lognorm_mu: f64,
    pub lognorm_sigma: f64,
    pub min_words: usize,
    pub max_words: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        // exp(mu + sigma^2/2) ≈ 120 words ≈ a few hundred sub-word tokens:
        // the Alpaca-like "many short, few long" shape from the paper.
        CorpusConfig {
            n_examples: 4096,
            lognorm_mu: 4.3,
            lognorm_sigma: 0.8,
            min_words: 8,
            max_words: 1024,
            seed: 42,
        }
    }
}

const INSTRUCTIONS: &[&str] = &[
    "explain the difference between",
    "write a short story about",
    "summarize the following passage on",
    "list the steps required to",
    "compare and contrast",
    "what is the capital of",
    "translate this sentence about",
    "give three reasons why",
    "describe the process of",
    "answer the question regarding",
];

const TOPICS: &[&str] = &[
    "gradient descent", "memory hierarchies", "rotary embeddings",
    "sequence packing", "attention kernels", "quantization error",
    "learning rate schedules", "tokenizer vocabularies", "loss landscapes",
    "systolic arrays", "cache coherence", "optimizer states",
];

const FILLER: &[&str] = &[
    "the", "model", "computes", "memory", "bandwidth", "kernel", "fusion",
    "reduces", "latency", "throughput", "gradient", "updates", "weights",
    "tokens", "per", "second", "with", "tiled", "online", "softmax",
    "accumulates", "results", "without", "materializing", "matrices",
    "training", "converges", "faster", "when", "learning", "rates",
    "respect", "parameter", "roles", "and", "padding", "wastes", "compute",
    "on", "positions", "that", "contribute", "nothing", "to", "loss",
];

pub struct SyntheticCorpus;

impl SyntheticCorpus {
    /// Generate the corpus. Deterministic in `cfg.seed`.
    pub fn generate(cfg: &CorpusConfig) -> Vec<Example> {
        let mut rng = Rng::new(cfg.seed);
        (0..cfg.n_examples)
            .map(|_| {
                let instr = *rng.choice(INSTRUCTIONS);
                let topic = *rng.choice(TOPICS);
                let prompt = format!("instruction: {instr} {topic} .");
                let words = (rng.lognormal(cfg.lognorm_mu, cfg.lognorm_sigma) as usize)
                    .clamp(cfg.min_words, cfg.max_words);
                let mut completion = String::with_capacity(words * 6);
                completion.push_str("response:");
                for _ in 0..words {
                    completion.push(' ');
                    completion.push_str(*rng.choice(FILLER));
                }
                Example { prompt, completion }
            })
            .collect()
    }

    /// Word-length statistics (used by the packing benches and reports).
    pub fn length_stats(examples: &[Example]) -> LengthStats {
        let lens: Vec<usize> = examples
            .iter()
            .map(|e| e.prompt.split_whitespace().count() + e.completion.split_whitespace().count())
            .collect();
        LengthStats::from(&lens)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LengthStats {
    pub n: usize,
    pub mean: f64,
    pub p50: usize,
    pub p90: usize,
    pub max: usize,
}

impl LengthStats {
    pub fn from(lens: &[usize]) -> LengthStats {
        if lens.is_empty() {
            return LengthStats { n: 0, mean: 0.0, p50: 0, p90: 0, max: 0 };
        }
        let mut sorted = lens.to_vec();
        sorted.sort_unstable();
        LengthStats {
            n: lens.len(),
            mean: lens.iter().sum::<usize>() as f64 / lens.len() as f64,
            p50: sorted[lens.len() / 2],
            p90: sorted[(lens.len() * 9) / 10],
            max: *sorted.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let cfg = CorpusConfig { n_examples: 16, ..Default::default() };
        let a = SyntheticCorpus::generate(&cfg);
        let b = SyntheticCorpus::generate(&cfg);
        assert_eq!(a.len(), 16);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.completion, y.completion);
        }
    }

    #[test]
    fn length_distribution_is_skewed() {
        let cfg = CorpusConfig { n_examples: 2000, ..Default::default() };
        let corpus = SyntheticCorpus::generate(&cfg);
        let stats = SyntheticCorpus::length_stats(&corpus);
        // log-normal: mean > median (right-skewed), long tail exists
        assert!(stats.mean > stats.p50 as f64, "{stats:?}");
        assert!(stats.max > 4 * stats.p50, "{stats:?}");
        assert!(stats.max <= cfg.max_words + 16);
    }

    #[test]
    fn respects_bounds() {
        let cfg = CorpusConfig {
            n_examples: 200,
            min_words: 10,
            max_words: 50,
            ..Default::default()
        };
        for ex in SyntheticCorpus::generate(&cfg) {
            let words = ex.completion.split_whitespace().count() - 1; // minus "response:"
            assert!((10..=50).contains(&words), "{words}");
        }
    }
}
