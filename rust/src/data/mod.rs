//! Data pipeline: synthetic instruction corpus → tokenizer → dataset.
//!
//! §Substitutions (DESIGN.md): the paper fine-tunes on Alpaca-cleaned. That
//! dataset is not available offline, so we generate a synthetic
//! instruction-following corpus whose *length distribution* matches the
//! paper's characterization (§7: "mean 512, max 2048", long tail of short
//! examples) — the only property the packing/padding experiments (Fig. 18,
//! Prop. 14) depend on.

pub mod corpus;
pub mod tokenizer;

pub use corpus::{CorpusConfig, Example, SyntheticCorpus};
pub use tokenizer::Tokenizer;

/// A tokenized training example: prompt tokens get target -100-style masking
/// (we use -1), completion tokens predict the next token.
#[derive(Debug, Clone)]
pub struct TokenizedExample {
    pub tokens: Vec<i32>,
    /// Per-position next-token targets; -1 = masked (prompt or final pos).
    pub targets: Vec<i32>,
}

impl TokenizedExample {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
    pub fn real_targets(&self) -> usize {
        self.targets.iter().filter(|&&t| t >= 0).count()
    }
}

/// Build the synthetic tokenized corpus once per (size, seed, vocab cap):
/// generate → fit tokenizer → tokenize. The standard data path behind
/// `DataSource::Synthetic` and the harness workflows.
pub fn build_corpus(
    n_examples: usize,
    seed: u64,
    vocab_cap: usize,
    max_seq: usize,
) -> (Tokenizer, Vec<TokenizedExample>) {
    let cfg = CorpusConfig { n_examples, seed, ..Default::default() };
    let corpus = SyntheticCorpus::generate(&cfg);
    let tok = Tokenizer::from_texts(
        corpus.iter().map(|e| format!("{} {}", e.prompt, e.completion)),
        vocab_cap,
    );
    let exs = tokenize_corpus(&corpus, &tok, max_seq);
    (tok, exs)
}

/// Tokenize a corpus: prompt tokens are loss-masked, completion tokens are
/// supervised (standard instruction-tuning recipe).
pub fn tokenize_corpus(
    corpus: &[Example],
    tok: &Tokenizer,
    max_len: usize,
) -> Vec<TokenizedExample> {
    corpus
        .iter()
        .map(|ex| {
            let mut tokens = tok.encode(&ex.prompt);
            let prompt_len = tokens.len();
            tokens.extend(tok.encode(&ex.completion));
            tokens.truncate(max_len);
            let mut targets = vec![-1i32; tokens.len()];
            for i in prompt_len.saturating_sub(1)..tokens.len().saturating_sub(1) {
                targets[i] = tokens[i + 1];
            }
            TokenizedExample { tokens, targets }
        })
        .filter(|ex| !ex.is_empty())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenize_masks_prompt() {
        let corpus = vec![Example {
            prompt: "alpha beta".into(),
            completion: "gamma delta".into(),
        }];
        let tok = Tokenizer::from_texts(
            corpus.iter().map(|e| format!("{} {}", e.prompt, e.completion)),
            64,
        );
        let exs = tokenize_corpus(&corpus, &tok, 128);
        assert_eq!(exs.len(), 1);
        let ex = &exs[0];
        // BOS + 2 words + EOS per side
        assert_eq!(ex.tokens.len(), 8);
        let prompt_len = 4;
        // prompt interior masked; boundary + completion supervised; last masked
        for i in 0..prompt_len - 1 {
            assert_eq!(ex.targets[i], -1, "prompt pos {i} must be masked");
        }
        for i in prompt_len - 1..ex.tokens.len() - 1 {
            assert_eq!(ex.targets[i], ex.tokens[i + 1], "pos {i}");
        }
        assert_eq!(*ex.targets.last().unwrap(), -1);
    }

    #[test]
    fn truncation_respects_max_len() {
        let corpus = vec![Example {
            prompt: "a b c d e f g h".into(),
            completion: "i j k l m n o p".into(),
        }];
        let tok = Tokenizer::from_texts(["a b c d e f g h i j k l m n o p".to_string()], 64);
        let exs = tokenize_corpus(&corpus, &tok, 5);
        assert_eq!(exs[0].tokens.len(), 5);
        assert_eq!(exs[0].targets.len(), 5);
    }
}
