//! # Chronicals — high-performance LLM fine-tuning, reproduced
//!
//! Rust + JAX + Pallas three-layer reproduction of *"Chronicals: A
//! High-Performance Framework for LLM Fine-Tuning with 3.51x Speedup over
//! Unsloth"* (Nair, 2026).
//!
//! Layering (see `DESIGN.md`):
//! * **L1** — Pallas kernels (`python/compile/kernels/`), build-time only.
//! * **L2** — the JAX training graph (`python/compile/model.py`), AOT-lowered
//!   to HLO text artifacts by `python/compile/aot.py`.
//! * **L3** — this crate: the training coordinator. It owns the event loop,
//!   data pipeline (synthetic instruction corpus or a file-backed JSONL
//!   corpus via [`data_source`] → tokenize → BFD-pack → shuffle/epoch batch
//!   stream), the pluggable execution backends (`backend::Backend`), metrics
//!   (throughput, MFU, memory model), benchmark verification (the paper's
//!   gradient-norm methodology), checkpointing and the CLI.
//!
//! Execution is backend-pluggable (DESIGN.md §3): the default
//! `backend::cpu::CpuBackend` is a deterministic pure-Rust reference of the
//! full train step, so `cargo test` drives the whole pipeline hermetically —
//! no Python, no artifacts, no native deps. `backend::cpu_fast` is the
//! throughput CPU path (threaded fused kernels, online-softmax flash
//! attention, streaming Cut Cross-Entropy — DESIGN.md §4.3), validated
//! against the reference by `rust/tests/parity.rs`. The `pjrt` feature
//! adds the PJRT runtime that executes the AOT artifacts; there, Python
//! never runs on the training path: `make artifacts` is the only Python
//! invocation and afterwards the `chronicals` binary is self-contained.
//!
//! ## Quickstart
//!
//! The typed [`session`] API is the one public way to run training:
//! describe the run with the builder, `build()` validates it against the
//! backend manifest, `run()` streams batches lazily and returns the
//! verified summary.
//!
//! ```
//! use chronicals::session::{DataSource, PackingStrategy, SessionBuilder, Task};
//!
//! let mut session = SessionBuilder::new()
//!     .task(Task::lora_plus(16.0))      // LoRA+ with λ = 16 (paper Thm. 1)
//!     .packing(PackingStrategy::Bfd)    // BFD sequence packing (Alg. 16)
//!     .steps(3)
//!     .lr(2e-3)
//!     .data(DataSource::synthetic(64, 7, 48))
//!     .build()?;                        // CPU reference backend by default
//! let report = session.run()?;
//! assert!(report.summary.verification.is_training);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod backend;
pub mod batching;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod data_source;
pub mod harness;
pub mod manifest;
pub mod metrics;
pub mod optim;
pub mod packing;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod util;

/// Crate version (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
