//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the training hot path with device-resident state.
//!
//! Key properties:
//! * **HLO text interchange** — `HloModuleProto::from_text_file` reassigns
//!   instruction ids, sidestepping the 64-bit-id protos jax ≥ 0.5 emits
//!   that xla_extension 0.5.1 rejects.
//! * **Compile cache** — each executable is compiled exactly once per
//!   process and shared (`Rc`).
//! * **Device residency** — training state (params + optimizer slots) lives
//!   in `PjRtBuffer`s between steps; only the batch (a few KiB of i32) and
//!   three scalar metrics cross the host boundary per step.
//!
//! This module is `pjrt`-feature-gated; the trait-level entry point is
//! `crate::backend::pjrt::PjrtBackend`.

use crate::batching::Batch;
use crate::manifest::{ExecutableSpec, Manifest, Role};
use crate::runtime::HostTensor;
use anyhow::{anyhow, bail, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) an executable by manifest name.
    pub fn compile(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.get(name)?;
        let path = self.manifest.hlo_path(spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("loading HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, t: &HostTensor) -> Result<PjRtBuffer> {
        match t {
            HostTensor::F32 { data, shape } => self
                .client
                .buffer_from_host_buffer(data, shape, None)
                .map_err(|e| anyhow!("upload f32: {e:?}")),
            HostTensor::I32 { data, shape } => self
                .client
                .buffer_from_host_buffer(data, shape, None)
                .map_err(|e| anyhow!("upload i32: {e:?}")),
        }
    }

    /// Upload a batch's four tensors once; reusable across steps (§Perf L3:
    /// the data is identical every epoch — re-uploading it per step was the
    /// top host-side cost in the profile).
    pub fn upload_train_batch(&self, batch: &Batch) -> Result<UploadedBatch> {
        let lits = vec![
            batch.tokens.to_literal(&[batch.batch, batch.seq])?,
            batch.targets.to_literal(&[batch.batch, batch.seq])?,
            batch.seg_ids.to_literal(&[batch.batch, batch.seq])?,
            batch.pos_ids.to_literal(&[batch.batch, batch.seq])?,
        ];
        let mut bufs = Vec::with_capacity(4);
        for lit in &lits {
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("batch upload: {e:?}"))?,
            );
        }
        Ok(UploadedBatch {
            _lits: lits, // keep host memory alive past the async transfer
            bufs,
            real_tokens: batch.real_tokens,
            slot_tokens: batch.batch * batch.seq,
        })
    }

    /// Execute with device buffers; returns the flat list of output buffers.
    ///
    /// jax lowers with `return_tuple=True`; PJRT may hand the root tuple
    /// back either pre-exploded (one buffer per leaf) or as a single tuple
    /// buffer. Both are handled; the exploded form keeps state on device.
    pub fn execute_buffers(
        &self,
        exe: &PjRtLoadedExecutable,
        args: &[&PjRtBuffer],
        n_outputs: usize,
    ) -> Result<Vec<OutBuf>> {
        let res = exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        self.collect_outputs(res, n_outputs)
    }

    /// Execute with host literals (used by init / one-shot paths).
    pub fn execute_literals(
        &self,
        exe: &PjRtLoadedExecutable,
        args: &[Literal],
        n_outputs: usize,
    ) -> Result<Vec<OutBuf>> {
        let res = exe
            .execute::<Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        self.collect_outputs(res, n_outputs)
    }

    fn collect_outputs(
        &self,
        mut res: Vec<Vec<PjRtBuffer>>,
        n_outputs: usize,
    ) -> Result<Vec<OutBuf>> {
        if res.is_empty() || res[0].is_empty() {
            bail!("executable produced no outputs");
        }
        let bufs = std::mem::take(&mut res[0]);
        if bufs.len() == n_outputs {
            return Ok(bufs.into_iter().map(OutBuf::Device).collect());
        }
        if bufs.len() == 1 && n_outputs > 1 {
            // single tuple buffer: pull to host once, decompose
            let lit = bufs[0]
                .to_literal_sync()
                .map_err(|e| anyhow!("tuple readback: {e:?}"))?;
            let parts = lit
                .to_tuple()
                .map_err(|e| anyhow!("tuple decompose: {e:?}"))?;
            if parts.len() != n_outputs {
                bail!("expected {n_outputs} outputs, tuple has {}", parts.len());
            }
            return Ok(parts.into_iter().map(OutBuf::Host).collect());
        }
        bail!("expected {n_outputs} outputs, got {} buffers", bufs.len())
    }

    /// Build the per-step batch + scalar literals for a train executable,
    /// in the exact manifest input order following the state inputs.
    pub fn batch_literals(
        spec: &ExecutableSpec,
        tensors: &HashMap<&str, HostTensor>,
    ) -> Result<Vec<Literal>> {
        let mut out = Vec::new();
        for inp in &spec.inputs {
            match inp.role {
                Role::Param | Role::Frozen | Role::Opt => continue,
                Role::Batch | Role::Scalar => {
                    let t = tensors.get(inp.name.as_str()).ok_or_else(|| {
                        anyhow!("missing batch tensor '{}'", inp.name)
                    })?;
                    if t.elements() != inp.elements() {
                        bail!(
                            "batch tensor '{}' has {} elements, expected {}",
                            inp.name,
                            t.elements(),
                            inp.elements()
                        );
                    }
                    out.push(t.to_literal(&inp.shape)?);
                }
            }
        }
        Ok(out)
    }
}

/// A batch whose four tensors already live on the device.
///
/// The source literals are kept alive alongside the buffers:
/// `BufferFromHostLiteral` is asynchronous, and the transfer may still be
/// reading host memory after the call returns (see the warning in the
/// vendored `xla_rs.cc::execute`). Dropping the literal early is a
/// use-after-free that manifests as a fatal size-check inside PJRT.
pub struct UploadedBatch {
    _lits: Vec<Literal>,
    pub(crate) bufs: Vec<PjRtBuffer>,
    pub real_tokens: usize,
    pub slot_tokens: usize,
}

/// Output of an execution: either still on device or already a host literal
/// (when PJRT returned a fused tuple).
pub enum OutBuf {
    Device(PjRtBuffer),
    Host(Literal),
}

impl OutBuf {
    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            OutBuf::Device(b) => b
                .to_literal_sync()
                .map_err(|e| anyhow!("readback: {e:?}")),
            OutBuf::Host(l) => clone_literal(l),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let lit = self.to_literal()?;
        lit.get_first_element::<f32>()
            .map_err(|e| anyhow!("scalar readback: {e:?}"))
    }
}

/// The xla crate's Literal lacks Clone; round-trip through raw bytes.
/// Errors (rather than panicking) on tuple literals and element types the
/// artifacts never produce.
pub fn clone_literal(l: &Literal) -> Result<Literal> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow!("clone_literal: not an array literal: {e:?}"))?;
    let dims: Vec<i64> = shape.dims().to_vec();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v = l.to_vec::<f32>().map_err(|e| anyhow!("clone f32: {e:?}"))?;
            Literal::vec1(&v)
                .reshape(&dims)
                .map_err(|e| anyhow!("clone reshape: {e:?}"))
        }
        xla::ElementType::S32 => {
            let v = l.to_vec::<i32>().map_err(|e| anyhow!("clone i32: {e:?}"))?;
            Literal::vec1(&v)
                .reshape(&dims)
                .map_err(|e| anyhow!("clone reshape: {e:?}"))
        }
        other => bail!("clone_literal: unsupported element type {other:?} (artifacts are f32/i32 only)"),
    }
}

/// Persistent, device-resident training state for one executable family.
pub struct TrainState {
    /// params (trainable then frozen) then slot0 then slot1 — manifest order.
    pub buffers: Vec<PjRtBuffer>,
    pub n_trainable: usize,
    pub n_frozen: usize,
    pub n_slots: usize,
}

impl TrainState {
    /// Initialize by running the family's `init_<variant>` executable.
    pub fn init(rt: &Runtime, init_name: &str, seed: i32) -> Result<TrainState> {
        let spec = rt.manifest.get(init_name)?.clone();
        let exe = rt.compile(init_name)?;
        let n_out = spec.outputs.len();
        let outs = rt.execute_literals(&exe, &[Literal::scalar(seed)], n_out)?;
        let mut buffers = Vec::with_capacity(n_out);
        for o in outs {
            buffers.push(match o {
                OutBuf::Device(b) => b,
                OutBuf::Host(l) => {
                    // BufferFromHostLiteral is async: force the transfer to
                    // finish before `l` drops (dormant path; see UploadedBatch)
                    let b = rt
                        .client
                        .buffer_from_host_literal(None, &l)
                        .map_err(|e| anyhow!("re-upload init output: {e:?}"))?;
                    let _ = b.to_literal_sync();
                    b
                }
            });
        }
        Ok(TrainState {
            buffers,
            n_trainable: spec.n_trainable,
            n_frozen: spec.n_frozen,
            n_slots: spec.n_slots,
        })
    }

    /// Apply a train step's outputs: replace trainable params + opt slots.
    pub fn apply_step_outputs(&mut self, rt: &Runtime, outs: Vec<OutBuf>) -> Result<()> {
        let nt = self.n_trainable;
        for (i, o) in outs.into_iter().enumerate() {
            let buf = match o {
                OutBuf::Device(b) => b,
                OutBuf::Host(l) => {
                    let b = rt
                        .client
                        .buffer_from_host_literal(None, &l)
                        .map_err(|e| anyhow!("re-upload step output: {e:?}"))?;
                    let _ = b.to_literal_sync(); // sync before `l` drops
                    b
                }
            };
            let dst = if i < nt {
                i // trainable params are the first nt state entries
            } else {
                // slots follow the frozen params in the state layout
                nt + self.n_frozen + (i - nt)
            };
            self.buffers[dst] = buf;
        }
        Ok(())
    }

    /// Borrow all state buffers in input order.
    pub fn input_refs(&self) -> Vec<&PjRtBuffer> {
        self.buffers.iter().collect()
    }

    /// Pull every parameter (trainable + frozen) to host literals.
    pub fn params_to_host(&self) -> Result<Vec<Literal>> {
        self.buffers[..self.n_trainable + self.n_frozen]
            .iter()
            .map(|b| b.to_literal_sync().map_err(|e| anyhow!("readback: {e:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_literal_roundtrips_f32_and_i32() {
        let f = Literal::vec1(&[1.0f32, -2.5, 3.25]).reshape(&[3]).unwrap();
        let c = clone_literal(&f).unwrap();
        assert_eq!(c.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);

        let i = Literal::vec1(&[7i32, -1]).reshape(&[2]).unwrap();
        let c = clone_literal(&i).unwrap();
        assert_eq!(c.to_vec::<i32>().unwrap(), vec![7, -1]);
    }

    #[test]
    fn clone_literal_rejects_unsupported_element_type() {
        let d = Literal::vec1(&[1.0f64, 2.0]);
        let err = clone_literal(&d).unwrap_err();
        assert!(err.to_string().contains("unsupported element type"), "{err}");
    }

    #[test]
    fn outbuf_host_to_literal_propagates_clone_errors() {
        let ok = OutBuf::Host(Literal::vec1(&[4.0f32]));
        assert!(ok.to_literal().is_ok());
        assert!((ok.scalar_f32().unwrap() - 4.0).abs() < 1e-6);

        let bad = OutBuf::Host(Literal::vec1(&[4.0f64]));
        assert!(bad.to_literal().is_err());
        assert!(bad.scalar_f32().is_err());
    }
}
