//! Host-side tensors: the tiny bridge type between the data pipeline and
//! the execution backends. Only f32 and i32 exist anywhere in the system.
//!
//! The XLA literal conversions are `pjrt`-feature-gated; the default build
//! (CPU reference backend) uses the plain slice accessors.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32 { data, shape }
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32 { data, shape }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 { data: vec![x], shape: vec![] }
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostTensor::I32 { data: vec![x], shape: vec![] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn elements(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Mutable f32 view (the CPU backend's in-place parameter updates).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Convert to an XLA literal with the given target shape (must have the
    /// same element count; scalars use an empty shape).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        use anyhow::anyhow;
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => {
                if shape.is_empty() {
                    return Ok(xla::Literal::scalar(data[0]));
                }
                xla::Literal::vec1(data.as_slice())
            }
            HostTensor::I32 { data, .. } => {
                if shape.is_empty() {
                    return Ok(xla::Literal::scalar(data[0]));
                }
                xla::Literal::vec1(data.as_slice())
            }
        };
        lit.reshape(&dims)
            .map_err(|e| anyhow!("reshape to {shape:?}: {e:?}"))
    }

    /// Read a literal back into a host tensor.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        use anyhow::anyhow;
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 {
                data: lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
                shape: dims,
            }),
            xla::ElementType::S32 => Ok(HostTensor::I32 {
                data: lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
                shape: dims,
            }),
            other => bail!("unsupported element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_mismatch_errors() {
        let t = HostTensor::scalar_f32(1.0);
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }

    #[test]
    fn mutable_access_updates_in_place() {
        let mut t = HostTensor::f32(vec![1.0, 2.0], vec![2]);
        t.as_f32_mut().unwrap()[1] = 5.0;
        assert_eq!(t.as_f32().unwrap(), &[1.0, 5.0]);
        let mut i = HostTensor::scalar_i32(3);
        assert!(i.as_f32_mut().is_err());
    }

    #[test]
    fn shape_and_elements() {
        let t = HostTensor::i32(vec![1, 2, 3, 4, 5, 6], vec![2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.elements(), 6);
        assert_eq!(HostTensor::scalar_f32(0.5).elements(), 1);
    }

    #[cfg(feature = "pjrt")]
    mod literal {
        use super::*;

        #[test]
        fn literal_roundtrip_f32() {
            let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
            let lit = t.to_literal(&[2, 2]).unwrap();
            let back = HostTensor::from_literal(&lit).unwrap();
            assert_eq!(t, back);
        }

        #[test]
        fn literal_roundtrip_i32() {
            let t = HostTensor::i32(vec![7, -3, 0], vec![3]);
            let lit = t.to_literal(&[3]).unwrap();
            let back = HostTensor::from_literal(&lit).unwrap();
            assert_eq!(t, back);
        }

        #[test]
        fn scalar_literal() {
            let t = HostTensor::scalar_f32(2.5);
            let lit = t.to_literal(&[]).unwrap();
            assert_eq!(lit.get_first_element::<f32>().unwrap(), 2.5);
        }

        #[test]
        fn from_literal_rejects_f64() {
            let lit = xla::Literal::vec1(&[1.0f64]);
            assert!(HostTensor::from_literal(&lit).is_err());
        }
    }
}
