//! Runtime substrate: host tensors (always available) and the PJRT
//! execution runtime (behind the off-by-default `pjrt` feature).
//!
//! The data pipeline, checkpointing and the CPU reference backend only need
//! [`HostTensor`]; everything XLA-shaped — literals, device buffers,
//! compiled executables — lives in [`pjrt`] so the default build is
//! hermetic (DESIGN.md §4.2).

pub mod tensor;

pub use tensor::HostTensor;

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::{clone_literal, OutBuf, Runtime, TrainState, UploadedBatch};
