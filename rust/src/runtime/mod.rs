//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the training hot path with device-resident state.
//!
//! Key properties:
//! * **HLO text interchange** — `HloModuleProto::from_text_file` reassigns
//!   instruction ids, sidestepping the 64-bit-id protos jax ≥ 0.5 emits
//!   that xla_extension 0.5.1 rejects.
//! * **Compile cache** — each executable is compiled exactly once per
//!   process and shared (`Rc`).
//! * **Device residency** — training state (params + optimizer slots) lives
//!   in `PjRtBuffer`s between steps; only the batch (a few KiB of i32) and
//!   three scalar metrics cross the host boundary per step.

pub mod tensor;

use crate::manifest::{ExecutableSpec, Manifest, Role};
use anyhow::{anyhow, bail, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
pub use tensor::HostTensor;
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) an executable by manifest name.
    pub fn compile(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.get(name)?;
        let path = self.manifest.hlo_path(spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("loading HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, t: &HostTensor) -> Result<PjRtBuffer> {
        match t {
            HostTensor::F32 { data, shape } => self
                .client
                .buffer_from_host_buffer(data, shape, None)
                .map_err(|e| anyhow!("upload f32: {e:?}")),
            HostTensor::I32 { data, shape } => self
                .client
                .buffer_from_host_buffer(data, shape, None)
                .map_err(|e| anyhow!("upload i32: {e:?}")),
        }
    }

    /// Execute with device buffers; returns the flat list of output buffers.
    ///
    /// jax lowers with `return_tuple=True`; PJRT may hand the root tuple
    /// back either pre-exploded (one buffer per leaf) or as a single tuple
    /// buffer. Both are handled; the exploded form keeps state on device.
    pub fn execute_buffers(
        &self,
        exe: &PjRtLoadedExecutable,
        args: &[&PjRtBuffer],
        n_outputs: usize,
    ) -> Result<Vec<OutBuf>> {
        let res = exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        self.collect_outputs(res, n_outputs)
    }

    /// Execute with host literals (used by init / one-shot paths).
    pub fn execute_literals(
        &self,
        exe: &PjRtLoadedExecutable,
        args: &[Literal],
        n_outputs: usize,
    ) -> Result<Vec<OutBuf>> {
        let res = exe
            .execute::<Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        self.collect_outputs(res, n_outputs)
    }

    fn collect_outputs(
        &self,
        mut res: Vec<Vec<PjRtBuffer>>,
        n_outputs: usize,
    ) -> Result<Vec<OutBuf>> {
        if res.is_empty() || res[0].is_empty() {
            bail!("executable produced no outputs");
        }
        let bufs = std::mem::take(&mut res[0]);
        if bufs.len() == n_outputs {
            return Ok(bufs.into_iter().map(OutBuf::Device).collect());
        }
        if bufs.len() == 1 && n_outputs > 1 {
            // single tuple buffer: pull to host once, decompose
            let lit = bufs[0]
                .to_literal_sync()
                .map_err(|e| anyhow!("tuple readback: {e:?}"))?;
            let parts = lit
                .to_tuple()
                .map_err(|e| anyhow!("tuple decompose: {e:?}"))?;
            if parts.len() != n_outputs {
                bail!("expected {n_outputs} outputs, tuple has {}", parts.len());
            }
            return Ok(parts.into_iter().map(OutBuf::Host).collect());
        }
        bail!("expected {n_outputs} outputs, got {} buffers", bufs.len())
    }

    /// Build the per-step batch + scalar literals for a train executable,
    /// in the exact manifest input order following the state inputs.
    pub fn batch_literals(
        spec: &ExecutableSpec,
        tensors: &HashMap<&str, HostTensor>,
    ) -> Result<Vec<Literal>> {
        let mut out = Vec::new();
        for inp in &spec.inputs {
            match inp.role {
                Role::Param | Role::Frozen | Role::Opt => continue,
                Role::Batch | Role::Scalar => {
                    let t = tensors.get(inp.name.as_str()).ok_or_else(|| {
                        anyhow!("missing batch tensor '{}'", inp.name)
                    })?;
                    if t.elements() != inp.elements() {
                        bail!(
                            "batch tensor '{}' has {} elements, expected {}",
                            inp.name,
                            t.elements(),
                            inp.elements()
                        );
                    }
                    out.push(t.to_literal(&inp.shape)?);
                }
            }
        }
        Ok(out)
    }
}

/// Output of an execution: either still on device or already a host literal
/// (when PJRT returned a fused tuple).
pub enum OutBuf {
    Device(PjRtBuffer),
    Host(Literal),
}

impl OutBuf {
    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            OutBuf::Device(b) => b
                .to_literal_sync()
                .map_err(|e| anyhow!("readback: {e:?}")),
            OutBuf::Host(l) => Ok(clone_literal(l)),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let lit = self.to_literal()?;
        lit.get_first_element::<f32>()
            .map_err(|e| anyhow!("scalar readback: {e:?}"))
    }
}

/// The xla crate's Literal lacks Clone; round-trip through raw bytes.
pub fn clone_literal(l: &Literal) -> Literal {
    let shape = l.array_shape().expect("array literal");
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v = l.to_vec::<f32>().expect("f32 literal");
            Literal::vec1(&v).reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>()).unwrap()
        }
        xla::ElementType::S32 => {
            let v = l.to_vec::<i32>().expect("i32 literal");
            Literal::vec1(&v).reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>()).unwrap()
        }
        other => panic!("unsupported literal type {other:?}"),
    }
}

/// Persistent, device-resident training state for one executable family.
pub struct TrainState {
    /// params (trainable then frozen) then slot0 then slot1 — manifest order.
    pub buffers: Vec<PjRtBuffer>,
    pub n_trainable: usize,
    pub n_frozen: usize,
    pub n_slots: usize,
}

impl TrainState {
    /// Initialize by running the family's `init_<variant>` executable.
    pub fn init(rt: &Runtime, init_name: &str, seed: i32) -> Result<TrainState> {
        let spec = rt.manifest.get(init_name)?.clone();
        let exe = rt.compile(init_name)?;
        let n_out = spec.outputs.len();
        let outs = rt.execute_literals(&exe, &[Literal::scalar(seed)], n_out)?;
        let mut buffers = Vec::with_capacity(n_out);
        for o in outs {
            buffers.push(match o {
                OutBuf::Device(b) => b,
                OutBuf::Host(l) => {
                    // BufferFromHostLiteral is async: force the transfer to
                    // finish before `l` drops (dormant path; see UploadedBatch)
                    let b = rt
                        .client
                        .buffer_from_host_literal(None, &l)
                        .map_err(|e| anyhow!("re-upload init output: {e:?}"))?;
                    let _ = b.to_literal_sync();
                    b
                }
            });
        }
        Ok(TrainState {
            buffers,
            n_trainable: spec.n_trainable,
            n_frozen: spec.n_frozen,
            n_slots: spec.n_slots,
        })
    }

    /// Apply a train step's outputs: replace trainable params + opt slots.
    pub fn apply_step_outputs(&mut self, rt: &Runtime, outs: Vec<OutBuf>) -> Result<()> {
        let nt = self.n_trainable;
        for (i, o) in outs.into_iter().enumerate() {
            let buf = match o {
                OutBuf::Device(b) => b,
                OutBuf::Host(l) => {
                    let b = rt
                        .client
                        .buffer_from_host_literal(None, &l)
                        .map_err(|e| anyhow!("re-upload step output: {e:?}"))?;
                    let _ = b.to_literal_sync(); // sync before `l` drops
                    b
                }
            };
            let dst = if i < nt {
                i // trainable params are the first nt state entries
            } else {
                // slots follow the frozen params in the state layout
                nt + self.n_frozen + (i - nt)
            };
            self.buffers[dst] = buf;
        }
        Ok(())
    }

    /// Borrow all state buffers in input order.
    pub fn input_refs(&self) -> Vec<&PjRtBuffer> {
        self.buffers.iter().collect()
    }

    /// Pull every parameter (trainable + frozen) to host literals.
    pub fn params_to_host(&self) -> Result<Vec<Literal>> {
        self.buffers[..self.n_trainable + self.n_frozen]
            .iter()
            .map(|b| b.to_literal_sync().map_err(|e| anyhow!("readback: {e:?}")))
            .collect()
    }
}

/// Convenience: make `(name -> HostTensor)` maps for a training batch.
pub fn batch_map<'a>(
    tokens: &'a HostTensor,
    targets: &'a HostTensor,
    seg_ids: &'a HostTensor,
    pos_ids: &'a HostTensor,
    step: f32,
    lr: f32,
    lr_b: f32,
) -> (HashMap<&'a str, HostTensor>, [f32; 3]) {
    let mut m: HashMap<&str, HostTensor> = HashMap::new();
    m.insert("tokens", tokens.clone());
    m.insert("targets", targets.clone());
    m.insert("seg_ids", seg_ids.clone());
    m.insert("pos_ids", pos_ids.clone());
    m.insert("step", HostTensor::scalar_f32(step));
    m.insert("lr", HostTensor::scalar_f32(lr));
    m.insert("lr_b", HostTensor::scalar_f32(lr_b));
    (m, [step, lr, lr_b])
}
