//! Table printers that regenerate the paper's result rows (Tables 2–6,
//! Fig. 21/23 summary grids) from measured `TrainSummary`s.

use crate::coordinator::TrainSummary;
use crate::metrics;
use crate::util::commas;

/// One benchmark row: a framework configuration + its measurements.
#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub mode: String,
    pub batch: usize,
    pub tokens_per_sec: f64,
    pub mean_step_ms: f64,
    pub param_count: u64,
    pub status: String,
}

impl Row {
    pub fn from_summary(label: &str, mode: &str, batch: usize, s: &TrainSummary) -> Row {
        Row {
            label: label.to_string(),
            mode: mode.to_string(),
            batch,
            tokens_per_sec: s.tokens_per_sec,
            mean_step_ms: s.mean_step_ms,
            param_count: s.param_count,
            status: s.verification.status().to_string(),
        }
    }
}

/// Render a Table-2/3-style comparison with speedups vs a baseline row.
pub fn throughput_table(title: &str, rows: &[Row], baseline_label: &str) -> String {
    let base = rows
        .iter()
        .find(|r| r.label == baseline_label)
        .map(|r| r.tokens_per_sec)
        .unwrap_or(0.0);
    let mut out = String::new();
    out.push_str(&format!("## {title}\n"));
    out.push_str(&format!(
        "| {:<28} | {:>6} | {:>12} | {:>10} | {:>8} | {:>8} | {:<22} |\n",
        "Config", "Batch", "Tok/s", "ms/step", "MFU*", "Speedup", "Status"
    ));
    out.push_str(&format!("|{}|\n", "-".repeat(112)));
    for r in rows {
        let speedup = if base > 0.0 { r.tokens_per_sec / base } else { 0.0 };
        let mfu = metrics::mfu_paper_scale(r.param_count, r.tokens_per_sec) * 100.0;
        out.push_str(&format!(
            "| {:<28} | {:>6} | {:>12} | {:>10.1} | {:>7.2}% | {:>7.2}x | {:<22} |\n",
            r.label,
            r.batch,
            commas(r.tokens_per_sec as u64),
            r.mean_step_ms,
            mfu,
            speedup,
            r.status
        ));
    }
    out.push_str("(*MFU uses the paper's A100 peak as the reference denominator; on the CPU\n substrate it is a cross-config comparator, not a hardware utilization.)\n");
    out
}

/// Render the Table-4 ablation ladder with cumulative speedups.
pub fn ablation_table(rows: &[Row]) -> String {
    let mut out = String::new();
    out.push_str("## Ablation ladder (paper Table 4 / Fig. 14)\n");
    out.push_str(&format!(
        "| {:<28} | {:>12} | {:>10} | {:>9} | {:>9} |\n",
        "Configuration", "Tok/s", "ms/step", "Step x", "Cum x"
    ));
    out.push_str(&format!("|{}|\n", "-".repeat(82)));
    let base = rows.first().map(|r| r.tokens_per_sec).unwrap_or(1.0);
    let mut prev = base;
    for r in rows {
        out.push_str(&format!(
            "| {:<28} | {:>12} | {:>10.1} | {:>8.2}x | {:>8.2}x |\n",
            r.label,
            commas(r.tokens_per_sec as u64),
            r.mean_step_ms,
            r.tokens_per_sec / prev,
            r.tokens_per_sec / base,
        ));
        prev = r.tokens_per_sec;
    }
    out
}

/// Kernel microbench table (paper Table 5).
pub fn kernel_table(rows: &[(String, f64, f64)]) -> String {
    // (kernel, fused_ms, naive_ms)
    let mut out = String::new();
    out.push_str("## Kernel microbenchmarks (paper Table 5)\n");
    out.push_str(&format!(
        "| {:<24} | {:>12} | {:>12} | {:>8} |\n",
        "Kernel", "Fused ms", "Naive ms", "Speedup"
    ));
    out.push_str(&format!("|{}|\n", "-".repeat(68)));
    for (name, fused, naive) in rows {
        out.push_str(&format!(
            "| {:<24} | {:>12.3} | {:>12.3} | {:>7.2}x |\n",
            name,
            fused * 1e3,
            naive * 1e3,
            naive / fused
        ));
    }
    out
}

/// Memory breakdown table (paper §S15 Table 10 shape).
pub fn memory_table(label: &str, b: &crate::metrics::MemoryBreakdown) -> String {
    let gb = |x: u64| x as f64 / 1e9;
    format!(
        "## Memory breakdown — {label}\n\
         | Component          | GB      |\n\
         |--------------------|---------|\n\
         | Weights            | {:>7.2} |\n\
         | Gradients          | {:>7.2} |\n\
         | Optimizer states   | {:>7.2} |\n\
         | Activations        | {:>7.2} |\n\
         | Attention scores   | {:>7.2} |\n\
         | Logits             | {:>7.2} |\n\
         | **Total**          | {:>7.2} |\n",
        gb(b.weights),
        gb(b.gradients),
        gb(b.optimizer),
        gb(b.activations),
        gb(b.attention_scores),
        gb(b.logits),
        gb(b.total)
    )
}

/// Repo-root location of the machine-readable CPU bench report that the
/// bench binaries merge their sections into — the single home for this
/// repo-layout assumption.
pub fn bench_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("the chronicals crate lives inside the workspace root")
        .join("BENCH_cpu.json")
}

/// Merge one section into a machine-readable bench JSON file (e.g. the
/// repo-root `BENCH_cpu.json` the bench binaries maintain): parse the
/// existing file when present and valid, replace `section`, write back
/// pretty-printed. Each bench binary owns one section, so running them in
/// any order converges to a complete report.
pub fn update_bench_json(
    path: &std::path::Path,
    section: &str,
    value: crate::util::json::Json,
) -> anyhow::Result<()> {
    use crate::util::json::{Json, Obj};
    // A missing file starts a fresh report; an *unreadable* or
    // *unparseable* existing file is an error — silently restarting would
    // discard the other benches' measured sections.
    let mut obj = match std::fs::read_to_string(path) {
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Obj::default(),
        Err(e) => anyhow::bail!("reading {}: {e}", path.display()),
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Obj(o)) => o,
            Ok(_) => anyhow::bail!(
                "{} exists but is not a JSON object; refusing to overwrite it",
                path.display()
            ),
            Err(e) => anyhow::bail!(
                "{} exists but failed to parse ({e}); fix or delete it before re-running",
                path.display()
            ),
        },
    };
    if let Some(slot) = obj.entries.iter_mut().find(|(k, _)| k == section) {
        slot.1 = value;
    } else {
        obj.insert(section, value);
    }
    std::fs::write(path, Json::Obj(obj).to_string_pretty())?;
    Ok(())
}

/// Outcome of a bench regression check ([`check_bench_metrics`]): every
/// fresh measurement lands in exactly one bucket.
#[derive(Debug, Clone, Default)]
pub struct BenchCheckOutcome {
    /// Metrics compared and within the threshold: "path: committed X,
    /// fresh Y (ratio)".
    pub checked: Vec<String>,
    /// Metrics not compared, with the reason (section `verified = false`,
    /// path missing from the committed report, non-numeric leaf).
    pub skipped: Vec<String>,
    /// Metrics that regressed beyond the threshold.
    pub regressions: Vec<String>,
}

impl BenchCheckOutcome {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// Compare fresh bench measurements against a committed report (e.g. the
/// repo-root `BENCH_cpu.json`). Each fresh entry is a dotted path into the
/// committed JSON (`"throughput.full_ft.cpu_tokens_per_sec"`) plus the
/// freshly measured value; higher is better. A fresh value below
/// `committed · (1 − threshold)` is a regression. Any object on the path
/// carrying `"verified": false` gates its whole subtree — seed numbers
/// that were never measured can't fail a check — and paths absent from
/// the committed report are skipped, so a fresh section can land before
/// its first committed measurement.
pub fn check_bench_metrics(
    committed: &crate::util::json::Json,
    fresh: &[(String, f64)],
    threshold: f64,
) -> BenchCheckOutcome {
    let mut out = BenchCheckOutcome::default();
    'next: for (path, fresh_v) in fresh {
        let mut node = committed;
        for seg in path.split('.') {
            let Some(obj) = node.as_obj() else {
                out.skipped.push(format!("{path}: committed entry is not an object"));
                continue 'next;
            };
            if obj.get("verified").and_then(|v| v.as_bool()) == Some(false) {
                out.skipped.push(format!("{path}: committed section is unverified"));
                continue 'next;
            }
            match obj.get(seg) {
                Some(n) => node = n,
                None => {
                    out.skipped.push(format!("{path}: not in the committed report"));
                    continue 'next;
                }
            }
        }
        let Some(committed_v) = node.as_f64() else {
            out.skipped.push(format!("{path}: committed value is not a number"));
            continue;
        };
        let floor = committed_v * (1.0 - threshold);
        let ratio = if committed_v > 0.0 { fresh_v / committed_v } else { f64::INFINITY };
        let line = format!("{path}: committed {committed_v:.1}, fresh {fresh_v:.1} ({ratio:.2}x)");
        if *fresh_v < floor {
            out.regressions.push(format!(
                "{line} — below the {:.0}% regression floor {floor:.1}",
                threshold * 100.0
            ));
        } else {
            out.checked.push(line);
        }
    }
    out
}

/// One tenant's `chronicals serve` run report (DESIGN.md §11), rendered
/// as deterministic JSON. Every field is a pure function of the job spec
/// and the training math — deliberately no wall-clock fields — so a fused
/// run's report byte-matches the same job run serially and CI can
/// `diff -r` the two output directories.
#[derive(Debug, Clone)]
pub struct ServeJobReport<'a> {
    /// Job id (also the report's file stem).
    pub id: &'a str,
    /// Human task label (`Task`'s `Display` form).
    pub task: String,
    /// Backend the job ran on.
    pub backend: &'a str,
    /// Data-source label.
    pub data: String,
    /// The job's requested step budget.
    pub steps_budget: u64,
    /// Steps actually run (< budget when `--max-rounds` cut the run).
    pub steps_run: u64,
    /// Whether the full budget completed.
    pub completed: bool,
    /// Per-step training losses, in step order.
    pub losses: &'a [f32],
    /// Per-step trainable gradient norms, in step order.
    pub grad_norms: &'a [f32],
    /// The §8 verification verdict: gradients flowed on every step.
    pub verified: bool,
}

impl ServeJobReport<'_> {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{Json, Obj};
        let series = |xs: &[f32]| Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect());
        let first = self.losses.first().copied();
        let last = self.losses.last().copied();
        let mut o = Obj::default();
        o.insert("id", Json::Str(self.id.to_string()));
        o.insert("task", Json::Str(self.task.clone()));
        o.insert("backend", Json::Str(self.backend.to_string()));
        o.insert("data", Json::Str(self.data.clone()));
        o.insert("steps_budget", Json::Num(self.steps_budget as f64));
        o.insert("steps_run", Json::Num(self.steps_run as f64));
        o.insert("completed", Json::Bool(self.completed));
        o.insert("first_loss", first.map_or(Json::Null, |v| Json::Num(v as f64)));
        o.insert("final_loss", last.map_or(Json::Null, |v| Json::Num(v as f64)));
        let decreased = matches!((first, last), (Some(a), Some(b)) if b < a);
        o.insert("loss_decreased", Json::Bool(decreased));
        o.insert("losses", series(self.losses));
        o.insert("grad_norms", series(self.grad_norms));
        o.insert("verified", Json::Bool(self.verified));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn row(label: &str, tps: f64) -> Row {
        Row {
            label: label.into(),
            mode: "full".into(),
            batch: 4,
            tokens_per_sec: tps,
            mean_step_ms: 10.0,
            param_count: 1_000_000,
            status: "VERIFIED".into(),
        }
    }

    #[test]
    fn throughput_table_computes_speedup() {
        let rows = vec![row("baseline", 1000.0), row("chronicals", 3510.0)];
        let t = throughput_table("T", &rows, "baseline");
        assert!(t.contains("3.51x"), "{t}");
        assert!(t.contains("1.00x"));
    }

    #[test]
    fn ablation_cumulative() {
        let rows = vec![row("a", 100.0), row("b", 200.0), row("c", 300.0)];
        let t = ablation_table(&rows);
        assert!(t.contains("3.00x"), "{t}");
    }

    #[test]
    fn kernel_table_speedup() {
        let t = kernel_table(&[("RMSNorm".into(), 0.001, 0.007)]);
        assert!(t.contains("7.00x"), "{t}");
    }

    #[test]
    fn bench_check_buckets_and_threshold() {
        let committed = Json::parse(
            r#"{
              "throughput": {
                "full_ft": {"cpu_tokens_per_sec": 1000.0, "speedup": 2.5, "verified": true},
                "lora": {"cpu_tokens_per_sec": 800.0, "verified": false}
              }
            }"#,
        )
        .unwrap();
        let fresh = vec![
            ("throughput.full_ft.cpu_tokens_per_sec".to_string(), 950.0), // -5%: ok
            ("throughput.full_ft.speedup".to_string(), 1.0),              // -60%: regression
            ("throughput.lora.cpu_tokens_per_sec".to_string(), 1.0),      // unverified: skip
            ("throughput.full_ft.missing_metric".to_string(), 1.0),       // absent: skip
            ("no_such_section.x".to_string(), 1.0),                       // absent: skip
        ];
        let out = check_bench_metrics(&committed, &fresh, 0.2);
        assert_eq!(out.checked.len(), 1, "{out:?}");
        assert_eq!(out.regressions.len(), 1, "{out:?}");
        assert_eq!(out.skipped.len(), 3, "{out:?}");
        assert!(!out.passed());
        assert!(out.regressions[0].contains("speedup"), "{:?}", out.regressions);
        assert!(
            out.skipped.iter().any(|s| s.contains("unverified")),
            "{:?}",
            out.skipped
        );
        // everything within threshold passes
        let out = check_bench_metrics(
            &committed,
            &[("throughput.full_ft.speedup".to_string(), 2.4)],
            0.2,
        );
        assert!(out.passed());
        assert_eq!(out.checked.len(), 1);
    }

    #[test]
    fn bench_check_improvements_pass_and_leaf_objects_skip() {
        let committed =
            Json::parse(r#"{"s": {"tps": 100.0, "cfg": {"batch": 4}}}"#).unwrap();
        // a big improvement is never a regression
        let out = check_bench_metrics(&committed, &[("s.tps".to_string(), 500.0)], 0.1);
        assert!(out.passed());
        // a path landing on an object (not a number) is skipped, not a panic
        let out = check_bench_metrics(&committed, &[("s.cfg".to_string(), 1.0)], 0.1);
        assert_eq!(out.skipped.len(), 1);
        assert!(out.passed());
    }

    #[test]
    fn bench_json_merges_and_replaces_sections() {
        let path = std::env::temp_dir().join("chronicals_bench_json_test.json");
        let _ = std::fs::remove_file(&path);
        let mut o = crate::util::json::Obj::default();
        o.insert("cpu_tokens_per_sec", Json::Num(1000.0));
        update_bench_json(&path, "throughput", Json::Obj(o)).unwrap();
        update_bench_json(&path, "kernels", Json::Num(2.0)).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let obj = j.as_obj().unwrap();
        assert!(obj.get("throughput").is_some());
        assert_eq!(obj.get("kernels").unwrap().as_f64(), Some(2.0));
        update_bench_json(&path, "kernels", Json::Num(3.0)).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.as_obj().unwrap().get("kernels").unwrap().as_f64(), Some(3.0));
        // a corrupt existing report must be an error, not a silent restart
        std::fs::write(&path, "{ truncated").unwrap();
        assert!(update_bench_json(&path, "kernels", Json::Num(4.0)).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn serve_job_report_is_deterministic_and_timing_free() {
        let rep = ServeJobReport {
            id: "tenant-a",
            task: "task lora".to_string(),
            backend: "cpu",
            data: "synthetic(40 examples, seed 3, max_seq 48)".to_string(),
            steps_budget: 2,
            steps_run: 2,
            completed: true,
            losses: &[4.5, 4.25],
            grad_norms: &[1.5, 1.25],
            verified: true,
        };
        let a = rep.to_json().to_string_pretty();
        let b = rep.to_json().to_string_pretty();
        assert_eq!(a, b);
        // the CI acceptance grep target, with exact formatting
        assert!(a.contains("\"loss_decreased\": true"), "{a}");
        // no wall-clock fields may ever sneak in (fused-vs-serial diff)
        for banned in ["tokens_per_sec", "_ms", "seconds", "elapsed", "wall"] {
            assert!(!a.contains(banned), "timing field '{banned}' in {a}");
        }
        let parsed = Json::parse(&a).unwrap();
        assert_eq!(parsed.field("final_loss").unwrap().as_f64(), Some(4.25));
        assert_eq!(parsed.field("losses").unwrap().as_arr().unwrap().len(), 2);
        // an empty (never-started) job still renders, without a decrease
        let empty = ServeJobReport { losses: &[], grad_norms: &[], completed: false, ..rep };
        let t = empty.to_json().to_string_pretty();
        assert!(t.contains("\"loss_decreased\": false"), "{t}");
        assert!(t.contains("\"first_loss\": null"), "{t}");
    }
}
