//! 8-bit optimizer-state storage (paper §S11, ROADMAP "memory tiers").
//!
//! AdamW's m/v moments tolerate 8-bit block quantization (the
//! `adamw_8bit` production pattern): each slot lives as [`Int8Blocks`]
//! plus one Kahan-computed per-block compensation term, and every
//! optimizer step runs decode → update → encode. The compensation holds
//! the block's mean quantization residual, so the decoded block has zero
//! mean drift; the per-element round-trip error stays within the paper's
//! Eq. 18 full-step bound `amax/127` (the uncompensated codec achieves
//! half of it — compensation trades per-element worst case for unbiased
//! block means, which is what matters for a moment estimate that feeds
//! hundreds of subsequent steps).
//!
//! Everything here is allocation-free after construction and strictly
//! sequential, so quantized optimizer state is bitwise invariant to the
//! fast backend's thread count and the data-parallel worker count by
//! construction.

use super::int8::Int8Blocks;
use anyhow::{bail, Result};

/// Block length for optimizer-state quantization (matches the checkpoint
/// codec's block so the two memory tiers share one error model).
pub const OPTIM_BLOCK: usize = 128;

/// Which codec holds the AdamW m/v slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OptimStates {
    /// Full-precision f32 slots (the legacy default; bitwise-stable).
    #[default]
    Fp32,
    /// Block-wise int8 slots with Kahan-compensated decode-update-encode.
    Int8,
}

impl OptimStates {
    /// Parse a CLI/TOML name (`--optim-states fp32|int8`).
    pub fn parse(name: &str) -> Result<OptimStates> {
        Ok(match name {
            "fp32" | "f32" => OptimStates::Fp32,
            "int8" | "i8" => OptimStates::Int8,
            other => bail!("unknown optimizer-state codec '{other}' (expected fp32 | int8)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimStates::Fp32 => "fp32",
            OptimStates::Int8 => "int8",
        }
    }
}

/// One quantized optimizer slot: int8 blocks plus a per-block Kahan
/// compensation (the mean encode residual, added back on decode).
#[derive(Debug, Clone, PartialEq)]
pub struct Int8Slot {
    pub q: Int8Blocks,
    /// One f32 per block: mean quantization residual of the last encode.
    pub comp: Vec<f32>,
}

impl Int8Slot {
    /// A zeroed slot for `n` elements (decodes to exactly 0.0 everywhere —
    /// bit-identical to a fresh f32 slot). Unlike the checkpoint codec's
    /// [`Int8Blocks`], the payload is NOT zero-padded to a block multiple:
    /// a slot stores exactly `n` bytes, so ragged small tensors (LoRA B
    /// mats, norms) keep the full ~4x byte savings.
    pub fn zeros(n: usize) -> Int8Slot {
        let n_blocks = n.div_ceil(OPTIM_BLOCK).max(1);
        Int8Slot {
            q: Int8Blocks {
                data: vec![0i8; n],
                scales: vec![1.0f32; n_blocks],
                block: OPTIM_BLOCK,
                n,
            },
            comp: vec![0.0f32; n_blocks],
        }
    }

    /// Logical element count.
    pub fn len(&self) -> usize {
        self.q.n
    }

    pub fn is_empty(&self) -> bool {
        self.q.n == 0
    }

    /// Storage bytes this slot actually holds (int8 payload + f32 scales
    /// + f32 compensations) — the honest numerator of the ≥3.5x pin.
    pub fn storage_bytes(&self) -> usize {
        self.q.data.len() + self.q.scales.len() * 4 + self.comp.len() * 4
    }

    /// Decode into `out[..self.len()]` (allocation-free). The caller owns
    /// the scratch; both CPU backends pass reusable buffers so steady-state
    /// steps never touch the heap.
    pub fn decode_into(&self, out: &mut [f32]) {
        let n = self.q.n;
        debug_assert!(out.len() >= n);
        for i in 0..n {
            let b = i / self.q.block;
            out[i] = self.q.data[i] as f32 * self.q.scales[b] + self.comp[b];
        }
    }

    /// Encode `x[..self.len()]` in place over the existing buffers
    /// (allocation-free): scale = amax/127 per block, round-to-nearest,
    /// then the block's mean residual — accumulated with Kahan summation
    /// so the compensation itself carries O(ε) error independent of the
    /// block length (paper Def. 14) — lands in `comp`.
    pub fn encode_from(&mut self, x: &[f32]) {
        let n = self.q.n;
        debug_assert_eq!(x.len(), n);
        let block = self.q.block;
        let n_blocks = self.q.scales.len();
        for b in 0..n_blocks {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            if lo >= hi {
                self.q.scales[b] = 1.0;
                self.comp[b] = 0.0;
                continue;
            }
            let amax = x[lo..hi].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            self.q.scales[b] = scale;
            // quantize, then Kahan-sum the residuals for the compensation
            let (mut s, mut c) = (0.0f32, 0.0f32);
            for i in lo..hi {
                let qv = (x[i] / scale).round().clamp(-127.0, 127.0) as i8;
                self.q.data[i] = qv;
                let r = x[i] - qv as f32 * scale;
                let y = r - c;
                let t = s + y;
                c = (t - s) - y;
                s = t;
            }
            self.comp[b] = s / (hi - lo) as f32;
        }
    }
}

/// The paper's Eq. 18 per-element round-trip bound for the compensated
/// codec: one full quantization step `amax/127` per block (see module
/// docs; the uncompensated bound is half this).
pub fn int8_slot_error_bound(x: &[f32]) -> f32 {
    super::int8::int8_error_bound(x, OPTIM_BLOCK) * 2.0
}

/// A host-side snapshot of a state's optimizer slots, in trainable state
/// order — the checkpoint interchange format for optimizer state. Pure
/// data: `checkpoint/` serializes it, backends produce/consume it.
#[derive(Debug, Clone, PartialEq)]
pub enum OptimSnapshot {
    Fp32 { m: Vec<Vec<f32>>, v: Vec<Vec<f32>> },
    Int8 { m: Vec<Int8Slot>, v: Vec<Int8Slot> },
}

impl OptimSnapshot {
    /// The codec this snapshot stores.
    pub fn codec(&self) -> OptimStates {
        match self {
            OptimSnapshot::Fp32 { .. } => OptimStates::Fp32,
            OptimSnapshot::Int8 { .. } => OptimStates::Int8,
        }
    }

    /// Slot-pair count (== trainable tensor count).
    pub fn len(&self) -> usize {
        match self {
            OptimSnapshot::Fp32 { m, .. } => m.len(),
            OptimSnapshot::Int8 { m, .. } => m.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn zeros_decode_to_zero() {
        let s = Int8Slot::zeros(300);
        let mut out = vec![9.0f32; 300];
        s.decode_into(&mut out);
        assert!(out.iter().all(|&x| x == 0.0));
        assert_eq!(s.len(), 300);
    }

    #[test]
    fn roundtrip_within_full_step_bound_and_zero_block_mean() {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal() as f32 * 0.01).collect();
        let mut slot = Int8Slot::zeros(x.len());
        slot.encode_from(&x);
        let mut back = vec![0.0f32; x.len()];
        slot.decode_into(&mut back);
        let bound = int8_slot_error_bound(&x) + 1e-7;
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
        // compensation kills the block-mean drift: per-block decoded mean
        // matches the exact mean to f32 roundoff, not to the codec step
        for blk in 0..x.len().div_ceil(OPTIM_BLOCK) {
            let lo = blk * OPTIM_BLOCK;
            let hi = ((blk + 1) * OPTIM_BLOCK).min(x.len());
            let exact: f64 = x[lo..hi].iter().map(|&v| v as f64).sum();
            let got: f64 = back[lo..hi].iter().map(|&v| v as f64).sum();
            assert!(
                ((exact - got) / (hi - lo) as f64).abs() < 1e-6,
                "block {blk} mean drift"
            );
        }
    }

    #[test]
    fn storage_is_at_least_3_5x_smaller_than_f32() {
        let slot = Int8Slot::zeros(100_000);
        let f32_bytes = 100_000 * 4;
        assert!(f32_bytes as f64 / slot.storage_bytes() as f64 >= 3.5);
    }

    #[test]
    fn encode_is_idempotent_on_grid_values() {
        // decode(encode(decode(encode(x)))) == decode(encode(x)): the
        // second pass sees on-grid+comp values whose re-encode reproduces
        // the same bytes is NOT guaranteed (comp shifts them off-grid), but
        // the decoded values must stay within one further bound step.
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let mut s = Int8Slot::zeros(x.len());
        s.encode_from(&x);
        let mut d1 = vec![0.0f32; x.len()];
        s.decode_into(&mut d1);
        s.encode_from(&d1);
        let mut d2 = vec![0.0f32; x.len()];
        s.decode_into(&mut d2);
        let bound = int8_slot_error_bound(&x) + 1e-7;
        for (a, b) in d1.iter().zip(&d2) {
            assert!((a - b).abs() <= bound);
        }
    }

    #[test]
    fn codec_parse_names() {
        assert_eq!(OptimStates::parse("fp32").unwrap(), OptimStates::Fp32);
        assert_eq!(OptimStates::parse("int8").unwrap(), OptimStates::Int8);
        assert!(OptimStates::parse("bf16").is_err());
        assert_eq!(OptimStates::default(), OptimStates::Fp32);
    }
}
