//! Quantization codecs (paper Def. 9/22/23, §S11/§S16) + Kahan summation
//! (§S2.4) — the Rust-side implementations used for checkpoint compression
//! and the error-bound benches. Mirrors `python/compile/kernels/quantize.py`.

pub mod fp8;
pub mod int8;
pub mod kahan;
pub mod optim;
pub mod qmat;

pub use fp8::{fp8_decode, fp8_encode, fp8_pack, fp8_unpack, DelayedScaler, Fp8Format};
pub use int8::{int8_dequantize, int8_quantize, Int8Blocks};
pub use kahan::{kahan_sum, naive_sum};
pub use optim::{int8_slot_error_bound, Int8Slot, OptimSnapshot, OptimStates, OPTIM_BLOCK};
pub use qmat::{BaseQuant, QuantMat, BASE_BLOCK};

#[cfg(test)]
mod tests {
    #[test]
    fn module_reexports() {
        // compile-time check that the public surface exists
        let _ = super::fp8_encode;
        let _ = super::int8_quantize;
        let _ = super::kahan_sum;
    }
}
