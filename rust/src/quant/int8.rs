//! Block-wise symmetric int8 quantization (paper Def. 9, Alg. 23).
//!
//! One f32 scale per `block` values: q = round(x/scale · 127) with
//! scale = amax/127, giving |err| ≤ amax/(2·127) per element (half ulp of
//! the paper's Eq. 18 bound). 8-bit optimizer states use exactly this.

/// Quantized blocks: `data.len() == n_blocks * block`, zero-padded.
#[derive(Debug, Clone, PartialEq)]
pub struct Int8Blocks {
    pub data: Vec<i8>,
    pub scales: Vec<f32>,
    pub block: usize,
    pub n: usize, // original element count
}

pub fn int8_quantize(x: &[f32], block: usize) -> Int8Blocks {
    assert!(block > 0);
    let n = x.len();
    let n_blocks = n.div_ceil(block).max(1);
    let mut data = vec![0i8; n_blocks * block];
    let mut scales = vec![1.0f32; n_blocks];
    for b in 0..n_blocks {
        let lo = b * block;
        let hi = ((b + 1) * block).min(n);
        let amax = x[lo..hi].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
        scales[b] = scale;
        for i in lo..hi {
            data[i] = (x[i] / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    Int8Blocks { data, scales, block, n }
}

pub fn int8_dequantize(q: &Int8Blocks) -> Vec<f32> {
    let mut out = Vec::with_capacity(q.n);
    for (i, &v) in q.data.iter().take(q.n).enumerate() {
        let scale = q.scales[i / q.block];
        out.push(v as f32 * scale);
    }
    out
}

/// Max absolute round-trip error permitted by the format for this input
/// (per-block amax/254 — half a quantization step, paper Eq. 18).
pub fn int8_error_bound(x: &[f32], block: usize) -> f32 {
    let n_blocks = x.len().div_ceil(block).max(1);
    let mut worst = 0.0f32;
    for b in 0..n_blocks {
        let lo = b * block;
        let hi = ((b + 1) * block).min(x.len());
        let amax = x[lo..hi].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        worst = worst.max(amax / 127.0 * 0.5);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_within_bound() {
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let q = int8_quantize(&x, 128);
        let back = int8_dequantize(&q);
        let bound = int8_error_bound(&x, 128) + 1e-7;
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
    }

    #[test]
    fn blockwise_beats_global_on_mixed_scales() {
        // paper §S11.1: embedding-layer ~1e-3 values next to output-layer
        // ~1e-1 values destroy a global scale.
        let mut x = vec![0.001f32; 128];
        x.extend(vec![0.1f32; 128]);
        let block = int8_quantize(&x, 128);
        let global = int8_quantize(&x, 256);
        let err = |q: &Int8Blocks| {
            int8_dequantize(q)
                .iter()
                .zip(&x)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max)
        };
        assert!(err(&block) < err(&global));
    }

    #[test]
    fn memory_savings_4x() {
        // paper Prop. 20: int8 + 1 scale per block ≈ 1/4 the f32 bytes
        let n = 100_000;
        let block = 2048;
        let q_bytes = n + (n / block) * 4;
        let f_bytes = n * 4;
        assert!((f_bytes as f64 / q_bytes as f64) > 3.9);
    }

    #[test]
    fn zero_block_is_exact() {
        let x = vec![0.0f32; 64];
        let q = int8_quantize(&x, 32);
        assert_eq!(int8_dequantize(&q), x);
    }

    #[test]
    fn uneven_tail_block() {
        let x: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let q = int8_quantize(&x, 64);
        assert_eq!(q.scales.len(), 2);
        assert_eq!(int8_dequantize(&q).len(), 100);
    }
}
