//! Quantized frozen-weight storage for LoRA-family tasks (ROADMAP "memory
//! tiers", paper §S11): base weight matrices held as int8 blocks (or FP8
//! bytes with a per-tensor delayed-style scale) and dequantized on the fly.
//!
//! The contract both CPU backends implement against this type:
//!
//! * dequantization is **elementwise and positional** — `dequant_range_into`
//!   over any flat range yields exactly the same values as a full
//!   `dequant()`, so a tiled consumer (cpu_fast: per-tile arena lease) and
//!   a naive consumer (cpu reference: whole-matrix dequant once at
//!   configure time) see bit-identical weights;
//! * encode(decode(encode(x))) is byte-stable — decoded values are on the
//!   codec grid, so checkpoint roundtrips through f32 interchange are
//!   lossless once quantized.

use super::fp8::{fp8_pack, fp8_unpack, Fp8Format};
use super::int8::{int8_quantize, Int8Blocks};
use anyhow::{bail, Result};

/// Block length for int8 base-weight quantization (same block as the
/// checkpoint codec and the optimizer-state tier).
pub const BASE_BLOCK: usize = 128;

/// Which codec holds frozen base weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaseQuant {
    /// Block-wise int8 (amax/127 per 128-block) — the default tier.
    Int8,
    /// FP8 E4M3 bytes with one per-tensor scale — the second codec.
    Fp8,
}

impl BaseQuant {
    /// Parse a CLI/TOML name (`--base-quant int8|fp8`; `none` is handled
    /// by the caller as `Option::None`).
    pub fn parse(name: &str) -> Result<BaseQuant> {
        Ok(match name {
            "int8" | "i8" => BaseQuant::Int8,
            "fp8" | "e4m3" => BaseQuant::Fp8,
            other => bail!("unknown base-weight codec '{other}' (expected none | int8 | fp8)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BaseQuant::Int8 => "int8",
            BaseQuant::Fp8 => "fp8",
        }
    }
}

/// One quantized weight matrix, stored flat in row-major order.
#[derive(Debug, Clone, PartialEq)]
pub enum QuantMat {
    Int8(Int8Blocks),
    Fp8 {
        bytes: Vec<u8>,
        fmt: Fp8Format,
        /// Per-tensor scale (amax / fmt.max_val()), DelayedScaler-style.
        scale: f32,
        n: usize,
    },
}

impl QuantMat {
    pub fn encode(x: &[f32], codec: BaseQuant) -> QuantMat {
        match codec {
            BaseQuant::Int8 => QuantMat::Int8(int8_quantize(x, BASE_BLOCK)),
            BaseQuant::Fp8 => {
                let fmt = Fp8Format::E4M3;
                let amax = x.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
                let scale = if amax > 0.0 { amax / fmt.max_val() } else { 1.0 };
                let bytes = x.iter().map(|&v| fp8_pack(v / scale, fmt)).collect();
                QuantMat::Fp8 { bytes, fmt, scale, n: x.len() }
            }
        }
    }

    pub fn codec(&self) -> BaseQuant {
        match self {
            QuantMat::Int8(_) => BaseQuant::Int8,
            QuantMat::Fp8 { .. } => BaseQuant::Fp8,
        }
    }

    /// Logical element count.
    pub fn n(&self) -> usize {
        match self {
            QuantMat::Int8(q) => q.n,
            QuantMat::Fp8 { n, .. } => *n,
        }
    }

    /// Actual storage bytes (payload + scales) — the memory-tier
    /// accounting numerator.
    pub fn storage_bytes(&self) -> usize {
        match self {
            QuantMat::Int8(q) => q.data.len() + q.scales.len() * 4,
            QuantMat::Fp8 { bytes, .. } => bytes.len() + 4,
        }
    }

    /// Dequantize the flat range `[lo, lo + out.len())` into `out`.
    /// Positional: element `lo + i` decodes identically regardless of the
    /// range it is fetched through — the per-tile dequant contract.
    pub fn dequant_range_into(&self, lo: usize, out: &mut [f32]) {
        match self {
            QuantMat::Int8(q) => {
                debug_assert!(lo + out.len() <= q.n);
                for (i, o) in out.iter_mut().enumerate() {
                    let j = lo + i;
                    *o = q.data[j] as f32 * q.scales[j / q.block];
                }
            }
            QuantMat::Fp8 { bytes, fmt, scale, n } => {
                debug_assert!(lo + out.len() <= *n);
                for (i, o) in out.iter_mut().enumerate() {
                    *o = fp8_unpack(bytes[lo + i], *fmt) * scale;
                }
            }
        }
    }

    /// Full dequantization (the reference backend's naive contract; also
    /// used for f32 checkpoint interchange).
    pub fn dequant(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n()];
        self.dequant_range_into(0, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32 * 0.08).collect()
    }

    #[test]
    fn tile_dequant_matches_full_dequant_bitwise() {
        let x = sample(1000, 21);
        for codec in [BaseQuant::Int8, BaseQuant::Fp8] {
            let q = QuantMat::encode(&x, codec);
            let full = q.dequant();
            // fetch through ragged tiles; every element must match bitwise
            let mut tile = vec![0.0f32; 96];
            let mut lo = 0;
            while lo < x.len() {
                let len = 96.min(x.len() - lo);
                q.dequant_range_into(lo, &mut tile[..len]);
                for i in 0..len {
                    assert_eq!(tile[i].to_bits(), full[lo + i].to_bits(), "{codec:?} @ {}", lo + i);
                }
                lo += len;
            }
        }
    }

    #[test]
    fn requantize_of_decoded_grid_is_lossless() {
        // checkpoint roundtrip: dequant → f32 interchange → re-encode must
        // reproduce the decoded values exactly (grid fixed points)
        let x = sample(512, 22);
        for codec in [BaseQuant::Int8, BaseQuant::Fp8] {
            let q1 = QuantMat::encode(&x, codec);
            let d1 = q1.dequant();
            let q2 = QuantMat::encode(&d1, codec);
            let d2 = q2.dequant();
            for (a, b) in d1.iter().zip(&d2) {
                assert_eq!(a.to_bits(), b.to_bits(), "{codec:?}");
            }
        }
    }

    #[test]
    fn int8_base_error_within_block_bound() {
        let x = sample(4096, 23);
        let q = QuantMat::encode(&x, BaseQuant::Int8);
        let d = q.dequant();
        let bound = crate::quant::int8::int8_error_bound(&x, BASE_BLOCK) + 1e-7;
        for (a, b) in x.iter().zip(&d) {
            assert!((a - b).abs() <= bound);
        }
    }

    #[test]
    fn storage_shrinks_vs_f32() {
        let x = sample(100_000, 24);
        for codec in [BaseQuant::Int8, BaseQuant::Fp8] {
            let q = QuantMat::encode(&x, codec);
            assert!(
                (x.len() * 4) as f64 / q.storage_bytes() as f64 >= 3.5,
                "{codec:?}"
            );
        }
    }

    #[test]
    fn codec_parse_names() {
        assert_eq!(BaseQuant::parse("int8").unwrap(), BaseQuant::Int8);
        assert_eq!(BaseQuant::parse("fp8").unwrap(), BaseQuant::Fp8);
        assert!(BaseQuant::parse("int4").is_err());
    }
}
