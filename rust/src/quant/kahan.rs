//! Kahan-compensated summation (paper Def. 14, Alg. 28, §S2.4/§S17.2).
//!
//! Error O(ε) independent of n, vs O(n·ε) for the naive loop — the paper
//! uses this for BF16 gradient accumulation; here it guards f32 checkpoint
//! statistics and is benchmarked in `benches/bench_quant.rs`.

/// Single-pass Kahan sum.
pub fn kahan_sum(xs: &[f32]) -> f32 {
    let mut s = 0.0f32;
    let mut c = 0.0f32;
    for &x in xs {
        let y = x - c;
        let t = s + y;
        c = (t - s) - y;
        s = t;
    }
    s
}

/// Naive left-to-right sum (the O(n·ε) baseline).
pub fn naive_sum(xs: &[f32]) -> f32 {
    xs.iter().sum()
}

/// Streaming Kahan accumulator for gradient-style accumulation across
/// micro-batches (one compensation per element, paper Alg. 28).
#[derive(Debug, Clone)]
pub struct KahanAccumulator {
    pub sum: Vec<f32>,
    comp: Vec<f32>,
}

impl KahanAccumulator {
    pub fn new(n: usize) -> Self {
        KahanAccumulator { sum: vec![0.0; n], comp: vec![0.0; n] }
    }

    pub fn add(&mut self, xs: &[f32]) {
        assert_eq!(xs.len(), self.sum.len());
        for i in 0..xs.len() {
            let y = xs[i] - self.comp[i];
            let t = self.sum[i] + y;
            self.comp[i] = (t - self.sum[i]) - y;
            self.sum[i] = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kahan_beats_naive_on_adversarial_input() {
        // many tiny values after a large one: naive loses the low bits
        let mut xs = vec![1e8f32];
        xs.extend(std::iter::repeat(1.0f32).take(10_000));
        let exact = 1e8f64 + 10_000.0;
        let k = kahan_sum(&xs) as f64;
        let n = naive_sum(&xs) as f64;
        assert!((k - exact).abs() <= (n - exact).abs());
        assert!((k - exact).abs() / exact < 1e-7, "kahan err {}", (k - exact).abs());
    }

    #[test]
    fn kahan_error_independent_of_n() {
        let mut rng = Rng::new(8);
        for n in [1_000usize, 100_000] {
            let xs: Vec<f32> = (0..n).map(|_| 1.0 + rng.f64() as f32 * 1e-4).collect();
            let exact: f64 = xs.iter().map(|&x| x as f64).sum();
            let k = kahan_sum(&xs) as f64;
            assert!(
                (k - exact).abs() / exact < 1e-6,
                "n={n} err={}",
                (k - exact).abs() / exact
            );
        }
    }

    #[test]
    fn accumulator_matches_scalar_kahan() {
        let mut rng = Rng::new(9);
        let micro: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..64).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut acc = KahanAccumulator::new(64);
        for m in &micro {
            acc.add(m);
        }
        for i in 0..64 {
            let col: Vec<f32> = micro.iter().map(|m| m[i]).collect();
            assert!((acc.sum[i] - kahan_sum(&col)).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_sum_is_zero() {
        assert_eq!(kahan_sum(&[]), 0.0);
    }
}
