//! Software FP8: E4M3 / E5M2 encode-decode (paper Def. 22/23, §S16) and the
//! DeepSeek-V3-style delayed scaler with an amax history window (Alg. 27).
//!
//! Hardware FP8 tensor cores are simulated (§Substitutions): the numerics —
//! range, mantissa grid, SNR, scale-factor dynamics — are exactly the
//! paper's; only the throughput benefit is out of scope on CPU.

/// FP8 format parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fp8Format {
    /// 4 exponent bits, 3 mantissa bits, max 448 (paper Def. 22).
    E4M3,
    /// 5 exponent bits, 2 mantissa bits, max 57344 (paper Def. 23).
    E5M2,
}

impl Fp8Format {
    pub fn max_val(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 448.0,
            Fp8Format::E5M2 => 57344.0,
        }
    }
    pub fn mant_bits(self) -> i32 {
        match self {
            Fp8Format::E4M3 => 3,
            Fp8Format::E5M2 => 2,
        }
    }
    pub fn min_exp(self) -> i32 {
        match self {
            Fp8Format::E4M3 => -6,
            Fp8Format::E5M2 => -14,
        }
    }
    /// Quantization SNR ≈ 6.02·b + 1.76 dB (paper Thm. 11).
    pub fn snr_db(self) -> f64 {
        6.02 * self.mant_bits() as f64 + 1.76
    }
}

/// Round one f32 to the nearest representable FP8 value (round-to-nearest,
/// saturating at ±max).
pub fn fp8_encode(x: f32, fmt: Fp8Format) -> f32 {
    if x == 0.0 || x.is_nan() {
        return if x.is_nan() { f32::NAN } else { 0.0 };
    }
    let sign = x.signum();
    let mag = x.abs().min(fmt.max_val());
    let exp = mag.log2().floor().max(fmt.min_exp() as f32);
    let quantum = (exp - fmt.mant_bits() as f32).exp2();
    let q = (mag / quantum).round() * quantum;
    sign * q.min(fmt.max_val())
}

/// Encode a slice (the "dequantized view": values on the FP8 grid).
pub fn fp8_decode(xs: &[f32], fmt: Fp8Format) -> Vec<f32> {
    xs.iter().map(|&x| fp8_encode(x, fmt)).collect()
}

/// Delayed scaling with an amax history window (paper Alg. 27, Prop. 25):
/// scale = max(history)/fmt.max — never underestimates within the window,
/// and damps single-outlier oscillation by 1/len.
#[derive(Debug, Clone)]
pub struct DelayedScaler {
    history: Vec<f32>,
    idx: usize,
    len: usize,
    fmt: Fp8Format,
}

impl DelayedScaler {
    pub fn new(window: usize, fmt: Fp8Format) -> Self {
        assert!(window > 0);
        DelayedScaler { history: vec![0.0; window], idx: 0, len: 0, fmt }
    }

    /// Record the tensor's amax, return the scale to use *this* step.
    pub fn update(&mut self, amax: f32) -> f32 {
        self.history[self.idx] = amax;
        self.idx = (self.idx + 1) % self.history.len();
        self.len = (self.len + 1).min(self.history.len());
        self.scale()
    }

    pub fn scale(&self) -> f32 {
        let m = self.history[..self.len.max(1)]
            .iter()
            .fold(0.0f32, |a, &b| a.max(b));
        if m > 0.0 {
            m / self.fmt.max_val()
        } else {
            1.0
        }
    }

    /// Quantize a tensor with the current delayed scale.
    pub fn quantize(&mut self, xs: &[f32]) -> (Vec<f32>, f32) {
        let amax = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let scale = self.update(amax);
        let q = xs.iter().map(|&x| fp8_encode(x / scale, self.fmt)).collect();
        (q, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn e4m3_saturates_at_448() {
        assert_eq!(fp8_encode(500.0, Fp8Format::E4M3), 448.0);
        assert_eq!(fp8_encode(-1e9, Fp8Format::E4M3), -448.0);
    }

    #[test]
    fn e5m2_saturates_at_57344() {
        assert_eq!(fp8_encode(60000.0, Fp8Format::E5M2), 57344.0);
    }

    #[test]
    fn mantissa_grid_e4m3() {
        // in [1, 2): steps of 1/8
        assert_eq!(fp8_encode(1.0, Fp8Format::E4M3), 1.0);
        assert_eq!(fp8_encode(1.0624, Fp8Format::E4M3), 1.0);
        assert_eq!(fp8_encode(1.07, Fp8Format::E4M3), 1.125);
    }

    #[test]
    fn relative_error_bound() {
        // half-ulp: 2^-(mant_bits+1) for normal values
        let mut rng = Rng::new(6);
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            let bound = 0.5f32.powi(fmt.mant_bits() + 1) + 1e-6;
            for _ in 0..1000 {
                let x = (rng.normal() as f32).abs().max(0.02) * 10.0;
                let q = fp8_encode(x, fmt);
                if x <= fmt.max_val() {
                    assert!(((q - x) / x).abs() <= bound, "{x} -> {q} ({fmt:?})");
                }
            }
        }
    }

    #[test]
    fn snr_formula() {
        assert!((Fp8Format::E4M3.snr_db() - 19.82).abs() < 0.01);
        assert!((Fp8Format::E5M2.snr_db() - 13.8).abs() < 0.01);
    }

    #[test]
    fn zero_and_nan() {
        assert_eq!(fp8_encode(0.0, Fp8Format::E4M3), 0.0);
        assert!(fp8_encode(f32::NAN, Fp8Format::E4M3).is_nan());
    }

    #[test]
    fn delayed_scaler_damps_outliers() {
        // paper §S16.2: one outlier must not swing the scale back down
        // after it leaves; max-over-window holds it.
        let mut s = DelayedScaler::new(32, Fp8Format::E4M3);
        for _ in 0..10 {
            s.update(1.0);
        }
        let before = s.scale();
        s.update(100.0); // outlier
        let spike = s.scale();
        for _ in 0..5 {
            s.update(1.0);
        }
        let after = s.scale();
        assert!(spike > before);
        assert_eq!(after, spike); // still inside the 32-window
    }

    #[test]
    fn delayed_scaler_never_underestimates_in_window() {
        let mut s = DelayedScaler::new(4, Fp8Format::E4M3);
        s.update(2.0);
        s.update(8.0);
        // quantizing values up to the window amax cannot overflow
        let (q, scale) = s.quantize(&[8.0, -8.0, 1.0]);
        assert!(scale >= 8.0 / 448.0);
        for v in q {
            assert!(v.abs() <= 448.0);
        }
    }

    #[test]
    fn window_expires_old_amax() {
        let mut s = DelayedScaler::new(2, Fp8Format::E4M3);
        s.update(100.0);
        s.update(1.0);
        s.update(1.0); // 100 has rolled out of the 2-window
        assert!((s.scale() - 1.0 / 448.0).abs() < 1e-9);
    }
}
