//! Software FP8: E4M3 / E5M2 encode-decode (paper Def. 22/23, §S16) and the
//! DeepSeek-V3-style delayed scaler with an amax history window (Alg. 27).
//!
//! Hardware FP8 tensor cores are simulated (§Substitutions): the numerics —
//! range, mantissa grid, SNR, scale-factor dynamics — are exactly the
//! paper's; only the throughput benefit is out of scope on CPU.

/// FP8 format parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fp8Format {
    /// 4 exponent bits, 3 mantissa bits, max 448 (paper Def. 22).
    E4M3,
    /// 5 exponent bits, 2 mantissa bits, max 57344 (paper Def. 23).
    E5M2,
}

impl Fp8Format {
    pub fn max_val(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 448.0,
            Fp8Format::E5M2 => 57344.0,
        }
    }
    pub fn mant_bits(self) -> i32 {
        match self {
            Fp8Format::E4M3 => 3,
            Fp8Format::E5M2 => 2,
        }
    }
    pub fn min_exp(self) -> i32 {
        match self {
            Fp8Format::E4M3 => -6,
            Fp8Format::E5M2 => -14,
        }
    }
    /// Quantization SNR ≈ 6.02·b + 1.76 dB (paper Thm. 11).
    pub fn snr_db(self) -> f64 {
        6.02 * self.mant_bits() as f64 + 1.76
    }
}

/// Round one f32 to the nearest representable FP8 value (round-to-nearest,
/// saturating at ±max).
pub fn fp8_encode(x: f32, fmt: Fp8Format) -> f32 {
    if x == 0.0 || x.is_nan() {
        return if x.is_nan() { f32::NAN } else { 0.0 };
    }
    let sign = x.signum();
    let mag = x.abs().min(fmt.max_val());
    let exp = mag.log2().floor().max(fmt.min_exp() as f32);
    let quantum = (exp - fmt.mant_bits() as f32).exp2();
    let q = (mag / quantum).round() * quantum;
    sign * q.min(fmt.max_val())
}

/// Encode a slice (the "dequantized view": values on the FP8 grid).
pub fn fp8_decode(xs: &[f32], fmt: Fp8Format) -> Vec<f32> {
    xs.iter().map(|&x| fp8_encode(x, fmt)).collect()
}

/// Pack one f32 into the real FP8 byte layout: sign | exponent | mantissa
/// (OCP FP8 bit pattern). The value is first snapped to the grid with
/// [`fp8_encode`], so packing is exact — no second rounding.
pub fn fp8_pack(x: f32, fmt: Fp8Format) -> u8 {
    let mant = fmt.mant_bits() as u32; // 3 (E4M3) | 2 (E5M2)
    let exp_bits = 7 - mant;
    let bias = (1i32 << (exp_bits - 1)) - 1;
    let mant_mask = (1u8 << mant) - 1;
    if x.is_nan() {
        // canonical NaN: all-ones exponent + all-ones mantissa (valid in
        // both formats; E4M3 reserves only this one pattern per sign)
        return ((((1u32 << exp_bits) - 1) << mant) as u8) | mant_mask;
    }
    let sign: u8 = if x.is_sign_negative() { 0x80 } else { 0 };
    let v = fp8_encode(x.abs(), fmt); // on-grid magnitude, saturating
    if v == 0.0 {
        return sign;
    }
    let bits = v.to_bits();
    let e = ((bits >> 23) & 0xff) as i32 - 127;
    if e < fmt.min_exp() {
        // subnormal: v = m · 2^(min_exp − mant), m ∈ 1..2^mant
        let m = (v * ((mant as i32 - fmt.min_exp()) as f32).exp2()).round() as u8;
        return sign | (m & mant_mask);
    }
    let exp_field = (e + bias) as u8;
    let mant_field = ((bits >> (23 - mant)) & mant_mask as u32) as u8;
    sign | (exp_field << mant) | mant_field
}

/// Unpack one FP8 byte back to f32. Inverse of [`fp8_pack`] for every
/// non-NaN bit pattern.
pub fn fp8_unpack(b: u8, fmt: Fp8Format) -> f32 {
    let mant = fmt.mant_bits() as u32;
    let exp_bits = 7 - mant;
    let bias = (1i32 << (exp_bits - 1)) - 1;
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp_field = ((b >> mant) & (((1u32 << exp_bits) - 1) as u8)) as i32;
    let m = (b & ((1u8 << mant) - 1)) as u32;
    if exp_field == (1i32 << exp_bits) - 1 {
        match fmt {
            // E5M2 follows IEEE: top exponent is inf/NaN
            Fp8Format::E5M2 => {
                return if m == 0 { sign * f32::INFINITY } else { f32::NAN };
            }
            // E4M3 reclaims the top exponent for normals; only the
            // all-ones mantissa is NaN
            Fp8Format::E4M3 => {
                if m == (1 << mant) - 1 {
                    return f32::NAN;
                }
            }
        }
    }
    if exp_field == 0 {
        return sign * m as f32 * ((fmt.min_exp() - mant as i32) as f32).exp2();
    }
    sign * (1.0 + m as f32 / (1u32 << mant) as f32) * ((exp_field - bias) as f32).exp2()
}

/// Delayed scaling with an amax history window (paper Alg. 27, Prop. 25):
/// scale = max(history)/fmt.max — never underestimates within the window,
/// and damps single-outlier oscillation by 1/len.
#[derive(Debug, Clone)]
pub struct DelayedScaler {
    history: Vec<f32>,
    idx: usize,
    len: usize,
    fmt: Fp8Format,
}

impl DelayedScaler {
    pub fn new(window: usize, fmt: Fp8Format) -> Self {
        assert!(window > 0);
        DelayedScaler { history: vec![0.0; window], idx: 0, len: 0, fmt }
    }

    /// Record the tensor's amax, return the scale to use *this* step.
    pub fn update(&mut self, amax: f32) -> f32 {
        self.history[self.idx] = amax;
        self.idx = (self.idx + 1) % self.history.len();
        self.len = (self.len + 1).min(self.history.len());
        self.scale()
    }

    pub fn scale(&self) -> f32 {
        let m = self.history[..self.len.max(1)]
            .iter()
            .fold(0.0f32, |a, &b| a.max(b));
        if m > 0.0 {
            m / self.fmt.max_val()
        } else {
            1.0
        }
    }

    /// Quantize a tensor with the current delayed scale.
    pub fn quantize(&mut self, xs: &[f32]) -> (Vec<f32>, f32) {
        let amax = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let scale = self.update(amax);
        let q = xs.iter().map(|&x| fp8_encode(x / scale, self.fmt)).collect();
        (q, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn e4m3_saturates_at_448() {
        assert_eq!(fp8_encode(500.0, Fp8Format::E4M3), 448.0);
        assert_eq!(fp8_encode(-1e9, Fp8Format::E4M3), -448.0);
    }

    #[test]
    fn e5m2_saturates_at_57344() {
        assert_eq!(fp8_encode(60000.0, Fp8Format::E5M2), 57344.0);
    }

    #[test]
    fn mantissa_grid_e4m3() {
        // in [1, 2): steps of 1/8
        assert_eq!(fp8_encode(1.0, Fp8Format::E4M3), 1.0);
        assert_eq!(fp8_encode(1.0624, Fp8Format::E4M3), 1.0);
        assert_eq!(fp8_encode(1.07, Fp8Format::E4M3), 1.125);
    }

    #[test]
    fn relative_error_bound() {
        // half-ulp: 2^-(mant_bits+1) for normal values
        let mut rng = Rng::new(6);
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            let bound = 0.5f32.powi(fmt.mant_bits() + 1) + 1e-6;
            for _ in 0..1000 {
                let x = (rng.normal() as f32).abs().max(0.02) * 10.0;
                let q = fp8_encode(x, fmt);
                if x <= fmt.max_val() {
                    assert!(((q - x) / x).abs() <= bound, "{x} -> {q} ({fmt:?})");
                }
            }
        }
    }

    #[test]
    fn snr_formula() {
        assert!((Fp8Format::E4M3.snr_db() - 19.82).abs() < 0.01);
        assert!((Fp8Format::E5M2.snr_db() - 13.8).abs() < 0.01);
    }

    #[test]
    fn zero_and_nan() {
        assert_eq!(fp8_encode(0.0, Fp8Format::E4M3), 0.0);
        assert!(fp8_encode(f32::NAN, Fp8Format::E4M3).is_nan());
    }

    #[test]
    fn delayed_scaler_damps_outliers() {
        // paper §S16.2: one outlier must not swing the scale back down
        // after it leaves; max-over-window holds it.
        let mut s = DelayedScaler::new(32, Fp8Format::E4M3);
        for _ in 0..10 {
            s.update(1.0);
        }
        let before = s.scale();
        s.update(100.0); // outlier
        let spike = s.scale();
        for _ in 0..5 {
            s.update(1.0);
        }
        let after = s.scale();
        assert!(spike > before);
        assert_eq!(after, spike); // still inside the 32-window
    }

    #[test]
    fn delayed_scaler_never_underestimates_in_window() {
        let mut s = DelayedScaler::new(4, Fp8Format::E4M3);
        s.update(2.0);
        s.update(8.0);
        // quantizing values up to the window amax cannot overflow
        let (q, scale) = s.quantize(&[8.0, -8.0, 1.0]);
        assert!(scale >= 8.0 / 448.0);
        for v in q {
            assert!(v.abs() <= 448.0);
        }
    }

    #[test]
    fn pack_roundtrips_every_finite_byte_pattern() {
        // exhaustive: unpack → pack must reproduce the byte for every
        // finite pattern in both formats (the quantized-base storage
        // contract: bytes on disk are canonical)
        for byte in 0u16..=255 {
            let b = byte as u8;
            for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
                let v = fp8_unpack(b, fmt);
                if v.is_nan() || v.is_infinite() {
                    continue;
                }
                assert_eq!(fp8_pack(v, fmt), b, "byte {b:#04x} ({fmt:?}) -> {v}");
                // grid closure: unpacked values are fp8_encode fixed points
                assert_eq!(fp8_encode(v, fmt), v, "byte {b:#04x} off-grid ({fmt:?})");
            }
        }
    }

    #[test]
    fn pack_agrees_with_grid_encode() {
        let mut rng = Rng::new(11);
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            for _ in 0..2000 {
                let x = rng.normal() as f32 * 3.0;
                let grid = fp8_encode(x, fmt);
                let via_bytes = fp8_unpack(fp8_pack(x, fmt), fmt);
                assert_eq!(grid.to_bits(), via_bytes.to_bits(), "{x} ({fmt:?})");
            }
        }
    }

    #[test]
    fn pack_handles_edges() {
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            assert_eq!(fp8_pack(0.0, fmt), 0x00);
            assert_eq!(fp8_pack(-0.0, fmt), 0x80);
            assert!(fp8_unpack(fp8_pack(f32::NAN, fmt), fmt).is_nan());
            // saturation packs to the max-magnitude finite byte
            let max = fmt.max_val();
            assert_eq!(fp8_unpack(fp8_pack(1e30, fmt), fmt), max);
            assert_eq!(fp8_unpack(fp8_pack(-1e30, fmt), fmt), -max);
        }
        // E4M3 smallest subnormal: 2^-9
        assert_eq!(fp8_unpack(0x01, Fp8Format::E4M3), 0.001953125);
    }

    #[test]
    fn window_expires_old_amax() {
        let mut s = DelayedScaler::new(2, Fp8Format::E4M3);
        s.update(100.0);
        s.update(1.0);
        s.update(1.0); // 100 has rolled out of the 2-window
        assert!((s.scale() - 1.0 / 448.0).abs() < 1e-9);
    }
}
