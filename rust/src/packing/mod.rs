//! Sequence packing as bin packing (paper §7, Thm. 8, Alg. 16).
//!
//! Best-Fit Decreasing with a capacity-ordered search structure, plus
//! First-Fit Decreasing and Next-Fit baselines for the ablation. The BFD
//! guarantee — `BFD(I) ≤ 11/9·OPT(I) + 6/9` — is property-tested against
//! the `⌈ΣL/C⌉` lower bound in `rust/tests/prop_packing.rs`.

use std::collections::BTreeMap;

/// One packed bin: indices into the original item list + used capacity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bin {
    pub items: Vec<usize>,
    pub used: usize,
}

#[derive(Debug, Clone)]
pub struct Packing {
    pub bins: Vec<Bin>,
    pub capacity: usize,
    /// Items that exceeded the capacity and were skipped (paper Alg. 16
    /// line 7 "skip oversized").
    pub oversized: Vec<usize>,
}

impl Packing {
    pub fn n_bins(&self) -> usize {
        self.bins.len()
    }

    pub fn total_packed(&self) -> usize {
        self.bins.iter().map(|b| b.used).sum()
    }

    /// Fraction of bin capacity holding real tokens (Fig. 18's "97%").
    pub fn efficiency(&self) -> f64 {
        if self.bins.is_empty() {
            return 1.0;
        }
        self.total_packed() as f64 / (self.bins.len() * self.capacity) as f64
    }

    /// Padding waste fraction = 1 - efficiency (paper Prop. 14).
    pub fn waste(&self) -> f64 {
        1.0 - self.efficiency()
    }

    /// `⌈ΣL/C⌉` — the capacity lower bound on OPT (paper Eq. 80).
    pub fn opt_lower_bound(lengths: &[usize], capacity: usize) -> usize {
        let total: usize = lengths.iter().filter(|&&l| l <= capacity).sum();
        total.div_ceil(capacity)
    }
}

/// Best-Fit Decreasing (paper Alg. 16): sort descending, place each item in
/// the *tightest* bin that fits. The open-bin set is kept in a
/// `BTreeMap<remaining, Vec<bin_idx>>` so each placement is O(log m)
/// (§S4.2's min-heap, in ordered-map form).
pub fn best_fit_decreasing(lengths: &[usize], capacity: usize) -> Packing {
    let mut order: Vec<usize> = (0..lengths.len()).collect();
    order.sort_by(|&a, &b| lengths[b].cmp(&lengths[a]).then(a.cmp(&b)));

    let mut bins: Vec<Bin> = Vec::new();
    let mut oversized = Vec::new();
    // remaining capacity -> bin indices with that remaining
    let mut by_remaining: BTreeMap<usize, Vec<usize>> = BTreeMap::new();

    for &idx in &order {
        let len = lengths[idx];
        if len > capacity {
            oversized.push(idx);
            continue;
        }
        // tightest fit: smallest remaining >= len
        let found = by_remaining
            .range(len..)
            .next()
            .map(|(&rem, v)| (rem, *v.last().unwrap()));
        match found {
            Some((rem, bin_idx)) => {
                let v = by_remaining.get_mut(&rem).unwrap();
                v.pop();
                if v.is_empty() {
                    by_remaining.remove(&rem);
                }
                bins[bin_idx].items.push(idx);
                bins[bin_idx].used += len;
                let new_rem = rem - len;
                if new_rem > 0 {
                    by_remaining.entry(new_rem).or_default().push(bin_idx);
                }
            }
            None => {
                bins.push(Bin { items: vec![idx], used: len });
                let new_rem = capacity - len;
                if new_rem > 0 {
                    by_remaining.entry(new_rem).or_default().push(bins.len() - 1);
                }
            }
        }
    }
    Packing { bins, capacity, oversized }
}

/// First-Fit Decreasing: sort descending, place in the first bin that fits.
pub fn first_fit_decreasing(lengths: &[usize], capacity: usize) -> Packing {
    let mut order: Vec<usize> = (0..lengths.len()).collect();
    order.sort_by(|&a, &b| lengths[b].cmp(&lengths[a]).then(a.cmp(&b)));
    let mut bins: Vec<Bin> = Vec::new();
    let mut oversized = Vec::new();
    for &idx in &order {
        let len = lengths[idx];
        if len > capacity {
            oversized.push(idx);
            continue;
        }
        match bins.iter_mut().find(|b| b.used + len <= capacity) {
            Some(b) => {
                b.items.push(idx);
                b.used += len;
            }
            None => bins.push(Bin { items: vec![idx], used: len }),
        }
    }
    Packing { bins, capacity, oversized }
}

/// Next-Fit: no sorting, only the last bin stays open — the weakest
/// baseline (85–90% efficiency per §S4.2).
pub fn next_fit(lengths: &[usize], capacity: usize) -> Packing {
    let mut bins: Vec<Bin> = Vec::new();
    let mut oversized = Vec::new();
    for (idx, &len) in lengths.iter().enumerate() {
        if len > capacity {
            oversized.push(idx);
            continue;
        }
        match bins.last_mut() {
            Some(b) if b.used + len <= capacity => {
                b.items.push(idx);
                b.used += len;
            }
            _ => bins.push(Bin { items: vec![idx], used: len }),
        }
    }
    Packing { bins, capacity, oversized }
}

/// No packing at all: one sequence per bin (the padded baseline). Waste is
/// `(C - mean(L))/C` (paper Eq. 85).
pub fn no_packing(lengths: &[usize], capacity: usize) -> Packing {
    let mut bins = Vec::new();
    let mut oversized = Vec::new();
    for (idx, &len) in lengths.iter().enumerate() {
        if len > capacity {
            oversized.push(idx);
        } else {
            bins.push(Bin { items: vec![idx], used: len });
        }
    }
    Packing { bins, capacity, oversized }
}

/// Check structural invariants (used by tests and debug assertions).
pub fn validate(p: &Packing, lengths: &[usize]) -> Result<(), String> {
    let mut seen = vec![false; lengths.len()];
    for bin in &p.bins {
        let mut used = 0;
        for &i in &bin.items {
            if seen[i] {
                return Err(format!("item {i} placed twice"));
            }
            seen[i] = true;
            used += lengths[i];
        }
        if used != bin.used {
            return Err(format!("bin used mismatch: {} vs {}", used, bin.used));
        }
        if used > p.capacity {
            return Err(format!("bin overflow: {used} > {}", p.capacity));
        }
        if bin.items.is_empty() {
            return Err("empty bin".into());
        }
    }
    for &i in &p.oversized {
        if seen[i] {
            return Err(format!("oversized item {i} also packed"));
        }
        seen[i] = true;
    }
    if let Some(missing) = seen.iter().position(|&s| !s) {
        return Err(format!("item {missing} not placed"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bfd_packs_perfectly_divisible() {
        let lengths = vec![4, 4, 4, 4];
        let p = best_fit_decreasing(&lengths, 8);
        assert_eq!(p.n_bins(), 2);
        assert_eq!(p.efficiency(), 1.0);
        validate(&p, &lengths).unwrap();
    }

    #[test]
    fn bfd_prefers_tightest_bin() {
        // after placing 7 and 5, a 3 must go with the 5 (remaining 3),
        // not the 7 (remaining 1 — doesn't fit anyway); then a 1 goes with 7.
        let lengths = vec![7, 5, 3, 1];
        let p = best_fit_decreasing(&lengths, 8);
        assert_eq!(p.n_bins(), 2);
        validate(&p, &lengths).unwrap();
        let b0: usize = p.bins[0].used;
        let b1: usize = p.bins[1].used;
        assert_eq!(b0 + b1, 16);
        assert_eq!(b0.max(b1), 8);
    }

    #[test]
    fn oversized_items_skipped() {
        let lengths = vec![10, 3];
        let p = best_fit_decreasing(&lengths, 8);
        assert_eq!(p.oversized, vec![0]);
        assert_eq!(p.n_bins(), 1);
        validate(&p, &lengths).unwrap();
    }

    #[test]
    fn bfd_beats_or_ties_next_fit() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let lengths: Vec<usize> = (0..200).map(|_| rng.range(10, 500)).collect();
            let bfd = best_fit_decreasing(&lengths, 512);
            let nf = next_fit(&lengths, 512);
            assert!(bfd.n_bins() <= nf.n_bins());
        }
    }

    #[test]
    fn bfd_within_bound_of_opt_lower_bound() {
        // Thm. 8: BFD <= 11/9 OPT + 6/9; OPT >= ceil(sum/C)
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let lengths: Vec<usize> = (0..300).map(|_| rng.range(20, 512)).collect();
            let p = best_fit_decreasing(&lengths, 512);
            let lb = Packing::opt_lower_bound(&lengths, 512);
            assert!(
                (p.n_bins() as f64) <= 11.0 / 9.0 * lb as f64 + 6.0 / 9.0 + 1e-9,
                "bins={} lb={}",
                p.n_bins(),
                lb
            );
        }
    }

    #[test]
    fn packing_recovers_padding_waste() {
        // paper Prop. 14: mean 512 / max 2048 padding: ~75% waste unpacked,
        // <12% packed.
        let mut rng = Rng::new(3);
        let lengths: Vec<usize> = (0..2000)
            .map(|_| (rng.lognormal(6.0, 0.6) as usize).clamp(32, 2048))
            .collect();
        let unpacked = no_packing(&lengths, 2048);
        let packed = best_fit_decreasing(&lengths, 2048);
        assert!(unpacked.waste() > 0.5, "unpacked waste {}", unpacked.waste());
        assert!(packed.waste() < 0.12, "packed waste {}", packed.waste());
    }

    #[test]
    fn ffd_validates() {
        let mut rng = Rng::new(4);
        let lengths: Vec<usize> = (0..150).map(|_| rng.range(1, 513)).collect();
        let p = first_fit_decreasing(&lengths, 512);
        validate(&p, &lengths).unwrap();
    }

    #[test]
    fn empty_input() {
        let p = best_fit_decreasing(&[], 512);
        assert_eq!(p.n_bins(), 0);
        assert_eq!(p.efficiency(), 1.0);
    }

    #[test]
    fn item_exactly_capacity() {
        let lengths = vec![512, 512];
        let p = best_fit_decreasing(&lengths, 512);
        assert_eq!(p.n_bins(), 2);
        assert_eq!(p.efficiency(), 1.0);
        validate(&p, &lengths).unwrap();
    }
}
