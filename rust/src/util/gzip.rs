//! Hermetic gzip (RFC 1952) + DEFLATE (RFC 1951) decompressor for
//! `.jsonl.gz` corpora. Decompression only — the repo never writes
//! archives — and no external crates: like `util::json` and `util::toml`
//! this is a small, auditable subset implementation (stored, fixed-Huffman
//! and dynamic-Huffman blocks; the complete format every `gzip`/zlib
//! encoder emits). The CRC32 and ISIZE trailer are verified, so a
//! truncated or corrupted corpus is a hard error, never silent garbage.

use anyhow::{anyhow, bail, Result};

const MAX_BITS: usize = 15;

/// Decompress a complete gzip file image (one or more concatenated
/// members, as `gzip` and `cat a.gz b.gz` produce).
pub fn decompress(gz: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    let mut rest = gz;
    if rest.is_empty() {
        bail!("empty gzip stream");
    }
    while !rest.is_empty() {
        rest = member(rest, &mut out)?;
    }
    Ok(out)
}

/// Decode one gzip member into `out`, returning the unconsumed suffix.
fn member<'a>(gz: &'a [u8], out: &mut Vec<u8>) -> Result<&'a [u8]> {
    if gz.len() < 10 || gz[0] != 0x1f || gz[1] != 0x8b {
        bail!("not a gzip stream (bad magic bytes)");
    }
    if gz[2] != 8 {
        bail!("unsupported gzip compression method {} (expected 8 = deflate)", gz[2]);
    }
    let flg = gz[3];
    if flg & 0xe0 != 0 {
        bail!("reserved gzip FLG bits set ({flg:#04x})");
    }
    // skip MTIME(4), XFL, OS
    let mut i = 10usize;
    let need = |i: usize, n: usize| -> Result<()> {
        if i + n > gz.len() {
            bail!("truncated gzip header");
        }
        Ok(())
    };
    if flg & 0x04 != 0 {
        // FEXTRA
        need(i, 2)?;
        let xlen = u16::from_le_bytes([gz[i], gz[i + 1]]) as usize;
        i += 2;
        need(i, xlen)?;
        i += xlen;
    }
    for flag in [0x08u8, 0x10] {
        // FNAME, FCOMMENT: NUL-terminated
        if flg & flag != 0 {
            let nul = gz[i..]
                .iter()
                .position(|&b| b == 0)
                .ok_or_else(|| anyhow!("unterminated gzip header string"))?;
            i += nul + 1;
        }
    }
    if flg & 0x02 != 0 {
        // FHCRC
        need(i, 2)?;
        i += 2;
    }

    let start = out.len();
    let consumed = inflate(&gz[i..], out)?;
    let trailer = &gz[i + consumed..];
    if trailer.len() < 8 {
        bail!("truncated gzip trailer");
    }
    let want_crc = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    let want_len = u32::from_le_bytes([trailer[4], trailer[5], trailer[6], trailer[7]]);
    let got = &out[start..];
    if got.len() as u32 != want_len {
        bail!(
            "gzip ISIZE mismatch: trailer says {want_len} bytes, decompressed {}",
            got.len()
        );
    }
    let got_crc = crc32(got);
    if got_crc != want_crc {
        bail!("gzip CRC32 mismatch: expected {want_crc:#010x}, computed {got_crc:#010x}");
    }
    Ok(&trailer[8..])
}

/// Inflate a raw DEFLATE stream into `out`; returns the number of input
/// bytes consumed (the stream knows its own end via the final-block bit).
fn inflate(data: &[u8], out: &mut Vec<u8>) -> Result<usize> {
    let mut bits = Bits { b: data, pos: 0, buf: 0, cnt: 0 };
    loop {
        let bfinal = bits.need(1)?;
        let btype = bits.need(2)?;
        match btype {
            0 => stored_block(&mut bits, out)?,
            1 => {
                let (litlen, dist) = fixed_tables();
                codes(&mut bits, &litlen, &dist, out)?;
            }
            2 => {
                let (litlen, dist) = dynamic_tables(&mut bits)?;
                codes(&mut bits, &litlen, &dist, out)?;
            }
            _ => bail!("invalid deflate block type 3"),
        }
        if bfinal == 1 {
            // cnt < 8 always holds here, so the buffered bits are padding
            // within the last consumed byte: the trailer starts at pos.
            return Ok(bits.pos);
        }
    }
}

/// LSB-first bit reader (the DEFLATE bit order).
struct Bits<'a> {
    b: &'a [u8],
    pos: usize,
    buf: u32,
    cnt: u32,
}

impl Bits<'_> {
    fn need(&mut self, n: u32) -> Result<u32> {
        debug_assert!(n <= 16);
        while self.cnt < n {
            let byte = *self
                .b
                .get(self.pos)
                .ok_or_else(|| anyhow!("truncated deflate stream"))? as u32;
            self.pos += 1;
            self.buf |= byte << self.cnt;
            self.cnt += 8;
        }
        let v = self.buf & ((1u32 << n) - 1);
        self.buf >>= n;
        self.cnt -= n;
        Ok(v)
    }

    /// Discard the partial byte (stored blocks are byte-aligned).
    fn byte_align(&mut self) {
        self.buf = 0;
        self.cnt = 0;
    }
}

fn stored_block(bits: &mut Bits, out: &mut Vec<u8>) -> Result<()> {
    bits.byte_align();
    let b = bits.b;
    if bits.pos + 4 > b.len() {
        bail!("truncated stored block header");
    }
    let len = u16::from_le_bytes([b[bits.pos], b[bits.pos + 1]]) as usize;
    let nlen = u16::from_le_bytes([b[bits.pos + 2], b[bits.pos + 3]]);
    if nlen != !(len as u16) {
        bail!("stored block LEN/NLEN mismatch");
    }
    bits.pos += 4;
    if bits.pos + len > b.len() {
        bail!("truncated stored block payload");
    }
    out.extend_from_slice(&b[bits.pos..bits.pos + len]);
    bits.pos += len;
    Ok(())
}

/// Canonical Huffman decoding table: code counts per bit length plus the
/// symbols sorted by (length, symbol) — the puff/zlib representation.
struct Huffman {
    count: [u16; MAX_BITS + 1],
    symbol: Vec<u16>,
}

impl Huffman {
    fn build(lengths: &[u16]) -> Result<Huffman> {
        let mut count = [0u16; MAX_BITS + 1];
        for &l in lengths {
            count[l as usize] += 1;
        }
        // over-subscription check (incomplete codes are tolerated: any
        // unassigned code errors at decode time)
        let mut left: i32 = 1;
        for len in 1..=MAX_BITS {
            left <<= 1;
            left -= count[len] as i32;
            if left < 0 {
                bail!("over-subscribed Huffman code");
            }
        }
        let mut offs = [0usize; MAX_BITS + 1];
        for len in 1..MAX_BITS {
            offs[len + 1] = offs[len] + count[len] as usize;
        }
        let mut symbol = vec![0u16; lengths.len()];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbol[offs[l as usize]] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { count, symbol })
    }

    /// Decode one symbol bit by bit (simple and fast enough for corpora).
    fn decode(&self, bits: &mut Bits) -> Result<usize> {
        let mut code = 0usize;
        let mut first = 0usize;
        let mut index = 0usize;
        for len in 1..=MAX_BITS {
            code |= bits.need(1)? as usize;
            let count = self.count[len] as usize;
            if code < first + count {
                return Ok(self.symbol[index + (code - first)] as usize);
            }
            index += count;
            first = (first + count) << 1;
            code <<= 1;
        }
        bail!("invalid Huffman code")
    }
}

/// The fixed-Huffman tables (RFC 1951 §3.2.6).
fn fixed_tables() -> (Huffman, Huffman) {
    let mut litlen = vec![8u16; 288];
    for l in litlen.iter_mut().take(256).skip(144) {
        *l = 9;
    }
    for l in litlen.iter_mut().take(280).skip(256) {
        *l = 7;
    }
    let dist = vec![5u16; 30];
    // fixed tables cannot be over-subscribed: unwraps are safe
    (Huffman::build(&litlen).unwrap(), Huffman::build(&dist).unwrap())
}

/// Read the dynamic-Huffman table definition (RFC 1951 §3.2.7).
fn dynamic_tables(bits: &mut Bits) -> Result<(Huffman, Huffman)> {
    const ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];
    let hlit = bits.need(5)? as usize + 257;
    let hdist = bits.need(5)? as usize + 1;
    let hclen = bits.need(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        bail!("dynamic block declares too many codes (HLIT={hlit}, HDIST={hdist})");
    }
    let mut cl_lengths = [0u16; 19];
    for &o in ORDER.iter().take(hclen) {
        cl_lengths[o] = bits.need(3)? as u16;
    }
    let cl = Huffman::build(&cl_lengths)?;

    let total = hlit + hdist;
    let mut lengths = Vec::with_capacity(total);
    while lengths.len() < total {
        let sym = cl.decode(bits)?;
        let (value, repeat) = match sym {
            0..=15 => (sym as u16, 1usize),
            16 => {
                let prev = *lengths
                    .last()
                    .ok_or_else(|| anyhow!("repeat code with no previous length"))?;
                (prev, 3 + bits.need(2)? as usize)
            }
            17 => (0, 3 + bits.need(3)? as usize),
            18 => (0, 11 + bits.need(7)? as usize),
            _ => bail!("invalid code-length symbol {sym}"),
        };
        if lengths.len() + repeat > total {
            bail!("code-length repeat overflows the table");
        }
        lengths.extend(std::iter::repeat(value).take(repeat));
    }
    if lengths[256] == 0 {
        bail!("dynamic block has no end-of-block code");
    }
    Ok((Huffman::build(&lengths[..hlit])?, Huffman::build(&lengths[hlit..])?))
}

const LEN_BASE: [usize; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u32; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [usize; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u32; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];

/// Decode one compressed block's literal/length + distance code stream.
fn codes(bits: &mut Bits, litlen: &Huffman, dist: &Huffman, out: &mut Vec<u8>) -> Result<()> {
    loop {
        let sym = litlen.decode(bits)?;
        match sym {
            0..=255 => out.push(sym as u8),
            256 => return Ok(()),
            _ => {
                let s = sym - 257;
                if s >= 29 {
                    bail!("invalid length symbol {sym}");
                }
                let len = LEN_BASE[s] + bits.need(LEN_EXTRA[s])? as usize;
                let d = dist.decode(bits)?;
                if d >= 30 {
                    bail!("invalid distance symbol {d}");
                }
                let distance = DIST_BASE[d] + bits.need(DIST_EXTRA[d])? as usize;
                if distance > out.len() {
                    bail!("back-reference distance {distance} exceeds output ({})", out.len());
                }
                // byte-at-a-time copy: overlapping references (distance <
                // len) are the RLE idiom and must see freshly written bytes
                let from = out.len() - distance;
                for k in 0..len {
                    let byte = out[from + k];
                    out.push(byte);
                }
            }
        }
    }
}

/// CRC-32 (IEEE 802.3, the gzip polynomial), bitwise — corpora are small
/// enough that a table is not worth the code.
fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Wrap a raw deflate stream in a minimal gzip member by hand.
    fn gz_wrap(deflate: &[u8], plain: &[u8]) -> Vec<u8> {
        let mut v = vec![0x1f, 0x8b, 0x08, 0x00, 0, 0, 0, 0, 0x00, 0xff];
        v.extend_from_slice(deflate);
        v.extend_from_slice(&crc32(plain).to_le_bytes());
        v.extend_from_slice(&(plain.len() as u32).to_le_bytes());
        v
    }

    /// Hand-built stored (uncompressed) block.
    fn stored_deflate(plain: &[u8]) -> Vec<u8> {
        let len = plain.len() as u16;
        let mut v = vec![0x01]; // BFINAL=1, BTYPE=00
        v.extend_from_slice(&len.to_le_bytes());
        v.extend_from_slice(&(!len).to_le_bytes());
        v.extend_from_slice(plain);
        v
    }

    #[test]
    fn stored_block_roundtrip() {
        let plain = b"one json line per record\n";
        let gz = gz_wrap(&stored_deflate(plain), plain);
        assert_eq!(decompress(&gz).unwrap(), plain);
    }

    #[test]
    fn fixed_huffman_block() {
        // `zlib.compressobj(9, DEFLATED, -15)` on b"hello hello hello hello"
        // emits a single fixed-Huffman final block with back-references.
        let deflate = [0xcb, 0x48, 0xcd, 0xc9, 0xc9, 0x57, 0xc8, 0x40, 0x27, 0x01];
        let plain = b"hello hello hello hello";
        let gz = gz_wrap(&deflate, plain);
        assert_eq!(decompress(&gz).unwrap(), plain);
    }

    #[test]
    fn dynamic_huffman_member_from_real_gzip() {
        // A real `gzip`-format member (python zlib, mtime=0) over 30 varied
        // chat-JSONL lines: BTYPE=10, the encoding every encoder uses for
        // real corpora.
        let gz: &[u8] = &DYNAMIC_VECTOR;
        let out = decompress(gz).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text.lines().count(), 30);
        assert!(text.starts_with(r#"{"messages":[{"role":"user","#), "{text}");
        assert!(text.contains(r#""content":"question 29 about packing and kernels""#));
    }

    #[test]
    fn concatenated_members() {
        let a = b"first member\n";
        let b = b"second member\n";
        let mut gz = gz_wrap(&stored_deflate(a), a);
        gz.extend_from_slice(&gz_wrap(&stored_deflate(b), b));
        assert_eq!(decompress(&gz).unwrap(), b"first member\nsecond member\n");
    }

    #[test]
    fn corruption_is_a_hard_error() {
        let plain = b"payload";
        let good = gz_wrap(&stored_deflate(plain), plain);

        // flipped payload byte -> CRC mismatch
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 10] ^= 0x01;
        assert!(decompress(&bad).unwrap_err().to_string().contains("CRC32"));

        // truncated trailer
        assert!(decompress(&good[..good.len() - 3])
            .unwrap_err()
            .to_string()
            .contains("trailer"));

        // not gzip at all
        assert!(decompress(b"{\"messages\": []}")
            .unwrap_err()
            .to_string()
            .contains("magic"));

        // empty input
        assert!(decompress(b"").is_err());
    }

    #[test]
    fn isize_mismatch_is_detected() {
        let plain = b"payload";
        let mut gz = gz_wrap(&stored_deflate(plain), plain);
        let n = gz.len();
        gz[n - 4] ^= 0x01; // corrupt ISIZE
        assert!(decompress(&gz).unwrap_err().to_string().contains("ISIZE"));
    }

    const DYNAMIC_VECTOR: [u8; 282] = [
        0x1f, 0x8b, 0x08, 0x00, 0x00, 0x00, 0x00, 0x00, 0x02, 0xff, 0xcd, 0xd7,
        0x3d, 0x6a, 0xc4, 0x30, 0x10, 0x86, 0xe1, 0x3e, 0xa7, 0x10, 0xaa, 0x5d,
        0xec, 0x8c, 0xff, 0xf7, 0x2a, 0x21, 0x85, 0x76, 0x33, 0x6c, 0xc4, 0x3a,
        0x52, 0xa2, 0x91, 0x71, 0x61, 0xf6, 0xee, 0x71, 0x9a, 0x90, 0x36, 0xf0,
        0x05, 0xa6, 0x32, 0x18, 0x3c, 0x3c, 0x18, 0x6b, 0x5e, 0xbc, 0xfb, 0x77,
        0x51, 0x0d, 0x37, 0x51, 0x7f, 0x7e, 0xde, 0x7d, 0xc9, 0x8b, 0xf8, 0xb3,
        0x5f, 0x55, 0x8a, 0x6f, 0xfc, 0x35, 0xa7, 0x2a, 0xa9, 0x1e, 0x37, 0x3e,
        0x57, 0xd1, 0x1a, 0x73, 0x72, 0x27, 0x17, 0x2e, 0x79, 0xad, 0xee, 0x23,
        0x5c, 0xef, 0x31, 0xdd, 0x5c, 0x48, 0xaf, 0xee, 0x2e, 0x25, 0xc9, 0xa2,
        0xfe, 0xd1, 0xfc, 0x0c, 0x08, 0xaa, 0x51, 0x6b, 0x38, 0x9e, 0xfd, 0x3d,
        0x25, 0x24, 0xdd, 0xa4, 0x1c, 0x33, 0xb6, 0x58, 0xdf, 0xdc, 0x25, 0x26,
        0x75, 0x8d, 0x2b, 0x79, 0xfb, 0xbe, 0x2c, 0x59, 0x8f, 0x09, 0x2f, 0x8f,
        0xa7, 0xfd, 0x8f, 0x22, 0x02, 0x88, 0x46, 0xa8, 0x88, 0x01, 0x22, 0x82,
        0x8a, 0x5a, 0x80, 0x68, 0x82, 0x8a, 0x3a, 0x80, 0x88, 0xa1, 0xa2, 0x1e,
        0x20, 0x9a, 0xa1, 0xa2, 0x01, 0x20, 0x6a, 0xa1, 0xa2, 0x11, 0xf1, 0x65,
        0x63, 0x8f, 0xff, 0x04, 0x20, 0x75, 0x50, 0xd1, 0x8c, 0x78, 0x49, 0xd8,
        0xf3, 0x4f, 0x88, 0xb5, 0xdd, 0x63, 0x49, 0x88, 0xbd, 0x4d, 0xd8, 0x15,
        0x40, 0x88, 0xcd, 0x3d, 0x60, 0x49, 0xad, 0xbd, 0xe0, 0x76, 0xe6, 0x8a,
        0x4b, 0xbd, 0xb9, 0xe4, 0xd2, 0x60, 0xae, 0xb9, 0x34, 0x9a, 0x8b, 0x2e,
        0x4d, 0xe6, 0xaa, 0x4b, 0xb3, 0xb9, 0xec, 0xf2, 0xc9, 0x5e, 0x77, 0x99,
        0xcc, 0x85, 0x97, 0xd9, 0x5e, 0x79, 0xb9, 0x35, 0x57, 0x5e, 0xee, 0xec,
        0x95, 0x97, 0x7b, 0x73, 0xe5, 0xe5, 0xc1, 0x5c, 0x79, 0x79, 0xb4, 0xf7,
        0xaf, 0x3b, 0x99, 0x2b, 0x2f, 0xcf, 0xff, 0x5e, 0xde, 0x2f, 0x97, 0x91,
        0x1e, 0x1d, 0x36, 0x11, 0x00, 0x00,
    ];
}
