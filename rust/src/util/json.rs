//! Minimal JSON parser/serializer for the AOT `manifest.json` and metric
//! dumps. Supports the full JSON grammar except exotic number forms; objects
//! preserve insertion order (the manifest relies on input ordering).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys keep file order in `entries`; `index` provides
/// O(log n) lookup.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Obj),
}

#[derive(Debug, Clone, PartialEq, Default)]
pub struct Obj {
    pub entries: Vec<(String, Json)>,
}

impl Obj {
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
    pub fn insert(&mut self, key: impl Into<String>, val: Json) {
        self.entries.push((key.into(), val));
    }
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&Obj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj.get(key)` with a descriptive error.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !o.entries.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let cp = match cp {
                                // UTF-16 high surrogate: must pair with a
                                // following \uDC00..\uDFFF low surrogate
                                0xD800..=0xDBFF => {
                                    if self.b.get(self.i) != Some(&b'\\')
                                        || self.b.get(self.i + 1) != Some(&b'u')
                                    {
                                        bail!(
                                            "lone high surrogate \\u{cp:04X} at offset {} \
                                             (expected a \\uDC00..\\uDFFF low surrogate)",
                                            self.i
                                        );
                                    }
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..=0xDFFF).contains(&lo) {
                                        bail!(
                                            "\\u{cp:04X} followed by \\u{lo:04X}, \
                                             which is not a low surrogate"
                                        );
                                    }
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                }
                                0xDC00..=0xDFFF => {
                                    bail!("lone low surrogate \\u{cp:04X} at offset {}", self.i)
                                }
                                cp => cp,
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("invalid code point U+{cp:04X}"))?,
                            );
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                }
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let s = std::str::from_utf8(&self.b[start..start + len])?;
                        out.push_str(s);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    /// Four hex digits of a `\u` escape, consumed.
    fn hex4(&mut self) -> Result<u32> {
        let hex = std::str::from_utf8(
            self.b
                .get(self.i..self.i + 4)
                .ok_or_else(|| anyhow!("bad \\u escape"))?,
        )?;
        let cp = u32::from_str_radix(hex, 16)?;
        self.i += 4;
        Ok(cp)
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut obj = Obj::default();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            obj.entries.push((key, val));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(obj));
                }
                c => bail!("expected ',' or '}}' got '{}' at {}", c as char, self.i),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

// keep BTreeMap import used for potential future sorted access
#[allow(unused)]
type _Unused = BTreeMap<String, ()>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        let a = j.field("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(1));
        assert_eq!(a[2].field("b").unwrap().as_str(), Some("c"));
        assert_eq!(j.field("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn preserves_object_order() {
        let j = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().entries.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"name":"x","shape":[2,64],"ok":true,"nested":{"v":1.5}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_pretty();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse(r#""café — ünïcödé""#).unwrap();
        assert_eq!(j.as_str(), Some("café — ünïcödé"));
    }

    #[test]
    fn surrogate_pairs_combine() {
        // U+1F600 is \ud83d\ude00 in UTF-16; the pair must decode to
        // one char, not two U+FFFD replacement chars
        let j = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(j.as_str(), Some("\u{1f600}"));
        let j = Json::parse(r#""a \ud83d\ude00 b""#).unwrap();
        assert_eq!(j.as_str(), Some("a \u{1f600} b"));
        // BMP escapes still work, including ones adjacent to a pair
        let j = Json::parse(r#""\u00e9\ud83d\ude00\u0041""#).unwrap();
        assert_eq!(j.as_str(), Some("\u{e9}\u{1f600}A"));
    }

    #[test]
    fn lone_surrogates_are_errors() {
        for src in [
            r#""\ud83d""#,         // lone high at end of string
            r#""\ud83d x""#,       // high followed by a plain char
            r#""\ud83d\u0041""#,   // high followed by a non-surrogate escape
            r#""\ude00""#,         // lone low
            r#""\ude00\ud83d""#,   // reversed pair
        ] {
            let err = Json::parse(src).unwrap_err();
            assert!(err.to_string().contains("surrogate"), "{src}: {err}");
        }
    }

    #[test]
    fn surrogate_pair_roundtrips_through_serializer() {
        let j = Json::parse(r#"{"t":"smile 😀"}"#).unwrap();
        assert_eq!(j.field("t").unwrap().as_str(), Some("smile 😀"));
        // the serializer writes the char raw (valid UTF-8 JSON) and it
        // parses back identically
        let out = j.to_string_pretty();
        assert!(out.contains('😀'), "{out}");
        assert_eq!(Json::parse(&out).unwrap(), j);
    }
}
