//! TOML-subset parser for run configs: `[section]` headers, `key = value`
//! with strings, integers, floats, booleans and flat arrays. Comments with
//! `#`. That covers every config this repo ships (`configs/*.toml`).

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: `get("section.key")` or `get("key")` for root keys.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    pub entries: Vec<(String, TomlValue)>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow!("line {}: {}", lineno + 1, e))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.entries.push((full, val));
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }
    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\n", "\n")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value: {s:?}")
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
# run config
name = "demo"
[train]
steps = 100
lr = 3.0e-4
packed = true
batch_dims = [8, 256]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "demo");
        assert_eq!(doc.i64_or("train.steps", 0), 100);
        assert!((doc.f64_or("train.lr", 0.0) - 3.0e-4).abs() < 1e-12);
        assert!(doc.bool_or("train.packed", false));
        let arr = doc.get("train.batch_dims").unwrap();
        match arr {
            TomlValue::Arr(items) => {
                assert_eq!(items[0].as_i64(), Some(8));
                assert_eq!(items[1].as_i64(), Some(256));
            }
            _ => panic!("not an array"),
        }
    }

    #[test]
    fn comments_and_underscores() {
        let doc = TomlDoc::parse("x = 1_000 # one thousand\ns = \"a # b\"").unwrap();
        assert_eq!(doc.i64_or("x", 0), 1000);
        assert_eq!(doc.str_or("s", ""), "a # b");
    }

    #[test]
    fn errors_on_garbage() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("x = @@").is_err());
    }

    #[test]
    fn defaults() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.i64_or("missing", 7), 7);
        assert_eq!(doc.str_or("missing", "d"), "d");
    }
}
