//! Deterministic PRNG (xoshiro256** seeded by SplitMix64) with the handful
//! of distributions the data pipeline needs. Hand-rolled: the offline crate
//! set has no `rand`.

/// xoshiro256** — fast, high-quality, reproducible across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed (the reference seeding procedure).
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range(3, 17);
            assert!((3..17).contains(&x));
        }
    }

    #[test]
    fn normal_moments_reasonable() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
