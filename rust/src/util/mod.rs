//! Self-contained substrates: JSON, a TOML subset, gzip inflation, and a
//! deterministic PRNG.
//!
//! The build environment is fully offline with a minimal crate set, so the
//! serde/toml/rand/flate stack is hand-rolled here (and unit-tested)
//! instead of pulled from crates.io.

pub mod gzip;
pub mod json;
pub mod rng;
pub mod toml;

/// Format a token count like `41,184`.
pub fn commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn commas_formats() {
        assert_eq!(super::commas(0), "0");
        assert_eq!(super::commas(999), "999");
        assert_eq!(super::commas(41184), "41,184");
        assert_eq!(super::commas(1234567), "1,234,567");
    }
}
