//! Data-parallel execution over any shardable [`Backend`] (DESIGN.md §10).
//!
//! [`DataParallel`] wraps N replica backends and splits every staged batch
//! into **per-row micro-shards**: a `[B, S]` batch always decomposes into
//! exactly B single-row gradient tasks, no matter how many workers run
//! them. The worker count only changes which replica executes which rows
//! (balanced contiguous assignment, remainder rows to the first `B % N`
//! replicas) — never the shape of the computation. Each row's flat
//! trainable gradient lands in its own lane of a shared gradient arena,
//! and the lanes are combined by a fixed-order in-place binary reduction
//! tree. Because the decomposition and the reduction order are functions
//! of B alone, the reduced gradient — and therefore the loss, grad-norm
//! and eval series of a whole run — is **bitwise invariant to the worker
//! count**: the thread-ladder determinism contract of DESIGN.md §4.3,
//! one level up.
//!
//! Gradient correctness: each shard's backward seeds its dlogits with the
//! *global* supervised-target count of the whole batch (not the row-local
//! count), so `Σ_rows ∂(loss_sum_row / N_global) = ∂(mean loss)` exactly —
//! the tree-reduced gradient equals the full-batch gradient, and the
//! optimizer + LR schedule are applied exactly once on it
//! ([`Backend::apply_grads`] on replica 0).
//!
//! Replicas are in-process today, each owning its own execution substrate
//! (a fast-CPU replica brings its own worker pool + scratch arena). The
//! seam — a replica sees `(staged batch, row range, global norm)` and
//! fills flat gradient lanes — is what a future mmap-backed worker
//! *process* would implement; nothing above this module would change.
//!
//! The wrapper implements [`Backend`] itself and delegates everything
//! except `train_step` to replica 0, so the Trainer/Session plumbing is
//! unchanged and `--workers 1` still exercises the full
//! shard→reduce→step path.

use super::{Backend, DeviceBatch, DeviceState, StepOutputs, StepPhases};
use crate::batching::{shard_rows, Batch};
use crate::manifest::Manifest;
use crate::runtime::HostTensor;
use anyhow::{ensure, Result};
use std::cell::RefCell;
use std::sync::Arc;
use std::time::Instant;

/// The shared gradient arena: one flat f32 lane per batch row plus the
/// per-row loss sums, allocated once and reused (zero per-step heap
/// allocations in the reduction path — pinned by
/// `rust/tests/no_materialization.rs`).
#[derive(Default)]
struct GradArena {
    lanes: Vec<f32>,
    loss_sums: Vec<f32>,
    lane_len: usize,
    rows: usize,
    heap_allocs: u64,
}

impl GradArena {
    /// Size the arena for `(rows, lane_len)` and zero it. Reallocation
    /// only happens when the geometry changes (counted in `heap_allocs`).
    fn prepare(&mut self, rows: usize, lane_len: usize) {
        if self.rows != rows || self.lane_len != lane_len {
            self.lanes = vec![0.0; rows * lane_len];
            self.loss_sums = vec![0.0; rows];
            self.rows = rows;
            self.lane_len = lane_len;
            self.heap_allocs += 1;
        } else {
            self.lanes.fill(0.0);
            self.loss_sums.fill(0.0);
        }
    }

    fn lane_mut(&mut self, row: usize) -> &mut [f32] {
        let lo = row * self.lane_len;
        &mut self.lanes[lo..lo + self.lane_len]
    }

    /// Fixed-order in-place binary reduction tree over the row lanes (and,
    /// with identical structure, the per-row loss sums): stride-doubling
    /// pairwise adds, `lane[i] += lane[i + stride]`. The tree is a pure
    /// function of the row count — worker assignment never appears — so
    /// the reduced bits are worker-count invariant by construction. Also
    /// handles non-power-of-two row counts (odd nodes pass through).
    fn tree_reduce(&mut self) {
        let ll = self.lane_len;
        let mut stride = 1;
        while stride < self.rows {
            let mut i = 0;
            while i + stride < self.rows {
                let (head, tail) = self.lanes.split_at_mut((i + stride) * ll);
                let dst = &mut head[i * ll..i * ll + ll];
                let src = &tail[..ll];
                for k in 0..ll {
                    dst[k] += src[k];
                }
                self.loss_sums[i] += self.loss_sums[i + stride];
                i += 2 * stride;
            }
            stride *= 2;
        }
    }

    /// The reduced gradient (lane 0 after [`Self::tree_reduce`]).
    fn reduced(&self) -> &[f32] {
        &self.lanes[..self.lane_len]
    }
}

/// Data-parallel wrapper over N replica backends. See the module docs for
/// the shard→reduce→step contract.
pub struct DataParallel {
    replicas: Vec<Arc<dyn Backend>>,
    arena: RefCell<GradArena>,
}

impl DataParallel {
    /// Wrap an explicit replica set (replica 0 is the primary: it serves
    /// the manifest, state init/IO, eval and the optimizer apply). All
    /// replicas must be interchangeable — same backend kind, same
    /// manifest geometry; the Session layer constructs them that way.
    pub fn from_replicas(replicas: Vec<Arc<dyn Backend>>) -> Result<DataParallel> {
        ensure!(!replicas.is_empty(), "data-parallel requires at least one replica");
        Ok(DataParallel { replicas, arena: RefCell::new(GradArena::default()) })
    }

    /// The worker (replica) count.
    pub fn workers(&self) -> usize {
        self.replicas.len()
    }

    /// Heap allocations performed by the shared gradient arena so far
    /// (exactly 1 after any number of same-geometry steps — the
    /// no-materialization contract for the reduction path).
    pub fn grad_arena_heap_allocs(&self) -> u64 {
        self.arena.borrow().heap_allocs
    }

    /// Currently allocated gradient-arena elements (`rows × lane_len`).
    pub fn grad_arena_elems(&self) -> usize {
        let a = self.arena.borrow();
        a.rows * a.lane_len
    }

    fn primary(&self) -> &Arc<dyn Backend> {
        &self.replicas[0]
    }
}

impl Backend for DataParallel {
    fn name(&self) -> &'static str {
        "data-parallel"
    }

    fn manifest(&self) -> &Manifest {
        self.primary().manifest()
    }

    fn init_state(&self, init_name: &str, seed: i32) -> Result<DeviceState> {
        self.primary().init_state(init_name, seed)
    }

    fn upload_batch(&self, train_name: &str, batch: &Batch) -> Result<DeviceBatch> {
        self.primary().upload_batch(train_name, batch)
    }

    fn train_step(
        &self,
        train_name: &str,
        state: &mut DeviceState,
        batch: &DeviceBatch,
        step: u64,
        lr: f32,
        lr_b: f32,
    ) -> Result<StepOutputs> {
        let (broken, rows) = {
            let spec = self.primary().manifest().get(train_name)?;
            (spec.step_config.broken, spec.batch)
        };
        let b = match batch {
            DeviceBatch::Cpu(b) => b,
            #[cfg(feature = "pjrt")]
            _ => anyhow::bail!("batch was uploaded to a different backend"),
        };
        ensure!(b.batch == rows, "staged batch has {} rows, executable expects {rows}", b.batch);
        let n_valid = b.targets.as_i32()?.iter().filter(|&&t| t >= 0).count();

        if broken {
            // the broken §8 config discards every gradient: no backward, no
            // reduce, and crucially no optimizer apply (AdamW with a zero
            // gradient would still decay weights) — matching the reference
            // step's "parameters never move" semantics exactly.
            let t_fwd = Instant::now();
            let loss = self.primary().eval_loss(train_name, state, b)?;
            let phases =
                StepPhases { fwd_s: t_fwd.elapsed().as_secs_f64(), ..StepPhases::default() };
            return Ok(StepOutputs { loss, grad_norm: 0.0, n_tokens: n_valid as f32, phases });
        }

        let lane_len = self.primary().flat_grad_len(state)?;
        let global = n_valid.max(1);
        let assignment = shard_rows(b.batch, self.workers());

        let mut arena = self.arena.borrow_mut();
        arena.prepare(b.batch, lane_len);

        // shard: every row's gradient is computed against the same frozen
        // `state` (replicas run their row ranges; in-process they run in
        // turn, each on its own pool/arena substrate)
        let (mut fwd_s, mut bwd_s) = (0.0f64, 0.0f64);
        for (replica, row_range) in self.replicas.iter().zip(&assignment) {
            for row in row_range.clone() {
                let lane = arena.lane_mut(row);
                let rg = replica.grad_row(train_name, state, batch, row, global, lane)?;
                arena.loss_sums[row] = rg.loss_sum;
                fwd_s += rg.fwd_s;
                bwd_s += rg.bwd_s;
            }
        }

        // reduce: fixed-order tree, charged to the backward phase
        let t_reduce = Instant::now();
        arena.tree_reduce();
        bwd_s += t_reduce.elapsed().as_secs_f64();

        // step once: grad-norm in fixed (flat) order, then one optimizer
        // apply on the reduced gradient
        let t_optim = Instant::now();
        let reduced = arena.reduced();
        let mut sq = 0.0f32;
        for &x in reduced {
            sq += x * x;
        }
        let grad_norm = sq.sqrt();
        self.primary().apply_grads(train_name, state, reduced, step, lr, lr_b)?;
        let optim_s = t_optim.elapsed().as_secs_f64();

        let loss = arena.loss_sums[0] / global as f32;
        let phases = StepPhases { fwd_s, bwd_s, optim_s };
        Ok(StepOutputs { loss, grad_norm, n_tokens: n_valid as f32, phases })
    }

    fn eval_loss(&self, eval_name: &str, state: &DeviceState, batch: &Batch) -> Result<f32> {
        self.primary().eval_loss(eval_name, state, batch)
    }

    fn state_params(&self, state: &DeviceState) -> Result<Vec<HostTensor>> {
        self.primary().state_params(state)
    }

    fn load_params(&self, state: &mut DeviceState, params: &[HostTensor]) -> Result<()> {
        self.primary().load_params(state, params)
    }

    fn configure_memory(&self, state: &mut DeviceState, cfg: &super::MemoryCfg) -> Result<()> {
        // the state lives on replica 0; shard replicas only read params
        // through it, so one configuration covers the whole group
        self.primary().configure_memory(state, cfg)
    }

    fn optim_snapshot(&self, state: &DeviceState) -> Result<crate::quant::OptimSnapshot> {
        self.primary().optim_snapshot(state)
    }

    fn load_optim_snapshot(
        &self,
        state: &mut DeviceState,
        snap: &crate::quant::OptimSnapshot,
    ) -> Result<()> {
        self.primary().load_optim_snapshot(state, snap)
    }

    fn bench_kernel(&self, name: &str, reps: usize, warmup: usize) -> Result<f64> {
        self.primary().bench_kernel(name, reps, warmup)
    }

    fn flat_grad_len(&self, state: &DeviceState) -> Result<usize> {
        self.primary().flat_grad_len(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;

    fn dp(workers: usize) -> DataParallel {
        let replicas: Vec<Arc<dyn Backend>> =
            (0..workers).map(|_| Arc::new(CpuBackend::new()) as Arc<dyn Backend>).collect();
        DataParallel::from_replicas(replicas).unwrap()
    }

    #[test]
    fn zero_replicas_rejected() {
        assert!(DataParallel::from_replicas(Vec::new()).is_err());
    }

    #[test]
    fn delegates_manifest_and_state_to_primary() {
        let dp = dp(2);
        assert_eq!(dp.workers(), 2);
        assert!(dp.manifest().get("train_step_chronicals").is_ok());
        let state = dp.init_state("init_chronicals", 3).unwrap();
        let reference = CpuBackend::new().init_state("init_chronicals", 3).unwrap();
        let a = dp.state_params(&state).unwrap();
        let b = CpuBackend::new().state_params(&reference).unwrap();
        assert_eq!(a, b, "data-parallel init must be the primary's init");
    }

    #[test]
    fn tree_reduce_is_exact_on_integers_and_handles_odd_rows() {
        // integer-valued f32 adds are exact, so the tree must produce the
        // plain sum for any row count, including non-powers of two
        for rows in 1..=9usize {
            let mut a = GradArena::default();
            a.prepare(rows, 3);
            for r in 0..rows {
                let lane = a.lane_mut(r);
                for (k, x) in lane.iter_mut().enumerate() {
                    *x = (r * 10 + k) as f32;
                }
                a.loss_sums[r] = r as f32;
            }
            a.tree_reduce();
            for k in 0..3 {
                let expect: f32 = (0..rows).map(|r| (r * 10 + k) as f32).sum();
                assert_eq!(a.reduced()[k], expect, "rows={rows} k={k}");
            }
            let expect: f32 = (0..rows).map(|r| r as f32).sum();
            assert_eq!(a.loss_sums[0], expect, "rows={rows} loss");
        }
    }

    #[test]
    fn grad_arena_reallocates_only_on_geometry_change() {
        let mut a = GradArena::default();
        a.prepare(4, 8);
        assert_eq!(a.heap_allocs, 1);
        a.lane_mut(2)[5] = 3.0;
        a.prepare(4, 8);
        assert_eq!(a.heap_allocs, 1, "same geometry must reuse the buffer");
        assert_eq!(a.lane_mut(2)[5], 0.0, "prepare must zero the lanes");
        a.prepare(2, 8);
        assert_eq!(a.heap_allocs, 2, "geometry change reallocates");
    }
}
