//! Pluggable execution backends (DESIGN.md §3).
//!
//! A [`Backend`] owns everything device-specific about training: how state
//! is initialized and held, how batches reach the device, and how one train
//! step executes. The coordinator ([`crate::coordinator::Trainer`]), the
//! harness and the CLI drive this trait only — they never name a concrete
//! runtime — so the same step loop, metering, verification and
//! checkpointing serve every backend.
//!
//! Three implementations exist:
//! * [`cpu::CpuBackend`] (always available, the default): a deterministic
//!   pure-Rust reference of the tiny-transformer train step. No artifacts,
//!   no native deps — this is what CI and `cargo test` exercise. It is the
//!   bitwise-deterministic correctness oracle.
//! * [`cpu_fast::FastCpuBackend`] (always available, `--backend cpu-fast`):
//!   the same contract executed through cache-blocked multithreaded
//!   matmuls, online-softmax flash attention and streaming Cut
//!   Cross-Entropy — validated against the reference by the parity suite.
//! * `pjrt::PjrtBackend` (behind the `pjrt` feature): executes the AOT HLO
//!   artifacts from `python/compile/aot.py` through PJRT.
//!
//! ## State layout contract
//!
//! `DeviceState` holds parameters in manifest order — trainable tensors
//! first, then frozen — plus optimizer slots. `state_params` /
//! `load_params` exchange exactly the `trainable + frozen` prefix as host
//! tensors in that order; this is the checkpoint interchange format shared
//! by all backends.
//!
//! ## Step contract
//!
//! `train_step` consumes `(state, uploaded batch, 1-based step, lr, lr_b)`
//! and returns the three scalar metrics `(loss, grad_norm, n_tokens)`:
//! mean loss over supervised targets, the global L2 norm of the trainable
//! gradients (0.0 ⇔ not training — the §8 verification signal) and the
//! supervised-target count. State advances in place; nothing else escapes
//! the device.

pub mod cpu;
pub mod cpu_fast;
pub mod data_parallel;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use data_parallel::DataParallel;

use crate::batching::Batch;
use crate::manifest::Manifest;
use crate::quant::{BaseQuant, OptimSnapshot, OptimStates};
use crate::runtime::HostTensor;
use anyhow::{bail, Result};
use std::sync::Arc;

/// The three memory tiers a session can request (DESIGN.md §12), resolved
/// by the session layer and pushed onto a fresh state via
/// [`Backend::configure_memory`] before the first step.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemoryCfg {
    /// Tier 1: AdamW m/v slot codec (`--optim-states fp32|int8`).
    pub optim_states: OptimStates,
    /// Tier 2: frozen-base weight codec for LoRA-family tasks
    /// (`--base-quant none|int8|fp8`).
    pub base_quant: Option<BaseQuant>,
    /// Tier 3: activation-checkpoint segment count (`--ckpt-segments N`,
    /// 0 = off).
    pub ckpt_segments: usize,
}

impl MemoryCfg {
    /// True when every tier is at its legacy default (dense fp32, no
    /// checkpointing) — the only configuration backends without the seam
    /// accept.
    pub fn is_default(&self) -> bool {
        *self == MemoryCfg::default()
    }
}

/// Backend registry: construct a backend by CLI/config name.
///
/// `threads` is the worker-thread request for the fast backend (0 =
/// resolve via `CHRONICALS_THREADS`, then `available_parallelism`);
/// `artifacts_dir` is only read by the PJRT backend. Shared by the CLI,
/// the benches and the tests so every entrypoint accepts the same names.
pub fn create_backend(name: &str, artifacts_dir: &str, threads: usize) -> Result<Arc<dyn Backend>> {
    match name {
        "cpu" => Ok(Arc::new(cpu::CpuBackend::new())),
        "cpu-fast" | "cpu_fast" => Ok(Arc::new(cpu_fast::FastCpuBackend::with_threads(threads))),
        "pjrt" => {
            #[cfg(feature = "pjrt")]
            {
                let _ = threads;
                Ok(Arc::new(pjrt::PjrtBackend::new(artifacts_dir)?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = (artifacts_dir, threads);
                bail!(
                    "this binary was built without PJRT support; rebuild with \
                     `cargo build --features pjrt` and vendored xla-rs (DESIGN.md §4.2)"
                )
            }
        }
        other => bail!("unknown backend '{other}' (expected cpu | cpu-fast | pjrt)"),
    }
}

/// Per-phase wall-clock breakdown of one train step, in seconds. The
/// backend fills the compute phases; the coordinator derives the data
/// phase as the residual of the measured step wall time (everything that
/// is not forward/backward/optimizer: batch cycling, metering, dispatch).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepPhases {
    /// Forward-pass seconds (loss computation).
    pub fwd_s: f64,
    /// Backward-pass seconds (gradient computation + reduction).
    pub bwd_s: f64,
    /// Optimizer seconds (grad-norm + AdamW update).
    pub optim_s: f64,
}

impl StepPhases {
    /// Total seconds attributed to compute phases.
    pub fn compute_s(&self) -> f64 {
        self.fwd_s + self.bwd_s + self.optim_s
    }
}

/// The three scalar metrics every train step reports, plus the per-phase
/// timing breakdown (zeroed on backends that predate it).
#[derive(Debug, Clone, Copy)]
pub struct StepOutputs {
    pub loss: f32,
    pub grad_norm: f32,
    pub n_tokens: f32,
    /// Per-phase step-time breakdown (fwd/bwd/optim seconds).
    pub phases: StepPhases,
}

/// One tenant's row-slice of an intra-step fused round (DESIGN.md §11):
/// which rows of the concatenated batch belong to the tenant, plus the
/// tenant's own optimizer coordinates for this step. Slices are contiguous,
/// ordered, and cover the concat batch exactly.
#[derive(Debug, Clone, Copy)]
pub struct FusedSlice {
    /// First batch row (not token) of the tenant's slice.
    pub row_start: usize,
    /// Number of batch rows in the slice (tenants may be ragged).
    pub rows: usize,
    /// The tenant's 1-based optimizer step for this update.
    pub step: u64,
    /// The tenant's learning rate at `step`.
    pub lr: f32,
    /// The tenant's LoRA+ B-matrix learning rate (== `lr` without LoRA+).
    pub lr_b: f32,
}

/// Result of one intra-step fused round: per-tenant step metrics in slice
/// order, plus the round's shared per-phase wall-clock breakdown (one base
/// forward/backward serves every tenant, so phase time is per-round, not
/// per-tenant — the per-tenant `phases` fields are zeroed).
#[derive(Debug, Clone)]
pub struct FusedOutputs {
    /// Per-tenant metrics, in the same order as the input slices.
    pub tenants: Vec<StepOutputs>,
    /// Wall-clock phase breakdown of the whole fused round.
    pub phases: StepPhases,
}

/// One shard-row gradient result from [`Backend::grad_row`].
#[derive(Debug, Clone, Copy)]
pub struct RowGrad {
    /// Summed (not mean) loss over the row's supervised targets.
    pub loss_sum: f32,
    /// Forward-pass seconds for this row.
    pub fwd_s: f64,
    /// Backward-pass seconds for this row.
    pub bwd_s: f64,
}

/// Backend-resident training state (params + optimizer slots).
pub enum DeviceState {
    Cpu(cpu::CpuState),
    #[cfg(feature = "pjrt")]
    Pjrt(crate::runtime::TrainState),
}

/// Per-tenant adapter state for the serve subsystem (DESIGN.md §11): the
/// trainable LoRA tensors plus their optimizer slots, detached from the
/// shared read-only base weights held in a workspace [`DeviceState`].
pub enum AdapterState {
    Cpu(cpu::model::CpuAdapter),
}

/// A batch staged for a backend (uploaded once, reusable across steps).
pub enum DeviceBatch {
    Cpu(Batch),
    #[cfg(feature = "pjrt")]
    Pjrt(crate::runtime::UploadedBatch),
}

impl DeviceBatch {
    /// Non-padding token count (the honest throughput numerator).
    pub fn real_tokens(&self) -> usize {
        match self {
            DeviceBatch::Cpu(b) => b.real_tokens,
            #[cfg(feature = "pjrt")]
            DeviceBatch::Pjrt(u) => u.real_tokens,
        }
    }

    /// Total `B·S` slots (what a padding-blind bench would count).
    pub fn slot_tokens(&self) -> usize {
        match self {
            DeviceBatch::Cpu(b) => b.batch * b.seq,
            #[cfg(feature = "pjrt")]
            DeviceBatch::Pjrt(u) => u.slot_tokens,
        }
    }
}

/// A training execution backend. See the module docs for the state and
/// step contracts; all methods take `&self` so a backend can be shared
/// behind `Arc<dyn Backend>`.
pub trait Backend {
    /// Short human name ("cpu", "pjrt") for logs and error messages.
    fn name(&self) -> &'static str;

    /// The executable manifest this backend serves (synthesized for the CPU
    /// reference, loaded from `artifacts/manifest.json` for PJRT).
    fn manifest(&self) -> &Manifest;

    /// Build fresh training state by running the named init executable.
    fn init_state(&self, init_name: &str, seed: i32) -> Result<DeviceState>;

    /// Stage a batch for repeated execution against `train_name`.
    fn upload_batch(&self, train_name: &str, batch: &Batch) -> Result<DeviceBatch>;

    /// Run one train step; `step` is 1-based, `lr_b` is the LoRA+ B-matrix
    /// learning rate (equal to `lr` when LoRA+ is off).
    fn train_step(
        &self,
        train_name: &str,
        state: &mut DeviceState,
        batch: &DeviceBatch,
        step: u64,
        lr: f32,
        lr_b: f32,
    ) -> Result<StepOutputs>;

    /// Forward-only mean loss with the named eval executable.
    fn eval_loss(&self, eval_name: &str, state: &DeviceState, batch: &Batch) -> Result<f32>;

    /// Read the `trainable + frozen` parameters to host, in state order
    /// (the checkpoint interchange format).
    fn state_params(&self, state: &DeviceState) -> Result<Vec<HostTensor>>;

    /// Restore parameters from host tensors (state order, shapes must
    /// match). Optimizer slots are left untouched.
    fn load_params(&self, state: &mut DeviceState, params: &[HostTensor]) -> Result<()>;

    // ---- memory-tier seams (DESIGN.md §12) ---------------------------

    /// Apply a [`MemoryCfg`] to a freshly initialized state: switch the
    /// optimizer-state codec, quantize the frozen base weights, and set
    /// the activation-checkpoint segment count. Must be called before the
    /// first train step; the default implementation accepts only the
    /// all-default config.
    fn configure_memory(&self, state: &mut DeviceState, cfg: &MemoryCfg) -> Result<()> {
        let _ = state;
        if cfg.is_default() {
            return Ok(());
        }
        bail!(
            "the {} backend does not support memory-tier configuration \
             (--optim-states / --base-quant / --ckpt-segments)",
            self.name()
        )
    }

    /// Export the optimizer slots in their native codec for checkpointing
    /// (int8 slots serialize bitwise — bytes, scales, compensations).
    fn optim_snapshot(&self, state: &DeviceState) -> Result<OptimSnapshot> {
        let _ = state;
        bail!("the {} backend does not expose optimizer-state snapshots", self.name())
    }

    /// Restore optimizer slots from a checkpoint snapshot. The snapshot's
    /// codec must match the state's configured codec; fp32↔int8 migration
    /// of live moments is rejected, not silently rounded.
    fn load_optim_snapshot(&self, state: &mut DeviceState, snap: &OptimSnapshot) -> Result<()> {
        let _ = (state, snap);
        bail!("the {} backend does not expose optimizer-state snapshots", self.name())
    }

    /// Convert a freshly initialized tenant adapter's optimizer slots to
    /// `codec` (serve honors the server-wide `--optim-states` here, right
    /// after `init_adapter`). Only legal while the moments are still zero.
    fn convert_adapter_optim(&self, adapter: &mut AdapterState, codec: OptimStates) -> Result<()> {
        let _ = (adapter, codec);
        bail!("the {} backend does not support per-tenant adapters", self.name())
    }

    /// Time one kernel microbench executable (Table 5). Only meaningful on
    /// backends with compiled kernel artifacts.
    fn bench_kernel(&self, name: &str, reps: usize, warmup: usize) -> Result<f64> {
        let _ = (reps, warmup);
        bail!(
            "kernel microbench '{name}' is not supported on the {} backend",
            self.name()
        )
    }

    // ---- multi-tenant serve seams (DESIGN.md §11) --------------------
    //
    // `chronicals serve` splits training state into one shared read-only
    // base (the frozen suffix of a workspace `DeviceState`, loaded once)
    // and many per-tenant `AdapterState`s (LoRA A/B + AdamW slots). A
    // fused round time-slices tenants onto the shared workspace by
    // swapping their adapters in and out — each swap is O(1) pointer
    // exchange on the trainable prefix, the base never moves — so the
    // fused path runs bit-for-bit the same math as a dedicated per-tenant
    // state. Backends without a host-visible trainable prefix (PJRT's
    // compiled state is opaque) keep the default bail and serve falls
    // back to serial execution.

    /// Build a fresh per-tenant adapter (trainable LoRA tensors + zeroed
    /// optimizer slots) for the named train executable, seeded
    /// deterministically: bitwise identical to the trainable prefix of
    /// [`Backend::init_state`] at the same seed.
    fn init_adapter(&self, train_name: &str, seed: i32) -> Result<AdapterState> {
        let _ = (train_name, seed);
        bail!("the {} backend does not support per-tenant adapters", self.name())
    }

    /// Exchange a tenant's adapter with the workspace state's trainable
    /// prefix (tensors + optimizer slots), leaving the shared base
    /// untouched. Symmetric: calling twice restores both sides.
    fn swap_adapter(&self, state: &mut DeviceState, adapter: &mut AdapterState) -> Result<()> {
        let _ = (state, adapter);
        bail!("the {} backend does not support per-tenant adapters", self.name())
    }

    /// Read a tenant adapter's trainable tensors to host, in state order
    /// (the trainable prefix of the checkpoint interchange format).
    fn adapter_params(&self, adapter: &AdapterState) -> Result<Vec<HostTensor>> {
        let _ = adapter;
        bail!("the {} backend does not support per-tenant adapters", self.name())
    }

    /// Whether this backend implements [`Backend::fused_step`]. The serve
    /// scheduler degrades `--fuse intra` to round fusion when this is
    /// false, so adding the seam never breaks a backend that lacks it.
    fn supports_fused_step(&self) -> bool {
        false
    }

    /// Run one *intra-step fused* round (DESIGN.md §11): a single shared
    /// base forward/backward over the concatenated `[B_total, S]` host
    /// batch, with each tenant's LoRA A/B applied only to its row-slice
    /// and each tenant's adapter gradients accumulated over a fixed-order
    /// row-slice reduction, then one optimizer step per tenant at that
    /// tenant's own `(step, lr, lr_b)`. Because the base weights are
    /// frozen under LoRA, per-tenant gradients are exactly separable —
    /// this must be *bitwise* identical to swapping each adapter in and
    /// training its rows serially at the same seeds. `adapters[k]` pairs
    /// with `slices[k]`; `state` is the shared workspace and is never
    /// mutated (only the adapters advance).
    fn fused_step(
        &self,
        train_name: &str,
        state: &DeviceState,
        adapters: &mut [AdapterState],
        batch: &Batch,
        slices: &[FusedSlice],
    ) -> Result<FusedOutputs> {
        let _ = (train_name, state, adapters, batch, slices);
        bail!("the {} backend does not support intra-step fused rounds", self.name())
    }

    // ---- data-parallel seams (DESIGN.md §10) -------------------------
    //
    // The `DataParallel` layer shards a staged batch into per-row micro-
    // shards, computes each row's gradient through `grad_row` (with the
    // loss normalizer forced to the whole batch's supervised-target count
    // so shard gradients sum to the full-batch gradient), tree-reduces the
    // shards in fixed order, then applies the optimizer exactly once via
    // `apply_grads`. Backends that cannot shard (PJRT's compiled [B, S]
    // step is monolithic) keep the default bail and simply cannot be
    // wrapped.

    /// Total element count of the flat trainable-gradient vector for
    /// `state` — the lane length of the data-parallel gradient arena.
    fn flat_grad_len(&self, state: &DeviceState) -> Result<usize> {
        let _ = state;
        bail!("the {} backend does not support data-parallel sharding", self.name())
    }

    /// Forward + backward on row `row` of the staged batch only, with the
    /// cross-entropy normalizer forced to `global_n_valid` (the whole
    /// batch's supervised-target count). Writes the row's flat trainable
    /// gradient into `out` (state order, trainable prefix) and returns its
    /// summed loss plus per-phase seconds. Must not touch optimizer state.
    fn grad_row(
        &self,
        train_name: &str,
        state: &DeviceState,
        batch: &DeviceBatch,
        row: usize,
        global_n_valid: usize,
        out: &mut [f32],
    ) -> Result<RowGrad> {
        let _ = (train_name, state, batch, row, global_n_valid, out);
        bail!("the {} backend does not support data-parallel sharding", self.name())
    }

    /// Apply one optimizer step from a flat reduced gradient (trainable
    /// prefix, state order) — the "step once" half of the data-parallel
    /// shard→reduce→step contract. Bitwise-identical to the update loop
    /// inside `train_step`.
    fn apply_grads(
        &self,
        train_name: &str,
        state: &mut DeviceState,
        flat: &[f32],
        step: u64,
        lr: f32,
        lr_b: f32,
    ) -> Result<()> {
        let _ = (train_name, state, flat, step, lr, lr_b);
        bail!("the {} backend does not support data-parallel sharding", self.name())
    }
}
