//! Cache-blocked kernels for the fast CPU backend, dispatched on the
//! persistent worker pool.
//!
//! Design rules (DESIGN.md §4.3):
//!
//! * **Row-tile parallelism on a persistent pool.** Every kernel
//!   partitions its *output* rows into at most `ex.threads()` contiguous
//!   tiles and hands each tile to the backend's [`Exec`] pool (`pool.rs`)
//!   — workers are spawned once per backend and parked between dispatches,
//!   so small-geometry kernels no longer pay a spawn/join per call. Tiles
//!   are disjoint `chunks_mut` slices: no locking, no write contention.
//! * **Thread-count-invariant bits.** Each output element is produced by
//!   exactly one job running the same sequential inner loop regardless of
//!   how rows were partitioned or which worker ran the tile, and every
//!   cross-tile reduction in the backend is performed on the dispatching
//!   thread in fixed tile order. The result: `threads = 1` and
//!   `threads = N` produce bitwise-identical steps (asserted in
//!   `rust/tests/parity.rs`), and `threads = 1` never touches the pool.
//! * **Fused epilogues.** RMSNorm feeds its projection(s) while the
//!   normalized row is still cache-hot (`fused_rmsnorm_qkv`,
//!   `fused_rmsnorm_swiglu`), matmuls carry their residual add
//!   (`matmul_residual`), and SwiGLU is applied as the gate/up epilogue —
//!   the paper's read-activations-once rule.
//! * **SIMD-width microkernels.** The inner dot ([`dot8`]) and AXPY
//!   ([`axpy`]) run fixed 8-lane unrolled loops over `[f32; 8]` chunks so
//!   the autovectorizer emits one AVX/NEON FMA per chunk, with a
//!   deterministic lane-reduction order (a fixed binary tree over the 8
//!   accumulators) — the summation order depends only on the slice
//!   length, never on threads or tiles. This reassociates vs. the scalar
//!   reference (tolerance-based parity, not bitwise — DESIGN.md §4.3
//!   tolerance policy).

use super::pool::Exec;
use crate::quant::QuantMat;

/// Rows per tile so that at most `threads` tiles cover `rows`.
pub(crate) fn rows_per_tile(rows: usize, threads: usize) -> usize {
    let th = threads.max(1).min(rows.max(1));
    rows.div_ceil(th)
}

/// Number of f32 lanes the unrolled microkernels process per iteration —
/// one AVX256 register (or two NEON registers) worth.
pub const LANES: usize = 8;

/// Dot product, 8-lane unrolled: independent per-lane accumulators over
/// `[f32; 8]` chunks (autovectorizes to one SIMD FMA per chunk), reduced
/// in a fixed binary-tree order. Deterministic for a given slice length.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    let mut acc = [0.0f32; LANES];
    for (x, y) in ca.by_ref().zip(cb.by_ref()) {
        let x: &[f32; LANES] = x.try_into().unwrap();
        let y: &[f32; LANES] = y.try_into().unwrap();
        for l in 0..LANES {
            acc[l] += x[l] * y[l];
        }
    }
    // fixed lane-reduction tree: bits depend only on the input length
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// `y += alpha · x`, 8-lane unrolled. Elementwise (no reduction), so the
/// bits match the scalar loop exactly.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut cy = y.chunks_exact_mut(LANES);
    let mut cx = x.chunks_exact(LANES);
    for (yc, xc) in cy.by_ref().zip(cx.by_ref()) {
        let yc: &mut [f32; LANES] = yc.try_into().unwrap();
        let xc: &[f32; LANES] = xc.try_into().unwrap();
        for l in 0..LANES {
            yc[l] += alpha * xc[l];
        }
    }
    for (yi, xi) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
        *yi += alpha * xi;
    }
}

/// `out[t, n] = Σ_k x[t, k] · w[n, k]` — `y = x @ W.T`, pooled over row
/// tiles of the output.
pub fn matmul(x: &[f32], w: &[f32], t: usize, k_in: usize, n_out: usize, out: &mut [f32], ex: &Exec) {
    debug_assert_eq!(x.len(), t * k_in);
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert_eq!(out.len(), t * n_out);
    let body = |r0: usize, out_c: &mut [f32]| {
        let rows = out_c.len() / n_out;
        for r in 0..rows {
            let xr = &x[(r0 + r) * k_in..(r0 + r + 1) * k_in];
            let or = &mut out_c[r * n_out..(r + 1) * n_out];
            for (n, o) in or.iter_mut().enumerate() {
                *o = dot8(xr, &w[n * k_in..(n + 1) * k_in]);
            }
        }
    };
    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        body(0, out);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        for (idx, out_c) in out.chunks_mut(rp * n_out).enumerate() {
            scope.spawn(move || body(idx * rp, out_c));
        }
    });
}

/// `out[t, n] = res[t, n] + Σ_k x[t, k] · w[n, k]` — matmul with the
/// residual add fused into the epilogue (one pass over the output).
#[allow(clippy::too_many_arguments)]
pub fn matmul_residual(
    x: &[f32],
    w: &[f32],
    res: &[f32],
    t: usize,
    k_in: usize,
    n_out: usize,
    out: &mut [f32],
    ex: &Exec,
) {
    debug_assert_eq!(x.len(), t * k_in);
    debug_assert_eq!(res.len(), t * n_out);
    debug_assert_eq!(out.len(), t * n_out);
    let body = |r0: usize, out_c: &mut [f32]| {
        let rows = out_c.len() / n_out;
        for r in 0..rows {
            let ti = r0 + r;
            let xr = &x[ti * k_in..(ti + 1) * k_in];
            let rr = &res[ti * n_out..(ti + 1) * n_out];
            let or = &mut out_c[r * n_out..(r + 1) * n_out];
            for n in 0..n_out {
                or[n] = rr[n] + dot8(xr, &w[n * k_in..(n + 1) * k_in]);
            }
        }
    };
    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        body(0, out);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        for (idx, out_c) in out.chunks_mut(rp * n_out).enumerate() {
            scope.spawn(move || body(idx * rp, out_c));
        }
    });
}

/// Weight rows dequantized per tile by the `*_q` kernels (DESIGN.md §12):
/// bounds the dequant scratch lease to `DEQ_ROWS · k` elements — far below
/// any full weight matrix, which is what the no-materialization pin
/// asserts via [`super::scratch::Arena::peak_elems`].
pub const DEQ_ROWS: usize = 64;

/// `out[t, n] = Σ_k x[t, k] · wq[n, k]` with the weight held in a
/// quantized codec. Weight rows are dequantized `DEQ_ROWS` at a time into
/// an arena-leased tile on the dispatching thread (never the whole matrix
/// — the §12 per-tile contract), then each tile runs the dense kernel's
/// row-parallel `dot8` loop over its output columns. Every output element
/// belongs to exactly one tile and `dequant_range_into` is positional
/// (elementwise-equal to a whole-matrix decode), so the bits are tile-,
/// chunk- and thread-count invariant — and identical to [`matmul`] run on
/// the dequantized matrix.
pub fn matmul_q(
    x: &[f32],
    wq: &QuantMat,
    t: usize,
    k_in: usize,
    n_out: usize,
    out: &mut [f32],
    ex: &Exec,
) {
    debug_assert_eq!(x.len(), t * k_in);
    debug_assert_eq!(wq.n(), n_out * k_in);
    debug_assert_eq!(out.len(), t * n_out);
    let rp = rows_per_tile(t, ex.threads());
    let mut n0 = 0usize;
    while n0 < n_out {
        let n1 = (n0 + DEQ_ROWS).min(n_out);
        let mut wtile = ex.arena().lease_uninit((n1 - n0) * k_in);
        wq.dequant_range_into(n0 * k_in, &mut wtile);
        let w: &[f32] = &wtile;
        let body = |r0: usize, out_c: &mut [f32]| {
            let rows = out_c.len() / n_out;
            for r in 0..rows {
                let xr = &x[(r0 + r) * k_in..(r0 + r + 1) * k_in];
                let or = &mut out_c[r * n_out + n0..r * n_out + n1];
                for (j, o) in or.iter_mut().enumerate() {
                    *o = dot8(xr, &w[j * k_in..(j + 1) * k_in]);
                }
            }
        };
        if ex.threads() <= 1 || t <= 1 {
            body(0, out);
        } else {
            ex.scope(|scope| {
                let body = &body;
                for (idx, out_c) in out.chunks_mut(rp * n_out).enumerate() {
                    scope.spawn(move || body(idx * rp, out_c));
                }
            });
        }
        n0 = n1;
    }
}

/// [`matmul_q`] with the residual add fused into the epilogue:
/// `out[t, n] = res[t, n] + Σ_k x[t, k] · wq[n, k]`. Each output column is
/// produced by exactly one weight tile, so the residual is added exactly
/// once.
#[allow(clippy::too_many_arguments)]
pub fn matmul_residual_q(
    x: &[f32],
    wq: &QuantMat,
    res: &[f32],
    t: usize,
    k_in: usize,
    n_out: usize,
    out: &mut [f32],
    ex: &Exec,
) {
    debug_assert_eq!(x.len(), t * k_in);
    debug_assert_eq!(wq.n(), n_out * k_in);
    debug_assert_eq!(res.len(), t * n_out);
    debug_assert_eq!(out.len(), t * n_out);
    let rp = rows_per_tile(t, ex.threads());
    let mut n0 = 0usize;
    while n0 < n_out {
        let n1 = (n0 + DEQ_ROWS).min(n_out);
        let mut wtile = ex.arena().lease_uninit((n1 - n0) * k_in);
        wq.dequant_range_into(n0 * k_in, &mut wtile);
        let w: &[f32] = &wtile;
        let body = |r0: usize, out_c: &mut [f32]| {
            let rows = out_c.len() / n_out;
            for r in 0..rows {
                let ti = r0 + r;
                let xr = &x[ti * k_in..(ti + 1) * k_in];
                let rr = &res[ti * n_out + n0..ti * n_out + n1];
                let or = &mut out_c[r * n_out + n0..r * n_out + n1];
                for (j, o) in or.iter_mut().enumerate() {
                    *o = rr[j] + dot8(xr, &w[j * k_in..(j + 1) * k_in]);
                }
            }
        };
        if ex.threads() <= 1 || t <= 1 {
            body(0, out);
        } else {
            ex.scope(|scope| {
                let body = &body;
                for (idx, out_c) in out.chunks_mut(rp * n_out).enumerate() {
                    scope.spawn(move || body(idx * rp, out_c));
                }
            });
        }
        n0 = n1;
    }
}

/// `dx[t, k] += Σ_n dy[t, n] · wq[n, k]` — input gradient against a
/// quantized weight, tiled like [`matmul_q`]. Tiles are visited in fixed
/// ascending order and each `dx` row accumulates its AXPYs in ascending
/// `n` within a tile, so the global accumulation order per element is the
/// dense kernel's `n`-ascending order — bitwise identical to
/// [`matmul_bwd_x`] on the dequantized matrix, at any thread count.
pub fn matmul_bwd_x_q(
    dy: &[f32],
    wq: &QuantMat,
    t: usize,
    k_in: usize,
    n_out: usize,
    dx: &mut [f32],
    ex: &Exec,
) {
    debug_assert_eq!(dy.len(), t * n_out);
    debug_assert_eq!(wq.n(), n_out * k_in);
    debug_assert_eq!(dx.len(), t * k_in);
    let rp = rows_per_tile(t, ex.threads());
    let mut n0 = 0usize;
    while n0 < n_out {
        let n1 = (n0 + DEQ_ROWS).min(n_out);
        let mut wtile = ex.arena().lease_uninit((n1 - n0) * k_in);
        wq.dequant_range_into(n0 * k_in, &mut wtile);
        let w: &[f32] = &wtile;
        let body = |r0: usize, dx_c: &mut [f32]| {
            let rows = dx_c.len() / k_in;
            for r in 0..rows {
                let ti = r0 + r;
                let dyr = &dy[ti * n_out + n0..ti * n_out + n1];
                let dxr = &mut dx_c[r * k_in..(r + 1) * k_in];
                for (j, &dyv) in dyr.iter().enumerate() {
                    if dyv == 0.0 {
                        continue;
                    }
                    axpy(dyv, &w[j * k_in..(j + 1) * k_in], dxr);
                }
            }
        };
        if ex.threads() <= 1 || t <= 1 {
            body(0, dx);
        } else {
            ex.scope(|scope| {
                let body = &body;
                for (idx, dx_c) in dx.chunks_mut(rp * k_in).enumerate() {
                    scope.spawn(move || body(idx * rp, dx_c));
                }
            });
        }
        n0 = n1;
    }
}

/// SwiGLU forward `y = SiLU(gate) · up`, pooled over element tiles — the
/// decomposed-path counterpart of the epilogue inside
/// [`fused_rmsnorm_swiglu`] (identical per-element math), used when the
/// gate/up projections run through the quantized kernels and the fusion
/// is not available.
pub fn swiglu(gate: &[f32], up: &[f32], y: &mut [f32], ex: &Exec) {
    debug_assert_eq!(gate.len(), y.len());
    debug_assert_eq!(up.len(), y.len());
    let n = y.len();
    let body = |e0: usize, y_c: &mut [f32]| {
        for (j, o) in y_c.iter_mut().enumerate() {
            let g = gate[e0 + j];
            let sig = 1.0 / (1.0 + (-g).exp());
            *o = g * sig * up[e0 + j];
        }
    };
    let ep = rows_per_tile(n, ex.threads());
    if ex.threads() <= 1 || n <= 1 {
        body(0, y);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        for (idx, y_c) in y.chunks_mut(ep).enumerate() {
            scope.spawn(move || body(idx * ep, y_c));
        }
    });
}

/// `dx[t, k] += Σ_n dy[t, n] · w[n, k]` — input gradient, pooled over dx
/// row tiles (accumulates, like the reference convention).
pub fn matmul_bwd_x(dy: &[f32], w: &[f32], t: usize, k_in: usize, n_out: usize, dx: &mut [f32], ex: &Exec) {
    debug_assert_eq!(dy.len(), t * n_out);
    debug_assert_eq!(dx.len(), t * k_in);
    let body = |r0: usize, dx_c: &mut [f32]| {
        let rows = dx_c.len() / k_in;
        for r in 0..rows {
            let ti = r0 + r;
            let dyr = &dy[ti * n_out..(ti + 1) * n_out];
            let dxr = &mut dx_c[r * k_in..(r + 1) * k_in];
            for (n, &dyv) in dyr.iter().enumerate() {
                if dyv == 0.0 {
                    continue;
                }
                axpy(dyv, &w[n * k_in..(n + 1) * k_in], dxr);
            }
        }
    };
    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        body(0, dx);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        for (idx, dx_c) in dx.chunks_mut(rp * k_in).enumerate() {
            scope.spawn(move || body(idx * rp, dx_c));
        }
    });
}

/// `dw[n, k] += Σ_t dy[t, n] · x[t, k]` — weight gradient, pooled over
/// output-neuron tiles (each job owns a contiguous block of dw rows and
/// scans all tokens sequentially, so bits are thread-count invariant).
pub fn matmul_bwd_w(dy: &[f32], x: &[f32], t: usize, k_in: usize, n_out: usize, dw: &mut [f32], ex: &Exec) {
    debug_assert_eq!(dy.len(), t * n_out);
    debug_assert_eq!(x.len(), t * k_in);
    debug_assert_eq!(dw.len(), n_out * k_in);
    let body = |n0: usize, dw_c: &mut [f32]| {
        let n_rows = dw_c.len() / k_in;
        for ti in 0..t {
            let xr = &x[ti * k_in..(ti + 1) * k_in];
            let dyr = &dy[ti * n_out..(ti + 1) * n_out];
            for n in 0..n_rows {
                let dyv = dyr[n0 + n];
                if dyv == 0.0 {
                    continue;
                }
                axpy(dyv, xr, &mut dw_c[n * k_in..(n + 1) * k_in]);
            }
        }
    };
    let np = rows_per_tile(n_out, ex.threads());
    if ex.threads() <= 1 || n_out <= 1 {
        body(0, dw);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        for (idx, dw_c) in dw.chunks_mut(np * k_in).enumerate() {
            scope.spawn(move || body(idx * np, dw_c));
        }
    });
}

/// RMSNorm forward, pooled over rows (same per-row math as the reference:
/// `rstd` sum stays sequential within a row).
pub fn rmsnorm(x: &[f32], gamma: &[f32], t: usize, d: usize, y: &mut [f32], rstd: &mut [f32], ex: &Exec) {
    use crate::backend::cpu::math::RMS_EPS;
    debug_assert_eq!(x.len(), t * d);
    debug_assert_eq!(gamma.len(), d);
    let body = |r0: usize, y_c: &mut [f32], rstd_c: &mut [f32]| {
        let rows = rstd_c.len();
        for r in 0..rows {
            let xr = &x[(r0 + r) * d..(r0 + r + 1) * d];
            let mut ss = 0.0f32;
            for &v in xr {
                ss += v * v;
            }
            let rs = 1.0 / (ss / d as f32 + RMS_EPS).sqrt();
            rstd_c[r] = rs;
            let yr = &mut y_c[r * d..(r + 1) * d];
            for i in 0..d {
                yr[i] = xr[i] * rs * gamma[i];
            }
        }
    };
    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        body(0, y, rstd);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        for (idx, (y_c, rstd_c)) in y.chunks_mut(rp * d).zip(rstd.chunks_mut(rp)).enumerate() {
            scope.spawn(move || body(idx * rp, y_c, rstd_c));
        }
    });
}

/// Fused RMSNorm → Q/K/V projections: each row tile normalizes its rows
/// into `h1` and immediately computes the three projections while the
/// normalized row is cache-hot. LoRA deltas are applied separately by the
/// caller (they need `h1 @ A.T` cached anyway).
#[allow(clippy::too_many_arguments)]
pub fn fused_rmsnorm_qkv(
    x: &[f32],
    gamma: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    t: usize,
    d: usize,
    dkv: usize,
    h1: &mut [f32],
    rstd: &mut [f32],
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
    ex: &Exec,
) {
    use crate::backend::cpu::math::RMS_EPS;
    debug_assert_eq!(x.len(), t * d);
    debug_assert_eq!(wq.len(), d * d);
    debug_assert_eq!(wk.len(), dkv * d);
    debug_assert_eq!(wv.len(), dkv * d);
    let body = |r0: usize, h1_c: &mut [f32], rstd_c: &mut [f32], q_c: &mut [f32], k_c: &mut [f32], v_c: &mut [f32]| {
        let rows = rstd_c.len();
        for r in 0..rows {
            let xr = &x[(r0 + r) * d..(r0 + r + 1) * d];
            let mut ss = 0.0f32;
            for &xv in xr {
                ss += xv * xv;
            }
            let rs = 1.0 / (ss / d as f32 + RMS_EPS).sqrt();
            rstd_c[r] = rs;
            let hr = &mut h1_c[r * d..(r + 1) * d];
            for i in 0..d {
                hr[i] = xr[i] * rs * gamma[i];
            }
            let hr = &h1_c[r * d..(r + 1) * d];
            let qr = &mut q_c[r * d..(r + 1) * d];
            for (n, o) in qr.iter_mut().enumerate() {
                *o = dot8(hr, &wq[n * d..(n + 1) * d]);
            }
            let kr = &mut k_c[r * dkv..(r + 1) * dkv];
            for (n, o) in kr.iter_mut().enumerate() {
                *o = dot8(hr, &wk[n * d..(n + 1) * d]);
            }
            let vr = &mut v_c[r * dkv..(r + 1) * dkv];
            for (n, o) in vr.iter_mut().enumerate() {
                *o = dot8(hr, &wv[n * d..(n + 1) * d]);
            }
        }
    };
    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        body(0, h1, rstd, q, k, v);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        let iter = h1
            .chunks_mut(rp * d)
            .zip(rstd.chunks_mut(rp))
            .zip(q.chunks_mut(rp * d))
            .zip(k.chunks_mut(rp * dkv))
            .zip(v.chunks_mut(rp * dkv))
            .enumerate();
        for (idx, ((((h1_c, rstd_c), q_c), k_c), v_c)) in iter {
            scope.spawn(move || body(idx * rp, h1_c, rstd_c, q_c, k_c, v_c));
        }
    });
}

/// Fused RMSNorm → gate/up projections → SwiGLU epilogue: one pass per row
/// tile produces `h2`, `gate`, `up` and `y = SiLU(gate)·up`.
#[allow(clippy::too_many_arguments)]
pub fn fused_rmsnorm_swiglu(
    x: &[f32],
    gamma: &[f32],
    w_gate: &[f32],
    w_up: &[f32],
    t: usize,
    d: usize,
    f: usize,
    h2: &mut [f32],
    rstd: &mut [f32],
    gate: &mut [f32],
    up: &mut [f32],
    y: &mut [f32],
    ex: &Exec,
) {
    use crate::backend::cpu::math::RMS_EPS;
    debug_assert_eq!(x.len(), t * d);
    debug_assert_eq!(w_gate.len(), f * d);
    debug_assert_eq!(w_up.len(), f * d);
    let body = |r0: usize, h2_c: &mut [f32], rstd_c: &mut [f32], gate_c: &mut [f32], up_c: &mut [f32], y_c: &mut [f32]| {
        let rows = rstd_c.len();
        for r in 0..rows {
            let xr = &x[(r0 + r) * d..(r0 + r + 1) * d];
            let mut ss = 0.0f32;
            for &xv in xr {
                ss += xv * xv;
            }
            let rs = 1.0 / (ss / d as f32 + RMS_EPS).sqrt();
            rstd_c[r] = rs;
            let hr = &mut h2_c[r * d..(r + 1) * d];
            for i in 0..d {
                hr[i] = xr[i] * rs * gamma[i];
            }
            let hr = &h2_c[r * d..(r + 1) * d];
            let gr = &mut gate_c[r * f..(r + 1) * f];
            let ur = &mut up_c[r * f..(r + 1) * f];
            let yr = &mut y_c[r * f..(r + 1) * f];
            for n in 0..f {
                let g = dot8(hr, &w_gate[n * d..(n + 1) * d]);
                let u = dot8(hr, &w_up[n * d..(n + 1) * d]);
                gr[n] = g;
                ur[n] = u;
                let sig = 1.0 / (1.0 + (-g).exp());
                yr[n] = g * sig * u;
            }
        }
    };
    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        body(0, h2, rstd, gate, up, y);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        let iter = h2
            .chunks_mut(rp * d)
            .zip(rstd.chunks_mut(rp))
            .zip(gate.chunks_mut(rp * f))
            .zip(up.chunks_mut(rp * f))
            .zip(y.chunks_mut(rp * f))
            .enumerate();
        for (idx, ((((h2_c, rstd_c), gate_c), up_c), y_c)) in iter {
            scope.spawn(move || body(idx * rp, h2_c, rstd_c, gate_c, up_c, y_c));
        }
    });
}

/// RMSNorm backward: `dx` rows pooled; `dgamma` accumulated in a
/// sequential second pass so its bits never depend on the row partition.
#[allow(clippy::too_many_arguments)]
pub fn rmsnorm_bwd(
    x: &[f32],
    gamma: &[f32],
    rstd: &[f32],
    dy: &[f32],
    t: usize,
    d: usize,
    dx: &mut [f32],
    dgamma: &mut [f32],
    ex: &Exec,
) {
    let body = |r0: usize, dx_c: &mut [f32]| {
        let rows = dx_c.len() / d;
        for r in 0..rows {
            let ti = r0 + r;
            let xr = &x[ti * d..(ti + 1) * d];
            let dyr = &dy[ti * d..(ti + 1) * d];
            let rs = rstd[ti];
            let mut c1 = 0.0f32;
            for i in 0..d {
                c1 += dyr[i] * gamma[i] * xr[i] * rs;
            }
            c1 /= d as f32;
            let dxr = &mut dx_c[r * d..(r + 1) * d];
            for i in 0..d {
                let xbar = xr[i] * rs;
                dxr[i] += rs * (gamma[i] * dyr[i] - xbar * c1);
            }
        }
    };
    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        body(0, dx);
    } else {
        ex.scope(|scope| {
            let body = &body;
            for (idx, dx_c) in dx.chunks_mut(rp * d).enumerate() {
                scope.spawn(move || body(idx * rp, dx_c));
            }
        });
    }
    // dgamma: tiny [d] reduction over all rows, fixed row order.
    for ti in 0..t {
        let xr = &x[ti * d..(ti + 1) * d];
        let dyr = &dy[ti * d..(ti + 1) * d];
        let rs = rstd[ti];
        for i in 0..d {
            dgamma[i] += dyr[i] * xr[i] * rs;
        }
    }
}

/// SwiGLU backward, pooled over element tiles (pure elementwise).
pub fn swiglu_bwd(gate: &[f32], up: &[f32], dy: &[f32], dgate: &mut [f32], dup: &mut [f32], ex: &Exec) {
    let n = dy.len();
    let body = |e0: usize, dgate_c: &mut [f32], dup_c: &mut [f32]| {
        for (j, (dg, du)) in dgate_c.iter_mut().zip(dup_c.iter_mut()).enumerate() {
            let i = e0 + j;
            let g = gate[i];
            let sig = 1.0 / (1.0 + (-g).exp());
            let silu = g * sig;
            *dg += dy[i] * up[i] * sig * (1.0 + g * (1.0 - sig));
            *du += dy[i] * silu;
        }
    };
    let ep = rows_per_tile(n, ex.threads());
    if ex.threads() <= 1 || n <= 1 {
        body(0, dgate, dup);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        for (idx, (dgate_c, dup_c)) in dgate.chunks_mut(ep).zip(dup.chunks_mut(ep)).enumerate() {
            scope.spawn(move || body(idx * ep, dgate_c, dup_c));
        }
    });
}

/// RoPE (rotate-half), pooled over token rows. Same per-element math as
/// the reference `rope_apply` (bitwise-identical results), but the angle —
/// which depends only on `(pos, j)` — is computed once per `(row, j)` and
/// reused across all heads instead of recomputing `powf`/`cos`/`sin`
/// `n_heads` times.
pub fn rope(x: &mut [f32], pos: &[i32], t: usize, n_heads: usize, hd: usize, sign: f32, ex: &Exec) {
    use crate::backend::cpu::math::ROPE_BASE;
    debug_assert_eq!(x.len(), t * n_heads * hd);
    let row = n_heads * hd;
    let half = hd / 2;
    let body = |r0: usize, x_c: &mut [f32]| {
        let rows = x_c.len() / row;
        for r in 0..rows {
            let p = pos[r0 + r] as f32;
            for j in 0..half {
                let inv_freq = ROPE_BASE.powf(-(j as f32) / half as f32);
                let theta = p * inv_freq;
                let (c, s) = (theta.cos(), theta.sin() * sign);
                for h in 0..n_heads {
                    let base = r * row + h * hd;
                    let x1 = x_c[base + j];
                    let x2 = x_c[base + half + j];
                    x_c[base + j] = x1 * c - x2 * s;
                    x_c[base + half + j] = x2 * c + x1 * s;
                }
            }
        }
    };
    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        body(0, x);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        for (idx, x_c) in x.chunks_mut(rp * row).enumerate() {
            scope.spawn(move || body(idx * rp, x_c));
        }
    });
}

/// AdamW, pooled over element tiles. Elementwise and therefore bitwise
/// identical to the sequential reference update for every element.
#[allow(clippy::too_many_arguments)]
pub fn adamw(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    lr: f32,
    step: f32,
    weight_decay: f32,
    ex: &Exec,
) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let bc1 = 1.0 - B1.powf(step);
    let bc2 = 1.0 - B2.powf(step);
    let n = p.len();
    let body = |e0: usize, p_c: &mut [f32], m_c: &mut [f32], v_c: &mut [f32]| {
        for (j, pv) in p_c.iter_mut().enumerate() {
            let gi = g[e0 + j];
            m_c[j] = B1 * m_c[j] + (1.0 - B1) * gi;
            v_c[j] = B2 * v_c[j] + (1.0 - B2) * gi * gi;
            let m_hat = m_c[j] / bc1;
            let v_hat = v_c[j] / bc2;
            *pv = *pv * (1.0 - lr * weight_decay) - lr * m_hat / (v_hat.sqrt() + EPS);
        }
    };
    let ep = rows_per_tile(n, ex.threads());
    if ex.threads() <= 1 || n <= 1 {
        body(0, p, m, v);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        let iter = p.chunks_mut(ep).zip(m.chunks_mut(ep)).zip(v.chunks_mut(ep)).enumerate();
        for (idx, ((p_c, m_c), v_c)) in iter {
            scope.spawn(move || body(idx * ep, p_c, m_c, v_c));
        }
    });
}

/// Fused LoRA linear: `ha = x @ A.T`, then `out += scale · ha @ B.T`, with
/// the intermediate row kept cache-hot (and cached in `ha` for backward).
#[allow(clippy::too_many_arguments)]
pub fn lora_linear(
    x: &[f32],
    a: &[f32],
    b: &[f32],
    t: usize,
    d: usize,
    r: usize,
    n_out: usize,
    scale: f32,
    ha: &mut [f32],
    out: &mut [f32],
    ex: &Exec,
) {
    debug_assert_eq!(x.len(), t * d);
    debug_assert_eq!(a.len(), r * d);
    debug_assert_eq!(b.len(), n_out * r);
    debug_assert_eq!(ha.len(), t * r);
    debug_assert_eq!(out.len(), t * n_out);
    let body = |r0: usize, ha_c: &mut [f32], out_c: &mut [f32]| {
        let rows = ha_c.len() / r;
        for rr in 0..rows {
            let xr = &x[(r0 + rr) * d..(r0 + rr + 1) * d];
            let har = &mut ha_c[rr * r..(rr + 1) * r];
            for (n, o) in har.iter_mut().enumerate() {
                *o = dot8(xr, &a[n * d..(n + 1) * d]);
            }
            let har = &ha_c[rr * r..(rr + 1) * r];
            let or = &mut out_c[rr * n_out..(rr + 1) * n_out];
            for (n, o) in or.iter_mut().enumerate() {
                *o += scale * dot8(har, &b[n * r..(n + 1) * r]);
            }
        }
    };
    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        body(0, ha, out);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        for (idx, (ha_c, out_c)) in ha.chunks_mut(rp * r).zip(out.chunks_mut(rp * n_out)).enumerate() {
            scope.spawn(move || body(idx * rp, ha_c, out_c));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::math;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * 0.5).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                "{what}[{i}]: {x} vs {y}"
            );
        }
    }

    #[test]
    fn dot8_matches_sequential() {
        let mut rng = Rng::new(1);
        for n in [0usize, 1, 3, 4, 7, 8, 9, 16, 33] {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let seq: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot8(&a, &b) - seq).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn axpy_matches_scalar_bits() {
        let mut rng = Rng::new(10);
        for n in [0usize, 1, 7, 8, 19, 32] {
            let x = randv(&mut rng, n);
            let mut y1 = randv(&mut rng, n);
            let mut y2 = y1.clone();
            axpy(0.37, &x, &mut y1);
            for (yi, xi) in y2.iter_mut().zip(&x) {
                *yi += 0.37 * xi;
            }
            assert_eq!(
                y1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "n={n}: unrolled axpy changed bits vs the scalar loop"
            );
        }
    }

    #[test]
    fn matmul_matches_reference_any_thread_count() {
        let mut rng = Rng::new(2);
        let (t, k, n) = (13, 9, 11);
        let x = randv(&mut rng, t * k);
        let w = randv(&mut rng, n * k);
        let mut want = vec![0.0f32; t * n];
        math::linear_fwd(&x, &w, t, k, n, &mut want);
        let mut bits1: Option<Vec<u32>> = None;
        for threads in [1usize, 2, 5] {
            let ex = Exec::new(threads);
            let mut got = vec![0.0f32; t * n];
            matmul(&x, &w, t, k, n, &mut got, &ex);
            assert_close(&got, &want, 1e-5, "matmul");
            let bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            match &bits1 {
                None => bits1 = Some(bits),
                Some(b1) => assert_eq!(&bits, b1, "threads={threads} changed bits"),
            }
        }
    }

    #[test]
    fn matmul_residual_adds_res() {
        let mut rng = Rng::new(3);
        let (t, k, n) = (5, 4, 6);
        let x = randv(&mut rng, t * k);
        let w = randv(&mut rng, n * k);
        let res = randv(&mut rng, t * n);
        let mut want = vec![0.0f32; t * n];
        math::linear_fwd(&x, &w, t, k, n, &mut want);
        for i in 0..t * n {
            want[i] += res[i];
        }
        let ex = Exec::new(3);
        let mut got = vec![0.0f32; t * n];
        matmul_residual(&x, &w, &res, t, k, n, &mut got, &ex);
        assert_close(&got, &want, 1e-5, "matmul_residual");
    }

    #[test]
    fn matmul_bwd_matches_reference() {
        let mut rng = Rng::new(4);
        let (t, k, n) = (10, 6, 7);
        let x = randv(&mut rng, t * k);
        let w = randv(&mut rng, n * k);
        let dy = randv(&mut rng, t * n);
        let (mut dx_ref, mut dw_ref) = (vec![0.0f32; t * k], vec![0.0f32; n * k]);
        math::linear_bwd_x(&dy, &w, t, k, n, &mut dx_ref);
        math::linear_bwd_w(&dy, &x, t, k, n, &mut dw_ref);
        for threads in [1usize, 3] {
            let ex = Exec::new(threads);
            let (mut dx, mut dw) = (vec![0.0f32; t * k], vec![0.0f32; n * k]);
            matmul_bwd_x(&dy, &w, t, k, n, &mut dx, &ex);
            matmul_bwd_w(&dy, &x, t, k, n, &mut dw, &ex);
            assert_close(&dx, &dx_ref, 1e-5, "dx");
            assert_close(&dw, &dw_ref, 1e-5, "dw");
        }
    }

    #[test]
    fn fused_rmsnorm_qkv_matches_unfused() {
        let mut rng = Rng::new(5);
        let (t, d, dkv) = (9, 8, 4);
        let x = randv(&mut rng, t * d);
        let gamma = randv(&mut rng, d);
        let wq = randv(&mut rng, d * d);
        let wk = randv(&mut rng, dkv * d);
        let wv = randv(&mut rng, dkv * d);
        let (mut h_ref, mut rstd_ref) = (vec![0.0f32; t * d], vec![0.0f32; t]);
        math::rmsnorm_fwd(&x, &gamma, t, d, &mut h_ref, &mut rstd_ref);
        let mut q_ref = vec![0.0f32; t * d];
        let mut k_ref = vec![0.0f32; t * dkv];
        let mut v_ref = vec![0.0f32; t * dkv];
        math::linear_fwd(&h_ref, &wq, t, d, d, &mut q_ref);
        math::linear_fwd(&h_ref, &wk, t, d, dkv, &mut k_ref);
        math::linear_fwd(&h_ref, &wv, t, d, dkv, &mut v_ref);
        for threads in [1usize, 4] {
            let ex = Exec::new(threads);
            let (mut h1, mut rstd) = (vec![0.0f32; t * d], vec![0.0f32; t]);
            let mut q = vec![0.0f32; t * d];
            let mut k = vec![0.0f32; t * dkv];
            let mut v = vec![0.0f32; t * dkv];
            fused_rmsnorm_qkv(&x, &gamma, &wq, &wk, &wv, t, d, dkv, &mut h1, &mut rstd, &mut q, &mut k, &mut v, &ex);
            assert_close(&h1, &h_ref, 1e-5, "h1");
            assert_close(&q, &q_ref, 1e-5, "q");
            assert_close(&k, &k_ref, 1e-5, "k");
            assert_close(&v, &v_ref, 1e-5, "v");
        }
    }

    #[test]
    fn fused_rmsnorm_swiglu_matches_unfused() {
        let mut rng = Rng::new(6);
        let (t, d, f) = (7, 6, 10);
        let x = randv(&mut rng, t * d);
        let gamma = randv(&mut rng, d);
        let wg = randv(&mut rng, f * d);
        let wu = randv(&mut rng, f * d);
        let (mut h_ref, mut rstd_ref) = (vec![0.0f32; t * d], vec![0.0f32; t]);
        math::rmsnorm_fwd(&x, &gamma, t, d, &mut h_ref, &mut rstd_ref);
        let mut g_ref = vec![0.0f32; t * f];
        let mut u_ref = vec![0.0f32; t * f];
        math::linear_fwd(&h_ref, &wg, t, d, f, &mut g_ref);
        math::linear_fwd(&h_ref, &wu, t, d, f, &mut u_ref);
        let mut y_ref = vec![0.0f32; t * f];
        math::swiglu_fwd(&g_ref, &u_ref, &mut y_ref);
        let ex = Exec::new(2);
        let (mut h2, mut rstd) = (vec![0.0f32; t * d], vec![0.0f32; t]);
        let (mut gate, mut up, mut y) =
            (vec![0.0f32; t * f], vec![0.0f32; t * f], vec![0.0f32; t * f]);
        fused_rmsnorm_swiglu(&x, &gamma, &wg, &wu, t, d, f, &mut h2, &mut rstd, &mut gate, &mut up, &mut y, &ex);
        assert_close(&y, &y_ref, 1e-5, "y");
        assert_close(&gate, &g_ref, 1e-5, "gate");
        assert_close(&up, &u_ref, 1e-5, "up");
    }

    #[test]
    fn rmsnorm_bwd_matches_reference() {
        let mut rng = Rng::new(7);
        let (t, d) = (6, 5);
        let x = randv(&mut rng, t * d);
        let gamma = randv(&mut rng, d);
        let dy = randv(&mut rng, t * d);
        let (mut y, mut rstd) = (vec![0.0f32; t * d], vec![0.0f32; t]);
        math::rmsnorm_fwd(&x, &gamma, t, d, &mut y, &mut rstd);
        let (mut dx_ref, mut dg_ref) = (vec![0.0f32; t * d], vec![0.0f32; d]);
        math::rmsnorm_bwd(&x, &gamma, &rstd, &dy, t, d, &mut dx_ref, &mut dg_ref);
        let ex = Exec::new(3);
        let (mut dx, mut dg) = (vec![0.0f32; t * d], vec![0.0f32; d]);
        rmsnorm_bwd(&x, &gamma, &rstd, &dy, t, d, &mut dx, &mut dg, &ex);
        assert_close(&dx, &dx_ref, 1e-5, "dx");
        assert_close(&dg, &dg_ref, 1e-5, "dgamma");
    }

    #[test]
    fn rope_and_adamw_match_reference_bits() {
        let mut rng = Rng::new(8);
        let (t, heads, hd) = (6, 2, 4);
        let pos: Vec<i32> = (0..t as i32).collect();
        let orig = randv(&mut rng, t * heads * hd);
        let mut a = orig.clone();
        let mut b = orig.clone();
        let ex = Exec::new(3);
        math::rope_apply(&mut a, &pos, t, heads, hd, 1.0);
        rope(&mut b, &pos, t, heads, hd, 1.0, &ex);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );

        let n = 23;
        let g = randv(&mut rng, n);
        let mut p1 = randv(&mut rng, n);
        let mut p2 = p1.clone();
        let (mut m1, mut v1) = (vec![0.0f32; n], vec![0.0f32; n]);
        let (mut m2, mut v2) = (vec![0.0f32; n], vec![0.0f32; n]);
        let ex = Exec::new(4);
        math::adamw_update(&mut p1, &g, &mut m1, &mut v1, 1e-3, 1.0, 0.01);
        adamw(&mut p2, &g, &mut m2, &mut v2, 1e-3, 1.0, 0.01, &ex);
        assert_eq!(
            p1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            p2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn quantized_matmuls_match_dense_kernels_on_dequant_bitwise() {
        use crate::quant::{BaseQuant, QuantMat};
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let mut rng = Rng::new(21);
        // n_out > DEQ_ROWS so the tile loop takes more than one pass
        let (t, k, n) = (5usize, 9, DEQ_ROWS + 7);
        let x = randv(&mut rng, t * k);
        let w = randv(&mut rng, n * k);
        let res = randv(&mut rng, t * n);
        let dy = randv(&mut rng, t * n);
        for codec in [BaseQuant::Int8, BaseQuant::Fp8] {
            let qm = QuantMat::encode(&w, codec);
            let wd = qm.dequant();
            for threads in [1usize, 3] {
                let ex = Exec::new(threads);
                let (mut want, mut got) = (vec![0.0f32; t * n], vec![0.0f32; t * n]);
                matmul(&x, &wd, t, k, n, &mut want, &ex);
                matmul_q(&x, &qm, t, k, n, &mut got, &ex);
                assert_eq!(bits(&got), bits(&want), "{codec:?} t{threads}: matmul_q bits");

                let (mut want_r, mut got_r) = (vec![0.0f32; t * n], vec![0.0f32; t * n]);
                matmul_residual(&x, &wd, &res, t, k, n, &mut want_r, &ex);
                matmul_residual_q(&x, &qm, &res, t, k, n, &mut got_r, &ex);
                assert_eq!(bits(&got_r), bits(&want_r), "{codec:?} t{threads}: residual bits");

                let (mut want_dx, mut got_dx) = (vec![0.0f32; t * k], vec![0.0f32; t * k]);
                matmul_bwd_x(&dy, &wd, t, k, n, &mut want_dx, &ex);
                matmul_bwd_x_q(&dy, &qm, t, k, n, &mut got_dx, &ex);
                assert_eq!(bits(&got_dx), bits(&want_dx), "{codec:?} t{threads}: bwd_x bits");
            }
        }
    }

    #[test]
    fn quantized_matmul_leases_only_weight_tiles() {
        use crate::quant::{BaseQuant, QuantMat};
        let mut rng = Rng::new(22);
        let (t, k, n) = (3usize, 16, 4 * DEQ_ROWS);
        let x = randv(&mut rng, t * k);
        let w = randv(&mut rng, n * k);
        let qm = QuantMat::encode(&w, BaseQuant::Int8);
        let ex = Exec::new(2);
        let mut out = vec![0.0f32; t * n];
        matmul_q(&x, &qm, t, k, n, &mut out, &ex);
        assert!(
            ex.arena().peak_elems() <= DEQ_ROWS * k,
            "dequant scratch {} exceeds one weight tile ({})",
            ex.arena().peak_elems(),
            DEQ_ROWS * k
        );
        assert!(ex.arena().peak_elems() < n * k, "a full weight matrix was materialized");
    }

    #[test]
    fn swiglu_forward_matches_reference_bits() {
        let mut rng = Rng::new(23);
        let n = 37;
        let gate = randv(&mut rng, n);
        let up = randv(&mut rng, n);
        let mut want = vec![0.0f32; n];
        math::swiglu_fwd(&gate, &up, &mut want);
        for threads in [1usize, 4] {
            let ex = Exec::new(threads);
            let mut got = vec![0.0f32; n];
            swiglu(&gate, &up, &mut got, &ex);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn lora_linear_matches_two_step_reference() {
        let mut rng = Rng::new(9);
        let (t, d, r, n) = (8, 6, 2, 5);
        let x = randv(&mut rng, t * d);
        let a = randv(&mut rng, r * d);
        let b = randv(&mut rng, n * r);
        let scale = 1.7f32;
        let mut ha_ref = vec![0.0f32; t * r];
        math::linear_fwd(&x, &a, t, d, r, &mut ha_ref);
        let mut delta = vec![0.0f32; t * n];
        math::linear_fwd(&ha_ref, &b, t, r, n, &mut delta);
        let base = randv(&mut rng, t * n);
        let mut want = base.clone();
        for i in 0..t * n {
            want[i] += scale * delta[i];
        }
        let ex = Exec::new(2);
        let mut ha = vec![0.0f32; t * r];
        let mut out = base.clone();
        lora_linear(&x, &a, &b, t, d, r, n, scale, &mut ha, &mut out, &ex);
        assert_close(&ha, &ha_ref, 1e-5, "ha");
        assert_close(&out, &want, 1e-5, "out");
    }
}
