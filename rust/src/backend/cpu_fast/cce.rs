//! Cut Cross-Entropy: loss and unembedding gradients without the `[T, V]`
//! logits/probability buffer (paper Thm. 3, the 5 GB → 135 MB result).
//!
//! * **Forward** computes, per token row, a streaming logsumexp over vocab
//!   tiles: logits for one `V_TILE`-wide tile are recomputed from
//!   `hf @ W_head.T`, folded into the running `(max, denom)` pair, and
//!   discarded. Only the per-row `lse` scalar (`[T]`) survives; the loss is
//!   `lse − z_target` summed over supervised rows.
//! * **Backward** fuses the `(softmax − onehot)/n_valid` term into the
//!   gradient tile loops, recomputing `d = (exp(z − lse) − onehot)/n_valid`
//!   on the fly from the forward's `lse`. It runs as two partial-free
//!   passes: the dW pass parallelizes over vocab-row tiles (each job owns
//!   its `dW_head` rows outright), the dhf pass over token rows (each job
//!   owns its `dhf` rows and walks the vocab tiles in ascending order).
//!   The only transient is one `V_TILE` logit strip per job — never
//!   `[T, V]`, and never a per-tile `[T, d]` partial either (a
//!   single-reduction variant would hold `V/V_TILE` of those, which
//!   *exceeds* `[T, V]` once `d_model ≥ V_TILE`). The price is recomputing
//!   the logit tile once per pass; that is the paper's CCE trade — flops
//!   for memory traffic.
//!
//! Jobs run on the backend's persistent pool (`pool.rs`); the per-job
//! logit strips and the `[T]` row-loss buffer are leased from the arena on
//! the dispatching thread before dispatch, so the lease sequence is
//! scheduling-independent.
//!
//! Thread-count invariance: the tile width is a fixed constant and every
//! output row (of `dW_head` and of `dhf`) is accumulated by exactly one
//! job in the same ascending order regardless of the partition — so the
//! bits never depend on how work was assigned to workers.

use super::kernels::{axpy, dot8, rows_per_tile};
use super::pool::Exec;

/// Vocab tile width. Fixed (not thread-derived) so results are independent
/// of parallelism.
pub const V_TILE: usize = 64;

/// Streaming-logsumexp loss forward.
///
/// `hf: [T, d]` (final normed hidden states), `w_head: [V, d]`,
/// `targets: [T]` with `-1` = masked. Fills `lse: [T]` (0.0 on masked
/// rows) and returns `(summed loss over valid rows, n_valid)` — the same
/// contract as the reference `softmax_xent`, minus the `[T, V]` buffer.
#[allow(clippy::too_many_arguments)]
pub fn cce_loss_fwd(
    hf: &[f32],
    w_head: &[f32],
    targets: &[i32],
    t: usize,
    d: usize,
    v: usize,
    lse: &mut [f32],
    ex: &Exec,
) -> (f32, usize) {
    debug_assert_eq!(hf.len(), t * d);
    debug_assert_eq!(w_head.len(), v * d);
    debug_assert_eq!(lse.len(), t);
    let mut rowloss = ex.arena().lease_uninit(t);

    let body = |r0: usize, lse_c: &mut [f32], rl_c: &mut [f32], z: &mut [f32]| {
        for r in 0..lse_c.len() {
            let ti = r0 + r;
            let tgt = targets[ti];
            if tgt < 0 {
                lse_c[r] = 0.0;
                rl_c[r] = 0.0;
                continue;
            }
            let hr = &hf[ti * d..(ti + 1) * d];
            let mut m = f32::NEG_INFINITY;
            let mut l = 0.0f32;
            let mut z_tgt = 0.0f32;
            let mut v0 = 0usize;
            while v0 < v {
                let v1 = (v0 + V_TILE).min(v);
                let mut tm = f32::NEG_INFINITY;
                for (jj, n) in (v0..v1).enumerate() {
                    let zv = dot8(hr, &w_head[n * d..(n + 1) * d]);
                    z[jj] = zv;
                    tm = tm.max(zv);
                }
                let m_new = m.max(tm);
                if m > f32::NEG_INFINITY {
                    l *= (m - m_new).exp(); // exp(0) = 1 exactly when unchanged
                }
                for &zv in z[..v1 - v0].iter() {
                    l += (zv - m_new).exp();
                }
                m = m_new;
                let tu = tgt as usize;
                if tu >= v0 && tu < v1 {
                    z_tgt = z[tu - v0];
                }
                v0 = v1;
            }
            lse_c[r] = m + l.ln();
            rl_c[r] = lse_c[r] - z_tgt;
        }
    };

    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        let mut z = ex.arena().lease_uninit(V_TILE);
        body(0, lse, &mut rowloss, &mut z);
    } else {
        ex.scope(|scope| {
            let body = &body;
            // per-tile logit strips leased before any job is queued, so
            // arena traffic never depends on worker scheduling
            let strips: Vec<_> =
                (0..t.div_ceil(rp)).map(|_| ex.arena().lease_uninit(V_TILE)).collect();
            let iter = lse.chunks_mut(rp).zip(rowloss.chunks_mut(rp)).zip(strips).enumerate();
            for (idx, ((lse_c, rl_c), mut z)) in iter {
                scope.spawn(move || body(idx * rp, lse_c, rl_c, &mut z));
            }
        });
    }

    // fixed-order reduction: bits independent of the row partition
    let mut loss_sum = 0.0f32;
    let mut n_valid = 0usize;
    for ti in 0..t {
        if targets[ti] >= 0 {
            loss_sum += rowloss[ti];
            n_valid += 1;
        }
    }
    (loss_sum, n_valid)
}

/// Fused CCE backward.
///
/// Accumulates `dhf += d @ W_head` (always) and, when the unembedding is
/// trainable, `dw_head += d ⊗ hf`, where `d = (softmax − onehot)/n_valid`
/// is recomputed tile-by-tile from `lse` — no `[T, V]` buffer and no
/// `[T, d]` partials exist (see the module docs for the two-pass scheme).
#[allow(clippy::too_many_arguments)]
pub fn cce_bwd_fused(
    hf: &[f32],
    w_head: &[f32],
    targets: &[i32],
    lse: &[f32],
    t: usize,
    d: usize,
    v: usize,
    n_valid: usize,
    mut dw_head: Option<&mut [f32]>,
    dhf: &mut [f32],
    ex: &Exec,
) {
    debug_assert_eq!(dhf.len(), t * d);
    if let Some(dw) = dw_head.as_deref() {
        debug_assert_eq!(dw.len(), v * d);
    }
    let nv = n_valid.max(1) as f32;

    // dW pass: jobs own disjoint vocab-row blocks of dw_head outright.
    if let Some(dw) = dw_head.as_deref_mut() {
        let n_tiles = v.div_ceil(V_TILE);
        let tp = rows_per_tile(n_tiles, ex.threads()); // vocab tiles per job
        if ex.threads() <= 1 || n_tiles <= 1 {
            let mut z = ex.arena().lease_uninit(V_TILE);
            dw_pass(hf, w_head, targets, lse, t, d, v, nv, 0, dw, &mut z);
        } else {
            ex.scope(|scope| {
                // strips leased up front (scheduling-independent arena traffic)
                let strips: Vec<_> = (0..dw.len().div_ceil(tp * V_TILE * d))
                    .map(|_| ex.arena().lease_uninit(V_TILE))
                    .collect();
                let iter = dw.chunks_mut(tp * V_TILE * d).zip(strips).enumerate();
                for (idx, (dw_c, mut z)) in iter {
                    scope.spawn(move || {
                        dw_pass(hf, w_head, targets, lse, t, d, v, nv, idx * tp * V_TILE, dw_c, &mut z)
                    });
                }
            });
        }
    }

    // dhf pass: jobs own disjoint token-row blocks of dhf, each walking
    // the vocab tiles in ascending order (thread-count-invariant bits).
    let rp = rows_per_tile(t, ex.threads());
    if ex.threads() <= 1 || t <= 1 {
        let mut z = ex.arena().lease_uninit(V_TILE);
        dhf_pass(hf, w_head, targets, lse, d, v, nv, 0, dhf, &mut z);
    } else {
        ex.scope(|scope| {
            // strips leased up front (scheduling-independent arena traffic)
            let strips: Vec<_> = (0..dhf.len().div_ceil(rp * d))
                .map(|_| ex.arena().lease_uninit(V_TILE))
                .collect();
            let iter = dhf.chunks_mut(rp * d).zip(strips).enumerate();
            for (idx, (dhf_c, mut z)) in iter {
                scope.spawn(move || dhf_pass(hf, w_head, targets, lse, d, v, nv, idx * rp, dhf_c, &mut z));
            }
        });
    }
}

/// dW job: accumulate `dw_c = dW_head[v0 .. v0 + rows]` (a contiguous
/// block of vocab rows starting at global row `v0`) over all tokens, one
/// recomputed logit strip at a time.
#[allow(clippy::too_many_arguments)]
fn dw_pass(
    hf: &[f32],
    w_head: &[f32],
    targets: &[i32],
    lse: &[f32],
    t: usize,
    d: usize,
    v: usize,
    nv: f32,
    v0: usize,
    dw_c: &mut [f32],
    z: &mut [f32],
) {
    let v_end = (v0 + dw_c.len() / d).min(v);
    let mut t0 = v0;
    while t0 < v_end {
        let t1 = (t0 + V_TILE).min(v_end);
        for ti in 0..t {
            let tgt = targets[ti];
            if tgt < 0 {
                continue;
            }
            let hr = &hf[ti * d..(ti + 1) * d];
            for (jj, n) in (t0..t1).enumerate() {
                z[jj] = dot8(hr, &w_head[n * d..(n + 1) * d]);
            }
            let lse_i = lse[ti];
            for (jj, n) in (t0..t1).enumerate() {
                let mut dl = (z[jj] - lse_i).exp() / nv;
                if n == tgt as usize {
                    dl -= 1.0 / nv;
                }
                if dl == 0.0 {
                    continue;
                }
                let off = (n - v0) * d;
                axpy(dl, hr, &mut dw_c[off..off + d]);
            }
        }
        t0 = t1;
    }
}

/// dhf job: accumulate `dhf_c = dhf[r0 .. r0 + rows]` (a contiguous block
/// of token rows), walking all vocab tiles in ascending order per row so
/// the summation order never depends on the thread count.
#[allow(clippy::too_many_arguments)]
fn dhf_pass(
    hf: &[f32],
    w_head: &[f32],
    targets: &[i32],
    lse: &[f32],
    d: usize,
    v: usize,
    nv: f32,
    r0: usize,
    dhf_c: &mut [f32],
    z: &mut [f32],
) {
    let rows = dhf_c.len() / d;
    for r in 0..rows {
        let ti = r0 + r;
        let tgt = targets[ti];
        if tgt < 0 {
            continue;
        }
        let hr = &hf[ti * d..(ti + 1) * d];
        let lse_i = lse[ti];
        let dr = &mut dhf_c[r * d..(r + 1) * d];
        let mut v0 = 0usize;
        while v0 < v {
            let v1 = (v0 + V_TILE).min(v);
            for (jj, n) in (v0..v1).enumerate() {
                z[jj] = dot8(hr, &w_head[n * d..(n + 1) * d]);
            }
            for (jj, n) in (v0..v1).enumerate() {
                let mut dl = (z[jj] - lse_i).exp() / nv;
                if n == tgt as usize {
                    dl -= 1.0 / nv;
                }
                if dl == 0.0 {
                    continue;
                }
                axpy(dl, &w_head[n * d..(n + 1) * d], dr);
            }
            v0 = v1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::math;
    use crate::util::rng::Rng;

    struct Fixture {
        t: usize,
        d: usize,
        v: usize,
        hf: Vec<f32>,
        w: Vec<f32>,
        targets: Vec<i32>,
    }

    /// v deliberately not a multiple of V_TILE to cover the ragged tail.
    fn fixture(seed: u64, v: usize) -> Fixture {
        let (t, d) = (11usize, 6usize);
        let mut rng = Rng::new(seed);
        let hf: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..v * d).map(|_| rng.normal() as f32 * 0.3).collect();
        let targets: Vec<i32> = (0..t)
            .map(|i| if i % 4 == 3 { -1 } else { rng.range(0, v) as i32 })
            .collect();
        Fixture { t, d, v, hf, w, targets }
    }

    fn reference(f: &Fixture) -> (f32, usize, Vec<f32>, Vec<f32>, Vec<f32>) {
        let (t, d, v) = (f.t, f.d, f.v);
        let mut logits = vec![0.0f32; t * v];
        math::linear_fwd(&f.hf, &f.w, t, d, v, &mut logits);
        let mut probs = vec![0.0f32; t * v];
        let (loss, n_valid) = math::softmax_xent(&logits, &f.targets, t, v, &mut probs);
        let nv = n_valid.max(1) as f32;
        let mut dlogits = vec![0.0f32; t * v];
        for ti in 0..t {
            let tgt = f.targets[ti];
            if tgt < 0 {
                continue;
            }
            for i in 0..v {
                dlogits[ti * v + i] = probs[ti * v + i] / nv;
            }
            dlogits[ti * v + tgt as usize] -= 1.0 / nv;
        }
        let mut dw = vec![0.0f32; v * d];
        let mut dhf = vec![0.0f32; t * d];
        math::linear_bwd_w(&dlogits, &f.hf, t, d, v, &mut dw);
        math::linear_bwd_x(&dlogits, &f.w, t, d, v, &mut dhf);
        (loss, n_valid, probs, dw, dhf)
    }

    #[test]
    fn tiled_logsumexp_matches_materialized_softmax() {
        for v in [V_TILE / 2, V_TILE, V_TILE + 17, 3 * V_TILE + 5] {
            let f = fixture(31, v);
            let (loss_ref, nv_ref, _, _, _) = reference(&f);
            for threads in [1usize, 2, 4] {
                let ex = Exec::new(threads);
                let mut lse = vec![0.0f32; f.t];
                let (loss, nv) = cce_loss_fwd(&f.hf, &f.w, &f.targets, f.t, f.d, f.v, &mut lse, &ex);
                assert_eq!(nv, nv_ref);
                assert!(
                    (loss - loss_ref).abs() < 1e-4 * (1.0 + loss_ref.abs()),
                    "v={v} threads={threads}: {loss} vs {loss_ref}"
                );
            }
        }
    }

    #[test]
    fn lse_matches_direct_computation() {
        let f = fixture(32, V_TILE + 9);
        let mut logits = vec![0.0f32; f.t * f.v];
        math::linear_fwd(&f.hf, &f.w, f.t, f.d, f.v, &mut logits);
        let ex = Exec::new(2);
        let mut lse = vec![0.0f32; f.t];
        cce_loss_fwd(&f.hf, &f.w, &f.targets, f.t, f.d, f.v, &mut lse, &ex);
        for ti in 0..f.t {
            if f.targets[ti] < 0 {
                continue;
            }
            let row = &logits[ti * f.v..(ti + 1) * f.v];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let want = row.iter().map(|z| (z - m).exp()).sum::<f32>().ln() + m;
            assert!((lse[ti] - want).abs() < 1e-4, "row {ti}: {} vs {want}", lse[ti]);
        }
    }

    #[test]
    fn fused_backward_matches_reference_grads() {
        let f = fixture(33, 2 * V_TILE + 13);
        let (_, n_valid, _, dw_ref, dhf_ref) = reference(&f);
        let ex1 = Exec::new(1);
        let mut lse = vec![0.0f32; f.t];
        cce_loss_fwd(&f.hf, &f.w, &f.targets, f.t, f.d, f.v, &mut lse, &ex1);
        for threads in [1usize, 3] {
            let ex = Exec::new(threads);
            let mut dw = vec![0.0f32; f.v * f.d];
            let mut dhf = vec![0.0f32; f.t * f.d];
            cce_bwd_fused(
                &f.hf, &f.w, &f.targets, &lse, f.t, f.d, f.v, n_valid,
                Some(&mut dw), &mut dhf, &ex,
            );
            for (i, (a, b)) in dw.iter().zip(&dw_ref).enumerate() {
                assert!((a - b).abs() < 1e-5, "threads={threads} dw[{i}]: {a} vs {b}");
            }
            for (i, (a, b)) in dhf.iter().zip(&dhf_ref).enumerate() {
                assert!((a - b).abs() < 1e-5, "threads={threads} dhf[{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn frozen_head_skips_weight_grad_but_fills_dhf() {
        let f = fixture(34, V_TILE + 3);
        let (_, n_valid, _, _, dhf_ref) = reference(&f);
        let ex = Exec::new(2);
        let mut lse = vec![0.0f32; f.t];
        cce_loss_fwd(&f.hf, &f.w, &f.targets, f.t, f.d, f.v, &mut lse, &ex);
        let mut dhf = vec![0.0f32; f.t * f.d];
        cce_bwd_fused(&f.hf, &f.w, &f.targets, &lse, f.t, f.d, f.v, n_valid, None, &mut dhf, &ex);
        for (i, (a, b)) in dhf.iter().zip(&dhf_ref).enumerate() {
            assert!((a - b).abs() < 1e-5, "dhf[{i}]: {a} vs {b}");
        }
    }

    #[test]
    fn bits_invariant_to_thread_count() {
        let f = fixture(35, 3 * V_TILE);
        let run = |threads: usize| -> (u32, Vec<u32>, Vec<u32>) {
            let ex = Exec::new(threads);
            let mut lse = vec![0.0f32; f.t];
            let (loss, nv) = cce_loss_fwd(&f.hf, &f.w, &f.targets, f.t, f.d, f.v, &mut lse, &ex);
            let mut dw = vec![0.0f32; f.v * f.d];
            let mut dhf = vec![0.0f32; f.t * f.d];
            cce_bwd_fused(&f.hf, &f.w, &f.targets, &lse, f.t, f.d, f.v, nv, Some(&mut dw), &mut dhf, &ex);
            (
                loss.to_bits(),
                dw.iter().map(|x| x.to_bits()).collect(),
                dhf.iter().map(|x| x.to_bits()).collect(),
            )
        };
        let a = run(1);
        for threads in [2usize, 4, 7] {
            assert_eq!(run(threads), a, "threads={threads} changed bits");
        }
    }
}
