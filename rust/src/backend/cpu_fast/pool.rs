//! Persistent worker pool and the execution substrate for the fast CPU
//! backend (DESIGN.md §4.3).
//!
//! PR 2 dispatched every kernel through `std::thread::scope`, which spawns
//! and joins fresh OS threads on every call — acceptable at large
//! geometries, but at small ones (short sequences, LoRA-rank projections,
//! the `[T]`-row norm passes) the spawn/join cost dominates the arithmetic.
//! That per-op dispatch overhead is the CPU analogue of the kernel-launch
//! overhead the paper's fusion work removes. [`WorkerPool`] eliminates it:
//!
//! * **Spawn once.** `threads − 1` workers are created with the backend
//!   and live until it is dropped. The dispatching thread is the remaining
//!   compute lane: inside [`WorkerPool::scope`] it runs queued jobs too,
//!   so `threads` lanes exist with only `threads − 1` OS threads.
//! * **Park between dispatches.** Idle workers block on a condvar — no
//!   spinning between kernels or between train steps.
//! * **Dispatch.** [`Scope::spawn`] mirrors the `std::thread::scope` API,
//!   so the kernels keep their disjoint-`chunks_mut` row-tile structure
//!   unchanged: each job is one output tile cut by `rows_per_tile`, every
//!   output element is written by exactly one job running the same
//!   sequential inner loop, and *which* worker runs a tile can never
//!   affect the bits.
//! * **Join on drop.** Dropping the pool signals shutdown and joins every
//!   worker. A panic inside a job is caught, recorded, and re-raised on
//!   the dispatching thread — after the scope has fully drained, so no
//!   worker can still hold a borrow into the caller's tiles.
//!
//! [`Exec`] bundles the pool with the resolved thread count and the
//! size-bucketed scratch [`Arena`] — the one execution handle the kernels
//! take in place of a bare `threads: usize`. `threads = 1` builds a pool
//! with zero workers and every kernel takes its serial path, so the
//! single-threaded contract ("never spawns, never touches the pool")
//! holds by construction.

use super::scratch::Arena;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

/// A queued unit of work: one output tile of one kernel call.
type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: VecDeque<Job>,
    /// Jobs queued or still running in the currently open scope.
    pending: usize,
    /// First panic payload raised by a job of the open scope.
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Wakes parked workers: a job was queued or shutdown was requested.
    work_cv: Condvar,
    /// Wakes the scope owner: `pending` may have reached zero.
    done_cv: Condvar,
}

impl Shared {
    /// Run one job and account for its completion.
    fn run_job(&self, job: Job) {
        let result = catch_unwind(AssertUnwindSafe(job));
        let mut st = self.state.lock().unwrap();
        if let Err(payload) = result {
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        st.pending -= 1;
        if st.pending == 0 {
            self.done_cv.notify_all();
        }
    }

    /// Caller-participating drain: run queued jobs on this thread until the
    /// queue is empty, then park until in-flight jobs finish.
    fn drain(&self) {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.queue.pop_front() {
                drop(st);
                self.run_job(job);
                st = self.state.lock().unwrap();
                continue;
            }
            if st.pending == 0 {
                return;
            }
            st = self.done_cv.wait(st).unwrap();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = st.queue.pop_front() {
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        shared.run_job(job);
    }
}

/// Drains the open scope even if the scope body unwinds, so spawned jobs
/// can never outlive the borrows they capture.
struct DrainGuard<'a>(&'a Shared);

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        self.0.drain();
    }
}

/// A pool of parked worker threads with a `std::thread::scope`-shaped
/// dispatch API. See the module docs for the lifecycle contract.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` long-lived worker threads (zero is valid: `scope`
    /// then runs every job on the dispatching thread).
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                pending: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("chronicals-worker-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawning fast-backend worker thread")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Number of parked worker threads (compute lanes minus the caller).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Open a dispatch scope: `f` queues jobs via [`Scope::spawn`]; this
    /// call returns only after every queued job has run to completion (the
    /// calling thread participates in running them). If a job panicked,
    /// the first panic is re-raised here after the drain.
    ///
    /// The fast backend opens at most one scope at a time (kernels never
    /// nest dispatches); concurrent scopes would be safe — each waits for
    /// a fully empty pool — just imprecise about whose jobs they wait on.
    pub fn scope<'env, F>(&self, f: F)
    where
        F: FnOnce(&Scope<'_, 'env>),
    {
        let scope = Scope { pool: self, _env: PhantomData };
        // discard any orphaned payload from a scope whose *body* (not a
        // job) unwound before it could re-raise, so it cannot surface here
        self.shared.state.lock().unwrap().panic = None;
        {
            let _guard = DrainGuard(&self.shared);
            f(&scope);
            // guard drops here: drain runs on the normal path and on unwind
        }
        let payload = self.shared.state.lock().unwrap().panic.take();
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Dispatch handle passed to the closure of [`WorkerPool::scope`].
///
/// `'env` is invariant and pinned to the scope call, exactly like
/// `std::thread::Scope`: jobs may borrow anything that outlives the
/// `scope()` call, because `scope()` cannot return before they finish.
pub struct Scope<'pool, 'env> {
    pool: &'pool WorkerPool,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'pool, 'env> Scope<'pool, 'env> {
    /// Queue one job on the pool (runs on a parked worker or on the
    /// dispatching thread during the drain — whichever is free first).
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(f);
        // SAFETY: `scope()` does not return (even on unwind — DrainGuard)
        // until `pending` reaches zero, i.e. until this job has run to
        // completion, so every `'env` borrow it captures strictly outlives
        // it. Erasing the lifetime to `'static` is the same argument
        // `std::thread::scope` makes for its spawned closures.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Box<dyn FnOnce() + Send + 'static>>(
                job,
            )
        };
        let shared = &self.pool.shared;
        {
            let mut st = shared.state.lock().unwrap();
            st.pending += 1;
            st.queue.push_back(job);
        }
        shared.work_cv.notify_one();
    }
}

/// The execution substrate of one `FastCpuBackend`: resolved thread count,
/// persistent worker pool, and the step-scoped scratch arena. Kernels take
/// `&Exec` instead of a bare thread count so dispatch and scratch leasing
/// share one lifecycle (spawned/warmed once per backend, dropped with it).
pub struct Exec {
    threads: usize,
    pool: WorkerPool,
    arena: Arena,
}

impl Exec {
    /// Build a substrate with `threads` compute lanes (`threads − 1`
    /// parked workers plus the dispatching thread). `threads = 1` spawns
    /// nothing and keeps every kernel on its serial path.
    pub fn new(threads: usize) -> Exec {
        let threads = threads.max(1);
        Exec { threads, pool: WorkerPool::new(threads - 1), arena: Arena::new() }
    }

    /// The compute-lane count kernels partition their output rows by.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The scratch arena working buffers are leased from.
    pub fn arena(&self) -> &Arena {
        &self.arena
    }

    /// Dispatch a batch of row-tile jobs on the persistent pool (see
    /// [`WorkerPool::scope`]).
    pub fn scope<'env, F>(&self, f: F)
    where
        F: FnOnce(&Scope<'_, 'env>),
    {
        self.pool.scope(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scope_runs_every_job_before_returning() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0usize; 64];
        pool.scope(|sc| {
            for (idx, chunk) in out.chunks_mut(16).enumerate() {
                sc.spawn(move || {
                    for (j, o) in chunk.iter_mut().enumerate() {
                        *o = idx * 16 + j;
                    }
                });
            }
        });
        // jobs finished inside scope(): the borrow is back and complete
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn zero_worker_pool_runs_jobs_on_the_caller() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 0);
        let hits = AtomicUsize::new(0);
        pool.scope(|sc| {
            for _ in 0..5 {
                let hits = &hits;
                sc.spawn(move || {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn pool_survives_many_dispatches() {
        // the point of the pool: thousands of scopes, zero new threads
        let pool = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        for _ in 0..500 {
            pool.scope(|sc| {
                for _ in 0..3 {
                    let total = &total;
                    sc.spawn(move || {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 1500);
    }

    #[test]
    fn job_panic_propagates_and_pool_stays_usable() {
        let pool = WorkerPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|sc| {
                sc.spawn(|| panic!("tile exploded"));
            });
        }));
        assert!(caught.is_err(), "scope must re-raise the job panic");
        // the scope drained before re-raising: the pool is clean and reusable
        let ran = AtomicUsize::new(0);
        pool.scope(|sc| {
            let ran = &ran;
            sc.spawn(move || {
                ran.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn exec_threads_clamp_to_at_least_one() {
        let ex = Exec::new(0);
        assert_eq!(ex.threads(), 1);
        assert_eq!(ex.pool.workers(), 0);
        let ex = Exec::new(4);
        assert_eq!(ex.threads(), 4);
        assert_eq!(ex.pool.workers(), 3);
    }
}
