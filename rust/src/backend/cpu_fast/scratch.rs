//! Step-scoped scratch arena + allocation accounting for the fast
//! backend's working buffers.
//!
//! Every f32 working buffer the fast train step uses — activations,
//! gradient accumulators, per-worker kernel scratch — is leased from the
//! backend's [`Arena`] via [`Arena::lease`]. The arena keeps returned
//! buffers on a size-bucketed free list, so the first train step pays the
//! heap allocations and every steady-state step after it performs **zero**
//! arena heap allocations (asserted by `rust/tests/no_materialization.rs`
//! via [`Arena::heap_allocs`]). Leases are RAII guards: dropping one
//! returns its buffer to the free list, capacity intact. Two lease
//! flavors split the zeroing cost: [`Arena::lease`] hands out zeroed
//! buffers for accumulators, [`Arena::lease_uninit`] skips the memset for
//! buffers whose every element is written before it is read.
//!
//! The accounting that *asserts* (rather than assumes) the
//! no-materialization claims survives the reuse: `lease(len)` records the
//! **logical** buffer size in a running largest-single-buffer peak even
//! when it hands back a recycled (possibly larger-capacity) buffer, so the
//! parity/no-materialization suites can still check that the peak stays
//! far below `B·Hq·S·S` (the attention probabilities the reference
//! materializes) and `T·V` (the full-logits buffer).
//!
//! Both counters are **arena-local** (one arena per backend instance), not
//! process-global as in PR 2 — accounting tests cannot race against other
//! tests that happen to drive a fast backend concurrently, which is what
//! made the old global counter flaky under `cargo test -q`.
//!
//! Determinism note: every lease is taken on the dispatching thread,
//! either between dispatches or — for per-tile kernel scratch — *before
//! any job of the dispatch is queued* (see `attention.rs`/`cce.rs`).
//! Workers return buffers in whatever order they finish, but no lease can
//! race a return within one dispatch, so the multiset of free buffers at
//! every lease point — and therefore the heap-allocation count and the
//! warm-arena zero-allocation property — never depends on worker
//! scheduling.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Size-bucketed free list of f32 buffers with peak/allocation accounting.
/// One arena lives in each `FastCpuBackend` (inside its `Exec` substrate).
pub struct Arena {
    free: Mutex<Vec<Vec<f32>>>,
    peak_elems: AtomicUsize,
    /// Sum of logical lengths of all currently outstanding leases.
    cur_leased: AtomicUsize,
    /// High-water mark of `cur_leased` — the concurrent-total peak that the
    /// activation-checkpointing pin compares across segment counts.
    peak_total: AtomicUsize,
    heap_allocs: AtomicUsize,
}

impl Default for Arena {
    fn default() -> Self {
        Arena::new()
    }
}

impl Arena {
    /// An empty (cold) arena: the first lease of each size allocates.
    pub fn new() -> Arena {
        Arena {
            free: Mutex::new(Vec::new()),
            peak_elems: AtomicUsize::new(0),
            cur_leased: AtomicUsize::new(0),
            peak_total: AtomicUsize::new(0),
            heap_allocs: AtomicUsize::new(0),
        }
    }

    /// Lease a zeroed f32 buffer of exactly `len` elements — the right
    /// call for accumulators (gradients, `dx` chains, attention `dq/dk/dv`)
    /// whose kernels `+=` into them.
    pub fn lease(&self, len: usize) -> Lease<'_> {
        let mut l = self.lease_uninit(len);
        l.fill(0.0);
        l
    }

    /// Lease an f32 buffer of exactly `len` elements *without* zeroing any
    /// recycled contents — for buffers every element of which is written
    /// before it is read (matmul/fused-kernel outputs, packed-KV tiles,
    /// logit strips). Skipping the memset matters: assign-style buffers
    /// dominate the forward pass, and lease-zeroing them is pure waste.
    ///
    /// Reuses the free buffer with the smallest sufficient capacity when
    /// one exists (exact fits win; best fit otherwise, so small requests
    /// do not squat on large buffers), allocating only on a cold miss.
    /// Always records `len` in the logical-size peak.
    pub fn lease_uninit(&self, len: usize) -> Lease<'_> {
        self.peak_elems.fetch_max(len, Ordering::Relaxed);
        let live = self.cur_leased.fetch_add(len, Ordering::Relaxed) + len;
        self.peak_total.fetch_max(live, Ordering::Relaxed);
        let mut buf = {
            let mut free = self.free.lock().unwrap();
            let mut best: Option<usize> = None;
            for (i, b) in free.iter().enumerate() {
                if b.capacity() < len {
                    continue;
                }
                match best {
                    Some(j) if free[j].capacity() <= b.capacity() => {}
                    _ => best = Some(i),
                }
                if b.capacity() == len {
                    break; // exact fit: stop scanning
                }
            }
            match best {
                Some(i) => free.swap_remove(i),
                None => Vec::new(),
            }
        };
        if buf.capacity() < len {
            self.heap_allocs.fetch_add(1, Ordering::Relaxed);
            buf = Vec::with_capacity(len);
        }
        // no clear-then-zero: keep recycled contents (stale values are
        // fine by this method's contract), only the growth region — and a
        // cold buffer — pays the fill that `resize` needs to set the length
        if buf.len() > len {
            buf.truncate(len);
        } else {
            buf.resize(len, 0.0);
        }
        Lease { buf: Some(buf), arena: self }
    }

    /// Return a buffer to the free list (called by `Lease::drop`).
    fn give_back(&self, buf: Vec<f32>) {
        // `lease_uninit` sets the Vec length to exactly the logical lease
        // length and the `[f32]` deref cannot change it, so `buf.len()` is
        // the amount to retire from the concurrent-total accounting
        self.cur_leased.fetch_sub(buf.len(), Ordering::Relaxed);
        if buf.capacity() == 0 {
            return;
        }
        self.free.lock().unwrap().push(buf);
    }

    /// Largest *logical* buffer (in f32 elements) leased since the last
    /// [`Arena::reset_peak`] — recorded even when the physical buffer was
    /// recycled, so no-materialization bounds hold on a warm arena.
    pub fn peak_elems(&self) -> usize {
        self.peak_elems.load(Ordering::SeqCst)
    }

    /// Reset both peaks (call before the step to measure). The
    /// concurrent-total peak restarts from the currently outstanding
    /// leases, not from zero, so a reset taken while buffers are live
    /// stays honest.
    pub fn reset_peak(&self) {
        self.peak_elems.store(0, Ordering::SeqCst);
        self.peak_total.store(self.cur_leased.load(Ordering::SeqCst), Ordering::SeqCst);
    }

    /// Peak *sum* of simultaneously outstanding lease lengths (f32
    /// elements) since the last [`Arena::reset_peak`] — the measure the
    /// `--ckpt-segments` pin compares: dropping interior activations must
    /// lower this, while [`Arena::peak_elems`] (largest single buffer)
    /// bounds any one materialization.
    pub fn peak_total_elems(&self) -> usize {
        self.peak_total.load(Ordering::SeqCst)
    }

    /// Sum of logical lengths of the leases currently outstanding.
    pub fn cur_leased_elems(&self) -> usize {
        self.cur_leased.load(Ordering::SeqCst)
    }

    /// Total heap allocations this arena has performed since construction
    /// (monotone). Steady-state train steps must not advance it.
    pub fn heap_allocs(&self) -> usize {
        self.heap_allocs.load(Ordering::SeqCst)
    }

    /// Number of buffers currently parked on the free list.
    pub fn free_buffers(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// RAII lease of an arena buffer. Dereferences to `[f32]`; dropping it
/// returns the buffer (capacity intact) to the arena's free list.
pub struct Lease<'a> {
    buf: Option<Vec<f32>>,
    arena: &'a Arena,
}

impl Lease<'_> {
    /// The leased buffer as a mutable slice (convenience for call sites
    /// that need an explicit `&mut [f32]`, e.g. `Option<&mut [f32]>`).
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        self.buf.as_mut().expect("lease buffer present").as_mut_slice()
    }
}

impl Deref for Lease<'_> {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.buf.as_ref().expect("lease buffer present")
    }
}

impl DerefMut for Lease<'_> {
    fn deref_mut(&mut self) -> &mut [f32] {
        self.buf.as_mut().expect("lease buffer present")
    }
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            self.arena.give_back(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_is_zeroed_and_tracks_logical_peak() {
        let arena = Arena::new();
        {
            let mut a = arena.lease(100);
            assert_eq!(a.len(), 100);
            assert!(a.iter().all(|&x| x == 0.0));
            a[0] = 7.0; // dirty it so reuse must re-zero
        }
        let b = arena.lease(10);
        assert_eq!(b.len(), 10);
        assert!(b.iter().all(|&x| x == 0.0), "recycled buffer must be re-zeroed");
        // peak records logical sizes: 100 from the first lease, not the
        // recycled capacity of the second
        assert_eq!(arena.peak_elems(), 100);
        arena.reset_peak();
        drop(b);
        let _c = arena.lease(10);
        assert_eq!(arena.peak_elems(), 10, "post-reset peak is the logical size");
    }

    #[test]
    fn lease_uninit_recycles_without_memset_but_lease_still_zeroes() {
        let arena = Arena::new();
        {
            let mut a = arena.lease_uninit(8);
            a.fill(3.0);
        }
        {
            // stale contents may (and here do) survive an uninit re-lease
            let b = arena.lease_uninit(8);
            assert_eq!(b.len(), 8);
            assert_eq!(arena.heap_allocs(), 1, "uninit re-lease must not allocate");
            assert!(b.iter().all(|&x| x == 3.0), "uninit lease should not memset");
        }
        // the zeroed flavor scrubs the same dirty buffer
        let c = arena.lease(8);
        assert!(c.iter().all(|&x| x == 0.0));
        assert_eq!(arena.heap_allocs(), 1);
        // growing within a fresh allocation still yields a fully-set length
        drop(c);
        let d = arena.lease_uninit(4096);
        assert_eq!(d.len(), 4096);
        assert_eq!(arena.heap_allocs(), 2);
    }

    #[test]
    fn warm_arena_leases_without_new_heap_allocations() {
        let arena = Arena::new();
        for _ in 0..3 {
            let _a = arena.lease(64);
            let _b = arena.lease(128);
        }
        // 2 live at once in round 1 ⇒ exactly 2 allocations ever
        assert_eq!(arena.heap_allocs(), 2);
        assert_eq!(arena.free_buffers(), 2);
    }

    #[test]
    fn best_fit_prefers_small_buffers_for_small_requests() {
        let arena = Arena::new();
        drop(arena.lease(1000));
        drop(arena.lease(8));
        let small = arena.lease(8);
        assert!(small.buf.as_ref().unwrap().capacity() < 1000, "small request took the big buffer");
        let big = arena.lease(1000); // big buffer still available: no alloc
        assert_eq!(big.len(), 1000);
        assert_eq!(arena.heap_allocs(), 2);
    }

    #[test]
    fn concurrent_total_peak_tracks_sum_of_live_leases() {
        let arena = Arena::new();
        let a = arena.lease(100);
        let b = arena.lease(50);
        assert_eq!(arena.cur_leased_elems(), 150);
        assert_eq!(arena.peak_total_elems(), 150);
        drop(a);
        // a third lease while b is live: peak stays at the true high water
        let c = arena.lease_uninit(20);
        assert_eq!(arena.cur_leased_elems(), 70);
        assert_eq!(arena.peak_total_elems(), 150);
        drop(b);
        drop(c);
        assert_eq!(arena.cur_leased_elems(), 0);
        arena.reset_peak();
        assert_eq!(arena.peak_total_elems(), 0);
        let _d = arena.lease(10);
        assert_eq!(arena.peak_total_elems(), 10, "post-reset peak restarts from live leases");
    }

    #[test]
    fn reset_peak_with_live_leases_restarts_from_outstanding_total() {
        let arena = Arena::new();
        let a = arena.lease(64);
        drop(arena.lease(512)); // spike, then gone
        assert_eq!(arena.peak_total_elems(), 576);
        arena.reset_peak();
        assert_eq!(arena.peak_total_elems(), 64, "live lease still counts after reset");
        drop(a);
    }

    #[test]
    fn cold_miss_allocates_even_when_smaller_buffers_are_free() {
        let arena = Arena::new();
        drop(arena.lease(16));
        let before = arena.heap_allocs();
        let big = arena.lease(4096);
        assert_eq!(big.len(), 4096);
        assert_eq!(arena.heap_allocs(), before + 1);
    }
}
