//! Allocation accounting for the fast backend's working buffers.
//!
//! Every f32 buffer the fast train step allocates — activations, gradient
//! accumulators, kernel scratch — goes through [`alloc_f32`], which records
//! the largest single allocation seen since the last [`reset_peak`]. This is
//! how the no-materialization claim is *asserted* rather than assumed: the
//! parity suite resets the counter, runs a full train step, and checks that
//! the peak single allocation is far below both `B·Hq·S·S` (the attention
//! probability tensor the reference backend materializes) and `T·V` (the
//! full-logits softmax buffer) — see `rust/tests/parity.rs`.
//!
//! The counter is a process-global atomic so worker threads spawned inside
//! kernels are counted too; `fetch_max` keeps it lock-free.

use std::sync::atomic::{AtomicUsize, Ordering};

static PEAK_ALLOC_ELEMS: AtomicUsize = AtomicUsize::new(0);

/// Record an allocation of `len` f32 elements (kept as the running peak of
/// the largest *single* allocation).
pub fn track(len: usize) {
    PEAK_ALLOC_ELEMS.fetch_max(len, Ordering::Relaxed);
}

/// Allocate a zeroed f32 buffer, recording its size.
pub fn alloc_f32(len: usize) -> Vec<f32> {
    track(len);
    vec![0.0; len]
}

/// Reset the peak counter (call before the step you want to measure).
pub fn reset_peak() {
    PEAK_ALLOC_ELEMS.store(0, Ordering::SeqCst);
}

/// Largest single f32 allocation (in elements) since the last reset.
pub fn peak_elems() -> usize {
    PEAK_ALLOC_ELEMS.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The counter is process-global and other lib tests allocate through
    /// it concurrently, so only race-proof (monotone ≥) properties are
    /// asserted here; the exact largest-single-allocation semantics are
    /// exercised in isolation by `rust/tests/no_materialization.rs`
    /// (integration-test files get their own process).
    #[test]
    fn peak_is_monotone_over_single_allocations() {
        reset_peak();
        let a = alloc_f32(10);
        let b = alloc_f32(100);
        let c = alloc_f32(50);
        assert_eq!(a.len() + b.len() + c.len(), 160);
        assert!(peak_elems() >= 100, "peak {} lost the largest alloc", peak_elems());
        track(7); // smaller than the peak: must not lower it
        assert!(peak_elems() >= 100);
    }
}
