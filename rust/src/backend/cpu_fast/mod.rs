//! The fast CPU backend (DESIGN.md §4.3): same contract and state layout
//! as the reference backend, built for throughput.
//!
//! `FastCpuBackend` registers the same executable families over the same
//! synthesized manifest as [`super::cpu::CpuBackend`] (profile
//! `"cpu-fast"`), shares `CpuState` — so checkpoints, init and the family
//! guards are identical — and swaps the execution for:
//!
//! * a persistent worker pool + step-scoped scratch arena (`pool.rs`,
//!   `scratch.rs`): workers spawn once per backend and park between
//!   dispatches, and working buffers are leased from a size-bucketed free
//!   list — zero arena heap allocations in steady-state train steps,
//! * cache-blocked, pooled matmuls with 8-lane SIMD-width inner loops
//!   (`kernels.rs`) and fused RMSNorm→linear / SwiGLU epilogues,
//! * flash-style tiled attention with online softmax and packed-KV tiles
//!   (`attention.rs`),
//! * streaming Cut Cross-Entropy (`cce.rs`).
//!
//! Thread count comes from [`crate::config::resolve_threads`]:
//! `CHRONICALS_THREADS` env > configured value > `available_parallelism`.
//! `threads = 1` runs fully single-threaded (the pool holds zero workers
//! and every kernel takes its serial path). The reference backend stays
//! the bitwise-deterministic oracle; this backend is validated against it
//! by the parity suite (`rust/tests/parity.rs`) under the tolerance policy
//! of DESIGN.md §4.3.

pub mod attention;
pub mod cce;
pub mod kernels;
pub mod model;
pub mod pool;
pub mod scratch;

pub use pool::Exec;

use super::cpu::{
    self, as_cpu_state, as_cpu_state_mut, batch_view, check_geometry, family_lora, reference_dims,
    REF_BATCH, REF_SEQ,
};
use super::{
    AdapterState, Backend, DeviceBatch, DeviceState, FusedOutputs, FusedSlice, MemoryCfg, RowGrad,
    StepOutputs,
};
use crate::backend::cpu::model::ModelDims;
use crate::batching::Batch;
use crate::manifest::{ExecutableSpec, Manifest};
use crate::quant::{OptimSnapshot, OptimStates};
use crate::runtime::HostTensor;
use anyhow::{bail, Result};

pub struct FastCpuBackend {
    manifest: Manifest,
    exec: Exec,
}

impl Default for FastCpuBackend {
    fn default() -> Self {
        FastCpuBackend::new()
    }
}

impl FastCpuBackend {
    /// Reference geometry, thread count resolved from env/auto.
    pub fn new() -> FastCpuBackend {
        FastCpuBackend::with_threads(0)
    }

    /// `threads = 0` means resolve (env override, then autodetect).
    pub fn with_threads(threads: usize) -> FastCpuBackend {
        FastCpuBackend::custom(reference_dims(), REF_BATCH, REF_SEQ, threads)
    }

    /// Custom batch geometry at reference model dims (benches, tests).
    pub fn with_geometry(batch: usize, seq: usize) -> FastCpuBackend {
        FastCpuBackend::custom(reference_dims(), batch, seq, 0)
    }

    /// Fully custom substrate (model dims, geometry, threads).
    pub fn custom(dims: ModelDims, batch: usize, seq: usize, threads: usize) -> FastCpuBackend {
        FastCpuBackend {
            manifest: cpu::synth_manifest(dims, batch, seq, "cpu-fast"),
            exec: Exec::new(crate::config::resolve_threads(threads)),
        }
    }

    /// The resolved worker-thread count this backend runs with.
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// The execution substrate (persistent pool + scratch arena). Exposed
    /// for the accounting tests (`rust/tests/no_materialization.rs`) and
    /// the dispatch benches.
    pub fn exec(&self) -> &Exec {
        &self.exec
    }

    fn spec(&self, name: &str) -> Result<&ExecutableSpec> {
        self.manifest.get(name)
    }
}

impl Backend for FastCpuBackend {
    fn name(&self) -> &'static str {
        "cpu-fast"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn init_state(&self, init_name: &str, seed: i32) -> Result<DeviceState> {
        let spec = self.spec(init_name)?;
        if spec.kind != "init" {
            bail!("'{init_name}' is not an init executable (kind = {})", spec.kind);
        }
        let lora = family_lora(&spec.family);
        // identical init to the reference backend: same seed ⇒ same params,
        // which is what makes cross-backend parity runs line up exactly
        Ok(DeviceState::Cpu(cpu::model::init_state(cpu::spec_dims(spec), lora, seed)))
    }

    fn upload_batch(&self, train_name: &str, batch: &Batch) -> Result<DeviceBatch> {
        let spec = self.spec(train_name)?;
        check_geometry(spec, batch)?;
        batch_view(batch)?;
        Ok(DeviceBatch::Cpu(batch.clone()))
    }

    fn train_step(
        &self,
        train_name: &str,
        state: &mut DeviceState,
        batch: &DeviceBatch,
        step: u64,
        lr: f32,
        lr_b: f32,
    ) -> Result<StepOutputs> {
        let spec = self.spec(train_name)?;
        if spec.kind != "train" {
            bail!("'{train_name}' is not a train executable (kind = {})", spec.kind);
        }
        let broken = spec.step_config.broken;
        let expect_lora = family_lora(&spec.family);
        let s = as_cpu_state_mut(state)?;
        if s.lora != expect_lora {
            bail!(
                "state family mismatch: executable '{train_name}' expects lora={:?}, state has {:?}",
                expect_lora,
                s.lora
            );
        }
        let b = match batch {
            DeviceBatch::Cpu(b) => b,
            #[cfg(feature = "pjrt")]
            _ => bail!("batch was uploaded to a different backend"),
        };
        check_geometry(spec, b)?;
        let view = batch_view(b)?;
        let out = model::train_step(s, &view, broken, step, lr, lr_b, &self.exec)?;
        Ok(StepOutputs {
            loss: out.loss,
            grad_norm: out.grad_norm,
            n_tokens: out.n_tokens,
            phases: out.phases,
        })
    }

    fn init_adapter(&self, train_name: &str, seed: i32) -> Result<AdapterState> {
        // identical adapter init to the reference backend: same seed ⇒ same
        // tensors, so fused serve rounds line up across CPU backends
        cpu::cpu_init_adapter(self.spec(train_name)?, seed)
    }

    fn swap_adapter(&self, state: &mut DeviceState, adapter: &mut AdapterState) -> Result<()> {
        cpu::cpu_swap_adapter(state, adapter)
    }

    fn adapter_params(&self, adapter: &AdapterState) -> Result<Vec<HostTensor>> {
        cpu::cpu_adapter_params(adapter)
    }

    fn supports_fused_step(&self) -> bool {
        true
    }

    fn fused_step(
        &self,
        train_name: &str,
        state: &DeviceState,
        adapters: &mut [AdapterState],
        batch: &Batch,
        slices: &[FusedSlice],
    ) -> Result<FusedOutputs> {
        let spec = self.spec(train_name)?;
        cpu::check_fused_batch(spec, batch, slices)?;
        let s = as_cpu_state(state)?;
        if s.lora != family_lora(&spec.family) {
            bail!(
                "state family mismatch: executable '{train_name}' expects lora={:?}, state has {:?}",
                family_lora(&spec.family),
                s.lora
            );
        }
        let view = batch_view(batch)?;
        let mut ads = cpu::cpu_adapters_mut(adapters);
        let (outs, phases) = model::fused_train_step(s, &mut ads, &view, slices, &self.exec)?;
        Ok(FusedOutputs {
            tenants: outs
                .into_iter()
                .map(|o| StepOutputs {
                    loss: o.loss,
                    grad_norm: o.grad_norm,
                    n_tokens: o.n_tokens,
                    phases: o.phases,
                })
                .collect(),
            phases,
        })
    }

    fn flat_grad_len(&self, state: &DeviceState) -> Result<usize> {
        Ok(cpu::model::flat_grad_len(as_cpu_state(state)?))
    }

    fn grad_row(
        &self,
        train_name: &str,
        state: &DeviceState,
        batch: &DeviceBatch,
        row: usize,
        global_n_valid: usize,
        out: &mut [f32],
    ) -> Result<RowGrad> {
        let spec = self.spec(train_name)?;
        let s = as_cpu_state(state)?;
        let b = cpu::check_shard_call(spec, family_lora(&spec.family), s.lora, batch)?;
        let view = cpu::row_view(b, row)?;
        let (loss_sum, fwd_s, bwd_s) =
            model::grad_row_into(s, &view, global_n_valid, out, &self.exec)?;
        Ok(RowGrad { loss_sum, fwd_s, bwd_s })
    }

    fn apply_grads(
        &self,
        train_name: &str,
        state: &mut DeviceState,
        flat: &[f32],
        step: u64,
        lr: f32,
        lr_b: f32,
    ) -> Result<()> {
        let spec = self.spec(train_name)?;
        if spec.kind != "train" {
            bail!("'{train_name}' is not a train executable (kind = {})", spec.kind);
        }
        model::apply_flat_grads(as_cpu_state_mut(state)?, flat, step, lr, lr_b, &self.exec)
    }

    fn eval_loss(&self, eval_name: &str, state: &DeviceState, batch: &Batch) -> Result<f32> {
        let spec = self.spec(eval_name)?;
        if spec.kind != "eval" && spec.kind != "train" {
            bail!("'{eval_name}' cannot evaluate (kind = {})", spec.kind);
        }
        check_geometry(spec, batch)?;
        let expect_lora = family_lora(&spec.family);
        let s = as_cpu_state(state)?;
        if s.lora != expect_lora {
            bail!(
                "state family mismatch: executable '{eval_name}' expects lora={:?}, state has {:?}",
                expect_lora,
                s.lora
            );
        }
        let view = batch_view(batch)?;
        model::eval_loss(s, &view, &self.exec)
    }

    fn state_params(&self, state: &DeviceState) -> Result<Vec<HostTensor>> {
        cpu::cpu_state_params(as_cpu_state(state)?)
    }

    fn load_params(&self, state: &mut DeviceState, params: &[HostTensor]) -> Result<()> {
        cpu::load_cpu_params(as_cpu_state_mut(state)?, params)
    }

    fn configure_memory(&self, state: &mut DeviceState, cfg: &MemoryCfg) -> Result<()> {
        cpu::cpu_configure_memory(as_cpu_state_mut(state)?, cfg)
    }

    fn optim_snapshot(&self, state: &DeviceState) -> Result<OptimSnapshot> {
        Ok(cpu::model::optim_snapshot(as_cpu_state(state)?))
    }

    fn load_optim_snapshot(&self, state: &mut DeviceState, snap: &OptimSnapshot) -> Result<()> {
        cpu::model::load_optim_snapshot(as_cpu_state_mut(state)?, snap)
    }

    fn convert_adapter_optim(&self, adapter: &mut AdapterState, codec: OptimStates) -> Result<()> {
        cpu::cpu_convert_adapter_optim(adapter, codec)
    }

    /// Table-5-style kernel microbench: `*_fused`/`*_flash` names time this
    /// backend's kernels, `*_naive` names time the reference scalar
    /// implementations — on identical deterministic inputs at a bench
    /// geometry large enough for tiling and threading to matter. The
    /// `dispatch_matmul_{pool,spawn,single}` names time a small-geometry
    /// matmul (where dispatch overhead dominates) through the persistent
    /// pool, a scoped-spawn baseline, and the serial path respectively.
    fn bench_kernel(&self, name: &str, reps: usize, warmup: usize) -> Result<f64> {
        bench::run(name, reps, warmup, &self.exec)
    }
}

/// Kernel microbench implementations (fused-vs-naive pairs, paper Table 5,
/// plus the pool-vs-spawn dispatch comparison).
mod bench {
    use super::super::cpu::math;
    use super::super::cpu::model as refmodel;
    use super::{attention, cce, kernels, Exec};
    use crate::backend::cpu::model::BatchView;
    use crate::util::rng::Rng;
    use anyhow::{bail, Result};
    use std::hint::black_box;
    use std::time::Instant;

    // bench substrate: big enough that a [B, Hq, S, S] / [T, V] buffer is
    // meaningfully larger than the tiled working set
    const B: usize = 4;
    const S: usize = 128;
    const T: usize = B * S;
    const D: usize = 64;
    const HEADS: usize = 8;
    const KV_HEADS: usize = 4;
    const HD: usize = D / HEADS;
    const DKV: usize = KV_HEADS * HD;
    const F: usize = 128;
    const V: usize = 512;
    const R: usize = 8;

    // dispatch bench substrate: deliberately small (T ≤ 64) so per-call
    // dispatch overhead — not arithmetic — dominates the timing
    const DISPATCH_T: usize = 32;
    const DISPATCH_K: usize = 64;
    const DISPATCH_N: usize = 64;

    fn randv(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    /// One packed-style segment layout: each row is a single full segment.
    fn seg_pos() -> (Vec<i32>, Vec<i32>) {
        let mut seg = vec![0i32; T];
        let mut pos = vec![0i32; T];
        for b in 0..B {
            for i in 0..S {
                seg[b * S + i] = 1;
                pos[b * S + i] = i as i32;
            }
        }
        (seg, pos)
    }

    fn time(reps: usize, warmup: usize, mut f: impl FnMut()) -> f64 {
        for _ in 0..warmup {
            f();
        }
        let t0 = Instant::now();
        for _ in 0..reps.max(1) {
            f();
        }
        t0.elapsed().as_secs_f64() / reps.max(1) as f64
    }

    /// The PR 2 dispatch baseline: identical tiling and inner loop to
    /// `kernels::matmul`, but spawning fresh scoped threads per call.
    /// Kept only as the bench reference the pooled dispatch is measured
    /// against (`dispatch_matmul_spawn`).
    fn matmul_scoped_spawn(
        x: &[f32],
        w: &[f32],
        t: usize,
        k_in: usize,
        n_out: usize,
        out: &mut [f32],
        threads: usize,
    ) {
        let body = |r0: usize, out_c: &mut [f32]| {
            let rows = out_c.len() / n_out;
            for r in 0..rows {
                let xr = &x[(r0 + r) * k_in..(r0 + r + 1) * k_in];
                let or = &mut out_c[r * n_out..(r + 1) * n_out];
                for (n, o) in or.iter_mut().enumerate() {
                    *o = kernels::dot8(xr, &w[n * k_in..(n + 1) * k_in]);
                }
            }
        };
        let rp = kernels::rows_per_tile(t, threads);
        if threads <= 1 || t <= 1 {
            body(0, out);
            return;
        }
        std::thread::scope(|sc| {
            let body = &body;
            for (idx, out_c) in out.chunks_mut(rp * n_out).enumerate() {
                sc.spawn(move || body(idx * rp, out_c));
            }
        });
    }

    pub fn run(name: &str, reps: usize, warmup: usize, ex: &Exec) -> Result<f64> {
        let mut rng = Rng::new(0xC0FFEE);
        let secs = match name {
            "kernel_rmsnorm_fused" | "kernel_rmsnorm_naive" => {
                let x = randv(&mut rng, T * D, 0.5);
                let gamma = randv(&mut rng, D, 0.2);
                let wq = randv(&mut rng, D * D, 0.1);
                let wk = randv(&mut rng, DKV * D, 0.1);
                let wv = randv(&mut rng, DKV * D, 0.1);
                let (mut h, mut rstd) = (vec![0.0f32; T * D], vec![0.0f32; T]);
                let mut q = vec![0.0f32; T * D];
                let mut k = vec![0.0f32; T * DKV];
                let mut v = vec![0.0f32; T * DKV];
                if name.ends_with("fused") {
                    time(reps, warmup, || {
                        kernels::fused_rmsnorm_qkv(
                            &x, &gamma, &wq, &wk, &wv, T, D, DKV, &mut h, &mut rstd, &mut q,
                            &mut k, &mut v, ex,
                        );
                        black_box(&q);
                    })
                } else {
                    time(reps, warmup, || {
                        math::rmsnorm_fwd(&x, &gamma, T, D, &mut h, &mut rstd);
                        math::linear_fwd(&h, &wq, T, D, D, &mut q);
                        math::linear_fwd(&h, &wk, T, D, DKV, &mut k);
                        math::linear_fwd(&h, &wv, T, D, DKV, &mut v);
                        black_box(&q);
                    })
                }
            }
            "kernel_swiglu_fused" | "kernel_swiglu_naive" => {
                let x = randv(&mut rng, T * D, 0.5);
                let gamma = randv(&mut rng, D, 0.2);
                let wg = randv(&mut rng, F * D, 0.1);
                let wu = randv(&mut rng, F * D, 0.1);
                let (mut h, mut rstd) = (vec![0.0f32; T * D], vec![0.0f32; T]);
                let mut gate = vec![0.0f32; T * F];
                let mut up = vec![0.0f32; T * F];
                let mut y = vec![0.0f32; T * F];
                if name.ends_with("fused") {
                    time(reps, warmup, || {
                        kernels::fused_rmsnorm_swiglu(
                            &x, &gamma, &wg, &wu, T, D, F, &mut h, &mut rstd, &mut gate, &mut up,
                            &mut y, ex,
                        );
                        black_box(&y);
                    })
                } else {
                    time(reps, warmup, || {
                        math::rmsnorm_fwd(&x, &gamma, T, D, &mut h, &mut rstd);
                        math::linear_fwd(&h, &wg, T, D, F, &mut gate);
                        math::linear_fwd(&h, &wu, T, D, F, &mut up);
                        math::swiglu_fwd(&gate, &up, &mut y);
                        black_box(&y);
                    })
                }
            }
            "kernel_rope_fused" | "kernel_rope_naive" => {
                let mut x = randv(&mut rng, T * HEADS * HD, 0.5);
                let (_, pos) = seg_pos();
                if name.ends_with("fused") {
                    time(reps, warmup, || {
                        kernels::rope(&mut x, &pos, T, HEADS, HD, 1.0, ex);
                        black_box(&x);
                    })
                } else {
                    time(reps, warmup, || {
                        math::rope_apply(&mut x, &pos, T, HEADS, HD, 1.0);
                        black_box(&x);
                    })
                }
            }
            "kernel_attention_flash" | "kernel_attention_naive" => {
                let q = randv(&mut rng, T * HEADS * HD, 0.3);
                let k = randv(&mut rng, T * DKV, 0.3);
                let v = randv(&mut rng, T * DKV, 0.3);
                let (seg, pos) = seg_pos();
                let tokens = vec![0i32; T];
                let mut out = vec![0.0f32; T * HEADS * HD];
                if name.ends_with("flash") {
                    let mut lse = vec![0.0f32; B * HEADS * S];
                    time(reps, warmup, || {
                        attention::flash_attention_fwd(
                            &q, &k, &v, &seg, B, S, HEADS, KV_HEADS, HD, &mut out, &mut lse, ex,
                        );
                        black_box(&out);
                    })
                } else {
                    let mut probs = vec![0.0f32; B * HEADS * S * S];
                    let bv = BatchView {
                        tokens: &tokens,
                        targets: &tokens,
                        seg: &seg,
                        pos: &pos,
                        bsz: B,
                        seq: S,
                    };
                    time(reps, warmup, || {
                        refmodel::attention_fwd(
                            &q, &k, &v, &bv, HEADS, KV_HEADS, HD, &mut out, &mut probs,
                        );
                        black_box(&out);
                    })
                }
            }
            "kernel_cross_entropy_fused" | "kernel_cross_entropy_naive" => {
                let hf = randv(&mut rng, T * D, 0.5);
                let w = randv(&mut rng, V * D, 0.05);
                let targets: Vec<i32> = (0..T).map(|i| (i % V) as i32).collect();
                if name.ends_with("fused") {
                    let mut lse = vec![0.0f32; T];
                    time(reps, warmup, || {
                        let out = cce::cce_loss_fwd(&hf, &w, &targets, T, D, V, &mut lse, ex);
                        black_box(out);
                    })
                } else {
                    let mut logits = vec![0.0f32; T * V];
                    let mut probs = vec![0.0f32; T * V];
                    time(reps, warmup, || {
                        math::linear_fwd(&hf, &w, T, D, V, &mut logits);
                        let out = math::softmax_xent(&logits, &targets, T, V, &mut probs);
                        black_box(out);
                    })
                }
            }
            "kernel_adamw_fused" | "kernel_adamw_naive" => {
                let n = V * D;
                let g = randv(&mut rng, n, 0.01);
                let mut pbuf = randv(&mut rng, n, 0.1);
                let mut m = vec![0.0f32; n];
                let mut v = vec![0.0f32; n];
                if name.ends_with("fused") {
                    time(reps, warmup, || {
                        kernels::adamw(&mut pbuf, &g, &mut m, &mut v, 1e-4, 2.0, 0.01, ex);
                        black_box(&pbuf);
                    })
                } else {
                    time(reps, warmup, || {
                        math::adamw_update(&mut pbuf, &g, &mut m, &mut v, 1e-4, 2.0, 0.01);
                        black_box(&pbuf);
                    })
                }
            }
            "kernel_lora_linear_fused" | "kernel_lora_linear_naive" => {
                let x = randv(&mut rng, T * D, 0.5);
                let a = randv(&mut rng, R * D, 0.1);
                let b = randv(&mut rng, D * R, 0.1);
                let mut ha = vec![0.0f32; T * R];
                let mut out = vec![0.0f32; T * D];
                if name.ends_with("fused") {
                    time(reps, warmup, || {
                        kernels::lora_linear(&x, &a, &b, T, D, R, D, 0.5, &mut ha, &mut out, ex);
                        black_box(&out);
                    })
                } else {
                    let mut delta = vec![0.0f32; T * D];
                    time(reps, warmup, || {
                        math::linear_fwd(&x, &a, T, D, R, &mut ha);
                        math::linear_fwd(&ha, &b, T, R, D, &mut delta);
                        for (o, &dl) in out.iter_mut().zip(delta.iter()) {
                            *o += 0.5 * dl;
                        }
                        black_box(&out);
                    })
                }
            }
            "dispatch_matmul_pool" | "dispatch_matmul_spawn" | "dispatch_matmul_single" => {
                let (t, k_in, n_out) = (DISPATCH_T, DISPATCH_K, DISPATCH_N);
                let x = randv(&mut rng, t * k_in, 0.5);
                let w = randv(&mut rng, n_out * k_in, 0.1);
                let mut out = vec![0.0f32; t * n_out];
                match name {
                    "dispatch_matmul_pool" => time(reps, warmup, || {
                        kernels::matmul(&x, &w, t, k_in, n_out, &mut out, ex);
                        black_box(&out);
                    }),
                    "dispatch_matmul_spawn" => time(reps, warmup, || {
                        matmul_scoped_spawn(&x, &w, t, k_in, n_out, &mut out, ex.threads());
                        black_box(&out);
                    }),
                    _ => {
                        let serial = Exec::new(1);
                        time(reps, warmup, || {
                            kernels::matmul(&x, &w, t, k_in, n_out, &mut out, &serial);
                            black_box(&out);
                        })
                    }
                }
            }
            other => bail!("unknown kernel microbench '{other}' on the cpu-fast backend"),
        };
        Ok(secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_mirrors_reference_families_under_fast_profile() {
        let be = FastCpuBackend::with_threads(2);
        for name in [
            "train_step_chronicals",
            "train_step_lora",
            "train_step_lora_broken",
            "init_chronicals",
            "init_lora",
            "eval_chronicals",
        ] {
            assert!(be.manifest().get(name).is_ok(), "missing {name}");
        }
        assert_eq!(be.manifest().profile, "cpu-fast");
        assert_eq!(be.name(), "cpu-fast");
        assert_eq!(be.threads(), 2);
    }

    #[test]
    fn init_matches_reference_backend_bitwise() {
        let fast = FastCpuBackend::with_threads(1);
        let reference = cpu::CpuBackend::new();
        let a = fast.init_state("init_chronicals", 9).unwrap();
        let b = reference.init_state("init_chronicals", 9).unwrap();
        assert_eq!(fast.state_params(&a).unwrap(), reference.state_params(&b).unwrap());
    }

    #[test]
    fn bench_kernel_pairs_run() {
        let be = FastCpuBackend::with_threads(1);
        for name in ["kernel_rmsnorm_fused", "kernel_rmsnorm_naive"] {
            let secs = be.bench_kernel(name, 1, 0).unwrap();
            assert!(secs > 0.0, "{name}: {secs}");
        }
        assert!(be.bench_kernel("kernel_nope", 1, 0).is_err());
    }

    #[test]
    fn dispatch_bench_variants_run() {
        let be = FastCpuBackend::with_threads(2);
        for name in ["dispatch_matmul_pool", "dispatch_matmul_spawn", "dispatch_matmul_single"] {
            let secs = be.bench_kernel(name, 1, 0).unwrap();
            assert!(secs > 0.0, "{name}: {secs}");
        }
    }

    #[test]
    fn threads_zero_resolves_to_at_least_one() {
        let be = FastCpuBackend::new();
        assert!(be.threads() >= 1);
    }
}
