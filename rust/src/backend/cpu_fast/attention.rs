//! Flash-attention-style tiled attention with online softmax.
//!
//! The reference backend materializes the `[B, Hq, S, S]` attention
//! probability tensor and keeps it alive for backward. This module never
//! builds it (the paper's O(N²d²M⁻¹) IO argument):
//!
//! * **Forward** streams KV tiles per query row, maintaining the running
//!   maximum `m`, running denominator `l` and running output accumulator —
//!   textbook online softmax. It emits the attention output plus one
//!   logsumexp scalar per `(batch, head, row)` (`lse = m + ln l`,
//!   `O(B·Hq·S)` — linear in S, not quadratic).
//! * **Backward** recomputes each probability on the fly from the cached
//!   post-RoPE Q/K and the stored `lse`: `p_ij = exp(q_i·k_j·scale −
//!   lse_i)`, using the identity `Σ_j p_ij dp_ij = dout_i · out_i` so no
//!   per-row probability vector is needed either.
//!
//! **Packed-KV tile layout.** K and V rows live in the `[T, n_kv·hd]`
//! activations with stride `n_kv·hd` between consecutive tokens, so the
//! hot `j` loops would walk memory with a gap per step. Both passes
//! therefore pack the K and V rows of the current `(batch row, kv head)`
//! into contiguous `[S, hd]` arena buffers once, and every KV-tile scan —
//! `group · S` query rows' worth — streams them unit-stride. The packed
//! rows hold identical bits read in the identical order, so packing does
//! not change results.
//!
//! Segment masking matches the reference exactly: tokens attend causally
//! within their own non-zero segment; padding rows (seg 0) produce zero
//! output and receive zero gradient.
//!
//! Pooling is per batch row (disjoint `chunks_mut` of out/lse/dq/dk/dv
//! dispatched on the backend's persistent pool), so bits are invariant to
//! the thread count. Per-tile scratch (score strip, output accumulator,
//! packed K/V) is leased from the arena on the dispatching thread *before*
//! jobs are queued, so the arena's lease sequence — and its warm-arena
//! zero-allocation property — never depends on worker scheduling.

use super::kernels::{axpy, dot8, rows_per_tile};
use super::pool::Exec;

/// KV tile width for the forward streaming pass. Fixed (not derived from
/// the thread count) so results do not depend on parallelism.
pub const KV_TILE: usize = 64;

/// Online-softmax attention forward.
///
/// `q: [T, n_heads·hd]`, `k`/`v`: `[T, n_kv·hd]`, `seg: [T]` with 0 =
/// padding. Writes `out: [T, n_heads·hd]` (assigned) and
/// `lse: [bsz, n_heads, s]` (logsumexp per query row; `-inf` on padding
/// rows).
#[allow(clippy::too_many_arguments)]
pub fn flash_attention_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    seg: &[i32],
    bsz: usize,
    s: usize,
    n_heads: usize,
    n_kv: usize,
    hd: usize,
    out: &mut [f32],
    lse: &mut [f32],
    ex: &Exec,
) {
    let group = n_heads / n_kv;
    let dqw = n_heads * hd;
    let dkvw = n_kv * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    debug_assert_eq!(q.len(), bsz * s * dqw);
    debug_assert_eq!(k.len(), bsz * s * dkvw);
    debug_assert_eq!(out.len(), bsz * s * dqw);
    debug_assert_eq!(lse.len(), bsz * n_heads * s);

    let body = |b0: usize,
                out_c: &mut [f32],
                lse_c: &mut [f32],
                sc: &mut [f32],
                acc: &mut [f32],
                kp: &mut [f32],
                vp: &mut [f32]| {
        let n_b = lse_c.len() / (n_heads * s);
        for lb in 0..n_b {
            let b = b0 + lb;
            for kh in 0..n_kv {
                // pack this (row, kv head)'s K/V once; the j loops below
                // then stream unit-stride through [S, hd] rows
                for j in 0..s {
                    let tj = b * s + j;
                    kp[j * hd..(j + 1) * hd]
                        .copy_from_slice(&k[tj * dkvw + kh * hd..tj * dkvw + (kh + 1) * hd]);
                    vp[j * hd..(j + 1) * hd]
                        .copy_from_slice(&v[tj * dkvw + kh * hd..tj * dkvw + (kh + 1) * hd]);
                }
                for g in 0..group {
                    let h = kh * group + g; // same ascending-h order as before
                    for i in 0..s {
                        let ti = b * s + i;
                        let seg_i = seg[ti];
                        let lse_slot = &mut lse_c[(lb * n_heads + h) * s + i];
                        if seg_i == 0 {
                            // padding row: zero output explicitly so reused
                            // (dirty) buffers cannot leak stale activations
                            *lse_slot = f32::NEG_INFINITY;
                            let or = &mut out_c
                                [(lb * s + i) * dqw + h * hd..(lb * s + i) * dqw + (h + 1) * hd];
                            or.fill(0.0);
                            continue;
                        }
                        let qr = &q[ti * dqw + h * hd..ti * dqw + (h + 1) * hd];
                        let mut m = f32::NEG_INFINITY;
                        let mut l = 0.0f32;
                        for a in acc.iter_mut() {
                            *a = 0.0;
                        }
                        let mut j0 = 0usize;
                        while j0 <= i {
                            let j1 = (j0 + KV_TILE).min(i + 1);
                            let mut tm = f32::NEG_INFINITY;
                            for (jj, j) in (j0..j1).enumerate() {
                                if seg[b * s + j] != seg_i {
                                    sc[jj] = f32::NEG_INFINITY;
                                    continue;
                                }
                                let kr = &kp[j * hd..(j + 1) * hd];
                                let sv = dot8(qr, kr) * scale;
                                sc[jj] = sv;
                                tm = tm.max(sv);
                            }
                            if tm > f32::NEG_INFINITY {
                                let m_new = m.max(tm);
                                if m > f32::NEG_INFINITY {
                                    // correct previous statistics (exp(0) = 1
                                    // exactly, so the no-op case is bit-exact)
                                    let alpha = (m - m_new).exp();
                                    l *= alpha;
                                    for a in acc.iter_mut() {
                                        *a *= alpha;
                                    }
                                }
                                for (jj, j) in (j0..j1).enumerate() {
                                    if sc[jj] == f32::NEG_INFINITY {
                                        continue;
                                    }
                                    let e = (sc[jj] - m_new).exp();
                                    l += e;
                                    let vr = &vp[j * hd..(j + 1) * hd];
                                    axpy(e, vr, acc);
                                }
                                m = m_new;
                            }
                            j0 = j1;
                        }
                        let or = &mut out_c
                            [(lb * s + i) * dqw + h * hd..(lb * s + i) * dqw + (h + 1) * hd];
                        for (o, &a) in or.iter_mut().zip(acc.iter()) {
                            *o = a / l;
                        }
                        *lse_slot = m + l.ln();
                    }
                }
            }
        }
    };

    let bp = rows_per_tile(bsz, ex.threads());
    if ex.threads() <= 1 || bsz <= 1 {
        let (mut sc, mut acc) = (ex.arena().lease_uninit(KV_TILE), ex.arena().lease_uninit(hd));
        let (mut kp, mut vp) = (ex.arena().lease_uninit(s * hd), ex.arena().lease_uninit(s * hd));
        body(0, out, lse, &mut sc, &mut acc, &mut kp, &mut vp);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        // lease every tile's scratch before any job is queued: a job that
        // finishes early returns buffers mid-loop, which would otherwise
        // make the cold-step allocation count scheduling-dependent and
        // break the warm-arena zero-allocation guarantee
        let scratch: Vec<_> = (0..out.len().div_ceil(bp * s * dqw))
            .map(|_| {
                (
                    ex.arena().lease_uninit(KV_TILE),
                    ex.arena().lease_uninit(hd),
                    ex.arena().lease_uninit(s * hd),
                    ex.arena().lease_uninit(s * hd),
                )
            })
            .collect();
        let iter = out
            .chunks_mut(bp * s * dqw)
            .zip(lse.chunks_mut(bp * n_heads * s))
            .zip(scratch)
            .enumerate();
        for (idx, ((out_c, lse_c), (mut sc, mut acc, mut kp, mut vp))) in iter {
            scope.spawn(move || {
                body(idx * bp, out_c, lse_c, &mut sc, &mut acc, &mut kp, &mut vp)
            });
        }
    });
}

/// Flash attention backward: recomputes probabilities tile-free from Q/K
/// and the forward's `lse`, accumulating `dq`/`dk`/`dv`.
///
/// Uses `D_i = dout_i · out_i` (the softmax-Jacobian row sum), so the only
/// state carried from forward is `out` and `lse`.
#[allow(clippy::too_many_arguments)]
pub fn flash_attention_bwd(
    dout: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    out: &[f32],
    lse: &[f32],
    seg: &[i32],
    bsz: usize,
    s: usize,
    n_heads: usize,
    n_kv: usize,
    hd: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    ex: &Exec,
) {
    let group = n_heads / n_kv;
    let dqw = n_heads * hd;
    let dkvw = n_kv * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    debug_assert_eq!(lse.len(), bsz * n_heads * s);

    let body = |b0: usize,
                dq_c: &mut [f32],
                dk_c: &mut [f32],
                dv_c: &mut [f32],
                kp: &mut [f32],
                vp: &mut [f32]| {
        let n_b = dq_c.len() / (s * dqw);
        for lb in 0..n_b {
            let b = b0 + lb;
            for kh in 0..n_kv {
                for j in 0..s {
                    let tj = b * s + j;
                    kp[j * hd..(j + 1) * hd]
                        .copy_from_slice(&k[tj * dkvw + kh * hd..tj * dkvw + (kh + 1) * hd]);
                    vp[j * hd..(j + 1) * hd]
                        .copy_from_slice(&v[tj * dkvw + kh * hd..tj * dkvw + (kh + 1) * hd]);
                }
                for g in 0..group {
                    let h = kh * group + g; // same ascending-h order as before
                    for i in 0..s {
                        let ti = b * s + i;
                        let seg_i = seg[ti];
                        if seg_i == 0 {
                            continue;
                        }
                        let lse_i = lse[(b * n_heads + h) * s + i];
                        let dor = &dout[ti * dqw + h * hd..ti * dqw + (h + 1) * hd];
                        let or = &out[ti * dqw + h * hd..ti * dqw + (h + 1) * hd];
                        let qr = &q[ti * dqw + h * hd..ti * dqw + (h + 1) * hd];
                        let d_i = dot8(dor, or);
                        for j in 0..=i {
                            if seg[b * s + j] != seg_i {
                                continue;
                            }
                            let kr = &kp[j * hd..(j + 1) * hd];
                            let vr = &vp[j * hd..(j + 1) * hd];
                            let s_ij = dot8(qr, kr) * scale;
                            let p = (s_ij - lse_i).exp();
                            let dp = dot8(dor, vr);
                            let ds = p * (dp - d_i) * scale;
                            let lrow = lb * s + j;
                            axpy(p, dor, &mut dv_c[lrow * dkvw + kh * hd..lrow * dkvw + (kh + 1) * hd]);
                            axpy(ds, qr, &mut dk_c[lrow * dkvw + kh * hd..lrow * dkvw + (kh + 1) * hd]);
                            let lqrow = lb * s + i;
                            axpy(ds, kr, &mut dq_c[lqrow * dqw + h * hd..lqrow * dqw + (h + 1) * hd]);
                        }
                    }
                }
            }
        }
    };

    let bp = rows_per_tile(bsz, ex.threads());
    if ex.threads() <= 1 || bsz <= 1 {
        let (mut kp, mut vp) = (ex.arena().lease_uninit(s * hd), ex.arena().lease_uninit(s * hd));
        body(0, dq, dk, dv, &mut kp, &mut vp);
        return;
    }
    ex.scope(|scope| {
        let body = &body;
        // all tile scratch leased up front (see the forward pass note)
        let scratch: Vec<_> = (0..dq.len().div_ceil(bp * s * dqw))
            .map(|_| (ex.arena().lease_uninit(s * hd), ex.arena().lease_uninit(s * hd)))
            .collect();
        let iter = dq
            .chunks_mut(bp * s * dqw)
            .zip(dk.chunks_mut(bp * s * dkvw))
            .zip(dv.chunks_mut(bp * s * dkvw))
            .zip(scratch)
            .enumerate();
        for (idx, (((dq_c, dk_c), dv_c), (mut kp, mut vp))) in iter {
            scope.spawn(move || body(idx * bp, dq_c, dk_c, dv_c, &mut kp, &mut vp));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::model::{self, BatchView};
    use crate::util::rng::Rng;

    /// Packed-batch fixture: row 0 has two segments, row 1 one + padding.
    struct Fixture {
        bsz: usize,
        s: usize,
        n_heads: usize,
        n_kv: usize,
        hd: usize,
        seg: Vec<i32>,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        dout: Vec<f32>,
    }

    fn fixture(seed: u64) -> Fixture {
        let (bsz, s, n_heads, n_kv, hd) = (2usize, 10usize, 4usize, 2usize, 4usize);
        let mut seg = vec![0i32; bsz * s];
        for i in 0..5 {
            seg[i] = 1;
        }
        for i in 5..9 {
            seg[i] = 2;
        }
        for i in 0..6 {
            seg[s + i] = 1;
        }
        let mut rng = Rng::new(seed);
        let mut rand = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32).collect() };
        let t = bsz * s;
        Fixture {
            bsz,
            s,
            n_heads,
            n_kv,
            hd,
            seg,
            q: rand(t * n_heads * hd),
            k: rand(t * n_kv * hd),
            v: rand(t * n_kv * hd),
            dout: rand(t * n_heads * hd),
        }
    }

    fn view_for<'a>(f: &'a Fixture, tokens: &'a [i32], pos: &'a [i32]) -> BatchView<'a> {
        BatchView { tokens, targets: tokens, seg: &f.seg, pos, bsz: f.bsz, seq: f.s }
    }

    #[test]
    fn forward_matches_materialized_reference() {
        let f = fixture(17);
        let t = f.bsz * f.s;
        let tokens = vec![0i32; t];
        let pos = vec![0i32; t];
        let bv = view_for(&f, &tokens, &pos);
        let mut want = vec![0.0f32; t * f.n_heads * f.hd];
        let mut probs = vec![0.0f32; f.bsz * f.n_heads * f.s * f.s];
        model::attention_fwd(&f.q, &f.k, &f.v, &bv, f.n_heads, f.n_kv, f.hd, &mut want, &mut probs);
        for threads in [1usize, 2, 4] {
            let ex = Exec::new(threads);
            let mut out = vec![0.0f32; t * f.n_heads * f.hd];
            let mut lse = vec![0.0f32; f.bsz * f.n_heads * f.s];
            flash_attention_fwd(
                &f.q, &f.k, &f.v, &f.seg, f.bsz, f.s, f.n_heads, f.n_kv, f.hd, &mut out, &mut lse,
                &ex,
            );
            for (i, (a, b)) in out.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-5, "threads={threads} out[{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn backward_matches_materialized_reference() {
        let f = fixture(18);
        let t = f.bsz * f.s;
        let tokens = vec![0i32; t];
        let pos = vec![0i32; t];
        let bv = view_for(&f, &tokens, &pos);
        let dqw = f.n_heads * f.hd;
        let dkvw = f.n_kv * f.hd;
        let mut att = vec![0.0f32; t * dqw];
        let mut probs = vec![0.0f32; f.bsz * f.n_heads * f.s * f.s];
        model::attention_fwd(&f.q, &f.k, &f.v, &bv, f.n_heads, f.n_kv, f.hd, &mut att, &mut probs);
        let (mut dq_r, mut dk_r, mut dv_r) =
            (vec![0.0f32; t * dqw], vec![0.0f32; t * dkvw], vec![0.0f32; t * dkvw]);
        model::attention_bwd(
            &f.dout, &f.q, &f.k, &f.v, &probs, &bv, f.n_heads, f.n_kv, f.hd, &mut dq_r, &mut dk_r,
            &mut dv_r,
        );

        let ex2 = Exec::new(2);
        let mut out = vec![0.0f32; t * dqw];
        let mut lse = vec![0.0f32; f.bsz * f.n_heads * f.s];
        flash_attention_fwd(
            &f.q, &f.k, &f.v, &f.seg, f.bsz, f.s, f.n_heads, f.n_kv, f.hd, &mut out, &mut lse, &ex2,
        );
        for threads in [1usize, 3] {
            let ex = Exec::new(threads);
            let (mut dq, mut dk, mut dv) =
                (vec![0.0f32; t * dqw], vec![0.0f32; t * dkvw], vec![0.0f32; t * dkvw]);
            flash_attention_bwd(
                &f.dout, &f.q, &f.k, &f.v, &out, &lse, &f.seg, f.bsz, f.s, f.n_heads, f.n_kv, f.hd,
                &mut dq, &mut dk, &mut dv, &ex,
            );
            for (name, got, want) in [("dq", &dq, &dq_r), ("dk", &dk, &dk_r), ("dv", &dv, &dv_r)] {
                for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
                    assert!((a - b).abs() < 1e-4, "threads={threads} {name}[{i}]: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn padding_rows_emit_zero_output_and_grad() {
        let f = fixture(19);
        let t = f.bsz * f.s;
        let dqw = f.n_heads * f.hd;
        let dkvw = f.n_kv * f.hd;
        let ex = Exec::new(1);
        let mut out = vec![0.0f32; t * dqw];
        let mut lse = vec![0.0f32; f.bsz * f.n_heads * f.s];
        flash_attention_fwd(
            &f.q, &f.k, &f.v, &f.seg, f.bsz, f.s, f.n_heads, f.n_kv, f.hd, &mut out, &mut lse, &ex,
        );
        // rows 9 (row 0 tail) and 16.. (row 1 tail) are padding
        for ti in [9usize, 16, 17, 18, 19] {
            assert!(out[ti * dqw..(ti + 1) * dqw].iter().all(|&x| x == 0.0), "out row {ti}");
        }
        let (mut dq, mut dk, mut dv) =
            (vec![0.0f32; t * dqw], vec![0.0f32; t * dkvw], vec![0.0f32; t * dkvw]);
        flash_attention_bwd(
            &f.dout, &f.q, &f.k, &f.v, &out, &lse, &f.seg, f.bsz, f.s, f.n_heads, f.n_kv, f.hd,
            &mut dq, &mut dk, &mut dv, &ex,
        );
        for ti in [9usize, 16, 17, 18, 19] {
            assert!(dq[ti * dqw..(ti + 1) * dqw].iter().all(|&x| x == 0.0), "dq row {ti}");
            assert!(dk[ti * dkvw..(ti + 1) * dkvw].iter().all(|&x| x == 0.0), "dk row {ti}");
        }
    }

    #[test]
    fn long_row_exercises_multiple_kv_tiles() {
        // one segment longer than KV_TILE forces the online rescale path
        let (bsz, s, n_heads, n_kv, hd) = (1usize, KV_TILE + 33, 2usize, 1usize, 4usize);
        let t = bsz * s;
        let seg = vec![1i32; t];
        let mut rng = Rng::new(23);
        let mut rand = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32 * 2.0).collect() };
        let q = rand(t * n_heads * hd);
        let k = rand(t * n_kv * hd);
        let v = rand(t * n_kv * hd);
        let tokens = vec![0i32; t];
        let pos = vec![0i32; t];
        let bv = BatchView { tokens: &tokens, targets: &tokens, seg: &seg, pos: &pos, bsz, seq: s };
        let mut want = vec![0.0f32; t * n_heads * hd];
        let mut probs = vec![0.0f32; n_heads * s * s];
        model::attention_fwd(&q, &k, &v, &bv, n_heads, n_kv, hd, &mut want, &mut probs);
        let ex = Exec::new(1);
        let mut out = vec![0.0f32; t * n_heads * hd];
        let mut lse = vec![0.0f32; n_heads * s];
        flash_attention_fwd(&q, &k, &v, &seg, bsz, s, n_heads, n_kv, hd, &mut out, &mut lse, &ex);
        for (i, (a, b)) in out.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "out[{i}]: {a} vs {b}");
        }
    }
}
