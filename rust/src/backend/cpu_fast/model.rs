//! The fast CPU train step: the reference architecture executed through
//! the fused/tiled/pooled kernels of this module's siblings.
//!
//! Same state layout, same parameter names, same batch semantics as
//! `backend::cpu::model` — the two backends share `CpuState`, so
//! checkpoints and init are interchangeable and parity tests line up
//! parameter-for-parameter. What differs is execution:
//!
//! * attention never materializes `[B, Hq, S, S]` (online softmax forward,
//!   recompute backward — `attention.rs`);
//! * the loss never materializes `[T, V]` (streaming CCE — `cce.rs`);
//! * RMSNorm feeds its projections fused, matmuls carry residual adds, and
//!   every row-parallel kernel dispatches on the backend's persistent
//!   worker pool (`pool.rs`);
//! * every f32 working buffer — activations, caches, gradients, kernel
//!   scratch — is leased from the backend's arena (`scratch.rs`), so
//!   steady-state steps perform zero arena heap allocations after the
//!   first (warm-arena) step.
//!
//! Numerics: reassociation (8-lane dots, online softmax) legitimately
//! changes low-order bits vs. the sequential reference, so cross-backend
//! parity is tolerance-based (loss |Δ| ≤ 1e-4, grad-norm rel ≤ 1e-3 —
//! `rust/tests/parity.rs`), while the fast backend itself is bitwise
//! deterministic run-to-run *and* across thread counts (every cross-tile
//! reduction happens in fixed tile order).

use super::attention::{flash_attention_bwd, flash_attention_fwd};
use super::cce::{cce_bwd_fused, cce_loss_fwd};
use super::kernels as k;
use super::pool::Exec;
use super::scratch::Lease;
use crate::backend::cpu::math::adamw_update_int8;
use crate::backend::cpu::model::{
    check_fused_inputs, ckpt_segment_starts, BatchView, CpuAdapter, CpuState, ParamIdx, StepOut,
    WEIGHT_DECAY,
};
use crate::backend::{FusedSlice, StepPhases};
use crate::optim::{classify_param, ParamGroup};
use crate::quant::{OptimStates, QuantMat};
use anyhow::{anyhow, bail, ensure, Result};
use std::time::Instant;

/// Per-layer forward activations kept for the backward pass, all leased
/// from the backend arena. Identical to the reference cache except
/// `probs: [B, Hq, S, S]` is replaced by `lse: [B, Hq, S]` (linear in S).
struct LayerCache<'e> {
    x_in: Lease<'e>,
    h1: Lease<'e>,
    rstd1: Lease<'e>,
    q: Lease<'e>,  // post-RoPE
    kk: Lease<'e>, // post-RoPE
    v: Lease<'e>,
    hq_a: Option<Lease<'e>>,
    hv_a: Option<Lease<'e>>,
    att: Lease<'e>, // attention output (pre-Wo); doubles as the bwd `out`
    lse: Lease<'e>, // [B, Hq, S] logsumexp per query row
    x_mid: Lease<'e>,
    h2: Lease<'e>,
    rstd2: Lease<'e>,
    gate: Lease<'e>,
    up: Lease<'e>,
    y: Lease<'e>,
}

struct FinalCache<'e> {
    x_f: Lease<'e>,
    hf: Lease<'e>,
    rstd_f: Lease<'e>,
    lse: Lease<'e>, // [T] streaming logsumexp (replaces [T, V] probs)
    n_valid: usize,
}

/// A frozen base matrix as the fast kernels consume it: dense f32, or a
/// quantized codec handle the `*_q` kernels dequantize tile-at-a-time.
/// The fast backend never materializes a whole dequantized matrix — that
/// naive contract belongs to the reference backend (the oracle).
enum W<'a> {
    Dense(&'a [f32]),
    Quant(&'a QuantMat),
}

/// Resolve a parameter for matmul use: a quantized frozen base matrix
/// surfaces its codec handle, everything else its dense payload.
fn weight<'a>(state: &'a CpuState, p: &ParamIdx, name: &str) -> Result<W<'a>> {
    let i = p.id(name)?;
    if let Some(qm) = state.qbase.get(i).and_then(|q| q.as_ref()) {
        return Ok(W::Quant(qm));
    }
    Ok(W::Dense(state.params[i].as_f32()?))
}

/// `y = x @ W.T`, dispatching on the weight's storage tier.
fn mm(x: &[f32], w: &W, t: usize, k_in: usize, n_out: usize, out: &mut [f32], ex: &Exec) {
    match w {
        W::Dense(wd) => k::matmul(x, wd, t, k_in, n_out, out, ex),
        W::Quant(qm) => k::matmul_q(x, qm, t, k_in, n_out, out, ex),
    }
}

/// `y = res + x @ W.T`, dispatching on the weight's storage tier.
#[allow(clippy::too_many_arguments)]
fn mm_res(
    x: &[f32],
    w: &W,
    res: &[f32],
    t: usize,
    k_in: usize,
    n_out: usize,
    out: &mut [f32],
    ex: &Exec,
) {
    match w {
        W::Dense(wd) => k::matmul_residual(x, wd, res, t, k_in, n_out, out, ex),
        W::Quant(qm) => k::matmul_residual_q(x, qm, res, t, k_in, n_out, out, ex),
    }
}

/// `dx += dy @ W`, dispatching on the weight's storage tier.
fn mm_bwd_x(dy: &[f32], w: &W, t: usize, k_in: usize, n_out: usize, dx: &mut [f32], ex: &Exec) {
    match w {
        W::Dense(wd) => k::matmul_bwd_x(dy, wd, t, k_in, n_out, dx, ex),
        W::Quant(qm) => k::matmul_bwd_x_q(dy, qm, t, k_in, n_out, dx, ex),
    }
}

/// Reject out-of-range tokens/targets before any compute.
fn validate_batch(state: &CpuState, bv: &BatchView) -> Result<()> {
    let v = state.dims.vocab;
    for (i, &tok) in bv.tokens.iter().enumerate() {
        if tok < 0 || tok as usize >= v {
            bail!("token id {tok} at position {i} out of vocab range 0..{v}");
        }
    }
    for (i, &tgt) in bv.targets.iter().enumerate() {
        if tgt >= v as i32 {
            bail!("target id {tgt} at position {i} out of vocab range");
        }
    }
    Ok(())
}

/// Token-embedding gather into a leased activation. A quantized embedding
/// dequantizes one `d`-element row per token, straight into the
/// destination row — never the whole table.
fn embed_fwd<'e>(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    ex: &'e Exec,
) -> Result<Lease<'e>> {
    let d = state.dims.d_model;
    let t = bv.bsz * bv.seq;
    let mut x = ex.arena().lease_uninit(t * d);
    match weight(state, p, "embed")? {
        W::Dense(embed) => {
            for ti in 0..t {
                let tok = bv.tokens[ti] as usize;
                x[ti * d..(ti + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
            }
        }
        W::Quant(qm) => {
            for ti in 0..t {
                let tok = bv.tokens[ti] as usize;
                qm.dequant_range_into(tok * d, &mut x[ti * d..(ti + 1) * d]);
            }
        }
    }
    Ok(x)
}

/// One transformer layer forward. Consumes the input activation: it moves
/// into the cache, or — when `want_cache` is false — drops back to the
/// arena with every intermediate, which is the checkpointed forward's
/// whole memory win. Under a quantized base the fused RMSNorm→projection
/// kernels decompose into `rmsnorm` + tile-dequantizing matmuls (the
/// fusion reads dense weight rows; the `*_q` kernels dequantize
/// `DEQ_ROWS`-row tiles into an arena lease instead).
#[allow(clippy::too_many_arguments)]
fn layer_fwd<'e>(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    l: usize,
    x_in: Lease<'e>,
    want_cache: bool,
    ex: &'e Exec,
) -> Result<(Lease<'e>, Option<LayerCache<'e>>)> {
    let dims = &state.dims;
    let (d, f) = (dims.d_model, dims.d_ff);
    let (hq, hkv, hd) = (dims.n_heads, dims.n_kv_heads, dims.head_dim());
    let dkv = dims.d_kv();
    let t = bv.bsz * bv.seq;
    let pre = format!("layer_{l:02}.");
    let quant = state.base_quant.is_some();

    let mut h1 = ex.arena().lease_uninit(t * d);
    let mut rstd1 = ex.arena().lease_uninit(t);
    let mut q = ex.arena().lease_uninit(t * d);
    let mut kk = ex.arena().lease_uninit(t * dkv);
    let mut vv = ex.arena().lease_uninit(t * dkv);
    if quant {
        k::rmsnorm(&x_in, p.get(&format!("{pre}norm1"))?, t, d, &mut h1, &mut rstd1, ex);
        mm(&h1, &weight(state, p, &format!("{pre}wq"))?, t, d, d, &mut q, ex);
        mm(&h1, &weight(state, p, &format!("{pre}wk"))?, t, d, dkv, &mut kk, ex);
        mm(&h1, &weight(state, p, &format!("{pre}wv"))?, t, d, dkv, &mut vv, ex);
    } else {
        k::fused_rmsnorm_qkv(
            &x_in,
            p.get(&format!("{pre}norm1"))?,
            p.get(&format!("{pre}wq"))?,
            p.get(&format!("{pre}wk"))?,
            p.get(&format!("{pre}wv"))?,
            t,
            d,
            dkv,
            &mut h1,
            &mut rstd1,
            &mut q,
            &mut kk,
            &mut vv,
            ex,
        );
    }

    let (mut hq_a, mut hv_a) = (None, None);
    if let Some(lc) = &state.lora {
        let r = lc.rank;
        let s = lc.scale();
        let mut ha = ex.arena().lease_uninit(t * r);
        k::lora_linear(
            &h1,
            p.get(&format!("{pre}wq_a"))?,
            p.get(&format!("{pre}wq_b"))?,
            t,
            d,
            r,
            d,
            s,
            &mut ha,
            &mut q,
            ex,
        );
        hq_a = Some(ha);
        let mut ha = ex.arena().lease_uninit(t * r);
        k::lora_linear(
            &h1,
            p.get(&format!("{pre}wv_a"))?,
            p.get(&format!("{pre}wv_b"))?,
            t,
            d,
            r,
            dkv,
            s,
            &mut ha,
            &mut vv,
            ex,
        );
        hv_a = Some(ha);
    }

    k::rope(&mut q, bv.pos, t, hq, hd, 1.0, ex);
    k::rope(&mut kk, bv.pos, t, hkv, hd, 1.0, ex);

    let mut att = ex.arena().lease_uninit(t * d);
    let mut lse = ex.arena().lease_uninit(bv.bsz * hq * bv.seq);
    flash_attention_fwd(
        &q, &kk, &vv, bv.seg, bv.bsz, bv.seq, hq, hkv, hd, &mut att, &mut lse, ex,
    );

    let mut x_mid = ex.arena().lease_uninit(t * d);
    mm_res(&att, &weight(state, p, &format!("{pre}wo"))?, &x_in, t, d, d, &mut x_mid, ex);

    let mut h2 = ex.arena().lease_uninit(t * d);
    let mut rstd2 = ex.arena().lease_uninit(t);
    let mut gate = ex.arena().lease_uninit(t * f);
    let mut up = ex.arena().lease_uninit(t * f);
    let mut y = ex.arena().lease_uninit(t * f);
    if quant {
        k::rmsnorm(&x_mid, p.get(&format!("{pre}norm2"))?, t, d, &mut h2, &mut rstd2, ex);
        mm(&h2, &weight(state, p, &format!("{pre}w_gate"))?, t, d, f, &mut gate, ex);
        mm(&h2, &weight(state, p, &format!("{pre}w_up"))?, t, d, f, &mut up, ex);
        k::swiglu(&gate, &up, &mut y, ex);
    } else {
        k::fused_rmsnorm_swiglu(
            &x_mid,
            p.get(&format!("{pre}norm2"))?,
            p.get(&format!("{pre}w_gate"))?,
            p.get(&format!("{pre}w_up"))?,
            t,
            d,
            f,
            &mut h2,
            &mut rstd2,
            &mut gate,
            &mut up,
            &mut y,
            ex,
        );
    }

    let mut x_out = ex.arena().lease_uninit(t * d);
    mm_res(&y, &weight(state, p, &format!("{pre}w_down"))?, &x_mid, t, f, d, &mut x_out, ex);

    let cache = if want_cache {
        Some(LayerCache {
            x_in,
            h1,
            rstd1,
            q,
            kk,
            v: vv,
            hq_a,
            hv_a,
            att,
            lse,
            x_mid,
            h2,
            rstd2,
            gate,
            up,
            y,
        })
    } else {
        None
    };
    Ok((x_out, cache))
}

/// Final norm + streaming CCE loss. Consumes the last activation.
fn head_fwd<'e>(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    x_f: Lease<'e>,
    want_cache: bool,
    ex: &'e Exec,
) -> Result<(f32, usize, Option<FinalCache<'e>>)> {
    let (d, v) = (state.dims.d_model, state.dims.vocab);
    let t = bv.bsz * bv.seq;
    let mut hf = ex.arena().lease_uninit(t * d);
    let mut rstd_f = ex.arena().lease_uninit(t);
    k::rmsnorm(&x_f, p.get("norm_f")?, t, d, &mut hf, &mut rstd_f, ex);
    let mut lse = ex.arena().lease_uninit(t);
    let (loss_sum, n_valid) =
        cce_loss_fwd(&hf, p.get("w_head")?, bv.targets, t, d, v, &mut lse, ex);
    let fc = if want_cache {
        Some(FinalCache { x_f, hf, rstd_f, lse, n_valid })
    } else {
        None
    };
    Ok((loss_sum, n_valid, fc))
}

/// Forward pass; fills `caches` when training. Returns summed loss +
/// valid-target count (mean reduction is the caller's, like the reference).
fn forward<'e>(
    state: &CpuState,
    bv: &BatchView,
    caches: Option<(&mut Vec<LayerCache<'e>>, &mut Option<FinalCache<'e>>)>,
    ex: &'e Exec,
) -> Result<(f32, usize)> {
    let p = ParamIdx::new(&state.names, &state.params);
    validate_batch(state, bv)?;
    let want = caches.is_some();
    let mut caches = caches;
    let mut x = embed_fwd(state, &p, bv, ex)?;
    for l in 0..state.dims.n_layers {
        let (x_out, cache) = layer_fwd(state, &p, bv, l, x, want, ex)?;
        if let Some((lcs, _)) = caches.as_mut() {
            lcs.push(cache.ok_or_else(|| anyhow!("layer cache requested but not built"))?);
        }
        x = x_out;
    }
    let (loss_sum, n_valid, fc) = head_fwd(state, &p, bv, x, want, ex)?;
    if let Some((_, slot)) = caches.as_mut() {
        **slot = fc;
    }
    Ok((loss_sum, n_valid))
}

/// CCE backward + final-norm backward; returns dx at the last residual
/// stream. dW_head and dhf come out of one fused tile loop, no `[T, V]`.
fn head_bwd<'e>(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    fc: &FinalCache<'e>,
    grads: &mut [Lease<'e>],
    ex: &'e Exec,
) -> Result<Lease<'e>> {
    let (d, v) = (state.dims.d_model, state.dims.vocab);
    let t = bv.bsz * bv.seq;
    let nt = state.n_trainable;
    let i_head = p.id("w_head")?;
    let mut dhf = ex.arena().lease(t * d);
    {
        let dw_head = if i_head < nt { Some(grads[i_head].as_mut_slice()) } else { None };
        cce_bwd_fused(
            &fc.hf,
            p.get("w_head")?,
            bv.targets,
            &fc.lse,
            t,
            d,
            v,
            fc.n_valid,
            dw_head,
            &mut dhf,
            ex,
        );
    }

    let mut dx = ex.arena().lease(t * d);
    let i_nf = p.id("norm_f")?;
    k::rmsnorm_bwd(&fc.x_f, p.get("norm_f")?, &fc.rstd_f, &dhf, t, d, &mut dx, &mut grads[i_nf], ex);
    Ok(dx)
}

/// One transformer layer backward: consumes the incoming dx (at `x_out`),
/// returns dx at `x_in`. Base-matrix dx chains run through `mm_bwd_x`, so
/// a quantized base dequantizes tile-at-a-time here too; weight gradients
/// only form for trainable (dense, `i < nt`) parameters.
#[allow(clippy::too_many_arguments)]
fn layer_bwd<'e>(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    l: usize,
    c: &LayerCache<'e>,
    dx: Lease<'e>,
    grads: &mut [Lease<'e>],
    ex: &'e Exec,
) -> Result<Lease<'e>> {
    let dims = &state.dims;
    let (d, f) = (dims.d_model, dims.d_ff);
    let (hq, hkv, hd) = (dims.n_heads, dims.n_kv_heads, dims.head_dim());
    let dkv = dims.d_kv();
    let t = bv.bsz * bv.seq;
    let nt = state.n_trainable;
    let pre = format!("layer_{l:02}.");

    // x_out = x_mid + y @ w_down.T
    let i_down = p.id(&format!("{pre}w_down"))?;
    if i_down < nt {
        k::matmul_bwd_w(&dx, &c.y, t, f, d, &mut grads[i_down], ex);
    }
    let mut dy = ex.arena().lease(t * f);
    mm_bwd_x(&dx, &weight(state, p, &format!("{pre}w_down"))?, t, f, d, &mut dy, ex);

    let mut dgate = ex.arena().lease(t * f);
    let mut dup = ex.arena().lease(t * f);
    k::swiglu_bwd(&c.gate, &c.up, &dy, &mut dgate, &mut dup, ex);

    let i_gate = p.id(&format!("{pre}w_gate"))?;
    let i_up = p.id(&format!("{pre}w_up"))?;
    if i_gate < nt {
        k::matmul_bwd_w(&dgate, &c.h2, t, d, f, &mut grads[i_gate], ex);
    }
    if i_up < nt {
        k::matmul_bwd_w(&dup, &c.h2, t, d, f, &mut grads[i_up], ex);
    }
    let mut dh2 = ex.arena().lease(t * d);
    mm_bwd_x(&dgate, &weight(state, p, &format!("{pre}w_gate"))?, t, d, f, &mut dh2, ex);
    mm_bwd_x(&dup, &weight(state, p, &format!("{pre}w_up"))?, t, d, f, &mut dh2, ex);

    let i_n2 = p.id(&format!("{pre}norm2"))?;
    let mut dx_mid = dx; // residual passthrough...
    k::rmsnorm_bwd(
        &c.x_mid,
        p.get(&format!("{pre}norm2"))?,
        &c.rstd2,
        &dh2,
        t,
        d,
        &mut dx_mid, // ...plus the norm branch accumulated
        &mut grads[i_n2],
        ex,
    );

    // x_mid = x_in + att @ wo.T
    let i_wo = p.id(&format!("{pre}wo"))?;
    if i_wo < nt {
        k::matmul_bwd_w(&dx_mid, &c.att, t, d, d, &mut grads[i_wo], ex);
    }
    let mut datt = ex.arena().lease(t * d);
    mm_bwd_x(&dx_mid, &weight(state, p, &format!("{pre}wo"))?, t, d, d, &mut datt, ex);

    let mut dq = ex.arena().lease(t * d);
    let mut dk = ex.arena().lease(t * dkv);
    let mut dv = ex.arena().lease(t * dkv);
    flash_attention_bwd(
        &datt, &c.q, &c.kk, &c.v, &c.att, &c.lse, bv.seg, bv.bsz, bv.seq, hq, hkv, hd,
        &mut dq, &mut dk, &mut dv, ex,
    );
    k::rope(&mut dq, bv.pos, t, hq, hd, -1.0, ex);
    k::rope(&mut dk, bv.pos, t, hkv, hd, -1.0, ex);

    let i_wq = p.id(&format!("{pre}wq"))?;
    let i_wk = p.id(&format!("{pre}wk"))?;
    let i_wv = p.id(&format!("{pre}wv"))?;
    if i_wq < nt {
        k::matmul_bwd_w(&dq, &c.h1, t, d, d, &mut grads[i_wq], ex);
    }
    if i_wk < nt {
        k::matmul_bwd_w(&dk, &c.h1, t, d, dkv, &mut grads[i_wk], ex);
    }
    if i_wv < nt {
        k::matmul_bwd_w(&dv, &c.h1, t, d, dkv, &mut grads[i_wv], ex);
    }
    let mut dh1 = ex.arena().lease(t * d);
    mm_bwd_x(&dq, &weight(state, p, &format!("{pre}wq"))?, t, d, d, &mut dh1, ex);
    mm_bwd_x(&dk, &weight(state, p, &format!("{pre}wk"))?, t, d, dkv, &mut dh1, ex);
    mm_bwd_x(&dv, &weight(state, p, &format!("{pre}wv"))?, t, d, dkv, &mut dh1, ex);

    if let Some(lc) = &state.lora {
        let (r, s) = (lc.rank, lc.scale());
        let hq_a = c.hq_a.as_ref().expect("lora cache");
        let hv_a = c.hv_a.as_ref().expect("lora cache");
        let mut dq_s = ex.arena().lease_uninit(t * d);
        for (o, &g) in dq_s.iter_mut().zip(dq.iter()) {
            *o = s * g;
        }
        let i_qb = p.id(&format!("{pre}wq_b"))?;
        let i_qa = p.id(&format!("{pre}wq_a"))?;
        k::matmul_bwd_w(&dq_s, hq_a, t, r, d, &mut grads[i_qb], ex);
        let mut dhq_a = ex.arena().lease(t * r);
        k::matmul_bwd_x(&dq_s, p.get(&format!("{pre}wq_b"))?, t, r, d, &mut dhq_a, ex);
        k::matmul_bwd_w(&dhq_a, &c.h1, t, d, r, &mut grads[i_qa], ex);
        k::matmul_bwd_x(&dhq_a, p.get(&format!("{pre}wq_a"))?, t, d, r, &mut dh1, ex);

        let mut dv_s = ex.arena().lease_uninit(t * dkv);
        for (o, &g) in dv_s.iter_mut().zip(dv.iter()) {
            *o = s * g;
        }
        let i_vb = p.id(&format!("{pre}wv_b"))?;
        let i_va = p.id(&format!("{pre}wv_a"))?;
        k::matmul_bwd_w(&dv_s, hv_a, t, r, dkv, &mut grads[i_vb], ex);
        let mut dhv_a = ex.arena().lease(t * r);
        k::matmul_bwd_x(&dv_s, p.get(&format!("{pre}wv_b"))?, t, r, dkv, &mut dhv_a, ex);
        k::matmul_bwd_w(&dhv_a, &c.h1, t, d, r, &mut grads[i_va], ex);
        k::matmul_bwd_x(&dhv_a, p.get(&format!("{pre}wv_a"))?, t, d, r, &mut dh1, ex);
    }

    let i_n1 = p.id(&format!("{pre}norm1"))?;
    let mut dx_in = dx_mid; // residual passthrough
    k::rmsnorm_bwd(
        &c.x_in,
        p.get(&format!("{pre}norm1"))?,
        &c.rstd1,
        &dh1,
        t,
        d,
        &mut dx_in,
        &mut grads[i_n1],
        ex,
    );
    Ok(dx_in)
}

/// Scatter the embedding gradient (only when the embedding is trainable).
fn embed_bwd(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    dx: &[f32],
    grads: &mut [Lease<'_>],
) -> Result<()> {
    let d = state.dims.d_model;
    let t = bv.bsz * bv.seq;
    let i_embed = p.id("embed")?;
    if i_embed < state.n_trainable {
        for ti in 0..t {
            let tok = bv.tokens[ti] as usize;
            let ge = &mut grads[i_embed][tok * d..(tok + 1) * d];
            for i in 0..d {
                ge[i] += dx[ti * d + i];
            }
        }
    }
    Ok(())
}

/// Full backward pass; gradients aligned with `state.params` (frozen
/// entries stay zero except where the dx chain needs them — same contract
/// as the reference backward).
fn backward<'e>(
    state: &CpuState,
    bv: &BatchView,
    layer_caches: &[LayerCache<'e>],
    fc: &FinalCache<'e>,
    ex: &'e Exec,
) -> Result<Vec<Lease<'e>>> {
    let p = ParamIdx::new(&state.names, &state.params);
    let mut grads: Vec<Lease<'e>> =
        state.params.iter().map(|tn| ex.arena().lease(tn.elements())).collect();
    let mut dx = head_bwd(state, &p, bv, fc, &mut grads, ex)?;
    for l in (0..state.dims.n_layers).rev() {
        dx = layer_bwd(state, &p, bv, l, &layer_caches[l], dx, &mut grads, ex)?;
    }
    embed_bwd(state, &p, bv, &dx, &mut grads)?;
    Ok(grads)
}

/// Segment-level activation checkpointing (DESIGN.md §12): the forward
/// keeps only the activations entering each of the `segs` layer segments
/// (leased boundary copies) and drops everything else back to the arena;
/// the backward recomputes one segment's caches at a time, so at most one
/// segment's activations plus the boundary stack are ever live — that is
/// what `Arena::peak_total_elems` pins in the tests. Recompute replays
/// the exact same kernels in the same order, so checkpointed steps are
/// bitwise identical to cached steps.
fn grads_checkpointed<'e>(
    state: &CpuState,
    bv: &BatchView,
    segs: usize,
    ex: &'e Exec,
) -> Result<(f32, usize, Vec<Lease<'e>>)> {
    let n_layers = state.dims.n_layers;
    let starts = ckpt_segment_starts(n_layers, segs);
    let p = ParamIdx::new(&state.names, &state.params);
    validate_batch(state, bv)?;

    // cache-free forward, snapshotting the segment-boundary activations
    let mut boundaries: Vec<Lease<'e>> = Vec::with_capacity(starts.len());
    let mut x = embed_fwd(state, &p, bv, ex)?;
    for l in 0..n_layers {
        if starts.contains(&l) {
            let mut b = ex.arena().lease_uninit(x.len());
            b.copy_from_slice(&x);
            boundaries.push(b);
        }
        let (x_out, _) = layer_fwd(state, &p, bv, l, x, false, ex)?;
        x = x_out;
    }
    let (loss_sum, n_valid, fc) = head_fwd(state, &p, bv, x, true, ex)?;
    let fc = fc.ok_or_else(|| anyhow!("head cache requested but not built"))?;

    let mut grads: Vec<Lease<'e>> =
        state.params.iter().map(|tn| ex.arena().lease(tn.elements())).collect();
    let mut dx = head_bwd(state, &p, bv, &fc, &mut grads, ex)?;
    drop(fc);

    // backward, one segment at a time, newest segment first
    for (si, &seg_start) in starts.iter().enumerate().rev() {
        let seg_end = if si + 1 < starts.len() { starts[si + 1] } else { n_layers };
        // recompute this segment's layer caches from its boundary (the
        // boundary lease itself feeds the first recomputed layer)
        let mut xr =
            boundaries.pop().ok_or_else(|| anyhow!("checkpoint boundary stack underflow"))?;
        let mut caches: Vec<LayerCache<'e>> = Vec::with_capacity(seg_end - seg_start);
        for l in seg_start..seg_end {
            let (x_out, cache) = layer_fwd(state, &p, bv, l, xr, true, ex)?;
            caches.push(cache.ok_or_else(|| anyhow!("layer cache requested but not built"))?);
            xr = x_out;
        }
        drop(xr); // the segment's output activation is not needed backward
        for l in (seg_start..seg_end).rev() {
            dx = layer_bwd(state, &p, bv, l, &caches[l - seg_start], dx, &mut grads, ex)?;
        }
        // caches drop here, returning the whole segment to the arena
    }
    embed_bwd(state, &p, bv, &dx, &mut grads)?;
    Ok((loss_sum, n_valid, grads))
}

/// Forward-only mean loss (the eval path).
pub fn eval_loss(state: &CpuState, bv: &BatchView, ex: &Exec) -> Result<f32> {
    let (loss_sum, n_valid) = forward(state, bv, None, ex)?;
    Ok(loss_sum / n_valid.max(1) as f32)
}

/// One full fast train step: forward, backward, grad-norm, AdamW with the
/// LoRA+ dual LR. Mirrors the reference `train_step` contract exactly,
/// including the `broken` zero-gradient mode.
pub fn train_step(
    state: &mut CpuState,
    bv: &BatchView,
    broken: bool,
    step: u64,
    lr: f32,
    lr_b: f32,
    ex: &Exec,
) -> Result<StepOut> {
    if broken {
        let t_fwd = Instant::now();
        let (ls, nv) = forward(state, bv, None, ex)?;
        let loss = ls / nv.max(1) as f32;
        let phases = StepPhases { fwd_s: t_fwd.elapsed().as_secs_f64(), ..StepPhases::default() };
        return Ok(StepOut { loss, grad_norm: 0.0, n_tokens: nv as f32, phases });
    }
    let t_pass = Instant::now();
    let (loss_sum, n_valid, grads, fwd_s, bwd_s) = if state.ckpt_segments > 0 {
        // fwd/bwd interleave under recompute; report the whole pass as bwd
        let (ls, nv, g) = grads_checkpointed(state, bv, state.ckpt_segments, ex)?;
        (ls, nv, g, 0.0, t_pass.elapsed().as_secs_f64())
    } else {
        let mut layer_caches: Vec<LayerCache> = Vec::with_capacity(state.dims.n_layers);
        let mut final_cache: Option<FinalCache> = None;
        let (ls, nv) = forward(state, bv, Some((&mut layer_caches, &mut final_cache)), ex)?;
        let fwd_s = t_pass.elapsed().as_secs_f64();
        let fc = final_cache.ok_or_else(|| anyhow!("forward did not fill caches"))?;
        let t_bwd = Instant::now();
        let g = backward(state, bv, &layer_caches, &fc, ex)?;
        (ls, nv, g, fwd_s, t_bwd.elapsed().as_secs_f64())
    };
    let loss = loss_sum / n_valid.max(1) as f32;

    // fixed parameter order: grad-norm bits never depend on threads
    let t_optim = Instant::now();
    let mut sq = 0.0f32;
    for g in &grads[..state.n_trainable] {
        for &x in g.iter() {
            sq += x * x;
        }
    }
    let grad_norm = sq.sqrt();

    apply_adamw(state, |i| &grads[i], step, lr, lr_b, ex)?;
    let optim_s = t_optim.elapsed().as_secs_f64();
    let phases = StepPhases { fwd_s, bwd_s, optim_s };
    Ok(StepOut { loss, grad_norm, n_tokens: n_valid as f32, phases })
}

/// One AdamW step over the trainable prefix, dispatching on the state's
/// optimizer-state codec. Fp32 runs the pooled elementwise kernel; Int8
/// decodes each slot pair into arena-leased scratch, runs the identical
/// recurrence sequentially, and re-encodes (`math::adamw_update_int8`) —
/// strictly ordered, so step bits never depend on the thread count.
fn apply_adamw<'g>(
    state: &mut CpuState,
    grad_of: impl Fn(usize) -> &'g [f32],
    step: u64,
    lr: f32,
    lr_b: f32,
    ex: &Exec,
) -> Result<()> {
    match state.optim {
        OptimStates::Fp32 => {
            for i in 0..state.n_trainable {
                let lr_p = match classify_param(&state.names[i]) {
                    ParamGroup::LoraB => lr_b,
                    _ => lr,
                };
                let param = state.params[i].as_f32_mut()?;
                k::adamw(
                    param,
                    grad_of(i),
                    &mut state.slot_m[i],
                    &mut state.slot_v[i],
                    lr_p,
                    step as f32,
                    WEIGHT_DECAY,
                    ex,
                );
            }
        }
        OptimStates::Int8 => {
            let maxn =
                state.params[..state.n_trainable].iter().map(|t| t.elements()).max().unwrap_or(0);
            let mut m_buf = ex.arena().lease_uninit(maxn);
            let mut v_buf = ex.arena().lease_uninit(maxn);
            for i in 0..state.n_trainable {
                let lr_p = match classify_param(&state.names[i]) {
                    ParamGroup::LoraB => lr_b,
                    _ => lr,
                };
                let param = state.params[i].as_f32_mut()?;
                adamw_update_int8(
                    param,
                    grad_of(i),
                    &mut state.qslot_m[i],
                    &mut state.qslot_v[i],
                    lr_p,
                    step as f32,
                    WEIGHT_DECAY,
                    &mut m_buf,
                    &mut v_buf,
                );
            }
        }
    }
    Ok(())
}

/// One intra-step fused round on the fast path (DESIGN.md §11): the same
/// single-shared-base-pass contract as the reference
/// `cpu::model::fused_train_step`, executed through the pooled/tiled
/// kernels with every working buffer leased from the arena — so a warm
/// arena serves whole fused rounds with zero new heap allocations, and
/// the peak lease scales with the *concatenated* batch (one set of
/// activations for all tenants), not with the tenant count times a
/// per-tenant batch.
///
/// Bitwise parity with the fast serial path holds for the same reason as
/// the reference: every full-batch kernel here is per-row pure (tiling
/// partitions rows across threads but never reassociates within a row),
/// and the order-sensitive reductions — CCE loss, adapter weight
/// gradients, grad-norm, AdamW — run per slice with the same kernels on
/// the same sub-inputs the serial run sees, in fixed slice order.
pub fn fused_train_step(
    state: &CpuState,
    adapters: &mut [&mut CpuAdapter],
    bv: &BatchView,
    slices: &[FusedSlice],
    ex: &Exec,
) -> Result<(Vec<StepOut>, StepPhases)> {
    check_fused_inputs(state, adapters, bv, slices)?;
    let dims = &state.dims;
    let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
    let (hq, hkv, hd) = (dims.n_heads, dims.n_kv_heads, dims.head_dim());
    let dkv = dims.d_kv();
    let (t, seq) = (bv.bsz * bv.seq, bv.seq);
    let p = ParamIdx::new(&state.names, &state.params);
    let lc_cfg = state.lora.expect("checked by check_fused_inputs");
    let (r, scale) = (lc_cfg.rank, lc_cfg.scale());
    let nt = state.n_trainable;
    let quant = state.base_quant.is_some();
    validate_batch(state, bv)?;

    // ---- forward: one shared base pass, per-slice adapter epilogues ----
    let t_fwd = Instant::now();
    let mut x = embed_fwd(state, &p, bv, ex)?;

    let mut layer_caches: Vec<LayerCache> = Vec::with_capacity(dims.n_layers);
    for l in 0..dims.n_layers {
        let pre = format!("layer_{l:02}.");
        let x_in = x;

        let mut h1 = ex.arena().lease_uninit(t * d);
        let mut rstd1 = ex.arena().lease_uninit(t);
        let mut q = ex.arena().lease_uninit(t * d);
        let mut kk = ex.arena().lease_uninit(t * dkv);
        let mut vv = ex.arena().lease_uninit(t * dkv);
        if quant {
            k::rmsnorm(&x_in, p.get(&format!("{pre}norm1"))?, t, d, &mut h1, &mut rstd1, ex);
            mm(&h1, &weight(state, &p, &format!("{pre}wq"))?, t, d, d, &mut q, ex);
            mm(&h1, &weight(state, &p, &format!("{pre}wk"))?, t, d, dkv, &mut kk, ex);
            mm(&h1, &weight(state, &p, &format!("{pre}wv"))?, t, d, dkv, &mut vv, ex);
        } else {
            k::fused_rmsnorm_qkv(
                &x_in,
                p.get(&format!("{pre}norm1"))?,
                p.get(&format!("{pre}wq"))?,
                p.get(&format!("{pre}wk"))?,
                p.get(&format!("{pre}wv"))?,
                t,
                d,
                dkv,
                &mut h1,
                &mut rstd1,
                &mut q,
                &mut kk,
                &mut vv,
                ex,
            );
        }

        let i_qa = p.id(&format!("{pre}wq_a"))?;
        let i_qb = p.id(&format!("{pre}wq_b"))?;
        let i_va = p.id(&format!("{pre}wv_a"))?;
        let i_vb = p.id(&format!("{pre}wv_b"))?;
        let mut hq_a = ex.arena().lease_uninit(t * r);
        let mut hv_a = ex.arena().lease_uninit(t * r);
        for (ki, sl) in slices.iter().enumerate() {
            let lo = sl.row_start * seq;
            let hi = (sl.row_start + sl.rows) * seq;
            let ts = hi - lo;
            let ad = &adapters[ki];
            k::lora_linear(
                &h1[lo * d..hi * d],
                ad.params[i_qa].as_f32()?,
                ad.params[i_qb].as_f32()?,
                ts,
                d,
                r,
                d,
                scale,
                &mut hq_a[lo * r..hi * r],
                &mut q[lo * d..hi * d],
                ex,
            );
            k::lora_linear(
                &h1[lo * d..hi * d],
                ad.params[i_va].as_f32()?,
                ad.params[i_vb].as_f32()?,
                ts,
                d,
                r,
                dkv,
                scale,
                &mut hv_a[lo * r..hi * r],
                &mut vv[lo * dkv..hi * dkv],
                ex,
            );
        }

        k::rope(&mut q, bv.pos, t, hq, hd, 1.0, ex);
        k::rope(&mut kk, bv.pos, t, hkv, hd, 1.0, ex);

        let mut att = ex.arena().lease_uninit(t * d);
        let mut lse = ex.arena().lease_uninit(bv.bsz * hq * seq);
        flash_attention_fwd(
            &q, &kk, &vv, bv.seg, bv.bsz, seq, hq, hkv, hd, &mut att, &mut lse, ex,
        );

        let mut x_mid = ex.arena().lease_uninit(t * d);
        mm_res(&att, &weight(state, &p, &format!("{pre}wo"))?, &x_in, t, d, d, &mut x_mid, ex);

        let mut h2 = ex.arena().lease_uninit(t * d);
        let mut rstd2 = ex.arena().lease_uninit(t);
        let mut gate = ex.arena().lease_uninit(t * f);
        let mut up = ex.arena().lease_uninit(t * f);
        let mut y = ex.arena().lease_uninit(t * f);
        if quant {
            k::rmsnorm(&x_mid, p.get(&format!("{pre}norm2"))?, t, d, &mut h2, &mut rstd2, ex);
            mm(&h2, &weight(state, &p, &format!("{pre}w_gate"))?, t, d, f, &mut gate, ex);
            mm(&h2, &weight(state, &p, &format!("{pre}w_up"))?, t, d, f, &mut up, ex);
            k::swiglu(&gate, &up, &mut y, ex);
        } else {
            k::fused_rmsnorm_swiglu(
                &x_mid,
                p.get(&format!("{pre}norm2"))?,
                p.get(&format!("{pre}w_gate"))?,
                p.get(&format!("{pre}w_up"))?,
                t,
                d,
                f,
                &mut h2,
                &mut rstd2,
                &mut gate,
                &mut up,
                &mut y,
                ex,
            );
        }

        let mut x_out = ex.arena().lease_uninit(t * d);
        mm_res(&y, &weight(state, &p, &format!("{pre}w_down"))?, &x_mid, t, f, d, &mut x_out, ex);

        layer_caches.push(LayerCache {
            x_in,
            h1,
            rstd1,
            q,
            kk,
            v: vv,
            hq_a: Some(hq_a),
            hv_a: Some(hv_a),
            att,
            lse,
            x_mid,
            h2,
            rstd2,
            gate,
            up,
            y,
        });
        x = x_out;
    }

    let x_f = x;
    let mut hf = ex.arena().lease_uninit(t * d);
    let mut rstd_f = ex.arena().lease_uninit(t);
    k::rmsnorm(&x_f, p.get("norm_f")?, t, d, &mut hf, &mut rstd_f, ex);
    // the loss reduction is order-sensitive: run it per slice so every
    // tenant gets exactly its serial (loss_sum, n_valid)
    let mut lse_f = ex.arena().lease_uninit(t);
    let mut tenant_fwd: Vec<(f32, usize)> = Vec::with_capacity(slices.len());
    for sl in slices {
        let lo = sl.row_start * seq;
        let hi = (sl.row_start + sl.rows) * seq;
        let (loss_sum, n_valid) = cce_loss_fwd(
            &hf[lo * d..hi * d],
            p.get("w_head")?,
            &bv.targets[lo..hi],
            hi - lo,
            d,
            v,
            &mut lse_f[lo..hi],
            ex,
        );
        tenant_fwd.push((loss_sum, n_valid));
    }
    let fwd_s = t_fwd.elapsed().as_secs_f64();

    // ---- backward: one shared base pass, per-slice adapter gradients ----
    let t_bwd = Instant::now();
    let mut tenant_grads: Vec<Vec<Lease>> = (0..slices.len())
        .map(|_| {
            state.params[..nt]
                .iter()
                .map(|tn| ex.arena().lease(tn.elements()))
                .collect()
        })
        .collect();
    // every norm is frozen under LoRA: dgamma goes to a discarded sink
    let mut dg_sink = ex.arena().lease(d);

    // CCE backward per slice, each normalized by its tenant's n_valid;
    // w_head is frozen under LoRA so no weight gradient is formed
    let mut dhf = ex.arena().lease(t * d);
    for (ki, sl) in slices.iter().enumerate() {
        let lo = sl.row_start * seq;
        let hi = (sl.row_start + sl.rows) * seq;
        cce_bwd_fused(
            &hf[lo * d..hi * d],
            p.get("w_head")?,
            &bv.targets[lo..hi],
            &lse_f[lo..hi],
            hi - lo,
            d,
            v,
            tenant_fwd[ki].1,
            None,
            &mut dhf[lo * d..hi * d],
            ex,
        );
    }

    let mut dx = ex.arena().lease(t * d);
    k::rmsnorm_bwd(&x_f, p.get("norm_f")?, &rstd_f, &dhf, t, d, &mut dx, &mut dg_sink, ex);

    for l in (0..dims.n_layers).rev() {
        let pre = format!("layer_{l:02}.");
        let c = &layer_caches[l];

        let mut dy = ex.arena().lease(t * f);
        mm_bwd_x(&dx, &weight(state, &p, &format!("{pre}w_down"))?, t, f, d, &mut dy, ex);

        let mut dgate = ex.arena().lease(t * f);
        let mut dup = ex.arena().lease(t * f);
        k::swiglu_bwd(&c.gate, &c.up, &dy, &mut dgate, &mut dup, ex);

        let mut dh2 = ex.arena().lease(t * d);
        mm_bwd_x(&dgate, &weight(state, &p, &format!("{pre}w_gate"))?, t, d, f, &mut dh2, ex);
        mm_bwd_x(&dup, &weight(state, &p, &format!("{pre}w_up"))?, t, d, f, &mut dh2, ex);

        let mut dx_mid = dx;
        k::rmsnorm_bwd(
            &c.x_mid,
            p.get(&format!("{pre}norm2"))?,
            &c.rstd2,
            &dh2,
            t,
            d,
            &mut dx_mid,
            &mut dg_sink,
            ex,
        );

        let mut datt = ex.arena().lease(t * d);
        mm_bwd_x(&dx_mid, &weight(state, &p, &format!("{pre}wo"))?, t, d, d, &mut datt, ex);

        let mut dq = ex.arena().lease(t * d);
        let mut dk = ex.arena().lease(t * dkv);
        let mut dv = ex.arena().lease(t * dkv);
        flash_attention_bwd(
            &datt, &c.q, &c.kk, &c.v, &c.att, &c.lse, bv.seg, bv.bsz, seq, hq, hkv, hd,
            &mut dq, &mut dk, &mut dv, ex,
        );
        k::rope(&mut dq, bv.pos, t, hq, hd, -1.0, ex);
        k::rope(&mut dk, bv.pos, t, hkv, hd, -1.0, ex);

        let mut dh1 = ex.arena().lease(t * d);
        mm_bwd_x(&dq, &weight(state, &p, &format!("{pre}wq"))?, t, d, d, &mut dh1, ex);
        mm_bwd_x(&dk, &weight(state, &p, &format!("{pre}wk"))?, t, d, dkv, &mut dh1, ex);
        mm_bwd_x(&dv, &weight(state, &p, &format!("{pre}wv"))?, t, d, dkv, &mut dh1, ex);

        // adapter chain: the only trainable gradients, reduced per slice
        let i_qa = p.id(&format!("{pre}wq_a"))?;
        let i_qb = p.id(&format!("{pre}wq_b"))?;
        let i_va = p.id(&format!("{pre}wv_a"))?;
        let i_vb = p.id(&format!("{pre}wv_b"))?;
        let hq_a = c.hq_a.as_ref().expect("lora cache");
        let hv_a = c.hv_a.as_ref().expect("lora cache");
        let mut dq_s = ex.arena().lease_uninit(t * d);
        for (o, &g) in dq_s.iter_mut().zip(dq.iter()) {
            *o = scale * g;
        }
        let mut dv_s = ex.arena().lease_uninit(t * dkv);
        for (o, &g) in dv_s.iter_mut().zip(dv.iter()) {
            *o = scale * g;
        }
        let mut dhq_a = ex.arena().lease(t * r);
        let mut dhv_a = ex.arena().lease(t * r);
        for (ki, sl) in slices.iter().enumerate() {
            let lo = sl.row_start * seq;
            let hi = (sl.row_start + sl.rows) * seq;
            let ts = hi - lo;
            let ad = &adapters[ki];
            let g = &mut tenant_grads[ki];

            k::matmul_bwd_w(&dq_s[lo * d..hi * d], &hq_a[lo * r..hi * r], ts, r, d, &mut g[i_qb], ex);
            k::matmul_bwd_x(
                &dq_s[lo * d..hi * d],
                ad.params[i_qb].as_f32()?,
                ts,
                r,
                d,
                &mut dhq_a[lo * r..hi * r],
                ex,
            );
            k::matmul_bwd_w(&dhq_a[lo * r..hi * r], &c.h1[lo * d..hi * d], ts, d, r, &mut g[i_qa], ex);
            k::matmul_bwd_x(
                &dhq_a[lo * r..hi * r],
                ad.params[i_qa].as_f32()?,
                ts,
                d,
                r,
                &mut dh1[lo * d..hi * d],
                ex,
            );

            k::matmul_bwd_w(&dv_s[lo * dkv..hi * dkv], &hv_a[lo * r..hi * r], ts, r, dkv, &mut g[i_vb], ex);
            k::matmul_bwd_x(
                &dv_s[lo * dkv..hi * dkv],
                ad.params[i_vb].as_f32()?,
                ts,
                r,
                dkv,
                &mut dhv_a[lo * r..hi * r],
                ex,
            );
            k::matmul_bwd_w(&dhv_a[lo * r..hi * r], &c.h1[lo * d..hi * d], ts, d, r, &mut g[i_va], ex);
            k::matmul_bwd_x(
                &dhv_a[lo * r..hi * r],
                ad.params[i_va].as_f32()?,
                ts,
                d,
                r,
                &mut dh1[lo * d..hi * d],
                ex,
            );
        }

        let mut dx_in = dx_mid;
        k::rmsnorm_bwd(
            &c.x_in,
            p.get(&format!("{pre}norm1"))?,
            &c.rstd1,
            &dh1,
            t,
            d,
            &mut dx_in,
            &mut dg_sink,
            ex,
        );
        dx = dx_in;
    }
    // the embedding is frozen under LoRA: the remaining dx is discarded
    let bwd_s = t_bwd.elapsed().as_secs_f64();

    // ---- per-tenant grad-norm + optimizer, each at its own coordinates --
    let t_optim = Instant::now();
    let mut outs = Vec::with_capacity(slices.len());
    for (ki, sl) in slices.iter().enumerate() {
        let g = &tenant_grads[ki];
        let mut sq = 0.0f32;
        for gi in g {
            for &xv in gi.iter() {
                sq += xv * xv;
            }
        }
        let grad_norm = sq.sqrt();

        // each tenant's optimizer runs in its own state codec; the int8
        // decode-update-encode is strictly sequential (thread-invariant)
        let ad = &mut *adapters[ki];
        let mut int8_scratch = match ad.optim {
            OptimStates::Fp32 => None,
            OptimStates::Int8 => {
                let maxn = ad.params.iter().map(|tn| tn.elements()).max().unwrap_or(0);
                Some((ex.arena().lease_uninit(maxn), ex.arena().lease_uninit(maxn)))
            }
        };
        for i in 0..nt {
            let lr_p = match classify_param(&state.names[i]) {
                ParamGroup::LoraB => sl.lr_b,
                _ => sl.lr,
            };
            let param = ad.params[i].as_f32_mut()?;
            match &mut int8_scratch {
                None => k::adamw(
                    param,
                    &g[i],
                    &mut ad.slot_m[i],
                    &mut ad.slot_v[i],
                    lr_p,
                    sl.step as f32,
                    WEIGHT_DECAY,
                    ex,
                ),
                Some((m_buf, v_buf)) => adamw_update_int8(
                    param,
                    &g[i],
                    &mut ad.qslot_m[i],
                    &mut ad.qslot_v[i],
                    lr_p,
                    sl.step as f32,
                    WEIGHT_DECAY,
                    m_buf,
                    v_buf,
                ),
            }
        }
        let (loss_sum, n_valid) = tenant_fwd[ki];
        outs.push(StepOut {
            loss: loss_sum / n_valid.max(1) as f32,
            grad_norm,
            n_tokens: n_valid as f32,
            phases: StepPhases::default(),
        });
    }
    let optim_s = t_optim.elapsed().as_secs_f64();
    Ok((outs, StepPhases { fwd_s, bwd_s, optim_s }))
}

/// Data-parallel shard gradient (DESIGN.md §10): forward + backward on a
/// single-row view with the CCE normalizer forced to `global_n_valid`, so
/// per-row gradients tree-reduce to the full-batch mean-loss gradient.
/// Flattens the trainable gradients into `out` (state order) and returns
/// `(row loss sum, forward seconds, backward seconds)`.
pub fn grad_row_into(
    state: &CpuState,
    bv: &BatchView,
    global_n_valid: usize,
    out: &mut [f32],
    ex: &Exec,
) -> Result<(f32, f64, f64)> {
    let mut layer_caches: Vec<LayerCache> = Vec::with_capacity(state.dims.n_layers);
    let mut final_cache: Option<FinalCache> = None;
    let t_fwd = Instant::now();
    let (loss_sum, _row_valid) =
        forward(state, bv, Some((&mut layer_caches, &mut final_cache)), ex)?;
    let fwd_s = t_fwd.elapsed().as_secs_f64();
    let mut fc = final_cache.ok_or_else(|| anyhow!("forward did not fill caches"))?;
    // backward reads its loss normalizer from the cache (cce_bwd_fused
    // divides by fc.n_valid); the global count makes shards sum exactly
    fc.n_valid = global_n_valid.max(1);
    let t_bwd = Instant::now();
    let grads = backward(state, bv, &layer_caches, &fc, ex)?;
    let bwd_s = t_bwd.elapsed().as_secs_f64();
    let mut off = 0usize;
    for g in &grads[..state.n_trainable] {
        ensure!(off + g.len() <= out.len(), "gradient lane overflow at offset {off}");
        out[off..off + g.len()].copy_from_slice(g);
        off += g.len();
    }
    ensure!(off == out.len(), "gradient lane length mismatch: wrote {off}, lane {}", out.len());
    Ok((loss_sum, fwd_s, bwd_s))
}

/// Apply one AdamW step from a flat reduced gradient (trainable prefix,
/// state order). Bitwise-identical to the update loop in [`train_step`].
pub fn apply_flat_grads(
    state: &mut CpuState,
    flat: &[f32],
    step: u64,
    lr: f32,
    lr_b: f32,
    ex: &Exec,
) -> Result<()> {
    let mut offs = Vec::with_capacity(state.n_trainable + 1);
    offs.push(0usize);
    for tn in &state.params[..state.n_trainable] {
        offs.push(offs.last().unwrap() + tn.elements());
    }
    ensure!(
        *offs.last().unwrap() == flat.len(),
        "flat gradient length {} != trainable elements {}",
        flat.len(),
        offs.last().unwrap()
    );
    apply_adamw(state, |i| &flat[offs[i]..offs[i + 1]], step, lr, lr_b, ex)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::model as refmodel;
    use crate::backend::cpu::model::{init_state, LoraCfg, ModelDims};
    use crate::util::rng::Rng;

    fn dims() -> ModelDims {
        ModelDims { vocab: 16, d_model: 8, n_layers: 2, n_heads: 2, n_kv_heads: 1, d_ff: 12 }
    }

    /// Same packed fixture as the reference model tests.
    fn batch() -> (Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>, usize, usize) {
        let (bsz, s) = (2usize, 10usize);
        let mut tokens = vec![0i32; bsz * s];
        let mut targets = vec![-1i32; bsz * s];
        let mut seg = vec![0i32; bsz * s];
        let mut pos = vec![0i32; bsz * s];
        let mut rng = Rng::new(99);
        let rows: [&[usize]; 2] = [&[5, 4], &[6]];
        for (b, lens) in rows.iter().enumerate() {
            let mut off = 0usize;
            for (si, &len) in lens.iter().enumerate() {
                for i in 0..len {
                    let t = b * s + off + i;
                    tokens[t] = rng.range(4, 16) as i32;
                    seg[t] = (si + 1) as i32;
                    pos[t] = i as i32;
                    if i > 0 {
                        targets[t - 1] = tokens[t];
                    }
                }
                off += len;
            }
        }
        (tokens, targets, seg, pos, bsz, s)
    }

    fn bv(t: &(Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>, usize, usize)) -> BatchView<'_> {
        BatchView { tokens: &t.0, targets: &t.1, seg: &t.2, pos: &t.3, bsz: t.4, seq: t.5 }
    }

    /// Per-parameter gradient parity against the reference backward — the
    /// strictest cross-implementation check (satellite requirement).
    #[test]
    fn per_parameter_gradients_match_reference() {
        for lora in [None, Some(LoraCfg { rank: 2, alpha: 4.0 })] {
            let state = init_state(dims(), lora, 5);
            let b = batch();
            let view = bv(&b);

            let mut ref_lcs = Vec::new();
            let mut ref_fc = None;
            let (ref_loss, _) =
                refmodel::forward(&state, &view, Some((&mut ref_lcs, &mut ref_fc))).unwrap();
            let ref_grads =
                refmodel::backward(&state, &view, &ref_lcs, &ref_fc.unwrap()).unwrap();

            let ex = Exec::new(2);
            let mut lcs = Vec::new();
            let mut fc = None;
            let (loss, _) = forward(&state, &view, Some((&mut lcs, &mut fc)), &ex).unwrap();
            let grads = backward(&state, &view, &lcs, &fc.unwrap(), &ex).unwrap();

            assert!(
                (loss - ref_loss).abs() < 1e-4 * (1.0 + ref_loss.abs()),
                "lora={lora:?} loss {loss} vs {ref_loss}"
            );
            assert_eq!(grads.len(), ref_grads.len());
            for (gi, (g, rg)) in grads.iter().zip(&ref_grads).enumerate() {
                for (ei, (a, b)) in g.iter().zip(rg).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                        "lora={lora:?} param {gi} ('{}') [{ei}]: {a} vs {b}",
                        state.names[gi]
                    );
                }
            }
        }
    }

    #[test]
    fn loss_decreases_and_matches_reference_trajectory() {
        let b = batch();
        let ex = Exec::new(3);
        let mut fast = init_state(dims(), None, 7);
        let mut reference = init_state(dims(), None, 7);
        for step in 1..=8u64 {
            let fo = train_step(&mut fast, &bv(&b), false, step, 5e-3, 5e-3, &ex).unwrap();
            let ro = refmodel::train_step(&mut reference, &bv(&b), false, step, 5e-3, 5e-3).unwrap();
            assert!(fo.grad_norm > 0.0);
            assert!(
                (fo.loss - ro.loss).abs() < 1e-4 * (1.0 + ro.loss.abs()),
                "step {step}: {} vs {}",
                fo.loss,
                ro.loss
            );
            let rel = (fo.grad_norm - ro.grad_norm).abs() / ro.grad_norm.max(1e-12);
            assert!(rel < 1e-3, "step {step}: grad_norm {} vs {}", fo.grad_norm, ro.grad_norm);
        }
    }

    #[test]
    fn step_bits_invariant_to_thread_count() {
        let b = batch();
        let run = |threads: usize| {
            let ex = Exec::new(threads);
            let mut state = init_state(dims(), Some(LoraCfg { rank: 2, alpha: 4.0 }), 42);
            let mut bits = Vec::new();
            for step in 1..=4u64 {
                let out =
                    train_step(&mut state, &bv(&b), false, step, 3e-3, 6e-3, &ex).unwrap();
                bits.push((out.loss.to_bits(), out.grad_norm.to_bits()));
            }
            bits
        };
        let one = run(1);
        assert_eq!(one, run(1), "threads=1 not run-to-run deterministic");
        assert_eq!(one, run(2), "threads=2 changed bits");
        assert_eq!(one, run(5), "threads=5 changed bits");
    }

    #[test]
    fn broken_mode_has_zero_grad() {
        let ex = Exec::new(2);
        let mut state = init_state(dims(), None, 7);
        let b = batch();
        let o1 = train_step(&mut state, &bv(&b), true, 1, 5e-3, 5e-3, &ex).unwrap();
        let o2 = train_step(&mut state, &bv(&b), true, 2, 5e-3, 5e-3, &ex).unwrap();
        assert_eq!(o1.grad_norm, 0.0);
        assert_eq!(o1.loss.to_bits(), o2.loss.to_bits(), "params moved in broken mode");
    }

    #[test]
    fn eval_matches_train_loss_before_update() {
        let ex = Exec::new(2);
        let mut state = init_state(dims(), None, 3);
        let b = batch();
        let e = eval_loss(&state, &bv(&b), &ex).unwrap();
        let out = train_step(&mut state, &bv(&b), false, 1, 1e-3, 1e-3, &ex).unwrap();
        assert_eq!(e.to_bits(), out.loss.to_bits());
    }

    #[test]
    fn warm_arena_train_steps_stop_allocating() {
        // the train-step-level version of the arena contract: after the
        // cold first step, further steps lease everything from the free
        // list (the integration-level assertion lives in
        // rust/tests/no_materialization.rs on a larger geometry)
        let ex = Exec::new(2);
        let mut state = init_state(dims(), None, 11);
        let b = batch();
        train_step(&mut state, &bv(&b), false, 1, 1e-3, 1e-3, &ex).unwrap();
        let cold = ex.arena().heap_allocs();
        assert!(cold > 0, "first step must populate the arena");
        for step in 2..=5u64 {
            train_step(&mut state, &bv(&b), false, step, 1e-3, 1e-3, &ex).unwrap();
        }
        assert_eq!(ex.arena().heap_allocs(), cold, "steady-state steps must not allocate");
    }

    /// Fused intra-step round vs the fast serial swap-in/train/swap-out
    /// path, on a ragged round (1-row + 2-row tenants) with LoRA+ dual LR:
    /// losses, grad norms, adapter weights and optimizer slots must match
    /// bit-for-bit (the DESIGN.md §11 separability contract on this
    /// backend's pooled/tiled kernels).
    #[test]
    fn fused_step_matches_fast_serial_bitwise() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let base_seed = 11;
        let b = batch();
        let seq = b.5;
        let a_view = BatchView {
            tokens: &b.0[..seq],
            targets: &b.1[..seq],
            seg: &b.2[..seq],
            pos: &b.3[..seq],
            bsz: 1,
            seq,
        };
        let cat = |v: &Vec<i32>| {
            let mut out = v[..seq].to_vec();
            out.extend_from_slice(v);
            out
        };
        let (ct, cg, cs, cp) = (cat(&b.0), cat(&b.1), cat(&b.2), cat(&b.3));
        let concat = BatchView { tokens: &ct, targets: &cg, seg: &cs, pos: &cp, bsz: 3, seq };

        let ex = Exec::new(2);
        let serial = |seed: i32, view: &BatchView, steps: u64, lr: f32, lr_b: f32| {
            let mut st = init_state(dims(), Some(lora), base_seed);
            let mut ad = refmodel::init_adapter(dims(), lora, seed);
            refmodel::swap_adapter(&mut st, &mut ad).unwrap();
            let mut outs = Vec::new();
            for step in 1..=steps {
                outs.push(train_step(&mut st, view, false, step, lr, lr_b, &ex).unwrap());
            }
            refmodel::swap_adapter(&mut st, &mut ad).unwrap();
            (outs, ad)
        };
        // tenant B runs LoRA+ (lr_b != lr) to exercise the dual-LR path
        let (sa, ada) = serial(100, &a_view, 4, 5e-3, 5e-3);
        let (sb, adb) = serial(200, &bv(&b), 4, 5e-3, 8e-3);

        let ws = init_state(dims(), Some(lora), base_seed);
        let mut t1 = refmodel::init_adapter(dims(), lora, 100);
        let mut t2 = refmodel::init_adapter(dims(), lora, 200);
        let mut fa = Vec::new();
        let mut fb = Vec::new();
        for step in 1..=4u64 {
            let slices = [
                FusedSlice { row_start: 0, rows: 1, step, lr: 5e-3, lr_b: 5e-3 },
                FusedSlice { row_start: 1, rows: 2, step, lr: 5e-3, lr_b: 8e-3 },
            ];
            let mut ads = [&mut t1, &mut t2];
            let (outs, _) = fused_train_step(&ws, &mut ads, &concat, &slices, &ex).unwrap();
            assert_eq!(outs.len(), 2);
            fa.push(outs[0]);
            fb.push(outs[1]);
        }
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for (fused, serial) in [(&fa, &sa), (&fb, &sb)] {
            for (fo, so) in fused.iter().zip(serial.iter()) {
                assert_eq!(fo.loss.to_bits(), so.loss.to_bits(), "loss diverges");
                assert_eq!(fo.grad_norm.to_bits(), so.grad_norm.to_bits(), "grad_norm diverges");
                assert_eq!(fo.n_tokens, so.n_tokens);
            }
        }
        for (fused, serial) in [(&t1, &ada), (&t2, &adb)] {
            for i in 0..fused.params.len() {
                assert_eq!(
                    bits(fused.params[i].as_f32().unwrap()),
                    bits(serial.params[i].as_f32().unwrap()),
                    "adapter weights diverge at {}",
                    fused.names[i]
                );
                assert_eq!(bits(&fused.slot_m[i]), bits(&serial.slot_m[i]), "slot_m diverges");
                assert_eq!(bits(&fused.slot_v[i]), bits(&serial.slot_v[i]), "slot_v diverges");
            }
        }
    }

    /// The fused round keeps the fast backend's thread-count bitwise
    /// invariance: a two-tenant round at 1, 2 and 5 threads produces
    /// identical step metrics and identical final adapter bits.
    #[test]
    fn fused_step_bits_invariant_to_thread_count() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let b = batch();
        let seq = b.5;
        let cat = |v: &Vec<i32>| {
            let mut out = v[..seq].to_vec();
            out.extend_from_slice(v);
            out
        };
        let (ct, cg, cs, cp) = (cat(&b.0), cat(&b.1), cat(&b.2), cat(&b.3));
        let run = |threads: usize| {
            let concat = BatchView { tokens: &ct, targets: &cg, seg: &cs, pos: &cp, bsz: 3, seq };
            let ex = Exec::new(threads);
            let ws = init_state(dims(), Some(lora), 3);
            let mut t1 = refmodel::init_adapter(dims(), lora, 21);
            let mut t2 = refmodel::init_adapter(dims(), lora, 22);
            let mut step_bits = Vec::new();
            for step in 1..=3u64 {
                let slices = [
                    FusedSlice { row_start: 0, rows: 1, step, lr: 3e-3, lr_b: 6e-3 },
                    FusedSlice { row_start: 1, rows: 2, step, lr: 3e-3, lr_b: 6e-3 },
                ];
                let mut ads = [&mut t1, &mut t2];
                let (outs, _) = fused_train_step(&ws, &mut ads, &concat, &slices, &ex).unwrap();
                for o in &outs {
                    step_bits.push((o.loss.to_bits(), o.grad_norm.to_bits()));
                }
            }
            let mut param_bits = Vec::new();
            for ad in [&t1, &t2] {
                for tn in &ad.params {
                    param_bits
                        .push(tn.as_f32().unwrap().iter().map(|x| x.to_bits()).collect::<Vec<_>>());
                }
            }
            (step_bits, param_bits)
        };
        let one = run(1);
        assert_eq!(one, run(2), "threads=2 changed fused-round bits");
        assert_eq!(one, run(5), "threads=5 changed fused-round bits");
    }

    #[test]
    fn out_of_vocab_token_rejected() {
        let ex = Exec::new(1);
        let state = init_state(dims(), None, 7);
        let tokens = vec![99i32];
        let targets = vec![-1i32];
        let seg = vec![1i32];
        let pos = vec![0i32];
        let view =
            BatchView { tokens: &tokens, targets: &targets, seg: &seg, pos: &pos, bsz: 1, seq: 1 };
        assert!(eval_loss(&state, &view, &ex).is_err());
    }

    /// Memory-tier oracle parity (DESIGN.md §12): the fast backend's
    /// tile-at-a-time dequantizing kernels against the reference backend's
    /// naive whole-matrix dequantization, on identically-quantized states.
    /// Both see bitwise-identical dequantized weights, so the usual fast
    /// vs. reference loss tolerance applies unchanged — for both codecs.
    #[test]
    fn quantized_base_lora_matches_reference_and_learns() {
        use crate::quant::BaseQuant;
        let lora = Some(LoraCfg { rank: 2, alpha: 4.0 });
        for codec in [BaseQuant::Int8, BaseQuant::Fp8] {
            let mut fast = init_state(dims(), lora, 5);
            refmodel::quantize_base(&mut fast, codec).unwrap();
            let mut reference = init_state(dims(), lora, 5);
            refmodel::quantize_base(&mut reference, codec).unwrap();
            let b = batch();
            let ex = Exec::new(2);
            let mut first = None;
            let mut last = None;
            for step in 1..=6u64 {
                let fo = train_step(&mut fast, &bv(&b), false, step, 5e-3, 5e-3, &ex).unwrap();
                let ro =
                    refmodel::train_step(&mut reference, &bv(&b), false, step, 5e-3, 5e-3)
                        .unwrap();
                assert!(
                    (fo.loss - ro.loss).abs() < 1e-4 * (1.0 + ro.loss.abs()),
                    "{codec:?} step {step}: {} vs {}",
                    fo.loss,
                    ro.loss
                );
                assert!(fo.grad_norm > 0.0, "{codec:?}: dead gradients");
                first.get_or_insert(fo.loss);
                last = Some(fo.loss);
            }
            assert!(last.unwrap() < first.unwrap(), "{codec:?}: loss did not decrease");
        }
    }

    /// Recompute-from-boundary replays the exact kernel sequence, so the
    /// checkpointed fast step must match the cached fast step bit-for-bit.
    #[test]
    fn checkpointed_fast_training_is_bitwise_identical() {
        let b = batch();
        let ex = Exec::new(3);
        let mut plain = init_state(dims(), None, 7);
        let mut ckpt = init_state(dims(), None, 7);
        ckpt.ckpt_segments = 2;
        for step in 1..=5u64 {
            let a = train_step(&mut plain, &bv(&b), false, step, 5e-3, 5e-3, &ex).unwrap();
            let c = train_step(&mut ckpt, &bv(&b), false, step, 5e-3, 5e-3, &ex).unwrap();
            assert_eq!(a.loss.to_bits(), c.loss.to_bits(), "step {step} loss");
            assert_eq!(a.grad_norm.to_bits(), c.grad_norm.to_bits(), "step {step} grad_norm");
        }
        for (i, (x, y)) in plain.params.iter().zip(&ckpt.params).enumerate() {
            assert_eq!(x, y, "param {} diverged under checkpointing", plain.names[i]);
        }
    }

    /// Zero int8 slots decode to exactly 0.0, so the first optimizer step
    /// is bitwise identical to fp32 states; later steps drift within the
    /// Eq. 18 bound (pinned loosely here, tightly in rust/tests/parity.rs).
    #[test]
    fn int8_optim_first_step_bitwise_then_tracks_fp32() {
        let b = batch();
        let ex = Exec::new(2);
        let mut fp = init_state(dims(), None, 9);
        let mut q8 = init_state(dims(), None, 9);
        refmodel::set_optim_states(&mut q8, OptimStates::Int8).unwrap();
        let a = train_step(&mut fp, &bv(&b), false, 1, 5e-3, 5e-3, &ex).unwrap();
        let c = train_step(&mut q8, &bv(&b), false, 1, 5e-3, 5e-3, &ex).unwrap();
        assert_eq!(a.loss.to_bits(), c.loss.to_bits());
        for (x, y) in fp.params.iter().zip(&q8.params) {
            assert_eq!(x, y, "step-1 params must be bitwise equal across optimizer codecs");
        }
        for step in 2..=12u64 {
            let a = train_step(&mut fp, &bv(&b), false, step, 5e-3, 5e-3, &ex).unwrap();
            let c = train_step(&mut q8, &bv(&b), false, step, 5e-3, 5e-3, &ex).unwrap();
            assert!((a.loss - c.loss).abs() < 0.05, "step {step}: {} vs {}", a.loss, c.loss);
        }
    }

    /// The determinism ladder's quantized rung: int8 states + int8 base +
    /// checkpointing together stay bitwise invariant across thread counts
    /// (tile-order reductions + strictly-sequential int8 optimizer).
    #[test]
    fn quantized_tiers_step_bits_invariant_to_thread_count() {
        use crate::quant::BaseQuant;
        let b = batch();
        let run = |threads: usize| {
            let ex = Exec::new(threads);
            let mut state = init_state(dims(), Some(LoraCfg { rank: 2, alpha: 4.0 }), 42);
            refmodel::set_optim_states(&mut state, OptimStates::Int8).unwrap();
            refmodel::quantize_base(&mut state, BaseQuant::Int8).unwrap();
            state.ckpt_segments = 2;
            let mut bits = Vec::new();
            for step in 1..=4u64 {
                let out = train_step(&mut state, &bv(&b), false, step, 3e-3, 6e-3, &ex).unwrap();
                bits.push((out.loss.to_bits(), out.grad_norm.to_bits()));
            }
            bits
        };
        let one = run(1);
        assert_eq!(one, run(2), "threads=2 changed quantized-tier bits");
        assert_eq!(one, run(8), "threads=8 changed quantized-tier bits");
    }

    /// The fused multi-tenant round over a *quantized* shared base must
    /// still match the fast serial swap-in path bit-for-bit: the shared
    /// base pass dequantizes the same tiles either way.
    #[test]
    fn fused_step_on_quantized_base_matches_fast_serial_bitwise() {
        use crate::quant::BaseQuant;
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let b = batch();
        let ex = Exec::new(2);

        let mut st = init_state(dims(), Some(lora), 11);
        refmodel::quantize_base(&mut st, BaseQuant::Int8).unwrap();
        let mut ad = refmodel::init_adapter(dims(), lora, 77);
        refmodel::swap_adapter(&mut st, &mut ad).unwrap();
        let mut serial = Vec::new();
        for step in 1..=3u64 {
            serial.push(train_step(&mut st, &bv(&b), false, step, 5e-3, 8e-3, &ex).unwrap());
        }
        refmodel::swap_adapter(&mut st, &mut ad).unwrap();

        let mut ws = init_state(dims(), Some(lora), 11);
        refmodel::quantize_base(&mut ws, BaseQuant::Int8).unwrap();
        let mut t1 = refmodel::init_adapter(dims(), lora, 77);
        for step in 1..=3u64 {
            let slices = [FusedSlice { row_start: 0, rows: 2, step, lr: 5e-3, lr_b: 8e-3 }];
            let mut ads = [&mut t1];
            let (outs, _) = fused_train_step(&ws, &mut ads, &bv(&b), &slices, &ex).unwrap();
            let s = &serial[(step - 1) as usize];
            assert_eq!(outs[0].loss.to_bits(), s.loss.to_bits(), "step {step} loss");
            assert_eq!(outs[0].grad_norm.to_bits(), s.grad_norm.to_bits(), "step {step} norm");
        }
        for i in 0..t1.params.len() {
            assert_eq!(t1.params[i], ad.params[i], "adapter diverges at {}", t1.names[i]);
        }
    }

    /// Two tenants fed identical rows, one on fp32 states and one on int8:
    /// step 1 is bitwise identical (zero slots decode equal), later steps
    /// stay close while the int8 tenant's moments live in the codec.
    #[test]
    fn fused_step_honors_per_adapter_optimizer_codec() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let b = batch();
        let seq = b.5;
        let cat = |v: &Vec<i32>| {
            let mut out = v[..seq].to_vec();
            out.extend_from_slice(&v[..seq]);
            out
        };
        let (ct, cg, cs, cp) = (cat(&b.0), cat(&b.1), cat(&b.2), cat(&b.3));
        let concat = BatchView { tokens: &ct, targets: &cg, seg: &cs, pos: &cp, bsz: 2, seq };
        let ex = Exec::new(2);
        let ws = init_state(dims(), Some(lora), 3);
        let mut t1 = refmodel::init_adapter(dims(), lora, 50);
        let mut t2 = refmodel::init_adapter(dims(), lora, 50);
        refmodel::set_adapter_optim(&mut t2, OptimStates::Int8).unwrap();
        for step in 1..=6u64 {
            let slices = [
                FusedSlice { row_start: 0, rows: 1, step, lr: 5e-3, lr_b: 5e-3 },
                FusedSlice { row_start: 1, rows: 1, step, lr: 5e-3, lr_b: 5e-3 },
            ];
            let mut ads = [&mut t1, &mut t2];
            let (outs, _) = fused_train_step(&ws, &mut ads, &concat, &slices, &ex).unwrap();
            if step == 1 {
                assert_eq!(outs[0].loss.to_bits(), outs[1].loss.to_bits());
            } else {
                assert!((outs[0].loss - outs[1].loss).abs() < 0.05, "step {step}");
            }
        }
        assert!(t2.slot_m.iter().all(|s| s.is_empty()), "fp32 slots must stay retired");
        assert!(t2.qslot_m.iter().any(|s| s.len() > 0), "int8 slots must be live");
        for i in 0..t1.params.len() {
            for (a, q) in
                t1.params[i].as_f32().unwrap().iter().zip(t2.params[i].as_f32().unwrap())
            {
                assert!((a - q).abs() < 0.01, "codec drift too large at {}", t1.names[i]);
            }
        }
    }

    /// `--ckpt-segments 2` keeps at most one segment's activations plus
    /// the boundary stack live, so the warm-arena concurrent-lease peak
    /// must land below the cached-forward peak on the same geometry.
    #[test]
    fn checkpointing_lowers_concurrent_activation_peak() {
        let dims4 =
            ModelDims { vocab: 16, d_model: 8, n_layers: 4, n_heads: 2, n_kv_heads: 1, d_ff: 12 };
        let b = batch();
        let peak = |segs: usize| {
            let ex = Exec::new(1);
            let mut state = init_state(dims4, None, 5);
            state.ckpt_segments = segs;
            // warm the arena, then measure a steady-state step
            train_step(&mut state, &bv(&b), false, 1, 1e-3, 1e-3, &ex).unwrap();
            ex.arena().reset_peak();
            train_step(&mut state, &bv(&b), false, 2, 1e-3, 1e-3, &ex).unwrap();
            ex.arena().peak_total_elems()
        };
        let full = peak(0);
        let two = peak(2);
        assert!(two < full, "ckpt=2 peak {two} not below no-ckpt peak {full}");
    }
}
