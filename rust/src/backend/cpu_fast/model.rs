//! The fast CPU train step: the reference architecture executed through
//! the fused/tiled/pooled kernels of this module's siblings.
//!
//! Same state layout, same parameter names, same batch semantics as
//! `backend::cpu::model` — the two backends share `CpuState`, so
//! checkpoints and init are interchangeable and parity tests line up
//! parameter-for-parameter. What differs is execution:
//!
//! * attention never materializes `[B, Hq, S, S]` (online softmax forward,
//!   recompute backward — `attention.rs`);
//! * the loss never materializes `[T, V]` (streaming CCE — `cce.rs`);
//! * RMSNorm feeds its projections fused, matmuls carry residual adds, and
//!   every row-parallel kernel dispatches on the backend's persistent
//!   worker pool (`pool.rs`);
//! * every f32 working buffer — activations, caches, gradients, kernel
//!   scratch — is leased from the backend's arena (`scratch.rs`), so
//!   steady-state steps perform zero arena heap allocations after the
//!   first (warm-arena) step.
//!
//! Numerics: reassociation (8-lane dots, online softmax) legitimately
//! changes low-order bits vs. the sequential reference, so cross-backend
//! parity is tolerance-based (loss |Δ| ≤ 1e-4, grad-norm rel ≤ 1e-3 —
//! `rust/tests/parity.rs`), while the fast backend itself is bitwise
//! deterministic run-to-run *and* across thread counts (every cross-tile
//! reduction happens in fixed tile order).

use super::attention::{flash_attention_bwd, flash_attention_fwd};
use super::cce::{cce_bwd_fused, cce_loss_fwd};
use super::kernels as k;
use super::pool::Exec;
use super::scratch::Lease;
use crate::backend::cpu::model::{
    check_fused_inputs, BatchView, CpuAdapter, CpuState, ParamIdx, StepOut, WEIGHT_DECAY,
};
use crate::backend::{FusedSlice, StepPhases};
use crate::optim::{classify_param, ParamGroup};
use anyhow::{anyhow, bail, ensure, Result};
use std::time::Instant;

/// Per-layer forward activations kept for the backward pass, all leased
/// from the backend arena. Identical to the reference cache except
/// `probs: [B, Hq, S, S]` is replaced by `lse: [B, Hq, S]` (linear in S).
struct LayerCache<'e> {
    x_in: Lease<'e>,
    h1: Lease<'e>,
    rstd1: Lease<'e>,
    q: Lease<'e>,  // post-RoPE
    kk: Lease<'e>, // post-RoPE
    v: Lease<'e>,
    hq_a: Option<Lease<'e>>,
    hv_a: Option<Lease<'e>>,
    att: Lease<'e>, // attention output (pre-Wo); doubles as the bwd `out`
    lse: Lease<'e>, // [B, Hq, S] logsumexp per query row
    x_mid: Lease<'e>,
    h2: Lease<'e>,
    rstd2: Lease<'e>,
    gate: Lease<'e>,
    up: Lease<'e>,
    y: Lease<'e>,
}

struct FinalCache<'e> {
    x_f: Lease<'e>,
    hf: Lease<'e>,
    rstd_f: Lease<'e>,
    lse: Lease<'e>, // [T] streaming logsumexp (replaces [T, V] probs)
    n_valid: usize,
}

/// Forward pass; fills `caches` when training. Returns summed loss +
/// valid-target count (mean reduction is the caller's, like the reference).
fn forward<'e>(
    state: &CpuState,
    bv: &BatchView,
    caches: Option<(&mut Vec<LayerCache<'e>>, &mut Option<FinalCache<'e>>)>,
    ex: &'e Exec,
) -> Result<(f32, usize)> {
    let dims = &state.dims;
    let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
    let (hq, hkv, hd) = (dims.n_heads, dims.n_kv_heads, dims.head_dim());
    let dkv = dims.d_kv();
    let t = bv.bsz * bv.seq;
    let p = ParamIdx::new(&state.names, &state.params);

    for (i, &tok) in bv.tokens.iter().enumerate() {
        if tok < 0 || tok as usize >= v {
            bail!("token id {tok} at position {i} out of vocab range 0..{v}");
        }
    }
    for (i, &tgt) in bv.targets.iter().enumerate() {
        if tgt >= v as i32 {
            bail!("target id {tgt} at position {i} out of vocab range");
        }
    }

    let embed = p.get("embed")?;
    let mut x = ex.arena().lease_uninit(t * d);
    for ti in 0..t {
        let tok = bv.tokens[ti] as usize;
        x[ti * d..(ti + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }

    let mut caches = caches;

    for l in 0..dims.n_layers {
        let pre = format!("layer_{l:02}.");
        let x_in = x;

        let mut h1 = ex.arena().lease_uninit(t * d);
        let mut rstd1 = ex.arena().lease_uninit(t);
        let mut q = ex.arena().lease_uninit(t * d);
        let mut kk = ex.arena().lease_uninit(t * dkv);
        let mut vv = ex.arena().lease_uninit(t * dkv);
        k::fused_rmsnorm_qkv(
            &x_in,
            p.get(&format!("{pre}norm1"))?,
            p.get(&format!("{pre}wq"))?,
            p.get(&format!("{pre}wk"))?,
            p.get(&format!("{pre}wv"))?,
            t,
            d,
            dkv,
            &mut h1,
            &mut rstd1,
            &mut q,
            &mut kk,
            &mut vv,
            ex,
        );

        let (mut hq_a, mut hv_a) = (None, None);
        if let Some(lc) = &state.lora {
            let r = lc.rank;
            let s = lc.scale();
            let mut ha = ex.arena().lease_uninit(t * r);
            k::lora_linear(
                &h1,
                p.get(&format!("{pre}wq_a"))?,
                p.get(&format!("{pre}wq_b"))?,
                t,
                d,
                r,
                d,
                s,
                &mut ha,
                &mut q,
                ex,
            );
            hq_a = Some(ha);
            let mut ha = ex.arena().lease_uninit(t * r);
            k::lora_linear(
                &h1,
                p.get(&format!("{pre}wv_a"))?,
                p.get(&format!("{pre}wv_b"))?,
                t,
                d,
                r,
                dkv,
                s,
                &mut ha,
                &mut vv,
                ex,
            );
            hv_a = Some(ha);
        }

        k::rope(&mut q, bv.pos, t, hq, hd, 1.0, ex);
        k::rope(&mut kk, bv.pos, t, hkv, hd, 1.0, ex);

        let mut att = ex.arena().lease_uninit(t * d);
        let mut lse = ex.arena().lease_uninit(bv.bsz * hq * bv.seq);
        flash_attention_fwd(
            &q, &kk, &vv, bv.seg, bv.bsz, bv.seq, hq, hkv, hd, &mut att, &mut lse, ex,
        );

        let mut x_mid = ex.arena().lease_uninit(t * d);
        k::matmul_residual(&att, p.get(&format!("{pre}wo"))?, &x_in, t, d, d, &mut x_mid, ex);

        let mut h2 = ex.arena().lease_uninit(t * d);
        let mut rstd2 = ex.arena().lease_uninit(t);
        let mut gate = ex.arena().lease_uninit(t * f);
        let mut up = ex.arena().lease_uninit(t * f);
        let mut y = ex.arena().lease_uninit(t * f);
        k::fused_rmsnorm_swiglu(
            &x_mid,
            p.get(&format!("{pre}norm2"))?,
            p.get(&format!("{pre}w_gate"))?,
            p.get(&format!("{pre}w_up"))?,
            t,
            d,
            f,
            &mut h2,
            &mut rstd2,
            &mut gate,
            &mut up,
            &mut y,
            ex,
        );

        let mut x_out = ex.arena().lease_uninit(t * d);
        k::matmul_residual(&y, p.get(&format!("{pre}w_down"))?, &x_mid, t, f, d, &mut x_out, ex);

        if let Some((lcs, _)) = caches.as_mut() {
            lcs.push(LayerCache {
                x_in,
                h1,
                rstd1,
                q,
                kk,
                v: vv,
                hq_a,
                hv_a,
                att,
                lse,
                x_mid,
                h2,
                rstd2,
                gate,
                up,
                y,
            });
        }
        x = x_out;
    }

    let x_f = x;
    let mut hf = ex.arena().lease_uninit(t * d);
    let mut rstd_f = ex.arena().lease_uninit(t);
    k::rmsnorm(&x_f, p.get("norm_f")?, t, d, &mut hf, &mut rstd_f, ex);
    let mut lse = ex.arena().lease_uninit(t);
    let (loss_sum, n_valid) =
        cce_loss_fwd(&hf, p.get("w_head")?, bv.targets, t, d, v, &mut lse, ex);

    if let Some((_, fc)) = caches.as_mut() {
        **fc = Some(FinalCache { x_f, hf, rstd_f, lse, n_valid });
    }
    Ok((loss_sum, n_valid))
}

/// Full backward pass; gradients aligned with `state.params` (frozen
/// entries stay zero except where the dx chain needs them — same contract
/// as the reference backward).
fn backward<'e>(
    state: &CpuState,
    bv: &BatchView,
    layer_caches: &[LayerCache<'e>],
    fc: &FinalCache<'e>,
    ex: &'e Exec,
) -> Result<Vec<Lease<'e>>> {
    let dims = &state.dims;
    let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
    let (hq, hkv, hd) = (dims.n_heads, dims.n_kv_heads, dims.head_dim());
    let dkv = dims.d_kv();
    let t = bv.bsz * bv.seq;
    let p = ParamIdx::new(&state.names, &state.params);
    let mut grads: Vec<Lease<'e>> =
        state.params.iter().map(|tn| ex.arena().lease(tn.elements())).collect();
    let nt = state.n_trainable;

    // CCE backward: dW_head and dhf in one fused tile loop, no [T, V]
    let i_head = p.id("w_head")?;
    let mut dhf = ex.arena().lease(t * d);
    {
        let dw_head = if i_head < nt { Some(grads[i_head].as_mut_slice()) } else { None };
        cce_bwd_fused(
            &fc.hf,
            p.get("w_head")?,
            bv.targets,
            &fc.lse,
            t,
            d,
            v,
            fc.n_valid,
            dw_head,
            &mut dhf,
            ex,
        );
    }

    let mut dx = ex.arena().lease(t * d);
    let i_nf = p.id("norm_f")?;
    k::rmsnorm_bwd(&fc.x_f, p.get("norm_f")?, &fc.rstd_f, &dhf, t, d, &mut dx, &mut grads[i_nf], ex);

    for l in (0..dims.n_layers).rev() {
        let pre = format!("layer_{l:02}.");
        let c = &layer_caches[l];

        // x_out = x_mid + y @ w_down.T
        let i_down = p.id(&format!("{pre}w_down"))?;
        if i_down < nt {
            k::matmul_bwd_w(&dx, &c.y, t, f, d, &mut grads[i_down], ex);
        }
        let mut dy = ex.arena().lease(t * f);
        k::matmul_bwd_x(&dx, p.get(&format!("{pre}w_down"))?, t, f, d, &mut dy, ex);

        let mut dgate = ex.arena().lease(t * f);
        let mut dup = ex.arena().lease(t * f);
        k::swiglu_bwd(&c.gate, &c.up, &dy, &mut dgate, &mut dup, ex);

        let i_gate = p.id(&format!("{pre}w_gate"))?;
        let i_up = p.id(&format!("{pre}w_up"))?;
        if i_gate < nt {
            k::matmul_bwd_w(&dgate, &c.h2, t, d, f, &mut grads[i_gate], ex);
        }
        if i_up < nt {
            k::matmul_bwd_w(&dup, &c.h2, t, d, f, &mut grads[i_up], ex);
        }
        let mut dh2 = ex.arena().lease(t * d);
        k::matmul_bwd_x(&dgate, p.get(&format!("{pre}w_gate"))?, t, d, f, &mut dh2, ex);
        k::matmul_bwd_x(&dup, p.get(&format!("{pre}w_up"))?, t, d, f, &mut dh2, ex);

        let i_n2 = p.id(&format!("{pre}norm2"))?;
        let mut dx_mid = dx; // residual passthrough...
        k::rmsnorm_bwd(
            &c.x_mid,
            p.get(&format!("{pre}norm2"))?,
            &c.rstd2,
            &dh2,
            t,
            d,
            &mut dx_mid, // ...plus the norm branch accumulated
            &mut grads[i_n2],
            ex,
        );

        // x_mid = x_in + att @ wo.T
        let i_wo = p.id(&format!("{pre}wo"))?;
        if i_wo < nt {
            k::matmul_bwd_w(&dx_mid, &c.att, t, d, d, &mut grads[i_wo], ex);
        }
        let mut datt = ex.arena().lease(t * d);
        k::matmul_bwd_x(&dx_mid, p.get(&format!("{pre}wo"))?, t, d, d, &mut datt, ex);

        let mut dq = ex.arena().lease(t * d);
        let mut dk = ex.arena().lease(t * dkv);
        let mut dv = ex.arena().lease(t * dkv);
        flash_attention_bwd(
            &datt, &c.q, &c.kk, &c.v, &c.att, &c.lse, bv.seg, bv.bsz, bv.seq, hq, hkv, hd,
            &mut dq, &mut dk, &mut dv, ex,
        );
        k::rope(&mut dq, bv.pos, t, hq, hd, -1.0, ex);
        k::rope(&mut dk, bv.pos, t, hkv, hd, -1.0, ex);

        let i_wq = p.id(&format!("{pre}wq"))?;
        let i_wk = p.id(&format!("{pre}wk"))?;
        let i_wv = p.id(&format!("{pre}wv"))?;
        if i_wq < nt {
            k::matmul_bwd_w(&dq, &c.h1, t, d, d, &mut grads[i_wq], ex);
        }
        if i_wk < nt {
            k::matmul_bwd_w(&dk, &c.h1, t, d, dkv, &mut grads[i_wk], ex);
        }
        if i_wv < nt {
            k::matmul_bwd_w(&dv, &c.h1, t, d, dkv, &mut grads[i_wv], ex);
        }
        let mut dh1 = ex.arena().lease(t * d);
        k::matmul_bwd_x(&dq, p.get(&format!("{pre}wq"))?, t, d, d, &mut dh1, ex);
        k::matmul_bwd_x(&dk, p.get(&format!("{pre}wk"))?, t, d, dkv, &mut dh1, ex);
        k::matmul_bwd_x(&dv, p.get(&format!("{pre}wv"))?, t, d, dkv, &mut dh1, ex);

        if let Some(lc) = &state.lora {
            let (r, s) = (lc.rank, lc.scale());
            let hq_a = c.hq_a.as_ref().expect("lora cache");
            let hv_a = c.hv_a.as_ref().expect("lora cache");
            let mut dq_s = ex.arena().lease_uninit(t * d);
            for (o, &g) in dq_s.iter_mut().zip(dq.iter()) {
                *o = s * g;
            }
            let i_qb = p.id(&format!("{pre}wq_b"))?;
            let i_qa = p.id(&format!("{pre}wq_a"))?;
            k::matmul_bwd_w(&dq_s, hq_a, t, r, d, &mut grads[i_qb], ex);
            let mut dhq_a = ex.arena().lease(t * r);
            k::matmul_bwd_x(&dq_s, p.get(&format!("{pre}wq_b"))?, t, r, d, &mut dhq_a, ex);
            k::matmul_bwd_w(&dhq_a, &c.h1, t, d, r, &mut grads[i_qa], ex);
            k::matmul_bwd_x(&dhq_a, p.get(&format!("{pre}wq_a"))?, t, d, r, &mut dh1, ex);

            let mut dv_s = ex.arena().lease_uninit(t * dkv);
            for (o, &g) in dv_s.iter_mut().zip(dv.iter()) {
                *o = s * g;
            }
            let i_vb = p.id(&format!("{pre}wv_b"))?;
            let i_va = p.id(&format!("{pre}wv_a"))?;
            k::matmul_bwd_w(&dv_s, hv_a, t, r, dkv, &mut grads[i_vb], ex);
            let mut dhv_a = ex.arena().lease(t * r);
            k::matmul_bwd_x(&dv_s, p.get(&format!("{pre}wv_b"))?, t, r, dkv, &mut dhv_a, ex);
            k::matmul_bwd_w(&dhv_a, &c.h1, t, d, r, &mut grads[i_va], ex);
            k::matmul_bwd_x(&dhv_a, p.get(&format!("{pre}wv_a"))?, t, d, r, &mut dh1, ex);
        }

        let i_n1 = p.id(&format!("{pre}norm1"))?;
        let mut dx_in = dx_mid; // residual passthrough
        k::rmsnorm_bwd(
            &c.x_in,
            p.get(&format!("{pre}norm1"))?,
            &c.rstd1,
            &dh1,
            t,
            d,
            &mut dx_in,
            &mut grads[i_n1],
            ex,
        );
        dx = dx_in;
    }

    let i_embed = p.id("embed")?;
    if i_embed < nt {
        for ti in 0..t {
            let tok = bv.tokens[ti] as usize;
            let ge = &mut grads[i_embed][tok * d..(tok + 1) * d];
            for i in 0..d {
                ge[i] += dx[ti * d + i];
            }
        }
    }
    Ok(grads)
}

/// Forward-only mean loss (the eval path).
pub fn eval_loss(state: &CpuState, bv: &BatchView, ex: &Exec) -> Result<f32> {
    let (loss_sum, n_valid) = forward(state, bv, None, ex)?;
    Ok(loss_sum / n_valid.max(1) as f32)
}

/// One full fast train step: forward, backward, grad-norm, AdamW with the
/// LoRA+ dual LR. Mirrors the reference `train_step` contract exactly,
/// including the `broken` zero-gradient mode.
pub fn train_step(
    state: &mut CpuState,
    bv: &BatchView,
    broken: bool,
    step: u64,
    lr: f32,
    lr_b: f32,
    ex: &Exec,
) -> Result<StepOut> {
    let mut layer_caches: Vec<LayerCache> = Vec::with_capacity(state.dims.n_layers);
    let mut final_cache: Option<FinalCache> = None;
    let t_fwd = Instant::now();
    let (loss_sum, n_valid) =
        forward(state, bv, Some((&mut layer_caches, &mut final_cache)), ex)?;
    let fwd_s = t_fwd.elapsed().as_secs_f64();
    let loss = loss_sum / n_valid.max(1) as f32;

    if broken {
        let phases = StepPhases { fwd_s, ..StepPhases::default() };
        return Ok(StepOut { loss, grad_norm: 0.0, n_tokens: n_valid as f32, phases });
    }

    let fc = final_cache.ok_or_else(|| anyhow!("forward did not fill caches"))?;
    let t_bwd = Instant::now();
    let grads = backward(state, bv, &layer_caches, &fc, ex)?;
    let bwd_s = t_bwd.elapsed().as_secs_f64();

    // fixed parameter order: grad-norm bits never depend on threads
    let t_optim = Instant::now();
    let mut sq = 0.0f32;
    for g in &grads[..state.n_trainable] {
        for &x in g.iter() {
            sq += x * x;
        }
    }
    let grad_norm = sq.sqrt();

    for i in 0..state.n_trainable {
        let lr_p = match classify_param(&state.names[i]) {
            ParamGroup::LoraB => lr_b,
            _ => lr,
        };
        let param = state.params[i].as_f32_mut()?;
        k::adamw(
            param,
            &grads[i],
            &mut state.slot_m[i],
            &mut state.slot_v[i],
            lr_p,
            step as f32,
            WEIGHT_DECAY,
            ex,
        );
    }
    let optim_s = t_optim.elapsed().as_secs_f64();
    let phases = StepPhases { fwd_s, bwd_s, optim_s };
    Ok(StepOut { loss, grad_norm, n_tokens: n_valid as f32, phases })
}

/// One intra-step fused round on the fast path (DESIGN.md §11): the same
/// single-shared-base-pass contract as the reference
/// `cpu::model::fused_train_step`, executed through the pooled/tiled
/// kernels with every working buffer leased from the arena — so a warm
/// arena serves whole fused rounds with zero new heap allocations, and
/// the peak lease scales with the *concatenated* batch (one set of
/// activations for all tenants), not with the tenant count times a
/// per-tenant batch.
///
/// Bitwise parity with the fast serial path holds for the same reason as
/// the reference: every full-batch kernel here is per-row pure (tiling
/// partitions rows across threads but never reassociates within a row),
/// and the order-sensitive reductions — CCE loss, adapter weight
/// gradients, grad-norm, AdamW — run per slice with the same kernels on
/// the same sub-inputs the serial run sees, in fixed slice order.
pub fn fused_train_step(
    state: &CpuState,
    adapters: &mut [&mut CpuAdapter],
    bv: &BatchView,
    slices: &[FusedSlice],
    ex: &Exec,
) -> Result<(Vec<StepOut>, StepPhases)> {
    check_fused_inputs(state, adapters, bv, slices)?;
    let dims = &state.dims;
    let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
    let (hq, hkv, hd) = (dims.n_heads, dims.n_kv_heads, dims.head_dim());
    let dkv = dims.d_kv();
    let (t, seq) = (bv.bsz * bv.seq, bv.seq);
    let p = ParamIdx::new(&state.names, &state.params);
    let lc_cfg = state.lora.expect("checked by check_fused_inputs");
    let (r, scale) = (lc_cfg.rank, lc_cfg.scale());
    let nt = state.n_trainable;

    for (i, &tok) in bv.tokens.iter().enumerate() {
        if tok < 0 || tok as usize >= v {
            bail!("token id {tok} at position {i} out of vocab range 0..{v}");
        }
    }
    for (i, &tgt) in bv.targets.iter().enumerate() {
        if tgt >= v as i32 {
            bail!("target id {tgt} at position {i} out of vocab range");
        }
    }

    // ---- forward: one shared base pass, per-slice adapter epilogues ----
    let t_fwd = Instant::now();
    let embed = p.get("embed")?;
    let mut x = ex.arena().lease_uninit(t * d);
    for ti in 0..t {
        let tok = bv.tokens[ti] as usize;
        x[ti * d..(ti + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }

    let mut layer_caches: Vec<LayerCache> = Vec::with_capacity(dims.n_layers);
    for l in 0..dims.n_layers {
        let pre = format!("layer_{l:02}.");
        let x_in = x;

        let mut h1 = ex.arena().lease_uninit(t * d);
        let mut rstd1 = ex.arena().lease_uninit(t);
        let mut q = ex.arena().lease_uninit(t * d);
        let mut kk = ex.arena().lease_uninit(t * dkv);
        let mut vv = ex.arena().lease_uninit(t * dkv);
        k::fused_rmsnorm_qkv(
            &x_in,
            p.get(&format!("{pre}norm1"))?,
            p.get(&format!("{pre}wq"))?,
            p.get(&format!("{pre}wk"))?,
            p.get(&format!("{pre}wv"))?,
            t,
            d,
            dkv,
            &mut h1,
            &mut rstd1,
            &mut q,
            &mut kk,
            &mut vv,
            ex,
        );

        let i_qa = p.id(&format!("{pre}wq_a"))?;
        let i_qb = p.id(&format!("{pre}wq_b"))?;
        let i_va = p.id(&format!("{pre}wv_a"))?;
        let i_vb = p.id(&format!("{pre}wv_b"))?;
        let mut hq_a = ex.arena().lease_uninit(t * r);
        let mut hv_a = ex.arena().lease_uninit(t * r);
        for (ki, sl) in slices.iter().enumerate() {
            let lo = sl.row_start * seq;
            let hi = (sl.row_start + sl.rows) * seq;
            let ts = hi - lo;
            let ad = &adapters[ki];
            k::lora_linear(
                &h1[lo * d..hi * d],
                ad.params[i_qa].as_f32()?,
                ad.params[i_qb].as_f32()?,
                ts,
                d,
                r,
                d,
                scale,
                &mut hq_a[lo * r..hi * r],
                &mut q[lo * d..hi * d],
                ex,
            );
            k::lora_linear(
                &h1[lo * d..hi * d],
                ad.params[i_va].as_f32()?,
                ad.params[i_vb].as_f32()?,
                ts,
                d,
                r,
                dkv,
                scale,
                &mut hv_a[lo * r..hi * r],
                &mut vv[lo * dkv..hi * dkv],
                ex,
            );
        }

        k::rope(&mut q, bv.pos, t, hq, hd, 1.0, ex);
        k::rope(&mut kk, bv.pos, t, hkv, hd, 1.0, ex);

        let mut att = ex.arena().lease_uninit(t * d);
        let mut lse = ex.arena().lease_uninit(bv.bsz * hq * seq);
        flash_attention_fwd(
            &q, &kk, &vv, bv.seg, bv.bsz, seq, hq, hkv, hd, &mut att, &mut lse, ex,
        );

        let mut x_mid = ex.arena().lease_uninit(t * d);
        k::matmul_residual(&att, p.get(&format!("{pre}wo"))?, &x_in, t, d, d, &mut x_mid, ex);

        let mut h2 = ex.arena().lease_uninit(t * d);
        let mut rstd2 = ex.arena().lease_uninit(t);
        let mut gate = ex.arena().lease_uninit(t * f);
        let mut up = ex.arena().lease_uninit(t * f);
        let mut y = ex.arena().lease_uninit(t * f);
        k::fused_rmsnorm_swiglu(
            &x_mid,
            p.get(&format!("{pre}norm2"))?,
            p.get(&format!("{pre}w_gate"))?,
            p.get(&format!("{pre}w_up"))?,
            t,
            d,
            f,
            &mut h2,
            &mut rstd2,
            &mut gate,
            &mut up,
            &mut y,
            ex,
        );

        let mut x_out = ex.arena().lease_uninit(t * d);
        k::matmul_residual(&y, p.get(&format!("{pre}w_down"))?, &x_mid, t, f, d, &mut x_out, ex);

        layer_caches.push(LayerCache {
            x_in,
            h1,
            rstd1,
            q,
            kk,
            v: vv,
            hq_a: Some(hq_a),
            hv_a: Some(hv_a),
            att,
            lse,
            x_mid,
            h2,
            rstd2,
            gate,
            up,
            y,
        });
        x = x_out;
    }

    let x_f = x;
    let mut hf = ex.arena().lease_uninit(t * d);
    let mut rstd_f = ex.arena().lease_uninit(t);
    k::rmsnorm(&x_f, p.get("norm_f")?, t, d, &mut hf, &mut rstd_f, ex);
    // the loss reduction is order-sensitive: run it per slice so every
    // tenant gets exactly its serial (loss_sum, n_valid)
    let mut lse_f = ex.arena().lease_uninit(t);
    let mut tenant_fwd: Vec<(f32, usize)> = Vec::with_capacity(slices.len());
    for sl in slices {
        let lo = sl.row_start * seq;
        let hi = (sl.row_start + sl.rows) * seq;
        let (loss_sum, n_valid) = cce_loss_fwd(
            &hf[lo * d..hi * d],
            p.get("w_head")?,
            &bv.targets[lo..hi],
            hi - lo,
            d,
            v,
            &mut lse_f[lo..hi],
            ex,
        );
        tenant_fwd.push((loss_sum, n_valid));
    }
    let fwd_s = t_fwd.elapsed().as_secs_f64();

    // ---- backward: one shared base pass, per-slice adapter gradients ----
    let t_bwd = Instant::now();
    let mut tenant_grads: Vec<Vec<Lease>> = (0..slices.len())
        .map(|_| {
            state.params[..nt]
                .iter()
                .map(|tn| ex.arena().lease(tn.elements()))
                .collect()
        })
        .collect();
    // every norm is frozen under LoRA: dgamma goes to a discarded sink
    let mut dg_sink = ex.arena().lease(d);

    // CCE backward per slice, each normalized by its tenant's n_valid;
    // w_head is frozen under LoRA so no weight gradient is formed
    let mut dhf = ex.arena().lease(t * d);
    for (ki, sl) in slices.iter().enumerate() {
        let lo = sl.row_start * seq;
        let hi = (sl.row_start + sl.rows) * seq;
        cce_bwd_fused(
            &hf[lo * d..hi * d],
            p.get("w_head")?,
            &bv.targets[lo..hi],
            &lse_f[lo..hi],
            hi - lo,
            d,
            v,
            tenant_fwd[ki].1,
            None,
            &mut dhf[lo * d..hi * d],
            ex,
        );
    }

    let mut dx = ex.arena().lease(t * d);
    k::rmsnorm_bwd(&x_f, p.get("norm_f")?, &rstd_f, &dhf, t, d, &mut dx, &mut dg_sink, ex);

    for l in (0..dims.n_layers).rev() {
        let pre = format!("layer_{l:02}.");
        let c = &layer_caches[l];

        let mut dy = ex.arena().lease(t * f);
        k::matmul_bwd_x(&dx, p.get(&format!("{pre}w_down"))?, t, f, d, &mut dy, ex);

        let mut dgate = ex.arena().lease(t * f);
        let mut dup = ex.arena().lease(t * f);
        k::swiglu_bwd(&c.gate, &c.up, &dy, &mut dgate, &mut dup, ex);

        let mut dh2 = ex.arena().lease(t * d);
        k::matmul_bwd_x(&dgate, p.get(&format!("{pre}w_gate"))?, t, d, f, &mut dh2, ex);
        k::matmul_bwd_x(&dup, p.get(&format!("{pre}w_up"))?, t, d, f, &mut dh2, ex);

        let mut dx_mid = dx;
        k::rmsnorm_bwd(
            &c.x_mid,
            p.get(&format!("{pre}norm2"))?,
            &c.rstd2,
            &dh2,
            t,
            d,
            &mut dx_mid,
            &mut dg_sink,
            ex,
        );

        let mut datt = ex.arena().lease(t * d);
        k::matmul_bwd_x(&dx_mid, p.get(&format!("{pre}wo"))?, t, d, d, &mut datt, ex);

        let mut dq = ex.arena().lease(t * d);
        let mut dk = ex.arena().lease(t * dkv);
        let mut dv = ex.arena().lease(t * dkv);
        flash_attention_bwd(
            &datt, &c.q, &c.kk, &c.v, &c.att, &c.lse, bv.seg, bv.bsz, seq, hq, hkv, hd,
            &mut dq, &mut dk, &mut dv, ex,
        );
        k::rope(&mut dq, bv.pos, t, hq, hd, -1.0, ex);
        k::rope(&mut dk, bv.pos, t, hkv, hd, -1.0, ex);

        let mut dh1 = ex.arena().lease(t * d);
        k::matmul_bwd_x(&dq, p.get(&format!("{pre}wq"))?, t, d, d, &mut dh1, ex);
        k::matmul_bwd_x(&dk, p.get(&format!("{pre}wk"))?, t, d, dkv, &mut dh1, ex);
        k::matmul_bwd_x(&dv, p.get(&format!("{pre}wv"))?, t, d, dkv, &mut dh1, ex);

        // adapter chain: the only trainable gradients, reduced per slice
        let i_qa = p.id(&format!("{pre}wq_a"))?;
        let i_qb = p.id(&format!("{pre}wq_b"))?;
        let i_va = p.id(&format!("{pre}wv_a"))?;
        let i_vb = p.id(&format!("{pre}wv_b"))?;
        let hq_a = c.hq_a.as_ref().expect("lora cache");
        let hv_a = c.hv_a.as_ref().expect("lora cache");
        let mut dq_s = ex.arena().lease_uninit(t * d);
        for (o, &g) in dq_s.iter_mut().zip(dq.iter()) {
            *o = scale * g;
        }
        let mut dv_s = ex.arena().lease_uninit(t * dkv);
        for (o, &g) in dv_s.iter_mut().zip(dv.iter()) {
            *o = scale * g;
        }
        let mut dhq_a = ex.arena().lease(t * r);
        let mut dhv_a = ex.arena().lease(t * r);
        for (ki, sl) in slices.iter().enumerate() {
            let lo = sl.row_start * seq;
            let hi = (sl.row_start + sl.rows) * seq;
            let ts = hi - lo;
            let ad = &adapters[ki];
            let g = &mut tenant_grads[ki];

            k::matmul_bwd_w(&dq_s[lo * d..hi * d], &hq_a[lo * r..hi * r], ts, r, d, &mut g[i_qb], ex);
            k::matmul_bwd_x(
                &dq_s[lo * d..hi * d],
                ad.params[i_qb].as_f32()?,
                ts,
                r,
                d,
                &mut dhq_a[lo * r..hi * r],
                ex,
            );
            k::matmul_bwd_w(&dhq_a[lo * r..hi * r], &c.h1[lo * d..hi * d], ts, d, r, &mut g[i_qa], ex);
            k::matmul_bwd_x(
                &dhq_a[lo * r..hi * r],
                ad.params[i_qa].as_f32()?,
                ts,
                d,
                r,
                &mut dh1[lo * d..hi * d],
                ex,
            );

            k::matmul_bwd_w(&dv_s[lo * dkv..hi * dkv], &hv_a[lo * r..hi * r], ts, r, dkv, &mut g[i_vb], ex);
            k::matmul_bwd_x(
                &dv_s[lo * dkv..hi * dkv],
                ad.params[i_vb].as_f32()?,
                ts,
                r,
                dkv,
                &mut dhv_a[lo * r..hi * r],
                ex,
            );
            k::matmul_bwd_w(&dhv_a[lo * r..hi * r], &c.h1[lo * d..hi * d], ts, d, r, &mut g[i_va], ex);
            k::matmul_bwd_x(
                &dhv_a[lo * r..hi * r],
                ad.params[i_va].as_f32()?,
                ts,
                d,
                r,
                &mut dh1[lo * d..hi * d],
                ex,
            );
        }

        let mut dx_in = dx_mid;
        k::rmsnorm_bwd(
            &c.x_in,
            p.get(&format!("{pre}norm1"))?,
            &c.rstd1,
            &dh1,
            t,
            d,
            &mut dx_in,
            &mut dg_sink,
            ex,
        );
        dx = dx_in;
    }
    // the embedding is frozen under LoRA: the remaining dx is discarded
    let bwd_s = t_bwd.elapsed().as_secs_f64();

    // ---- per-tenant grad-norm + optimizer, each at its own coordinates --
    let t_optim = Instant::now();
    let mut outs = Vec::with_capacity(slices.len());
    for (ki, sl) in slices.iter().enumerate() {
        let g = &tenant_grads[ki];
        let mut sq = 0.0f32;
        for gi in g {
            for &xv in gi.iter() {
                sq += xv * xv;
            }
        }
        let grad_norm = sq.sqrt();

        let ad = &mut *adapters[ki];
        for i in 0..nt {
            let lr_p = match classify_param(&state.names[i]) {
                ParamGroup::LoraB => sl.lr_b,
                _ => sl.lr,
            };
            let param = ad.params[i].as_f32_mut()?;
            k::adamw(
                param,
                &g[i],
                &mut ad.slot_m[i],
                &mut ad.slot_v[i],
                lr_p,
                sl.step as f32,
                WEIGHT_DECAY,
                ex,
            );
        }
        let (loss_sum, n_valid) = tenant_fwd[ki];
        outs.push(StepOut {
            loss: loss_sum / n_valid.max(1) as f32,
            grad_norm,
            n_tokens: n_valid as f32,
            phases: StepPhases::default(),
        });
    }
    let optim_s = t_optim.elapsed().as_secs_f64();
    Ok((outs, StepPhases { fwd_s, bwd_s, optim_s }))
}

/// Data-parallel shard gradient (DESIGN.md §10): forward + backward on a
/// single-row view with the CCE normalizer forced to `global_n_valid`, so
/// per-row gradients tree-reduce to the full-batch mean-loss gradient.
/// Flattens the trainable gradients into `out` (state order) and returns
/// `(row loss sum, forward seconds, backward seconds)`.
pub fn grad_row_into(
    state: &CpuState,
    bv: &BatchView,
    global_n_valid: usize,
    out: &mut [f32],
    ex: &Exec,
) -> Result<(f32, f64, f64)> {
    let mut layer_caches: Vec<LayerCache> = Vec::with_capacity(state.dims.n_layers);
    let mut final_cache: Option<FinalCache> = None;
    let t_fwd = Instant::now();
    let (loss_sum, _row_valid) =
        forward(state, bv, Some((&mut layer_caches, &mut final_cache)), ex)?;
    let fwd_s = t_fwd.elapsed().as_secs_f64();
    let mut fc = final_cache.ok_or_else(|| anyhow!("forward did not fill caches"))?;
    // backward reads its loss normalizer from the cache (cce_bwd_fused
    // divides by fc.n_valid); the global count makes shards sum exactly
    fc.n_valid = global_n_valid.max(1);
    let t_bwd = Instant::now();
    let grads = backward(state, bv, &layer_caches, &fc, ex)?;
    let bwd_s = t_bwd.elapsed().as_secs_f64();
    let mut off = 0usize;
    for g in &grads[..state.n_trainable] {
        ensure!(off + g.len() <= out.len(), "gradient lane overflow at offset {off}");
        out[off..off + g.len()].copy_from_slice(g);
        off += g.len();
    }
    ensure!(off == out.len(), "gradient lane length mismatch: wrote {off}, lane {}", out.len());
    Ok((loss_sum, fwd_s, bwd_s))
}

/// Apply one AdamW step from a flat reduced gradient (trainable prefix,
/// state order). Bitwise-identical to the update loop in [`train_step`].
pub fn apply_flat_grads(
    state: &mut CpuState,
    flat: &[f32],
    step: u64,
    lr: f32,
    lr_b: f32,
    ex: &Exec,
) -> Result<()> {
    let mut off = 0usize;
    for i in 0..state.n_trainable {
        let lr_p = match classify_param(&state.names[i]) {
            ParamGroup::LoraB => lr_b,
            _ => lr,
        };
        let param = state.params[i].as_f32_mut()?;
        let n = param.len();
        ensure!(off + n <= flat.len(), "flat gradient underflow at parameter {i}");
        k::adamw(
            param,
            &flat[off..off + n],
            &mut state.slot_m[i],
            &mut state.slot_v[i],
            lr_p,
            step as f32,
            WEIGHT_DECAY,
            ex,
        );
        off += n;
    }
    ensure!(off == flat.len(), "flat gradient length {} != trainable elements {off}", flat.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::model as refmodel;
    use crate::backend::cpu::model::{init_state, LoraCfg, ModelDims};
    use crate::util::rng::Rng;

    fn dims() -> ModelDims {
        ModelDims { vocab: 16, d_model: 8, n_layers: 2, n_heads: 2, n_kv_heads: 1, d_ff: 12 }
    }

    /// Same packed fixture as the reference model tests.
    fn batch() -> (Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>, usize, usize) {
        let (bsz, s) = (2usize, 10usize);
        let mut tokens = vec![0i32; bsz * s];
        let mut targets = vec![-1i32; bsz * s];
        let mut seg = vec![0i32; bsz * s];
        let mut pos = vec![0i32; bsz * s];
        let mut rng = Rng::new(99);
        let rows: [&[usize]; 2] = [&[5, 4], &[6]];
        for (b, lens) in rows.iter().enumerate() {
            let mut off = 0usize;
            for (si, &len) in lens.iter().enumerate() {
                for i in 0..len {
                    let t = b * s + off + i;
                    tokens[t] = rng.range(4, 16) as i32;
                    seg[t] = (si + 1) as i32;
                    pos[t] = i as i32;
                    if i > 0 {
                        targets[t - 1] = tokens[t];
                    }
                }
                off += len;
            }
        }
        (tokens, targets, seg, pos, bsz, s)
    }

    fn bv(t: &(Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>, usize, usize)) -> BatchView<'_> {
        BatchView { tokens: &t.0, targets: &t.1, seg: &t.2, pos: &t.3, bsz: t.4, seq: t.5 }
    }

    /// Per-parameter gradient parity against the reference backward — the
    /// strictest cross-implementation check (satellite requirement).
    #[test]
    fn per_parameter_gradients_match_reference() {
        for lora in [None, Some(LoraCfg { rank: 2, alpha: 4.0 })] {
            let state = init_state(dims(), lora, 5);
            let b = batch();
            let view = bv(&b);

            let mut ref_lcs = Vec::new();
            let mut ref_fc = None;
            let (ref_loss, _) =
                refmodel::forward(&state, &view, Some((&mut ref_lcs, &mut ref_fc))).unwrap();
            let ref_grads =
                refmodel::backward(&state, &view, &ref_lcs, &ref_fc.unwrap()).unwrap();

            let ex = Exec::new(2);
            let mut lcs = Vec::new();
            let mut fc = None;
            let (loss, _) = forward(&state, &view, Some((&mut lcs, &mut fc)), &ex).unwrap();
            let grads = backward(&state, &view, &lcs, &fc.unwrap(), &ex).unwrap();

            assert!(
                (loss - ref_loss).abs() < 1e-4 * (1.0 + ref_loss.abs()),
                "lora={lora:?} loss {loss} vs {ref_loss}"
            );
            assert_eq!(grads.len(), ref_grads.len());
            for (gi, (g, rg)) in grads.iter().zip(&ref_grads).enumerate() {
                for (ei, (a, b)) in g.iter().zip(rg).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                        "lora={lora:?} param {gi} ('{}') [{ei}]: {a} vs {b}",
                        state.names[gi]
                    );
                }
            }
        }
    }

    #[test]
    fn loss_decreases_and_matches_reference_trajectory() {
        let b = batch();
        let ex = Exec::new(3);
        let mut fast = init_state(dims(), None, 7);
        let mut reference = init_state(dims(), None, 7);
        for step in 1..=8u64 {
            let fo = train_step(&mut fast, &bv(&b), false, step, 5e-3, 5e-3, &ex).unwrap();
            let ro = refmodel::train_step(&mut reference, &bv(&b), false, step, 5e-3, 5e-3).unwrap();
            assert!(fo.grad_norm > 0.0);
            assert!(
                (fo.loss - ro.loss).abs() < 1e-4 * (1.0 + ro.loss.abs()),
                "step {step}: {} vs {}",
                fo.loss,
                ro.loss
            );
            let rel = (fo.grad_norm - ro.grad_norm).abs() / ro.grad_norm.max(1e-12);
            assert!(rel < 1e-3, "step {step}: grad_norm {} vs {}", fo.grad_norm, ro.grad_norm);
        }
    }

    #[test]
    fn step_bits_invariant_to_thread_count() {
        let b = batch();
        let run = |threads: usize| {
            let ex = Exec::new(threads);
            let mut state = init_state(dims(), Some(LoraCfg { rank: 2, alpha: 4.0 }), 42);
            let mut bits = Vec::new();
            for step in 1..=4u64 {
                let out =
                    train_step(&mut state, &bv(&b), false, step, 3e-3, 6e-3, &ex).unwrap();
                bits.push((out.loss.to_bits(), out.grad_norm.to_bits()));
            }
            bits
        };
        let one = run(1);
        assert_eq!(one, run(1), "threads=1 not run-to-run deterministic");
        assert_eq!(one, run(2), "threads=2 changed bits");
        assert_eq!(one, run(5), "threads=5 changed bits");
    }

    #[test]
    fn broken_mode_has_zero_grad() {
        let ex = Exec::new(2);
        let mut state = init_state(dims(), None, 7);
        let b = batch();
        let o1 = train_step(&mut state, &bv(&b), true, 1, 5e-3, 5e-3, &ex).unwrap();
        let o2 = train_step(&mut state, &bv(&b), true, 2, 5e-3, 5e-3, &ex).unwrap();
        assert_eq!(o1.grad_norm, 0.0);
        assert_eq!(o1.loss.to_bits(), o2.loss.to_bits(), "params moved in broken mode");
    }

    #[test]
    fn eval_matches_train_loss_before_update() {
        let ex = Exec::new(2);
        let mut state = init_state(dims(), None, 3);
        let b = batch();
        let e = eval_loss(&state, &bv(&b), &ex).unwrap();
        let out = train_step(&mut state, &bv(&b), false, 1, 1e-3, 1e-3, &ex).unwrap();
        assert_eq!(e.to_bits(), out.loss.to_bits());
    }

    #[test]
    fn warm_arena_train_steps_stop_allocating() {
        // the train-step-level version of the arena contract: after the
        // cold first step, further steps lease everything from the free
        // list (the integration-level assertion lives in
        // rust/tests/no_materialization.rs on a larger geometry)
        let ex = Exec::new(2);
        let mut state = init_state(dims(), None, 11);
        let b = batch();
        train_step(&mut state, &bv(&b), false, 1, 1e-3, 1e-3, &ex).unwrap();
        let cold = ex.arena().heap_allocs();
        assert!(cold > 0, "first step must populate the arena");
        for step in 2..=5u64 {
            train_step(&mut state, &bv(&b), false, step, 1e-3, 1e-3, &ex).unwrap();
        }
        assert_eq!(ex.arena().heap_allocs(), cold, "steady-state steps must not allocate");
    }

    /// Fused intra-step round vs the fast serial swap-in/train/swap-out
    /// path, on a ragged round (1-row + 2-row tenants) with LoRA+ dual LR:
    /// losses, grad norms, adapter weights and optimizer slots must match
    /// bit-for-bit (the DESIGN.md §11 separability contract on this
    /// backend's pooled/tiled kernels).
    #[test]
    fn fused_step_matches_fast_serial_bitwise() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let base_seed = 11;
        let b = batch();
        let seq = b.5;
        let a_view = BatchView {
            tokens: &b.0[..seq],
            targets: &b.1[..seq],
            seg: &b.2[..seq],
            pos: &b.3[..seq],
            bsz: 1,
            seq,
        };
        let cat = |v: &Vec<i32>| {
            let mut out = v[..seq].to_vec();
            out.extend_from_slice(v);
            out
        };
        let (ct, cg, cs, cp) = (cat(&b.0), cat(&b.1), cat(&b.2), cat(&b.3));
        let concat = BatchView { tokens: &ct, targets: &cg, seg: &cs, pos: &cp, bsz: 3, seq };

        let ex = Exec::new(2);
        let serial = |seed: i32, view: &BatchView, steps: u64, lr: f32, lr_b: f32| {
            let mut st = init_state(dims(), Some(lora), base_seed);
            let mut ad = refmodel::init_adapter(dims(), lora, seed);
            refmodel::swap_adapter(&mut st, &mut ad).unwrap();
            let mut outs = Vec::new();
            for step in 1..=steps {
                outs.push(train_step(&mut st, view, false, step, lr, lr_b, &ex).unwrap());
            }
            refmodel::swap_adapter(&mut st, &mut ad).unwrap();
            (outs, ad)
        };
        // tenant B runs LoRA+ (lr_b != lr) to exercise the dual-LR path
        let (sa, ada) = serial(100, &a_view, 4, 5e-3, 5e-3);
        let (sb, adb) = serial(200, &bv(&b), 4, 5e-3, 8e-3);

        let ws = init_state(dims(), Some(lora), base_seed);
        let mut t1 = refmodel::init_adapter(dims(), lora, 100);
        let mut t2 = refmodel::init_adapter(dims(), lora, 200);
        let mut fa = Vec::new();
        let mut fb = Vec::new();
        for step in 1..=4u64 {
            let slices = [
                FusedSlice { row_start: 0, rows: 1, step, lr: 5e-3, lr_b: 5e-3 },
                FusedSlice { row_start: 1, rows: 2, step, lr: 5e-3, lr_b: 8e-3 },
            ];
            let mut ads = [&mut t1, &mut t2];
            let (outs, _) = fused_train_step(&ws, &mut ads, &concat, &slices, &ex).unwrap();
            assert_eq!(outs.len(), 2);
            fa.push(outs[0]);
            fb.push(outs[1]);
        }
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for (fused, serial) in [(&fa, &sa), (&fb, &sb)] {
            for (fo, so) in fused.iter().zip(serial.iter()) {
                assert_eq!(fo.loss.to_bits(), so.loss.to_bits(), "loss diverges");
                assert_eq!(fo.grad_norm.to_bits(), so.grad_norm.to_bits(), "grad_norm diverges");
                assert_eq!(fo.n_tokens, so.n_tokens);
            }
        }
        for (fused, serial) in [(&t1, &ada), (&t2, &adb)] {
            for i in 0..fused.params.len() {
                assert_eq!(
                    bits(fused.params[i].as_f32().unwrap()),
                    bits(serial.params[i].as_f32().unwrap()),
                    "adapter weights diverge at {}",
                    fused.names[i]
                );
                assert_eq!(bits(&fused.slot_m[i]), bits(&serial.slot_m[i]), "slot_m diverges");
                assert_eq!(bits(&fused.slot_v[i]), bits(&serial.slot_v[i]), "slot_v diverges");
            }
        }
    }

    /// The fused round keeps the fast backend's thread-count bitwise
    /// invariance: a two-tenant round at 1, 2 and 5 threads produces
    /// identical step metrics and identical final adapter bits.
    #[test]
    fn fused_step_bits_invariant_to_thread_count() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let b = batch();
        let seq = b.5;
        let cat = |v: &Vec<i32>| {
            let mut out = v[..seq].to_vec();
            out.extend_from_slice(v);
            out
        };
        let (ct, cg, cs, cp) = (cat(&b.0), cat(&b.1), cat(&b.2), cat(&b.3));
        let run = |threads: usize| {
            let concat = BatchView { tokens: &ct, targets: &cg, seg: &cs, pos: &cp, bsz: 3, seq };
            let ex = Exec::new(threads);
            let ws = init_state(dims(), Some(lora), 3);
            let mut t1 = refmodel::init_adapter(dims(), lora, 21);
            let mut t2 = refmodel::init_adapter(dims(), lora, 22);
            let mut step_bits = Vec::new();
            for step in 1..=3u64 {
                let slices = [
                    FusedSlice { row_start: 0, rows: 1, step, lr: 3e-3, lr_b: 6e-3 },
                    FusedSlice { row_start: 1, rows: 2, step, lr: 3e-3, lr_b: 6e-3 },
                ];
                let mut ads = [&mut t1, &mut t2];
                let (outs, _) = fused_train_step(&ws, &mut ads, &concat, &slices, &ex).unwrap();
                for o in &outs {
                    step_bits.push((o.loss.to_bits(), o.grad_norm.to_bits()));
                }
            }
            let mut param_bits = Vec::new();
            for ad in [&t1, &t2] {
                for tn in &ad.params {
                    param_bits
                        .push(tn.as_f32().unwrap().iter().map(|x| x.to_bits()).collect::<Vec<_>>());
                }
            }
            (step_bits, param_bits)
        };
        let one = run(1);
        assert_eq!(one, run(2), "threads=2 changed fused-round bits");
        assert_eq!(one, run(5), "threads=5 changed fused-round bits");
    }

    #[test]
    fn out_of_vocab_token_rejected() {
        let ex = Exec::new(1);
        let state = init_state(dims(), None, 7);
        let tokens = vec![99i32];
        let targets = vec![-1i32];
        let seg = vec![1i32];
        let pos = vec![0i32];
        let view =
            BatchView { tokens: &tokens, targets: &targets, seg: &seg, pos: &pos, bsz: 1, seq: 1 };
        assert!(eval_loss(&state, &view, &ex).is_err());
    }
}
