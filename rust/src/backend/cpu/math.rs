//! Scalar math primitives for the CPU reference backend.
//!
//! Every function is a plain sequential loop over `f32` slices: no SIMD, no
//! threading, no reassociation — so a given (inputs, seed) pair produces
//! bitwise-identical results on every run, which is the property the
//! determinism gate in `rust/tests/integration.rs` relies on. The backward
//! formulas mirror `python/compile/kernels/ref.py` and were validated
//! against central finite differences (see DESIGN.md §4.1).
//!
//! Convention: forward outputs are *assigned*, backward outputs are
//! *accumulated* (`+=`) into caller-zeroed buffers, so residual branches
//! combine naturally.

pub const RMS_EPS: f32 = 1e-6;
pub const ROPE_BASE: f32 = 10000.0;

/// `out[t, n] = Σ_k x[t, k] · w[n, k]` — `y = x @ W.T` with `W: [n_out, k_in]`.
pub fn linear_fwd(x: &[f32], w: &[f32], t: usize, k_in: usize, n_out: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), t * k_in);
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert_eq!(out.len(), t * n_out);
    for ti in 0..t {
        let xr = &x[ti * k_in..(ti + 1) * k_in];
        let or = &mut out[ti * n_out..(ti + 1) * n_out];
        for (n, o) in or.iter_mut().enumerate() {
            let wr = &w[n * k_in..(n + 1) * k_in];
            let mut acc = 0.0f32;
            for k in 0..k_in {
                acc += xr[k] * wr[k];
            }
            *o = acc;
        }
    }
}

/// `dx[t, k] += Σ_n dy[t, n] · w[n, k]` — input gradient of `linear_fwd`.
pub fn linear_bwd_x(dy: &[f32], w: &[f32], t: usize, k_in: usize, n_out: usize, dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), t * n_out);
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert_eq!(dx.len(), t * k_in);
    for ti in 0..t {
        let dyr = &dy[ti * n_out..(ti + 1) * n_out];
        let dxr = &mut dx[ti * k_in..(ti + 1) * k_in];
        for (n, &dyv) in dyr.iter().enumerate() {
            if dyv == 0.0 {
                continue;
            }
            let wr = &w[n * k_in..(n + 1) * k_in];
            for k in 0..k_in {
                dxr[k] += dyv * wr[k];
            }
        }
    }
}

/// `dw[n, k] += Σ_t dy[t, n] · x[t, k]` — weight gradient of `linear_fwd`.
pub fn linear_bwd_w(dy: &[f32], x: &[f32], t: usize, k_in: usize, n_out: usize, dw: &mut [f32]) {
    debug_assert_eq!(dy.len(), t * n_out);
    debug_assert_eq!(x.len(), t * k_in);
    debug_assert_eq!(dw.len(), n_out * k_in);
    for ti in 0..t {
        let dyr = &dy[ti * n_out..(ti + 1) * n_out];
        let xr = &x[ti * k_in..(ti + 1) * k_in];
        for (n, &dyv) in dyr.iter().enumerate() {
            if dyv == 0.0 {
                continue;
            }
            let dwr = &mut dw[n * k_in..(n + 1) * k_in];
            for k in 0..k_in {
                dwr[k] += dyv * xr[k];
            }
        }
    }
}

/// RMSNorm forward over rows: `y = x · rstd · γ`, `rstd = 1/√(mean(x²)+ε)`.
/// Also emits the per-row `rstd` for the backward pass.
pub fn rmsnorm_fwd(x: &[f32], gamma: &[f32], t: usize, d: usize, y: &mut [f32], rstd: &mut [f32]) {
    debug_assert_eq!(x.len(), t * d);
    debug_assert_eq!(gamma.len(), d);
    for ti in 0..t {
        let xr = &x[ti * d..(ti + 1) * d];
        let mut ss = 0.0f32;
        for &v in xr {
            ss += v * v;
        }
        let r = 1.0 / (ss / d as f32 + RMS_EPS).sqrt();
        rstd[ti] = r;
        let yr = &mut y[ti * d..(ti + 1) * d];
        for i in 0..d {
            yr[i] = xr[i] * r * gamma[i];
        }
    }
}

/// RMSNorm backward (paper Prop. 3):
/// `dx_i = rstd·(γ_i·dy_i − x̄_i·mean_j(dy_j γ_j x̄_j))`, `dγ = Σ_rows dy·x̄`.
#[allow(clippy::too_many_arguments)]
pub fn rmsnorm_bwd(
    x: &[f32],
    gamma: &[f32],
    rstd: &[f32],
    dy: &[f32],
    t: usize,
    d: usize,
    dx: &mut [f32],
    dgamma: &mut [f32],
) {
    for ti in 0..t {
        let xr = &x[ti * d..(ti + 1) * d];
        let dyr = &dy[ti * d..(ti + 1) * d];
        let r = rstd[ti];
        let mut c1 = 0.0f32;
        for i in 0..d {
            c1 += dyr[i] * gamma[i] * xr[i] * r;
        }
        c1 /= d as f32;
        let dxr = &mut dx[ti * d..(ti + 1) * d];
        for i in 0..d {
            let xbar = xr[i] * r;
            dxr[i] += r * (gamma[i] * dyr[i] - xbar * c1);
            dgamma[i] += dyr[i] * xbar;
        }
    }
}

/// Apply RoPE in place (rotate-half convention, paper Alg. 8). `sign = 1.0`
/// rotates forward; `sign = -1.0` is the exact inverse rotation, i.e. the
/// backward pass (rotations are orthogonal).
pub fn rope_apply(x: &mut [f32], pos: &[i32], t: usize, n_heads: usize, hd: usize, sign: f32) {
    debug_assert_eq!(x.len(), t * n_heads * hd);
    let half = hd / 2;
    for ti in 0..t {
        let p = pos[ti] as f32;
        for h in 0..n_heads {
            let base = ti * n_heads * hd + h * hd;
            for j in 0..half {
                let inv_freq = ROPE_BASE.powf(-(j as f32) / half as f32);
                let theta = p * inv_freq;
                let (c, s) = (theta.cos(), theta.sin() * sign);
                let x1 = x[base + j];
                let x2 = x[base + half + j];
                x[base + j] = x1 * c - x2 * s;
                x[base + half + j] = x2 * c + x1 * s;
            }
        }
    }
}

/// SwiGLU forward: `y = SiLU(gate) · up`, elementwise.
pub fn swiglu_fwd(gate: &[f32], up: &[f32], y: &mut [f32]) {
    for i in 0..y.len() {
        let g = gate[i];
        let sig = 1.0 / (1.0 + (-g).exp());
        y[i] = g * sig * up[i];
    }
}

/// SwiGLU backward (paper Alg. 7), accumulated into `dgate`/`dup`.
pub fn swiglu_bwd(gate: &[f32], up: &[f32], dy: &[f32], dgate: &mut [f32], dup: &mut [f32]) {
    for i in 0..dy.len() {
        let g = gate[i];
        let sig = 1.0 / (1.0 + (-g).exp());
        let silu = g * sig;
        dgate[i] += dy[i] * up[i] * sig * (1.0 + g * (1.0 - sig));
        dup[i] += dy[i] * silu;
    }
}

/// Softmax cross-entropy over `[t, v]` logits with `-1`-masked targets.
///
/// Fills `probs` with the row softmax (all rows — the backward needs it) and
/// returns `(summed loss over valid rows, n_valid)`. The mean reduction is
/// the caller's job so the `1/n_valid` scaling stays in one place.
pub fn softmax_xent(
    logits: &[f32],
    targets: &[i32],
    t: usize,
    v: usize,
    probs: &mut [f32],
) -> (f32, usize) {
    let mut loss_sum = 0.0f32;
    let mut n_valid = 0usize;
    for ti in 0..t {
        let zr = &logits[ti * v..(ti + 1) * v];
        let mut m = f32::NEG_INFINITY;
        for &z in zr {
            m = m.max(z);
        }
        let mut denom = 0.0f32;
        let pr = &mut probs[ti * v..(ti + 1) * v];
        for i in 0..v {
            let e = (zr[i] - m).exp();
            pr[i] = e;
            denom += e;
        }
        for p in pr.iter_mut() {
            *p /= denom;
        }
        let tgt = targets[ti];
        if tgt >= 0 {
            n_valid += 1;
            let lse = denom.ln() + m;
            loss_sum += lse - zr[tgt as usize];
        }
    }
    (loss_sum, n_valid)
}

/// One AdamW step (paper Def. 8): `β1=0.9, β2=0.999, ε=1e-8`, decoupled
/// weight decay. `step` is 1-based (bias correction).
#[allow(clippy::too_many_arguments)]
pub fn adamw_update(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    lr: f32,
    step: f32,
    weight_decay: f32,
) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let bc1 = 1.0 - B1.powf(step);
    let bc2 = 1.0 - B2.powf(step);
    for i in 0..p.len() {
        m[i] = B1 * m[i] + (1.0 - B1) * g[i];
        v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
        let m_hat = m[i] / bc1;
        let v_hat = v[i] / bc2;
        p[i] = p[i] * (1.0 - lr * weight_decay) - lr * m_hat / (v_hat.sqrt() + EPS);
    }
}

/// Trust-ratio ceiling for the quantized optimizer path: `|m̂|/√v̂` is ≈1
/// for exact AdamW (measured ≤ ~1.03 across healthy regimes), but block
/// quantization can zero a small `v` inside a large-amax block — with a
/// negative block compensation the decoded `v` clamps to 0 while `m`
/// keeps its real magnitude, and the unguarded normalized step explodes
/// to `m̂/ε`-scale. The ceiling binds only in that degenerate case; every
/// healthy element takes the bitwise-identical unclamped path.
const INT8_UPDATE_CLIP: f32 = 10.0;

/// One AdamW step over int8-quantized m/v slots (ROADMAP "memory tiers"):
/// Kahan-compensated decode → exactly the [`adamw_update`] recurrence →
/// re-encode. Strictly sequential like everything else in this module, and
/// shared verbatim by both CPU backends, so the quantized optimizer path is
/// bitwise invariant to `CHRONICALS_THREADS` and `--workers` by
/// construction.
///
/// `m_buf`/`v_buf` are caller-owned scratch (≥ `p.len()`): the reference
/// backend hands in plain vectors, the fast backend hands in arena leases so
/// steady-state steps stay allocation-free. Decoded `v` can dip fractionally
/// below zero through the block-mean compensation; it is clamped before the
/// square root, and the normalized update is capped at
/// [`INT8_UPDATE_CLIP`] so a quantization-collapsed `v` cannot blow the
/// step (the unclamped branch keeps the fp32 op order, so step 1 from
/// zeroed slots stays bit-identical to [`adamw_update`]).
#[allow(clippy::too_many_arguments)]
pub fn adamw_update_int8(
    p: &mut [f32],
    g: &[f32],
    m_slot: &mut crate::quant::Int8Slot,
    v_slot: &mut crate::quant::Int8Slot,
    lr: f32,
    step: f32,
    weight_decay: f32,
    m_buf: &mut [f32],
    v_buf: &mut [f32],
) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let n = p.len();
    debug_assert_eq!(g.len(), n);
    debug_assert_eq!(m_slot.len(), n);
    debug_assert_eq!(v_slot.len(), n);
    debug_assert!(m_buf.len() >= n && v_buf.len() >= n);
    let (m, v) = (&mut m_buf[..n], &mut v_buf[..n]);
    m_slot.decode_into(m);
    v_slot.decode_into(v);
    let bc1 = 1.0 - B1.powf(step);
    let bc2 = 1.0 - B2.powf(step);
    for i in 0..n {
        m[i] = B1 * m[i] + (1.0 - B1) * g[i];
        v[i] = (B2 * v[i].max(0.0) + (1.0 - B2) * g[i] * g[i]).max(0.0);
        let m_hat = m[i] / bc1;
        let v_hat = v[i] / bc2;
        let denom = v_hat.sqrt() + EPS;
        let step_term = if (m_hat / denom).abs() > INT8_UPDATE_CLIP {
            lr * INT8_UPDATE_CLIP.copysign(m_hat)
        } else {
            lr * m_hat / denom
        };
        p[i] = p[i] * (1.0 - lr * weight_decay) - step_term;
    }
    m_slot.encode_from(m);
    v_slot.encode_from(v);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    #[test]
    fn linear_fwd_matches_hand_matmul() {
        // x = [[1, 2], [3, 4]], W = [[1, 0], [0, 1], [1, 1]] -> y = x @ W.T
        let x = [1.0, 2.0, 3.0, 4.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let mut y = [0.0f32; 6];
        linear_fwd(&x, &w, 2, 2, 3, &mut y);
        assert_eq!(y, [1.0, 2.0, 3.0, 3.0, 4.0, 7.0]);
    }

    #[test]
    fn linear_bwd_shapes_and_values() {
        // numerical check of d(sum y)/dx and /dw: dy = ones
        let x = [0.5, -1.0, 2.0, 0.25];
        let w = [0.3, 0.7, -0.2, 0.4, 0.1, -0.6];
        let dy = [1.0f32; 6];
        let mut dx = [0.0f32; 4];
        let mut dw = [0.0f32; 6];
        linear_bwd_x(&dy, &w, 2, 2, 3, &mut dx);
        linear_bwd_w(&dy, &x, 2, 2, 3, &mut dw);
        // dx[t,k] = sum_n w[n,k]; column sums of W = (0.2, 0.5)
        assert_close(dx[0], 0.2, 1e-6);
        assert_close(dx[1], 0.5, 1e-6);
        // dw[n,k] = sum_t x[t,k]; column sums of x = (2.5, -0.75)
        assert_close(dw[0], 2.5, 1e-6);
        assert_close(dw[1], -0.75, 1e-6);
        assert_close(dw[4], 2.5, 1e-6);
    }

    #[test]
    fn rmsnorm_unit_gamma_normalizes() {
        let x = [3.0, 4.0]; // rms = sqrt(12.5)
        let gamma = [1.0, 1.0];
        let mut y = [0.0f32; 2];
        let mut rstd = [0.0f32; 1];
        rmsnorm_fwd(&x, &gamma, 1, 2, &mut y, &mut rstd);
        let rms = (12.5f32 + RMS_EPS).sqrt();
        assert_close(y[0], 3.0 / rms, 1e-6);
        assert_close(y[1], 4.0 / rms, 1e-6);
    }

    #[test]
    fn rmsnorm_bwd_matches_finite_difference() {
        let x = [0.5, -1.2, 0.8];
        let gamma = [1.1, 0.9, 1.3];
        let dy = [0.7, -0.3, 0.2];
        let mut y = [0.0f32; 3];
        let mut rstd = [0.0f32; 1];
        rmsnorm_fwd(&x, &gamma, 1, 3, &mut y, &mut rstd);
        let mut dx = [0.0f32; 3];
        let mut dg = [0.0f32; 3];
        rmsnorm_bwd(&x, &gamma, &rstd, &dy, 1, 3, &mut dx, &mut dg);
        // L = dy . y; perturb x[i] and compare
        let eps = 1e-3f32;
        for i in 0..3 {
            let mut xp = x;
            xp[i] += eps;
            let mut yp = [0.0f32; 3];
            let mut rp = [0.0f32; 1];
            rmsnorm_fwd(&xp, &gamma, 1, 3, &mut yp, &mut rp);
            let mut xm = x;
            xm[i] -= eps;
            let mut ym = [0.0f32; 3];
            rmsnorm_fwd(&xm, &gamma, 1, 3, &mut ym, &mut rp);
            let lp: f32 = (0..3).map(|j| dy[j] * yp[j]).sum();
            let lm: f32 = (0..3).map(|j| dy[j] * ym[j]).sum();
            assert_close(dx[i], (lp - lm) / (2.0 * eps), 2e-3);
        }
    }

    #[test]
    fn rope_roundtrips() {
        let orig: Vec<f32> = (0..8).map(|i| i as f32 * 0.3 - 1.0).collect();
        let mut x = orig.clone();
        let pos = [5i32, 11];
        rope_apply(&mut x, &pos, 2, 1, 4, 1.0);
        assert!(x.iter().zip(&orig).any(|(a, b)| (a - b).abs() > 1e-4));
        rope_apply(&mut x, &pos, 2, 1, 4, -1.0);
        for (a, b) in x.iter().zip(&orig) {
            assert_close(*a, *b, 1e-5);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = vec![0.9f32, -0.4, 1.7, 0.2];
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope_apply(&mut x, &[7], 1, 1, 4, 1.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert_close(n0, n1, 1e-5);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let orig = vec![0.3f32, 1.4, -0.8, 0.05];
        let mut x = orig.clone();
        rope_apply(&mut x, &[0], 1, 1, 4, 1.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn swiglu_bwd_matches_finite_difference() {
        let gate = [0.4f32, -1.1];
        let up = [1.5f32, 0.3];
        let dy = [1.0f32, 1.0];
        let mut dgate = [0.0f32; 2];
        let mut dup = [0.0f32; 2];
        swiglu_bwd(&gate, &up, &dy, &mut dgate, &mut dup);
        let eps = 1e-3f32;
        for i in 0..2 {
            let (mut gp, mut gm) = (gate, gate);
            gp[i] += eps;
            gm[i] -= eps;
            let (mut yp, mut ym) = ([0.0f32; 2], [0.0f32; 2]);
            swiglu_fwd(&gp, &up, &mut yp);
            swiglu_fwd(&gm, &up, &mut ym);
            assert_close(dgate[i], (yp[i] - ym[i]) / (2.0 * eps), 2e-3);
        }
    }

    #[test]
    fn xent_uniform_logits_is_log_v() {
        let logits = [0.0f32; 8]; // 2 rows x 4 vocab
        let targets = [2i32, -1];
        let mut probs = [0.0f32; 8];
        let (loss, n) = softmax_xent(&logits, &targets, 2, 4, &mut probs);
        assert_eq!(n, 1);
        assert_close(loss, (4.0f32).ln(), 1e-6);
        for &p in &probs[..4] {
            assert_close(p, 0.25, 1e-6);
        }
    }

    #[test]
    fn xent_all_masked_is_zero() {
        let logits = [1.0f32, 2.0, 3.0, 4.0];
        let targets = [-1i32];
        let mut probs = [0.0f32; 4];
        let (loss, n) = softmax_xent(&logits, &targets, 1, 4, &mut probs);
        assert_eq!(n, 0);
        assert_eq!(loss, 0.0);
    }

    #[test]
    fn adamw_first_step_is_signed_lr() {
        // with zero slots, step 1: m_hat = g, v_hat = g^2 => update ≈ lr·sign(g)
        let mut p = [1.0f32, 1.0];
        let g = [0.5f32, -0.25];
        let mut m = [0.0f32; 2];
        let mut v = [0.0f32; 2];
        adamw_update(&mut p, &g, &mut m, &mut v, 0.01, 1.0, 0.0);
        assert_close(p[0], 1.0 - 0.01, 1e-4);
        assert_close(p[1], 1.0 + 0.01, 1e-4);
    }

    #[test]
    fn adamw_int8_first_step_matches_fp32_exactly() {
        // zero slots quantize losslessly, so step 1 is bit-identical to
        // the fp32 path (both start from exact zeros)
        let g = [0.5f32, -0.25, 0.125, 1.5];
        let mut p_f = [1.0f32, -2.0, 0.5, 3.0];
        let mut p_q = p_f;
        let mut m = [0.0f32; 4];
        let mut v = [0.0f32; 4];
        adamw_update(&mut p_f, &g, &mut m, &mut v, 0.01, 1.0, 0.01);
        let mut ms = crate::quant::Int8Slot::zeros(4);
        let mut vs = crate::quant::Int8Slot::zeros(4);
        let (mut mb, mut vb) = (vec![0.0f32; 4], vec![0.0f32; 4]);
        adamw_update_int8(&mut p_q, &g, &mut ms, &mut vs, 0.01, 1.0, 0.01, &mut mb, &mut vb);
        for (a, b) in p_f.iter().zip(&p_q) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn adamw_int8_tracks_fp32_over_many_steps() {
        use crate::util::rng::Rng;
        let n = 256;
        let mut rng = Rng::new(17);
        let mut p_f: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p_q = p_f.clone();
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let mut ms = crate::quant::Int8Slot::zeros(n);
        let mut vs = crate::quant::Int8Slot::zeros(n);
        let (mut mb, mut vb) = (vec![0.0f32; n], vec![0.0f32; n]);
        for step in 1..=50 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            adamw_update(&mut p_f, &g, &mut m, &mut v, 5e-3, step as f32, 0.0);
            adamw_update_int8(
                &mut p_q, &g, &mut ms, &mut vs, 5e-3, step as f32, 0.0, &mut mb, &mut vb,
            );
        }
        // quantized moments distort per-element adaptive scaling but the
        // trajectories must stay close in norm (drift tier, DESIGN §12)
        let norm: f32 = p_f.iter().map(|x| x * x).sum::<f32>().sqrt();
        let diff: f32 = p_f
            .iter()
            .zip(&p_q)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        assert!(diff / norm < 0.05, "rel drift {} too large", diff / norm);
        assert!(p_q.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn adamw_int8_clamps_quantization_collapsed_v() {
        // craft the degenerate block: one huge v dominates the scale, a
        // mid value rounds UP (negative residual drags the compensation
        // below zero), so the tiny element's v decodes NEGATIVE while its
        // m keeps real magnitude. Unclamped, the normalized step would be
        // m_hat/ε-scale (hundreds of lr); the trust-ratio ceiling caps it.
        let scale = 1.0f32 / 127.0;
        let v_in = [1.0f32, 0.6 * scale, 1e-9];
        let m_in = [0.5f32, 0.1, -6e-3];
        let mut ms = crate::quant::Int8Slot::zeros(3);
        let mut vs = crate::quant::Int8Slot::zeros(3);
        ms.encode_from(&m_in);
        vs.encode_from(&v_in);
        let mut dec = [0.0f32; 3];
        vs.decode_into(&mut dec);
        assert!(dec[2] < 0.0, "premise: collapsed v decodes negative, got {}", dec[2]);
        let mut p = [0.1f32; 3];
        let g = [0.0f32, 0.0, 1e-6];
        let lr = 2e-3f32;
        let (mut mb, mut vb) = (vec![0.0f32; 3], vec![0.0f32; 3]);
        adamw_update_int8(&mut p, &g, &mut ms, &mut vs, lr, 5.0, 0.0, &mut mb, &mut vb);
        let step = (0.1 - p[2]).abs();
        assert!(
            step <= lr * INT8_UPDATE_CLIP * 1.001,
            "clamp must bound the degenerate step, got {step}"
        );
        assert!(step > lr * 2.0, "the degenerate element should hit the clamp, got {step}");
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn adamw_weight_decay_shrinks_params() {
        let mut p = [2.0f32];
        let g = [0.0f32];
        let mut m = [0.0f32];
        let mut v = [0.0f32];
        adamw_update(&mut p, &g, &mut m, &mut v, 0.1, 1.0, 0.01);
        assert_close(p[0], 2.0 * (1.0 - 0.1 * 0.01), 1e-6);
    }
}
