//! The pure-Rust CPU reference backend (DESIGN.md §4.1).
//!
//! Always available, dependency-free, and bitwise deterministic: this is
//! the backend `cargo test` and CI drive end to end. It synthesizes its own
//! manifest (no `artifacts/` directory) describing a tiny-transformer
//! substrate whose train step mirrors the reference semantics in
//! `python/compile/kernels/ref.py`, and registers the same executable
//! names the PJRT artifact set uses, so every harness workflow —
//! `run_variant`, the ablation ladder, the Unsloth-bug verify demo — runs
//! unchanged against it.

pub mod math;
pub mod model;

pub use model::{CpuState, LoraCfg, ModelDims};

use super::{
    AdapterState, Backend, DeviceBatch, DeviceState, FusedOutputs, FusedSlice, MemoryCfg,
    RowGrad, StepOutputs,
};
use crate::batching::Batch;
use crate::manifest::{
    DType, ExecutableSpec, Manifest, ModelConfigEcho, Role, StepConfigEcho, TensorSpec,
};
use crate::quant::{OptimSnapshot, OptimStates};
use crate::runtime::HostTensor;
use anyhow::{anyhow, bail, ensure, Result};
use std::path::PathBuf;

/// Reference batch geometry: small enough that a full train step is
/// sub-millisecond, large enough that BFD packing has real work to do.
pub const REF_BATCH: usize = 4;
pub const REF_SEQ: usize = 64;
/// LoRA adapter geometry for the reference `lora` family.
pub const REF_LORA_RANK: usize = 4;
pub const REF_LORA_ALPHA: usize = 8;

/// The reference substrate model (vocab ≫ is not needed here; the CCE
/// memory experiments live on the PJRT side).
pub(crate) fn reference_dims() -> ModelDims {
    ModelDims { vocab: 64, d_model: 32, n_layers: 2, n_heads: 4, n_kv_heads: 2, d_ff: 64 }
}

pub struct CpuBackend {
    manifest: Manifest,
}

impl Default for CpuBackend {
    fn default() -> Self {
        CpuBackend::new()
    }
}

/// One registered executable family member.
struct VariantDef {
    name: &'static str,
    kind: &'static str, // train | init | eval
    family: &'static str,
    kernels: &'static str,
    broken: bool,
}

const VARIANTS: &[VariantDef] = &[
    // full fine-tuning family: the ablation ladder rungs are semantic
    // aliases on this backend (the reference math is already "fused").
    VariantDef { name: "init_chronicals", kind: "init", family: "full", kernels: "reference", broken: false },
    VariantDef { name: "eval_chronicals", kind: "eval", family: "full", kernels: "reference", broken: false },
    VariantDef { name: "train_step_chronicals", kind: "train", family: "full", kernels: "reference", broken: false },
    VariantDef { name: "train_step_ablate_naive", kind: "train", family: "full", kernels: "reference_naive", broken: false },
    VariantDef { name: "train_step_ablate_flash", kind: "train", family: "full", kernels: "reference_flash", broken: false },
    VariantDef { name: "train_step_ablate_compiled", kind: "train", family: "full", kernels: "reference_compiled", broken: false },
    VariantDef { name: "train_step_ablate_liger", kind: "train", family: "full", kernels: "reference_liger", broken: false },
    // LoRA family, including the intentionally-broken zero-gradient config
    // (the paper's §8 "fast mode" failure).
    VariantDef { name: "init_lora", kind: "init", family: "lora", kernels: "reference", broken: false },
    VariantDef { name: "eval_lora", kind: "eval", family: "lora", kernels: "reference", broken: false },
    VariantDef { name: "train_step_lora", kind: "train", family: "lora", kernels: "reference", broken: false },
    VariantDef { name: "train_step_lora_naive", kind: "train", family: "lora", kernels: "reference_naive", broken: false },
    VariantDef { name: "train_step_lora_broken", kind: "train", family: "lora", kernels: "reference", broken: true },
];

fn lora_cfg() -> LoraCfg {
    LoraCfg { rank: REF_LORA_RANK, alpha: REF_LORA_ALPHA as f32 }
}

pub(crate) fn family_lora(family: &str) -> Option<LoraCfg> {
    if family == "lora" {
        Some(lora_cfg())
    } else {
        None
    }
}

impl CpuBackend {
    pub fn new() -> CpuBackend {
        CpuBackend {
            manifest: synth_manifest(reference_dims(), REF_BATCH, REF_SEQ, "cpu-reference"),
        }
    }

    /// A backend with custom batch geometry (tests exercising other B/S).
    pub fn with_geometry(batch: usize, seq: usize) -> CpuBackend {
        CpuBackend { manifest: synth_manifest(reference_dims(), batch, seq, "cpu-reference") }
    }

    fn spec(&self, name: &str) -> Result<&ExecutableSpec> {
        self.manifest.get(name)
    }
}

/// Build the synthesized manifest for a CPU substrate backend. Shared with
/// the fast backend (`super::cpu_fast`): both register the same executable
/// families over the same batch geometry, so every harness workflow runs
/// on either and cross-backend parity tests line up by executable name.
pub(crate) fn synth_manifest(
    dims: ModelDims,
    batch: usize,
    seq: usize,
    profile: &str,
) -> Manifest {
    let executables = VARIANTS
        .iter()
        .map(|v| {
            let lora = family_lora(v.family);
            let (layout, n_trainable) = model::param_layout(&dims, lora.as_ref());
            let param_count: u64 = layout
                .iter()
                .map(|(_, s)| s.iter().product::<usize>() as u64)
                .sum();
            let trainable_param_count: u64 = layout[..n_trainable]
                .iter()
                .map(|(_, s)| s.iter().product::<usize>() as u64)
                .sum();
            let variant = v
                .name
                .strip_prefix("train_step_")
                .or_else(|| v.name.strip_prefix("init_"))
                .or_else(|| v.name.strip_prefix("eval_"))
                .unwrap_or(v.name);
            let mut inputs = Vec::new();
            for batch_name in ["tokens", "targets", "seg_ids", "pos_ids"] {
                inputs.push(TensorSpec {
                    name: batch_name.into(),
                    shape: vec![batch, seq],
                    dtype: DType::I32,
                    role: Role::Batch,
                });
            }
            for scalar in ["step", "lr", "lr_b"] {
                inputs.push(TensorSpec {
                    name: scalar.into(),
                    shape: vec![],
                    dtype: DType::F32,
                    role: Role::Scalar,
                });
            }
            ExecutableSpec {
                name: v.name.into(),
                file: String::new(), // nothing on disk: the step is native code
                kind: v.kind.into(),
                variant: variant.into(),
                family: v.family.into(),
                batch,
                seq,
                n_trainable,
                n_frozen: layout.len() - n_trainable,
                n_slots: 2, // AdamW m, v
                param_count,
                trainable_param_count,
                step_config: StepConfigEcho {
                    attention: "segment_masked_causal".into(),
                    kernels: v.kernels.into(),
                    loss: "masked_cross_entropy".into(),
                    optimizer: "adamw".into(),
                    broken: v.broken,
                    lora_rank: lora.map(|l| l.rank).unwrap_or(0),
                    lora_alpha: lora.map(|l| l.alpha as usize).unwrap_or(0),
                },
                model_config: ModelConfigEcho {
                    vocab: dims.vocab,
                    d_model: dims.d_model,
                    n_layers: dims.n_layers,
                    n_heads: dims.n_heads,
                    n_kv_heads: dims.n_kv_heads,
                    d_ff: dims.d_ff,
                },
                inputs,
                outputs: vec!["loss".into(), "grad_norm".into(), "n_tokens".into()],
            }
        })
        .collect();
    Manifest { profile: profile.into(), dir: PathBuf::new(), executables }
}

/// Model geometry echoed by an executable spec — the dims every CPU-family
/// state and adapter for that executable must carry.
pub(crate) fn spec_dims(spec: &ExecutableSpec) -> ModelDims {
    ModelDims {
        vocab: spec.model_config.vocab,
        d_model: spec.model_config.d_model,
        n_layers: spec.model_config.n_layers,
        n_heads: spec.model_config.n_heads,
        n_kv_heads: spec.model_config.n_kv_heads,
        d_ff: spec.model_config.d_ff,
    }
}

// ---- multi-tenant adapter seam (DESIGN.md §11) -----------------------
//
// Both CPU backends share the `CpuState` layout, so one implementation of
// the adapter split serves both; a validation fix applied here reaches
// `cpu` and `cpu-fast` alike.

pub(crate) fn cpu_init_adapter(spec: &ExecutableSpec, seed: i32) -> Result<AdapterState> {
    let lora = family_lora(&spec.family).ok_or_else(|| {
        anyhow!(
            "executable '{}' (family '{}') has no LoRA adapters — only the lora \
             family supports per-tenant adapter state",
            spec.name,
            spec.family
        )
    })?;
    Ok(AdapterState::Cpu(model::init_adapter(spec_dims(spec), lora, seed)))
}

pub(crate) fn cpu_swap_adapter(state: &mut DeviceState, adapter: &mut AdapterState) -> Result<()> {
    let s = as_cpu_state_mut(state)?;
    let AdapterState::Cpu(a) = adapter;
    model::swap_adapter(s, a)
}

pub(crate) fn cpu_adapter_params(adapter: &AdapterState) -> Result<Vec<HostTensor>> {
    let AdapterState::Cpu(a) = adapter;
    Ok(a.params.clone())
}

pub(crate) fn as_cpu_state(state: &DeviceState) -> Result<&CpuState> {
    match state {
        DeviceState::Cpu(s) => Ok(s),
        #[cfg(feature = "pjrt")]
        _ => bail!("state was created by a different backend than 'cpu'"),
    }
}

pub(crate) fn as_cpu_state_mut(state: &mut DeviceState) -> Result<&mut CpuState> {
    match state {
        DeviceState::Cpu(s) => Ok(s),
        #[cfg(feature = "pjrt")]
        _ => bail!("state was created by a different backend than 'cpu'"),
    }
}

/// The reference step is shape-polymorphic, but the PJRT executables are
/// not; enforce the manifest geometry on both backends so behavior never
/// diverges by backend.
pub(crate) fn check_geometry(spec: &ExecutableSpec, b: &Batch) -> Result<()> {
    if b.batch != spec.batch || b.seq != spec.seq {
        bail!(
            "batch geometry [{}, {}] does not match executable '{}' [{}, {}]",
            b.batch,
            b.seq,
            spec.name,
            spec.batch,
            spec.seq
        );
    }
    Ok(())
}

/// Restore checkpoint tensors into a CPU-family state. Shared by both CPU
/// backends — they use the same `CpuState` layout, so validation must stay
/// identical (a fix applied here reaches both).
///
/// On a quantized-base state, incoming frozen quantizable matrices are
/// re-encoded through the state's codec instead of stored dense. Values
/// from a quantized state's own checkpoint sit on the codec grid, so the
/// resume roundtrip is bitwise lossless.
pub(crate) fn load_cpu_params(s: &mut CpuState, params: &[HostTensor]) -> Result<()> {
    if params.len() != s.params.len() {
        bail!(
            "checkpoint has {} tensors, state expects {}",
            params.len(),
            s.params.len()
        );
    }
    for (i, (cur, new)) in s.params.iter().zip(params).enumerate() {
        if cur.shape() != new.shape() {
            bail!(
                "tensor {} ('{}') shape mismatch: checkpoint {:?} vs state {:?}",
                i,
                s.names[i],
                new.shape(),
                cur.shape()
            );
        }
        new.as_f32()?; // checkpoints are f32-only
    }
    for i in 0..params.len() {
        if s.qbase.get(i).map(|q| q.is_some()) == Some(true) {
            model::requantize_base_tensor(s, i, params[i].as_f32()?.to_vec())?;
        } else {
            s.params[i] = params[i].clone();
        }
    }
    Ok(())
}

/// Export a CPU-family state's parameters as dense f32 host tensors (the
/// checkpoint interchange format): quantized frozen matrices are
/// dequantized whole into fresh tensors; everything else is cloned.
pub(crate) fn cpu_state_params(s: &CpuState) -> Result<Vec<HostTensor>> {
    let mut out = Vec::with_capacity(s.params.len());
    for (i, t) in s.params.iter().enumerate() {
        match s.qbase.get(i).and_then(|q| q.as_ref()) {
            Some(qm) => out.push(HostTensor::f32(qm.dequant(), t.shape().to_vec())),
            None => out.push(t.clone()),
        }
    }
    Ok(out)
}

/// Shared [`Backend::configure_memory`] implementation for the CPU-family
/// backends: optimizer-state codec, then base-weight quantization, then the
/// checkpoint segment count. Order matters only for error quality — every
/// tier validates independently.
pub(crate) fn cpu_configure_memory(s: &mut CpuState, cfg: &MemoryCfg) -> Result<()> {
    model::set_optim_states(s, cfg.optim_states)?;
    if let Some(codec) = cfg.base_quant {
        if s.base_quant != Some(codec) {
            model::quantize_base(s, codec)?;
        }
    }
    s.ckpt_segments = cfg.ckpt_segments;
    Ok(())
}

pub(crate) fn cpu_convert_adapter_optim(
    adapter: &mut AdapterState,
    codec: OptimStates,
) -> Result<()> {
    let AdapterState::Cpu(a) = adapter;
    model::set_adapter_optim(a, codec)
}

pub(crate) fn batch_view(b: &Batch) -> Result<model::BatchView<'_>> {
    Ok(model::BatchView {
        tokens: b.tokens.as_i32()?,
        targets: b.targets.as_i32()?,
        seg: b.seg_ids.as_i32()?,
        pos: b.pos_ids.as_i32()?,
        bsz: b.batch,
        seq: b.seq,
    })
}

/// A borrowed single-row view of a staged batch — the data-parallel row
/// shard (DESIGN.md §10). Sound because every part of the step is
/// row-local: segment-masked attention never attends across batch rows,
/// norms and the loss are per-position, so row `r` of the full batch and a
/// `[1, S]` batch holding only row `r` compute identical values.
pub(crate) fn row_view(b: &Batch, row: usize) -> Result<model::BatchView<'_>> {
    ensure!(row < b.batch, "shard row {row} out of range for batch of {} rows", b.batch);
    let (lo, hi) = (row * b.seq, (row + 1) * b.seq);
    Ok(model::BatchView {
        tokens: &b.tokens.as_i32()?[lo..hi],
        targets: &b.targets.as_i32()?[lo..hi],
        seg: &b.seg_ids.as_i32()?[lo..hi],
        pos: &b.pos_ids.as_i32()?[lo..hi],
        bsz: 1,
        seq: b.seq,
    })
}

/// Shared validation for the intra-step fused seam (DESIGN.md §11): a
/// non-broken LoRA train executable and a concatenated batch whose
/// sequence length matches the spec. The row count is deliberately *not*
/// checked against `spec.batch` — a fused round concatenates several
/// tenants' batches, so the row total is validated against the slice map
/// inside the model instead. Both CPU backends call this so their fused
/// paths reject identical inputs.
pub(crate) fn check_fused_batch(
    spec: &ExecutableSpec,
    b: &Batch,
    slices: &[FusedSlice],
) -> Result<()> {
    if spec.kind != "train" {
        bail!("'{}' is not a train executable (kind = {})", spec.name, spec.kind);
    }
    if spec.step_config.broken {
        bail!(
            "'{}' is a broken (zero-gradient) executable — refusing to fuse it",
            spec.name
        );
    }
    if family_lora(&spec.family).is_none() {
        bail!(
            "executable '{}' (family '{}') has no LoRA adapters — intra-step fusion \
             requires the lora family",
            spec.name,
            spec.family
        );
    }
    if b.seq != spec.seq {
        bail!(
            "concatenated batch seq {} does not match executable '{}' seq {}",
            b.seq,
            spec.name,
            spec.seq
        );
    }
    let rows: usize = slices.iter().map(|s| s.rows).sum();
    if rows != b.batch {
        bail!(
            "slice map covers {rows} rows but the concatenated batch has {} — \
             the serve scheduler built an inconsistent round",
            b.batch
        );
    }
    Ok(())
}

/// Unwrap a slice of [`AdapterState`]s into the CPU adapters both CPU
/// backends train. Infallible today (CPU is the only adapter variant) but
/// kept as the single seam to extend when another backend grows adapters.
pub(crate) fn cpu_adapters_mut(adapters: &mut [AdapterState]) -> Vec<&mut model::CpuAdapter> {
    adapters
        .iter_mut()
        .map(|a| {
            let AdapterState::Cpu(ad) = a;
            ad
        })
        .collect()
}

/// Shared spec/family/geometry validation for the data-parallel seams —
/// the same guards `train_step` applies, factored so both CPU backends
/// stay exactly as strict on the sharded path.
pub(crate) fn check_shard_call<'b>(
    spec: &ExecutableSpec,
    lora: Option<model::LoraCfg>,
    state_lora: Option<model::LoraCfg>,
    batch: &'b DeviceBatch,
) -> Result<&'b Batch> {
    if spec.kind != "train" {
        bail!("'{}' is not a train executable (kind = {})", spec.name, spec.kind);
    }
    if state_lora != lora {
        bail!(
            "state family mismatch: executable '{}' expects lora={:?}, state has {:?}",
            spec.name,
            lora,
            state_lora
        );
    }
    let b = match batch {
        DeviceBatch::Cpu(b) => b,
        #[cfg(feature = "pjrt")]
        _ => bail!("batch was uploaded to a different backend"),
    };
    check_geometry(spec, b)?;
    Ok(b)
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn init_state(&self, init_name: &str, seed: i32) -> Result<DeviceState> {
        let spec = self.spec(init_name)?;
        if spec.kind != "init" {
            bail!("'{init_name}' is not an init executable (kind = {})", spec.kind);
        }
        let lora = family_lora(&spec.family);
        Ok(DeviceState::Cpu(model::init_state(spec_dims(spec), lora, seed)))
    }

    fn upload_batch(&self, train_name: &str, batch: &Batch) -> Result<DeviceBatch> {
        // "upload" on the host backend is a defensive copy; validate dtype
        // and geometry now so errors point at the right call site — and so
        // CPU is exactly as strict as PJRT's compiled shapes.
        let spec = self.spec(train_name)?;
        check_geometry(spec, batch)?;
        batch_view(batch)?;
        Ok(DeviceBatch::Cpu(batch.clone()))
    }

    fn train_step(
        &self,
        train_name: &str,
        state: &mut DeviceState,
        batch: &DeviceBatch,
        step: u64,
        lr: f32,
        lr_b: f32,
    ) -> Result<StepOutputs> {
        let spec = self.spec(train_name)?;
        if spec.kind != "train" {
            bail!("'{train_name}' is not a train executable (kind = {})", spec.kind);
        }
        let broken = spec.step_config.broken;
        let expect_lora = family_lora(&spec.family);
        let s = as_cpu_state_mut(state)?;
        if s.lora != expect_lora {
            bail!(
                "state family mismatch: executable '{train_name}' expects lora={:?}, state has {:?}",
                expect_lora,
                s.lora
            );
        }
        let b = match batch {
            DeviceBatch::Cpu(b) => b,
            #[cfg(feature = "pjrt")]
            _ => bail!("batch was uploaded to a different backend"),
        };
        // re-check geometry: DeviceBatch::Cpu is a public variant, so a
        // batch may not have come through upload_batch
        check_geometry(spec, b)?;
        let view = batch_view(b)?;
        let out = model::train_step(s, &view, broken, step, lr, lr_b)?;
        Ok(StepOutputs {
            loss: out.loss,
            grad_norm: out.grad_norm,
            n_tokens: out.n_tokens,
            phases: out.phases,
        })
    }

    fn init_adapter(&self, train_name: &str, seed: i32) -> Result<AdapterState> {
        cpu_init_adapter(self.spec(train_name)?, seed)
    }

    fn swap_adapter(&self, state: &mut DeviceState, adapter: &mut AdapterState) -> Result<()> {
        cpu_swap_adapter(state, adapter)
    }

    fn adapter_params(&self, adapter: &AdapterState) -> Result<Vec<HostTensor>> {
        cpu_adapter_params(adapter)
    }

    fn supports_fused_step(&self) -> bool {
        true
    }

    fn fused_step(
        &self,
        train_name: &str,
        state: &DeviceState,
        adapters: &mut [AdapterState],
        batch: &Batch,
        slices: &[FusedSlice],
    ) -> Result<FusedOutputs> {
        let spec = self.spec(train_name)?;
        check_fused_batch(spec, batch, slices)?;
        let s = as_cpu_state(state)?;
        let expect_lora = family_lora(&spec.family);
        if s.lora != expect_lora {
            bail!(
                "state family mismatch: executable '{train_name}' expects lora={:?}, state has {:?}",
                expect_lora,
                s.lora
            );
        }
        let view = batch_view(batch)?;
        let mut ads = cpu_adapters_mut(adapters);
        let (outs, phases) = model::fused_train_step(s, &mut ads, &view, slices)?;
        Ok(FusedOutputs {
            tenants: outs
                .into_iter()
                .map(|o| StepOutputs {
                    loss: o.loss,
                    grad_norm: o.grad_norm,
                    n_tokens: o.n_tokens,
                    phases: o.phases,
                })
                .collect(),
            phases,
        })
    }

    fn flat_grad_len(&self, state: &DeviceState) -> Result<usize> {
        Ok(model::flat_grad_len(as_cpu_state(state)?))
    }

    fn grad_row(
        &self,
        train_name: &str,
        state: &DeviceState,
        batch: &DeviceBatch,
        row: usize,
        global_n_valid: usize,
        out: &mut [f32],
    ) -> Result<RowGrad> {
        let spec = self.spec(train_name)?;
        let s = as_cpu_state(state)?;
        let b = check_shard_call(spec, family_lora(&spec.family), s.lora, batch)?;
        let view = row_view(b, row)?;
        let (loss_sum, fwd_s, bwd_s) = model::grad_row_into(s, &view, global_n_valid, out)?;
        Ok(RowGrad { loss_sum, fwd_s, bwd_s })
    }

    fn apply_grads(
        &self,
        train_name: &str,
        state: &mut DeviceState,
        flat: &[f32],
        step: u64,
        lr: f32,
        lr_b: f32,
    ) -> Result<()> {
        let spec = self.spec(train_name)?;
        if spec.kind != "train" {
            bail!("'{train_name}' is not a train executable (kind = {})", spec.kind);
        }
        model::apply_flat_grads(as_cpu_state_mut(state)?, flat, step, lr, lr_b)
    }

    fn eval_loss(&self, eval_name: &str, state: &DeviceState, batch: &Batch) -> Result<f32> {
        let spec = self.spec(eval_name)?;
        if spec.kind != "eval" && spec.kind != "train" {
            bail!("'{eval_name}' cannot evaluate (kind = {})", spec.kind);
        }
        check_geometry(spec, batch)?;
        let expect_lora = family_lora(&spec.family);
        let s = as_cpu_state(state)?;
        if s.lora != expect_lora {
            bail!(
                "state family mismatch: executable '{eval_name}' expects lora={:?}, state has {:?}",
                expect_lora,
                s.lora
            );
        }
        let view = batch_view(batch)?;
        model::eval_loss(s, &view)
    }

    fn state_params(&self, state: &DeviceState) -> Result<Vec<HostTensor>> {
        cpu_state_params(as_cpu_state(state)?)
    }

    fn load_params(&self, state: &mut DeviceState, params: &[HostTensor]) -> Result<()> {
        load_cpu_params(as_cpu_state_mut(state)?, params)
    }

    fn configure_memory(&self, state: &mut DeviceState, cfg: &MemoryCfg) -> Result<()> {
        cpu_configure_memory(as_cpu_state_mut(state)?, cfg)
    }

    fn optim_snapshot(&self, state: &DeviceState) -> Result<OptimSnapshot> {
        Ok(model::optim_snapshot(as_cpu_state(state)?))
    }

    fn load_optim_snapshot(&self, state: &mut DeviceState, snap: &OptimSnapshot) -> Result<()> {
        model::load_optim_snapshot(as_cpu_state_mut(state)?, snap)
    }

    fn convert_adapter_optim(&self, adapter: &mut AdapterState, codec: OptimStates) -> Result<()> {
        cpu_convert_adapter_optim(adapter, codec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_covers_reference_families() {
        let be = CpuBackend::new();
        for name in [
            "train_step_chronicals",
            "train_step_lora",
            "train_step_lora_broken",
            "init_chronicals",
            "init_lora",
            "eval_chronicals",
        ] {
            assert!(be.manifest().get(name).is_ok(), "missing {name}");
        }
        assert_eq!(be.manifest().profile, "cpu-reference");
    }

    #[test]
    fn lora_spec_has_fewer_trainable_params() {
        let be = CpuBackend::new();
        let full = be.manifest().get("train_step_chronicals").unwrap();
        let lora = be.manifest().get("train_step_lora").unwrap();
        assert_eq!(full.param_count, full.trainable_param_count);
        assert!(lora.trainable_param_count < lora.param_count);
        assert!(lora.param_count > full.param_count); // base + adapters
        assert_eq!(lora.n_slots, 2);
    }

    #[test]
    fn init_rejects_train_executable() {
        let be = CpuBackend::new();
        assert!(be.init_state("train_step_chronicals", 1).is_err());
        assert!(be.init_state("init_chronicals", 1).is_ok());
    }

    #[test]
    fn init_is_seed_deterministic() {
        let be = CpuBackend::new();
        let a = be.init_state("init_chronicals", 9).unwrap();
        let b = be.init_state("init_chronicals", 9).unwrap();
        let (pa, pb) = (be.state_params(&a).unwrap(), be.state_params(&b).unwrap());
        assert_eq!(pa, pb);
        let c = be.init_state("init_chronicals", 10).unwrap();
        assert_ne!(pa, be.state_params(&c).unwrap());
    }

    fn spec_geometry_batch(be: &CpuBackend, exe: &str) -> Batch {
        let spec = be.manifest().get(exe).unwrap();
        let exs: Vec<crate::data::TokenizedExample> = (0..spec.batch as i32)
            .map(|i| crate::data::TokenizedExample {
                tokens: vec![4 + i, 5 + i, 6 + i],
                targets: vec![5 + i, 6 + i, -1],
            })
            .collect();
        crate::batching::padded_batches(&exs, spec.batch, spec.seq).remove(0)
    }

    #[test]
    fn state_family_mismatch_rejected() {
        let be = CpuBackend::new();
        let mut full_state = be.init_state("init_chronicals", 1).unwrap();
        let batch = spec_geometry_batch(&be, "train_step_lora");
        let ub = be.upload_batch("train_step_lora", &batch).unwrap();
        assert!(be
            .train_step("train_step_lora", &mut full_state, &ub, 1, 1e-3, 1e-3)
            .is_err());
        // eval is exactly as strict as the train path
        assert!(be.eval_loss("eval_lora", &full_state, &batch).is_err());
    }

    #[test]
    fn wrong_geometry_batch_rejected() {
        let be = CpuBackend::new();
        let exs = vec![crate::data::TokenizedExample {
            tokens: vec![4, 5, 6, 7],
            targets: vec![5, 6, 7, -1],
        }];
        // spec geometry is 4x64; a 1x8 batch must be refused at staging,
        // exactly like PJRT's compiled shapes would refuse it at execute
        let batch = crate::batching::padded_batches(&exs, 1, 8).remove(0);
        let err = be
            .upload_batch("train_step_chronicals", &batch)
            .map(|_| ())
            .unwrap_err();
        assert!(err.to_string().contains("geometry"), "{err}");
        let state = be.init_state("init_chronicals", 1).unwrap();
        assert!(be.eval_loss("eval_chronicals", &state, &batch).is_err());
    }

    #[test]
    fn load_params_validates_shapes() {
        let be = CpuBackend::new();
        let mut state = be.init_state("init_chronicals", 1).unwrap();
        let mut params = be.state_params(&state).unwrap();
        assert!(be.load_params(&mut state, &params).is_ok());
        params.pop();
        assert!(be.load_params(&mut state, &params).is_err());
    }
}
