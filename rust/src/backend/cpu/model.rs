//! The tiny-transformer reference train step, in pure Rust.
//!
//! Architecture (mirroring `python/compile/model.py` and the reference
//! semantics in `python/compile/kernels/ref.py`):
//!
//! ```text
//! embed → N × [ RMSNorm → QKV(+LoRA) → RoPE → segment-masked causal
//!               attention (GQA) → Wo → +residual → RMSNorm → SwiGLU MLP
//!               → +residual ] → RMSNorm → head → masked cross-entropy
//! ```
//!
//! with a hand-derived backward pass and a fused AdamW update carrying the
//! LoRA+ dual learning rate (`lr_b` for `*_b` adapter matrices, paper
//! Thm. 1). The backward formulas were derived against central finite
//! differences in both full-FT and LoRA modes (worst relative error ~5e-6;
//! DESIGN.md §4.1), and the composed backward is guarded in-repo by the
//! `whole_model_gradient_matches_directional_derivative` test below.
//!
//! Everything is sequential `f32`: two runs with identical state and batch
//! produce bitwise-identical losses, gradients and parameter updates.

use super::math::{
    adamw_update, adamw_update_int8, linear_bwd_w, linear_bwd_x, linear_fwd, rmsnorm_bwd,
    rmsnorm_fwd, rope_apply, softmax_xent, swiglu_bwd, swiglu_fwd,
};
use crate::backend::{FusedSlice, StepPhases};
use crate::optim::{classify_param, ParamGroup};
use crate::quant::{BaseQuant, Int8Slot, OptimSnapshot, OptimStates, QuantMat};
use crate::runtime::HostTensor;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::HashMap;
use std::time::Instant;

pub const WEIGHT_DECAY: f32 = 0.01;

/// Model geometry for the reference backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
}

impl ModelDims {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// KV projection width (GQA: `n_kv_heads · head_dim`).
    pub fn d_kv(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }
}

/// LoRA adapter geometry (rank-`r` adapters on Wq and Wv, paper Def. 10).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoraCfg {
    pub rank: usize,
    pub alpha: f32,
}

impl LoraCfg {
    pub fn scale(&self) -> f32 {
        self.alpha / self.rank as f32
    }
}

/// The CPU backend's training state: host parameters + AdamW slots.
///
/// `params` follows the Backend state-layout convention — trainable tensors
/// first, then frozen — so checkpoints and `state_params()` line up with the
/// PJRT backend (DESIGN.md §3).
#[derive(Debug, Clone)]
pub struct CpuState {
    pub dims: ModelDims,
    pub lora: Option<LoraCfg>,
    /// Tensor names, parallel to `params` (trainable then frozen).
    pub names: Vec<String>,
    pub params: Vec<HostTensor>,
    pub n_trainable: usize,
    /// AdamW first/second-moment slots, parallel to the trainable prefix
    /// (entries are empty placeholders when `optim` is `Int8`).
    pub slot_m: Vec<Vec<f32>>,
    pub slot_v: Vec<Vec<f32>>,
    /// Optimizer-state codec (ROADMAP "memory tiers"). `Int8` stores the
    /// moments in `qslot_m`/`qslot_v` instead of `slot_m`/`slot_v`.
    pub optim: OptimStates,
    /// Quantized AdamW slots, parallel to the trainable prefix (empty when
    /// `optim` is `Fp32`).
    pub qslot_m: Vec<Int8Slot>,
    pub qslot_v: Vec<Int8Slot>,
    /// Frozen-base weight codec for LoRA tasks; `None` = dense f32 base.
    pub base_quant: Option<BaseQuant>,
    /// Quantized frozen weight matrices, parallel to `params` (`Some` only
    /// for quantized frozen 2-D mats, whose `params` entry then holds an
    /// empty payload — the FP32 copy is genuinely gone).
    pub qbase: Vec<Option<QuantMat>>,
    /// Activation-checkpoint segments (0 = off): backward recomputes each
    /// segment's interior activations from its boundary instead of caching
    /// the whole depth.
    pub ckpt_segments: usize,
}

/// One batch, viewed as flat `[B·S]` slices.
pub struct BatchView<'a> {
    pub tokens: &'a [i32],
    pub targets: &'a [i32],
    pub seg: &'a [i32],
    pub pos: &'a [i32],
    pub bsz: usize,
    pub seq: usize,
}

impl BatchView<'_> {
    fn t(&self) -> usize {
        self.bsz * self.seq
    }
}

/// Parameter layout for a variant: `(name, shape)` in state order
/// (trainable first, then frozen) plus the trainable count.
pub fn param_layout(dims: &ModelDims, lora: Option<&LoraCfg>) -> (Vec<(String, Vec<usize>)>, usize) {
    let (v, d, f) = (dims.vocab, dims.d_model, dims.d_ff);
    let dkv = dims.d_kv();
    let mut base: Vec<(String, Vec<usize>)> = vec![("embed".into(), vec![v, d])];
    for l in 0..dims.n_layers {
        let p = format!("layer_{l:02}.");
        base.push((format!("{p}norm1"), vec![d]));
        base.push((format!("{p}wq"), vec![d, d]));
        base.push((format!("{p}wk"), vec![dkv, d]));
        base.push((format!("{p}wv"), vec![dkv, d]));
        base.push((format!("{p}wo"), vec![d, d]));
        base.push((format!("{p}norm2"), vec![d]));
        base.push((format!("{p}w_gate"), vec![f, d]));
        base.push((format!("{p}w_up"), vec![f, d]));
        base.push((format!("{p}w_down"), vec![d, f]));
    }
    base.push(("norm_f".into(), vec![d]));
    base.push(("w_head".into(), vec![v, d]));

    match lora {
        None => {
            let n = base.len();
            (base, n)
        }
        Some(lc) => {
            let r = lc.rank;
            let mut adapters: Vec<(String, Vec<usize>)> = Vec::new();
            for l in 0..dims.n_layers {
                let p = format!("layer_{l:02}.");
                adapters.push((format!("{p}wq_a"), vec![r, d]));
                adapters.push((format!("{p}wq_b"), vec![d, r]));
                adapters.push((format!("{p}wv_a"), vec![r, d]));
                adapters.push((format!("{p}wv_b"), vec![dkv, r]));
            }
            let n = adapters.len();
            adapters.extend(base);
            (adapters, n)
        }
    }
}

/// Deterministic parameter init: norms = 1, LoRA B = 0 (paper §5), LoRA A and
/// projections small normals. Draw order is the state order, so a seed fully
/// determines every tensor.
pub fn init_state(dims: ModelDims, lora: Option<LoraCfg>, seed: i32) -> CpuState {
    let (layout, n_trainable) = param_layout(&dims, lora.as_ref());
    let mut rng = Rng::new(seed as u32 as u64);
    let mut names = Vec::with_capacity(layout.len());
    let mut params = Vec::with_capacity(layout.len());
    for (name, shape) in layout {
        let n: usize = shape.iter().product();
        let short = name.rsplit('.').next().unwrap_or(&name);
        let data: Vec<f32> = if short.starts_with("norm") {
            vec![1.0; n]
        } else if short.ends_with("_b") {
            vec![0.0; n]
        } else {
            let scale = if short.ends_with("_a") {
                0.1
            } else if short == "embed" || short == "w_head" {
                0.05
            } else {
                0.08
            };
            (0..n).map(|_| rng.normal() as f32 * scale).collect()
        };
        names.push(name);
        params.push(HostTensor::f32(data, shape));
    }
    let slot_m: Vec<Vec<f32>> = params[..n_trainable]
        .iter()
        .map(|t| vec![0.0; t.elements()])
        .collect();
    let slot_v = slot_m.clone();
    CpuState {
        dims,
        lora,
        names,
        params,
        n_trainable,
        slot_m,
        slot_v,
        optim: OptimStates::Fp32,
        qslot_m: Vec::new(),
        qslot_v: Vec::new(),
        base_quant: None,
        qbase: Vec::new(),
        ckpt_segments: 0,
    }
}

/// Switch the state's optimizer-state codec (memory tier 1). Legal only
/// while every moment slot is still zero — i.e. before the first optimizer
/// step — because converting a live moment estimate across codecs would be
/// silently lossy. Zero slots convert exactly, so a fresh int8 run's first
/// step is bit-identical to the fp32 run's first step.
pub fn set_optim_states(state: &mut CpuState, codec: OptimStates) -> Result<()> {
    if state.optim == codec {
        return Ok(());
    }
    let zeroed = match state.optim {
        OptimStates::Fp32 => state
            .slot_m
            .iter()
            .chain(&state.slot_v)
            .all(|s| s.iter().all(|&x| x == 0.0)),
        OptimStates::Int8 => state
            .qslot_m
            .iter()
            .chain(&state.qslot_v)
            .all(|s| s.q.data.iter().all(|&b| b == 0) && s.comp.iter().all(|&c| c == 0.0)),
    };
    ensure!(
        zeroed,
        "cannot change the optimizer-state codec from {} to {} after training started: \
         the moment slots are non-zero and cross-codec migration is not supported — \
         restart from init or resume a checkpoint saved with the requested codec",
        state.optim.name(),
        codec.name()
    );
    match codec {
        OptimStates::Int8 => {
            state.qslot_m = state.params[..state.n_trainable]
                .iter()
                .map(|t| Int8Slot::zeros(t.elements()))
                .collect();
            state.qslot_v = state.qslot_m.clone();
            // keep placeholder entries so index-parallel code (swap_adapter)
            // stays uniform, but drop the fp32 payloads
            for s in state.slot_m.iter_mut().chain(state.slot_v.iter_mut()) {
                *s = Vec::new();
            }
        }
        OptimStates::Fp32 => {
            state.qslot_m = Vec::new();
            state.qslot_v = Vec::new();
            for (s, t) in state
                .slot_m
                .iter_mut()
                .chain(state.slot_v.iter_mut())
                .zip(state.params[..state.n_trainable].iter().cycle())
            {
                *s = vec![0.0; t.elements()];
            }
        }
    }
    state.optim = codec;
    Ok(())
}

/// True for frozen tensors that the base-quant tier stores quantized: the
/// 2-D projection/MLP/embedding matrices. Norm vectors are 1-D and tiny;
/// `w_head` feeds the streaming CCE loss — both stay dense f32 (the
/// production QLoRA pattern).
pub fn is_quantizable_base(name: &str, shape: &[usize]) -> bool {
    let short = name.rsplit('.').next().unwrap_or(name);
    shape.len() == 2 && !short.starts_with("norm") && short != "w_head"
}

/// Quantize the frozen base weights (memory tier 2). The FP32 payloads of
/// the quantized tensors are dropped — only the codec bytes remain in the
/// state; shape metadata is kept for checkpoint interchange. Requires a
/// LoRA-family state (full fine-tuning has no frozen weights).
pub fn quantize_base(state: &mut CpuState, codec: BaseQuant) -> Result<()> {
    ensure!(
        state.lora.is_some(),
        "base-weight quantization requires a LoRA-family task: full fine-tuning trains \
         every matrix, so there is no frozen base to quantize"
    );
    ensure!(
        state.base_quant.is_none(),
        "base weights are already quantized ({})",
        state.base_quant.unwrap().name()
    );
    let mut qbase: Vec<Option<QuantMat>> = vec![None; state.params.len()];
    for i in state.n_trainable..state.params.len() {
        let shape = state.params[i].shape().to_vec();
        if !is_quantizable_base(&state.names[i], &shape) {
            continue;
        }
        let qm = QuantMat::encode(state.params[i].as_f32()?, codec);
        qbase[i] = Some(qm);
        // drop the dense payload; the shape survives for interchange
        state.params[i] = HostTensor::F32 { data: Vec::new(), shape };
    }
    state.qbase = qbase;
    state.base_quant = Some(codec);
    Ok(())
}

/// Re-quantize a frozen tensor after a dense f32 load (checkpoint restore
/// into a quantized state). Values coming from a quantized state's own
/// checkpoint sit on the codec grid, so this roundtrip is bitwise lossless.
pub fn requantize_base_tensor(state: &mut CpuState, i: usize, data: Vec<f32>) -> Result<()> {
    let codec = state
        .base_quant
        .ok_or_else(|| anyhow!("state has no base-weight codec configured"))?;
    ensure!(
        state.qbase.get(i).map(|q| q.is_some()) == Some(true),
        "parameter {i} is not a quantized base tensor"
    );
    state.qbase[i] = Some(QuantMat::encode(&data, codec));
    Ok(())
}

/// Total bytes the optimizer slots occupy under the current codec — the
/// numerator of the ≥3.5x memory pin.
pub fn optim_state_bytes(state: &CpuState) -> usize {
    match state.optim {
        OptimStates::Fp32 => state
            .slot_m
            .iter()
            .chain(&state.slot_v)
            .map(|s| s.len() * 4)
            .sum(),
        OptimStates::Int8 => state
            .qslot_m
            .iter()
            .chain(&state.qslot_v)
            .map(|s| s.storage_bytes())
            .sum(),
    }
}

/// Bytes held by the frozen base weights under the current codec (dense
/// f32 tensors count at 4 bytes/element).
pub fn base_weight_bytes(state: &CpuState) -> usize {
    let mut total = 0usize;
    for i in state.n_trainable..state.params.len() {
        total += match state.qbase.get(i).and_then(|q| q.as_ref()) {
            Some(qm) => qm.storage_bytes(),
            None => state.params[i].elements() * 4,
        };
    }
    total
}

/// Export the optimizer slots for checkpointing (bitwise: int8 slots are
/// serialized as their raw bytes + scales + compensations).
pub fn optim_snapshot(state: &CpuState) -> OptimSnapshot {
    match state.optim {
        OptimStates::Fp32 => OptimSnapshot::Fp32 {
            m: state.slot_m.clone(),
            v: state.slot_v.clone(),
        },
        OptimStates::Int8 => OptimSnapshot::Int8 {
            m: state.qslot_m.clone(),
            v: state.qslot_v.clone(),
        },
    }
}

/// Restore optimizer slots from a checkpoint snapshot. The snapshot codec
/// must match the state's configured codec: fp32↔int8 migration of live
/// moments is rejected rather than silently rounded.
pub fn load_optim_snapshot(state: &mut CpuState, snap: &OptimSnapshot) -> Result<()> {
    ensure!(
        snap.len() == state.n_trainable,
        "optimizer snapshot has {} slot pairs but the state has {} trainable tensors",
        snap.len(),
        state.n_trainable
    );
    match (state.optim, snap) {
        (OptimStates::Fp32, OptimSnapshot::Fp32 { m, v }) => {
            for (i, (sm, sv)) in m.iter().zip(v).enumerate() {
                let n = state.params[i].elements();
                ensure!(
                    sm.len() == n && sv.len() == n,
                    "optimizer slot {i} length {} != parameter elements {n}",
                    sm.len()
                );
            }
            state.slot_m = m.clone();
            state.slot_v = v.clone();
        }
        (OptimStates::Int8, OptimSnapshot::Int8 { m, v }) => {
            for (i, (sm, sv)) in m.iter().zip(v).enumerate() {
                let n = state.params[i].elements();
                ensure!(
                    sm.len() == n && sv.len() == n,
                    "optimizer slot {i} length {} != parameter elements {n}",
                    sm.len()
                );
            }
            state.qslot_m = m.clone();
            state.qslot_v = v.clone();
        }
        (want, got) => bail!(
            "optimizer-state codec mismatch: the checkpoint stores {} moment slots but the \
             session is configured for --optim-states {}; fp32<->int8 optimizer-state \
             migration is not supported — resume with --optim-states {} or restart training \
             from scratch",
            got.codec().name(),
            want.name(),
            got.codec().name()
        ),
    }
    Ok(())
}

/// Name → index lookup over the state's parameter list. Shared with the
/// fast backend, which walks the same state layout.
///
/// When built via [`ParamIdx::for_state`] on a quantized-base state, each
/// quantized frozen matrix is dequantized **whole, once, up front** — the
/// reference backend's naive implementation of the per-tile dequant
/// contract (same elementwise decode, so the values are bit-identical to
/// the fast backend's tile-at-a-time leases).
pub(crate) struct ParamIdx<'a> {
    params: &'a [HostTensor],
    idx: HashMap<&'a str, usize>,
    /// Dense views of quantized frozen tensors, parallel to `params`.
    dense: Vec<Option<Vec<f32>>>,
}

impl<'a> ParamIdx<'a> {
    pub(crate) fn new(names: &'a [String], params: &'a [HostTensor]) -> ParamIdx<'a> {
        let idx = names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.as_str(), i))
            .collect();
        ParamIdx { params, idx, dense: Vec::new() }
    }

    /// Build the accessor for a state, dequantizing any quantized base
    /// tensors into dense scratch (the naive oracle path).
    pub(crate) fn for_state(state: &'a CpuState) -> ParamIdx<'a> {
        let mut p = ParamIdx::new(&state.names, &state.params);
        if state.base_quant.is_some() {
            p.dense = state
                .qbase
                .iter()
                .map(|q| q.as_ref().map(|qm| qm.dequant()))
                .collect();
        }
        p
    }

    pub(crate) fn id(&self, name: &str) -> Result<usize> {
        self.idx
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("state has no parameter '{name}' — variant/state mismatch"))
    }

    pub(crate) fn get(&self, name: &str) -> Result<&[f32]> {
        let i = self.id(name)?;
        if let Some(d) = self.dense.get(i).and_then(|o| o.as_ref()) {
            return Ok(d);
        }
        self.params[i].as_f32()
    }
}

/// Per-layer forward activations kept for the backward pass.
pub(crate) struct LayerCache {
    x_in: Vec<f32>,
    h1: Vec<f32>,
    rstd1: Vec<f32>,
    q: Vec<f32>, // post-RoPE
    k: Vec<f32>, // post-RoPE
    v: Vec<f32>,
    hq_a: Option<Vec<f32>>, // h1 @ A_q.T
    hv_a: Option<Vec<f32>>, // h1 @ A_v.T
    probs: Vec<f32>,        // [B, Hq, S, S] attention weights
    att: Vec<f32>,          // concatenated head outputs (pre-Wo)
    x_mid: Vec<f32>,
    h2: Vec<f32>,
    rstd2: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    y: Vec<f32>,
}

pub(crate) struct FinalCache {
    x_f: Vec<f32>,
    hf: Vec<f32>,
    rstd_f: Vec<f32>,
    probs: Vec<f32>, // softmax over vocab, [T, V]
    n_valid: usize,
}

/// Reject out-of-range tokens/targets before any compute.
fn validate_batch(state: &CpuState, bv: &BatchView) -> Result<()> {
    let v = state.dims.vocab;
    for (i, &tok) in bv.tokens.iter().enumerate() {
        if tok < 0 || tok as usize >= v {
            bail!("token id {tok} at position {i} out of vocab range 0..{v}");
        }
    }
    for (i, &tgt) in bv.targets.iter().enumerate() {
        if tgt >= v as i32 {
            bail!("target id {tgt} at position {i} out of vocab range");
        }
    }
    Ok(())
}

/// Token-embedding gather: the depth-0 activation.
fn embed_fwd(state: &CpuState, p: &ParamIdx, bv: &BatchView) -> Result<Vec<f32>> {
    let d = state.dims.d_model;
    let t = bv.t();
    let embed = p.get("embed")?;
    let mut x = vec![0.0f32; t * d];
    for ti in 0..t {
        let tok = bv.tokens[ti] as usize;
        x[ti * d..(ti + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
    Ok(x)
}

/// One transformer layer forward. Consumes `x_in`, returns `x_out` and —
/// when `want_cache` — the layer's backward cache. The op sequence is
/// identical either way, so cache-free (checkpointed) and cached forwards
/// produce bitwise-equal activations.
pub(crate) fn layer_fwd(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    l: usize,
    x_in: Vec<f32>,
    want_cache: bool,
) -> Result<(Vec<f32>, Option<LayerCache>)> {
    let dims = &state.dims;
    let (d, f) = (dims.d_model, dims.d_ff);
    let (hq, hkv, hd) = (dims.n_heads, dims.n_kv_heads, dims.head_dim());
    let dkv = dims.d_kv();
    let t = bv.t();
    let pre = format!("layer_{l:02}.");

    let mut h1 = vec![0.0f32; t * d];
    let mut rstd1 = vec![0.0f32; t];
    rmsnorm_fwd(&x_in, p.get(&format!("{pre}norm1"))?, t, d, &mut h1, &mut rstd1);

    let mut q = vec![0.0f32; t * d];
    linear_fwd(&h1, p.get(&format!("{pre}wq"))?, t, d, d, &mut q);
    let mut k = vec![0.0f32; t * dkv];
    linear_fwd(&h1, p.get(&format!("{pre}wk"))?, t, d, dkv, &mut k);
    let mut vv = vec![0.0f32; t * dkv];
    linear_fwd(&h1, p.get(&format!("{pre}wv"))?, t, d, dkv, &mut vv);

    let (mut hq_a, mut hv_a) = (None, None);
    if let Some(lc) = &state.lora {
        let r = lc.rank;
        let s = lc.scale();
        let mut ha = vec![0.0f32; t * r];
        linear_fwd(&h1, p.get(&format!("{pre}wq_a"))?, t, d, r, &mut ha);
        let mut dq = vec![0.0f32; t * d];
        linear_fwd(&ha, p.get(&format!("{pre}wq_b"))?, t, r, d, &mut dq);
        for i in 0..t * d {
            q[i] += s * dq[i];
        }
        hq_a = Some(ha);

        let mut ha = vec![0.0f32; t * r];
        linear_fwd(&h1, p.get(&format!("{pre}wv_a"))?, t, d, r, &mut ha);
        let mut dv = vec![0.0f32; t * dkv];
        linear_fwd(&ha, p.get(&format!("{pre}wv_b"))?, t, r, dkv, &mut dv);
        for i in 0..t * dkv {
            vv[i] += s * dv[i];
        }
        hv_a = Some(ha);
    }

    rope_apply(&mut q, bv.pos, t, hq, hd, 1.0);
    rope_apply(&mut k, bv.pos, t, hkv, hd, 1.0);

    let mut att = vec![0.0f32; t * d];
    let mut probs = vec![0.0f32; bv.bsz * hq * bv.seq * bv.seq];
    attention_fwd(&q, &k, &vv, bv, hq, hkv, hd, &mut att, &mut probs);

    let mut ao = vec![0.0f32; t * d];
    linear_fwd(&att, p.get(&format!("{pre}wo"))?, t, d, d, &mut ao);
    let mut x_mid = x_in.clone();
    for i in 0..t * d {
        x_mid[i] += ao[i];
    }

    let mut h2 = vec![0.0f32; t * d];
    let mut rstd2 = vec![0.0f32; t];
    rmsnorm_fwd(&x_mid, p.get(&format!("{pre}norm2"))?, t, d, &mut h2, &mut rstd2);
    let mut gate = vec![0.0f32; t * f];
    linear_fwd(&h2, p.get(&format!("{pre}w_gate"))?, t, d, f, &mut gate);
    let mut up = vec![0.0f32; t * f];
    linear_fwd(&h2, p.get(&format!("{pre}w_up"))?, t, d, f, &mut up);
    let mut y = vec![0.0f32; t * f];
    swiglu_fwd(&gate, &up, &mut y);
    let mut mlp = vec![0.0f32; t * d];
    linear_fwd(&y, p.get(&format!("{pre}w_down"))?, t, f, d, &mut mlp);

    let mut x_out = x_mid.clone();
    for i in 0..t * d {
        x_out[i] += mlp[i];
    }

    let cache = want_cache.then_some(LayerCache {
        x_in,
        h1,
        rstd1,
        q,
        k,
        v: vv,
        hq_a,
        hv_a,
        probs,
        att,
        x_mid,
        h2,
        rstd2,
        gate,
        up,
        y,
    });
    Ok((x_out, cache))
}

/// Final norm + head + masked cross-entropy. Consumes `x_f`.
pub(crate) fn head_fwd(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    x_f: Vec<f32>,
    want_cache: bool,
) -> Result<(f32, usize, Option<FinalCache>)> {
    let (d, v) = (state.dims.d_model, state.dims.vocab);
    let t = bv.t();
    let mut hf = vec![0.0f32; t * d];
    let mut rstd_f = vec![0.0f32; t];
    rmsnorm_fwd(&x_f, p.get("norm_f")?, t, d, &mut hf, &mut rstd_f);
    let mut logits = vec![0.0f32; t * v];
    linear_fwd(&hf, p.get("w_head")?, t, d, v, &mut logits);
    let mut probs = vec![0.0f32; t * v];
    let (loss_sum, n_valid) = softmax_xent(&logits, bv.targets, t, v, &mut probs);
    let fc = want_cache.then_some(FinalCache { x_f, hf, rstd_f, probs, n_valid });
    Ok((loss_sum, n_valid, fc))
}

/// Forward pass; fills `caches` when provided (training) and returns the
/// summed loss + valid-target count. Crate-visible so the fast backend's
/// unit tests can compare per-parameter gradients against this oracle.
pub(crate) fn forward(
    state: &CpuState,
    bv: &BatchView,
    caches: Option<(&mut Vec<LayerCache>, &mut Option<FinalCache>)>,
) -> Result<(f32, usize)> {
    let p = ParamIdx::for_state(state);
    validate_batch(state, bv)?;
    let mut x = embed_fwd(state, &p, bv)?;
    let mut caches = caches;
    for l in 0..state.dims.n_layers {
        let (x_out, cache) = layer_fwd(state, &p, bv, l, x, caches.is_some())?;
        if let Some((lcs, _)) = caches.as_mut() {
            lcs.push(cache.expect("cache requested"));
        }
        x = x_out;
    }
    let want = caches.is_some();
    let (loss_sum, n_valid, fc) = head_fwd(state, &p, bv, x, want)?;
    if let Some((_, slot)) = caches.as_mut() {
        **slot = fc;
    }
    Ok((loss_sum, n_valid))
}

/// Segment-masked causal attention forward (paper Def. 1/2 with the packing
/// mask of Alg. 17): tokens attend causally within their own non-zero
/// segment; padding rows (seg 0) emit zeros. Crate-visible: the fast
/// backend's kernel microbench times this as the naive attention baseline.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bv: &BatchView,
    n_heads: usize,
    n_kv: usize,
    hd: usize,
    out: &mut [f32],
    probs: &mut [f32],
) {
    let s = bv.seq;
    let group = n_heads / n_kv;
    let dq = n_heads * hd;
    let dkv = n_kv * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores = vec![0.0f32; s];
    for b in 0..bv.bsz {
        for h in 0..n_heads {
            let kh = h / group;
            for i in 0..s {
                let ti = b * s + i;
                let seg_i = bv.seg[ti];
                if seg_i == 0 {
                    continue; // padding: probs row stays zero, out stays zero
                }
                let qr = &q[ti * dq + h * hd..ti * dq + (h + 1) * hd];
                let mut m = f32::NEG_INFINITY;
                for j in 0..=i {
                    let tj = b * s + j;
                    if bv.seg[tj] != seg_i {
                        continue;
                    }
                    let kr = &k[tj * dkv + kh * hd..tj * dkv + (kh + 1) * hd];
                    let mut dot = 0.0f32;
                    for x in 0..hd {
                        dot += qr[x] * kr[x];
                    }
                    scores[j] = dot * scale;
                    m = m.max(scores[j]);
                }
                let mut denom = 0.0f32;
                let prow = &mut probs[((b * n_heads + h) * s + i) * s..((b * n_heads + h) * s + i + 1) * s];
                for j in 0..=i {
                    let tj = b * s + j;
                    if bv.seg[tj] != seg_i {
                        continue;
                    }
                    let e = (scores[j] - m).exp();
                    prow[j] = e;
                    denom += e;
                }
                let or = &mut out[ti * dq + h * hd..ti * dq + (h + 1) * hd];
                for j in 0..=i {
                    let tj = b * s + j;
                    if bv.seg[tj] != seg_i {
                        continue;
                    }
                    prow[j] /= denom;
                    let vr = &v[tj * dkv + kh * hd..tj * dkv + (kh + 1) * hd];
                    for x in 0..hd {
                        or[x] += prow[j] * vr[x];
                    }
                }
            }
        }
    }
}

/// Attention backward: accumulates `dq`, `dk`, `dv` from `dout` and the
/// cached attention weights. GQA gradients sum over each KV head's group.
/// Crate-visible as the oracle for the fast backend's recompute backward.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_bwd(
    dout: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    bv: &BatchView,
    n_heads: usize,
    n_kv: usize,
    hd: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    let s = bv.seq;
    let group = n_heads / n_kv;
    let dq_w = n_heads * hd;
    let dkv_w = n_kv * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dp = vec![0.0f32; s];
    for b in 0..bv.bsz {
        for h in 0..n_heads {
            let kh = h / group;
            for i in 0..s {
                let ti = b * s + i;
                if bv.seg[ti] == 0 {
                    continue;
                }
                let prow = &probs[((b * n_heads + h) * s + i) * s..((b * n_heads + h) * s + i + 1) * s];
                let dor = &dout[ti * dq_w + h * hd..ti * dq_w + (h + 1) * hd];
                // dv_j += p_ij · dout_i ; dp_ij = dout_i · v_j
                let mut dsum = 0.0f32;
                for j in 0..=i {
                    if prow[j] == 0.0 {
                        dp[j] = 0.0;
                        continue;
                    }
                    let tj = b * s + j;
                    let vr = &v[tj * dkv_w + kh * hd..tj * dkv_w + (kh + 1) * hd];
                    let dvr = &mut dv[tj * dkv_w + kh * hd..tj * dkv_w + (kh + 1) * hd];
                    let mut acc = 0.0f32;
                    for x in 0..hd {
                        dvr[x] += prow[j] * dor[x];
                        acc += dor[x] * vr[x];
                    }
                    dp[j] = acc;
                    dsum += prow[j] * acc;
                }
                // ds_ij = p_ij (dp_ij − Σ_k p_ik dp_ik); chain into q and k
                let qr = &q[ti * dq_w + h * hd..ti * dq_w + (h + 1) * hd];
                let dqr = &mut dq[ti * dq_w + h * hd..ti * dq_w + (h + 1) * hd];
                for j in 0..=i {
                    if prow[j] == 0.0 {
                        continue;
                    }
                    let ds = prow[j] * (dp[j] - dsum) * scale;
                    let tj = b * s + j;
                    let kr = &k[tj * dkv_w + kh * hd..tj * dkv_w + (kh + 1) * hd];
                    let dkr = &mut dk[tj * dkv_w + kh * hd..tj * dkv_w + (kh + 1) * hd];
                    for x in 0..hd {
                        dqr[x] += ds * kr[x];
                        dkr[x] += ds * qr[x];
                    }
                }
            }
        }
    }
}

/// Full backward pass. Returns per-parameter gradients aligned with
/// `state.params` (frozen entries included; callers use the trainable
/// prefix). Crate-visible as the gradient oracle for fast-backend tests.
/// Loss → final-norm gradient: produces `dx` at the last layer's output
/// and accumulates the head/norm_f weight gradients.
pub(crate) fn head_bwd(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    fc: &FinalCache,
    grads: &mut [Vec<f32>],
) -> Result<Vec<f32>> {
    let (d, v) = (state.dims.d_model, state.dims.vocab);
    let t = bv.t();
    let nt = state.n_trainable;
    let n_valid = fc.n_valid.max(1) as f32;

    // d(mean loss)/d logits = (softmax − onehot)/n_valid on valid rows
    let mut dlogits = vec![0.0f32; t * v];
    for ti in 0..t {
        let tgt = bv.targets[ti];
        if tgt < 0 {
            continue;
        }
        let pr = &fc.probs[ti * v..(ti + 1) * v];
        let dr = &mut dlogits[ti * v..(ti + 1) * v];
        for i in 0..v {
            dr[i] = pr[i] / n_valid;
        }
        dr[tgt as usize] -= 1.0 / n_valid;
    }

    let i_head = p.id("w_head")?;
    if i_head < nt {
        linear_bwd_w(&dlogits, &fc.hf, t, d, v, &mut grads[i_head]);
    }
    let mut dhf = vec![0.0f32; t * d];
    linear_bwd_x(&dlogits, p.get("w_head")?, t, d, v, &mut dhf);

    let mut dx = vec![0.0f32; t * d];
    let i_nf = p.id("norm_f")?;
    rmsnorm_bwd(&fc.x_f, p.get("norm_f")?, &fc.rstd_f, &dhf, t, d, &mut dx, &mut grads[i_nf]);
    Ok(dx)
}

/// One transformer layer backward: consumes `dx` at the layer's output,
/// returns `dx` at its input, accumulating trainable weight gradients.
/// Frozen parameters (indices >= n_trainable, i.e. the LoRA base) never
/// feed grad_norm or AdamW, so their weight-gradient accumulation is
/// skipped outright — the dx chain through them is still computed.
pub(crate) fn layer_bwd(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    l: usize,
    c: &LayerCache,
    dx: Vec<f32>,
    grads: &mut [Vec<f32>],
) -> Result<Vec<f32>> {
    let dims = &state.dims;
    let (d, f) = (dims.d_model, dims.d_ff);
    let (hq, hkv, hd) = (dims.n_heads, dims.n_kv_heads, dims.head_dim());
    let dkv = dims.d_kv();
    let t = bv.t();
    let nt = state.n_trainable;
    let pre = format!("layer_{l:02}.");

    {
        // x_out = x_mid + y @ w_down.T
        let i_down = p.id(&format!("{pre}w_down"))?;
        if i_down < nt {
            linear_bwd_w(&dx, &c.y, t, f, d, &mut grads[i_down]);
        }
        let mut dy = vec![0.0f32; t * f];
        linear_bwd_x(&dx, p.get(&format!("{pre}w_down"))?, t, f, d, &mut dy);

        let mut dgate = vec![0.0f32; t * f];
        let mut dup = vec![0.0f32; t * f];
        swiglu_bwd(&c.gate, &c.up, &dy, &mut dgate, &mut dup);

        let i_gate = p.id(&format!("{pre}w_gate"))?;
        let i_up = p.id(&format!("{pre}w_up"))?;
        if i_gate < nt {
            linear_bwd_w(&dgate, &c.h2, t, d, f, &mut grads[i_gate]);
        }
        if i_up < nt {
            linear_bwd_w(&dup, &c.h2, t, d, f, &mut grads[i_up]);
        }
        let mut dh2 = vec![0.0f32; t * d];
        linear_bwd_x(&dgate, p.get(&format!("{pre}w_gate"))?, t, d, f, &mut dh2);
        linear_bwd_x(&dup, p.get(&format!("{pre}w_up"))?, t, d, f, &mut dh2);

        let i_n2 = p.id(&format!("{pre}norm2"))?;
        let mut dx_mid = dx; // residual: gradient flows straight through...
        rmsnorm_bwd(
            &c.x_mid,
            p.get(&format!("{pre}norm2"))?,
            &c.rstd2,
            &dh2,
            t,
            d,
            &mut dx_mid, // ...and accumulates the norm branch
            &mut grads[i_n2],
        );

        // x_mid = x_in + att @ wo.T
        let i_wo = p.id(&format!("{pre}wo"))?;
        if i_wo < nt {
            linear_bwd_w(&dx_mid, &c.att, t, d, d, &mut grads[i_wo]);
        }
        let mut datt = vec![0.0f32; t * d];
        linear_bwd_x(&dx_mid, p.get(&format!("{pre}wo"))?, t, d, d, &mut datt);

        let mut dq = vec![0.0f32; t * d];
        let mut dk = vec![0.0f32; t * dkv];
        let mut dv = vec![0.0f32; t * dkv];
        attention_bwd(&datt, &c.q, &c.k, &c.v, &c.probs, bv, hq, hkv, hd, &mut dq, &mut dk, &mut dv);
        rope_apply(&mut dq, bv.pos, t, hq, hd, -1.0);
        rope_apply(&mut dk, bv.pos, t, hkv, hd, -1.0);

        let i_wq = p.id(&format!("{pre}wq"))?;
        let i_wk = p.id(&format!("{pre}wk"))?;
        let i_wv = p.id(&format!("{pre}wv"))?;
        if i_wq < nt {
            linear_bwd_w(&dq, &c.h1, t, d, d, &mut grads[i_wq]);
        }
        if i_wk < nt {
            linear_bwd_w(&dk, &c.h1, t, d, dkv, &mut grads[i_wk]);
        }
        if i_wv < nt {
            linear_bwd_w(&dv, &c.h1, t, d, dkv, &mut grads[i_wv]);
        }
        let mut dh1 = vec![0.0f32; t * d];
        linear_bwd_x(&dq, p.get(&format!("{pre}wq"))?, t, d, d, &mut dh1);
        linear_bwd_x(&dk, p.get(&format!("{pre}wk"))?, t, d, dkv, &mut dh1);
        linear_bwd_x(&dv, p.get(&format!("{pre}wv"))?, t, d, dkv, &mut dh1);

        if let Some(lc) = &state.lora {
            let (r, s) = (lc.rank, lc.scale());
            let hq_a = c.hq_a.as_ref().expect("lora cache");
            let hv_a = c.hv_a.as_ref().expect("lora cache");
            // q += s · (h1 @ A.T) @ B.T
            let mut dq_s = dq.clone();
            for g in dq_s.iter_mut() {
                *g *= s;
            }
            let i_qb = p.id(&format!("{pre}wq_b"))?;
            let i_qa = p.id(&format!("{pre}wq_a"))?;
            linear_bwd_w(&dq_s, hq_a, t, r, d, &mut grads[i_qb]);
            let mut dhq_a = vec![0.0f32; t * r];
            linear_bwd_x(&dq_s, p.get(&format!("{pre}wq_b"))?, t, r, d, &mut dhq_a);
            linear_bwd_w(&dhq_a, &c.h1, t, d, r, &mut grads[i_qa]);
            linear_bwd_x(&dhq_a, p.get(&format!("{pre}wq_a"))?, t, d, r, &mut dh1);

            let mut dv_s = dv.clone();
            for g in dv_s.iter_mut() {
                *g *= s;
            }
            let i_vb = p.id(&format!("{pre}wv_b"))?;
            let i_va = p.id(&format!("{pre}wv_a"))?;
            linear_bwd_w(&dv_s, hv_a, t, r, dkv, &mut grads[i_vb]);
            let mut dhv_a = vec![0.0f32; t * r];
            linear_bwd_x(&dv_s, p.get(&format!("{pre}wv_b"))?, t, r, dkv, &mut dhv_a);
            linear_bwd_w(&dhv_a, &c.h1, t, d, r, &mut grads[i_va]);
            linear_bwd_x(&dhv_a, p.get(&format!("{pre}wv_a"))?, t, d, r, &mut dh1);
        }

        let i_n1 = p.id(&format!("{pre}norm1"))?;
        let mut dx_in = dx_mid; // residual passthrough
        rmsnorm_bwd(
            &c.x_in,
            p.get(&format!("{pre}norm1"))?,
            &c.rstd1,
            &dh1,
            t,
            d,
            &mut dx_in,
            &mut grads[i_n1],
        );
        Ok(dx_in)
    }
}

/// Scatter-add the depth-0 gradient into the embedding rows (trainable
/// full-FT path only — the embed is frozen under LoRA).
pub(crate) fn embed_bwd(
    state: &CpuState,
    p: &ParamIdx,
    bv: &BatchView,
    dx: &[f32],
    grads: &mut [Vec<f32>],
) -> Result<()> {
    let d = state.dims.d_model;
    let t = bv.t();
    let i_embed = p.id("embed")?;
    if i_embed < state.n_trainable {
        for ti in 0..t {
            let tok = bv.tokens[ti] as usize;
            let ge = &mut grads[i_embed][tok * d..(tok + 1) * d];
            for i in 0..d {
                ge[i] += dx[ti * d + i];
            }
        }
    }
    Ok(())
}

pub(crate) fn backward(
    state: &CpuState,
    bv: &BatchView,
    layer_caches: &[LayerCache],
    fc: &FinalCache,
) -> Result<Vec<Vec<f32>>> {
    let p = ParamIdx::for_state(state);
    let mut grads: Vec<Vec<f32>> = state.params.iter().map(|t| vec![0.0; t.elements()]).collect();
    let mut dx = head_bwd(state, &p, bv, fc, &mut grads)?;
    for l in (0..state.dims.n_layers).rev() {
        dx = layer_bwd(state, &p, bv, l, &layer_caches[l], dx, &mut grads)?;
    }
    embed_bwd(state, &p, bv, &dx, &mut grads)?;
    Ok(grads)
}

/// Segment boundaries for `--ckpt-segments N` over `n_layers`: the first
/// `n_layers % segs` segments get one extra layer.
pub(crate) fn ckpt_segment_starts(n_layers: usize, segs: usize) -> Vec<usize> {
    let segs = segs.clamp(1, n_layers.max(1));
    let base = n_layers / segs;
    let rem = n_layers % segs;
    let mut starts = Vec::with_capacity(segs);
    let mut at = 0usize;
    for s in 0..segs {
        starts.push(at);
        at += base + usize::from(s < rem);
    }
    starts
}

/// Segment-checkpointed forward + backward (memory tier 3): the forward
/// runs cache-free, cloning only the boundary activation at each segment
/// start; the backward recomputes one segment's caches at a time, so at
/// most one segment's worth of `LayerCache`s is ever live. The recompute
/// replays the exact op sequence of `layer_fwd`, so loss and gradients are
/// bitwise equal to the cache-everything path — only peak activation
/// memory changes.
fn grads_checkpointed(
    state: &CpuState,
    bv: &BatchView,
    segs: usize,
) -> Result<(f32, usize, Vec<Vec<f32>>, f64, f64)> {
    let nl = state.dims.n_layers;
    let starts = ckpt_segment_starts(nl, segs);
    let p = ParamIdx::for_state(state);
    validate_batch(state, bv)?;

    let t_fwd = Instant::now();
    let mut x = embed_fwd(state, &p, bv)?;
    let mut boundaries: Vec<Vec<f32>> = Vec::with_capacity(starts.len());
    for l in 0..nl {
        if starts.contains(&l) {
            boundaries.push(x.clone());
        }
        let (x_out, _) = layer_fwd(state, &p, bv, l, x, false)?;
        x = x_out;
    }
    let (loss_sum, n_valid, fc) = head_fwd(state, &p, bv, x, true)?;
    let fwd_s = t_fwd.elapsed().as_secs_f64();
    let fc = fc.expect("head cache requested");

    let t_bwd = Instant::now();
    let mut grads: Vec<Vec<f32>> = state.params.iter().map(|t| vec![0.0; t.elements()]).collect();
    let mut dx = head_bwd(state, &p, bv, &fc, &mut grads)?;
    for s in (0..starts.len()).rev() {
        let lo = starts[s];
        let hi = if s + 1 < starts.len() { starts[s + 1] } else { nl };
        // recompute this segment's caches from its boundary activation
        let mut xx = boundaries.pop().expect("segment boundary");
        let mut caches: Vec<LayerCache> = Vec::with_capacity(hi - lo);
        for l in lo..hi {
            let (x_out, cache) = layer_fwd(state, &p, bv, l, xx, true)?;
            caches.push(cache.expect("cache requested"));
            xx = x_out;
        }
        for l in (lo..hi).rev() {
            dx = layer_bwd(state, &p, bv, l, &caches[l - lo], dx, &mut grads)?;
        }
    }
    embed_bwd(state, &p, bv, &dx, &mut grads)?;
    let bwd_s = t_bwd.elapsed().as_secs_f64();
    Ok((loss_sum, n_valid, grads, fwd_s, bwd_s))
}

/// Metrics returned by one reference train step.
#[derive(Debug, Clone, Copy)]
pub struct StepOut {
    /// Mean loss over valid targets.
    pub loss: f32,
    /// Global L2 norm over the *trainable* gradients (the §8 verification
    /// signal; exactly 0.0 in broken mode — the Unsloth-bug signature).
    pub grad_norm: f32,
    /// Number of supervised (non-masked) targets in the batch.
    pub n_tokens: f32,
    /// Per-phase step-time breakdown (fwd/bwd/optim seconds).
    pub phases: StepPhases,
}

/// Forward-only mean loss (the eval path — identical math to the train-step
/// forward, so eval-vs-train-loss equivalence holds exactly).
pub fn eval_loss(state: &CpuState, bv: &BatchView) -> Result<f32> {
    let (loss_sum, n_valid) = forward(state, bv, None)?;
    Ok(loss_sum / n_valid.max(1) as f32)
}

/// One full train step: forward, backward, grad-norm, AdamW with the LoRA+
/// dual LR (`lr_b` for `*_b` params). `broken` reproduces the paper's §8
/// failure mode: the loss is computed but every gradient is discarded, so
/// grad_norm is exactly 0.0 and the parameters never move.
pub fn train_step(
    state: &mut CpuState,
    bv: &BatchView,
    broken: bool,
    step: u64,
    lr: f32,
    lr_b: f32,
) -> Result<StepOut> {
    let (loss_sum, n_valid, grads, fwd_s, bwd_s) = if broken {
        // broken mode never needs gradients: plain forward, loss only
        let t_fwd = Instant::now();
        let (loss_sum, n_valid) = forward(state, bv, None)?;
        let fwd_s = t_fwd.elapsed().as_secs_f64();
        let loss = loss_sum / n_valid.max(1) as f32;
        let phases = StepPhases { fwd_s, ..StepPhases::default() };
        return Ok(StepOut { loss, grad_norm: 0.0, n_tokens: n_valid as f32, phases });
    } else if state.ckpt_segments > 0 {
        grads_checkpointed(state, bv, state.ckpt_segments)?
    } else {
        let mut layer_caches: Vec<LayerCache> = Vec::with_capacity(state.dims.n_layers);
        let mut final_cache: Option<FinalCache> = None;
        let t_fwd = Instant::now();
        let (loss_sum, n_valid) =
            forward(state, bv, Some((&mut layer_caches, &mut final_cache)))?;
        let fwd_s = t_fwd.elapsed().as_secs_f64();
        let fc = final_cache.ok_or_else(|| anyhow!("forward did not fill caches"))?;
        let t_bwd = Instant::now();
        let grads = backward(state, bv, &layer_caches, &fc)?;
        let bwd_s = t_bwd.elapsed().as_secs_f64();
        (loss_sum, n_valid, grads, fwd_s, bwd_s)
    };
    let loss = loss_sum / n_valid.max(1) as f32;

    let t_optim = Instant::now();
    let mut sq = 0.0f32;
    for g in &grads[..state.n_trainable] {
        for &x in g {
            sq += x * x;
        }
    }
    let grad_norm = sq.sqrt();

    apply_adamw(state, |i| &grads[i], step, lr, lr_b)?;
    let optim_s = t_optim.elapsed().as_secs_f64();
    let phases = StepPhases { fwd_s, bwd_s, optim_s };
    Ok(StepOut { loss, grad_norm, n_tokens: n_valid as f32, phases })
}

/// One AdamW pass over the trainable prefix, dispatching on the
/// optimizer-state codec. `grad_of(i)` yields the gradient slice for
/// trainable parameter `i`. The int8 path decodes the moment slots into
/// two scratch buffers (allocated once per call, sized to the largest
/// trainable tensor), runs the identical fp32 recurrence, and re-encodes —
/// strictly sequential, so it is bitwise invariant across thread/worker
/// counts by construction.
fn apply_adamw<'g>(
    state: &mut CpuState,
    grad_of: impl Fn(usize) -> &'g [f32],
    step: u64,
    lr: f32,
    lr_b: f32,
) -> Result<()> {
    let nt = state.n_trainable;
    match state.optim {
        OptimStates::Fp32 => {
            for i in 0..nt {
                let lr_p = match classify_param(&state.names[i]) {
                    ParamGroup::LoraB => lr_b,
                    _ => lr,
                };
                let param = state.params[i].as_f32_mut()?;
                adamw_update(
                    param,
                    grad_of(i),
                    &mut state.slot_m[i],
                    &mut state.slot_v[i],
                    lr_p,
                    step as f32,
                    WEIGHT_DECAY,
                );
            }
        }
        OptimStates::Int8 => {
            let maxn = state.params[..nt].iter().map(|t| t.elements()).max().unwrap_or(0);
            let mut m_buf = vec![0.0f32; maxn];
            let mut v_buf = vec![0.0f32; maxn];
            for i in 0..nt {
                let lr_p = match classify_param(&state.names[i]) {
                    ParamGroup::LoraB => lr_b,
                    _ => lr,
                };
                let param = state.params[i].as_f32_mut()?;
                adamw_update_int8(
                    param,
                    grad_of(i),
                    &mut state.qslot_m[i],
                    &mut state.qslot_v[i],
                    lr_p,
                    step as f32,
                    WEIGHT_DECAY,
                    &mut m_buf,
                    &mut v_buf,
                );
            }
        }
    }
    Ok(())
}

/// Total element count of the trainable-gradient vector — the lane length
/// of the data-parallel gradient arena (DESIGN.md §10).
pub fn flat_grad_len(state: &CpuState) -> usize {
    state.params[..state.n_trainable].iter().map(|t| t.elements()).sum()
}

/// Data-parallel shard gradient (DESIGN.md §10): forward + backward on a
/// single-row view, with the cross-entropy normalizer forced to
/// `global_n_valid` — the *whole batch's* supervised-target count — so the
/// per-row gradients sum to exactly the full-batch mean-loss gradient.
/// Flattens the trainable gradients into `out` (state order) and returns
/// `(row loss sum, forward seconds, backward seconds)`. Never touches
/// optimizer state.
pub fn grad_row_into(
    state: &CpuState,
    bv: &BatchView,
    global_n_valid: usize,
    out: &mut [f32],
) -> Result<(f32, f64, f64)> {
    let mut layer_caches: Vec<LayerCache> = Vec::with_capacity(state.dims.n_layers);
    let mut final_cache: Option<FinalCache> = None;
    let t_fwd = Instant::now();
    let (loss_sum, _row_valid) = forward(state, bv, Some((&mut layer_caches, &mut final_cache)))?;
    let fwd_s = t_fwd.elapsed().as_secs_f64();
    let mut fc = final_cache.ok_or_else(|| anyhow!("forward did not fill caches"))?;
    // backward reads its loss normalizer from the cache; seeding it with
    // the global count is what makes shard gradients tree-reduce to the
    // full-batch gradient
    fc.n_valid = global_n_valid.max(1);
    let t_bwd = Instant::now();
    let grads = backward(state, bv, &layer_caches, &fc)?;
    let bwd_s = t_bwd.elapsed().as_secs_f64();
    let mut off = 0usize;
    for g in &grads[..state.n_trainable] {
        ensure!(off + g.len() <= out.len(), "gradient lane overflow at offset {off}");
        out[off..off + g.len()].copy_from_slice(g);
        off += g.len();
    }
    ensure!(off == out.len(), "gradient lane length mismatch: wrote {off}, lane {}", out.len());
    Ok((loss_sum, fwd_s, bwd_s))
}

/// Apply one AdamW step from a flat reduced gradient (trainable prefix,
/// state order) — the "step once" half of the data-parallel contract.
/// Bitwise-identical to the per-parameter update loop in [`train_step`].
pub fn apply_flat_grads(
    state: &mut CpuState,
    flat: &[f32],
    step: u64,
    lr: f32,
    lr_b: f32,
) -> Result<()> {
    let mut offs = Vec::with_capacity(state.n_trainable + 1);
    let mut off = 0usize;
    for t in &state.params[..state.n_trainable] {
        offs.push(off);
        off += t.elements();
    }
    offs.push(off);
    ensure!(off == flat.len(), "flat gradient length {} != trainable elements {off}", flat.len());
    apply_adamw(state, |i| &flat[offs[i]..offs[i + 1]], step, lr, lr_b)
}

/// Per-tenant adapter state for the serve subsystem (DESIGN.md §11): the
/// trainable prefix of a LoRA [`CpuState`] — the rank-r A/B tensors and
/// their AdamW moment slots — detached from the shared frozen base
/// weights, so many tenants can train against one resident base.
#[derive(Debug, Clone)]
pub struct CpuAdapter {
    pub dims: ModelDims,
    pub lora: LoraCfg,
    /// Trainable tensor names, state order (the LoRA prefix of the layout).
    pub names: Vec<String>,
    pub params: Vec<HostTensor>,
    pub slot_m: Vec<Vec<f32>>,
    pub slot_v: Vec<Vec<f32>>,
    /// Optimizer-state codec for this tenant (must match the workspace's
    /// at swap time — enforced by [`swap_adapter`]).
    pub optim: OptimStates,
    pub qslot_m: Vec<Int8Slot>,
    pub qslot_v: Vec<Int8Slot>,
}

/// Initialize a fresh per-tenant adapter. Draw-order contract: the LoRA
/// adapters are the *first* tensors in the state layout and the only
/// pre-base tensors that consume RNG draws (`*_b` starts at zero, paper
/// §5), so `init_adapter(dims, lora, seed)` is bitwise identical to the
/// trainable prefix of `init_state(dims, Some(lora), seed)` — pinned by
/// the `init_adapter_matches_init_state_prefix` test below.
pub fn init_adapter(dims: ModelDims, lora: LoraCfg, seed: i32) -> CpuAdapter {
    let (layout, n_trainable) = param_layout(&dims, Some(&lora));
    let mut rng = Rng::new(seed as u32 as u64);
    let mut names = Vec::with_capacity(n_trainable);
    let mut params = Vec::with_capacity(n_trainable);
    for (name, shape) in layout.into_iter().take(n_trainable) {
        let n: usize = shape.iter().product();
        let short = name.rsplit('.').next().unwrap_or(&name);
        let data: Vec<f32> = if short.ends_with("_b") {
            vec![0.0; n]
        } else {
            (0..n).map(|_| rng.normal() as f32 * 0.1).collect()
        };
        names.push(name);
        params.push(HostTensor::f32(data, shape));
    }
    let slot_m: Vec<Vec<f32>> = params.iter().map(|t| vec![0.0; t.elements()]).collect();
    let slot_v = slot_m.clone();
    CpuAdapter {
        dims,
        lora,
        names,
        params,
        slot_m,
        slot_v,
        optim: OptimStates::Fp32,
        qslot_m: Vec::new(),
        qslot_v: Vec::new(),
    }
}

/// Switch a tenant adapter's optimizer-state codec. Like
/// [`set_optim_states`], only legal before any step has touched the
/// moments — converting populated slots would silently change the
/// training trajectory.
pub fn set_adapter_optim(ad: &mut CpuAdapter, codec: OptimStates) -> Result<()> {
    if ad.optim == codec {
        return Ok(());
    }
    let fp32_zero = ad.slot_m.iter().chain(&ad.slot_v).all(|s| s.iter().all(|&x| x == 0.0));
    let int8_zero = ad
        .qslot_m
        .iter()
        .chain(&ad.qslot_v)
        .all(|s| s.q.data.iter().all(|&b| b == 0) && s.comp.iter().all(|&c| c == 0.0));
    ensure!(
        fp32_zero && int8_zero,
        "cannot change the adapter optimizer-state codec from {} to {} after training \
         started: the AdamW moments are non-zero and converting them is not supported",
        ad.optim.name(),
        codec.name()
    );
    match codec {
        OptimStates::Int8 => {
            ad.qslot_m = ad.params.iter().map(|t| Int8Slot::zeros(t.elements())).collect();
            ad.qslot_v = ad.qslot_m.clone();
            for s in ad.slot_m.iter_mut().chain(ad.slot_v.iter_mut()) {
                *s = Vec::new();
            }
        }
        OptimStates::Fp32 => {
            ad.qslot_m = Vec::new();
            ad.qslot_v = Vec::new();
            for (s, t) in
                ad.slot_m.iter_mut().chain(ad.slot_v.iter_mut()).zip(ad.params.iter().cycle())
            {
                *s = vec![0.0; t.elements()];
            }
        }
    }
    ad.optim = codec;
    Ok(())
}

/// O(1) swap of a tenant's adapter into (or out of) a shared workspace
/// state. The workspace's frozen suffix — the shared base weights — is
/// untouched; the trainable prefix tensors and their AdamW slots exchange
/// places with the adapter's, so "swap in → `train_step` × N → swap out"
/// runs exactly the math a dedicated per-tenant state would. The serve
/// subsystem's fused-vs-serial bitwise-parity contract rests on this
/// (DESIGN.md §11).
pub fn swap_adapter(state: &mut CpuState, adapter: &mut CpuAdapter) -> Result<()> {
    ensure!(
        state.dims == adapter.dims,
        "adapter/base geometry mismatch: adapter {:?} vs workspace {:?}",
        adapter.dims,
        state.dims
    );
    let sl = state
        .lora
        .ok_or_else(|| anyhow!("workspace state is not a LoRA state — nothing to swap"))?;
    ensure!(
        sl == adapter.lora,
        "LoRA config mismatch: workspace {sl:?} vs adapter {:?}",
        adapter.lora
    );
    ensure!(
        state.n_trainable == adapter.params.len(),
        "adapter tensor count {} != workspace trainable prefix {}",
        adapter.params.len(),
        state.n_trainable
    );
    ensure!(
        state.optim == adapter.optim,
        "optimizer-state codec mismatch: workspace uses {} but the adapter holds {} moment \
         slots — convert the adapter before swapping (serve does this at registration)",
        state.optim.name(),
        adapter.optim.name()
    );
    for i in 0..state.n_trainable {
        ensure!(
            state.names[i] == adapter.names[i],
            "trainable tensor {i} name mismatch: workspace '{}' vs adapter '{}'",
            state.names[i],
            adapter.names[i]
        );
        std::mem::swap(&mut state.params[i], &mut adapter.params[i]);
        std::mem::swap(&mut state.slot_m[i], &mut adapter.slot_m[i]);
        std::mem::swap(&mut state.slot_v[i], &mut adapter.slot_v[i]);
        if state.optim == OptimStates::Int8 {
            std::mem::swap(&mut state.qslot_m[i], &mut adapter.qslot_m[i]);
            std::mem::swap(&mut state.qslot_v[i], &mut adapter.qslot_v[i]);
        }
    }
    Ok(())
}

/// Validate one intra-step fused round (DESIGN.md §11) against the shared
/// workspace: a LoRA workspace, one adapter per slice, contiguous ordered
/// slices covering the concatenated batch exactly, and every adapter
/// geometry-compatible with the workspace's trainable prefix. Shared by
/// both CPU backends so their fused paths reject identical inputs.
pub fn check_fused_inputs(
    state: &CpuState,
    adapters: &[&mut CpuAdapter],
    bv: &BatchView,
    slices: &[FusedSlice],
) -> Result<()> {
    let sl_cfg = state
        .lora
        .ok_or_else(|| anyhow!("intra-step fusion requires a LoRA workspace state"))?;
    ensure!(!slices.is_empty(), "a fused round needs at least one tenant slice");
    ensure!(
        slices.len() == adapters.len(),
        "slice count {} != adapter count {}",
        slices.len(),
        adapters.len()
    );
    let mut next_row = 0usize;
    for (k, sl) in slices.iter().enumerate() {
        ensure!(sl.rows > 0, "slice {k} is empty");
        ensure!(sl.step >= 1, "slice {k} has 0-based step {} (steps are 1-based)", sl.step);
        ensure!(
            sl.row_start == next_row,
            "slice {k} starts at row {} but the previous slice ends at row {next_row} \
             (slices must be contiguous and ordered)",
            sl.row_start
        );
        next_row += sl.rows;
    }
    ensure!(
        next_row == bv.bsz,
        "slices cover {next_row} rows but the concatenated batch has {}",
        bv.bsz
    );
    for (k, ad) in adapters.iter().enumerate() {
        ensure!(
            ad.dims == state.dims,
            "adapter {k} geometry {:?} != workspace {:?}",
            ad.dims,
            state.dims
        );
        ensure!(
            ad.lora == sl_cfg,
            "adapter {k} LoRA config {:?} != workspace {sl_cfg:?}",
            ad.lora
        );
        ensure!(
            ad.params.len() == state.n_trainable,
            "adapter {k} tensor count {} != workspace trainable prefix {}",
            ad.params.len(),
            state.n_trainable
        );
        for i in 0..state.n_trainable {
            ensure!(
                ad.names[i] == state.names[i],
                "adapter {k} tensor {i} name '{}' != workspace '{}'",
                ad.names[i],
                state.names[i]
            );
        }
    }
    Ok(())
}

/// One intra-step fused round (DESIGN.md §11): a single shared base
/// forward/backward over the concatenated `[B_total, S]` batch, per-slice
/// LoRA A/B application in the matmul epilogues, per-tenant adapter
/// gradients accumulated over fixed-order row-slice reductions, then one
/// AdamW step per tenant at that tenant's own `(step, lr, lr_b)`.
///
/// Bitwise-parity argument (the separability contract, pinned by the
/// `fused_step_*` tests below): every base-path op in this model —
/// embedding copy, RMSNorm, linears, RoPE, SwiGLU, residual adds, and the
/// segment-masked attention (which iterates strictly per batch row) — is
/// per-row pure, so running it once over the concat batch produces, on
/// each tenant's rows, exactly the bits the serial per-tenant run
/// produces. The order-sensitive pieces — the loss normalizer, the
/// adapter weight-gradient reductions over tokens, the grad-norm, and the
/// optimizer — are executed per-slice with the same functions, the same
/// sub-inputs, and the same fixed accumulation order as the serial path.
/// The base weights are frozen under LoRA, so no cross-tenant gradient
/// ever accumulates: the dx chain through frozen weights is per-token
/// pure, and frozen weight gradients are never formed at all.
pub fn fused_train_step(
    state: &CpuState,
    adapters: &mut [&mut CpuAdapter],
    bv: &BatchView,
    slices: &[FusedSlice],
) -> Result<(Vec<StepOut>, StepPhases)> {
    check_fused_inputs(state, adapters, bv, slices)?;
    let dims = &state.dims;
    let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
    let (hq, hkv, hd) = (dims.n_heads, dims.n_kv_heads, dims.head_dim());
    let dkv = dims.d_kv();
    let (t, seq) = (bv.t(), bv.seq);
    let p = ParamIdx::for_state(state);
    let lc_cfg = state.lora.expect("checked above");
    let (r, scale) = (lc_cfg.rank, lc_cfg.scale());
    let nt = state.n_trainable;

    for (i, &tok) in bv.tokens.iter().enumerate() {
        if tok < 0 || tok as usize >= v {
            bail!("token id {tok} at position {i} out of vocab range 0..{v}");
        }
    }
    for (i, &tgt) in bv.targets.iter().enumerate() {
        if tgt >= v as i32 {
            bail!("target id {tgt} at position {i} out of vocab range");
        }
    }

    // ---- forward: one shared base pass, per-slice adapter epilogues ----
    let t_fwd = Instant::now();
    let embed = p.get("embed")?;
    let mut x = vec![0.0f32; t * d];
    for ti in 0..t {
        let tok = bv.tokens[ti] as usize;
        x[ti * d..(ti + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }

    let mut layer_caches: Vec<LayerCache> = Vec::with_capacity(dims.n_layers);
    for l in 0..dims.n_layers {
        let pre = format!("layer_{l:02}.");
        let x_in = x;

        let mut h1 = vec![0.0f32; t * d];
        let mut rstd1 = vec![0.0f32; t];
        rmsnorm_fwd(&x_in, p.get(&format!("{pre}norm1"))?, t, d, &mut h1, &mut rstd1);

        let mut q = vec![0.0f32; t * d];
        linear_fwd(&h1, p.get(&format!("{pre}wq"))?, t, d, d, &mut q);
        let mut k = vec![0.0f32; t * dkv];
        linear_fwd(&h1, p.get(&format!("{pre}wk"))?, t, d, dkv, &mut k);
        let mut vv = vec![0.0f32; t * dkv];
        linear_fwd(&h1, p.get(&format!("{pre}wv"))?, t, d, dkv, &mut vv);

        let i_qa = p.id(&format!("{pre}wq_a"))?;
        let i_qb = p.id(&format!("{pre}wq_b"))?;
        let i_va = p.id(&format!("{pre}wv_a"))?;
        let i_vb = p.id(&format!("{pre}wv_b"))?;
        let mut hq_a = vec![0.0f32; t * r];
        let mut hv_a = vec![0.0f32; t * r];
        for (ki, sl) in slices.iter().enumerate() {
            let lo = sl.row_start * seq;
            let hi = (sl.row_start + sl.rows) * seq;
            let ts = hi - lo;
            let ad = &adapters[ki];
            linear_fwd(&h1[lo * d..hi * d], ad.params[i_qa].as_f32()?, ts, d, r, &mut hq_a[lo * r..hi * r]);
            let mut dq = vec![0.0f32; ts * d];
            linear_fwd(&hq_a[lo * r..hi * r], ad.params[i_qb].as_f32()?, ts, r, d, &mut dq);
            for i in 0..ts * d {
                q[lo * d + i] += scale * dq[i];
            }
            linear_fwd(&h1[lo * d..hi * d], ad.params[i_va].as_f32()?, ts, d, r, &mut hv_a[lo * r..hi * r]);
            let mut dv = vec![0.0f32; ts * dkv];
            linear_fwd(&hv_a[lo * r..hi * r], ad.params[i_vb].as_f32()?, ts, r, dkv, &mut dv);
            for i in 0..ts * dkv {
                vv[lo * dkv + i] += scale * dv[i];
            }
        }

        rope_apply(&mut q, bv.pos, t, hq, hd, 1.0);
        rope_apply(&mut k, bv.pos, t, hkv, hd, 1.0);

        let mut att = vec![0.0f32; t * d];
        let mut probs = vec![0.0f32; bv.bsz * hq * seq * seq];
        attention_fwd(&q, &k, &vv, bv, hq, hkv, hd, &mut att, &mut probs);

        let mut ao = vec![0.0f32; t * d];
        linear_fwd(&att, p.get(&format!("{pre}wo"))?, t, d, d, &mut ao);
        let mut x_mid = x_in.clone();
        for i in 0..t * d {
            x_mid[i] += ao[i];
        }

        let mut h2 = vec![0.0f32; t * d];
        let mut rstd2 = vec![0.0f32; t];
        rmsnorm_fwd(&x_mid, p.get(&format!("{pre}norm2"))?, t, d, &mut h2, &mut rstd2);
        let mut gate = vec![0.0f32; t * f];
        linear_fwd(&h2, p.get(&format!("{pre}w_gate"))?, t, d, f, &mut gate);
        let mut up = vec![0.0f32; t * f];
        linear_fwd(&h2, p.get(&format!("{pre}w_up"))?, t, d, f, &mut up);
        let mut y = vec![0.0f32; t * f];
        swiglu_fwd(&gate, &up, &mut y);
        let mut mlp = vec![0.0f32; t * d];
        linear_fwd(&y, p.get(&format!("{pre}w_down"))?, t, f, d, &mut mlp);

        let mut x_out = x_mid.clone();
        for i in 0..t * d {
            x_out[i] += mlp[i];
        }

        layer_caches.push(LayerCache {
            x_in,
            h1,
            rstd1,
            q,
            k,
            v: vv,
            hq_a: Some(hq_a),
            hv_a: Some(hv_a),
            probs,
            att,
            x_mid,
            h2,
            rstd2,
            gate,
            up,
            y,
        });
        x = x_out;
    }

    let x_f = x;
    let mut hf = vec![0.0f32; t * d];
    let mut rstd_f = vec![0.0f32; t];
    rmsnorm_fwd(&x_f, p.get("norm_f")?, t, d, &mut hf, &mut rstd_f);
    let mut logits = vec![0.0f32; t * v];
    linear_fwd(&hf, p.get("w_head")?, t, d, v, &mut logits);
    // the loss reduction is the first order-sensitive op: run it per slice
    // so each tenant gets exactly its serial (loss_sum, n_valid)
    let mut probs_f = vec![0.0f32; t * v];
    let mut tenant_fwd: Vec<(f32, usize)> = Vec::with_capacity(slices.len());
    for sl in slices {
        let lo = sl.row_start * seq;
        let hi = (sl.row_start + sl.rows) * seq;
        let (loss_sum, n_valid) = softmax_xent(
            &logits[lo * v..hi * v],
            &bv.targets[lo..hi],
            hi - lo,
            v,
            &mut probs_f[lo * v..hi * v],
        );
        tenant_fwd.push((loss_sum, n_valid));
    }
    let fwd_s = t_fwd.elapsed().as_secs_f64();

    // ---- backward: one shared base pass, per-slice adapter gradients ----
    let t_bwd = Instant::now();
    let mut tenant_grads: Vec<Vec<Vec<f32>>> = (0..slices.len())
        .map(|_| state.params[..nt].iter().map(|tn| vec![0.0; tn.elements()]).collect())
        .collect();
    // frozen-parameter gradient sink: RMSNorm backward always emits a
    // dgamma, but under LoRA every norm is frozen, so it is discarded —
    // zeroed per call only to mirror the serial call's zeroed target
    let mut dg_sink = vec![0.0f32; d];

    // d(mean loss)/d logits, normalized per slice by that tenant's n_valid
    let mut dlogits = vec![0.0f32; t * v];
    for (ki, sl) in slices.iter().enumerate() {
        let lo = sl.row_start * seq;
        let hi = (sl.row_start + sl.rows) * seq;
        let nv = tenant_fwd[ki].1.max(1) as f32;
        for ti in lo..hi {
            let tgt = bv.targets[ti];
            if tgt < 0 {
                continue;
            }
            let pr = &probs_f[ti * v..(ti + 1) * v];
            let dr = &mut dlogits[ti * v..(ti + 1) * v];
            for i in 0..v {
                dr[i] = pr[i] / nv;
            }
            dr[tgt as usize] -= 1.0 / nv;
        }
    }

    // w_head is frozen under LoRA: no weight grad, dx chain only
    let mut dhf = vec![0.0f32; t * d];
    linear_bwd_x(&dlogits, p.get("w_head")?, t, d, v, &mut dhf);

    let mut dx = vec![0.0f32; t * d];
    dg_sink.iter_mut().for_each(|g| *g = 0.0);
    rmsnorm_bwd(&x_f, p.get("norm_f")?, &rstd_f, &dhf, t, d, &mut dx, &mut dg_sink);

    for l in (0..dims.n_layers).rev() {
        let pre = format!("layer_{l:02}.");
        let c = &layer_caches[l];

        let mut dy = vec![0.0f32; t * f];
        linear_bwd_x(&dx, p.get(&format!("{pre}w_down"))?, t, f, d, &mut dy);

        let mut dgate = vec![0.0f32; t * f];
        let mut dup = vec![0.0f32; t * f];
        swiglu_bwd(&c.gate, &c.up, &dy, &mut dgate, &mut dup);

        let mut dh2 = vec![0.0f32; t * d];
        linear_bwd_x(&dgate, p.get(&format!("{pre}w_gate"))?, t, d, f, &mut dh2);
        linear_bwd_x(&dup, p.get(&format!("{pre}w_up"))?, t, d, f, &mut dh2);

        let mut dx_mid = dx;
        dg_sink.iter_mut().for_each(|g| *g = 0.0);
        rmsnorm_bwd(
            &c.x_mid,
            p.get(&format!("{pre}norm2"))?,
            &c.rstd2,
            &dh2,
            t,
            d,
            &mut dx_mid,
            &mut dg_sink,
        );

        let mut datt = vec![0.0f32; t * d];
        linear_bwd_x(&dx_mid, p.get(&format!("{pre}wo"))?, t, d, d, &mut datt);

        let mut dq = vec![0.0f32; t * d];
        let mut dk = vec![0.0f32; t * dkv];
        let mut dv = vec![0.0f32; t * dkv];
        attention_bwd(&datt, &c.q, &c.k, &c.v, &c.probs, bv, hq, hkv, hd, &mut dq, &mut dk, &mut dv);
        rope_apply(&mut dq, bv.pos, t, hq, hd, -1.0);
        rope_apply(&mut dk, bv.pos, t, hkv, hd, -1.0);

        let mut dh1 = vec![0.0f32; t * d];
        linear_bwd_x(&dq, p.get(&format!("{pre}wq"))?, t, d, d, &mut dh1);
        linear_bwd_x(&dk, p.get(&format!("{pre}wk"))?, t, d, dkv, &mut dh1);
        linear_bwd_x(&dv, p.get(&format!("{pre}wv"))?, t, d, dkv, &mut dh1);

        // the adapter chain: the only trainable weights, reduced per slice
        // in fixed slice order so each tenant's grads see exactly its own
        // tokens in the serial accumulation order
        let i_qa = p.id(&format!("{pre}wq_a"))?;
        let i_qb = p.id(&format!("{pre}wq_b"))?;
        let i_va = p.id(&format!("{pre}wv_a"))?;
        let i_vb = p.id(&format!("{pre}wv_b"))?;
        let hq_a = c.hq_a.as_ref().expect("lora cache");
        let hv_a = c.hv_a.as_ref().expect("lora cache");
        for (ki, sl) in slices.iter().enumerate() {
            let lo = sl.row_start * seq;
            let hi = (sl.row_start + sl.rows) * seq;
            let ts = hi - lo;
            let ad = &adapters[ki];
            let g = &mut tenant_grads[ki];

            let mut dq_s = dq[lo * d..hi * d].to_vec();
            for gv in dq_s.iter_mut() {
                *gv *= scale;
            }
            linear_bwd_w(&dq_s, &hq_a[lo * r..hi * r], ts, r, d, &mut g[i_qb]);
            let mut dhq_a = vec![0.0f32; ts * r];
            linear_bwd_x(&dq_s, ad.params[i_qb].as_f32()?, ts, r, d, &mut dhq_a);
            linear_bwd_w(&dhq_a, &c.h1[lo * d..hi * d], ts, d, r, &mut g[i_qa]);
            linear_bwd_x(&dhq_a, ad.params[i_qa].as_f32()?, ts, d, r, &mut dh1[lo * d..hi * d]);

            let mut dv_s = dv[lo * dkv..hi * dkv].to_vec();
            for gv in dv_s.iter_mut() {
                *gv *= scale;
            }
            linear_bwd_w(&dv_s, &hv_a[lo * r..hi * r], ts, r, dkv, &mut g[i_vb]);
            let mut dhv_a = vec![0.0f32; ts * r];
            linear_bwd_x(&dv_s, ad.params[i_vb].as_f32()?, ts, r, dkv, &mut dhv_a);
            linear_bwd_w(&dhv_a, &c.h1[lo * d..hi * d], ts, d, r, &mut g[i_va]);
            linear_bwd_x(&dhv_a, ad.params[i_va].as_f32()?, ts, d, r, &mut dh1[lo * d..hi * d]);
        }

        let mut dx_in = dx_mid;
        dg_sink.iter_mut().for_each(|g| *g = 0.0);
        rmsnorm_bwd(
            &c.x_in,
            p.get(&format!("{pre}norm1"))?,
            &c.rstd1,
            &dh1,
            t,
            d,
            &mut dx_in,
            &mut dg_sink,
        );
        dx = dx_in;
    }
    // the embedding is frozen under LoRA: the remaining dx is discarded
    let bwd_s = t_bwd.elapsed().as_secs_f64();

    // ---- per-tenant grad-norm + optimizer, each at its own coordinates --
    let t_optim = Instant::now();
    let mut outs = Vec::with_capacity(slices.len());
    for (ki, sl) in slices.iter().enumerate() {
        let g = &tenant_grads[ki];
        let mut sq = 0.0f32;
        for gi in g {
            for &xv in gi {
                sq += xv * xv;
            }
        }
        let grad_norm = sq.sqrt();

        let ad = &mut *adapters[ki];
        // each tenant steps under its *own* optimizer-state codec
        let int8_scratch = match ad.optim {
            OptimStates::Fp32 => None,
            OptimStates::Int8 => {
                let maxn = ad.params.iter().map(|tn| tn.elements()).max().unwrap_or(0);
                Some((vec![0.0f32; maxn], vec![0.0f32; maxn]))
            }
        };
        let mut int8_scratch = int8_scratch;
        for i in 0..nt {
            let lr_p = match classify_param(&state.names[i]) {
                ParamGroup::LoraB => sl.lr_b,
                _ => sl.lr,
            };
            let param = ad.params[i].as_f32_mut()?;
            match &mut int8_scratch {
                None => adamw_update(
                    param,
                    &g[i],
                    &mut ad.slot_m[i],
                    &mut ad.slot_v[i],
                    lr_p,
                    sl.step as f32,
                    WEIGHT_DECAY,
                ),
                Some((m_buf, v_buf)) => adamw_update_int8(
                    param,
                    &g[i],
                    &mut ad.qslot_m[i],
                    &mut ad.qslot_v[i],
                    lr_p,
                    sl.step as f32,
                    WEIGHT_DECAY,
                    m_buf,
                    v_buf,
                ),
            }
        }
        let (loss_sum, n_valid) = tenant_fwd[ki];
        outs.push(StepOut {
            loss: loss_sum / n_valid.max(1) as f32,
            grad_norm,
            n_tokens: n_valid as f32,
            phases: StepPhases::default(),
        });
    }
    let optim_s = t_optim.elapsed().as_secs_f64();
    Ok((outs, StepPhases { fwd_s, bwd_s, optim_s }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { vocab: 16, d_model: 8, n_layers: 2, n_heads: 2, n_kv_heads: 1, d_ff: 12 }
    }

    /// A packed two-row batch: row 0 holds two segments, row 1 one segment
    /// plus padding; some targets masked.
    fn batch() -> (Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>, usize, usize) {
        let (bsz, s) = (2usize, 10usize);
        let mut tokens = vec![0i32; bsz * s];
        let mut targets = vec![-1i32; bsz * s];
        let mut seg = vec![0i32; bsz * s];
        let mut pos = vec![0i32; bsz * s];
        let mut rng = Rng::new(99);
        let rows: [&[usize]; 2] = [&[5, 4], &[6]];
        for (b, lens) in rows.iter().enumerate() {
            let mut off = 0usize;
            for (si, &len) in lens.iter().enumerate() {
                for i in 0..len {
                    let t = b * s + off + i;
                    tokens[t] = rng.range(4, 16) as i32;
                    seg[t] = (si + 1) as i32;
                    pos[t] = i as i32;
                    if i > 0 {
                        targets[t - 1] = tokens[t];
                    }
                }
                off += len;
            }
        }
        (tokens, targets, seg, pos, bsz, s)
    }

    fn bv(t: &(Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>, usize, usize)) -> BatchView<'_> {
        BatchView { tokens: &t.0, targets: &t.1, seg: &t.2, pos: &t.3, bsz: t.4, seq: t.5 }
    }

    #[test]
    fn initial_loss_near_log_vocab() {
        let state = init_state(dims(), None, 7);
        let b = batch();
        let loss = eval_loss(&state, &bv(&b)).unwrap();
        let lv = (16.0f32).ln();
        assert!((loss - lv).abs() < 0.5, "loss {loss} vs ln V {lv}");
    }

    #[test]
    fn full_ft_loss_decreases_and_grads_flow() {
        let mut state = init_state(dims(), None, 7);
        let b = batch();
        let mut losses = Vec::new();
        for step in 1..=12u64 {
            let out = train_step(&mut state, &bv(&b), false, step, 5e-3, 5e-3).unwrap();
            assert!(out.loss.is_finite());
            assert!(out.grad_norm > 0.0, "step {step} grad_norm zero");
            losses.push(out.loss);
        }
        assert!(
            losses[11] < losses[0],
            "loss did not decrease: {losses:?}"
        );
    }

    #[test]
    fn lora_trains_only_adapters() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let mut state = init_state(dims(), Some(lora), 7);
        let frozen_before: Vec<Vec<f32>> = state.params[state.n_trainable..]
            .iter()
            .map(|t| t.as_f32().unwrap().to_vec())
            .collect();
        let b = batch();
        let mut losses = Vec::new();
        for step in 1..=12u64 {
            let out = train_step(&mut state, &bv(&b), false, step, 5e-3, 5e-3).unwrap();
            assert!(out.grad_norm > 0.0);
            losses.push(out.loss);
        }
        assert!(losses[11] < losses[0], "{losses:?}");
        for (t, before) in state.params[state.n_trainable..].iter().zip(&frozen_before) {
            assert_eq!(t.as_f32().unwrap(), &before[..], "frozen param moved");
        }
    }

    #[test]
    fn broken_mode_has_zero_grad_and_frozen_loss() {
        let mut state = init_state(dims(), None, 7);
        let b = batch();
        let mut losses = Vec::new();
        for step in 1..=5u64 {
            let out = train_step(&mut state, &bv(&b), true, step, 5e-3, 5e-3).unwrap();
            assert_eq!(out.grad_norm, 0.0);
            losses.push(out.loss);
        }
        assert!(losses.windows(2).all(|w| w[0] == w[1]), "{losses:?}");
    }

    #[test]
    fn train_step_is_bitwise_deterministic() {
        let b = batch();
        let run = || {
            let mut state = init_state(dims(), None, 42);
            let mut bits = Vec::new();
            for step in 1..=6u64 {
                let out = train_step(&mut state, &bv(&b), false, step, 3e-3, 3e-3).unwrap();
                bits.push((out.loss.to_bits(), out.grad_norm.to_bits()));
            }
            bits
        };
        assert_eq!(run(), run());
    }

    /// Whole-model gradient check: the central finite difference of the
    /// mean loss along the *normalized analytic gradient* direction must
    /// equal ‖∇L‖ (since dL/dε at θ+ε·∇L/‖∇L‖ is exactly ‖∇L‖). This
    /// exercises every backward component composed — attention, RoPE,
    /// SwiGLU, CCE, LoRA chain, embeddings — so a dropped scale factor
    /// anywhere shows up as a relative error far above the tolerance.
    #[test]
    fn whole_model_gradient_matches_directional_derivative() {
        for lora in [None, Some(LoraCfg { rank: 2, alpha: 4.0 })] {
            let state = init_state(dims(), lora, 5);
            let b = batch();
            let view = bv(&b);
            let mut lcs = Vec::new();
            let mut fc = None;
            forward(&state, &view, Some((&mut lcs, &mut fc))).unwrap();
            let grads = backward(&state, &view, &lcs, &fc.unwrap()).unwrap();
            let norm: f32 = grads[..state.n_trainable]
                .iter()
                .flat_map(|g| g.iter())
                .map(|&x| x * x)
                .sum::<f32>()
                .sqrt();
            assert!(norm > 0.0, "lora={lora:?}: zero gradient at init");

            let eps = 1e-2f32;
            let perturbed = |sign: f32| {
                let mut s2 = state.clone();
                for i in 0..s2.n_trainable {
                    let p = s2.params[i].as_f32_mut().unwrap();
                    for (pv, gv) in p.iter_mut().zip(&grads[i]) {
                        *pv += sign * eps * gv / norm;
                    }
                }
                eval_loss(&s2, &view).unwrap()
            };
            let fd = (perturbed(1.0) - perturbed(-1.0)) / (2.0 * eps);
            let rel = (fd - norm).abs() / norm;
            assert!(
                rel < 0.05,
                "lora={lora:?}: directional derivative {fd} vs ‖∇L‖ {norm} (rel err {rel})"
            );
        }
    }

    #[test]
    fn padding_rows_get_no_gradient() {
        // embeddings of tokens that never appear must have zero grad; the
        // pad token (0) appears only in padding slots, whose dlogits are
        // masked, so its row receives gradient only via attention — which
        // the segment mask forbids.
        let state = init_state(dims(), None, 7);
        let b = batch();
        let view = bv(&b);
        let mut lcs = Vec::new();
        let mut fc = None;
        forward(&state, &view, Some((&mut lcs, &mut fc))).unwrap();
        let grads = backward(&state, &view, &lcs, &fc.unwrap()).unwrap();
        let d = state.dims.d_model;
        let ge = &grads[0][0..d]; // embed row of the pad token
        assert!(ge.iter().all(|&g| g == 0.0), "pad embedding got gradient: {ge:?}");
    }

    #[test]
    fn eval_matches_train_loss_before_update() {
        let mut state = init_state(dims(), None, 3);
        let b = batch();
        let e = eval_loss(&state, &bv(&b)).unwrap();
        let out = train_step(&mut state, &bv(&b), false, 1, 1e-3, 1e-3).unwrap();
        assert_eq!(e.to_bits(), out.loss.to_bits());
    }

    #[test]
    fn init_adapter_matches_init_state_prefix() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        for seed in [0, 7, 42, -3] {
            let state = init_state(dims(), Some(lora), seed);
            let adapter = init_adapter(dims(), lora, seed);
            assert_eq!(adapter.params.len(), state.n_trainable);
            for i in 0..state.n_trainable {
                assert_eq!(adapter.names[i], state.names[i]);
                let a: Vec<u32> =
                    adapter.params[i].as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
                let s: Vec<u32> =
                    state.params[i].as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, s, "seed {seed}, tensor {} diverges", adapter.names[i]);
            }
        }
    }

    /// The serve contract in miniature: two tenants time-sliced onto one
    /// shared workspace via `swap_adapter` train bitwise identically to
    /// each tenant on its own dedicated state, and the shared base never
    /// moves.
    #[test]
    fn swapped_adapters_train_bitwise_identically_to_dedicated_states() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let b = batch();
        let base_seed = 11;

        // dedicated per-tenant states (the serial oracle)
        let dedicated = |adapter_seed: i32| {
            let mut st = init_state(dims(), Some(lora), base_seed);
            let mut ad = init_adapter(dims(), lora, adapter_seed);
            swap_adapter(&mut st, &mut ad).unwrap();
            let mut losses = Vec::new();
            for step in 1..=6u64 {
                losses.push(train_step(&mut st, &bv(&b), false, step, 5e-3, 5e-3).unwrap().loss);
            }
            swap_adapter(&mut st, &mut ad).unwrap();
            (losses, ad)
        };
        let (l1, a1) = dedicated(100);
        let (l2, a2) = dedicated(200);

        // one shared workspace, tenants interleaved round-robin
        let mut ws = init_state(dims(), Some(lora), base_seed);
        let base_before: Vec<Vec<f32>> = ws.params[ws.n_trainable..]
            .iter()
            .map(|t| t.as_f32().unwrap().to_vec())
            .collect();
        let mut t1 = init_adapter(dims(), lora, 100);
        let mut t2 = init_adapter(dims(), lora, 200);
        let mut f1 = Vec::new();
        let mut f2 = Vec::new();
        for step in 1..=6u64 {
            swap_adapter(&mut ws, &mut t1).unwrap();
            f1.push(train_step(&mut ws, &bv(&b), false, step, 5e-3, 5e-3).unwrap().loss);
            swap_adapter(&mut ws, &mut t1).unwrap();
            swap_adapter(&mut ws, &mut t2).unwrap();
            f2.push(train_step(&mut ws, &bv(&b), false, step, 5e-3, 5e-3).unwrap().loss);
            swap_adapter(&mut ws, &mut t2).unwrap();
        }
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&f1), bits(&l1), "tenant 1 fused != serial");
        assert_eq!(bits(&f2), bits(&l2), "tenant 2 fused != serial");
        for (ad, ded) in [(&t1, &a1), (&t2, &a2)] {
            for i in 0..ad.params.len() {
                assert_eq!(
                    bits(ad.params[i].as_f32().unwrap()),
                    bits(ded.params[i].as_f32().unwrap()),
                    "final adapter weights diverge at {}",
                    ad.names[i]
                );
                assert_eq!(bits(&ad.slot_m[i]), bits(&ded.slot_m[i]), "slot_m diverges");
                assert_eq!(bits(&ad.slot_v[i]), bits(&ded.slot_v[i]), "slot_v diverges");
            }
        }
        for (t, before) in ws.params[ws.n_trainable..].iter().zip(&base_before) {
            assert_eq!(t.as_f32().unwrap(), &before[..], "shared base weights moved");
        }
    }

    /// The intra-step contract in miniature, on a *ragged* round: tenant A
    /// contributes 1 row and tenant B 2 rows to one concatenated batch; a
    /// single shared base pass with per-slice adapter epilogues must land
    /// bit-for-bit where each tenant's serial swap-in/train/swap-out run
    /// lands — losses, grad norms, adapter weights and optimizer slots.
    #[test]
    fn fused_step_matches_serial_bitwise_with_ragged_slices() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let base_seed = 11;
        let b = batch();
        let seq = b.5;

        // tenant A trains on row 0 only; tenant B on both rows
        let a_view = BatchView {
            tokens: &b.0[..seq],
            targets: &b.1[..seq],
            seg: &b.2[..seq],
            pos: &b.3[..seq],
            bsz: 1,
            seq,
        };
        let cat = |v: &Vec<i32>| {
            let mut out = v[..seq].to_vec();
            out.extend_from_slice(v);
            out
        };
        let (ct, cg, cs, cp) = (cat(&b.0), cat(&b.1), cat(&b.2), cat(&b.3));
        let concat = BatchView { tokens: &ct, targets: &cg, seg: &cs, pos: &cp, bsz: 3, seq };

        let serial = |seed: i32, view: &BatchView, steps: u64, lr: f32, lr_b: f32| {
            let mut st = init_state(dims(), Some(lora), base_seed);
            let mut ad = init_adapter(dims(), lora, seed);
            swap_adapter(&mut st, &mut ad).unwrap();
            let mut outs = Vec::new();
            for step in 1..=steps {
                outs.push(train_step(&mut st, view, false, step, lr, lr_b).unwrap());
            }
            swap_adapter(&mut st, &mut ad).unwrap();
            (outs, ad)
        };
        // tenant B runs LoRA+ (lr_b != lr) to exercise the dual-LR path
        let (sa, ada) = serial(100, &a_view, 4, 5e-3, 5e-3);
        let (sb, adb) = serial(200, &bv(&b), 4, 5e-3, 8e-3);

        let ws = init_state(dims(), Some(lora), base_seed);
        let mut t1 = init_adapter(dims(), lora, 100);
        let mut t2 = init_adapter(dims(), lora, 200);
        let mut fa = Vec::new();
        let mut fb = Vec::new();
        for step in 1..=4u64 {
            let slices = [
                FusedSlice { row_start: 0, rows: 1, step, lr: 5e-3, lr_b: 5e-3 },
                FusedSlice { row_start: 1, rows: 2, step, lr: 5e-3, lr_b: 8e-3 },
            ];
            let mut ads = [&mut t1, &mut t2];
            let (outs, _) = fused_train_step(&ws, &mut ads, &concat, &slices).unwrap();
            assert_eq!(outs.len(), 2);
            fa.push(outs[0]);
            fb.push(outs[1]);
        }
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for (fused, serial) in [(&fa, &sa), (&fb, &sb)] {
            for (fo, so) in fused.iter().zip(serial.iter()) {
                assert_eq!(fo.loss.to_bits(), so.loss.to_bits(), "loss diverges");
                assert_eq!(fo.grad_norm.to_bits(), so.grad_norm.to_bits(), "grad_norm diverges");
                assert_eq!(fo.n_tokens, so.n_tokens);
            }
        }
        for (fused, serial) in [(&t1, &ada), (&t2, &adb)] {
            for i in 0..fused.params.len() {
                assert_eq!(
                    bits(fused.params[i].as_f32().unwrap()),
                    bits(serial.params[i].as_f32().unwrap()),
                    "adapter weights diverge at {}",
                    fused.names[i]
                );
                assert_eq!(bits(&fused.slot_m[i]), bits(&serial.slot_m[i]), "slot_m diverges");
                assert_eq!(bits(&fused.slot_v[i]), bits(&serial.slot_v[i]), "slot_v diverges");
            }
        }
    }

    /// A mixed round: tenant A is mid-schedule (optimizer step 3) while
    /// tenant B joins at step 1 with a different learning rate. Each slice
    /// carries its own `(step, lr, lr_b)`, so the fused round must land
    /// exactly where the two serial schedules land. Tenant A's warm-up
    /// runs through single-slice fused rounds, which pins the degenerate
    /// one-tenant fused path to serial bits as well.
    #[test]
    fn fused_step_handles_mixed_step_rounds() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let base_seed = 11;
        let b = batch();
        let seq = b.5;
        let a_view = BatchView {
            tokens: &b.0[..seq],
            targets: &b.1[..seq],
            seg: &b.2[..seq],
            pos: &b.3[..seq],
            bsz: 1,
            seq,
        };
        let cat = |v: &Vec<i32>| {
            let mut out = v[..seq].to_vec();
            out.extend_from_slice(v);
            out
        };
        let (ct, cg, cs, cp) = (cat(&b.0), cat(&b.1), cat(&b.2), cat(&b.3));
        let concat = BatchView { tokens: &ct, targets: &cg, seg: &cs, pos: &cp, bsz: 3, seq };

        // serial oracles: A takes 3 steps at lr 5e-3, B one step at lr 2e-3
        let serial = |seed: i32, view: &BatchView, steps: u64, lr: f32| {
            let mut st = init_state(dims(), Some(lora), base_seed);
            let mut ad = init_adapter(dims(), lora, seed);
            swap_adapter(&mut st, &mut ad).unwrap();
            let mut losses = Vec::new();
            for step in 1..=steps {
                losses.push(train_step(&mut st, view, false, step, lr, lr).unwrap().loss);
            }
            swap_adapter(&mut st, &mut ad).unwrap();
            (losses, ad)
        };
        let (sa, ada) = serial(100, &a_view, 3, 5e-3);
        let (sb, adb) = serial(200, &bv(&b), 1, 2e-3);

        let ws = init_state(dims(), Some(lora), base_seed);
        let mut t1 = init_adapter(dims(), lora, 100);
        let mut t2 = init_adapter(dims(), lora, 200);
        let mut fa = Vec::new();
        // warm tenant A up two steps through single-slice fused rounds
        for step in 1..=2u64 {
            let slices = [FusedSlice { row_start: 0, rows: 1, step, lr: 5e-3, lr_b: 5e-3 }];
            let mut ads = [&mut t1];
            let (outs, _) = fused_train_step(&ws, &mut ads, &a_view, &slices).unwrap();
            fa.push(outs[0].loss);
        }
        // the mixed round: A at step 3, B at step 1 with its own lr
        let slices = [
            FusedSlice { row_start: 0, rows: 1, step: 3, lr: 5e-3, lr_b: 5e-3 },
            FusedSlice { row_start: 1, rows: 2, step: 1, lr: 2e-3, lr_b: 2e-3 },
        ];
        let mut ads = [&mut t1, &mut t2];
        let (outs, _) = fused_train_step(&ws, &mut ads, &concat, &slices).unwrap();
        fa.push(outs[0].loss);
        let fb = vec![outs[1].loss];

        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&fa), bits(&sa), "tenant A mixed-round losses diverge");
        assert_eq!(bits(&fb), bits(&sb), "tenant B mixed-round losses diverge");
        for (fused, serial) in [(&t1, &ada), (&t2, &adb)] {
            for i in 0..fused.params.len() {
                assert_eq!(
                    bits(fused.params[i].as_f32().unwrap()),
                    bits(serial.params[i].as_f32().unwrap()),
                    "adapter weights diverge at {}",
                    fused.names[i]
                );
            }
        }
    }

    #[test]
    fn fused_step_rejects_bad_inputs() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let b = batch();
        let view = bv(&b);
        let sl = |row_start, rows, step| FusedSlice { row_start, rows, step, lr: 1e-3, lr_b: 1e-3 };

        // a full-FT workspace has no adapter seam
        let full = init_state(dims(), None, 1);
        let mut ad = init_adapter(dims(), lora, 1);
        let mut ads = [&mut ad];
        assert!(fused_train_step(&full, &mut ads, &view, &[sl(0, 2, 1)]).is_err());

        let ws = init_state(dims(), Some(lora), 1);
        // coverage mismatch: slices must tile the concat batch exactly
        let mut a1 = init_adapter(dims(), lora, 1);
        let mut ads = [&mut a1];
        assert!(fused_train_step(&ws, &mut ads, &view, &[sl(0, 1, 1)]).is_err());
        // non-contiguous slices
        let mut a1 = init_adapter(dims(), lora, 1);
        let mut a2 = init_adapter(dims(), lora, 2);
        let mut ads = [&mut a1, &mut a2];
        assert!(fused_train_step(&ws, &mut ads, &view, &[sl(0, 1, 1), sl(2, 1, 1)]).is_err());
        // 0-based step
        let mut a1 = init_adapter(dims(), lora, 1);
        let mut ads = [&mut a1];
        assert!(fused_train_step(&ws, &mut ads, &view, &[sl(0, 2, 0)]).is_err());
        // adapter/slice count mismatch
        let mut a1 = init_adapter(dims(), lora, 1);
        let mut ads = [&mut a1];
        assert!(fused_train_step(&ws, &mut ads, &view, &[sl(0, 1, 1), sl(1, 1, 1)]).is_err());
    }

    #[test]
    fn swap_adapter_rejects_mismatches() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let mut full = init_state(dims(), None, 1);
        let mut ad = init_adapter(dims(), lora, 1);
        assert!(swap_adapter(&mut full, &mut ad).is_err(), "full-FT state has no adapter seam");
        let mut st = init_state(dims(), Some(lora), 1);
        let mut wrong_rank = init_adapter(dims(), LoraCfg { rank: 4, alpha: 4.0 }, 1);
        assert!(swap_adapter(&mut st, &mut wrong_rank).is_err(), "rank mismatch must fail");
        let other =
            ModelDims { vocab: 16, d_model: 8, n_layers: 1, n_heads: 2, n_kv_heads: 1, d_ff: 12 };
        let mut wrong_dims = init_adapter(other, lora, 1);
        assert!(swap_adapter(&mut st, &mut wrong_dims).is_err(), "geometry mismatch must fail");
    }

    #[test]
    fn out_of_vocab_token_rejected() {
        let state = init_state(dims(), None, 7);
        let tokens = vec![99i32];
        let targets = vec![-1i32];
        let seg = vec![1i32];
        let pos = vec![0i32];
        let view = BatchView { tokens: &tokens, targets: &targets, seg: &seg, pos: &pos, bsz: 1, seq: 1 };
        assert!(eval_loss(&state, &view).is_err());
    }

    #[test]
    fn ckpt_segment_starts_partition_layers() {
        assert_eq!(ckpt_segment_starts(2, 2), vec![0, 1]);
        assert_eq!(ckpt_segment_starts(5, 2), vec![0, 3]);
        assert_eq!(ckpt_segment_starts(4, 8), vec![0, 1, 2, 3]); // clamped
        assert_eq!(ckpt_segment_starts(6, 1), vec![0]);
    }

    #[test]
    fn checkpointed_training_is_bitwise_identical() {
        // recompute-from-boundary replays the exact op sequence, so every
        // loss, grad_norm, and parameter bit must match the cached run
        let b = batch();
        let mut plain = init_state(dims(), None, 7);
        let mut ckpt = init_state(dims(), None, 7);
        ckpt.ckpt_segments = 2;
        for step in 1..=6u64 {
            let a = train_step(&mut plain, &bv(&b), false, step, 5e-3, 5e-3).unwrap();
            let c = train_step(&mut ckpt, &bv(&b), false, step, 5e-3, 5e-3).unwrap();
            assert_eq!(a.loss.to_bits(), c.loss.to_bits(), "step {step} loss");
            assert_eq!(a.grad_norm.to_bits(), c.grad_norm.to_bits(), "step {step} grad_norm");
        }
        for (x, y) in plain.params.iter().zip(&ckpt.params) {
            let (x, y) = (x.as_f32().unwrap(), y.as_f32().unwrap());
            assert!(x.iter().zip(y).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn int8_optim_first_step_is_bitwise_and_later_steps_track() {
        let b = batch();
        let mut fp = init_state(dims(), None, 7);
        let mut q = init_state(dims(), None, 7);
        set_optim_states(&mut q, OptimStates::Int8).unwrap();
        // step 1 decodes all-zero slots → identical AdamW inputs → bitwise
        let a = train_step(&mut fp, &bv(&b), false, 1, 5e-3, 5e-3).unwrap();
        let c = train_step(&mut q, &bv(&b), false, 1, 5e-3, 5e-3).unwrap();
        assert_eq!(a.loss.to_bits(), c.loss.to_bits());
        assert_eq!(a.grad_norm.to_bits(), c.grad_norm.to_bits());
        // later steps quantize the moments; losses stay close and finite
        for step in 2..=15u64 {
            let a = train_step(&mut fp, &bv(&b), false, step, 5e-3, 5e-3).unwrap();
            let c = train_step(&mut q, &bv(&b), false, step, 5e-3, 5e-3).unwrap();
            assert!(c.loss.is_finite() && c.grad_norm > 0.0);
            assert!((a.loss - c.loss).abs() < 0.05, "step {step}: {} vs {}", a.loss, c.loss);
        }
        assert!(optim_state_bytes(&q) * 7 < optim_state_bytes(&fp) * 2, "int8 slots ≥3.5x smaller");
    }

    #[test]
    fn optim_codec_change_after_training_is_rejected() {
        let b = batch();
        let mut st = init_state(dims(), None, 7);
        train_step(&mut st, &bv(&b), false, 1, 5e-3, 5e-3).unwrap();
        let err = set_optim_states(&mut st, OptimStates::Int8).unwrap_err().to_string();
        assert!(err.contains("after training started"), "{err}");
    }

    #[test]
    fn quantized_base_lora_trains_close_to_dense_base() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let b = batch();
        let mut dense = init_state(dims(), Some(lora), 7);
        let mut quant = init_state(dims(), Some(lora), 7);
        quantize_base(&mut quant, BaseQuant::Int8).unwrap();
        // quantized frozen payloads are really gone
        let n_gone = quant.qbase.iter().filter(|q| q.is_some()).count();
        assert!(n_gone > 0);
        for (i, q) in quant.qbase.iter().enumerate() {
            if q.is_some() {
                assert_eq!(quant.params[i].elements(), 0, "dense payload survived at {i}");
            }
        }
        let mut dl = Vec::new();
        let mut ql = Vec::new();
        for step in 1..=12u64 {
            dl.push(train_step(&mut dense, &bv(&b), false, step, 5e-3, 5e-3).unwrap().loss);
            ql.push(train_step(&mut quant, &bv(&b), false, step, 5e-3, 5e-3).unwrap().loss);
        }
        assert!(ql[11] < ql[0], "quantized-base LoRA did not learn: {ql:?}");
        for (a, c) in dl.iter().zip(&ql) {
            assert!((a - c).abs() / a.abs().max(1e-6) < 0.02, "{dl:?} vs {ql:?}");
        }
    }

    #[test]
    fn quantize_base_requires_lora() {
        let mut st = init_state(dims(), None, 7);
        let err = quantize_base(&mut st, BaseQuant::Int8).unwrap_err().to_string();
        assert!(err.contains("LoRA"), "{err}");
    }

    #[test]
    fn optim_snapshot_roundtrips_bitwise_and_rejects_codec_migration() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let b = batch();
        let mut st = init_state(dims(), Some(lora), 7);
        set_optim_states(&mut st, OptimStates::Int8).unwrap();
        for step in 1..=3u64 {
            train_step(&mut st, &bv(&b), false, step, 5e-3, 5e-3).unwrap();
        }
        let snap = optim_snapshot(&st);
        let mut fresh = init_state(dims(), Some(lora), 7);
        set_optim_states(&mut fresh, OptimStates::Int8).unwrap();
        load_optim_snapshot(&mut fresh, &snap).unwrap();
        assert_eq!(fresh.qslot_m, st.qslot_m);
        assert_eq!(fresh.qslot_v, st.qslot_v);
        // fp32-configured state must reject the int8 snapshot with the
        // migration message, not silently convert
        let mut fp = init_state(dims(), Some(lora), 7);
        let err = load_optim_snapshot(&mut fp, &snap).unwrap_err().to_string();
        assert!(err.contains("migration is not supported"), "{err}");
    }

    #[test]
    fn swap_adapter_rejects_optim_codec_mismatch() {
        let lora = LoraCfg { rank: 2, alpha: 4.0 };
        let mut st = init_state(dims(), Some(lora), 1);
        set_optim_states(&mut st, OptimStates::Int8).unwrap();
        let mut ad = init_adapter(dims(), lora, 2);
        let err = swap_adapter(&mut st, &mut ad).unwrap_err().to_string();
        assert!(err.contains("optimizer-state codec mismatch"), "{err}");
        set_adapter_optim(&mut ad, OptimStates::Int8).unwrap();
        swap_adapter(&mut st, &mut ad).unwrap();
    }
}
