//! The PJRT execution backend: drives the AOT HLO artifacts emitted by
//! `python/compile/aot.py` through the [`crate::runtime::pjrt::Runtime`].
//!
//! Feature-gated behind `pjrt`. With the vendored host-only xla stub this
//! module type-checks but `PjrtBackend::new` fails at runtime (the stub's
//! `PjRtClient::cpu()` errors); vendor real xla-rs bindings to execute
//! (DESIGN.md §4.2).

use super::{Backend, DeviceBatch, DeviceState, StepOutputs};
use crate::batching::Batch;
use crate::manifest::{DType, Manifest};
use crate::runtime::{HostTensor, OutBuf, Runtime, TrainState, UploadedBatch};
use anyhow::{anyhow, bail, Result};
use xla::Literal;

pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: Runtime::new(artifacts_dir)? })
    }

    /// Direct runtime access for PJRT-only workflows (microbench harnesses,
    /// artifact inspection).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    fn as_pjrt_state<'a>(&self, state: &'a DeviceState) -> Result<&'a TrainState> {
        match state {
            DeviceState::Pjrt(s) => Ok(s),
            _ => bail!("state was created by a different backend than 'pjrt'"),
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.rt.manifest
    }

    fn init_state(&self, init_name: &str, seed: i32) -> Result<DeviceState> {
        Ok(DeviceState::Pjrt(TrainState::init(&self.rt, init_name, seed)?))
    }

    fn upload_batch(&self, train_name: &str, batch: &Batch) -> Result<DeviceBatch> {
        self.rt.manifest.get(train_name)?;
        Ok(DeviceBatch::Pjrt(self.rt.upload_train_batch(batch)?))
    }

    fn train_step(
        &self,
        train_name: &str,
        state: &mut DeviceState,
        batch: &DeviceBatch,
        step: u64,
        lr: f32,
        lr_b: f32,
    ) -> Result<StepOutputs> {
        // borrow, don't clone: this runs every step and the spec is only read
        let spec = self.rt.manifest.get(train_name)?;
        if spec.kind != "train" {
            bail!("'{train_name}' is not a train executable (kind = {})", spec.kind);
        }
        let st = match state {
            DeviceState::Pjrt(s) => s,
            _ => bail!("state was created by a different backend than 'pjrt'"),
        };
        let ub: &UploadedBatch = match batch {
            DeviceBatch::Pjrt(u) => u,
            _ => bail!("batch was uploaded to a different backend"),
        };
        if st.buffers.len() != spec.n_state_inputs() {
            bail!(
                "state has {} buffers, executable expects {}",
                st.buffers.len(),
                spec.n_state_inputs()
            );
        }
        let exe = self.rt.compile(train_name)?;

        // Per step only three f32 scalars (step, lr, lr_b) cross the host
        // boundary in, and three (loss, grad_norm, n_tokens) come back out.
        let scalar_lits = [
            Literal::scalar(step as f32),
            Literal::scalar(lr),
            Literal::scalar(lr_b),
        ];
        let mut scalar_bufs = Vec::with_capacity(3);
        for lit in &scalar_lits {
            scalar_bufs.push(
                self.rt
                    .client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("scalar upload: {e:?}"))?,
            );
        }

        let mut args: Vec<&xla::PjRtBuffer> = st.input_refs();
        args.extend(ub.bufs.iter());
        args.extend(scalar_bufs.iter());

        let n_outputs = spec.outputs.len();
        let mut outs = self.rt.execute_buffers(&exe, &args, n_outputs)?;

        // last three outputs: loss, grad_norm, n_tokens
        let n_tokens_out = outs.pop().ok_or_else(|| anyhow!("missing n_tokens"))?;
        let gnorm_out = outs.pop().ok_or_else(|| anyhow!("missing grad_norm"))?;
        let loss_out = outs.pop().ok_or_else(|| anyhow!("missing loss"))?;
        let loss = loss_out.scalar_f32()?;
        let grad_norm = gnorm_out.scalar_f32()?;
        let n_tokens = n_tokens_out.scalar_f32()?;

        debug_assert_eq!(outs.len(), spec.n_state_outputs());
        st.apply_step_outputs(&self.rt, outs)?;

        Ok(StepOutputs { loss, grad_norm, n_tokens, phases: Default::default() })
    }

    fn eval_loss(&self, eval_name: &str, state: &DeviceState, batch: &Batch) -> Result<f32> {
        let spec = self.rt.manifest.get(eval_name)?;
        let exe = self.rt.compile(eval_name)?;
        let st = self.as_pjrt_state(state)?;
        let n_params = spec.n_trainable + spec.n_frozen;
        let mut args: Vec<&xla::PjRtBuffer> = st.buffers[..n_params].iter().collect();
        let batch_lits = [
            batch.tokens.to_literal(&[batch.batch, batch.seq])?,
            batch.targets.to_literal(&[batch.batch, batch.seq])?,
            batch.seg_ids.to_literal(&[batch.batch, batch.seq])?,
            batch.pos_ids.to_literal(&[batch.batch, batch.seq])?,
        ];
        let mut bufs = Vec::new();
        for lit in &batch_lits {
            bufs.push(
                self.rt
                    .client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("eval upload: {e:?}"))?,
            );
        }
        args.extend(bufs.iter());
        let outs = self.rt.execute_buffers(&exe, &args, spec.outputs.len())?;
        outs[0].scalar_f32()
    }

    fn state_params(&self, state: &DeviceState) -> Result<Vec<HostTensor>> {
        self.as_pjrt_state(state)?
            .params_to_host()?
            .iter()
            .map(HostTensor::from_literal)
            .collect()
    }

    fn load_params(&self, state: &mut DeviceState, params: &[HostTensor]) -> Result<()> {
        let st = match state {
            DeviceState::Pjrt(s) => s,
            _ => bail!("state was created by a different backend than 'pjrt'"),
        };
        let n = st.n_trainable + st.n_frozen;
        if params.len() != n {
            bail!("checkpoint has {} tensors, state expects {n}", params.len());
        }
        // two-phase: upload every tensor first, then swap, so a failure
        // partway through never leaves half-restored device state behind
        let mut staged = Vec::with_capacity(n);
        for (i, t) in params.iter().enumerate() {
            let lit = t.to_literal(t.shape())?;
            let b = self
                .rt
                .client
                .buffer_from_host_literal(None, &lit)
                .map_err(|e| anyhow!("uploading checkpoint tensor {i}: {e:?}"))?;
            let _ = b.to_literal_sync(); // force the async copy before `lit` drops
            staged.push(b);
        }
        for (i, b) in staged.into_iter().enumerate() {
            st.buffers[i] = b;
        }
        Ok(())
    }

    /// One-shot kernel microbench: run a kernel executable with synthetic
    /// inputs, returning mean wall time per execution (Table 5).
    fn bench_kernel(&self, name: &str, reps: usize, warmup: usize) -> Result<f64> {
        let spec = self.rt.manifest.get(name)?;
        let exe = self.rt.compile(name)?;
        let mut rng = crate::util::rng::Rng::new(0xC0FFEE);
        let mut lits = Vec::new();
        for inp in &spec.inputs {
            let n = inp.elements();
            let lit = match inp.dtype {
                DType::F32 => {
                    let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
                    HostTensor::f32(v, inp.shape.clone()).to_literal(&inp.shape)?
                }
                DType::I32 => {
                    let v: Vec<i32> = (0..n).map(|_| rng.range(0, 16) as i32).collect();
                    HostTensor::i32(v, inp.shape.clone()).to_literal(&inp.shape)?
                }
            };
            lits.push(lit);
        }
        let mut bufs = Vec::new();
        for lit in &lits {
            bufs.push(
                self.rt
                    .client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("bench upload: {e:?}"))?,
            );
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        // outputs unknown for kernels (manifest lists []); execute and count
        let first = exe
            .execute_b(&refs)
            .map_err(|e| anyhow!("bench execute: {e:?}"))?;
        let n_out = first[0].len().max(1);
        for _ in 0..warmup {
            force(&self.rt.execute_buffers(&exe, &refs, n_out)?)?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            force(&self.rt.execute_buffers(&exe, &refs, n_out)?)?;
        }
        Ok(t0.elapsed().as_secs_f64() / reps as f64)
    }
}

/// Force async execution to completion by reading one output back.
fn force(outs: &[OutBuf]) -> Result<()> {
    if let Some(o) = outs.first() {
        let _ = o.to_literal()?;
    }
    Ok(())
}
