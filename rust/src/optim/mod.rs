//! Host-side optimizer policy: learning-rate schedules and LoRA+ grouping.
//!
//! The optimizer math itself runs inside the AOT step executable (L2); the
//! coordinator only decides *which scalars to feed* each step: `lr` for
//! every parameter group and `lr_b = λ·lr` for LoRA B matrices (paper
//! Thm. 1: λ ≈ 16). Changing λ therefore needs no recompilation.

/// Warmup + cosine decay (paper Table 7: warmup_ratio 0.03).
#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub base_lr: f64,
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_lr_frac: f64,
    /// LoRA+ ratio λ = η_B / η_A (1.0 = plain LoRA, 16.0 = LoRA+).
    pub lora_plus_ratio: f64,
}

impl LrSchedule {
    pub fn constant(lr: f64, lora_plus_ratio: f64) -> Self {
        LrSchedule {
            base_lr: lr,
            warmup_steps: 0,
            total_steps: u64::MAX,
            min_lr_frac: 1.0,
            lora_plus_ratio,
        }
    }

    pub fn warmup_cosine(
        lr: f64,
        warmup_steps: u64,
        total_steps: u64,
        lora_plus_ratio: f64,
    ) -> Self {
        LrSchedule {
            base_lr: lr,
            warmup_steps,
            total_steps,
            min_lr_frac: 0.1,
            lora_plus_ratio,
        }
    }

    /// lr at a 1-based step.
    pub fn lr(&self, step: u64) -> f64 {
        if self.warmup_steps > 0 && step <= self.warmup_steps {
            return self.base_lr * step as f64 / self.warmup_steps as f64;
        }
        if self.total_steps == u64::MAX {
            return self.base_lr;
        }
        let t = (step - self.warmup_steps) as f64
            / (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f64;
        let t = t.clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        self.base_lr * (self.min_lr_frac + (1.0 - self.min_lr_frac) * cos)
    }

    /// The (lr, lr_b) scalar pair fed to the step executable.
    pub fn lr_pair(&self, step: u64) -> (f32, f32) {
        let lr = self.lr(step);
        (lr as f32, (lr * self.lora_plus_ratio) as f32)
    }
}

/// LoRA+ parameter-group classification (paper Alg. 11): by our naming
/// convention, `*_a` are A matrices, `*_b` are B matrices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamGroup {
    LoraA,
    LoraB,
    Other,
}

pub fn classify_param(name: &str) -> ParamGroup {
    if name.ends_with("_a") {
        ParamGroup::LoraA
    } else if name.ends_with("_b") {
        ParamGroup::LoraB
    } else {
        ParamGroup::Other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::warmup_cosine(1e-3, 10, 100, 16.0);
        assert!((s.lr(1) - 1e-4).abs() < 1e-12);
        assert!((s.lr(5) - 5e-4).abs() < 1e-12);
        assert!((s.lr(10) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = LrSchedule::warmup_cosine(1e-3, 0, 100, 16.0);
        assert!(s.lr(100) < s.lr(50));
        assert!((s.lr(100) - 1e-4).abs() < 1e-6); // min_lr_frac = 0.1
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(2e-5, 1.0);
        assert_eq!(s.lr(1), s.lr(10_000));
    }

    #[test]
    fn lora_plus_ratio_applied() {
        let s = LrSchedule::constant(1e-4, 16.0);
        let (lr, lr_b) = s.lr_pair(5);
        assert!((lr_b / lr - 16.0).abs() < 1e-5);
    }

    #[test]
    fn classification() {
        assert_eq!(classify_param("layer_00.wq_a"), ParamGroup::LoraA);
        assert_eq!(classify_param("layer_00.wq_b"), ParamGroup::LoraB);
        assert_eq!(classify_param("embed"), ParamGroup::Other);
        assert_eq!(classify_param("layer_01.norm1"), ParamGroup::Other);
    }
}
